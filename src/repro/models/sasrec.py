"""SASRec: self-attentive sequential recommendation (arXiv:1808.09781).

Config: embed_dim=50, 2 blocks, 1 head, seq_len=50.  The item table is the
dominant state (n_items x d, row-sharded over the 'items'/model axis —
recsys EP).  Lookups go through :func:`repro.models.layers.embedding_bag`
machinery (gather + segment ops; JAX has no native EmbeddingBag).

Steps provided:
* ``train_loss``      — BCE with one sampled negative per position (paper);
* ``user_embedding``  — encode a behavior sequence;
* ``score_all``       — user x full-catalog scores (serve_p99/serve_bulk);
* ``score_candidates``— one user vs n_candidates gathered items
                        (retrieval_cand; batched dot, not a loop).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import RecsysConfig
from ..distributed.sharding import shard
from .layers import dense_init, flash_attention, layer_norm

__all__ = [
    "init_params",
    "logical_axes",
    "user_embedding",
    "train_loss",
    "score_all",
    "score_candidates",
]


def _dt(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[name]


def init_params(key, cfg: RecsysConfig) -> Dict:
    pdt = _dt(cfg.param_dtype)
    d = cfg.d
    ks = jax.random.split(key, 2 + 6 * cfg.n_blocks)
    params = {
        "item_embed": (jax.random.normal(ks[0], (cfg.n_items, d)) * 0.02).astype(pdt),
        "pos_embed": (jax.random.normal(ks[1], (cfg.seq_len, d)) * 0.02).astype(pdt),
        "blocks": [],
        "final_ln": jnp.ones((d,), pdt),
        "final_ln_b": jnp.zeros((d,), pdt),
    }
    blocks = []
    for i in range(cfg.n_blocks):
        o = 2 + 6 * i
        blocks.append(
            {
                "wq": dense_init(ks[o], d, d, pdt),
                "wk": dense_init(ks[o + 1], d, d, pdt),
                "wv": dense_init(ks[o + 2], d, d, pdt),
                "w1": dense_init(ks[o + 3], d, d, pdt),
                "w2": dense_init(ks[o + 4], d, d, pdt),
                "ln1": jnp.ones((d,), pdt),
                "ln1_b": jnp.zeros((d,), pdt),
                "ln2": jnp.ones((d,), pdt),
                "ln2_b": jnp.zeros((d,), pdt),
            }
        )
    params["blocks"] = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *blocks)
    return params


def logical_axes(cfg: RecsysConfig) -> Dict:
    blk = {
        "wq": (None, None, "ff"), "wk": (None, None, "ff"),
        "wv": (None, None, "ff"), "w1": (None, None, "ff"),
        "w2": (None, "ff", None),
        "ln1": (None, None), "ln1_b": (None, None),
        "ln2": (None, None), "ln2_b": (None, None),
    }
    return {
        "item_embed": ("items", None),
        "pos_embed": (None, None),
        "blocks": blk,
        "final_ln": (None,),
        "final_ln_b": (None,),
    }


def user_embedding(
    params: Dict, seqs: jnp.ndarray, cfg: RecsysConfig
) -> jnp.ndarray:
    """seqs: (B, L) item ids, 0 = padding. Returns (B, L, d) states."""
    adt = _dt(cfg.dtype)
    B, L = seqs.shape
    d = cfg.d
    x = jnp.take(params["item_embed"], seqs, axis=0).astype(adt)
    x = x * np.sqrt(d) + params["pos_embed"][None, :L].astype(adt)
    mask = (seqs > 0)
    x = x * mask[..., None].astype(adt)
    x = shard(x, "batch", None, None)

    def block(x, bp):
        h = layer_norm(x, bp["ln1"], bp["ln1_b"])
        q = (h @ bp["wq"].astype(adt)).reshape(B, L, cfg.n_heads, d // cfg.n_heads)
        k = (h @ bp["wk"].astype(adt)).reshape(B, L, cfg.n_heads, d // cfg.n_heads)
        v = (h @ bp["wv"].astype(adt)).reshape(B, L, cfg.n_heads, d // cfg.n_heads)
        attn = flash_attention(
            q, k, v, causal=True, block_q=min(64, L), block_kv=min(64, L),
        )
        x = x + attn.reshape(B, L, d)
        h = layer_norm(x, bp["ln2"], bp["ln2_b"])
        h = jax.nn.relu(h @ bp["w1"].astype(adt)) @ bp["w2"].astype(adt)
        x = (x + h) * mask[..., None].astype(adt)
        return x, None

    x, _ = jax.lax.scan(block, x, params["blocks"])
    x = layer_norm(x, params["final_ln"], params["final_ln_b"])
    return x


def train_loss(
    params: Dict,
    seqs: jnp.ndarray,        # (B, L) inputs
    pos_items: jnp.ndarray,   # (B, L) next-item targets (0 = pad)
    neg_items: jnp.ndarray,   # (B, L) sampled negatives
    cfg: RecsysConfig,
) -> jnp.ndarray:
    states = user_embedding(params, seqs, cfg)  # (B, L, d)
    pe = jnp.take(params["item_embed"], pos_items, axis=0).astype(states.dtype)
    ne = jnp.take(params["item_embed"], neg_items, axis=0).astype(states.dtype)
    pos_logit = jnp.sum(states * pe, axis=-1).astype(jnp.float32)
    neg_logit = jnp.sum(states * ne, axis=-1).astype(jnp.float32)
    mask = (pos_items > 0).astype(jnp.float32)
    loss = -(
        jax.nn.log_sigmoid(pos_logit) + jax.nn.log_sigmoid(-neg_logit)
    )
    return jnp.sum(loss * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def score_all(
    params: Dict,
    seqs: jnp.ndarray,
    cfg: RecsysConfig,
    top_k: int = 10,
    item_chunks: int = 16,
    batch_chunk: Optional[int] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Last-position user embedding x full catalog -> (scores, ids) top-k.

    Two-stage top-k: a per-item-chunk top-k (chunk axis rides the 'items'
    mesh axis, so stage 1 is shard-local) followed by a tiny global merge —
    the full (B, n_items) logits never need to be gathered.  ``batch_chunk``
    additionally tiles huge offline-scoring batches (serve_bulk) so the
    logits working set stays bounded.
    """
    states = user_embedding(params, seqs, cfg)
    u = states[:, -1]  # (B, d)
    u = shard(u, "batch", None)
    n_items = params["item_embed"].shape[0]
    while n_items % item_chunks:
        item_chunks -= 1  # smoke-scale catalogs: fall back gracefully
    chunk = n_items // item_chunks
    table = params["item_embed"].reshape(item_chunks, chunk, cfg.d)

    def score_block(u_blk):
        logits = jnp.einsum(
            "bd,cnd->bcn", u_blk, table.astype(u_blk.dtype),
            preferred_element_type=jnp.float32,
        )
        logits = shard(logits, "batch", "items", None)
        s, i = jax.lax.top_k(logits, top_k)               # (b, chunks, k)
        i = i + (jnp.arange(item_chunks, dtype=jnp.int32) * chunk)[None, :, None]
        s2, idx = jax.lax.top_k(s.reshape(s.shape[0], -1), top_k)
        ids = jnp.take_along_axis(i.reshape(i.shape[0], -1), idx, axis=-1)
        return s2, ids

    if batch_chunk is None or u.shape[0] <= batch_chunk:
        return score_block(u)
    nb = u.shape[0] // batch_chunk
    s, ids = jax.lax.map(score_block, u.reshape(nb, batch_chunk, -1))
    return s.reshape(u.shape[0], top_k), ids.reshape(u.shape[0], top_k)


def score_candidates(
    params: Dict,
    seqs: jnp.ndarray,          # (B, L)
    candidates: jnp.ndarray,    # (B, n_cand) item ids
    cfg: RecsysConfig,
) -> jnp.ndarray:
    """Batched dot against a candidate set (retrieval scoring)."""
    states = user_embedding(params, seqs, cfg)
    u = states[:, -1]
    cand = jnp.take(params["item_embed"], candidates, axis=0).astype(u.dtype)
    return jnp.einsum("bd,bnd->bn", u, cand, preferred_element_type=jnp.float32)
