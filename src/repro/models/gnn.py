"""GNN zoo: MeshGraphNet, GraphCast, SchNet, DimeNet.

All message passing is expressed as gather + ``segment_sum`` over an edge
index — the same machinery as the condensed-graph engine (DESIGN.md §4):
JAX has no CSR SpMM, so scatter/segment ops ARE the system here.

Input container: :class:`GraphBatch` — one (possibly batched, padded)
graph.  Molecular nets (SchNet/DimeNet) need ``positions``; DimeNet needs
``triplets`` (edge-pair index list: k->j->i built by
:mod:`repro.data.graphs`).  Masks make padding inert.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import GNNConfig
from ..distributed.sharding import shard
from .layers import mlp_apply, mlp_init, layer_norm

__all__ = ["GraphBatch", "init_params", "forward"]


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=[
        "nodes", "positions", "edge_src", "edge_dst", "edge_feat",
        "node_mask", "edge_mask", "graph_ids", "triplets", "triplet_mask",
    ],
    meta_fields=["n_graphs"],
)
@dataclasses.dataclass
class GraphBatch:
    nodes: jnp.ndarray                      # (N, d_in)
    edge_src: jnp.ndarray                   # (E,) int32
    edge_dst: jnp.ndarray                   # (E,) int32
    node_mask: jnp.ndarray                  # (N,) bool
    edge_mask: jnp.ndarray                  # (E,) bool
    positions: Optional[jnp.ndarray] = None  # (N, 3)
    edge_feat: Optional[jnp.ndarray] = None  # (E, d_e)
    graph_ids: Optional[jnp.ndarray] = None  # (N,) for batched small graphs
    triplets: Optional[jnp.ndarray] = None   # (T, 2) = (edge_kj, edge_ji)
    triplet_mask: Optional[jnp.ndarray] = None
    n_graphs: int = 1

    @property
    def n_nodes(self) -> int:
        return self.nodes.shape[0]

    @property
    def n_edges(self) -> int:
        return self.edge_src.shape[0]


def _dt(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[name]


def _seg_sum(vals, ids, n):
    return jax.ops.segment_sum(vals, ids, num_segments=n)


def _rbf(dist: jnp.ndarray, n_rbf: int, cutoff: float) -> jnp.ndarray:
    """Gaussian radial basis on [0, cutoff] (SchNet §3)."""
    mu = jnp.linspace(0.0, cutoff, n_rbf, dtype=jnp.float32)
    gamma = n_rbf / cutoff
    return jnp.exp(-gamma * (dist[:, None] - mu[None, :]) ** 2)


def _edge_geometry(g: GraphBatch):
    rel = jnp.take(g.positions, g.edge_dst, axis=0) - jnp.take(
        g.positions, g.edge_src, axis=0
    )
    dist = jnp.sqrt(jnp.maximum(jnp.sum(rel * rel, axis=-1), 1e-12))
    return rel, dist


# ---------------------------------------------------------------------------
# MeshGraphNet / GraphCast: encode-process-decode, edge+node latents.
# ---------------------------------------------------------------------------

def _epd_init(key, cfg: GNNConfig, d_in: int, d_edge_in: int, dtype):
    h = cfg.d_hidden
    mlp_dims = [h] * cfg.mlp_layers
    ks = jax.random.split(key, 4 + 2 * cfg.n_layers)
    params = {
        "node_enc": mlp_init(ks[0], [d_in] + mlp_dims, dtype),
        "edge_enc": mlp_init(ks[1], [d_edge_in] + mlp_dims, dtype),
        "decoder": mlp_init(ks[2], [h] + mlp_dims[:-1] + [cfg.d_out], dtype),
        "blocks": [],
    }
    blocks = []
    for i in range(cfg.n_layers):
        blocks.append(
            {
                "edge_mlp": mlp_init(ks[3 + 2 * i], [3 * h] + mlp_dims, dtype),
                "node_mlp": mlp_init(ks[4 + 2 * i], [2 * h] + mlp_dims, dtype),
                "ln_e": jnp.ones((h,), dtype),
                "ln_e_b": jnp.zeros((h,), dtype),
                "ln_n": jnp.ones((h,), dtype),
                "ln_n_b": jnp.zeros((h,), dtype),
            }
        )
    # stack blocks for scan
    params["blocks"] = jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs), *blocks
    )
    return params


def _epd_forward(params, g: GraphBatch, cfg: GNNConfig):
    adt = _dt(cfg.dtype)
    n, e = g.n_nodes, g.n_edges
    h = mlp_apply(params["node_enc"], g.nodes.astype(adt))
    h = shard(h, "nodes", None)
    if g.edge_feat is not None:
        ef = g.edge_feat.astype(adt)
    elif g.positions is not None:
        rel, dist = _edge_geometry(g)
        ef = jnp.concatenate([rel, dist[:, None]], axis=-1).astype(adt)
    else:
        # structural fallback: featureless edges
        ef = jnp.ones((e, 1), adt)
    he = mlp_apply(params["edge_enc"], ef)
    he = shard(he, "edges", None)
    emask = g.edge_mask[:, None].astype(adt)
    nmask = g.node_mask[:, None].astype(adt)

    def block(carry, bp):
        h, he = carry
        src_h = jnp.take(h, g.edge_src, axis=0)
        dst_h = jnp.take(h, g.edge_dst, axis=0)
        e_upd = mlp_apply(bp["edge_mlp"], jnp.concatenate([he, src_h, dst_h], -1))
        he = layer_norm(he + e_upd * emask, bp["ln_e"], bp["ln_e_b"])
        agg = _seg_sum(he * emask, g.edge_dst, n)
        if cfg.aggregator == "mean":
            deg = _seg_sum(emask, g.edge_dst, n)
            agg = agg / jnp.maximum(deg, 1.0)
        n_upd = mlp_apply(bp["node_mlp"], jnp.concatenate([h, agg], -1))
        h = layer_norm(h + n_upd * nmask, bp["ln_n"], bp["ln_n_b"])
        h = shard(h, "nodes", None)
        he = shard(he, "edges", None)
        return (h, he), None

    (h, he), _ = jax.lax.scan(block, (h, he), params["blocks"])
    out = mlp_apply(params["decoder"], h) * nmask
    return shard(out, "nodes", None)


# ---------------------------------------------------------------------------
# SchNet: continuous-filter convolutions.
# ---------------------------------------------------------------------------

def _schnet_init(key, cfg: GNNConfig, d_in: int, dtype):
    h = cfg.d_hidden
    ks = jax.random.split(key, 2 + 3 * cfg.n_layers)
    params = {
        "embed": mlp_init(ks[0], [d_in, h], dtype),
        "out": mlp_init(ks[1], [h, h, cfg.d_out], dtype),
        "blocks": [],
    }
    blocks = []
    for i in range(cfg.n_layers):
        blocks.append(
            {
                "filter": mlp_init(ks[2 + 3 * i], [cfg.n_rbf, h, h], dtype),
                "in_lin": mlp_init(ks[3 + 3 * i], [h, h], dtype),
                "post": mlp_init(ks[4 + 3 * i], [h, h, h], dtype),
            }
        )
    params["blocks"] = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *blocks)
    return params


def _schnet_forward(params, g: GraphBatch, cfg: GNNConfig):
    adt = _dt(cfg.dtype)
    n = g.n_nodes
    if g.positions is None:
        raise ValueError("SchNet needs positions")
    _, dist = _edge_geometry(g)
    rbf = _rbf(dist, cfg.n_rbf, cfg.cutoff).astype(adt)
    # smooth cutoff envelope
    env = 0.5 * (jnp.cos(jnp.pi * jnp.clip(dist / cfg.cutoff, 0, 1)) + 1.0)
    emask = (g.edge_mask * (dist < cfg.cutoff)).astype(adt) * env.astype(adt)
    h = mlp_apply(params["embed"], g.nodes.astype(adt))

    def block(h, bp):
        w = mlp_apply(bp["filter"], rbf, activation=jax.nn.softplus)  # (E, h)
        src = jnp.take(mlp_apply(bp["in_lin"], h), g.edge_src, axis=0)
        msg = src * w * emask[:, None]
        agg = _seg_sum(msg, g.edge_dst, n)
        h = h + mlp_apply(bp["post"], agg, activation=jax.nn.softplus)
        return shard(h, "nodes", None), None

    h, _ = jax.lax.scan(block, h, params["blocks"])
    out = mlp_apply(params["out"], h, activation=jax.nn.softplus)
    out = out * g.node_mask[:, None].astype(adt)
    if g.graph_ids is not None:
        return _seg_sum(out, g.graph_ids, g.n_graphs)  # per-molecule energy
    return out


# ---------------------------------------------------------------------------
# DimeNet: directional message passing over edge messages + triplets.
# ---------------------------------------------------------------------------

def _sbf(dist_kj: jnp.ndarray, angle: jnp.ndarray, cfg: GNNConfig) -> jnp.ndarray:
    """Simplified spherical basis: radial sinc-like × angular cos(l θ).

    (DimeNet uses Bessel bases; we keep the tensor structure
    n_radial × n_spherical — noted in DESIGN.md as a TPU-friendly
    simplification that preserves shape/compute characteristics.)
    """
    nr, ns = cfg.n_radial, cfg.n_spherical
    freq = jnp.arange(1, nr + 1, dtype=jnp.float32) * jnp.pi
    d = jnp.clip(dist_kj / cfg.cutoff, 1e-4, 1.0)
    radial = jnp.sin(freq * d[:, None]) / d[:, None]            # (T, nr)
    ls = jnp.arange(ns, dtype=jnp.float32)
    angular = jnp.cos(ls[None, :] * angle[:, None])             # (T, ns)
    return (radial[:, :, None] * angular[:, None, :]).reshape(
        dist_kj.shape[0], nr * ns
    )


def _dimenet_init(key, cfg: GNNConfig, d_in: int, dtype):
    h = cfg.d_hidden
    nb = cfg.n_bilinear
    sbf_dim = cfg.n_radial * cfg.n_spherical
    ks = jax.random.split(key, 4 + 4 * cfg.n_layers)
    params = {
        "embed_node": mlp_init(ks[0], [d_in, h], dtype),
        "embed_msg": mlp_init(ks[1], [2 * h + cfg.n_rbf, h], dtype),
        "rbf_out": mlp_init(ks[2], [cfg.n_rbf, h], dtype),
        "out": mlp_init(ks[3], [h, h, cfg.d_out], dtype),
        "blocks": [],
    }
    blocks = []
    for i in range(cfg.n_layers):
        k1, k2, k3, k4 = jax.random.split(ks[4 + i], 4)
        blocks.append(
            {
                "sbf_lin": mlp_init(k1, [sbf_dim, nb], dtype),
                "msg_lin": mlp_init(k2, [h, nb * h], dtype),
                "bilinear": (jax.random.normal(k3, (nb, h, h)) / np.sqrt(h)).astype(dtype),
                "update": mlp_init(k4, [h, h, h], dtype),
            }
        )
    params["blocks"] = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *blocks)
    return params


def _dimenet_forward(params, g: GraphBatch, cfg: GNNConfig):
    adt = _dt(cfg.dtype)
    if g.positions is None or g.triplets is None:
        raise ValueError("DimeNet needs positions and triplets")
    n, e = g.n_nodes, g.n_edges
    rel, dist = _edge_geometry(g)
    rbf = _rbf(dist, cfg.n_rbf, cfg.cutoff).astype(adt)
    emask = g.edge_mask.astype(adt)

    h = mlp_apply(params["embed_node"], g.nodes.astype(adt))
    src_h = jnp.take(h, g.edge_src, axis=0)
    dst_h = jnp.take(h, g.edge_dst, axis=0)
    m = mlp_apply(params["embed_msg"], jnp.concatenate([src_h, dst_h, rbf], -1))
    m = m * emask[:, None]
    m = shard(m, "edges", None)

    # triplet geometry: k->j (edge_kj) then j->i (edge_ji)
    idx_kj = g.triplets[:, 0]
    idx_ji = g.triplets[:, 1]
    tmask = (
        g.triplet_mask.astype(adt)
        if g.triplet_mask is not None
        else jnp.ones((g.triplets.shape[0],), adt)
    )
    v_kj = jnp.take(rel, idx_kj, axis=0)
    v_ji = jnp.take(rel, idx_ji, axis=0)
    cosang = jnp.sum(-v_kj * v_ji, axis=-1) / (
        jnp.maximum(jnp.linalg.norm(v_kj, axis=-1) * jnp.linalg.norm(v_ji, axis=-1), 1e-9)
    )
    angle = jnp.arccos(jnp.clip(cosang, -1 + 1e-6, 1 - 1e-6))
    sbf = _sbf(jnp.take(dist, idx_kj), angle, cfg).astype(adt)

    def block(m, bp):
        nb = cfg.n_bilinear
        hdim = cfg.d_hidden
        a = mlp_apply(bp["sbf_lin"], sbf)                       # (T, nb)
        mk = jnp.take(m, idx_kj, axis=0)                        # (T, h)
        # bilinear: sum_b a_b * (mk @ W_b)
        mw = jnp.einsum("th,bhg->tbg", mk, bp["bilinear"].astype(m.dtype))
        tri_msg = jnp.einsum("tb,tbg->tg", a, mw) * tmask[:, None]
        agg = _seg_sum(tri_msg, idx_ji, e)                      # per target edge
        m = m + mlp_apply(bp["update"], agg, activation=jax.nn.silu)
        return shard(m * emask[:, None], "edges", None), None

    m, _ = jax.lax.scan(block, m, params["blocks"])
    w = mlp_apply(params["rbf_out"], rbf)
    node_out = _seg_sum(m * w * emask[:, None], g.edge_dst, n)
    out = mlp_apply(params["out"], node_out, activation=jax.nn.silu)
    out = out * g.node_mask[:, None].astype(adt)
    if g.graph_ids is not None:
        return _seg_sum(out, g.graph_ids, g.n_graphs)
    return out


# ---------------------------------------------------------------------------
# Dispatch
# ---------------------------------------------------------------------------

def init_params(key, cfg: GNNConfig, d_in: int, d_edge_in: int = 4) -> Dict:
    dtype = _dt(cfg.param_dtype)
    if cfg.kind in ("meshgraphnet", "graphcast"):
        return _epd_init(key, cfg, d_in, d_edge_in, dtype)
    if cfg.kind == "schnet":
        return _schnet_init(key, cfg, d_in, dtype)
    if cfg.kind == "dimenet":
        return _dimenet_init(key, cfg, d_in, dtype)
    raise ValueError(cfg.kind)


def forward(params: Dict, g: GraphBatch, cfg: GNNConfig) -> jnp.ndarray:
    fwd = {
        "meshgraphnet": _epd_forward,
        "graphcast": _epd_forward,
        "schnet": _schnet_forward,
        "dimenet": _dimenet_forward,
    }[cfg.kind]
    if cfg.remat_policy != "none":
        base = fwd
        fwd2 = jax.checkpoint(
            lambda p, gb: base(p, gb, cfg),
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
        )
        return fwd2(params, g)
    return fwd(params, g, cfg)
