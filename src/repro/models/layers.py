"""Shared neural building blocks (pure JAX, framework-local).

Everything here is functional: ``init_*`` builds parameter pytrees,
apply functions are pure.  Tensors are annotated with logical axes via
:func:`repro.distributed.sharding.shard` (no-op without a mesh context).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..distributed.sharding import shard

__all__ = [
    "dense_init",
    "rms_norm",
    "layer_norm",
    "mlp_init",
    "mlp_apply",
    "rope",
    "flash_attention",
    "embedding_bag",
]


def dense_init(key, in_dim: int, out_dim: int, dtype=jnp.float32, scale=None):
    scale = scale if scale is not None else 1.0 / np.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim)) * scale).astype(dtype)


def rms_norm(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(dtype) * weight.astype(dtype)


def layer_norm(
    x: jnp.ndarray, weight: jnp.ndarray, bias: jnp.ndarray, eps: float = 1e-5
) -> jnp.ndarray:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(dtype) * weight.astype(dtype) + bias.astype(dtype)


def mlp_init(key, dims, dtype=jnp.float32):
    """Plain MLP parameter stack: dims = [in, hidden..., out]."""
    keys = jax.random.split(key, len(dims) - 1)
    return {
        f"w{i}": dense_init(k, dims[i], dims[i + 1], dtype)
        for i, k in enumerate(keys)
    } | {
        f"b{i}": jnp.zeros((dims[i + 1],), dtype) for i in range(len(dims) - 1)
    }


def mlp_apply(params, x, activation=jax.nn.gelu, final_activation=False):
    n = len([k for k in params if k.startswith("w")])
    for i in range(n):
        x = x @ params[f"w{i}"].astype(x.dtype) + params[f"b{i}"].astype(x.dtype)
        if i < n - 1 or final_activation:
            x = activation(x)
    return x


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotary embedding, llama split-half convention.

    x: (..., T, n_heads, head_dim); positions: broadcastable to (..., T).
    """
    head_dim = x.shape[-1]
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., T, half)
    cos = jnp.cos(angles)[..., :, None, :]  # (..., T, 1, half)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# Blockwise (flash) attention with GQA — the memory-safe default path.
# ---------------------------------------------------------------------------

def _flash_impl(
    q, k, v, causal, q_offset, kv_length, block_q, block_kv,
    return_lse: bool = False,
):
    """Online-softmax blockwise attention core (padded internally)."""
    B, Tq, H, D = q.shape
    _, Tk, KV, _ = k.shape
    if H % KV:
        raise ValueError(f"H={H} not a multiple of KV={KV}")
    G = H // KV
    block_q = min(block_q, Tq)
    block_kv = min(block_kv, Tk)
    pad_q = (-Tq) % block_q
    pad_kv = (-Tk) % block_kv
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
    Tq_p, Tk_p = Tq + pad_q, Tk + pad_kv
    nq, nkv = Tq_p // block_q, Tk_p // block_kv

    qg = q.reshape(B, nq, block_q, KV, G, D)
    kg = k.reshape(B, nkv, block_kv, KV, D)
    vg = v.reshape(B, nkv, block_kv, KV, D)
    scale = 1.0 / np.sqrt(D)
    q_off = jnp.asarray(q_offset, dtype=jnp.int32)
    kv_valid = jnp.full((B,), Tk, dtype=jnp.int32) if kv_length is None else kv_length

    def q_block(carry, qi):
        qb = qg[:, qi]  # (B, bq, KV, G, D)
        q_pos = q_off + qi * block_q + jnp.arange(block_q, dtype=jnp.int32)

        def kv_block(state, ki):
            acc, m, l = state
            kb = kg[:, ki]
            vb = vg[:, ki]
            s = jnp.einsum(
                "bqkgd,bskd->bqkgs", qb, kb, preferred_element_type=jnp.float32
            ) * scale
            kv_pos = ki * block_kv + jnp.arange(block_kv, dtype=jnp.int32)
            mask = kv_pos[None, :] < kv_valid[:, None]  # (B, bkv) padding
            if causal:
                mask = mask[:, None, :] & (
                    kv_pos[None, None, :] <= q_pos[None, :, None]
                )  # (B, bq, bkv)
                s = jnp.where(mask[:, :, None, None, :], s, -jnp.inf)
            else:
                s = jnp.where(mask[:, None, None, None, :], s, -jnp.inf)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            # guard all -inf rows
            m_safe = jnp.where(jnp.isneginf(m_new), 0.0, m_new)
            p = jnp.exp(s - m_safe[..., None])
            p = jnp.where(jnp.isneginf(s), 0.0, p)
            alpha = jnp.where(jnp.isneginf(m), 0.0, jnp.exp(m - m_safe))
            l_new = l * alpha + jnp.sum(p, axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bqkgs,bskd->bqkgd", p.astype(vb.dtype), vb,
                preferred_element_type=jnp.float32,
            )
            return (acc_new, m_new, l_new), None

        init = (
            jnp.zeros((B, block_q, KV, G, D), jnp.float32),
            jnp.full((B, block_q, KV, G), -jnp.inf, jnp.float32),
            jnp.zeros((B, block_q, KV, G), jnp.float32),
        )
        (acc, m, l), _ = jax.lax.scan(kv_block, init, jnp.arange(nkv))
        out = acc / jnp.maximum(l[..., None], 1e-20)
        # logsumexp per row; +inf for fully-masked rows so recomputed p = 0
        lse = jnp.where(l > 0, m + jnp.log(jnp.maximum(l, 1e-30)), jnp.inf)
        return carry, (out.astype(q.dtype), lse)

    _, (blocks, lses) = jax.lax.scan(q_block, None, jnp.arange(nq))
    out = jnp.moveaxis(blocks, 0, 1).reshape(B, Tq_p, KV, G, D)
    out = out[:, :Tq].reshape(B, Tq, H, D)
    if return_lse:
        lse = jnp.moveaxis(lses, 0, 1).reshape(B, Tq_p, KV, G)[:, :Tq]
        return out, lse
    return out


# -- FlashAttention backward: recompute p per block from saved (q,k,v,lse) —
# nothing quadratic is ever saved (this is the paper-exact FA bwd dataflow).

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _flash_train(q, k, v, causal, block_q, block_kv):
    return _flash_impl(q, k, v, causal, 0, None, block_q, block_kv)


def _flash_train_fwd(q, k, v, causal, block_q, block_kv):
    out, lse = _flash_impl(
        q, k, v, causal, 0, None, block_q, block_kv, return_lse=True
    )
    return out, (q, k, v, out, lse)


def _flash_train_bwd(causal, block_q, block_kv, res, do):
    q, k, v, out, lse = res
    B, Tq, H, D = q.shape
    _, Tk, KV, _ = k.shape
    G = H // KV
    block_q = min(block_q, Tq)
    block_kv = min(block_kv, Tk)
    pad_q = (-Tq) % block_q
    pad_kv = (-Tk) % block_kv
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0))) if pad_q else q
    dop = jnp.pad(do, ((0, 0), (0, pad_q), (0, 0), (0, 0))) if pad_q else do
    outp = jnp.pad(out, ((0, 0), (0, pad_q), (0, 0), (0, 0))) if pad_q else out
    lsep = (
        jnp.pad(lse, ((0, 0), (0, pad_q), (0, 0), (0, 0)),
                constant_values=jnp.inf)
        if pad_q else lse
    )
    kp = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0))) if pad_kv else k
    vp = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0))) if pad_kv else v
    Tq_p, Tk_p = Tq + pad_q, Tk + pad_kv
    nq, nkv = Tq_p // block_q, Tk_p // block_kv

    qg = qp.reshape(B, nq, block_q, KV, G, D)
    dog = dop.reshape(B, nq, block_q, KV, G, D)
    lseg = lsep.reshape(B, nq, block_q, KV, G)
    # delta = rowsum(do * out)
    deltag = jnp.sum(
        dop.reshape(B, nq, block_q, KV, G, D).astype(jnp.float32)
        * outp.reshape(B, nq, block_q, KV, G, D).astype(jnp.float32),
        axis=-1,
    )
    kg = kp.reshape(B, nkv, block_kv, KV, D)
    vg = vp.reshape(B, nkv, block_kv, KV, D)
    scale = 1.0 / np.sqrt(D)

    def q_block(carry, qi):
        dk_acc, dv_acc = carry
        qb = qg[:, qi].astype(jnp.float32)
        dob = dog[:, qi].astype(jnp.float32)
        lseb = lseg[:, qi]
        deltab = deltag[:, qi]
        q_pos = qi * block_q + jnp.arange(block_q, dtype=jnp.int32)

        def kv_block(carry2, ki):
            dqb, dk_acc, dv_acc = carry2
            kb = kg[:, ki].astype(jnp.float32)
            vb = vg[:, ki].astype(jnp.float32)
            s = jnp.einsum("bqkgd,bskd->bqkgs", qb, kb) * scale
            kv_pos = ki * block_kv + jnp.arange(block_kv, dtype=jnp.int32)
            mask = kv_pos[None, :] < Tk  # padding mask (B-broadcast)
            if causal:
                cm = kv_pos[None, None, :] <= q_pos[None, :, None]
                s = jnp.where((mask[:, None, :] & cm)[:, :, None, None, :], s, -jnp.inf)
            else:
                s = jnp.where(mask[:, None, None, None, :], s, -jnp.inf)
            p = jnp.exp(s - lseb[..., None])          # rows with lse=inf -> 0
            p = jnp.where(jnp.isneginf(s), 0.0, p)
            dp = jnp.einsum("bqkgd,bskd->bqkgs", dob, vb)
            ds = p * (dp - deltab[..., None])
            dqb = dqb + scale * jnp.einsum("bqkgs,bskd->bqkgd", ds, kb)
            dk_blk = scale * jnp.einsum("bqkgs,bqkgd->bskd", ds, qb)
            dv_blk = jnp.einsum("bqkgs,bqkgd->bskd", p, dob)
            dk_acc = dk_acc.at[:, ki].add(dk_blk)
            dv_acc = dv_acc.at[:, ki].add(dv_blk)
            return (dqb, dk_acc, dv_acc), None

        dqb0 = jnp.zeros((B, block_q, KV, G, D), jnp.float32)
        (dqb, dk_acc, dv_acc), _ = jax.lax.scan(
            kv_block, (dqb0, dk_acc, dv_acc), jnp.arange(nkv)
        )
        return (dk_acc, dv_acc), dqb

    dk0 = jnp.zeros((B, nkv, block_kv, KV, D), jnp.float32)
    dv0 = jnp.zeros((B, nkv, block_kv, KV, D), jnp.float32)
    (dk_acc, dv_acc), dq_blocks = jax.lax.scan(
        q_block, (dk0, dv0), jnp.arange(nq)
    )
    dq = jnp.moveaxis(dq_blocks, 0, 1).reshape(B, Tq_p, KV, G, D)[:, :Tq]
    dq = dq.reshape(B, Tq, H, D).astype(q.dtype)
    dk = dk_acc.reshape(B, Tk_p, KV, D)[:, :Tk].astype(k.dtype)
    dv = dv_acc.reshape(B, Tk_p, KV, D)[:, :Tk].astype(v.dtype)
    return dq, dk, dv


_flash_train.defvjp(_flash_train_fwd, _flash_train_bwd)


def flash_attention(
    q: jnp.ndarray,             # (B, Tq, H, D)
    k: jnp.ndarray,             # (B, Tk, KV, D)
    v: jnp.ndarray,             # (B, Tk, KV, D)
    *,
    causal: bool = True,
    q_offset: jnp.ndarray | int = 0,   # absolute position of q[0] (decode)
    kv_length: Optional[jnp.ndarray] = None,  # valid kv prefix length (B,)
    block_q: int = 512,
    block_kv: int = 1024,
) -> jnp.ndarray:
    """Blockwise attention; never materializes (Tq, Tk) — in either pass.

    GQA: H must be a multiple of KV; query heads are grouped over kv heads.
    The training path (no cache: ``q_offset == 0``, ``kv_length is None``)
    runs a custom-VJP FlashAttention backward that recomputes probability
    blocks from (q, k, v, lse); cache/serving paths use the plain forward.
    """
    train_path = kv_length is None and isinstance(q_offset, int) and q_offset == 0
    if train_path:
        return _flash_train(q, k, v, causal, block_q, block_kv)
    return _flash_impl(q, k, v, causal, q_offset, kv_length, block_q, block_kv)


# ---------------------------------------------------------------------------
# EmbeddingBag — JAX has no native one (kernel taxonomy §RecSys): gather +
# segment-reduce, the recsys hot path and the condensed engine's sibling.
# ---------------------------------------------------------------------------

def embedding_bag(
    table: jnp.ndarray,          # (n_items, d)
    indices: jnp.ndarray,        # (n_lookups,)
    segment_ids: jnp.ndarray,    # (n_lookups,) -> bag id
    num_bags: int,
    mode: str = "sum",
    weights: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    rows = jnp.take(table, indices, axis=0)
    if weights is not None:
        rows = rows * weights[:, None].astype(rows.dtype)
    if mode == "sum":
        return jax.ops.segment_sum(rows, segment_ids, num_segments=num_bags)
    if mode == "mean":
        s = jax.ops.segment_sum(rows, segment_ids, num_segments=num_bags)
        n = jax.ops.segment_sum(
            jnp.ones_like(segment_ids, dtype=rows.dtype), segment_ids, num_bags
        )
        return s / jnp.maximum(n, 1.0)[:, None]
    if mode == "max":
        out = jax.ops.segment_max(rows, segment_ids, num_segments=num_bags)
        return jnp.where(jnp.isneginf(out), 0.0, out)
    raise ValueError(mode)
