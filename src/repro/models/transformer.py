"""Decoder-only transformer LM: GQA + RoPE, dense or MoE FFN.

Production posture:

* layers stacked + ``jax.lax.scan`` (O(1) HLO in depth, MaxText-style);
* selectable remat policy on the layer body;
* blockwise (flash) attention — (Tq, Tk) never materialized;
* KV cache for serving (prefill writes a prefix, decode appends);
* logical-axis sharding annotations throughout.

Param pytree (leaves stacked over layers under "layers"):

    embed (V, D); layers/{ln1, ln2 (L, D), attn/{wq, wk, wv, wo},
    mlp/{w_gate, w_up, w_down} or moe/{router, w_gate, w_up, w_down}};
    final_norm (D,); lm_head (D, V) unless tied.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import TransformerConfig
from ..distributed.sharding import shard
from . import moe as moe_lib
from .layers import dense_init, flash_attention, rms_norm, rope

__all__ = ["init_params", "logical_axes", "forward", "KVCache", "init_cache"]


def _dt(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[name]


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def init_params(key, cfg: TransformerConfig) -> Dict:
    pdt = _dt(cfg.param_dtype)
    hd = cfg.resolved_head_dim
    D, H, KV, L = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.n_layers
    k_embed, k_head, k_layers = jax.random.split(key, 3)

    def layer_stack(k):
        ks = jax.random.split(k, 8)
        attn = {
            "wq": dense_init(ks[0], D, H * hd, pdt),
            "wk": dense_init(ks[1], D, KV * hd, pdt),
            "wv": dense_init(ks[2], D, KV * hd, pdt),
            "wo": dense_init(ks[3], H * hd, D, pdt),
        }
        if cfg.moe is not None:
            ffn = {"moe": moe_lib.moe_init(ks[4], D, cfg.moe, pdt)}
        else:
            ffn = {
                "mlp": {
                    "w_gate": dense_init(ks[5], D, cfg.d_ff, pdt),
                    "w_up": dense_init(ks[6], D, cfg.d_ff, pdt),
                    "w_down": dense_init(ks[7], cfg.d_ff, D, pdt),
                }
            }
        return {
            "attn": attn,
            **ffn,
            "ln1": jnp.ones((D,), pdt),
            "ln2": jnp.ones((D,), pdt),
        }

    layer_keys = jax.random.split(k_layers, L)
    layers = jax.vmap(layer_stack)(layer_keys)
    params = {
        "embed": (jax.random.normal(k_embed, (cfg.vocab_size, D)) * 0.02).astype(pdt),
        "layers": layers,
        "final_norm": jnp.ones((D,), pdt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(k_head, D, cfg.vocab_size, pdt)
    return params


def logical_axes(cfg: TransformerConfig) -> Dict:
    """Same structure as init_params, leaves = logical axis tuples."""
    attn = {
        "wq": (None, "embed_param", "heads"),
        "wk": (None, "embed_param", "kv_heads"),
        "wv": (None, "embed_param", "kv_heads"),
        "wo": (None, "heads", "embed_param"),
    }
    if cfg.moe is not None:
        ffn = {
            "moe": {
                k: (None,) + v
                for k, v in moe_lib.moe_logical_axes().items()
            }
        }
    else:
        ffn = {
            "mlp": {
                "w_gate": (None, "embed_param", "ff"),
                "w_up": (None, "embed_param", "ff"),
                "w_down": (None, "ff", "embed_param"),
            }
        }
    axes = {
        "embed": ("vocab", "embed_param"),
        "layers": {
            "attn": attn,
            **ffn,
            "ln1": (None, None),
            "ln2": (None, None),
        },
        "final_norm": (None,),
    }
    if not cfg.tie_embeddings:
        axes["lm_head"] = ("embed_param", "vocab")
    return axes


# ---------------------------------------------------------------------------
# KV cache
# ---------------------------------------------------------------------------

@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=["k", "v", "length"],
    meta_fields=[],
)
@dataclasses.dataclass
class KVCache:
    k: jnp.ndarray        # (L, B, max_len, KV, hd)
    v: jnp.ndarray
    length: jnp.ndarray   # scalar int32: filled prefix


def init_cache(cfg: TransformerConfig, batch: int, max_len: int) -> KVCache:
    hd = cfg.resolved_head_dim
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, hd)
    adt = _dt(cfg.dtype)
    return KVCache(
        k=jnp.zeros(shape, adt),
        v=jnp.zeros(shape, adt),
        length=jnp.zeros((), jnp.int32),
    )


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _attention(
    lp: Dict,
    x: jnp.ndarray,
    cfg: TransformerConfig,
    pos_offset,
    cache_kv: Optional[Tuple[jnp.ndarray, jnp.ndarray]],
    cache_len,
):
    B, T, D = x.shape
    hd = cfg.resolved_head_dim
    H, KV = cfg.n_heads, cfg.n_kv_heads
    q = (x @ lp["wq"].astype(x.dtype)).reshape(B, T, H, hd)
    k = (x @ lp["wk"].astype(x.dtype)).reshape(B, T, KV, hd)
    v = (x @ lp["wv"].astype(x.dtype)).reshape(B, T, KV, hd)
    q = shard(q, "batch", "seq", "heads", None)
    k = shard(k, "batch", "seq", "kv_heads", None)
    v = shard(v, "batch", "seq", "kv_heads", None)
    positions = pos_offset + jnp.arange(T, dtype=jnp.int32)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)

    if cache_kv is not None:
        ck, cv = cache_kv  # (B, max_len, KV, hd)
        ck = jax.lax.dynamic_update_slice(ck, k, (0, cache_len, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v, (0, cache_len, 0, 0))
        kv_len = jnp.full((B,), cache_len + T, dtype=jnp.int32)
        out = flash_attention(
            q, ck, cv,
            causal=False,  # masked by kv_length: all cached positions visible
            q_offset=cache_len,
            kv_length=kv_len,
            block_q=cfg.attn_block_q,
            block_kv=cfg.attn_block_kv,
        ) if T == 1 else flash_attention(
            q, ck, cv,
            causal=True,
            q_offset=cache_len,
            kv_length=kv_len,
            block_q=cfg.attn_block_q,
            block_kv=cfg.attn_block_kv,
        )
        new_cache = (ck, cv)
    else:
        out = flash_attention(
            q, k, v,
            causal=True,
            block_q=cfg.attn_block_q,
            block_kv=cfg.attn_block_kv,
        )
        new_cache = None
    out = shard(out, "batch", "seq", "heads", None)
    y = out.reshape(B, T, H * hd) @ lp["wo"].astype(x.dtype)
    return shard(y, "batch", "act_seq", "embed"), new_cache


def _ffn(lp: Dict, x: jnp.ndarray, cfg: TransformerConfig):
    B, T, D = x.shape
    if cfg.moe is not None:
        y, metrics = moe_lib.moe_apply(lp["moe"], x.reshape(B * T, D), cfg.moe)
        return y.reshape(B, T, D), metrics
    mlp = lp["mlp"]
    g = x @ mlp["w_gate"].astype(x.dtype)
    u = x @ mlp["w_up"].astype(x.dtype)
    g = shard(g, "batch", "seq", "ff")
    u = shard(u, "batch", "seq", "ff")
    h = jax.nn.silu(g) * u
    y = h @ mlp["w_down"].astype(x.dtype)
    return shard(y, "batch", "act_seq", "embed"), {}


def _layer_body(cfg: TransformerConfig, x, lp, pos_offset, cache_kv, cache_len):
    h, new_cache = _attention(
        lp["attn"], rms_norm(x, lp["ln1"], cfg.norm_eps), cfg,
        pos_offset, cache_kv, cache_len,
    )
    x = x + h
    h, metrics = _ffn(lp, rms_norm(x, lp["ln2"], cfg.norm_eps), cfg)
    x = x + h
    aux = metrics.get("moe_aux_loss", jnp.zeros((), jnp.float32)) + metrics.get(
        "moe_z_loss", jnp.zeros((), jnp.float32)
    )
    return x, new_cache, aux


_REMAT_POLICIES = {
    "none": None,
    "minimal": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
    "full": jax.checkpoint_policies.nothing_saveable,
}


def forward(
    params: Dict,
    tokens: jnp.ndarray,                 # (B, T) int32
    cfg: TransformerConfig,
    cache: Optional[KVCache] = None,
) -> Tuple[jnp.ndarray, Optional[KVCache], jnp.ndarray]:
    """Returns (logits (B, T, V) f32, updated cache or None, aux loss)."""
    adt = _dt(cfg.dtype)
    # cast BEFORE the gather: the all-gather/dynamic-gather of the vocab-
    # sharded table then moves bf16, not fp32 master weights (2x traffic)
    x = jnp.take(params["embed"].astype(adt), tokens, axis=0)
    x = shard(x, "batch", "act_seq", "embed")
    pos_offset = cache.length if cache is not None else jnp.zeros((), jnp.int32)

    # Re-assert per-layer weight shardings on the scanned slices: without
    # this XLA may hoist the FSDP all-gather of the WHOLE layer stack out
    # of the loop (fast, but 16x the weight memory at 405B scale).
    layer_axes = logical_axes(cfg)["layers"]

    def _constrain_lp(lp):
        return jax.tree_util.tree_map(
            lambda ax, w: shard(w, *ax[1:]),
            layer_axes,
            lp,
            is_leaf=lambda a: isinstance(a, tuple)
            and all(isinstance(e, str) or e is None for e in a),
        )

    def body(x, layer_inputs):
        lp, cache_kv = layer_inputs
        lp = _constrain_lp(lp)
        x, new_cache, aux = _layer_body(cfg, x, lp, pos_offset, cache_kv, pos_offset)
        return x, (new_cache, aux)

    policy = _REMAT_POLICIES[cfg.remat_policy]
    if policy is not None:
        body = jax.checkpoint(body, policy=policy)

    if cfg.scan_layers:
        cache_kv = (cache.k, cache.v) if cache is not None else None
        xs = (params["layers"], cache_kv)
        x, (new_caches, aux) = jax.lax.scan(body, x, xs)
    else:
        new_ks, new_vs, auxs = [], [], []
        for i in range(cfg.n_layers):
            lp = jax.tree_util.tree_map(lambda a: a[i], params["layers"])
            ckv = (cache.k[i], cache.v[i]) if cache is not None else None
            x, (nc, a) = body(x, (lp, ckv))
            auxs.append(a)
            if nc is not None:
                new_ks.append(nc[0])
                new_vs.append(nc[1])
        aux = jnp.stack(auxs)
        new_caches = (
            (jnp.stack(new_ks), jnp.stack(new_vs)) if new_ks else None
        )

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    # Vocab-parallel logits (Megatron): under SP the residual stream is
    # seq-sharded on 'model'; gathering seq here (cheap: bf16 activations)
    # keeps V sharded, so the lm_head gradient reduces shard-locally
    # instead of all-reducing a full (V, D) fp32 tensor.  "seq" is unmapped
    # in every arch's rules, so this spec resolves to (batch, None, vocab).
    x = shard(x, "batch", "seq", "embed")
    logits = (x @ head.astype(x.dtype)).astype(jnp.float32)
    logits = shard(logits, "batch", "seq", "vocab")

    new_cache = None
    if cache is not None:
        T = tokens.shape[1]
        new_cache = KVCache(
            k=new_caches[0], v=new_caches[1], length=cache.length + T
        )
    return logits, new_cache, jnp.sum(aux)
