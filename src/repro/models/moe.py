"""Mixture-of-Experts layer: top-k routing with sort-based dispatch.

TPU-native dropping dispatch (MegaBlocks/GShard hybrid; see the MoE-LM
configs granite / moonshot):

1. router logits -> top-k gates per token (softmax over selected);
2. (token, expert) assignments flattened and sorted by expert id —
   the token<->expert incidence is a bipartite graph, and this is the
   same gather/segment machinery as the condensed-graph engine;
3. tokens scattered into an (E, C, D) capacity buffer (overflow dropped,
   capacity_factor-controlled), expert FFNs run as one batched einsum
   sharded over the expert axis (EP);
4. results weighted by gates and scattered back.

Aux losses: load-balancing (Switch) + router z-loss.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..compat import shard_map
from ..configs.base import MoEConfig
from ..distributed.sharding import shard
from .layers import dense_init

__all__ = ["moe_init", "moe_apply", "moe_logical_axes"]


def moe_init(key, d_model: int, cfg: MoEConfig, dtype=jnp.float32) -> Dict:
    kr, kg, ku, kd = jax.random.split(key, 4)
    E, F = cfg.n_experts, cfg.d_expert
    return {
        "router": dense_init(kr, d_model, E, dtype),
        "w_gate": (
            jax.random.normal(kg, (E, d_model, F)) / jnp.sqrt(d_model)
        ).astype(dtype),
        "w_up": (
            jax.random.normal(ku, (E, d_model, F)) / jnp.sqrt(d_model)
        ).astype(dtype),
        "w_down": (
            jax.random.normal(kd, (E, F, d_model)) / jnp.sqrt(F)
        ).astype(dtype),
    }


def moe_logical_axes() -> Dict:
    return {
        "router": ("embed_param", "experts"),
        "w_gate": ("experts", "embed_param", "expert_ff"),
        "w_up": ("experts", "embed_param", "expert_ff"),
        "w_down": ("experts", "expert_ff", "embed_param"),
    }


def _route(params, x, cfg: MoEConfig):
    """Router top-k + aux losses (shared by both dispatch paths)."""
    E, K = cfg.n_experts, cfg.top_k
    logits = (x @ params["router"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eids = jax.lax.top_k(probs, K)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    density = jnp.mean(jax.nn.one_hot(eids[:, 0], E, dtype=jnp.float32), axis=0)
    mean_probs = jnp.mean(probs, axis=0)
    aux_loss = cfg.aux_loss_weight * E * jnp.sum(density * mean_probs)
    z_loss = 1e-4 * jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return eids, gates, aux_loss, z_loss


def _sort_positions(eids, gates, n_buckets: int, C: int, bucket_of):
    """Sort (token, k)-slots into per-bucket capacity positions.

    Returns (bucket, token, gate, pos, keep) arrays of length T*K, slot
    order sorted by bucket.  ``bucket_of`` maps expert id -> bucket id.
    """
    T, K = eids.shape
    flat_e = eids.reshape(-1)
    flat_b = bucket_of(flat_e)
    flat_t = jnp.repeat(jnp.arange(T, dtype=jnp.int32), K)
    flat_g = gates.reshape(-1)
    order = jnp.argsort(flat_b)                            # stable
    sb, se, st, sg = flat_b[order], flat_e[order], flat_t[order], flat_g[order]
    counts = jax.ops.segment_sum(jnp.ones_like(sb), sb, num_segments=n_buckets)
    start = jnp.cumsum(counts) - counts
    pos = jnp.arange(T * K, dtype=jnp.int32) - start[sb].astype(jnp.int32)
    keep = pos < C
    return sb, se, st, sg, jnp.where(keep, pos, 0), keep


def _expert_ffn(params, buf, dtype, constrain=True):
    """(E, C, D) capacity buffer through the gated expert FFN."""
    h_g = jnp.einsum(
        "ecd,edf->ecf", buf, params["w_gate"].astype(dtype),
        preferred_element_type=jnp.float32,
    )
    h_u = jnp.einsum(
        "ecd,edf->ecf", buf, params["w_up"].astype(dtype),
        preferred_element_type=jnp.float32,
    )
    h = (jax.nn.silu(h_g) * h_u).astype(dtype)
    if constrain:  # no-op under shard_map (manual sharding)
        h = shard(h, "experts", "expert_capacity", "expert_ff")
    return jnp.einsum(
        "ecf,efd->ecd", h, params["w_down"].astype(dtype),
        preferred_element_type=jnp.float32,
    ).astype(dtype)


def _moe_sort(params, x, cfg: MoEConfig):
    """Baseline: global sort-based dispatch, XLA SPMD resolves layouts."""
    T, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    # Capacity-factor dropping at scale; dropless floor for small token
    # counts (decode / smoke) so serving matches full-context routing.
    C = max(int(T * K / E * cfg.capacity_factor), min(T, 128), 1)
    eids, gates, aux_loss, z_loss = _route(params, x, cfg)
    se, se_e, st, sg, pos_c, keep = _sort_positions(
        eids, gates, E, C, lambda e: e
    )
    buf = jnp.zeros((E, C, D), dtype=x.dtype)
    gathered = jnp.take(x, st, axis=0) * keep[:, None].astype(x.dtype)
    buf = buf.at[jnp.where(keep, se, 0), pos_c].add(gathered)
    buf = shard(buf, "experts", "expert_capacity", "embed")
    out_buf = _expert_ffn(params, buf, x.dtype)
    expert_out = out_buf[jnp.where(keep, se, 0), pos_c] * (
        sg * keep
    )[:, None].astype(x.dtype)
    y = jax.ops.segment_sum(expert_out, st, num_segments=T)
    y = shard(y, None, "embed")
    metrics = {
        "moe_aux_loss": aux_loss,
        "moe_z_loss": z_loss,
        "moe_drop_fraction": 1.0 - jnp.mean(keep.astype(jnp.float32)),
    }
    return y, metrics


def _moe_a2a(params, x, cfg: MoEConfig, mesh, ep_axis: str, token_axes):
    """Expert-parallel all-to-all dispatch (shard_map; §Perf optimized).

    Tokens are partitioned across every mesh axis (``token_axes``); experts
    are partitioned over ``ep_axis`` and replicated elsewhere.  Each device
    routes its local tokens, buckets them *by destination EP rank*, and one
    ``all_to_all`` over ``ep_axis`` moves exactly T_local*K*D values there
    and back — instead of the baseline's all-reduce of the whole capacity
    buffer (measured 250x collective reduction on moonshot train_4k).
    """
    from jax.sharding import PartitionSpec as P

    E, K = cfg.n_experts, cfg.top_k
    n_ranks = 1
    for ax in ([ep_axis] if isinstance(ep_axis, str) else ep_axis):
        n_ranks *= mesh.shape[ax]
    E_loc = E // n_ranks

    def local_fn(x_loc, router, wg, wu, wd):
        T_loc, D = x_loc.shape
        rank = jax.lax.axis_index(ep_axis)
        p_loc = {"router": router, "w_gate": wg, "w_up": wu, "w_down": wd}
        eids, gates, aux_loss, z_loss = _route(p_loc, x_loc, cfg)
        # capacity of each (destination rank) bucket
        C = max(int(T_loc * K / n_ranks * cfg.capacity_factor), 8)
        sb, se, st, sg, pos_c, keep = _sort_positions(
            eids, gates, n_ranks, C, lambda e: e // E_loc
        )
        sb_c = jnp.where(keep, sb, 0)
        send = jnp.zeros((n_ranks, C, D), x_loc.dtype)
        send = send.at[sb_c, pos_c].add(
            jnp.take(x_loc, st, axis=0) * keep[:, None].astype(x_loc.dtype)
        )
        send_e = jnp.full((n_ranks, C), -1, jnp.int32)
        send_e = send_e.at[sb_c, pos_c].max(
            jnp.where(keep, se, -1).astype(jnp.int32)
        )
        # the collective: tokens travel to their expert's EP rank and back
        recv = jax.lax.all_to_all(send, ep_axis, split_axis=0, concat_axis=0)
        recv_e = jax.lax.all_to_all(send_e, ep_axis, split_axis=0, concat_axis=0)

        # local dispatch into per-expert capacity slots (all local now)
        flat = recv.reshape(n_ranks * C, D)
        flat_e = recv_e.reshape(n_ranks * C)
        le = jnp.clip(flat_e - rank * E_loc, 0, E_loc - 1)
        valid = flat_e >= 0
        order = jnp.argsort(jnp.where(valid, le, E_loc))   # invalid last
        fe, fv = le[order], valid[order]
        C2 = max(int(n_ranks * C * cfg.capacity_factor / max(E_loc, 1)), 8)
        counts = jax.ops.segment_sum(
            fv.astype(jnp.int32), jnp.where(fv, fe, E_loc - 1), num_segments=E_loc
        )
        start = jnp.cumsum(counts) - counts
        pos2 = jnp.arange(n_ranks * C, dtype=jnp.int32) - start[fe].astype(jnp.int32)
        keep2 = (pos2 >= 0) & (pos2 < C2) & fv
        buf = jnp.zeros((E_loc, C2, D), x_loc.dtype)
        buf = buf.at[jnp.where(keep2, fe, 0), jnp.where(keep2, pos2, 0)].add(
            flat[order] * keep2[:, None].astype(x_loc.dtype)
        )
        out = _expert_ffn(p_loc, buf, x_loc.dtype, constrain=False)
        # undo the local dispatch
        flat_out = jnp.zeros((n_ranks * C, D), x_loc.dtype)
        flat_out = flat_out.at[order].set(
            out[jnp.where(keep2, fe, 0), jnp.where(keep2, pos2, 0)]
            * keep2[:, None].astype(x_loc.dtype)
        )
        back = jax.lax.all_to_all(
            flat_out.reshape(n_ranks, C, D), ep_axis, split_axis=0, concat_axis=0
        )
        contrib = back[sb_c, pos_c] * (sg * keep)[:, None].astype(x_loc.dtype)
        y = jnp.zeros_like(x_loc).at[st].add(contrib)
        drop = 1.0 - jnp.mean(keep.astype(jnp.float32))
        # replicate scalars so out_specs=P() is legal
        all_axes = tuple(mesh.axis_names)
        aux_loss = jax.lax.pmean(aux_loss, all_axes)
        z_loss = jax.lax.pmean(z_loss, all_axes)
        drop = jax.lax.pmean(drop, all_axes)
        return y, aux_loss, z_loss, drop

    tok_spec = P(token_axes, None)
    w_spec3 = P(ep_axis, None, None)
    y, aux, zl, drop = shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(tok_spec, P(None, None), w_spec3, w_spec3, w_spec3),
        out_specs=(tok_spec, P(), P(), P()),
    )(x, params["router"], params["w_gate"], params["w_up"], params["w_down"])
    metrics = {
        "moe_aux_loss": jnp.mean(aux),
        "moe_z_loss": jnp.mean(zl),
        "moe_drop_fraction": jnp.mean(drop),
    }
    return y, metrics


def moe_apply(
    params: Dict, x: jnp.ndarray, cfg: MoEConfig
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """x: (T, D) flattened tokens -> (T, D), aux metrics/losses."""
    if cfg.dispatch == "a2a":
        from ..distributed import sharding as shlib

        mesh, rules = shlib._ctx()
        ep_axis = rules.get("experts") if rules else None
        if (
            mesh is not None
            and isinstance(ep_axis, str)
            and ep_axis in mesh.axis_names
            and cfg.n_experts % mesh.shape[ep_axis] == 0
        ):
            token_axes = tuple(mesh.axis_names)  # tokens over every axis
            return _moe_a2a(params, x, cfg, mesh, ep_axis, token_axes)
        # no mesh / incompatible sharding: fall through to the baseline
    return _moe_sort(params, x, cfg)
