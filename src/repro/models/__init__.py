"""Model zoo: LM transformers (dense + MoE), mesh/molecular GNNs, SASRec."""
