"""Data pipelines: synthetic corpora + sharded host->device batching.

The LM pipeline generates a Zipf-token synthetic corpus deterministically
per (seed, shard) so every data-parallel host draws disjoint streams —
the multi-host contract real pipelines must satisfy.  Batches are placed
with ``jax.device_put`` against the batch sharding so the train step
never sees host arrays.

:func:`sharded_extract_to_device` is the graph-side counterpart
(DESIGN.md §7): relational catalog -> budgeted sharded extraction ->
device graph, with the per-layer bitmap packing also done
shard-at-a-time so no stage of the host pipeline materializes an
unbounded transient.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "TokenPipeline",
    "sasrec_batches",
    "gnn_batch",
    "sharded_extract_to_device",
]


@dataclasses.dataclass
class TokenPipeline:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    shard_index: int = 0
    num_shards: int = 1
    zipf_a: float = 1.3

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, self.shard_index])
        )
        batch = self.global_batch // self.num_shards
        while True:
            toks = rng.zipf(self.zipf_a, size=(batch, self.seq_len + 1))
            toks = (toks - 1) % self.vocab_size
            # structure: repeat bigrams so the model has signal to learn
            toks[:, 2::3] = toks[:, 1:-1:3]
            yield {
                "tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32),
            }

    def device_iter(self, sharding=None) -> Iterator[Dict[str, jnp.ndarray]]:
        for batch in self:
            if sharding is None:
                yield {k: jnp.asarray(v) for k, v in batch.items()}
            else:
                yield {k: jax.device_put(v, sharding) for k, v in batch.items()}


def sasrec_batches(
    n_items: int, seq_len: int, batch: int, seed: int = 0
) -> Iterator[Dict[str, np.ndarray]]:
    rng = np.random.default_rng(seed)
    while True:
        seqs = rng.integers(1, n_items, size=(batch, seq_len), dtype=np.int64)
        pos = np.roll(seqs, -1, axis=1)
        pos[:, -1] = rng.integers(1, n_items, size=batch)
        neg = rng.integers(1, n_items, size=(batch, seq_len), dtype=np.int64)
        yield {
            "seqs": seqs.astype(np.int32),
            "pos": pos.astype(np.int32),
            "neg": neg.astype(np.int32),
        }


def gnn_batch(graph, target: np.ndarray) -> Dict:
    return {"graph": graph, "target": jnp.asarray(target)}


def sharded_extract_to_device(
    catalog,
    dsl_text: str,
    n_shards: int,
    max_resident_rows: Optional[int] = None,
    mode: str = "auto",
    packed: bool = False,
    pack_shard_edges: Optional[int] = None,
    correction_budget_triples: Optional[int] = None,
    spill_dir: Optional[str] = None,
    max_assembly_bytes: Optional[int] = None,
    delta_log: Optional["object"] = None,
    plan: Optional["object"] = None,
):
    """Catalog -> budgeted sharded extraction -> device graph, end to end.

    The larger-than-memory serving pipeline (DESIGN.md §7/§8): extraction
    runs in ``n_shards`` row partitions with per-shard transients capped
    at ``max_resident_rows`` (violations raise — see
    :class:`repro.core.planner.ExtractionBudget`) and — when
    ``spill_dir`` is given — per-shard outputs spilled to disk as each
    shard finishes, tree-reduce merged instead of held resident
    (``max_assembly_bytes`` caps the assembly buffers; without a spill
    directory an over-cap accumulation raises).  The DEDUP-C correction
    is built with the streaming fold (optionally under
    ``correction_budget_triples``), and — when ``packed`` — each layer's
    bitmap operands are packed shard-at-a-time (``pack_shard_edges``
    edges per slice) before upload.  Returns ``(extraction_result,
    device_graph)``; the device graph is duplicate-exact (DEDUP-C) and
    identical to the one the unsharded pipeline would build.

    ``delta_log``: a :class:`~repro.core.serialize.DeltaLog` of committed
    writes since the base catalog.  When given, the pipeline resumes from
    base graph + log via :meth:`~repro.core.delta.LiveGraph.replay`
    (byte-identical to extracting the mutated catalog from scratch) and
    the device graph is stamped with the replayed ``graph_version`` — so
    a restarted server comes back serving the *current* graph, not the
    base snapshot.  Sharded spill staging applies to the base build only
    (delta batches are small); both paths honor ``max_resident_rows``.

    ``plan``: a :class:`repro.core.cost.ExtractionPlan` from
    :func:`repro.core.cost.plan` (DESIGN.md §12).  When given, it drives
    both stages: extraction runs the plan's sharding/spill/budget config
    (the explicit ``n_shards`` / ``max_*`` / ``spill_dir`` knobs are
    ignored in its favor), and the device pack honors the plan's
    ``pack_method`` / ``fuse_correction`` knobs.  Incompatible with
    ``delta_log`` (a replayed graph's plan came from the base catalog).
    """
    from repro.core import dedup, engine
    from repro.core.extract import extract, extract_sharded

    pack_kwargs: Dict[str, object] = {}
    if plan is not None and delta_log is not None:
        raise ValueError("pass either plan= or delta_log=, not both")
    if plan is not None and packed:
        pack_kwargs = dict(plan.device_kwargs())

    graph_version = 0
    if delta_log is not None:
        from repro.core.delta import LiveGraph
        from repro.core.planner import ExtractionBudget

        budget = (
            ExtractionBudget(max_resident_rows=max_resident_rows)
            if max_resident_rows is not None
            else None
        )
        live = LiveGraph.replay(
            catalog, dsl_text, delta_log, mode=mode, budget=budget
        )
        res = live.result()
        graph_version = live.version
    elif plan is not None:
        res = extract(catalog, dsl_text, preprocess=False, plan=plan,
                      spill_dir=spill_dir)
    else:
        res = extract_sharded(
            catalog, dsl_text, n_shards=n_shards,
            max_resident_rows=max_resident_rows, mode=mode,
            spill_dir=spill_dir, max_assembly_bytes=max_assembly_bytes,
        )
    corr = dedup.build_correction_streaming(
        res.graph, budget_triples=correction_budget_triples
    )
    if packed:
        dev = engine.to_device_packed(
            res.graph, correction=corr, pack_shard_edges=pack_shard_edges,
            graph_version=graph_version, **pack_kwargs,
        )
    else:
        dev = engine.to_device(
            res.graph, correction=corr, graph_version=graph_version
        )
    return res, dev
