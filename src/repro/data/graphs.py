"""Graph construction utilities: synthetic graphs for the assigned shapes,
DimeNet triplet builder, batched-molecule collation, and a real neighbor
sampler (minibatch_lg requires one — GraphSAGE-style fanout sampling)."""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from ..core.condensed import BipartiteEdges, build_csr
from ..models.gnn import GraphBatch

__all__ = [
    "random_graph",
    "build_triplets",
    "batch_molecules",
    "NeighborSampler",
    "graph_batch_from_numpy",
]


def random_graph(
    n_nodes: int,
    n_edges: int,
    d_feat: int,
    seed: int = 0,
    with_positions: bool = False,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, Optional[np.ndarray]]:
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n_nodes, size=n_edges)
    dst = rng.integers(0, n_nodes, size=n_edges)
    feats = rng.standard_normal((n_nodes, d_feat)).astype(np.float32)
    pos = (
        rng.standard_normal((n_nodes, 3)).astype(np.float32) * 3.0
        if with_positions
        else None
    )
    return src.astype(np.int32), dst.astype(np.int32), feats, pos


def build_triplets(
    src: np.ndarray, dst: np.ndarray, n_nodes: int, cap: Optional[int] = None
) -> np.ndarray:
    """DimeNet triplets: pairs (edge_kj, edge_ji) with shared middle node j.

    For edge e1 = (k -> j) and e2 = (j -> i), k != i: one triplet.
    Returns (T, 2) int32, truncated to ``cap`` if given (noted budget —
    see configs; dropping triplets only reduces angular terms).
    """
    order = np.argsort(src, kind="stable")  # edges grouped by their source j
    e_by_src = order
    counts = np.bincount(src, minlength=n_nodes)
    starts = np.concatenate([[0], np.cumsum(counts)])
    in_order = np.argsort(dst, kind="stable")  # edges grouped by their dest j
    in_counts = np.bincount(dst, minlength=n_nodes)
    in_starts = np.concatenate([[0], np.cumsum(in_counts)])

    # For each node j: in-edges (k->j) x out-edges (j->i).
    n_tri_per_node = in_counts * counts
    total = int(n_tri_per_node.sum())
    if total == 0:
        return np.empty((0, 2), dtype=np.int32)
    nodes = np.repeat(np.arange(n_nodes), n_tri_per_node)
    offs = np.arange(total) - np.repeat(
        np.cumsum(n_tri_per_node) - n_tri_per_node, n_tri_per_node
    )
    kj_rank = offs // counts[nodes]
    ji_rank = offs % counts[nodes]
    e_kj = in_order[in_starts[nodes] + kj_rank]
    e_ji = e_by_src[starts[nodes] + ji_rank]
    keep = src[e_kj] != dst[e_ji]  # k != i (no backtracking)
    tri = np.stack([e_kj[keep], e_ji[keep]], axis=1).astype(np.int32)
    if cap is not None and tri.shape[0] > cap:
        tri = tri[:cap]
    return tri


def batch_molecules(
    n_mols: int, atoms_per_mol: int, edges_per_mol: int, d_feat: int, seed: int = 0
) -> GraphBatch:
    """Batched small molecules as one padded disjoint union."""
    import jax.numpy as jnp

    rng = np.random.default_rng(seed)
    N = n_mols * atoms_per_mol
    E = n_mols * edges_per_mol
    src = np.concatenate(
        [
            rng.integers(0, atoms_per_mol, edges_per_mol) + m * atoms_per_mol
            for m in range(n_mols)
        ]
    )
    dst = np.concatenate(
        [
            rng.integers(0, atoms_per_mol, edges_per_mol) + m * atoms_per_mol
            for m in range(n_mols)
        ]
    )
    feats = rng.standard_normal((N, d_feat)).astype(np.float32)
    pos = rng.standard_normal((N, 3)).astype(np.float32) * 2.0
    gid = np.repeat(np.arange(n_mols), atoms_per_mol)
    tri = build_triplets(src, dst, N)
    return graph_batch_from_numpy(
        src, dst, feats, positions=pos, graph_ids=gid, n_graphs=n_mols,
        triplets=tri,
    )


def graph_batch_from_numpy(
    src, dst, feats, positions=None, graph_ids=None, n_graphs=1, triplets=None,
) -> GraphBatch:
    import jax.numpy as jnp

    n = feats.shape[0]
    e = src.shape[0]
    return GraphBatch(
        nodes=jnp.asarray(feats),
        edge_src=jnp.asarray(src, dtype=jnp.int32),
        edge_dst=jnp.asarray(dst, dtype=jnp.int32),
        node_mask=jnp.ones((n,), dtype=bool),
        edge_mask=jnp.ones((e,), dtype=bool),
        positions=None if positions is None else jnp.asarray(positions),
        graph_ids=None if graph_ids is None else jnp.asarray(graph_ids, dtype=jnp.int32),
        triplets=None if triplets is None else jnp.asarray(triplets, dtype=jnp.int32),
        triplet_mask=None
        if triplets is None
        else jnp.ones((triplets.shape[0],), dtype=bool),
        n_graphs=n_graphs,
    )


@dataclasses.dataclass
class NeighborSampler:
    """GraphSAGE fanout sampler over a host CSR (minibatch_lg shape).

    Produces fixed-shape padded subgraphs: seed nodes + per-hop sampled
    neighbors, edges pointing child -> parent (aggregation direction).
    """

    indptr: np.ndarray
    indices: np.ndarray
    fanouts: Sequence[int]
    seed: int = 0

    @classmethod
    def from_edges(
        cls, src: np.ndarray, dst: np.ndarray, n_nodes: int, fanouts, seed=0
    ) -> "NeighborSampler":
        e = BipartiteEdges(
            np.asarray(dst, np.int64), np.asarray(src, np.int64), n_nodes, n_nodes
        )
        csr = build_csr(e)  # row = dst: in-neighbors
        return cls(csr.indptr, csr.indices, list(fanouts), seed)

    def sample(self, seeds: np.ndarray, rng: Optional[np.random.Generator] = None):
        """Returns (node_ids, edge_src, edge_dst, layer_sizes) — edge ids
        are positions into node_ids; padded to the fixed fanout budget by
        self-loops on the seed 0 slot with mask=False."""
        rng = rng or np.random.default_rng(self.seed)
        all_nodes = [np.asarray(seeds, dtype=np.int64)]
        edge_src_parts: List[np.ndarray] = []
        edge_dst_parts: List[np.ndarray] = []
        edge_mask_parts: List[np.ndarray] = []
        frontier = all_nodes[0]
        frontier_offset = 0
        next_offset = frontier.size
        for fanout in self.fanouts:
            deg = self.indptr[frontier + 1] - self.indptr[frontier]
            # sample `fanout` in-neighbors per frontier node (with
            # replacement when deg > 0; padded/masked when deg == 0)
            r = rng.integers(0, 2**31, size=(frontier.size, fanout))
            has = deg > 0
            idx = self.indptr[frontier][:, None] + (
                r % np.maximum(deg, 1)[:, None]
            )
            neigh = self.indices[idx]
            mask = np.broadcast_to(has[:, None], neigh.shape)
            child_pos = next_offset + np.arange(neigh.size)
            parent_pos = frontier_offset + np.repeat(
                np.arange(frontier.size), fanout
            )
            edge_src_parts.append(child_pos)
            edge_dst_parts.append(parent_pos)
            edge_mask_parts.append(mask.reshape(-1))
            flat = neigh.reshape(-1)
            flat = np.where(mask.reshape(-1), flat, 0)
            all_nodes.append(flat)
            frontier = flat
            frontier_offset = next_offset
            next_offset += flat.size
        node_ids = np.concatenate(all_nodes)
        return (
            node_ids,
            np.concatenate(edge_src_parts).astype(np.int32),
            np.concatenate(edge_dst_parts).astype(np.int32),
            np.concatenate(edge_mask_parts),
            [a.size for a in all_nodes],
        )
