"""Data substrate: synthetic relational datasets, condensed-graph
generators (paper App. C), graph samplers, and token pipelines."""
