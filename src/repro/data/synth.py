"""Synthetic datasets (paper §6, App. C).

Relational catalogs mirroring the paper's evaluation databases:

* :func:`dblp_catalog`  — Author / Pub / AuthorPub (co-author graphs)
* :func:`tpch_catalog`  — Customer / Orders / LineItem ("customers who
  bought the same item", the multi-layer Fig 5a example)
* :func:`univ_catalog`  — Instructor / Student / TaughtCourse / TookCourse
  (heterogeneous bipartite [Q3])

Condensed-graph generators:

* :func:`barabasi_albert_condensed` — App. C.1: virtual-node sizes drawn
  from a normal distribution, preferential attachment of real nodes, with
  the split/merge steps of the paper's sketch.
* :func:`layered_condensed` — App. C.2: multi-layer chains with chosen
  join selectivities (Layered_1/2, Single_1/2 analogs).
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import numpy as np

from ..core.condensed import BipartiteEdges, Chain, CondensedGraph
from ..core.relational import Catalog, Table

__all__ = [
    "dblp_catalog",
    "tpch_catalog",
    "univ_catalog",
    "barabasi_albert_condensed",
    "layered_condensed",
    "zipf_sizes",
]


def zipf_sizes(n: int, mean: float, rng: np.random.Generator, a: float = 2.5) -> np.ndarray:
    """Heavy-tailed sizes with a given mean (paper datasets are skewed)."""
    raw = rng.zipf(a, size=n).astype(np.float64)
    raw *= mean / raw.mean()
    return np.maximum(raw.astype(np.int64), 1)


# ---------------------------------------------------------------------------
# Relational catalogs
# ---------------------------------------------------------------------------

def dblp_catalog(
    n_authors: int = 2000,
    n_pubs: int = 3000,
    mean_authors_per_pub: float = 3.0,
    seed: int = 0,
) -> Catalog:
    rng = np.random.default_rng(seed)
    sizes = np.minimum(zipf_sizes(n_pubs, mean_authors_per_pub, rng), n_authors)
    pub_ids = np.repeat(np.arange(n_pubs), sizes)
    # Preferential-ish author assignment: zipf-weighted sampling.
    w = 1.0 / np.arange(1, n_authors + 1) ** 0.8
    w /= w.sum()
    author_ids = np.concatenate(
        [rng.choice(n_authors, size=s, replace=False, p=w) for s in sizes]
    )
    years = rng.integers(1990, 2024, size=n_pubs)
    authors = Table(
        "Author",
        {
            "aid": np.arange(n_authors),
            "name": np.array([f"author_{i}" for i in range(n_authors)]),
        },
    )
    pubs = Table(
        "Pub",
        {"pid": np.arange(n_pubs) + 1_000_000, "year": years},
    )
    author_pub = Table(
        "AuthorPub",
        {"aid": author_ids, "pid": pub_ids + 1_000_000},
    )
    return Catalog([authors, pubs, author_pub])


def tpch_catalog(
    n_customers: int = 1000,
    n_orders: int = 4000,
    n_parts: int = 300,
    mean_items_per_order: float = 3.0,
    seed: int = 0,
) -> Catalog:
    rng = np.random.default_rng(seed)
    cust_of_order = rng.integers(0, n_customers, size=n_orders)
    sizes = zipf_sizes(n_orders, mean_items_per_order, rng)
    order_ids = np.repeat(np.arange(n_orders), sizes)
    part_w = 1.0 / np.arange(1, n_parts + 1) ** 1.1
    part_w /= part_w.sum()
    part_ids = rng.choice(n_parts, size=order_ids.size, p=part_w)
    customers = Table(
        "Customer",
        {
            "ckey": np.arange(n_customers),
            "name": np.array([f"cust_{i}" for i in range(n_customers)]),
        },
    )
    orders = Table(
        "Orders",
        {"okey": np.arange(n_orders) + 5_000_000, "ckey": cust_of_order},
    )
    lineitem = Table(
        "LineItem",
        {"okey": order_ids + 5_000_000, "pkey": part_ids + 9_000_000},
    )
    return Catalog([customers, orders, lineitem])


def univ_catalog(
    n_instructors: int = 50,
    n_students: int = 500,
    n_courses: int = 80,
    mean_courses_per_student: float = 4.0,
    seed: int = 0,
) -> Catalog:
    rng = np.random.default_rng(seed)
    taught_by = rng.integers(0, n_instructors, size=n_courses)
    sizes = zipf_sizes(n_students, mean_courses_per_student, rng)
    student_ids = np.repeat(np.arange(n_students), sizes)
    course_ids = rng.integers(0, n_courses, size=student_ids.size)
    instructors = Table(
        "Instructor",
        {
            "iid": np.arange(n_instructors) + 10_000_000,
            "name": np.array([f"instr_{i}" for i in range(n_instructors)]),
        },
    )
    students = Table(
        "Student",
        {
            "sid": np.arange(n_students) + 20_000_000,
            "name": np.array([f"stud_{i}" for i in range(n_students)]),
        },
    )
    taught = Table(
        "TaughtCourse",
        {"iid": taught_by + 10_000_000, "cid": np.arange(n_courses)},
    )
    took = Table(
        "TookCourse",
        {"sid": student_ids + 20_000_000, "cid": course_ids},
    )
    return Catalog([instructors, students, taught, took])


# ---------------------------------------------------------------------------
# Condensed-graph generators (paper App. C.1/C.2)
# ---------------------------------------------------------------------------

def barabasi_albert_condensed(
    n_real: int,
    n_virtual: int,
    mean_size: float,
    sd_size: float,
    seed: int = 0,
    p_initial: float = 0.15,
    p_random_after_split: float = 0.35,
) -> CondensedGraph:
    """App. C.1 generator: preferential-attachment condensed graphs.

    1. draw virtual node sizes ~ N(mean, sd);
    2. split each virtual node with probability relative to its size;
    3. attach an initial batch (``p_initial``) at random;
    4. remaining virtual nodes attach either at random (split children,
       with prob. ``p_random_after_split``) or preferentially: pick an
       anchor real node of sufficient degree and sample its neighborhood
       with probability proportional to (degree)^2;
    5. merge split children back together.
    """
    rng = np.random.default_rng(seed)
    sizes = np.maximum(
        rng.normal(mean_size, sd_size, size=n_virtual).astype(np.int64), 2
    )
    sizes = np.minimum(sizes, max(2, n_real - 1))

    # Step 2: split
    split_prob = np.clip(sizes / (sizes.max() + 1.0), 0.05, 0.9)
    is_split = rng.random(n_virtual) < split_prob
    members: List[np.ndarray] = [np.empty(0, np.int64)] * n_virtual
    degree = np.zeros(n_real, dtype=np.int64)

    def attach_random(size: int) -> np.ndarray:
        sel = rng.choice(n_real, size=size, replace=False)
        degree[sel] += 1
        return sel

    def attach_preferential(size: int) -> np.ndarray:
        anchors = np.flatnonzero(degree >= 1)
        if anchors.size == 0:
            return attach_random(size)
        r = int(anchors[rng.integers(anchors.size)])
        # Neighborhood = union of members of virtual nodes containing r —
        # approximated by degree-weighted sampling over attached nodes
        # (paper's P_i ∝ d(s_i)^2 rule).
        attached = np.flatnonzero(degree > 0)
        w = degree[attached].astype(np.float64) ** 2
        w /= w.sum()
        take = min(size, attached.size)
        sel = rng.choice(attached, size=take, replace=False, p=w)
        if take < size:
            rest = rng.choice(
                np.setdiff1d(np.arange(n_real), sel, assume_unique=False),
                size=size - take,
                replace=False,
            )
            sel = np.concatenate([sel, rest])
        degree[sel] += 1
        return sel

    order = rng.permutation(n_virtual)
    n_init = max(1, int(p_initial * n_virtual))
    for i, v in enumerate(order):
        size = int(sizes[v])
        if i < n_init:
            members[v] = attach_random(size)
        elif is_split[v] and rng.random() < p_random_after_split:
            members[v] = attach_random(size)
        else:
            members[v] = attach_preferential(size)

    src = np.concatenate(members)
    dst = np.concatenate(
        [np.full(m.size, v, dtype=np.int64) for v, m in enumerate(members)]
    )
    e_in = BipartiteEdges(src, dst, n_real, n_virtual)
    return CondensedGraph(n_real, [Chain([e_in, e_in.reversed()])])


def layered_condensed(
    n_real: int,
    layer_sizes: Sequence[int],
    edges_per_level: Sequence[int],
    seed: int = 0,
    symmetric: bool = True,
) -> CondensedGraph:
    """App. C.2 generator: k-layer chains with controlled selectivity.

    ``layer_sizes``  virtual nodes per layer (k entries);
    ``edges_per_level``  edge count per bipartite level (k+1 entries).
    Lower layer_size / edge ratio = lower selectivity = denser expansion.
    """
    rng = np.random.default_rng(seed)
    if len(edges_per_level) != len(layer_sizes) + 1:
        raise ValueError("need len(edges_per_level) == len(layer_sizes) + 1")
    levels = [n_real] + list(layer_sizes) + [n_real]
    edges: List[BipartiteEdges] = []
    for i, ne in enumerate(edges_per_level):
        n_src, n_dst = levels[i], levels[i + 1]
        src = rng.integers(0, n_src, size=ne)
        dst = rng.integers(0, n_dst, size=ne)
        # connectivity guarantee: each dst appears at least once
        probe = rng.permutation(n_dst)
        src2 = rng.integers(0, n_src, size=n_dst)
        edges.append(
            BipartiteEdges(
                np.concatenate([src, src2]),
                np.concatenate([dst, probe]),
                n_src,
                n_dst,
            )
        )
    if symmetric and len(layer_sizes) == 1:
        e_in = edges[0]
        return CondensedGraph(n_real, [Chain([e_in, e_in.reversed()])])
    return CondensedGraph(n_real, [Chain(edges)])
