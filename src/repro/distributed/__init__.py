"""Distribution substrate: logical-axis sharding rules, collective helpers,
and gradient compression."""
