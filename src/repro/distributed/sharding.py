"""Logical-axis sharding (MaxText-style rules, framework-local).

Models annotate tensors with *logical* axis names ("batch", "heads", ...).
A rules mapping (per arch config) resolves logical names to mesh axes.
Outside any mesh context the annotations are no-ops, so the same model
code runs in CPU smoke tests and 512-chip dry-runs.

Usage::

    with use_mesh_rules(mesh, cfg.sharding_rules):
        y = jax.jit(step, in_shardings=..., out_shardings=...)(...)

    # inside model code
    x = shard(x, "batch", "seq", "embed")
"""
from __future__ import annotations

import contextlib
import threading
from typing import Mapping, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

__all__ = [
    "use_mesh_rules",
    "shard",
    "logical_spec",
    "named_sharding",
    "specs_for_tree",
    "current_mesh",
    "GRAPH_RULES",
    "shard_frontier",
    "extraction_shard_range",
]

# Logical-axis rules for the condensed-graph engine (DESIGN.md §3/§5):
# frontier matrices are (graph_nodes, graph_batch); the *batch* axis is the
# data-parallel one — every device holds the full node axis (edge arrays
# are replicated or banded separately) and owns a slice of the sources.
# Activate with ``use_mesh_rules(mesh, GRAPH_RULES)`` around jitted calls.
GRAPH_RULES = {
    "graph_nodes": None,
    "graph_batch": ("data", "model"),
}

_state = threading.local()


def _ctx() -> Tuple[Optional[Mesh], Optional[Mapping]]:
    return getattr(_state, "mesh", None), getattr(_state, "rules", None)


@contextlib.contextmanager
def use_mesh_rules(mesh: Optional[Mesh], rules: Optional[Mapping]):
    old = _ctx()
    _state.mesh, _state.rules = mesh, rules
    try:
        yield
    finally:
        _state.mesh, _state.rules = old


def current_mesh() -> Optional[Mesh]:
    return _ctx()[0]


def _resolve(axis: Optional[str], rules: Mapping, mesh: Mesh):
    """Logical axis -> mesh axis (or tuple), filtered to existing axes."""
    if axis is None:
        return None
    target = rules.get(axis, None)
    if target is None:
        return None
    if isinstance(target, (tuple, list)):
        present = tuple(t for t in target if t in mesh.axis_names)
        return present if present else None
    return target if target in mesh.axis_names else None


def logical_spec(
    logical_axes: Sequence[Optional[str]],
    rules: Optional[Mapping] = None,
    mesh: Optional[Mesh] = None,
) -> PartitionSpec:
    m, r = _ctx()
    mesh = mesh or m
    rules = rules or r
    if mesh is None or rules is None:
        return PartitionSpec()
    return PartitionSpec(*[_resolve(a, rules, mesh) for a in logical_axes])


def named_sharding(
    logical_axes: Sequence[Optional[str]],
    rules: Optional[Mapping] = None,
    mesh: Optional[Mesh] = None,
) -> Optional[NamedSharding]:
    m, r = _ctx()
    mesh = mesh or m
    rules = rules or r
    if mesh is None or rules is None:
        return None
    return NamedSharding(mesh, logical_spec(logical_axes, rules, mesh))


def _dedup_axes(spec: PartitionSpec) -> PartitionSpec:
    """Drop later duplicate mesh-axis uses (keep-first priority): lets
    model code annotate e.g. ("batch", "act_seq", "vocab") and stay legal
    when an arch maps act_seq and vocab to the same mesh axis (SP)."""
    seen = set()
    out = []
    for entry in spec:
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        kept = tuple(a for a in axes if a not in seen)
        seen.update(kept)
        out.append(kept if len(kept) > 1 else (kept[0] if kept else None))
    return PartitionSpec(*out)


def shard(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """Apply a sharding constraint if a mesh context is active (else no-op)."""
    mesh, rules = _ctx()
    if mesh is None or rules is None or len(mesh.devices.flatten()) == 1:
        return x
    if x.ndim != len(logical_axes):
        raise ValueError(
            f"rank {x.ndim} tensor got {len(logical_axes)} logical axes"
        )
    spec = _dedup_axes(logical_spec(logical_axes, rules, mesh))
    ns = NamedSharding(mesh, spec)
    return jax.lax.with_sharding_constraint(x, ns)


def shard_frontier(x: jax.Array) -> jax.Array:
    """Annotate a propagation frontier: ``(n,)`` vector or ``(n, B)`` batch.

    The same engine code then runs unconstrained on one CPU device and
    batch-sharded under ``use_mesh_rules(mesh, GRAPH_RULES)`` (rules may
    remap the logical names per deployment).  No-op outside a mesh context.
    """
    if x.ndim == 1:
        return shard(x, "graph_nodes")
    if x.ndim == 2:
        return shard(x, "graph_nodes", "graph_batch")
    raise ValueError(f"frontier must be (n,) or (n, B); got rank {x.ndim}")


def extraction_shard_range(
    n_shards: int,
    process_index: Optional[int] = None,
    process_count: Optional[int] = None,
) -> range:
    """The contiguous extraction-shard ids this host owns (DESIGN.md §7).

    The sharded extraction pipeline (``repro.core.extract``,
    ``n_shards=...``) is embarrassingly parallel across shards until the
    merge step; this maps the global shard space onto JAX processes so
    each host runs ``extract``'s per-shard work for its own slice
    (trailing hosts get one fewer shard when ``n_shards % process_count
    != 0``).  Single-process (the CPU test container): the full range.
    ``process_index``/``process_count`` default to
    ``jax.process_index()``/``jax.process_count()``.
    """
    if process_index is None:
        process_index = jax.process_index()
    if process_count is None:
        process_count = jax.process_count()
    if not 0 <= process_index < process_count:
        raise ValueError(
            f"process_index {process_index} out of range [0, {process_count})"
        )
    base, extra = divmod(n_shards, process_count)
    lo = process_index * base + min(process_index, extra)
    hi = lo + base + (1 if process_index < extra else 0)
    return range(lo, hi)


def specs_for_tree(axes_tree, rules: Mapping, mesh: Mesh):
    """Pytree of logical-axis tuples -> pytree of NamedSharding."""
    return jax.tree_util.tree_map(
        lambda axes: NamedSharding(mesh, logical_spec(axes, rules, mesh)),
        axes_tree,
        is_leaf=lambda v: isinstance(v, tuple)
        and all(isinstance(a, str) or a is None for a in v),
    )
