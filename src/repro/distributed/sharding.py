"""Logical-axis sharding (MaxText-style rules, framework-local).

Models annotate tensors with *logical* axis names ("batch", "heads", ...).
A rules mapping (per arch config) resolves logical names to mesh axes.
Outside any mesh context the annotations are no-ops, so the same model
code runs in CPU smoke tests and 512-chip dry-runs.

Usage::

    with use_mesh_rules(mesh, cfg.sharding_rules):
        y = jax.jit(step, in_shardings=..., out_shardings=...)(...)

    # inside model code
    x = shard(x, "batch", "seq", "embed")
"""
from __future__ import annotations

import contextlib
import threading
from typing import Mapping, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

__all__ = [
    "use_mesh_rules",
    "shard",
    "logical_spec",
    "named_sharding",
    "specs_for_tree",
    "current_mesh",
    "GRAPH_RULES",
    "shard_frontier",
    "extraction_shard_range",
    "merge_schedule",
    "MultihostSpillExtraction",
]

# Logical-axis rules for the condensed-graph engine (DESIGN.md §3/§5):
# frontier matrices are (graph_nodes, graph_batch); the *batch* axis is the
# data-parallel one — every device holds the full node axis (edge arrays
# are replicated or banded separately) and owns a slice of the sources.
# Activate with ``use_mesh_rules(mesh, GRAPH_RULES)`` around jitted calls.
GRAPH_RULES = {
    "graph_nodes": None,
    "graph_batch": ("data", "model"),
}

_state = threading.local()


def _ctx() -> Tuple[Optional[Mesh], Optional[Mapping]]:
    return getattr(_state, "mesh", None), getattr(_state, "rules", None)


@contextlib.contextmanager
def use_mesh_rules(mesh: Optional[Mesh], rules: Optional[Mapping]):
    """Activate a (mesh, logical-axis rules) context for :func:`shard` /
    :func:`logical_spec` calls in the dynamic scope (thread-local,
    re-entrant).  ``None`` for either disables annotations — the same
    model code then runs unconstrained (DESIGN.md §5)."""
    old = _ctx()
    _state.mesh, _state.rules = mesh, rules
    try:
        yield
    finally:
        _state.mesh, _state.rules = old


def current_mesh() -> Optional[Mesh]:
    """The mesh of the innermost :func:`use_mesh_rules` context, if any."""
    return _ctx()[0]


def _resolve(axis: Optional[str], rules: Mapping, mesh: Mesh):
    """Logical axis -> mesh axis (or tuple), filtered to existing axes."""
    if axis is None:
        return None
    target = rules.get(axis, None)
    if target is None:
        return None
    if isinstance(target, (tuple, list)):
        present = tuple(t for t in target if t in mesh.axis_names)
        return present if present else None
    return target if target in mesh.axis_names else None


def logical_spec(
    logical_axes: Sequence[Optional[str]],
    rules: Optional[Mapping] = None,
    mesh: Optional[Mesh] = None,
) -> PartitionSpec:
    """Resolve logical axis names to a ``PartitionSpec`` under the given
    (or ambient) rules + mesh; empty spec outside any context."""
    m, r = _ctx()
    mesh = mesh or m
    rules = rules or r
    if mesh is None or rules is None:
        return PartitionSpec()
    return PartitionSpec(*[_resolve(a, rules, mesh) for a in logical_axes])


def named_sharding(
    logical_axes: Sequence[Optional[str]],
    rules: Optional[Mapping] = None,
    mesh: Optional[Mesh] = None,
) -> Optional[NamedSharding]:
    """:func:`logical_spec` wrapped in a ``NamedSharding`` for
    ``jax.device_put`` / ``in_shardings``; ``None`` outside a context."""
    m, r = _ctx()
    mesh = mesh or m
    rules = rules or r
    if mesh is None or rules is None:
        return None
    return NamedSharding(mesh, logical_spec(logical_axes, rules, mesh))


def _dedup_axes(spec: PartitionSpec) -> PartitionSpec:
    """Drop later duplicate mesh-axis uses (keep-first priority): lets
    model code annotate e.g. ("batch", "act_seq", "vocab") and stay legal
    when an arch maps act_seq and vocab to the same mesh axis (SP)."""
    seen = set()
    out = []
    for entry in spec:
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        kept = tuple(a for a in axes if a not in seen)
        seen.update(kept)
        out.append(kept if len(kept) > 1 else (kept[0] if kept else None))
    return PartitionSpec(*out)


def shard(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """Apply a sharding constraint if a mesh context is active (else no-op)."""
    mesh, rules = _ctx()
    if mesh is None or rules is None or len(mesh.devices.flatten()) == 1:
        return x
    if x.ndim != len(logical_axes):
        raise ValueError(
            f"rank {x.ndim} tensor got {len(logical_axes)} logical axes"
        )
    spec = _dedup_axes(logical_spec(logical_axes, rules, mesh))
    ns = NamedSharding(mesh, spec)
    return jax.lax.with_sharding_constraint(x, ns)


def shard_frontier(x: jax.Array) -> jax.Array:
    """Annotate a propagation frontier: ``(n,)`` vector or ``(n, B)`` batch.

    The same engine code then runs unconstrained on one CPU device and
    batch-sharded under ``use_mesh_rules(mesh, GRAPH_RULES)`` (rules may
    remap the logical names per deployment).  No-op outside a mesh context.
    """
    if x.ndim == 1:
        return shard(x, "graph_nodes")
    if x.ndim == 2:
        return shard(x, "graph_nodes", "graph_batch")
    raise ValueError(f"frontier must be (n,) or (n, B); got rank {x.ndim}")


def extraction_shard_range(
    n_shards: int,
    process_index: Optional[int] = None,
    process_count: Optional[int] = None,
) -> range:
    """The contiguous extraction-shard ids this host owns (DESIGN.md §8).

    The sharded extraction pipeline (``repro.core.extract``,
    ``n_shards=...``) is embarrassingly parallel across shards until the
    merge; this maps the global shard space onto JAX processes so each
    host runs ``extract``'s per-shard work — and its process-local
    pre-merge — for its own slice.  The division is ragged-safe in both
    directions: trailing hosts get one fewer shard when ``n_shards %
    process_count != 0``, and when ``n_shards < process_count`` the
    trailing hosts get *empty* ranges (they spill nothing, pre-merge
    nothing, and are simply absent from the cross-process reduce —
    :class:`MultihostSpillExtraction` schedules the tree over the
    processes with non-empty ranges only).  Ranges are contiguous and
    ascending in ``process_index``, which is what lets the pairwise
    reduce concatenate partner partials in shard order and stay
    byte-identical.  Single-process (the CPU test container): the full
    range.  ``process_index``/``process_count`` default to
    ``jax.process_index()``/``jax.process_count()``.
    """
    if process_index is None:
        process_index = jax.process_index()
    if process_count is None:
        process_count = jax.process_count()
    if not 0 <= process_index < process_count:
        raise ValueError(
            f"process_index {process_index} out of range [0, {process_count})"
        )
    base, extra = divmod(n_shards, process_count)
    lo = process_index * base + min(process_index, extra)
    hi = lo + base + (1 if process_index < extra else 0)
    return range(lo, hi)


def merge_schedule(n_partials: int) -> list:
    """Log-depth pairwise reduce schedule over ``n_partials`` contiguous
    partials (DESIGN.md §8).

    Returns a list of rounds; each round is a list of ``(dst, src)``
    index pairs, every pair independent within its round.  ``dst``
    absorbs ``src``, and — because partials are ordered by the contiguous
    shard ranges of :func:`extraction_shard_range` — ``src``'s
    accumulated shard range always directly follows ``dst``'s, so the
    merged partial is again a contiguous range and the final reduce at
    index 0 concatenates every shard in order (the byte-identity
    requirement).  Depth is ``ceil(log2(n_partials))``; a partial with no
    partner in a round carries to the next unchanged.
    """
    if n_partials < 0:
        raise ValueError(f"n_partials must be >= 0, got {n_partials}")
    rounds = []
    stride = 1
    while stride < n_partials:
        rounds.append([
            (i, i + stride)
            for i in range(0, n_partials, 2 * stride)
            if i + stride < n_partials
        ])
        stride *= 2
    return rounds


def _sync_barrier(process_count: int):
    """Default cross-phase barrier: no-op single-process, else
    ``jax.experimental.multihost_utils.sync_global_devices``."""

    def barrier(name: str) -> None:
        if process_count == 1:
            return
        from jax.experimental import multihost_utils

        multihost_utils.sync_global_devices(name)

    return barrier


class MultihostSpillExtraction:
    """Multi-host sharded extraction with spill-to-disk assembly and a
    log-depth cross-process tree-reduce merge (DESIGN.md §8).

    Every JAX process runs the same program against the same catalog and
    a *shared* spill directory (the exchange medium — spill records are
    how processes hand partials to each other, so no array ever crosses
    hosts in memory):

    1. :meth:`phase_nodes` — each process binds and spills node-space
       candidate records for its own shards
       (:func:`extraction_shard_range`).
    2. :meth:`phase_shards` — after a barrier, each process merges *all*
       node records into the (identical-everywhere) global ``NodeSpace``,
       extracts + spills its shard assemblies, and pre-merges them into
       one process partial (``partial_p<index>``).
    3. :meth:`phase_merge_round` — ``ceil(log2(P'))`` rounds of pairwise
       partial merges per :func:`merge_schedule`, over the ``P'``
       processes that own shards; one barrier per round.
    4. :meth:`phase_finish` — every process loads the root partial and
       builds the same ``CondensedGraph``; the root process finalizes the
       spill manifest (making the directory a valid
       :func:`repro.core.extract.merge_spilled_graph` input).

    :meth:`run` drives all phases with the default barrier
    (``multihost_utils.sync_global_devices`` when ``process_count > 1``,
    no-op single-process — the CPU fallback).  Tests drive the phases
    explicitly with simulated ``process_index``/``process_count`` and a
    no-op barrier, which is exactly equivalent because every
    cross-process data dependency goes through the spill directory at a
    phase boundary.

    The graph is byte-identical to ``extract(catalog, dsl_text)`` — the
    multi-host reduce is the same associative sorted-key-union merge,
    grouped differently.

    Use a *fresh* spill directory per multi-process run: the single-host
    pipeline clears a reused directory's stale records at start (it is
    the only writer), but with concurrent processes that wipe would race
    other processes' fresh records, so only the stale closing manifest is
    invalidated here — leftover records from an earlier differently-
    sharded run would be certified into the new manifest.
    """

    def __init__(
        self,
        catalog,
        dsl_text: str,
        n_shards: int,
        spill_dir: str,
        mode: str = "auto",
        preprocess: bool = False,
        max_resident_rows: Optional[int] = None,
        max_assembly_bytes: Optional[int] = None,
        merge_arity: int = 2,
        process_index: Optional[int] = None,
        process_count: Optional[int] = None,
        barrier=None,
    ) -> None:
        from repro.core.dsl import parse
        from repro.core.planner import ExtractionBudget
        from repro.core.serialize import ShardSpillStore

        self.catalog = catalog
        self.query = parse(dsl_text)
        self.n_shards = int(n_shards)
        self.mode = mode
        self.preprocess = preprocess
        self.merge_arity = int(merge_arity)
        self.process_index = (
            jax.process_index() if process_index is None else int(process_index)
        )
        self.process_count = (
            jax.process_count() if process_count is None else int(process_count)
        )
        self.my_shards = extraction_shard_range(
            self.n_shards, self.process_index, self.process_count
        )
        # processes that own shards: the partial owners the reduce runs over
        self.active = [
            p for p in range(self.process_count)
            if len(extraction_shard_range(self.n_shards, p, self.process_count))
        ]
        self.schedule = merge_schedule(len(self.active))
        self.root = self.active[0]
        self.barrier = barrier or _sync_barrier(self.process_count)
        self.budget = ExtractionBudget(
            max_resident_rows=max_resident_rows,
            max_assembly_bytes=max_assembly_bytes,
            spill_enabled=True,
        )
        self.store = ShardSpillStore(spill_dir)
        self.nodes = None
        self.props = None
        self._plans = None
        self._seconds = 0.0

    def _partial_name(self, process_index: int) -> str:
        return f"partial_p{process_index:05d}"

    # -- phases ---------------------------------------------------------------
    def phase_nodes(self) -> None:
        """Spill node-space candidate records for my shard range."""
        import time

        from repro.core.extract import _spill_node_shards

        t0 = time.perf_counter()
        _spill_node_shards(
            self.catalog, self.query.nodes_rules, self.n_shards,
            self.my_shards, self.store, self.budget,
        )
        self._seconds += time.perf_counter() - t0

    def phase_shards(self) -> None:
        """Global node space from all processes' records, then extract,
        spill, and pre-merge my shards into ``partial_p<me>``."""
        import time

        from repro.core.extract import (
            _node_space_from_spill,
            _plans_info,
            _spill_chain_shards,
            _write_nodespace_record,
        )
        from repro.core.serialize import tree_merge_records

        t0 = time.perf_counter()
        self.nodes, self.props = _node_space_from_spill(
            self.store, self.query.nodes_rules, self.n_shards, self.budget
        )
        self._plans = _plans_info(self.catalog, self.query, self.mode)
        names = _spill_chain_shards(
            self.catalog, self._plans, self.nodes, self.n_shards,
            self.my_shards, self.store, self.budget,
        )
        if names:
            reduced, _ = tree_merge_records(
                self.store, names, arity=self.merge_arity,
                out_prefix=f"pre_p{self.process_index:05d}_",
                budget=self.budget,
            )
            canonical = self._partial_name(self.process_index)
            if reduced != canonical:
                if reduced.startswith("pre_p"):
                    # an intermediate partial: just move it (no payload
                    # rewrite)
                    self.store.rename_record(reduced, canonical)
                else:
                    # a leaf shard record (single-shard slice): keep the
                    # leaf, copy it to the canonical partial name
                    assembly, _ = self.store.read_assembly(reduced)
                    self.store.write_assembly(canonical, assembly)
        if self.process_index == self.root:
            _write_nodespace_record(self.store, self.nodes, self.props)
        self._seconds += time.perf_counter() - t0

    def phase_merge_round(self, round_index: int) -> None:
        """Execute my pair (if any) of reduce round ``round_index``: load
        the partner's partial from the spill directory, merge it after
        mine, write the result back over my partial."""
        import time

        from repro.core.serialize import merge_assemblies

        t0 = time.perf_counter()
        for dst, src in self.schedule[round_index]:
            if self.active[dst] != self.process_index:
                continue
            mine, nb_dst = self.store.read_assembly(self._partial_name(self.active[dst]))
            theirs, nb_src = self.store.read_assembly(self._partial_name(self.active[src]))
            merged = merge_assemblies([mine, theirs])
            out_bytes = self.store.write_assembly(
                self._partial_name(self.active[dst]), merged
            )
            self.budget.note_merge(nb_dst + nb_src + out_bytes)
        self.budget.n_merge_rounds += 1
        self._seconds += time.perf_counter() - t0

    def phase_finish(self):
        """Load the root partial, finalize the manifest (root process
        only), and return the :class:`~repro.core.extract.ExtractionResult`
        — identical on every process."""
        import time

        from repro.core.extract import ExtractionResult, _graph_from_assembly

        t0 = time.perf_counter()
        merged, _ = self.store.read_assembly(self._partial_name(self.root))
        if self.process_index == self.root:
            self.store.finalize(meta={
                "kind": "extraction_spill",
                "n_shards": self.n_shards,
                "n_rules": len(self._plans or []),
                "mode": self.mode,
                "preprocess": self.preprocess,
                "final_record": self._partial_name(self.root),
                "process_count": self.process_count,
            })
        graph = _graph_from_assembly(
            self.nodes, self.props, merged, self.preprocess
        )
        self._seconds += time.perf_counter() - t0
        return ExtractionResult(
            graph=graph,
            nodes=self.nodes,
            plans=[p for p, _, _ in (self._plans or [])],
            seconds=self._seconds,
            dropped_endpoints=merged.dropped,
            mode=self.mode,
            n_shards=self.n_shards,
            budget=self.budget,
        )

    def run(self):
        """All phases with barriers between — the one-call multi-host
        entry point; single-process it degrades to the plain spilled
        pipeline (no barriers, full shard range)."""
        self.phase_nodes()
        self.barrier("spill:nodes")
        self.phase_shards()
        self.barrier("spill:shards")
        for r in range(len(self.schedule)):
            self.phase_merge_round(r)
            self.barrier(f"spill:merge{r}")
        return self.phase_finish()


def specs_for_tree(axes_tree, rules: Mapping, mesh: Mesh):
    """Pytree of logical-axis tuples -> pytree of NamedSharding."""
    return jax.tree_util.tree_map(
        lambda axes: NamedSharding(mesh, logical_spec(axes, rules, mesh)),
        axes_tree,
        is_leaf=lambda v: isinstance(v, tuple)
        and all(isinstance(a, str) or a is None for a in v),
    )
