"""Gradient compression: int8 quantization with error feedback.

At 1000+-node scale the cross-pod (DCI) gradient reduce dominates step
time for pure-DP axes.  Error-feedback int8 (1-bit-Adam-family trick,
cf. Seide et al. 2014; Karimireddy et al. 2019) cuts that traffic 4x
versus f32 / 2x versus bf16 with negligible quality loss when the
quantization error is fed back into the next step.

Two entry points:

* :func:`compress_decompress` — SPMD-friendly: quantize+dequantize the
  gradient *before* the (XLA-inserted) all-reduce; the collective then
  moves int8-precision values. Error feedback state threads through the
  train state.
* :func:`allreduce_int8` — explicit shard_map collective for the manual
  path (used in tests and the orchestrator's elastic fallback).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["quantize_int8", "dequantize_int8", "compress_decompress", "allreduce_int8"]


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor int8: returns (q, scale)."""
    x32 = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_decompress(grads, err_state: Optional[dict]):
    """Quantize->dequantize each gradient leaf with error feedback.

    err_state is a pytree of residuals (or None on step 0).
    """
    if err_state is None:
        err_state = jax.tree_util.tree_map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads
        )

    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, s = quantize_int8(corrected)
        deq = dequantize_int8(q, s)
        return deq, corrected - deq

    out = jax.tree_util.tree_map(one, grads, err_state)
    is_pair = lambda x: isinstance(x, tuple)
    deq = jax.tree_util.tree_map(lambda o: o[0], out, is_leaf=is_pair)
    err = jax.tree_util.tree_map(lambda o: o[1], out, is_leaf=is_pair)
    return deq, err


def allreduce_int8(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """Explicit compressed all-reduce inside shard_map: each participant
    contributes int8 values; scales are reduced separately (max)."""
    q, s = quantize_int8(x)
    s_max = jax.lax.pmax(s, axis_name)
    # re-quantize against the shared scale so the integer sum is exact
    q_shared = jnp.clip(
        jnp.round(x.astype(jnp.float32) / s_max), -127, 127
    ).astype(jnp.int32)
    total = jax.lax.psum(q_shared, axis_name)
    return total.astype(jnp.float32) * s_max
