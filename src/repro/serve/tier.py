"""Continuous-batching multi-tenant graph serving tier (DESIGN.md §10).

:class:`~repro.serve.server.GraphQueryServer` is a synchronous
flush-the-queue loop over one graph: every flush is a barrier (a query
arriving just after a round starts waits for the *whole* round, every
kind's batches included), one process serves one graph, and every version
bump re-traces every propagation executable.  This module rebuilds
serving around the economics that matter at scale:

* **Continuous batching** — queries are admitted into per-``(tenant,
  kind)`` queues and executed one bucket-padded batch at a time; after
  every batch the scheduler re-admits whatever arrived in the meantime
  and picks the queue with the oldest waiting request.  There is no
  flush barrier: the worst-case wait is one batch, not one round.  (The
  lockstep-invariant machinery from ``BatchedServer.step`` generalizes:
  a batch slot is a fixed compiled width, admission fills it from the
  live queue, and freeing it re-opens admission immediately.)
* **Multi-graph tenancy under a residency budget** — one process serves
  many extracted graphs.  Host graphs (plus their DEDUP-C corrections)
  stay resident; *device* operands are uploaded lazily and LRU-evicted
  under a byte budget (:class:`~repro.core.engine.ResidencyBudget`, the
  serving twin of ``ExtractionBudget``'s assembly account).  Eviction is
  loss-free: a re-upload from the same host arrays is byte-identical, so
  an evicted tenant's next query answers with the exact same bytes.
* **Executable cache** — compiled propagation executables are keyed on
  ``(kind, bucket width, graph shape signature)`` with warm LRU
  eviction.  The signature (:func:`~repro.core.engine.
  graph_shape_signature`) excludes ``graph_version``, and dispatch
  normalizes the version to 0, so bucket churn, version churn, and even
  distinct tenants whose graphs share a shape all reuse one trace.
* **Result cache keyed on GraphVersion** — queries are idempotent reads
  of one graph version, so ``(tenant, kind, node, version)`` fully
  determines the answer.  A version bump (from
  :meth:`~repro.core.delta.LiveGraph.apply_delta`, via the registered
  version listener) invalidates exactly that tenant's entries; other
  tenants keep serving from cache.

Version handoff follows the quiesce protocol (see
:meth:`~repro.serve.server.GraphQueryServer.update_graph`): admissions
for the bumped tenant close, its in-flight queries drain against the old
graph (they were validated against the old node space and are owed an
old-version answer), then the host graph, correction, and version swap
and admission reopens.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax.numpy as jnp
import numpy as np

from ..core import algorithms
from ..core import dedup as _dedup
from ..core import engine as _engine
from ..core.condensed import CondensedGraph
from ..core.engine import (
    DeviceGraph,
    ResidencyBudget,
    ResidencyError,
    device_graph_bytes,
    graph_shape_signature,
    with_graph_version,
)
from .server import ServerStats

__all__ = [
    "ServeRequest",
    "ServeResult",
    "ExecutableCacheStats",
    "ResultCacheStats",
    "GraphServingTier",
]

KINDS = (
    "bfs",
    "ppr",
    "common_neighbors",
    "shortest",
    "widest",
    "scc",
    "triangles",
)

# Host-driven analytics (DESIGN.md §11): computed by a Python-side sweep
# of batched propagations rather than one jitted (n, B) call.  The whole
# batch shares one sweep, and the per-(tenant, kind, node, version)
# result cache absorbs repeats.
HOST_KINDS = frozenset({"scc", "triangles"})

# Kinds whose executables take the tenant's per-virtual-layer weights as
# a call argument — weights are tenant state, but executables are shared
# across tenants by (kind, width, shape signature), so they must never be
# closed over.
WEIGHTED_KINDS = frozenset({"shortest", "widest"})


@dataclasses.dataclass
class ServeRequest:
    """One tenant-addressed analytics request.

    ``graph_version`` pins the version the client resolved ``node``
    against (``None`` = whatever the tenant currently serves); a mismatch
    with the tenant's live version is rejected at submit.
    ``arrival_time`` is the load-generator timestamp (seconds, virtual)
    used by :meth:`GraphServingTier.run_load` for latency accounting."""

    qid: int
    tenant: str
    kind: str
    node: int
    graph_version: Optional[int] = None
    arrival_time: float = 0.0


@dataclasses.dataclass
class ServeResult:
    """One answered request: the ``(n,)`` result vector plus how it was
    served — from the result cache or inside a batch of ``batch_fill``
    real queries padded to ``batch_width`` slots — and when (virtual
    clock seconds; ``latency = done_time - arrival_time``)."""

    qid: int
    tenant: str
    kind: str
    node: int
    value: np.ndarray
    graph_version: int
    cached: bool
    arrival_time: float
    done_time: float
    batch_width: int = 0
    batch_fill: int = 0

    @property
    def latency(self) -> float:
        return self.done_time - self.arrival_time


@dataclasses.dataclass
class ExecutableCacheStats:
    hits: int = 0
    misses: int = 0          # = executables built (trace candidates)
    evictions: int = 0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


@dataclasses.dataclass
class ResultCacheStats:
    hits: int = 0
    misses: int = 0
    invalidated: int = 0     # entries dropped by version bumps

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


@dataclasses.dataclass
class _Executable:
    """One compiled propagation entry: the jitted callable plus trace
    evidence (``traces[0]`` increments only when jax actually re-traces
    the wrapper — the honest no-retrace signal tests pin)."""

    fn: object
    traces: List[int]


class _Tenant:
    """One served graph: host state (authoritative, never evicted) plus
    lazily uploaded device operands (evictable)."""

    def __init__(
        self,
        name: str,
        host: CondensedGraph,
        correction,
        version: int,
        *,
        packed: bool,
        with_counts: bool,
        drop_self_loops: bool,
        pin: bool,
        live=None,
        layer_weights=None,
        layer_capacities=None,
    ):
        self.name = name
        self.host = host
        self.correction = correction
        self.version = int(version)
        self.packed = packed
        self.with_counts = with_counts
        self.drop_self_loops = drop_self_loops
        self.pin = pin
        self.live = live
        self.layer_weights = layer_weights
        self.layer_capacities = layer_capacities
        self.quiescing = False
        # device residency (None = evicted / never uploaded)
        self.device: Optional[DeviceGraph] = None
        self.counts_device: Optional[DeviceGraph] = None
        self.resident_bytes = 0
        self.last_used = 0
        self.n_uploads = 0

    @property
    def n_nodes(self) -> int:
        return self.host.n_real

    def graph_for(self, kind: str) -> DeviceGraph:
        if kind == "common_neighbors" and self.counts_device is not None:
            return self.counts_device
        return self.device

    def weights_for(self, kind: str):
        """Per-virtual-layer weight pytree passed to weighted executables
        at call time (None = unweighted: hop-count distances /
        reachability widths)."""
        return self.layer_weights if kind == "shortest" else self.layer_capacities


class GraphServingTier:
    """Continuous-batching serving front-end over many tenant graphs.

    Two driving modes share one scheduler:

    * :meth:`submit` + :meth:`step`/:meth:`drain` — event-style: submit
      admits (answering result-cache hits immediately), each step
      executes exactly one bucket-padded batch for the queue with the
      oldest waiting request, then control returns so new arrivals can be
      admitted before the next batch.  ``serve(requests)`` is the
      submit-all-then-drain convenience.
    * :meth:`run_load` — the load-generator loop: requests carry virtual
      ``arrival_time`` stamps; the clock advances by each batch's *real*
      measured execution time, so the per-request latencies are honest
      service times under the offered schedule.

    ``budget`` caps device residency across all tenants; ``None`` means
    unbounded.  ``max_executables`` caps the warm executable cache.
    """

    def __init__(
        self,
        *,
        max_batch: int = 32,
        bucket_widths: Tuple[int, ...] = (8, 16, 32),
        budget: Optional[ResidencyBudget] = None,
        max_executables: int = 64,
        ppr_iters: int = 20,
        damping: float = 0.85,
        bfs_max_iters: Optional[int] = None,
        result_cache: bool = True,
    ):
        self.max_batch = int(max_batch)
        widths = sorted(
            {int(w) for w in bucket_widths if 0 < int(w) < self.max_batch}
        )
        self.bucket_widths: Tuple[int, ...] = tuple(widths) + (self.max_batch,)
        self.budget = budget if budget is not None else ResidencyBudget()
        self.max_executables = int(max_executables)
        self.ppr_iters = int(ppr_iters)
        self.damping = float(damping)
        self.bfs_max_iters = bfs_max_iters
        self.result_cache_enabled = bool(result_cache)

        self.tenants: Dict[str, _Tenant] = {}
        # per-(tenant, kind) FIFO queues — the continuous-batching slots
        # fill from these, oldest head first
        self._queues: "collections.OrderedDict[Tuple[str, str], List[ServeRequest]]" = (
            collections.OrderedDict()
        )
        self._pending_qids: set = set()
        self.now = 0.0
        self._tick = 0
        # caches
        self._executables: "collections.OrderedDict[Tuple[str, int, str], _Executable]" = (
            collections.OrderedDict()
        )
        self.exec_stats = ExecutableCacheStats()
        self._results: Dict[Tuple[str, str, int, int], np.ndarray] = {}
        self.result_stats = ResultCacheStats()
        # batching efficiency (occupancy / padding waste / width census)
        self.stats = ServerStats()
        # results produced out-of-band by a version-bump drain handoff
        self._handoff: List[ServeResult] = []

    # -- tenancy --------------------------------------------------------------

    def add_tenant(
        self,
        name: str,
        source: Union[CondensedGraph, "object"],
        *,
        correction=None,
        packed: bool = False,
        with_counts: bool = True,
        drop_self_loops: bool = True,
        pin: bool = False,
        budget_triples: Optional[int] = None,
        layer_weights=None,
        layer_capacities=None,
    ) -> None:
        """Register one graph for serving.  ``source`` is a host
        :class:`CondensedGraph` or a live
        :class:`~repro.core.delta.LiveGraph` — for a live source the tier
        registers a version listener, so every ``apply_delta`` drives the
        quiesce-drain-swap handoff and result-cache invalidation
        automatically.  ``correction`` defaults to a fresh streamed
        DEDUP-C build (under ``budget_triples`` when given); ``packed``
        uploads bit-packed SpMM operands
        (:func:`~repro.core.engine.to_device_packed`).  ``pin`` exempts
        the tenant from LRU eviction.  ``layer_weights`` /
        ``layer_capacities`` carry the tenant's per-virtual-layer edge
        properties for the ``shortest`` / ``widest`` kinds (see
        :func:`~repro.core.engine.propagate`); they are tenant state
        handed to the shared executables as call arguments."""
        if name in self.tenants:
            raise ValueError(f"tenant {name!r} already registered")
        live = None
        if hasattr(source, "apply_delta") and hasattr(source, "graph"):
            live = source
            host = live.graph
            version = int(live.version)
        else:
            host = source
            version = 0
        if correction is None:
            correction = _dedup.build_correction_streaming(
                host,
                budget_triples=budget_triples,
                drop_self_loops=drop_self_loops,
            )
        def _as_weight_pytree(lw, what):
            if lw is None:
                return None
            # Validate against the host chain structure here, at admission,
            # so a mismatch fails with the tenant's name instead of deep
            # inside a jitted serve step.
            if len(lw) != len(host.chains):
                raise ValueError(
                    f"tenant {name!r}: {what} must cover all "
                    f"{len(host.chains)} chains; got {len(lw)}"
                )
            for ci, (cw, chain) in enumerate(zip(lw, host.chains)):
                n_virt = len(chain.edges) - 1
                if len(cw) != n_virt:
                    raise ValueError(
                        f"tenant {name!r}: chain {ci} has {n_virt} virtual "
                        f"layers; got {len(cw)} {what} arrays"
                    )
            return tuple(
                tuple(jnp.asarray(w, dtype=jnp.float32) for w in chain_w)
                for chain_w in lw
            )

        tenant = _Tenant(
            name, host, correction, version,
            packed=packed, with_counts=with_counts,
            drop_self_loops=drop_self_loops, pin=pin, live=live,
            layer_weights=_as_weight_pytree(layer_weights, "layer_weights"),
            layer_capacities=_as_weight_pytree(
                layer_capacities, "layer_capacities"
            ),
        )
        self.tenants[name] = tenant
        if live is not None:
            def _listener(graph, new_version, _name=name):
                self._refresh_tenant(_name, graph, int(new_version))

            live.add_version_listener(_listener)
            tenant._listener = _listener

    def update_tenant(self, name: str, graph: CondensedGraph, version: int) -> List[ServeResult]:
        """Manual version handoff for tenants not backed by a
        :class:`LiveGraph`: quiesce, drain in-flight against the old
        graph, swap host state, invalidate the result cache.  Returns the
        drained results (old-version answers)."""
        return self._refresh_tenant(name, graph, version)

    def _refresh_tenant(self, name: str, graph: CondensedGraph, version: int) -> List[ServeResult]:
        tenant = self.tenants[name]
        if version <= tenant.version:
            raise ValueError(
                f"tenant {name!r} version must increase: {version} <= "
                f"{tenant.version}"
            )
        tenant.quiescing = True
        try:
            drained = self._drain_tenant(name)
            self._evict_device(tenant, invalidation=True)
            tenant.host = graph
            tenant.correction = _dedup.build_correction_streaming(
                graph, drop_self_loops=tenant.drop_self_loops
            )
            tenant.version = int(version)
            self.invalidate_results(name)
        finally:
            tenant.quiescing = False
        self._handoff.extend(drained)
        return drained

    def _drain_tenant(self, name: str) -> List[ServeResult]:
        out: List[ServeResult] = []
        while any(t == name and q for (t, _), q in self._queues.items()):
            out.extend(self.step(tenant=name))
        return out

    # -- residency ------------------------------------------------------------

    def _ensure_resident(self, tenant: _Tenant) -> None:
        self._tick += 1
        tenant.last_used = self._tick
        if tenant.device is not None:
            return
        to_dev = _engine.to_device_packed if tenant.packed else _engine.to_device
        exact = to_dev(
            tenant.host,
            correction=tenant.correction,
            drop_self_loops=tenant.drop_self_loops,
            graph_version=tenant.version,
        )
        counts = None
        nbytes = device_graph_bytes(exact)
        if tenant.with_counts:
            counts = to_dev(
                tenant.host, drop_self_loops=False,
                graph_version=tenant.version,
            )
            nbytes += device_graph_bytes(counts)
        while not self.budget.would_fit(nbytes):
            if not self._evict_lru(exclude=tenant.name):
                break   # nothing left to evict: charge() raises below
        self.budget.charge(nbytes, f"tenant {tenant.name!r}")
        tenant.device = exact
        tenant.counts_device = counts
        tenant.resident_bytes = nbytes
        tenant.n_uploads += 1

    def _evict_device(self, tenant: _Tenant, invalidation: bool = False) -> None:
        if tenant.device is None:
            return
        self.budget.release(tenant.resident_bytes, evicted=not invalidation)
        tenant.device = None
        tenant.counts_device = None
        tenant.resident_bytes = 0

    def _evict_lru(self, exclude: Optional[str] = None) -> bool:
        """Evict the least-recently-used unpinned resident tenant;
        returns False when there is nothing left to evict."""
        candidates = [
            t for t in self.tenants.values()
            if t.device is not None and not t.pin and t.name != exclude
        ]
        if not candidates:
            return False
        victim = min(candidates, key=lambda t: t.last_used)
        self._evict_device(victim)
        return True

    def evict_tenant(self, name: str) -> None:
        """Explicitly drop one tenant's device operands (host state and
        caches stay; the next query re-uploads byte-identically)."""
        self._evict_device(self.tenants[name])

    # -- caches ---------------------------------------------------------------

    def invalidate_results(self, tenant: Optional[str] = None) -> int:
        """Drop cached results — one tenant's (a version bump: its old
        version's answers are unreachable anyway, reclaim the memory) or
        everyone's.  Returns the number of entries dropped."""
        if tenant is None:
            n = len(self._results)
            self._results.clear()
        else:
            keys = [k for k in self._results if k[0] == tenant]
            for k in keys:
                del self._results[k]
            n = len(keys)
        self.result_stats.invalidated += n
        return n

    def _executable(self, kind: str, width: int, signature: str) -> _Executable:
        key = (kind, width, signature)
        entry = self._executables.get(key)
        if entry is not None:
            self._executables.move_to_end(key)
            self.exec_stats.hits += 1
            return entry
        entry = self._build_executable(kind)
        self._executables[key] = entry
        self.exec_stats.misses += 1
        while len(self._executables) > self.max_executables:
            self._executables.popitem(last=False)
            self.exec_stats.evictions += 1
        return entry

    def _build_executable(self, kind: str) -> _Executable:
        import jax

        traces = [0]
        if kind == "bfs":
            max_iters = self.bfs_max_iters

            def raw(graph, sources):
                traces[0] += 1
                return algorithms.bfs_multi(graph, sources, max_iters=max_iters)

        elif kind == "ppr":
            damping, iters = self.damping, self.ppr_iters

            def raw(graph, sources):
                traces[0] += 1
                seeds = algorithms.one_hot_frontier(
                    algorithms.n_nodes(graph), sources
                )
                return algorithms.personalized_pagerank(
                    graph, seeds, damping=damping, num_iters=iters
                )

        elif kind == "common_neighbors":

            def raw(graph, sources):
                traces[0] += 1
                return algorithms.common_neighbors_multi(graph, sources)

        elif kind == "shortest":

            def raw(graph, sources, layer_weights):
                traces[0] += 1
                return algorithms.shortest_paths_multi(
                    graph, sources, layer_weights=layer_weights
                )

        elif kind == "widest":

            def raw(graph, sources, layer_capacities):
                traces[0] += 1
                return algorithms.widest_paths_multi(
                    graph, sources, layer_capacities=layer_capacities
                )

        elif kind == "scc":
            # host-driven: one pivot sweep answers the whole batch — each
            # column is the queried node's SCC membership indicator
            def raw(graph, sources):
                traces[0] += 1
                labels = algorithms.scc_labels(graph)
                cols = labels[np.asarray(sources)]
                return (labels[:, None] == cols[None, :]).astype(np.float32)

        else:  # triangles
            # host-driven whole-graph analytic: every column is the full
            # per-node triangle-count vector (the node is a handle, the
            # batch shares one blocked sweep)
            def raw(graph, sources):
                traces[0] += 1
                t = algorithms.triangle_counts(graph).astype(np.float32)
                return np.tile(t[:, None], (1, int(np.asarray(sources).size)))

        if kind in HOST_KINDS:
            return _Executable(fn=raw, traces=traces)
        return _Executable(fn=jax.jit(raw), traces=traces)

    # -- admission ------------------------------------------------------------

    def _validate(self, req: ServeRequest) -> _Tenant:
        tenant = self.tenants.get(req.tenant)
        if tenant is None:
            raise ValueError(
                f"unknown tenant {req.tenant!r}; serving "
                f"{sorted(self.tenants)}"
            )
        if tenant.quiescing:
            raise ValueError(
                f"tenant {req.tenant!r} is quiescing for a version "
                f"handoff; resubmit after the swap"
            )
        if req.kind not in KINDS:
            raise ValueError(f"unknown query kind {req.kind!r}")
        if (
            req.graph_version is not None
            and int(req.graph_version) != tenant.version
        ):
            raise ValueError(
                f"stale graph_version {int(req.graph_version)} for tenant "
                f"{req.tenant!r}: serving version {tenant.version}; "
                f"re-resolve the node id and resubmit"
            )
        if not 0 <= req.node < tenant.n_nodes:
            raise ValueError(
                f"node {req.node} out of range for tenant {req.tenant!r} "
                f"with {tenant.n_nodes} nodes"
            )
        if req.qid in self._pending_qids:
            raise ValueError(
                f"qid {req.qid} already pending; answers are keyed by qid"
            )
        return tenant

    def submit(self, req: ServeRequest) -> Optional[ServeResult]:
        """Admit one request.  A result-cache hit completes immediately
        (the returned :class:`ServeResult`); otherwise the request joins
        its ``(tenant, kind)`` queue and ``None`` is returned — the
        answer arrives from a later :meth:`step`."""
        tenant = self._validate(req)
        self.now = max(self.now, req.arrival_time)
        key = (req.tenant, req.kind, int(req.node), tenant.version)
        if self.result_cache_enabled:
            hit = self._results.get(key)
            if hit is not None:
                self.result_stats.hits += 1
                self.stats.n_queries += 1
                return ServeResult(
                    qid=req.qid, tenant=req.tenant, kind=req.kind,
                    node=req.node, value=hit, graph_version=tenant.version,
                    cached=True, arrival_time=req.arrival_time,
                    done_time=self.now,
                )
            self.result_stats.misses += 1
        qkey = (req.tenant, req.kind)
        self._queues.setdefault(qkey, []).append(req)
        self._pending_qids.add(req.qid)
        return None

    @property
    def n_pending(self) -> int:
        return sum(len(q) for q in self._queues.values())

    def _bucket_width(self, b: int) -> int:
        for w in self.bucket_widths:
            if b <= w:
                return w
        return self.max_batch

    # -- execution ------------------------------------------------------------

    def _pick_queue(self, tenant: Optional[str] = None) -> Optional[Tuple[str, str]]:
        best = None
        best_t = None
        for key, queue in self._queues.items():
            if not queue or (tenant is not None and key[0] != tenant):
                continue
            head = queue[0].arrival_time
            if best is None or head < best_t:
                best, best_t = key, head
        return best

    def step(self, tenant: Optional[str] = None) -> List[ServeResult]:
        """Execute one batch: the queue with the oldest waiting request
        (optionally restricted to one tenant), up to ``max_batch``
        requests, padded to its bucket width.  Advances the virtual
        clock by the batch's measured execution time and returns the
        completed results."""
        key = self._pick_queue(tenant)
        if key is None:
            return []
        tname, kind = key
        queue = self._queues[key]
        group, rest = queue[: self.max_batch], queue[self.max_batch :]
        self._queues[key] = rest
        t = self.tenants[tname]
        t0 = time.perf_counter()
        self._ensure_resident(t)
        graph = t.graph_for(kind)
        width = self._bucket_width(len(group))
        nodes = [int(q.node) for q in group]
        nodes += [nodes[0]] * (width - len(nodes))
        entry = self._executable(
            kind, width, graph_shape_signature(graph)
        )
        call = (with_graph_version(graph, 0), jnp.asarray(nodes, dtype=jnp.int32))
        if kind in WEIGHTED_KINDS:
            res = np.asarray(entry.fn(*call, t.weights_for(kind)))
        else:
            res = np.asarray(entry.fn(*call))
        dt = time.perf_counter() - t0
        self.now += dt
        self.stats.record_batch(len(group), width)
        out: List[ServeResult] = []
        for i, q in enumerate(group):
            value = res[:, i]
            ckey = (tname, kind, int(q.node), t.version)
            if self.result_cache_enabled:
                self._results[ckey] = value
            self._pending_qids.discard(q.qid)
            self.stats.n_queries += 1
            out.append(ServeResult(
                qid=q.qid, tenant=tname, kind=kind, node=q.node,
                value=value, graph_version=t.version, cached=False,
                arrival_time=q.arrival_time, done_time=self.now,
                batch_width=width, batch_fill=len(group),
            ))
        return out

    def take_handoff(self) -> List[ServeResult]:
        """Results drained out-of-band by a version handoff (the bumped
        tenant's in-flight queries, answered at the superseded version)."""
        out, self._handoff = self._handoff, []
        return out

    def drain(self) -> List[ServeResult]:
        """Run :meth:`step` until every queue is empty."""
        out = self.take_handoff()
        while self.n_pending:
            out.extend(self.step())
        return out

    def serve(self, requests: Sequence[ServeRequest]) -> Dict[int, np.ndarray]:
        """Submit-then-drain convenience: ``{qid: (n,) answer}``."""
        out: Dict[int, np.ndarray] = {}
        for req in requests:
            res = self.submit(req)
            if res is not None:
                out[res.qid] = res.value
        for res in self.drain():
            out[res.qid] = res.value
        return out

    def run_load(self, requests: Sequence[ServeRequest]) -> List[ServeResult]:
        """Load-generator loop: admit requests at their virtual arrival
        times, execute batches continuously, advance the clock by real
        measured batch times.  Returns every completion (cache hits
        included) with honest latencies under the offered schedule."""
        reqs = sorted(requests, key=lambda r: r.arrival_time)
        results: List[ServeResult] = []
        i = 0
        while i < len(reqs) or self.n_pending:
            while i < len(reqs) and reqs[i].arrival_time <= self.now + 1e-12:
                res = self.submit(reqs[i])
                i += 1
                if res is not None:
                    results.append(res)
            if self.n_pending == 0:
                if i < len(reqs):
                    self.now = reqs[i].arrival_time
                    continue
                break
            results.extend(self.step())
        results.extend(self.take_handoff())
        return results
