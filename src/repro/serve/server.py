"""Batched serving loops: LM decode over a KV cache, and graph analytics
over a condensed graph.

Two deliberately compact production shapes:

* :class:`BatchedServer` — fixed-slot LM batch, each slot an independent
  request; prefill admits new requests into free slots; decode advances
  all active slots one token per step.  (Slot-level batching is the
  scheduling core of vLLM-style serving; paging is out of scope for a
  CPU container and noted in DESIGN.md §5.)
* :class:`GraphQueryServer` — micro-batching front-end for multi-source
  graph analytics (DESIGN.md §3/§5): queued per-node queries of the same
  kind are fused into one ``(n, B)`` frontier and answered by a single
  batched propagation call instead of ``B`` serial traversals.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import TransformerConfig
from ..core import algorithms
from ..core.engine import DeviceGraph

# The LM stack is only needed by BatchedServer; it is imported inside its
# methods (cached by sys.modules) so graph-analytics users of this module
# don't pay for (or depend on) it.

__all__ = [
    "Request",
    "BatchedServer",
    "GraphQuery",
    "GraphQueryServer",
    "ServerStats",
]


@dataclasses.dataclass
class ServerStats:
    """Batching efficiency of one flush (or an accumulation of many).

    ``queries_batched`` counts real queries answered by propagation
    batches; ``slots_compiled`` counts the padded bucket slots those
    batches occupied.  Their ratio is the **occupancy** — the fraction of
    compiled SpMM columns doing real work — and its complement is the
    bucket-padding waste, the quantity the fixed-width bucketing trades
    for a bounded compile-shape count.  ``batch_widths_used`` maps padded
    width -> batches answered at that width (the compile-shape census
    that used to be counted on the server but never reported)."""

    n_queries: int = 0           # queries answered (cache hits included)
    n_batches: int = 0           # propagation batches launched
    queries_batched: int = 0     # real queries inside those batches
    slots_compiled: int = 0      # padded slots (sum of bucket widths used)
    batch_widths_used: Dict[int, int] = dataclasses.field(default_factory=dict)

    @property
    def occupancy(self) -> float:
        """Real queries per compiled slot in [0, 1]; 1.0 when idle."""
        if self.slots_compiled == 0:
            return 1.0
        return self.queries_batched / self.slots_compiled

    @property
    def padding_waste(self) -> float:
        """Fraction of compiled slots that were bucket padding."""
        return 1.0 - self.occupancy

    def record_batch(self, n_real: int, width: int) -> None:
        self.n_batches += 1
        self.queries_batched += int(n_real)
        self.slots_compiled += int(width)
        self.batch_widths_used[width] = (
            self.batch_widths_used.get(width, 0) + 1
        )

    def merge(self, other: "ServerStats") -> None:
        """Fold another flush's stats into this accumulator."""
        self.n_queries += other.n_queries
        self.n_batches += other.n_batches
        self.queries_batched += other.queries_batched
        self.slots_compiled += other.slots_compiled
        for w, c in other.batch_widths_used.items():
            self.batch_widths_used[w] = self.batch_widths_used.get(w, 0) + c


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # (T,) int32
    max_new_tokens: int
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class BatchedServer:
    """Greedy-decode batched server over fixed slots (single host demo)."""

    def __init__(
        self,
        params,
        cfg: TransformerConfig,
        batch_slots: int = 4,
        max_len: int = 256,
    ):
        from ..models import transformer

        self.params = params
        self.cfg = cfg
        self.slots: List[Optional[Request]] = [None] * batch_slots
        self.max_len = max_len
        self.cache = transformer.init_cache(cfg, batch_slots, max_len)
        self.lengths = np.zeros(batch_slots, dtype=np.int64)

        def decode(params, cache, tokens):
            logits, cache, _ = transformer.forward(params, tokens, cfg, cache)
            return jnp.argmax(logits[:, -1], axis=-1), cache

        self._decode = jax.jit(decode)

    def _free_slot(self) -> Optional[int]:
        for i, s in enumerate(self.slots):
            if s is None:
                return i
        return None

    def _active_length(self) -> Optional[int]:
        """Common sequence length of the active slots, or None if idle.

        The shared :class:`KVCache` carries one scalar ``length``, so every
        active slot must sit at the same position; admission enforces that
        invariant and decode preserves it (all active slots advance one
        token per step)."""
        for i, s in enumerate(self.slots):
            if s is not None:
                return int(self.lengths[i])
        return None

    def can_admit(self, req: Request) -> bool:
        """True iff ``admit(req)`` would succeed right now: a slot is free
        and the prompt length matches the active batch (or the batch is
        idle).  Schedulers use this to defer ragged requests until the
        current batch drains instead of tripping the admission error."""
        if self._free_slot() is None:
            return False
        active = self._active_length()
        return active is None or int(req.prompt.size) == active

    def admit(self, req: Request) -> bool:
        """Prefill a request into a free slot (one slot at a time demo).

        Raises ``ValueError`` on ragged admission — a prompt whose length
        differs from the active slots'.  The batch cache has a single
        scalar ``length``, so decoding a shorter request at the longer
        batch position would read garbage keys/values (and previously
        served silently wrong tokens).  Use :meth:`can_admit` to defer
        instead."""
        from ..models import transformer

        slot = self._free_slot()
        if slot is None:
            return False
        active = self._active_length()
        if active is not None and int(req.prompt.size) != active:
            raise ValueError(
                f"ragged admission: prompt length {int(req.prompt.size)} != "
                f"active batch length {active}; the shared KV cache has one "
                f"scalar length, so all active slots must decode in lockstep. "
                f"Use can_admit() to defer this request until the batch "
                f"drains."
            )
        # per-slot prefill: run the prompt through with a slot-local cache,
        # then splice into the batch cache.
        scfg = self.cfg
        prompt = jnp.asarray(req.prompt[None, :], dtype=jnp.int32)
        cache1 = transformer.init_cache(scfg, 1, self.max_len)
        logits, cache1, _ = transformer.forward(self.params, prompt, scfg, cache1)
        first = int(jnp.argmax(logits[0, -1]))
        req.generated.append(first)
        self.cache = transformer.KVCache(
            k=self.cache.k.at[:, slot : slot + 1].set(cache1.k),
            v=self.cache.v.at[:, slot : slot + 1].set(cache1.v),
            length=self.cache.length,
        )
        self.lengths[slot] = req.prompt.size
        self.slots[slot] = req
        return True

    def step(self) -> None:
        """One decode step for every active slot."""
        from ..models import transformer

        if all(s is None for s in self.slots):
            return
        tokens = np.zeros((len(self.slots), 1), dtype=np.int32)
        for i, s in enumerate(self.slots):
            if s is not None and s.generated:
                tokens[i, 0] = s.generated[-1]
        # Admission enforces that active slots share one length, so the
        # common active length is the batch position.  (max() over all
        # slots would be wrong: a freed slot's stale length, or a longer
        # concurrent prompt, would shift every other slot's attention
        # window past its real history.)
        cache = transformer.KVCache(
            k=self.cache.k, v=self.cache.v,
            length=jnp.asarray(self._active_length(), jnp.int32),
        )
        nxt, cache = self._decode(self.params, cache, jnp.asarray(tokens))
        self.cache = cache
        nxt = np.asarray(nxt)
        for i, s in enumerate(self.slots):
            if s is None:
                continue
            s.generated.append(int(nxt[i]))
            self.lengths[i] += 1
            if len(s.generated) >= s.max_new_tokens:
                s.done = True
                self.slots[i] = None
                self.lengths[i] = 0

    def run(self, requests: List[Request]) -> Dict[int, List[int]]:
        pending = list(requests)
        out: Dict[int, List[int]] = {}
        active: List[Request] = []
        while pending or any(self.slots):
            # Admit every pending request whose prompt length matches the
            # active batch (all of them when idle); ragged requests are
            # deferred until the batch drains rather than rejected.  No
            # livelock: with all slots free any request is admissible, and
            # with active slots step() always makes progress.
            admitted = True
            while admitted:
                admitted = False
                for j, r in enumerate(pending):
                    if self.can_admit(r):
                        self.admit(pending.pop(j))
                        active.append(r)
                        admitted = True
                        break
            self.step()
            for r in active:
                if r.done:
                    out[r.rid] = r.generated
            active = [r for r in active if not r.done]
        for r in requests:
            out.setdefault(r.rid, r.generated)
        return out


# ---------------------------------------------------------------------------
# Graph-analytics serving: fuse queued queries into one batched propagation
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class GraphQuery:
    """One node-seeded analytics request.

    ``kind``: ``'bfs'`` (hop distances), ``'ppr'`` (personalized PageRank
    from a one-hot restart at ``node``), or ``'common_neighbors'``
    (path-multiplicity scores — the recsys scoring primitive; needs a
    duplicate-counting graph, e.g. raw C-DUP kept with self loops).

    ``graph_version``: the graph version the client computed ``node``
    against (e.g. from :class:`~repro.core.delta.LiveGraph`).  ``None``
    means "whatever the server holds"; a mismatch with the server's
    current version is rejected at submit time — node ids are only
    meaningful relative to one version's node space.
    """

    qid: int
    kind: str
    node: int
    graph_version: Optional[int] = None


class GraphQueryServer:
    """Micro-batching graph-analytics server over one device graph.

    Incoming queries are queued with :meth:`submit`; :meth:`flush` groups
    them by kind, packs up to ``max_batch`` sources into one ``(n, B)``
    frontier, and answers the whole group with a single batched algorithm
    call (:func:`~repro.core.algorithms.bfs_multi` & friends).  Amortizing
    the graph traversal over the batch is the serving-side payoff of the
    condensed representation: extract once, answer many (paper §6.1.3).
    """

    def __init__(
        self,
        graph: DeviceGraph,
        max_batch: int = 32,
        ppr_iters: int = 20,
        damping: float = 0.85,
        bfs_max_iters: Optional[int] = None,
        counts_graph: Optional[DeviceGraph] = None,
        bucket_widths: Tuple[int, ...] = (8, 16, 32),
        graph_version: Optional[int] = None,
    ):
        """``graph`` must be duplicate-exact (EXP / DEDUP-C / DEDUP-1) for
        ``'ppr'`` queries; ``'common_neighbors'`` queries are answered from
        ``counts_graph`` (a raw C-DUP, typically kept *with* self loops so
        the multiplicity signal survives), defaulting to ``graph``.

        ``bucket_widths``: flush groups are padded up to the smallest of
        these fixed widths (capped by ``max_batch``), so live traffic with
        arbitrary group sizes compiles at most ``len(bucket_widths) + 1``
        propagation shapes per kind instead of one per distinct B.

        ``graph_version``: the version this server's graph was extracted
        at; defaults to the device graph's own ``graph_version`` field.
        Queries stamped with a different version are rejected — see
        :class:`GraphQuery` and :meth:`update_graph`."""
        self.graph = graph
        self.counts_graph = counts_graph if counts_graph is not None else graph
        if graph_version is None:
            graph_version = int(getattr(graph, "graph_version", 0))
        self.graph_version = int(graph_version)
        self.max_batch = int(max_batch)
        self.ppr_iters = int(ppr_iters)
        self.damping = float(damping)
        self.bfs_max_iters = bfs_max_iters
        widths = sorted({int(w) for w in bucket_widths if 0 < int(w) < self.max_batch})
        self.bucket_widths: Tuple[int, ...] = tuple(widths) + (self.max_batch,)
        self.pending: List[GraphQuery] = []
        self._pending_qids: set = set()
        # served-traffic accounting (asserted in tests, shown in examples)
        self.n_queries = 0
        self.n_propagation_batches = 0
        # compile-shape accounting: {padded width: batches answered}
        self.batch_widths_used: Dict[int, int] = {}
        # batching-efficiency accounting: lifetime accumulation and the
        # last flush's snapshot (per-flush stats are also returned by
        # flush(with_stats=True) / run(with_stats=True))
        self.stats = ServerStats()
        self.last_flush_stats = ServerStats()
        # admission gate: True while an update_graph handoff is draining
        # in-flight queries — submits are rejected, flush still runs
        self.quiescing = False
        # set by from_condensed: streaming-correction build evidence
        self.correction_accounting = None

    def _bucket_width(self, b: int) -> int:
        """Smallest fixed width >= b (groups are pre-chunked to max_batch)."""
        for w in self.bucket_widths:
            if b <= w:
                return w
        return self.max_batch

    @classmethod
    def from_condensed(
        cls,
        graph,
        *,
        budget_bytes: Optional[int] = None,
        budget_triples: Optional[int] = None,
        packed: bool = False,
        drop_self_loops: bool = True,
        graph_version: int = 0,
        **kwargs,
    ) -> "GraphQueryServer":
        """Load a host ``CondensedGraph`` for serving.

        Builds the DEDUP-C correction with
        :func:`~repro.core.dedup.build_correction_streaming` under the
        given expansion budget — so a server can load graphs whose full
        expansion exceeds host memory — and wires the duplicate-exact
        graph for ``bfs``/``ppr`` next to a raw C-DUP ``counts_graph``
        (self loops kept so the multiplicity signal survives) for
        ``common_neighbors``.  ``packed=True`` uses
        :func:`~repro.core.engine.to_device_packed` so batched ring steps
        can hit the Pallas SpMM.  The build's
        :class:`~repro.core.condensed.ExpansionAccounting` is kept on
        ``server.correction_accounting``.
        """
        from ..core import dedup as _dedup
        from ..core import engine as _engine

        correction = _dedup.build_correction_streaming(
            graph,
            budget_bytes=budget_bytes,
            budget_triples=budget_triples,
            drop_self_loops=drop_self_loops,
        )
        to_dev = _engine.to_device_packed if packed else _engine.to_device
        exact = to_dev(
            graph, correction=correction, drop_self_loops=drop_self_loops,
            graph_version=graph_version,
        )
        counts = to_dev(
            graph, drop_self_loops=False, graph_version=graph_version
        )
        server = cls(exact, counts_graph=counts, **kwargs)
        server.correction_accounting = correction.accounting
        return server

    def _validate(self, query: GraphQuery, extra_qids: set) -> None:
        if query.kind not in ("bfs", "ppr", "common_neighbors"):
            raise ValueError(f"unknown query kind {query.kind!r}")
        # Node ids are positions in one version's node space; a query
        # stamped against an older (or newer) graph would be answered
        # about a different node entirely.  Reject instead of guessing.
        if (
            query.graph_version is not None
            and int(query.graph_version) != self.graph_version
        ):
            raise ValueError(
                f"stale graph_version {int(query.graph_version)}: server "
                f"is serving version {self.graph_version}; re-resolve the "
                f"node id against the current graph and resubmit"
            )
        if query.qid in self._pending_qids or query.qid in extra_qids:
            raise ValueError(
                f"qid {query.qid} already pending; answers are keyed by qid"
            )
        # JAX scatters silently drop out-of-bounds indices (and wrap
        # negative ones), which would serve a confidently wrong answer.
        target = (
            self.counts_graph if query.kind == "common_neighbors" else self.graph
        )
        n = algorithms.n_nodes(target)
        if not 0 <= query.node < n:
            raise ValueError(
                f"node {query.node} out of range for graph with {n} nodes"
            )

    def submit(self, query: GraphQuery) -> None:
        if self.quiescing:
            raise ValueError(
                "server is quiescing for update_graph(): new admissions "
                "are rejected while in-flight queries drain against "
                f"version {self.graph_version}; resubmit after the swap"
            )
        self._validate(query, set())
        self.pending.append(query)
        self._pending_qids.add(query.qid)

    def begin_quiesce(self) -> None:
        """Stop admitting new queries (submits raise) while keeping
        :meth:`flush` available to drain the in-flight queue.  Under
        continuous admission the queue is never naturally empty, so a
        graph swap cannot wait for it to drain on its own — it closes the
        door first, then drains what already got in."""
        self.quiescing = True

    def end_quiesce(self) -> None:
        self.quiescing = False

    def update_graph(
        self,
        graph: DeviceGraph,
        counts_graph: Optional[DeviceGraph] = None,
        graph_version: Optional[int] = None,
    ) -> Dict[int, np.ndarray]:
        """Swap in a freshly extracted device graph (e.g. after
        :meth:`~repro.core.delta.LiveGraph.apply_delta`) and bump
        ``graph_version``.

        The version lives in the device graphs' jit-static metadata, so
        the bump invalidates every compiled propagation executable and
        cached packed operand by construction — the next flush traces
        against the new graph.

        Pending queries were validated against the *old* node space, so
        they are owed an old-graph answer — but under continuous
        admission the queue is never empty, so "flush first" would never
        fire.  The handoff instead quiesces new admissions (submits raise
        while the swap is in progress), drains the in-flight queue
        against the old graph, then swaps and reopens.  Returns the
        drained answers, keyed by qid, computed at the superseded
        version."""
        if graph_version is None:
            graph_version = int(getattr(graph, "graph_version", 0))
            if graph_version == self.graph_version:
                graph_version = self.graph_version + 1
        if int(graph_version) <= self.graph_version:
            raise ValueError(
                f"graph_version must increase: {int(graph_version)} <= "
                f"current {self.graph_version}"
            )
        self.begin_quiesce()
        try:
            # drain-in-flight: answered by the graph they were validated
            # against.  A mid-drain failure leaves the queue intact and
            # the server still quiesced on the old graph — retryable.
            drained = self.flush() if self.pending else {}
            self.graph = graph
            self.counts_graph = (
                counts_graph if counts_graph is not None else graph
            )
            self.graph_version = int(graph_version)
        finally:
            self.end_quiesce()
        return drained

    def _answer_group(
        self, kind: str, group: List[GraphQuery]
    ) -> Tuple[Dict[int, np.ndarray], int]:
        """Returns (answers, padded width) — the width actually compiled,
        so flush's compile-shape accounting can't drift from the padding
        decision made here."""
        # pad the frontier to a fixed bucket width (repeating the first
        # source — columns are independent, extras are sliced off) so the
        # batched propagation compiles once per bucket, not per group size
        width = self._bucket_width(len(group))
        nodes = [q.node for q in group]
        nodes += [nodes[0]] * (width - len(nodes))
        sources = jnp.asarray(nodes, dtype=jnp.int32)
        if kind == "bfs":
            res = algorithms.bfs_multi(
                self.graph, sources, max_iters=self.bfs_max_iters
            )
        elif kind == "ppr":
            n = algorithms.n_nodes(self.graph)
            seeds = algorithms.one_hot_frontier(n, sources)
            res = algorithms.personalized_pagerank(
                self.graph, seeds, damping=self.damping,
                num_iters=self.ppr_iters,
            )
        else:  # common_neighbors
            res = algorithms.common_neighbors_multi(self.counts_graph, sources)
        res = np.asarray(res)
        return {q.qid: res[:, i] for i, q in enumerate(group)}, width

    def flush(self, with_stats: bool = False):
        """Answer everything queued; returns ``{qid: (n,) result}``, or
        ``(answers, ServerStats)`` for this flush with
        ``with_stats=True``.  The per-flush stats (occupancy, padding
        waste, width census) are also kept on ``last_flush_stats`` and
        accumulated into ``stats``."""
        out: Dict[int, np.ndarray] = {}
        by_kind: Dict[str, List[GraphQuery]] = {}
        for q in self.pending:
            by_kind.setdefault(q.kind, []).append(q)
        flush_stats = ServerStats()
        batches: List[Tuple[int, int]] = []   # (real queries, padded width)
        for kind, group in by_kind.items():
            for i in range(0, len(group), self.max_batch):
                chunk = group[i : i + self.max_batch]
                answers, width = self._answer_group(kind, chunk)
                out.update(answers)
                batches.append((len(chunk), width))
        # queue and counters committed only once every group answered, so
        # a failure mid-flush leaves pending intact and counts unchanged
        # for a retry
        flush_stats.n_queries = len(self.pending)
        for n_real, w in batches:
            flush_stats.record_batch(n_real, w)
        self.n_propagation_batches += flush_stats.n_batches
        self.n_queries += flush_stats.n_queries
        for w, c in flush_stats.batch_widths_used.items():
            self.batch_widths_used[w] = self.batch_widths_used.get(w, 0) + c
        self.last_flush_stats = flush_stats
        self.stats.merge(flush_stats)
        self.pending = []
        self._pending_qids = set()
        return (out, flush_stats) if with_stats else out

    def run(self, queries: List[GraphQuery], with_stats: bool = False):
        if self.quiescing:
            raise ValueError(
                "server is quiescing for update_graph(); resubmit after "
                "the swap"
            )
        # validate the whole batch before enqueuing any of it, so a bad
        # query can't leave earlier ones orphaned in the queue
        seen: set = set()
        for q in queries:
            self._validate(q, seen)
            seen.add(q.qid)
        for q in queries:
            self.pending.append(q)
            self._pending_qids.add(q.qid)
        return self.flush(with_stats=with_stats)
