"""Batched LM serving loop: continuous prefill + decode over a KV cache.

A deliberately compact production shape: fixed-slot batch, each slot an
independent request; prefill admits new requests into free slots; decode
advances all active slots one token per step.  (Slot-level batching is
the scheduling core of vLLM-style serving; paging is out of scope for a
CPU container and noted in DESIGN.md.)
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import TransformerConfig
from ..models import transformer

__all__ = ["Request", "BatchedServer"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # (T,) int32
    max_new_tokens: int
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class BatchedServer:
    """Greedy-decode batched server over fixed slots (single host demo)."""

    def __init__(
        self,
        params,
        cfg: TransformerConfig,
        batch_slots: int = 4,
        max_len: int = 256,
    ):
        self.params = params
        self.cfg = cfg
        self.slots: List[Optional[Request]] = [None] * batch_slots
        self.max_len = max_len
        self.cache = transformer.init_cache(cfg, batch_slots, max_len)
        self.lengths = np.zeros(batch_slots, dtype=np.int64)

        def decode(params, cache, tokens):
            logits, cache, _ = transformer.forward(params, tokens, cfg, cache)
            return jnp.argmax(logits[:, -1], axis=-1), cache

        self._decode = jax.jit(decode)

    def _free_slot(self) -> Optional[int]:
        for i, s in enumerate(self.slots):
            if s is None:
                return i
        return None

    def admit(self, req: Request) -> bool:
        """Prefill a request into a free slot (one slot at a time demo)."""
        slot = self._free_slot()
        if slot is None:
            return False
        # per-slot prefill: run the prompt through with a slot-local cache,
        # then splice into the batch cache.
        scfg = self.cfg
        prompt = jnp.asarray(req.prompt[None, :], dtype=jnp.int32)
        cache1 = transformer.init_cache(scfg, 1, self.max_len)
        logits, cache1, _ = transformer.forward(self.params, prompt, scfg, cache1)
        first = int(jnp.argmax(logits[0, -1]))
        req.generated.append(first)
        self.cache = transformer.KVCache(
            k=self.cache.k.at[:, slot : slot + 1].set(cache1.k),
            v=self.cache.v.at[:, slot : slot + 1].set(cache1.v),
            length=self.cache.length,
        )
        self.lengths[slot] = req.prompt.size
        self.slots[slot] = req
        return True

    def step(self) -> None:
        """One decode step for every active slot."""
        if all(s is None for s in self.slots):
            return
        tokens = np.zeros((len(self.slots), 1), dtype=np.int32)
        for i, s in enumerate(self.slots):
            if s is not None and s.generated:
                tokens[i, 0] = s.generated[-1]
        # batch cache length: slots grow in lockstep in this demo; use max.
        cache = transformer.KVCache(
            k=self.cache.k, v=self.cache.v,
            length=jnp.asarray(int(self.lengths.max()), jnp.int32),
        )
        nxt, cache = self._decode(self.params, cache, jnp.asarray(tokens))
        self.cache = cache
        nxt = np.asarray(nxt)
        for i, s in enumerate(self.slots):
            if s is None:
                continue
            s.generated.append(int(nxt[i]))
            self.lengths[i] += 1
            if len(s.generated) >= s.max_new_tokens:
                s.done = True
                self.slots[i] = None

    def run(self, requests: List[Request]) -> Dict[int, List[int]]:
        pending = list(requests)
        out: Dict[int, List[int]] = {}
        active: List[Request] = []
        while pending or any(self.slots):
            while pending and self._free_slot() is not None:
                r = pending.pop(0)
                self.admit(r)
                active.append(r)
            self.step()
            for r in active:
                if r.done:
                    out[r.rid] = r.generated
            active = [r for r in active if not r.done]
        for r in requests:
            out.setdefault(r.rid, r.generated)
        return out
