"""Serving substrate: KV-cache serving loop, graph-analytics micro-batching,
and request batching."""
from .server import BatchedServer, GraphQuery, GraphQueryServer, Request

__all__ = ["BatchedServer", "GraphQuery", "GraphQueryServer", "Request"]
