"""Serving substrate: KV-cache serving loop, graph-analytics micro-batching,
request batching, and the continuous-batching multi-tenant tier."""
from .server import BatchedServer, GraphQuery, GraphQueryServer, Request, ServerStats
from .tier import (
    ExecutableCacheStats,
    GraphServingTier,
    ResultCacheStats,
    ServeRequest,
    ServeResult,
)

__all__ = [
    "BatchedServer",
    "GraphQuery",
    "GraphQueryServer",
    "Request",
    "ServerStats",
    "GraphServingTier",
    "ServeRequest",
    "ServeResult",
    "ExecutableCacheStats",
    "ResultCacheStats",
]
