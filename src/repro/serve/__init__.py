"""Serving substrate: KV-cache serving loop and request batching."""
