"""Jit'd public wrappers around the Pallas kernels with XLA fallback.

``bitmap_spmm``       one condensed layer:  y = B ⊕ x (any kernel semiring)
``condensed_two_hop`` the paper's hot loop: y = B_out @ (B_in @ x)

Backend selection: ``backend='pallas'`` uses the bit-packed streamed MXU
kernel (compiled on TPU, interpret mode elsewhere); ``'xla'`` uses the
gather/segment-reduce path; ``'auto'`` consults the measured-crossover
table recorded at pack time (:mod:`repro.kernels.autotune`) when the
layer carries one — the backend the measurement says is faster wins —
and otherwise falls back to the footprint formula: pallas whenever the
kernel's *streamed* working set fits VMEM
(:func:`repro.kernels.pack.fits_vmem`) — since the source column is
streamed, this no longer depends on the source count, so arbitrarily
tall source columns dispatch to the kernel.  ``reverse=True`` propagates
along transposed edges using the reverse packing carried by
:class:`PackedLayer`.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp

from ..core.condensed import BipartiteEdges
from ..core.semiring import PLUS_TIMES, Semiring, kernelizable
from .autotune import DEFAULT_CONFIG, CrossoverTable, KernelConfig
from .bitmap_spmm import bitmap_spmm_pallas
from .pack import TILE, BlockSparseBitmap, fits_vmem, pack_bipartite
from .ref import segment_semiring_ref

__all__ = [
    "PackedLayer",
    "pack_layer",
    "bitmap_spmm",
    "condensed_two_hop",
    "resolve_backend",
]


@dataclasses.dataclass
class PackedLayer:
    """Both kernel operands for one bipartite layer, in both directions.

    ``bsb`` is the dst-major forward packing (``y = B @ x``); ``bsb_rev``
    packs the transposed incidence so ``reverse=True`` (HITS, out-degrees)
    dispatches to the kernel too instead of being segment-only.
    ``crossover`` is the optional measured-crossover table recorded at
    pack time (``from_edges(..., measure=True)``); when present, 'auto'
    dispatch follows the measurement instead of the footprint formula.
    """

    bsb: BlockSparseBitmap
    bsb_rev: Optional[BlockSparseBitmap]
    src: jnp.ndarray
    dst: jnp.ndarray
    n_src: int
    n_dst: int
    crossover: Optional[CrossoverTable] = None

    @classmethod
    def from_edges(
        cls,
        edges: BipartiteEdges,
        with_reverse: bool = True,
        measure: bool = False,
        measure_batch_sizes: "tuple[int, ...]" = (128,),
        measure_ops: "tuple[str, ...]" = ("sum",),
    ) -> "PackedLayer":
        layer = cls(
            bsb=pack_bipartite(edges),
            bsb_rev=pack_bipartite(edges.reversed()) if with_reverse else None,
            src=jnp.asarray(edges.src, dtype=jnp.int32),
            dst=jnp.asarray(edges.dst, dtype=jnp.int32),
            n_src=edges.n_src,
            n_dst=edges.n_dst,
        )
        if measure:
            from .autotune import measure_crossover

            layer.crossover = measure_crossover(
                layer, ops=measure_ops, batch_sizes=measure_batch_sizes
            )
        return layer


def pack_layer(edges: BipartiteEdges) -> PackedLayer:
    return PackedLayer.from_edges(edges)


def resolve_backend(
    backend: str,
    n_features: int,
    feature_block: int,
    itemsize: int,
    semiring: Semiring = PLUS_TIMES,
    packable: bool = True,
    n_slots: Optional[int] = None,
    table: Optional[CrossoverTable] = None,
    n_src: Optional[int] = None,
) -> str:
    """The one 'auto' resolution both dispatch sites agree on.

    Precedence: (1) a measured crossover entry, when a ``table`` recorded
    at pack time covers this (op, n_src, B) cell — 'auto' never selects a
    backend the measurement says is slower, and a measured-pallas win is
    still sanity-checked against the VMEM/SMEM budget of its recorded
    config; (2) the footprint formula — pallas when the layer is packed,
    the semiring is kernelizable, and the streamed working set fits VMEM
    (plus the SMEM slot tables, when ``n_slots`` is known); xla
    otherwise.  Exposed so tests and benchmarks can assert dispatch
    honesty without running the kernel."""
    if backend != "auto":
        return backend
    if not packable or not kernelizable(semiring):
        return "xla"
    if table is not None and n_src is not None:
        entry = table.lookup(semiring.add_kind, n_src, n_features)
        if entry is not None:
            if entry.backend == "xla":
                return "xla"
            return (
                "pallas"
                if fits_vmem(
                    n_features,
                    entry.feature_block,
                    itemsize,
                    n_slots=n_slots,
                    row_window=entry.row_window,
                )
                else "xla"
            )
    return (
        "pallas"
        if fits_vmem(n_features, feature_block, itemsize, n_slots=n_slots)
        else "xla"
    )


def _pad_to(x: jnp.ndarray, rows: int, cols: int) -> jnp.ndarray:
    pr, pc = rows - x.shape[0], cols - x.shape[1]
    return x if pr == 0 and pc == 0 else jnp.pad(x, ((0, pr), (0, pc)))


def _pallas_spmm(
    bsb: BlockSparseBitmap,
    x: jnp.ndarray,
    config: KernelConfig,
    semiring: Semiring,
    interpret: Optional[bool],
) -> jnp.ndarray:
    f = x.shape[1]
    f_pad = -(-f // config.feature_block) * config.feature_block
    # pad the source axis to a whole number of streamed windows (a
    # row_window > TILE config fetches several source tiles per step)
    n_src_pad = (
        -(-(bsb.n_src_tiles * TILE) // config.row_window) * config.row_window
    )
    n_dst_pad = bsb.n_row_tiles * TILE
    xp = _pad_to(x, n_src_pad, f_pad)
    yp = bitmap_spmm_pallas(
        jnp.asarray(bsb.slot_src),
        jnp.asarray(bsb.slot_row),
        jnp.asarray(bsb.row_start),
        jnp.asarray(bsb.row_count),
        jnp.asarray(bsb.bitmaps),
        xp,
        n_dst_pad=n_dst_pad,
        feature_block=config.feature_block,
        op=semiring.add_kind,
        zero=float(semiring.zero),
        interpret=interpret,
        row_window=config.row_window,
    )
    return yp[: bsb.n_dst, :f]


def bitmap_spmm(
    layer: PackedLayer,
    x: jnp.ndarray,
    backend: str = "auto",
    feature_block: int = 128,
    interpret: Optional[bool] = None,
    semiring: Semiring = PLUS_TIMES,
    reverse: bool = False,
    config: Optional[KernelConfig] = None,
) -> jnp.ndarray:
    """y[dst] = ⊕ over edges of x[src]; x may be (n_src,) or (n_src, F).

    ``reverse=True`` flips the edge direction (x indexed by dst, output
    over src) using the transposed packing.  ``semiring`` selects the
    ⊕-reduction; idempotent min/max run the masked-select kernel variant.
    ``config`` pins the kernel window geometry; left None, the layer's
    crossover table supplies the measured-fastest config for this cell
    (``feature_block`` is the legacy single-axis override and still wins
    when no table/config is present).
    """
    squeeze = x.ndim == 1
    if squeeze:
        x = x[:, None]
    bsb = layer.bsb_rev if reverse else layer.bsb
    # n_src of the dispatched direction = the source count the kernel
    # actually streams over (layer.n_dst when reversed)
    n_src_dir = layer.n_dst if reverse else layer.n_src
    backend = resolve_backend(
        backend,
        x.shape[1],
        feature_block,
        x.dtype.itemsize,
        semiring=semiring,
        packable=bsb is not None,
        n_slots=bsb.n_slots if bsb is not None else None,
        table=layer.crossover,
        n_src=n_src_dir,
    )
    if backend == "xla":
        src, dst = (layer.dst, layer.src) if reverse else (layer.src, layer.dst)
        n_out = layer.n_src if reverse else layer.n_dst
        y = segment_semiring_ref(src, dst, x, n_out, semiring=semiring)
    elif backend == "pallas":
        if bsb is None:
            raise ValueError(
                "reverse=True needs the transposed packing; build the "
                "layer with PackedLayer.from_edges(..., with_reverse=True)"
                if reverse
                else "layer has no packing"
            )
        if config is None:
            if layer.crossover is not None:
                config = layer.crossover.config_for(
                    semiring.add_kind, n_src_dir, x.shape[1]
                )
            else:
                config = KernelConfig(feature_block=feature_block)
        y = _pallas_spmm(bsb, x, config, semiring, interpret)
    else:
        raise ValueError(f"unknown backend {backend!r}")
    return y[:, 0] if squeeze else y


def condensed_two_hop(
    layer_in: PackedLayer,
    layer_out: PackedLayer,
    x: jnp.ndarray,
    backend: str = "auto",
    feature_block: int = 128,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """The condensed hot loop: y = B_out @ (B_in @ x) (plus-times)."""
    h = bitmap_spmm(layer_in, x, backend, feature_block, interpret)
    return bitmap_spmm(layer_out, h, backend, feature_block, interpret)
