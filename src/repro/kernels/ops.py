"""Jit'd public wrappers around the Pallas kernels with XLA fallback.

``bitmap_spmm``       one condensed layer:  y = B ⊕ x (any kernel semiring)
``condensed_two_hop`` the paper's hot loop: y = B_out @ (B_in @ x)

Backend selection: ``backend='pallas'`` uses the bit-packed streamed MXU
kernel (compiled on TPU, interpret mode elsewhere); ``'xla'`` uses the
gather/segment-reduce path; ``'auto'`` picks pallas whenever the kernel's
*streamed* working set fits VMEM (:func:`repro.kernels.pack.fits_vmem`) —
since the source column is streamed, this no longer depends on the source
count, so arbitrarily tall source columns dispatch to the kernel.
``reverse=True`` propagates along transposed edges using the reverse
packing carried by :class:`PackedLayer`.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp

from ..core.condensed import BipartiteEdges
from ..core.semiring import PLUS_TIMES, Semiring, kernelizable
from .bitmap_spmm import bitmap_spmm_pallas
from .pack import TILE, BlockSparseBitmap, fits_vmem, pack_bipartite
from .ref import segment_semiring_ref

__all__ = [
    "PackedLayer",
    "pack_layer",
    "bitmap_spmm",
    "condensed_two_hop",
    "resolve_backend",
]


@dataclasses.dataclass
class PackedLayer:
    """Both kernel operands for one bipartite layer, in both directions.

    ``bsb`` is the dst-major forward packing (``y = B @ x``); ``bsb_rev``
    packs the transposed incidence so ``reverse=True`` (HITS, out-degrees)
    dispatches to the kernel too instead of being segment-only.
    """

    bsb: BlockSparseBitmap
    bsb_rev: Optional[BlockSparseBitmap]
    src: jnp.ndarray
    dst: jnp.ndarray
    n_src: int
    n_dst: int

    @classmethod
    def from_edges(
        cls, edges: BipartiteEdges, with_reverse: bool = True
    ) -> "PackedLayer":
        return cls(
            bsb=pack_bipartite(edges),
            bsb_rev=pack_bipartite(edges.reversed()) if with_reverse else None,
            src=jnp.asarray(edges.src, dtype=jnp.int32),
            dst=jnp.asarray(edges.dst, dtype=jnp.int32),
            n_src=edges.n_src,
            n_dst=edges.n_dst,
        )


def pack_layer(edges: BipartiteEdges) -> PackedLayer:
    return PackedLayer.from_edges(edges)


def resolve_backend(
    backend: str,
    n_features: int,
    feature_block: int,
    itemsize: int,
    semiring: Semiring = PLUS_TIMES,
    packable: bool = True,
    n_slots: Optional[int] = None,
) -> str:
    """The one 'auto' resolution both dispatch sites agree on: pallas when
    the layer is packed, the semiring is kernelizable, and the streamed
    working set fits VMEM (plus the SMEM slot tables, when ``n_slots`` is
    known); xla otherwise.  Exposed so tests and benchmarks can assert
    no-fallback without running the kernel."""
    if backend != "auto":
        return backend
    if not packable or not kernelizable(semiring):
        return "xla"
    return (
        "pallas"
        if fits_vmem(n_features, feature_block, itemsize, n_slots=n_slots)
        else "xla"
    )


def _pad_to(x: jnp.ndarray, rows: int, cols: int) -> jnp.ndarray:
    pr, pc = rows - x.shape[0], cols - x.shape[1]
    return x if pr == 0 and pc == 0 else jnp.pad(x, ((0, pr), (0, pc)))


def _pallas_spmm(
    bsb: BlockSparseBitmap,
    x: jnp.ndarray,
    feature_block: int,
    semiring: Semiring,
    interpret: Optional[bool],
) -> jnp.ndarray:
    f = x.shape[1]
    f_pad = -(-f // feature_block) * feature_block
    n_src_pad = bsb.n_src_tiles * TILE
    n_dst_pad = bsb.n_row_tiles * TILE
    xp = _pad_to(x, n_src_pad, f_pad)
    yp = bitmap_spmm_pallas(
        jnp.asarray(bsb.slot_src),
        jnp.asarray(bsb.slot_row),
        jnp.asarray(bsb.row_start),
        jnp.asarray(bsb.row_count),
        jnp.asarray(bsb.bitmaps),
        xp,
        n_dst_pad=n_dst_pad,
        feature_block=feature_block,
        op=semiring.add_kind,
        zero=float(semiring.zero),
        interpret=interpret,
    )
    return yp[: bsb.n_dst, :f]


def bitmap_spmm(
    layer: PackedLayer,
    x: jnp.ndarray,
    backend: str = "auto",
    feature_block: int = 128,
    interpret: Optional[bool] = None,
    semiring: Semiring = PLUS_TIMES,
    reverse: bool = False,
) -> jnp.ndarray:
    """y[dst] = ⊕ over edges of x[src]; x may be (n_src,) or (n_src, F).

    ``reverse=True`` flips the edge direction (x indexed by dst, output
    over src) using the transposed packing.  ``semiring`` selects the
    ⊕-reduction; idempotent min/max run the masked-select kernel variant.
    """
    squeeze = x.ndim == 1
    if squeeze:
        x = x[:, None]
    bsb = layer.bsb_rev if reverse else layer.bsb
    backend = resolve_backend(
        backend,
        x.shape[1],
        feature_block,
        x.dtype.itemsize,
        semiring=semiring,
        packable=bsb is not None,
        n_slots=bsb.n_slots if bsb is not None else None,
    )
    if backend == "xla":
        src, dst = (layer.dst, layer.src) if reverse else (layer.src, layer.dst)
        n_out = layer.n_src if reverse else layer.n_dst
        y = segment_semiring_ref(src, dst, x, n_out, semiring=semiring)
    elif backend == "pallas":
        if bsb is None:
            raise ValueError(
                "reverse=True needs the transposed packing; build the "
                "layer with PackedLayer.from_edges(..., with_reverse=True)"
                if reverse
                else "layer has no packing"
            )
        y = _pallas_spmm(bsb, x, feature_block, semiring, interpret)
    else:
        raise ValueError(f"unknown backend {backend!r}")
    return y[:, 0] if squeeze else y


def condensed_two_hop(
    layer_in: PackedLayer,
    layer_out: PackedLayer,
    x: jnp.ndarray,
    backend: str = "auto",
    feature_block: int = 128,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """The condensed hot loop: y = B_out @ (B_in @ x) (plus-times)."""
    h = bitmap_spmm(layer_in, x, backend, feature_block, interpret)
    return bitmap_spmm(layer_out, h, backend, feature_block, interpret)
