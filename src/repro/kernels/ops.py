"""Jit'd public wrappers around the Pallas kernels with XLA fallback.

``bitmap_spmm``       one condensed layer:  y = B @ x
``condensed_two_hop`` the paper's hot loop: y = B_out @ (B_in @ x)

Backend selection: ``backend='pallas'`` uses the bit-packed MXU kernel
(interpret mode on CPU, compiled on TPU); ``'xla'`` uses the
gather/segment-sum path; ``'auto'`` picks pallas when the source feature
column fits the VMEM budget.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.condensed import BipartiteEdges
from .bitmap_spmm import bitmap_spmm_pallas
from .pack import TILE, BlockSparseBitmap, fits_vmem_column, pack_bipartite
from .ref import segment_spmm_ref

__all__ = ["PackedLayer", "pack_layer", "bitmap_spmm", "condensed_two_hop"]



@dataclasses.dataclass
class PackedLayer:
    """Both kernel operands for one bipartite layer."""

    bsb: BlockSparseBitmap
    src: jnp.ndarray
    dst: jnp.ndarray
    n_src: int
    n_dst: int

    @classmethod
    def from_edges(cls, edges: BipartiteEdges) -> "PackedLayer":
        return cls(
            bsb=pack_bipartite(edges),
            src=jnp.asarray(edges.src, dtype=jnp.int32),
            dst=jnp.asarray(edges.dst, dtype=jnp.int32),
            n_src=edges.n_src,
            n_dst=edges.n_dst,
        )


def pack_layer(edges: BipartiteEdges) -> PackedLayer:
    return PackedLayer.from_edges(edges)


def _pad_rows(x: jnp.ndarray, n: int) -> jnp.ndarray:
    pad = n - x.shape[0]
    return x if pad == 0 else jnp.pad(x, ((0, pad), (0, 0)))


def _pad_cols(x: jnp.ndarray, m: int) -> jnp.ndarray:
    pad = m - x.shape[1]
    return x if pad == 0 else jnp.pad(x, ((0, 0), (0, pad)))


def bitmap_spmm(
    layer: PackedLayer,
    x: jnp.ndarray,
    backend: str = "auto",
    feature_block: int = 128,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """y[dst] = sum over edges of x[src]; x may be (n_src,) or (n_src, F)."""
    squeeze = x.ndim == 1
    if squeeze:
        x = x[:, None]
    n_src_pad = -(-layer.n_src // TILE) * TILE
    f_pad = -(-x.shape[1] // feature_block) * feature_block
    if backend == "auto":
        fits = fits_vmem_column(
            n_src_pad, x.shape[1], feature_block, x.dtype.itemsize
        )
        backend = "pallas" if fits else "xla"
    if backend == "xla":
        y = segment_spmm_ref(layer.src, layer.dst, x, layer.n_dst)
    elif backend == "pallas":
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        xp = _pad_cols(_pad_rows(x, n_src_pad), f_pad)
        n_dst_pad = layer.bsb.n_row_tiles * TILE
        yp = bitmap_spmm_pallas(
            jnp.asarray(layer.bsb.blocks),
            jnp.asarray(layer.bsb.bitmaps),
            xp,
            n_dst_pad=n_dst_pad,
            feature_block=feature_block,
            interpret=interpret,
        )
        y = yp[: layer.n_dst, : x.shape[1]]
    else:
        raise ValueError(f"unknown backend {backend!r}")
    return y[:, 0] if squeeze else y


def condensed_two_hop(
    layer_in: PackedLayer,
    layer_out: PackedLayer,
    x: jnp.ndarray,
    backend: str = "auto",
    feature_block: int = 128,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """The condensed hot loop: y = B_out @ (B_in @ x) (plus-times)."""
    h = bitmap_spmm(layer_in, x, backend, feature_block, interpret)
    return bitmap_spmm(layer_out, h, backend, feature_block, interpret)
