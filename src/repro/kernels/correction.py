"""Bit-plane packing of the DEDUP-C correction + fused-stream assembly.

The DEDUP-C correction is a sparse integer matrix ``D`` of duplicate-path
counts: ring propagation is made exact by ``y = M x − D x`` (paper §4.1).
Until now ``D x`` ran as a separate gather + ``segment_sum`` with the
subtraction applied on the result — a second pass over ``x`` outside the
kernel.  This module feeds the subtraction *into* the Pallas kernel's
epilogue (DESIGN.md §6):

* :func:`pack_correction` decomposes the counts into bit-planes,
  ``D = Σ_k 2^k · D_k`` with each ``D_k`` a 0/1 incidence — so every
  plane packs into the same 128x128 uint32 bitmaps the main kernel
  already streams, and ``D x`` becomes ``Σ_k 2^k (D_k x)``: plain
  bit-packed SpMMs scaled by exact powers of two (the scaling loses no
  float precision, so integer-valued frontiers stay byte-identical to
  the two-pass ``segment_sum`` result).
* :func:`build_fused_stream` interleaves the final layer's incidence
  slots with the correction slots, per destination row tile (main slots
  first, then that tile's correction slots).  The fused kernel
  (:func:`repro.kernels.bitmap_spmm.bitmap_spmm_fused_pallas`) walks
  this combined stream with *two* VMEM accumulators — main slots feed
  ``acc``, correction slots feed ``cacc`` — and the epilogue writes
  ``acc − cacc``: structurally the same arithmetic as SpMM-then-subtract,
  with one kernel launch and one pass over the output tiles.

Host-side numpy only; uploading is the engine's job.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

from .pack import TILE, WORDS, BlockSparseBitmap

__all__ = ["CorrectionPlanes", "FusedStream", "pack_correction", "build_fused_stream"]


@dataclasses.dataclass
class CorrectionPlanes:
    """Bit-plane packed correction: rows = dst, cols = src, one bitmap
    stack per nonzero block, one plane per count bit.  Unlike
    :class:`~repro.kernels.pack.BlockSparseBitmap` there are *no* pad
    slots — empty row tiles simply contribute no correction slots (the
    fused stream's main slots already visit every row tile)."""

    slot_src: np.ndarray       # (n_slots,) int32 — source tile per block
    slot_row: np.ndarray       # (n_slots,) int32 — dst row tile per block
    row_start: np.ndarray      # (n_rt,) int32
    row_count: np.ndarray      # (n_rt,) int32 — may be zero
    planes: np.ndarray         # (n_slots, n_planes, TILE, WORDS) uint32
    plane_weights: Tuple[float, ...]  # 2**k per plane
    n_dst: int
    n_src: int

    @property
    def n_slots(self) -> int:
        return int(self.slot_src.shape[0])

    @property
    def n_planes(self) -> int:
        return int(self.planes.shape[1])

    @property
    def n_src_tiles(self) -> int:
        return max(-(-self.n_src // TILE), 1)

    @property
    def n_row_tiles(self) -> int:
        return int(self.row_start.shape[0])

    def to_dense(self) -> np.ndarray:
        """Oracle helper: dense (n_dst_pad, n_src_pad) count matrix."""
        dense = np.zeros(
            (self.n_row_tiles * TILE, self.n_src_tiles * TILE), np.float64
        )
        shifts = np.arange(32, dtype=np.uint32)
        for s in range(self.n_slots):
            i, b = int(self.slot_row[s]), int(self.slot_src[s])
            for k, w in enumerate(self.plane_weights):
                bits = (
                    (self.planes[s, k][:, :, None] >> shifts) & 1
                ).reshape(TILE, TILE)
                dense[i * TILE : (i + 1) * TILE, b * TILE : (b + 1) * TILE] += (
                    w * bits
                )
        return dense


def pack_correction(
    cs: np.ndarray, cd: np.ndarray, cm: np.ndarray, n_src: int, n_dst: int
) -> CorrectionPlanes:
    """Pack correction triples (src, dst, count) into bit-planes.

    ``count`` must be positive integers (duplicate-path counts are);
    ``n_planes`` is the bit width of the largest count, so typical
    corrections (counts 1–3) cost one or two planes.
    """
    cs = np.asarray(cs, dtype=np.int64)
    cd = np.asarray(cd, dtype=np.int64)
    cm = np.asarray(cm)
    cmi = cm.astype(np.int64)
    if cs.size and (np.any(cmi <= 0) or np.any(cmi != cm)):
        raise ValueError("correction counts must be positive integers")
    n_rt = max(-(-n_dst // TILE), 1)
    n_st = max(-(-n_src // TILE), 1)
    n_planes = max(int(cmi.max()).bit_length(), 1) if cs.size else 1
    bkey = (cd // TILE) * n_st + (cs // TILE)
    uniq, inv = np.unique(bkey, return_inverse=True)
    n_slots = uniq.size
    slot_row = (uniq // n_st).astype(np.int32)
    slot_src = (uniq % n_st).astype(np.int32)
    row_count = np.bincount(slot_row, minlength=n_rt).astype(np.int32)
    row_start = np.concatenate([[0], np.cumsum(row_count[:-1])]).astype(np.int32)
    r = cd % TILE
    c = cs % TILE
    word = c // 32
    bit = (c % 32).astype(np.uint32)
    flat = np.zeros(n_slots * n_planes * TILE * WORDS, dtype=np.uint32)
    for k in range(n_planes):
        sel = ((cmi >> k) & 1).astype(bool)
        if not sel.any():
            continue
        lin = ((inv[sel] * n_planes + k) * TILE + r[sel]) * WORDS + word[sel]
        np.bitwise_or.at(flat, lin, np.uint32(1) << bit[sel])
    return CorrectionPlanes(
        slot_src=slot_src,
        slot_row=slot_row,
        row_start=row_start,
        row_count=row_count,
        planes=flat.reshape(n_slots, n_planes, TILE, WORDS),
        plane_weights=tuple(float(2**k) for k in range(n_planes)),
        n_dst=n_dst,
        n_src=n_src,
    )


@dataclasses.dataclass
class FusedStream:
    """The combined slot stream the fused kernel walks: per destination
    row tile, the main incidence slots (kind 0) followed by that tile's
    correction slots (kind 1).  ``main_idx``/``corr_idx`` index into the
    respective bitmap/plane stacks; the inactive index of each slot is 0
    (the fetched-but-unused operand is mathematically inert).  Likewise
    ``main_src``/``corr_src`` route the two streamed feature operands
    (``h`` — the last hidden frontier — and ``x`` — the original input)."""

    kind: np.ndarray       # (n_slots,) int32 — 0 main, 1 correction
    main_src: np.ndarray   # (n_slots,) int32 — h source tile
    corr_src: np.ndarray   # (n_slots,) int32 — x source tile
    main_idx: np.ndarray   # (n_slots,) int32 — index into main bitmaps
    corr_idx: np.ndarray   # (n_slots,) int32 — index into corr planes
    slot_row: np.ndarray   # (n_slots,) int32
    row_start: np.ndarray  # (n_rt,) int32
    row_count: np.ndarray  # (n_rt,) int32

    @property
    def n_slots(self) -> int:
        return int(self.kind.shape[0])


def build_fused_stream(
    main: BlockSparseBitmap, corr: CorrectionPlanes
) -> FusedStream:
    """Interleave a layer's packed incidence with the packed correction.

    Both must share the destination space (``n_dst``) — the fused kernel
    writes each output row tile exactly once, after *all* of its main and
    correction slots have accumulated.  The main packing's pad-slot
    invariant (every row tile has ≥ 1 slot) carries over, so first/last
    bookkeeping needs no special cases.
    """
    if main.n_dst != corr.n_dst:
        raise ValueError(
            f"fused stream needs a shared destination space: "
            f"main n_dst={main.n_dst}, correction n_dst={corr.n_dst}"
        )
    if main.n_row_tiles != corr.n_row_tiles:
        raise ValueError("row-tile counts disagree")
    m, c = main.n_slots, corr.n_slots
    rows = np.concatenate([main.slot_row, corr.slot_row]).astype(np.int64)
    kind = np.concatenate(
        [np.zeros(m, np.int32), np.ones(c, np.int32)]
    )
    # stable sort by (row, kind): keeps each group's internal order, puts
    # main slots before correction slots within a row tile
    order = np.argsort(rows * 2 + kind, kind="stable")
    zeros_m = np.zeros(m, np.int32)
    zeros_c = np.zeros(c, np.int32)
    main_idx = np.concatenate([np.arange(m, dtype=np.int32), zeros_c])
    corr_idx = np.concatenate([zeros_m, np.arange(c, dtype=np.int32)])
    main_src = np.concatenate([main.slot_src.astype(np.int32), zeros_c])
    corr_src = np.concatenate([zeros_m, corr.slot_src.astype(np.int32)])
    row_count = (main.row_count + corr.row_count).astype(np.int32)
    row_start = np.concatenate([[0], np.cumsum(row_count[:-1])]).astype(np.int32)
    return FusedStream(
        kind=kind[order],
        main_src=main_src[order],
        corr_src=corr_src[order],
        main_idx=main_idx[order],
        corr_idx=corr_idx[order],
        slot_row=rows[order].astype(np.int32),
        row_start=row_start,
        row_count=row_count,
    )
