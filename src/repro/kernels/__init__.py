"""Pallas TPU kernels for the paper's compute hot-spot: condensed-layer
SpMM.  ``bitmap_spmm.py`` (pl.pallas_call + BlockSpec VMEM tiling, the
BITMAP representation reborn as bit-packed block-sparse MXU operands,
plus the fused DEDUP-C-epilogue variant), ``ops.py`` (jit wrappers + XLA
fallback), ``ref.py`` (pure-jnp oracles), ``pack.py`` (host-side
packing), ``correction.py`` (bit-plane correction packing + fused-stream
assembly), ``autotune.py`` (config sweep + measured-crossover dispatch
table)."""
from .autotune import (
    CANDIDATES,
    DEFAULT_CONFIG,
    CrossoverEntry,
    CrossoverTable,
    KernelConfig,
    autotune_spmm,
    measure_crossover,
)
from .ops import (
    PackedLayer,
    bitmap_spmm,
    condensed_two_hop,
    pack_layer,
    resolve_backend,
)

__all__ = [
    "PackedLayer",
    "bitmap_spmm",
    "condensed_two_hop",
    "pack_layer",
    "resolve_backend",
    "KernelConfig",
    "DEFAULT_CONFIG",
    "CANDIDATES",
    "CrossoverEntry",
    "CrossoverTable",
    "autotune_spmm",
    "measure_crossover",
]
