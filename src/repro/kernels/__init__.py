"""Pallas TPU kernels for the paper's compute hot-spot: condensed-layer
SpMM.  ``bitmap_spmm.py`` (pl.pallas_call + BlockSpec VMEM tiling, the
BITMAP representation reborn as bit-packed block-sparse MXU operands),
``ops.py`` (jit wrappers + XLA fallback), ``ref.py`` (pure-jnp oracles),
``pack.py`` (host-side packing)."""
from .ops import (
    PackedLayer,
    bitmap_spmm,
    condensed_two_hop,
    pack_layer,
    resolve_backend,
)

__all__ = [
    "PackedLayer",
    "bitmap_spmm",
    "condensed_two_hop",
    "pack_layer",
    "resolve_backend",
]
