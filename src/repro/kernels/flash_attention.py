"""Pallas TPU flash-attention forward (the §Perf cell-2 memory fix).

Pure-XLA blockwise attention still streams s/p score blocks through HBM
(~6 x T² f32 per layer-pass — the dominant memory-roofline term for LM
training, EXPERIMENTS.md §Perf).  This kernel keeps the entire online-
softmax state in VMEM scratch: HBM traffic drops to q/k/v/out only.

Grid: ``(batch, q_heads, q_blocks, kv_blocks)`` — the innermost dimension
revisits the same output block (TPU grids execute sequentially), carrying
(acc, m, l) in VMEM scratch; on the last kv block the normalized tile is
written out.  GQA folds the group into the head index (k/v BlockSpecs map
``h -> h // group``).  Causal blocks strictly above the diagonal are
skipped with ``pl.when``.

Compiled path is TPU-only (CPU dry-runs cannot lower Pallas custom
calls); interpret mode validates the kernel body on CPU against the
pure-jnp oracle (tests/test_kernels.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

__all__ = ["flash_attention_pallas"]

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
            *, causal: bool, scale: float, bq: int, bkv: int, nkv: int):
    i_q = pl.program_id(2)
    i_kv = pl.program_id(3)

    @pl.when(i_kv == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    def _step():
        q = q_ref[0, :, 0, :]                    # (bq, d)
        k = k_ref[0, :, 0, :]                    # (bkv, d)
        v = v_ref[0, :, 0, :]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                 # (bq, bkv)
        if causal:
            q_pos = i_q * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
            k_pos = i_kv * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
            s = jnp.where(k_pos <= q_pos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(s <= NEG_INF / 2, 0.0, p)
        alpha = jnp.exp(m_prev - m_new)
        alpha = jnp.where(m_prev <= NEG_INF / 2, 0.0, alpha)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new

    if causal:
        # skip kv blocks strictly above the causal diagonal
        pl.when(i_kv * bkv <= i_q * bq + (bq - 1))(_step)
    else:
        _step()

    @pl.when(i_kv == nkv - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_kv", "interpret"),
)
def flash_attention_pallas(
    q: jnp.ndarray,     # (B, Tq, H, D)
    k: jnp.ndarray,     # (B, Tk, KV, D)
    v: jnp.ndarray,
    causal: bool = True,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    B, Tq, H, D = q.shape
    _, Tk, KV, _ = k.shape
    if H % KV:
        raise ValueError(f"H={H} not a multiple of KV={KV}")
    G = H // KV
    bq = min(block_q, Tq)
    bkv = min(block_kv, Tk)
    pad_q = (-Tq) % bq
    pad_kv = (-Tk) % bkv
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_kv:
        # padded keys masked out by causal/softmax: give them -inf via the
        # causal mask when causal; for non-causal, padded keys would leak —
        # mask by padding k with a huge negative... instead require exact
        # tiling for non-causal (enforced below).
        k = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
    if not causal and pad_kv:
        raise ValueError("non-causal path requires Tk % block_kv == 0")
    Tq_p, Tk_p = Tq + pad_q, Tk + pad_kv
    nq, nkv = Tq_p // bq, Tk_p // bkv
    grid = (B, H, nq, nkv)

    out = pl.pallas_call(
        functools.partial(
            _kernel, causal=causal or pad_kv > 0, scale=1.0 / np.sqrt(D),
            bq=bq, bkv=bkv, nkv=nkv,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, 1, D), lambda b, h, iq, ik: (b, iq, h, 0)),
            pl.BlockSpec((1, bkv, 1, D), lambda b, h, iq, ik: (b, ik, h // G, 0)),
            pl.BlockSpec((1, bkv, 1, D), lambda b, h, iq, ik: (b, ik, h // G, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, D), lambda b, h, iq, ik: (b, iq, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Tq_p, H, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :Tq]
