"""Pure-jnp oracles for the kernels package (the correctness ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..core.semiring import PLUS_TIMES, Semiring, segment_reduce
from .pack import BlockSparseBitmap

__all__ = [
    "bitmap_spmm_ref",
    "segment_spmm_ref",
    "segment_semiring_ref",
    "two_hop_ref",
]


def bitmap_spmm_ref(bsb: BlockSparseBitmap, x: np.ndarray) -> np.ndarray:
    """Dense oracle: unpack every block and matmul (small inputs only)."""
    dense = bsb.to_dense()
    n_src_pad = dense.shape[1]
    xp = np.zeros((n_src_pad, x.shape[1]), dtype=np.float64)
    xp[: x.shape[0]] = x
    return (dense.astype(np.float64) @ xp)[: bsb.n_dst]


def segment_spmm_ref(
    src: jnp.ndarray, dst: jnp.ndarray, x: jnp.ndarray, n_dst: int
) -> jnp.ndarray:
    """Edge-list oracle: y[dst] += x[src] via segment_sum (XLA path)."""
    return jax.ops.segment_sum(jnp.take(x, src, axis=0), dst, num_segments=n_dst)


def segment_semiring_ref(
    src: jnp.ndarray,
    dst: jnp.ndarray,
    x: jnp.ndarray,
    n_dst: int,
    semiring: Semiring = PLUS_TIMES,
) -> jnp.ndarray:
    """Edge-list oracle under any semiring: y[dst] = ⊕ x[src] (the XLA
    segment-reduce path the kernel must agree with, for min/max too)."""
    return segment_reduce(semiring, jnp.take(x, src, axis=0), dst, n_dst)


def two_hop_ref(
    in_src: jnp.ndarray,
    in_dst: jnp.ndarray,
    n_virtual: int,
    out_src: jnp.ndarray,
    out_dst: jnp.ndarray,
    n_real: int,
    x: jnp.ndarray,
) -> jnp.ndarray:
    """Condensed 2-hop oracle: y = B_out (B_in x)."""
    h = segment_spmm_ref(in_src, in_dst, x, n_virtual)
    return segment_spmm_ref(out_src, out_dst, h, n_real)
