"""Pallas TPU kernel: bit-packed block-sparse SpMM (the condensed hot loop).

Computes ``y = B @ x`` where ``B`` is the 0/1 incidence of one condensed
layer, stored as block-ELL bitmaps (:mod:`repro.kernels.pack`).  Two calls
realize the paper's 2-hop condensed propagation ``y = B_out (B_in^T x)``
without ever materializing the expanded adjacency.

TPU mapping (see DESIGN.md §6):

* grid = (dst row-tiles, feature tiles); each cell owns a (128, Fb) output
  tile in VMEM — MXU-aligned.
* the k-loop walks that row-tile's nonzero source blocks; bitmaps
  (128 x 4 uint32 = 2 KiB) are unpacked in-register into a dense 128x128
  0/1 MXU operand — 32x less HBM traffic than an f32 block.
* the source feature column (n_src_pad, Fb) resides in VMEM; source tiles
  are fetched with dynamic slices (``pl.ds``) indexed by the block table
  (data-dependent gather at tile granularity — TPU-friendly).

VMEM budget per grid cell ~= n_src_pad*Fb*4 + max_k*2KiB + 2*128*Fb*4;
``ops.bitmap_spmm`` falls back to the XLA segment-sum path when the
source column exceeds the VMEM budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .pack import TILE, WORDS

__all__ = ["bitmap_spmm_pallas"]


def _kernel(blocks_ref, bitmaps_ref, x_ref, y_ref, *, max_k: int):
    """One (row-tile, feature-tile) output block."""
    fb = y_ref.shape[-1]

    def body(k, acc):
        b = blocks_ref[0, k]
        xb = x_ref[pl.ds(b * TILE, TILE), :]  # (T, Fb) dynamic tile gather
        words = bitmaps_ref[0, k]  # (T, WORDS) uint32
        shifts = jax.lax.broadcasted_iota(jnp.uint32, (TILE, WORDS, 32), 2)
        bits = (words[:, :, None] >> shifts) & jnp.uint32(1)
        mask = bits.reshape(TILE, TILE).astype(xb.dtype)
        return acc + jnp.dot(mask, xb, preferred_element_type=jnp.float32)

    acc = jnp.zeros((TILE, fb), dtype=jnp.float32)
    acc = jax.lax.fori_loop(0, max_k, body, acc)
    y_ref[...] = acc.astype(y_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("n_dst_pad", "feature_block", "interpret")
)
def bitmap_spmm_pallas(
    blocks: jnp.ndarray,     # (n_rt, max_k) int32
    bitmaps: jnp.ndarray,    # (n_rt, max_k, TILE, WORDS) uint32
    x: jnp.ndarray,          # (n_src_pad, F) — n_src_pad, F multiples of TILE granularity
    n_dst_pad: int,
    feature_block: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    n_rt, max_k = blocks.shape
    n_src_pad, f = x.shape
    if n_dst_pad % TILE or f % feature_block or n_src_pad % TILE:
        raise ValueError(
            f"padded dims required: n_dst_pad={n_dst_pad}, f={f}, "
            f"n_src_pad={n_src_pad} (TILE={TILE}, fb={feature_block})"
        )
    grid = (n_rt, f // feature_block)
    return pl.pallas_call(
        functools.partial(_kernel, max_k=max_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, max_k), lambda i, j: (i, 0)),
            pl.BlockSpec((1, max_k, TILE, WORDS), lambda i, j: (i, 0, 0, 0)),
            pl.BlockSpec((n_src_pad, feature_block), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((TILE, feature_block), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n_dst_pad, f), x.dtype),
        interpret=interpret,
    )(blocks, bitmaps, x)
