"""Pallas TPU kernel: bit-packed block-sparse SpMM (the condensed hot loop).

Computes ``y = B @ x`` where ``B`` is the 0/1 incidence of one condensed
layer, stored as a streamed slot list of bitmap blocks
(:mod:`repro.kernels.pack`).  Two calls realize the paper's 2-hop condensed
propagation ``y = B_out (B_in^T x)`` without ever materializing the
expanded adjacency.

TPU mapping (see DESIGN.md §6):

* grid = (feature tiles, slots); the inner axis walks the packed slot
  stream — sorted by (dst row tile, src tile) — so the Pallas pipeline
  streams one (128, Fb) source tile per step through a double-buffered
  VMEM window (tile t+1 is fetched while the MXU consumes tile t).
  Per-cell VMEM is O(window), independent of n_src: no resident source
  column, no 8 MiB cliff.
* the slot tables (``slot_src``, ``slot_row``) and the per-row-tile
  (start, count) run table are scalar-prefetched into SMEM; the BlockSpec
  index maps read them to route each slot's source tile and output tile —
  a data-dependent gather at tile granularity, which is the TPU-friendly
  kind.
* bitmaps (128 x 4 uint32 = 2 KiB) are unpacked in-register into a dense
  128x128 0/1 operand — 32x less HBM traffic than an f32 block.
* a (128, Fb) f32 VMEM scratch accumulates across a row tile's slots; the
  run table marks the first slot (init) and last slot (write-out), so
  each output tile is written exactly once.
* ``op`` selects the ⊕-reduction: ``'sum'`` feeds the MXU
  (``jnp.dot(mask, x)``); ``'min'``/``'max'`` run the idempotent-semiring
  variant — masked select over column chunks on the VPU, so min-plus /
  max-times / or-and propagation (batched BFS, reachability) runs packed
  too, and ``inf`` frontiers never meet a multiply.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pack import STREAM_CHUNK as _CHUNK
from .pack import TILE, WORDS

__all__ = [
    "bitmap_spmm_pallas",
    "bitmap_spmm_fused_pallas",
    "default_interpret",
]

# _CHUNK: column chunk width of the masked-select reduction (min/max
# ops); lives in pack so the shared footprint formula sizes the
# (TILE, _CHUNK, Fb) select intermediate (~512 KiB at Fb=128).


def default_interpret() -> bool:
    """Interpret mode policy: compiled on TPU, interpreted elsewhere.

    Override with ``REPRO_PALLAS_INTERPRET=0|1`` (forcing compiled mode on
    a non-TPU backend will fail inside Mosaic — it exists for TPU hosts
    whose default backend is not the TPU plugin).
    """
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env.lower() not in ("0", "false", "no")
    return jax.default_backend() != "tpu"


def _unpack_bits(words: jnp.ndarray) -> jnp.ndarray:
    """(TILE, WORDS) uint32 -> (TILE, TILE) 0/1 uint32, in-register."""
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (TILE, WORDS, 32), 2)
    bits = (words[:, :, None] >> shifts) & jnp.uint32(1)
    return bits.reshape(TILE, TILE)


def _kernel(
    slot_src_ref,   # scalar prefetch: (n_slots,) source tile per slot
    slot_row_ref,   # scalar prefetch: (n_slots,) dst row tile per slot
    row_start_ref,  # scalar prefetch: (n_rt,) run table starts
    row_count_ref,  # scalar prefetch: (n_rt,) run table counts
    bitmaps_ref,    # (1, TILE, WORDS) current slot's bitmap
    x_ref,          # (row_window, Fb) current source window (streamed)
    y_ref,          # (TILE, Fb) output tile of the slot's row
    acc_ref,        # VMEM scratch: (TILE, Fb) f32 accumulator
    *,
    op: str,
    zero: float,
    window_tiles: int,
):
    s = pl.program_id(1)
    row = slot_row_ref[s]
    start = row_start_ref[row]
    first = s == start
    last = s == start + row_count_ref[row] - 1
    init = {"sum": 0.0, "min": jnp.inf, "max": -jnp.inf}[op]

    @pl.when(first)
    def _():
        acc_ref[...] = jnp.full(acc_ref.shape, init, acc_ref.dtype)

    if window_tiles == 1:
        x_tile = x_ref[...]
    else:
        # the fetched window spans window_tiles source tiles; this slot's
        # bitmap addresses one of them (slot_src modulo the window)
        off = (slot_src_ref[s] % window_tiles) * TILE
        x_tile = jax.lax.dynamic_slice_in_dim(x_ref[...], off, TILE, axis=0)
    bits = _unpack_bits(bitmaps_ref[0])
    if op == "sum":
        mask = bits.astype(x_tile.dtype)
        acc_ref[...] += jnp.dot(
            mask, x_tile, preferred_element_type=jnp.float32
        )
    else:
        m = bits != 0
        xf = x_tile.astype(jnp.float32)
        fill = jnp.inf if op == "min" else -jnp.inf
        combine = jnp.minimum if op == "min" else jnp.maximum
        reduce_ = jnp.min if op == "min" else jnp.max

        def body(c, acc):
            mc = jax.lax.dynamic_slice_in_dim(m, c * _CHUNK, _CHUNK, axis=1)
            xc = jax.lax.dynamic_slice_in_dim(xf, c * _CHUNK, _CHUNK, axis=0)
            vals = jnp.where(mc[:, :, None], xc[None, :, :], fill)
            return combine(acc, reduce_(vals, axis=1))

        acc_ref[...] = jax.lax.fori_loop(0, TILE // _CHUNK, body, acc_ref[...])

    @pl.when(last)
    def _():
        out = acc_ref[...]
        # rows with no incident sources take the semiring zero, matching
        # the segment-reduce path's empty-segment convention
        if op == "min":
            out = jnp.where(jnp.isposinf(out), jnp.float32(zero), out)
        elif op == "max":
            out = jnp.where(jnp.isneginf(out), jnp.float32(zero), out)
        y_ref[...] = out.astype(y_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "n_dst_pad", "feature_block", "op", "zero", "interpret", "row_window"
    ),
)
def _bitmap_spmm_pallas(
    slot_src: jnp.ndarray,
    slot_row: jnp.ndarray,
    row_start: jnp.ndarray,
    row_count: jnp.ndarray,
    bitmaps: jnp.ndarray,
    x: jnp.ndarray,
    n_dst_pad: int,
    feature_block: int,
    op: str,
    zero: float,
    interpret: bool,
    row_window: int,
) -> jnp.ndarray:
    n_slots = slot_src.shape[0]
    n_src_pad, f = x.shape
    w = row_window // TILE
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(f // feature_block, n_slots),
        in_specs=[
            pl.BlockSpec(
                (1, TILE, WORDS), lambda j, s, ss, sr, rs, rc: (s, 0, 0)
            ),
            pl.BlockSpec(
                (row_window, feature_block),
                lambda j, s, ss, sr, rs, rc: (ss[s] // w, j),
            ),
        ],
        out_specs=pl.BlockSpec(
            (TILE, feature_block), lambda j, s, ss, sr, rs, rc: (sr[s], j)
        ),
        scratch_shapes=[pltpu.VMEM((TILE, feature_block), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_kernel, op=op, zero=zero, window_tiles=w),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_dst_pad, f), x.dtype),
        interpret=interpret,
    )(slot_src, slot_row, row_start, row_count, bitmaps, x)


def _fused_kernel(
    kind_ref,       # scalar prefetch: (n_slots,) 0 = incidence, 1 = correction
    main_src_ref,   # scalar prefetch: (n_slots,) h source tile per slot
    corr_src_ref,   # scalar prefetch: (n_slots,) x source tile per slot
    main_idx_ref,   # scalar prefetch: (n_slots,) main bitmap index (BlockSpec)
    corr_idx_ref,   # scalar prefetch: (n_slots,) corr plane index (BlockSpec)
    slot_row_ref,   # scalar prefetch: (n_slots,) dst row tile per slot
    row_start_ref,  # scalar prefetch: (n_rt,) run table starts
    row_count_ref,  # scalar prefetch: (n_rt,) run table counts
    bitmaps_ref,    # (1, TILE, WORDS) current main slot's bitmap
    planes_ref,     # (1, P, TILE, WORDS) current correction slot's planes
    h_ref,          # (TILE, Fb) last-hidden source tile (main slots)
    x_ref,          # (TILE, Fb) input-frontier source tile (corr slots)
    y_ref,          # (TILE, Fb) output tile of the slot's row
    acc_ref,        # VMEM scratch: (TILE, Fb) f32 main accumulator
    cacc_ref,       # VMEM scratch: (TILE, Fb) f32 correction accumulator
    *,
    plane_weights: tuple,
):
    """Fused DEDUP-C epilogue (DESIGN.md §6): walk the interleaved
    main/correction slot stream, accumulate the two terms separately, and
    write ``acc − cacc`` once per output tile — the same arithmetic as
    SpMM-then-subtract, in one launch.  Correction slots reconstruct the
    integer count matrix from bit-planes: ``Σ_k 2^k (D_k ⊙ x)``; each
    plane feeds the MXU like a main slot, and the power-of-two scaling is
    float-exact."""
    s = pl.program_id(1)
    row = slot_row_ref[s]
    start = row_start_ref[row]
    first = s == start
    last = s == start + row_count_ref[row] - 1
    is_corr = kind_ref[s] == 1

    @pl.when(first)
    def _():
        acc_ref[...] = jnp.zeros(acc_ref.shape, acc_ref.dtype)
        cacc_ref[...] = jnp.zeros(cacc_ref.shape, cacc_ref.dtype)

    @pl.when(jnp.logical_not(is_corr))
    def _():
        mask = _unpack_bits(bitmaps_ref[0]).astype(h_ref.dtype)
        acc_ref[...] += jnp.dot(
            mask, h_ref[...], preferred_element_type=jnp.float32
        )

    @pl.when(is_corr)
    def _():
        cacc = cacc_ref[...]
        for k, w in enumerate(plane_weights):
            mask = _unpack_bits(planes_ref[0, k]).astype(x_ref.dtype)
            cacc = cacc + jnp.float32(w) * jnp.dot(
                mask, x_ref[...], preferred_element_type=jnp.float32
            )
        cacc_ref[...] = cacc

    @pl.when(last)
    def _():
        y_ref[...] = (acc_ref[...] - cacc_ref[...]).astype(y_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "n_dst_pad", "feature_block", "plane_weights", "interpret"
    ),
)
def _bitmap_spmm_fused(
    kind: jnp.ndarray,
    main_src: jnp.ndarray,
    corr_src: jnp.ndarray,
    main_idx: jnp.ndarray,
    corr_idx: jnp.ndarray,
    slot_row: jnp.ndarray,
    row_start: jnp.ndarray,
    row_count: jnp.ndarray,
    bitmaps: jnp.ndarray,
    planes: jnp.ndarray,
    h: jnp.ndarray,
    x: jnp.ndarray,
    n_dst_pad: int,
    feature_block: int,
    plane_weights: tuple,
    interpret: bool,
) -> jnp.ndarray:
    n_slots = kind.shape[0]
    f = h.shape[1]
    n_planes = planes.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=8,
        grid=(f // feature_block, n_slots),
        in_specs=[
            pl.BlockSpec(
                (1, TILE, WORDS),
                lambda j, s, kd, ms, cs, mi, ci, sr, rs, rc: (mi[s], 0, 0),
            ),
            pl.BlockSpec(
                (1, n_planes, TILE, WORDS),
                lambda j, s, kd, ms, cs, mi, ci, sr, rs, rc: (ci[s], 0, 0, 0),
            ),
            pl.BlockSpec(
                (TILE, feature_block),
                lambda j, s, kd, ms, cs, mi, ci, sr, rs, rc: (ms[s], j),
            ),
            pl.BlockSpec(
                (TILE, feature_block),
                lambda j, s, kd, ms, cs, mi, ci, sr, rs, rc: (cs[s], j),
            ),
        ],
        out_specs=pl.BlockSpec(
            (TILE, feature_block),
            lambda j, s, kd, ms, cs, mi, ci, sr, rs, rc: (sr[s], j),
        ),
        scratch_shapes=[
            pltpu.VMEM((TILE, feature_block), jnp.float32),
            pltpu.VMEM((TILE, feature_block), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_fused_kernel, plane_weights=plane_weights),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_dst_pad, f), h.dtype),
        interpret=interpret,
    )(
        kind, main_src, corr_src, main_idx, corr_idx,
        slot_row, row_start, row_count,
        bitmaps, planes, h, x,
    )


def bitmap_spmm_fused_pallas(
    kind: jnp.ndarray,       # (n_slots,) int32
    main_src: jnp.ndarray,   # (n_slots,) int32
    corr_src: jnp.ndarray,   # (n_slots,) int32
    main_idx: jnp.ndarray,   # (n_slots,) int32
    corr_idx: jnp.ndarray,   # (n_slots,) int32
    slot_row: jnp.ndarray,   # (n_slots,) int32
    row_start: jnp.ndarray,  # (n_rt,) int32
    row_count: jnp.ndarray,  # (n_rt,) int32
    bitmaps: jnp.ndarray,    # (n_main, TILE, WORDS) uint32
    planes: jnp.ndarray,     # (n_corr, P, TILE, WORDS) uint32
    h: jnp.ndarray,          # (n_h_pad, F) last-hidden frontier
    x: jnp.ndarray,          # (n_x_pad, F) original input frontier
    n_dst_pad: int,
    plane_weights: "tuple[float, ...]",
    feature_block: int = 128,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Fused last-layer SpMM with the DEDUP-C subtraction in the epilogue:
    ``y = B h − D x`` over one interleaved slot stream
    (:func:`repro.kernels.correction.build_fused_stream`), plus-times
    ring only.  ``h`` and ``x`` are the two streamed feature operands —
    the last hidden frontier and the original input — each padded to its
    own tile multiple; both must share the feature width ``F``."""
    if h.shape[1] != x.shape[1]:
        raise ValueError(
            f"h and x must share the feature axis: {h.shape} vs {x.shape}"
        )
    f = h.shape[1]
    if (
        n_dst_pad % TILE
        or f % feature_block
        or h.shape[0] % TILE
        or x.shape[0] % TILE
    ):
        raise ValueError(
            f"padded dims required: n_dst_pad={n_dst_pad}, f={f}, "
            f"h_rows={h.shape[0]}, x_rows={x.shape[0]} (TILE={TILE}, "
            f"fb={feature_block})"
        )
    if planes.shape[1] != len(plane_weights):
        raise ValueError("plane_weights must match the plane count")
    if interpret is None:
        interpret = default_interpret()
    return _bitmap_spmm_fused(
        kind, main_src, corr_src, main_idx, corr_idx,
        slot_row, row_start, row_count,
        bitmaps, planes, h, x,
        n_dst_pad=n_dst_pad,
        feature_block=feature_block,
        plane_weights=tuple(float(w) for w in plane_weights),
        interpret=bool(interpret),
    )


def bitmap_spmm_pallas(
    slot_src: jnp.ndarray,   # (n_slots,) int32
    slot_row: jnp.ndarray,   # (n_slots,) int32
    row_start: jnp.ndarray,  # (n_rt,) int32
    row_count: jnp.ndarray,  # (n_rt,) int32
    bitmaps: jnp.ndarray,    # (n_slots, TILE, WORDS) uint32
    x: jnp.ndarray,          # (n_src_pad, F); row_window/fb multiples
    n_dst_pad: int,
    feature_block: int = 128,
    op: str = "sum",
    zero: float = 0.0,
    interpret: bool | None = None,
    row_window: int = TILE,
) -> jnp.ndarray:
    """Streamed bit-packed SpMM: ``y = B ⊕ x`` over one packed incidence.

    ``op``/``zero`` come from the semiring's ``add_kind``/``zero``
    (``'sum'`` = plus-times on the MXU; ``'min'``/``'max'`` = idempotent
    masked select).  ``interpret=None`` auto-selects compiled mode on TPU
    and interpret mode elsewhere (:func:`default_interpret`).

    ``(row_window, feature_block)`` is the autotuned window configuration
    (:mod:`repro.kernels.autotune`): ``feature_block`` tiles the feature /
    batch axis (the outer grid axis walks ``F`` in ``feature_block``-wide
    tiles, so ``B ≫ 128`` frontiers stream through the same pipeline) and
    ``row_window`` is the number of source rows fetched per streamed step
    — a multiple of ``TILE``; windows wider than one tile amortize DMA
    issue over more resident rows, and the slot's bitmap addresses its
    ``TILE``-row sub-tile of the window.
    """
    if op not in ("sum", "min", "max"):
        raise ValueError(f"unknown kernel op {op!r}")
    if row_window % TILE or row_window <= 0:
        raise ValueError(f"row_window must be a positive multiple of {TILE}")
    n_src_pad, f = x.shape
    if n_dst_pad % TILE or f % feature_block or n_src_pad % row_window:
        raise ValueError(
            f"padded dims required: n_dst_pad={n_dst_pad}, f={f}, "
            f"n_src_pad={n_src_pad} (TILE={TILE}, fb={feature_block}, "
            f"row_window={row_window})"
        )
    if interpret is None:
        interpret = default_interpret()
    return _bitmap_spmm_pallas(
        slot_src,
        slot_row,
        row_start,
        row_count,
        bitmaps,
        x,
        n_dst_pad=n_dst_pad,
        feature_block=feature_block,
        op=op,
        zero=float(zero),
        interpret=bool(interpret),
        row_window=int(row_window),
    )
