"""Autotuned kernel configurations + the measured-crossover dispatch table.

Two decisions used to be hardcoded: the Pallas kernel always streamed a
fixed ``(128, feature_block)`` source window, and ``'auto'`` dispatch
trusted the VMEM footprint formula alone — which routed cells to Pallas
at a measured 35x loss (BENCH_kernels.json, PR 3 smoke cells).  Following
the Vertica lesson (arXiv:1412.5263: measurement-driven planning beats
fixed heuristics), both become measured (DESIGN.md §6):

* :func:`autotune_spmm` sweeps ``CANDIDATES`` — (row_window,
  feature_block) pairs — against a layer's real packing and returns the
  fastest :class:`KernelConfig` plus the per-candidate timings.
* :func:`measure_crossover` races the winning Pallas configuration
  against the XLA segment path per (op, n_src-bucket, B-bucket) cell and
  records the result in a :class:`CrossoverTable` — a small frozen table
  carried by the pack (``PackedLayer.crossover`` /
  ``engine.PackedOperands.crossover``) and consulted by
  ``ops.resolve_backend`` / ``engine._kernel_applicable``, so ``'auto'``
  never again selects a backend the recording says is slower.

Buckets are power-of-two (``bit_length``) so a handful of measured cells
covers the whole size axis; lookups fall back to the nearest measured
bucket (deterministically) and, with no table at all, to the footprint
formula — packs that skip measurement behave exactly as before.

Everything here is host-side numpy/stdlib except the measurement
functions, which import the kernel wrappers lazily (this module is
imported by ``ops`` for the table types).
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Callable, Dict, Optional, Sequence, Tuple

from .pack import TILE, fits_vmem

__all__ = [
    "KernelConfig",
    "DEFAULT_CONFIG",
    "CANDIDATES",
    "CrossoverEntry",
    "CrossoverTable",
    "src_bucket",
    "batch_bucket",
    "autotune_spmm",
    "measure_crossover",
]


@dataclasses.dataclass(frozen=True)
class KernelConfig:
    """One point of the autotune sweep: the streamed-window geometry.

    ``row_window`` — source rows fetched per streamed step (multiple of
    ``TILE``; wider windows amortize DMA issue over more resident rows).
    ``feature_block`` — width of one feature/batch tile (the outer grid
    axis walks the feature axis in these, so ``B ≫ 128`` frontiers
    stream through the same pipeline as a single tile).
    """

    row_window: int = TILE
    feature_block: int = 128

    def __post_init__(self) -> None:
        if self.row_window <= 0 or self.row_window % TILE:
            raise ValueError(
                f"row_window must be a positive multiple of {TILE}, "
                f"got {self.row_window}"
            )
        # feature_block only needs to tile the (padded) feature axis; the
        # legacy API allowed sub-TILE blocks, keep that working
        if self.feature_block <= 0:
            raise ValueError(
                f"feature_block must be positive, got {self.feature_block}"
            )


DEFAULT_CONFIG = KernelConfig()

# The sweep space.  Small on purpose: each candidate must be pinned by an
# exact-parity test (tests/test_kernels_autotune.py) before dispatch may
# select it, and the footprint formula must admit it at f32.
CANDIDATES: Tuple[KernelConfig, ...] = (
    KernelConfig(row_window=128, feature_block=128),
    KernelConfig(row_window=128, feature_block=256),
    KernelConfig(row_window=256, feature_block=128),
    KernelConfig(row_window=256, feature_block=256),
    KernelConfig(row_window=512, feature_block=128),
)


def src_bucket(n_src: int) -> int:
    """Power-of-two bucket of a source count: ``ceil(log2(n_src))``."""
    return max(int(n_src) - 1, 0).bit_length()


def batch_bucket(n_features: int) -> int:
    """Power-of-two bucket of a feature/batch width."""
    return max(int(n_features) - 1, 0).bit_length()


@dataclasses.dataclass(frozen=True)
class CrossoverEntry:
    """One measured cell: both backends' times and the winning config."""

    pallas_us: float
    xla_us: float
    row_window: int = TILE
    feature_block: int = 128

    @property
    def backend(self) -> str:
        return "pallas" if self.pallas_us <= self.xla_us else "xla"

    @property
    def config(self) -> KernelConfig:
        return KernelConfig(self.row_window, self.feature_block)


# (op, src_bucket, batch_bucket) — op is the semiring add_kind
Key = Tuple[str, int, int]


@dataclasses.dataclass(frozen=True)
class CrossoverTable:
    """Measured crossover decisions, frozen and hashable.

    Hashability matters: the table rides in ``PackedOperands`` /
    ``DevicePacked`` *meta* fields, which participate in jit static
    hashing — so entries are a sorted tuple of (key, entry) pairs, not a
    dict.  Use :meth:`from_entries` to build one.
    """

    entries: Tuple[Tuple[Key, CrossoverEntry], ...] = ()

    @classmethod
    def from_entries(cls, entries: Dict[Key, CrossoverEntry]) -> "CrossoverTable":
        return cls(entries=tuple(sorted(entries.items())))

    def __len__(self) -> int:
        return len(self.entries)

    def lookup(
        self, op: str, n_src: int, n_features: int
    ) -> Optional[CrossoverEntry]:
        """The entry for (op, n_src, B) — exact bucket, else the nearest
        measured bucket for the same op (deterministic: minimal bucket
        distance, ties broken by the sorted key order), else None."""
        if not self.entries:
            return None
        sb, bb = src_bucket(n_src), batch_bucket(n_features)
        best: Optional[Tuple[Tuple[int, int, int], CrossoverEntry]] = None
        for (eop, esb, ebb), entry in self.entries:
            if eop != op:
                continue
            rank = (abs(esb - sb) + abs(ebb - bb), esb, ebb)
            if best is None or rank < best[0]:
                best = (rank, entry)
        return None if best is None else best[1]

    def decide(self, op: str, n_src: int, n_features: int) -> Optional[str]:
        """'pallas' / 'xla' per the measurement, or None when unmeasured."""
        entry = self.lookup(op, n_src, n_features)
        return None if entry is None else entry.backend

    def config_for(
        self, op: str, n_src: int, n_features: int
    ) -> KernelConfig:
        """The measured-fastest kernel config for this cell (the default
        config when the op is unmeasured)."""
        entry = self.lookup(op, n_src, n_features)
        return DEFAULT_CONFIG if entry is None else entry.config

    # -- persistence (golden-tested: tests/test_crossover_golden.py) ----

    def to_json(self) -> str:
        cells = [
            {
                "op": op,
                "src_bucket": sb,
                "batch_bucket": bb,
                "pallas_us": e.pallas_us,
                "xla_us": e.xla_us,
                "row_window": e.row_window,
                "feature_block": e.feature_block,
            }
            for (op, sb, bb), e in self.entries
        ]
        return json.dumps({"version": 1, "cells": cells}, indent=1, sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "CrossoverTable":
        doc = json.loads(text)
        if doc.get("version") != 1:
            raise ValueError(f"unknown crossover table version {doc.get('version')!r}")
        entries: Dict[Key, CrossoverEntry] = {}
        for c in doc["cells"]:
            key = (str(c["op"]), int(c["src_bucket"]), int(c["batch_bucket"]))
            entries[key] = CrossoverEntry(
                pallas_us=float(c["pallas_us"]),
                xla_us=float(c["xla_us"]),
                row_window=int(c["row_window"]),
                feature_block=int(c["feature_block"]),
            )
        return cls.from_entries(entries)


# -- measurement ------------------------------------------------------------

TimeFn = Callable[[Callable[[], object]], float]


def _op_semiring(op: str):
    """Representative semiring for a kernel op (add_kind)."""
    from ..core.semiring import MAX_TIMES, MIN_PLUS, PLUS_TIMES

    try:
        return {"sum": PLUS_TIMES, "min": MIN_PLUS, "max": MAX_TIMES}[op]
    except KeyError:
        raise ValueError(f"unknown kernel op {op!r}") from None


def _wall_time(fn: Callable[[], object], repeats: int = 3) -> float:
    """Best-of-N wall time in seconds, after one warmup (compile) call."""
    fn()
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _viable(
    config: KernelConfig, n_features: int, itemsize: int, n_slots: int
) -> bool:
    return fits_vmem(
        n_features,
        config.feature_block,
        itemsize,
        n_slots=n_slots,
        row_window=config.row_window,
    )


def autotune_spmm(
    layer,
    n_features: int,
    op: str = "sum",
    candidates: Sequence[KernelConfig] = CANDIDATES,
    reverse: bool = False,
    interpret: Optional[bool] = None,
    time_fn: Optional[TimeFn] = None,
) -> Tuple[KernelConfig, Dict[KernelConfig, float]]:
    """Sweep ``candidates`` on a real packed layer; return (best, timings).

    Candidates whose working set exceeds the VMEM/SMEM budget are skipped
    (never timed, never selectable).  ``time_fn`` is injectable so tests
    can force deterministic 'measurements' without racing real kernels.
    """
    import jax.numpy as jnp

    from . import ops as _ops

    semiring = _op_semiring(op)
    bsb = layer.bsb_rev if reverse else layer.bsb
    if bsb is None:
        raise ValueError("autotune_spmm needs a packed direction")
    timer = time_fn or _wall_time
    x = jnp.ones((bsb.n_src, max(n_features, 1)), jnp.float32)
    timings: Dict[KernelConfig, float] = {}
    for cfg in candidates:
        if not _viable(cfg, n_features, x.dtype.itemsize, bsb.n_slots):
            continue

        def run(cfg=cfg):
            _ops.bitmap_spmm(
                layer,
                x,
                backend="pallas",
                feature_block=cfg.feature_block,
                interpret=interpret,
                semiring=semiring,
                reverse=reverse,
                config=cfg,
            ).block_until_ready()

        timings[cfg] = timer(run)
    if not timings:
        return DEFAULT_CONFIG, timings
    best = min(timings.items(), key=lambda kv: (kv[1], kv[0].row_window, kv[0].feature_block))
    return best[0], timings


def measure_crossover(
    layer,
    ops: Sequence[str] = ("sum",),
    batch_sizes: Sequence[int] = (128,),
    candidates: Sequence[KernelConfig] = CANDIDATES,
    interpret: Optional[bool] = None,
    time_fn: Optional[TimeFn] = None,
) -> CrossoverTable:
    """Race Pallas (autotuned per cell) against the XLA segment path and
    record the winners.  Called at pack time when measurement is requested
    (``PackedLayer.from_edges(..., measure=True)`` /
    ``engine.to_device_packed(..., measure_crossover=True)``)."""
    import jax.numpy as jnp

    from . import ops as _ops

    timer = time_fn or _wall_time
    entries: Dict[Key, CrossoverEntry] = {}
    for op in ops:
        semiring = _op_semiring(op)
        for b in batch_sizes:
            best_cfg, timings = autotune_spmm(
                layer,
                b,
                op=op,
                candidates=candidates,
                interpret=interpret,
                time_fn=time_fn,
            )
            x = jnp.ones((layer.n_src, b), jnp.float32)

            def run_xla():
                _ops.bitmap_spmm(
                    layer, x, backend="xla", semiring=semiring
                ).block_until_ready()

            t_xla = timer(run_xla)
            t_pallas = timings.get(best_cfg, float("inf"))
            key = (op, src_bucket(layer.n_src), batch_bucket(b))
            entries[key] = CrossoverEntry(
                pallas_us=t_pallas * 1e6,
                xla_us=t_xla * 1e6,
                row_window=best_cfg.row_window,
                feature_block=best_cfg.feature_block,
            )
    return CrossoverTable.from_entries(entries)
