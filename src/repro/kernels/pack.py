"""Host-side packing: BipartiteEdges -> bit-packed block-sparse incidence.

The paper's BITMAP idea (per-virtual-node bitmaps consulted during
traversal) reborn TPU-native: the 0/1 incidence matrix of a condensed
layer is tiled into 128x128 blocks; only nonzero blocks are stored, each
as a 128x4 uint32 bitmap (2 KiB instead of 64 KiB f32).  The Pallas kernel
unpacks a block's bits in VMEM and feeds the MXU with a dense 128x128
operand — bandwidth-compressed SpMM (see DESIGN.md §6).

Layout (block-ELL):
    blocks  : (n_row_tiles, max_k) int32   — source-tile index per slot
    bitmaps : (n_row_tiles, max_k, TILE, TILE//32) uint32
    nnz slots are left-justified; padding slots have block id 0 and
    all-zero bitmaps (mathematically inert).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

from ..core.condensed import BipartiteEdges

TILE = 128
WORDS = TILE // 32

# VMEM budget for the kernel's resident source column (bytes); practical
# budget 8 MiB.  Lives here (numpy-only module) so both auto-dispatchers
# (kernels.ops.bitmap_spmm and core.engine) share it without the engine
# importing the Pallas stack.
_VMEM_COLUMN_BUDGET = 8 * 2**20


def fits_vmem_column(
    n_src_pad: int, n_features: int, feature_block: int, itemsize: int
) -> bool:
    """Whether the kernel's resident source column fits the VMEM budget —
    the one fits formula both auto-dispatchers must agree on."""
    f_pad = -(-n_features // feature_block) * feature_block
    return n_src_pad * f_pad * itemsize <= _VMEM_COLUMN_BUDGET

__all__ = ["BlockSparseBitmap", "pack_bipartite", "TILE", "WORDS"]


@dataclasses.dataclass
class BlockSparseBitmap:
    """Destination-major packed incidence: rows = dst, cols = src."""

    blocks: np.ndarray     # (n_row_tiles, max_k) int32
    bitmaps: np.ndarray    # (n_row_tiles, max_k, TILE, WORDS) uint32
    n_dst: int             # logical rows
    n_src: int             # logical cols

    @property
    def n_row_tiles(self) -> int:
        return int(self.blocks.shape[0])

    @property
    def max_k(self) -> int:
        return int(self.blocks.shape[1])

    @property
    def n_src_tiles(self) -> int:
        return -(-self.n_src // TILE)

    @property
    def n_nonzero_blocks(self) -> int:
        return int((self.bitmaps.any(axis=(2, 3))).sum())

    def nbytes(self) -> int:
        return int(self.blocks.nbytes + self.bitmaps.nbytes)

    def to_dense(self) -> np.ndarray:
        """Oracle helper: dense (n_dst_pad, n_src_pad) 0/1 matrix."""
        n_rt, mk = self.blocks.shape
        dense = np.zeros((n_rt * TILE, self.n_src_tiles * TILE), dtype=np.float32)
        shifts = np.arange(32, dtype=np.uint32)
        for i in range(n_rt):
            for k in range(mk):
                w = self.bitmaps[i, k]
                if not w.any():
                    continue
                bits = ((w[:, :, None] >> shifts) & 1).reshape(TILE, TILE)
                b = int(self.blocks[i, k])
                dense[i * TILE : (i + 1) * TILE, b * TILE : (b + 1) * TILE] += bits
        return dense


def pack_bipartite(edges: BipartiteEdges) -> BlockSparseBitmap:
    """Pack dst-major: y[dst] += x[src]  ==  y = B @ x with B[dst, src]=1.

    Duplicate (src, dst) pairs are rejected — a bitmap holds one bit per
    cell (condensed incidence layers are duplicate-free by construction;
    multiplicity lives across *paths*, not within a layer).
    """
    src = edges.src
    dst = edges.dst
    key = dst.astype(np.int64) * edges.n_src + src
    if np.unique(key).size != key.size:
        raise ValueError("pack_bipartite requires duplicate-free edges")

    n_rt = -(-edges.n_dst // TILE)
    bd = dst // TILE
    bs = src // TILE
    # unique (row_tile, src_tile) blocks
    bkey = bd.astype(np.int64) * (edges.n_src // TILE + 1) + bs
    uniq, inv = np.unique(bkey, return_inverse=True)
    ub_rows = (uniq // (edges.n_src // TILE + 1)).astype(np.int64)
    ub_cols = (uniq % (edges.n_src // TILE + 1)).astype(np.int64)
    # slot within row tile: rank of block among its row's blocks
    counts = np.bincount(ub_rows, minlength=n_rt)
    max_k = max(int(counts.max()) if counts.size else 0, 1)
    slot_of_block = np.zeros(uniq.size, dtype=np.int64)
    # uniq sorted => blocks grouped by row already
    row_starts = np.searchsorted(ub_rows, np.arange(n_rt))
    slot_of_block = np.arange(uniq.size) - row_starts[ub_rows]

    blocks = np.zeros((n_rt, max_k), dtype=np.int32)
    blocks[ub_rows, slot_of_block] = ub_cols.astype(np.int32)
    bitmaps = np.zeros((n_rt, max_k, TILE, WORDS), dtype=np.uint32)
    r = (dst % TILE).astype(np.int64)
    c = (src % TILE).astype(np.int64)
    word = c // 32
    bit = (c % 32).astype(np.uint32)
    np.bitwise_or.at(
        bitmaps,
        (ub_rows[inv], slot_of_block[inv], r, word),
        (np.uint32(1) << bit),
    )
    return BlockSparseBitmap(
        blocks=blocks, bitmaps=bitmaps, n_dst=edges.n_dst, n_src=edges.n_src
    )
