"""Host-side packing: BipartiteEdges -> bit-packed block-sparse incidence.

The paper's BITMAP idea (per-virtual-node bitmaps consulted during
traversal) reborn TPU-native: the 0/1 incidence matrix of a condensed
layer is tiled into 128x128 blocks; only nonzero blocks are stored, each
as a 128x4 uint32 bitmap (2 KiB instead of 64 KiB f32).  The Pallas kernel
unpacks a block's bits in VMEM and feeds the MXU with a dense 128x128
operand — bandwidth-compressed SpMM (see DESIGN.md §6).

Layout (streamed slot list + run table):
    slot_src  : (n_slots,) int32  — source-tile index per nonzero block
    slot_row  : (n_slots,) int32  — dst row-tile index per nonzero block
    bitmaps   : (n_slots, TILE, TILE//32) uint32
    row_start : (n_row_tiles,) int32 — first slot of each row tile
    row_count : (n_row_tiles,) int32 — slots in each row tile

Slots are sorted by (row tile, source tile), so the kernel's inner grid
axis walks each row tile's source blocks as one contiguous, monotonically
increasing run — the access pattern the Pallas pipeline double-buffers
(DESIGN.md §6).  Every row tile owns at least one slot (empty rows get a
single all-zero pad bitmap, mathematically inert) so each output tile is
visited and written exactly once per feature tile.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from ..core.condensed import BipartiteEdges

TILE = 128
WORDS = TILE // 32

# Per-grid-cell VMEM working-set budget (bytes).  Lives here (numpy-only
# module) so both auto-dispatchers (kernels.ops.bitmap_spmm and
# core.engine._kernel_applicable) share one formula without the engine
# importing the Pallas stack.
_VMEM_BUDGET = 8 * 2**20

# Scalar-prefetch budget (bytes): the slot/run tables land in SMEM,
# which is far smaller than VMEM.  Conservative cap; graphs with more
# nonzero blocks than this fall back to the segment path instead of
# failing inside Mosaic.
_SMEM_BUDGET = 256 * 2**10

# Pipeline depth: Pallas double-buffers each streamed input block (fetch
# tile t+1 while the MXU consumes tile t).
_STREAM_WINDOW = 2

# Column chunk width of the kernel's min/max masked-select reduction
# (bitmap_spmm imports it from here): sizes the (TILE, CHUNK, Fb) select
# intermediate that the footprint formula must account for.
STREAM_CHUNK = 8

# Bit-field widths of pack_bipartite's combined sort key; derived from
# the tile constants so the layout can't silently drift from them.
_R_BITS = TILE.bit_length() - 1          # row-in-tile
_W_BITS = WORDS.bit_length() - 1         # word-in-row
_B_BITS = 5                              # bit-in-word (uint32)

__all__ = [
    "BlockSparseBitmap",
    "pack_bipartite",
    "merge_block_sparse",
    "streamed_footprint_bytes",
    "fits_vmem",
    "fused_fits_vmem",
    "measure_pack_throughput",
    "TILE",
    "WORDS",
]


def streamed_footprint_bytes(
    n_features: int, feature_block: int, itemsize: int, row_window: int = TILE
) -> int:
    """Per-grid-cell VMEM working set of the streamed kernel, in bytes.

    The source column is *streamed* through a double-buffered window of
    one (row_window, feature_block) tile, so — unlike the old
    resident-column formula — the footprint is independent of ``n_src``:
    window (x2 buffers) + bitmap slot (x2) + output tile (x2) + f32
    accumulator.  ``n_features`` is accepted (both dispatchers know it)
    but intentionally unused: streaming removed the source-count *and*
    feature-count terms — only the window dimensions matter.
    ``row_window`` is the autotune axis (DESIGN.md §6): source rows
    fetched per streamed step, a multiple of ``TILE``.
    """
    del n_features  # the streamed window is one feature_block tile wide
    x_tile = row_window * feature_block * itemsize
    bitmap_slot = TILE * WORDS * 4
    out_tile = TILE * feature_block * itemsize
    acc = TILE * feature_block * 4
    # kernel-body intermediates, whichever op variant is larger: the
    # unpacked dense 0/1 mask (sum) vs the (TILE, CHUNK, Fb) f32 select
    # of the min/max path — without these the formula re-grows a cliff
    # at wide feature blocks; a >TILE row window also materializes one
    # (TILE, Fb) sub-tile slice of the fetched window
    body = max(TILE * TILE * 4, TILE * STREAM_CHUNK * feature_block * 4)
    if row_window > TILE:
        body += TILE * feature_block * itemsize
    return _STREAM_WINDOW * (x_tile + bitmap_slot + out_tile) + acc + body


def fits_vmem(
    n_features: int,
    feature_block: int,
    itemsize: int,
    n_slots: Optional[int] = None,
    row_window: int = TILE,
) -> bool:
    """Whether the streamed kernel's working set fits the VMEM budget —
    the one fits formula both auto-dispatchers must agree on.  With the
    source column streamed this no longer depends on the source count, so
    graphs far above the old 8 MiB resident-column cliff still dispatch
    to the kernel.  ``n_slots`` (when the caller knows it) guards the one
    remaining size-dependent operand: the scalar-prefetched slot/run
    tables, which live in SMEM — four int32 tables bounded by ``n_slots``
    entries each.  ``row_window`` sizes the streamed source window of the
    candidate kernel configuration (autotune sweep, DESIGN.md §6).
    """
    if n_slots is not None and 4 * n_slots * 4 > _SMEM_BUDGET:
        return False
    return (
        streamed_footprint_bytes(
            n_features, feature_block, itemsize, row_window=row_window
        )
        <= _VMEM_BUDGET
    )


def fused_fits_vmem(
    n_features: int,
    feature_block: int,
    itemsize: int,
    n_planes: int,
    n_slots: Optional[int] = None,
) -> bool:
    """VMEM/SMEM admission for the fused DEDUP-C-epilogue kernel.

    On top of the plain streamed footprint it double-buffers a *second*
    feature operand (the original input frontier next to the hidden one)
    and the ``n_planes``-deep correction bitmap stack, and holds a second
    f32 accumulator; its slot stream carries eight scalar tables instead
    of four.
    """
    if n_slots is not None and 8 * n_slots * 4 > _SMEM_BUDGET:
        return False
    base = streamed_footprint_bytes(n_features, feature_block, itemsize)
    extra = _STREAM_WINDOW * (
        TILE * feature_block * itemsize + n_planes * TILE * WORDS * 4
    )
    extra += TILE * feature_block * 4  # second accumulator
    return base + extra <= _VMEM_BUDGET


@dataclasses.dataclass
class BlockSparseBitmap:
    """Destination-major packed incidence: rows = dst, cols = src."""

    slot_src: np.ndarray   # (n_slots,) int32
    slot_row: np.ndarray   # (n_slots,) int32
    bitmaps: np.ndarray    # (n_slots, TILE, WORDS) uint32
    row_start: np.ndarray  # (n_row_tiles,) int32
    row_count: np.ndarray  # (n_row_tiles,) int32
    n_dst: int             # logical rows
    n_src: int             # logical cols

    @property
    def n_slots(self) -> int:
        return int(self.slot_src.shape[0])

    @property
    def n_row_tiles(self) -> int:
        return int(self.row_start.shape[0])

    @property
    def max_k(self) -> int:
        return int(self.row_count.max()) if self.row_count.size else 0

    @property
    def n_src_tiles(self) -> int:
        # min 1, matching pack_bipartite's n_st: pad slots index source
        # tile 0, so a zero-source layer must still pad x to one (inert,
        # all-zero) tile instead of handing the kernel a 0-row operand
        return max(-(-self.n_src // TILE), 1)

    @property
    def n_nonzero_blocks(self) -> int:
        return int((self.bitmaps.any(axis=(1, 2))).sum())

    def nbytes(self) -> int:
        return int(
            self.slot_src.nbytes
            + self.slot_row.nbytes
            + self.bitmaps.nbytes
            + self.row_start.nbytes
            + self.row_count.nbytes
        )

    def to_dense(self) -> np.ndarray:
        """Oracle helper: dense (n_dst_pad, n_src_pad) 0/1 matrix."""
        dense = np.zeros(
            (self.n_row_tiles * TILE, self.n_src_tiles * TILE), dtype=np.float32
        )
        shifts = np.arange(32, dtype=np.uint32)
        for s in range(self.n_slots):
            w = self.bitmaps[s]
            if not w.any():
                continue
            bits = ((w[:, :, None] >> shifts) & 1).reshape(TILE, TILE)
            i = int(self.slot_row[s])
            b = int(self.slot_src[s])
            dense[i * TILE : (i + 1) * TILE, b * TILE : (b + 1) * TILE] += bits
        return dense


def _slot_layout(ub_rows: np.ndarray, ub_cols: np.ndarray, n_rt: int):
    """Canonical slot-stream layout from sorted unique (row, src) blocks:
    per row tile, real slots in ascending source order, one all-zero pad
    slot for each empty row tile.  Shared by :func:`pack_bipartite` and
    :func:`merge_block_sparse` so a merged pack is byte-identical to a
    one-shot pack."""
    counts = np.bincount(ub_rows, minlength=n_rt)
    empty = np.flatnonzero(counts == 0)
    all_rows = np.concatenate([ub_rows, empty])
    all_cols = np.concatenate([ub_cols, np.zeros(empty.size, dtype=np.int64)])
    order = np.argsort(all_rows, kind="stable")
    slot_row = all_rows[order].astype(np.int32)
    slot_src = all_cols[order].astype(np.int32)
    n_slots = slot_row.size
    slot_of = np.empty(n_slots, dtype=np.int64)
    slot_of[order] = np.arange(n_slots)
    row_count = np.bincount(slot_row, minlength=n_rt).astype(np.int32)
    row_start = np.concatenate(
        [[0], np.cumsum(row_count[:-1])]
    ).astype(np.int32)
    return slot_row, slot_src, row_start, row_count, slot_of, n_slots


def _popcount(bitmaps: np.ndarray) -> int:
    """Total set bits across a bitmap stack (the packed edge count)."""
    fn = getattr(np, "bitwise_count", None)
    if fn is not None:
        return int(fn(bitmaps).sum())
    return int(np.unpackbits(bitmaps.view(np.uint8)).sum())


def merge_block_sparse(parts: "list[BlockSparseBitmap]") -> BlockSparseBitmap:
    """Merge per-shard packed incidences into one (DESIGN.md §7).

    Every part must pack a disjoint edge subset of the *same* logical
    matrix (equal ``n_dst``/``n_src``).  Slots sharing a (row tile, src
    tile) block are OR-folded; pad slots are dropped and re-derived; the
    canonical slot ordering is rebuilt — so the result is byte-identical
    to packing all edges at once, which is what lets sharded extraction
    build ``DevicePackedLayer`` operands shard-at-a-time without ever
    sorting the full edge list in one shot.  Overlapping edges (the same
    (src, dst) cell set in two parts) are rejected, matching
    :func:`pack_bipartite`'s duplicate check.
    """
    if not parts:
        raise ValueError("merge_block_sparse needs at least one part")
    n_dst, n_src = parts[0].n_dst, parts[0].n_src
    for p in parts:
        if p.n_dst != n_dst or p.n_src != n_src:
            raise ValueError("parts disagree on logical matrix shape")
    n_rt = max(-(-n_dst // TILE), 1)
    n_st = max(-(-n_src // TILE), 1)
    rows, cols, maps = [], [], []
    total_bits = 0
    for p in parts:
        live = p.bitmaps.any(axis=(1, 2))  # drop pad slots
        live_maps = p.bitmaps[live]
        rows.append(p.slot_row[live].astype(np.int64))
        cols.append(p.slot_src[live].astype(np.int64))
        maps.append(live_maps)
        total_bits += _popcount(live_maps)
    rows_c = np.concatenate(rows) if rows else np.empty(0, np.int64)
    cols_c = np.concatenate(cols) if cols else np.empty(0, np.int64)
    maps_c = (
        np.concatenate(maps)
        if maps and sum(m.shape[0] for m in maps)
        else np.zeros((0, TILE, WORDS), dtype=np.uint32)
    )
    key = rows_c * n_st + cols_c
    order = np.argsort(key, kind="stable")
    key_s = key[order]
    starts = (
        np.flatnonzero(np.r_[True, key_s[1:] != key_s[:-1]])
        if key_s.size
        else np.empty(0, dtype=np.int64)
    )
    uniq = key_s[starts] if key_s.size else np.empty(0, dtype=np.int64)
    flat = maps_c[order].reshape(-1, TILE * WORDS)
    merged = (
        np.bitwise_or.reduceat(flat, starts, axis=0)
        if starts.size
        else np.zeros((0, TILE * WORDS), dtype=np.uint32)
    )
    if _popcount(merged) != total_bits:
        raise ValueError(
            "merge_block_sparse requires disjoint edge shards "
            "(a (src, dst) cell is set in more than one part)"
        )
    slot_row, slot_src, row_start, row_count, slot_of, n_slots = _slot_layout(
        uniq // n_st, uniq % n_st, n_rt
    )
    full = np.concatenate(
        [merged, np.zeros((n_slots - uniq.size, TILE * WORDS), dtype=np.uint32)]
    )
    # slot i holds the block that _slot_layout placed at position i:
    # candidate j (real blocks first, pads after) lands at slot slot_of[j]
    bitmaps = np.empty((n_slots, TILE * WORDS), dtype=np.uint32)
    bitmaps[slot_of] = full
    return BlockSparseBitmap(
        slot_src=slot_src,
        slot_row=slot_row,
        bitmaps=bitmaps.reshape(n_slots, TILE, WORDS),
        row_start=row_start,
        row_count=row_count,
        n_dst=n_dst,
        n_src=n_src,
    )


def pack_bipartite(
    edges: BipartiteEdges,
    method: str = "reduceat",
    shard_edges: Optional[int] = None,
) -> BlockSparseBitmap:
    """Pack dst-major: y[dst] += x[src]  ==  y = B @ x with B[dst, src]=1.

    Duplicate (src, dst) pairs are rejected — a bitmap holds one bit per
    cell (condensed incidence layers are duplicate-free by construction;
    multiplicity lives across *paths*, not within a layer).

    ``method`` selects the fold strategy: ``'reduceat'`` (default) sorts
    edges once by a combined (block, row, word, bit) key — that single
    sort yields the duplicate check, the block grouping, *and* the word
    runs, folded with one buffered ``np.bitwise_or.reduceat`` pass;
    ``'scatter'`` is the original algorithm (two ``np.unique`` sorts plus
    an unbuffered ``np.bitwise_or.at`` scatter), kept as the before/after
    baseline for ``benchmarks/bench_kernels.py``.

    ``shard_edges`` bounds the edges packed in one shot (DESIGN.md §7):
    larger edge lists are packed slice by slice and OR-merged
    *incrementally* with :func:`merge_block_sparse` — byte-identical
    output, with resident packing state bounded by the accumulated packed
    form plus one slice's pack (never all slices at once, whose per-slice
    pad slots would otherwise dwarf the final structure on tall
    matrices).
    """
    if method not in ("reduceat", "scatter"):
        raise ValueError(f"unknown pack method {method!r}")
    if shard_edges is not None and edges.n_edges > shard_edges:
        width = max(int(shard_edges), 1)
        acc: Optional[BlockSparseBitmap] = None
        for lo in range(0, edges.n_edges, width):
            part = pack_bipartite(
                BipartiteEdges(
                    edges.src[lo : lo + width],
                    edges.dst[lo : lo + width],
                    edges.n_src,
                    edges.n_dst,
                ),
                method=method,
            )
            acc = part if acc is None else merge_block_sparse([acc, part])
        assert acc is not None
        return acc
    src = edges.src
    dst = edges.dst
    n_rt = max(-(-edges.n_dst // TILE), 1)
    n_st = max(-(-edges.n_src // TILE), 1)
    bd = dst // TILE
    bs = src // TILE
    r = (dst % TILE).astype(np.int64)
    c = (src % TILE).astype(np.int64)
    word = c // 32
    bit = (c % 32).astype(np.uint32)
    bkey = bd.astype(np.int64) * n_st + bs

    if method == "scatter":
        key = dst.astype(np.int64) * edges.n_src + src
        if np.unique(key).size != key.size:
            raise ValueError("pack_bipartite requires duplicate-free edges")
        uniq, inv = np.unique(bkey, return_inverse=True)
    else:
        # one sort does everything: the full key is unique per (src, dst)
        # cell (duplicate check), its high bits group blocks row-major
        # with source tiles ascending (the kernel's streaming order), and
        # its (row, word) middle bits delimit the bitmap-word runs.  All
        # field widths are powers of two, so packing/unpacking is pure
        # shift/mask — the residual cost after the scatter is gone.
        low = _R_BITS + _W_BITS + _B_BITS
        full = (
            (bkey << low)
            | (r << (_W_BITS + _B_BITS))
            | (word << _B_BITS)
            | bit
        )
        order_e = np.argsort(full, kind="stable")
        full_s = full[order_e]
        if full_s.size and np.any(full_s[1:] == full_s[:-1]):
            raise ValueError("pack_bipartite requires duplicate-free edges")
        bkey_s = full_s >> low
        block_bounds = np.flatnonzero(
            np.r_[True, bkey_s[1:] != bkey_s[:-1]]
        ) if bkey_s.size else np.empty(0, dtype=np.int64)
        uniq = bkey_s[block_bounds] if bkey_s.size else np.empty(0, np.int64)

    # pad every empty row tile with one all-zero slot so each output tile
    # is visited (and therefore written) by the kernel
    slot_row, slot_src, row_start, row_count, slot_of, n_slots = _slot_layout(
        uniq // n_st, uniq % n_st, n_rt
    )

    flat = np.zeros(n_slots * TILE * WORDS, dtype=np.uint32)
    if src.size:
        if method == "scatter":
            lin = (slot_of[inv] * TILE + r) * WORDS + word
            np.bitwise_or.at(flat, lin, np.uint32(1) << bit)
        else:
            # slot_of is monotone over sorted blocks (pads append after
            # each row's real slots), so the sorted edge order is also
            # sorted by (slot, row, word): reduceat folds each word run
            block_of_edge = np.repeat(
                slot_of[: uniq.size],
                np.diff(np.r_[block_bounds, full_s.size]),
            )
            rw_s = (full_s >> _B_BITS) & (TILE * WORDS - 1)
            lin_s = (block_of_edge << (_R_BITS + _W_BITS)) | rw_s
            starts = np.flatnonzero(np.r_[True, lin_s[1:] != lin_s[:-1]])
            vals_s = np.uint32(1) << bit[order_e]
            flat[lin_s[starts]] = np.bitwise_or.reduceat(vals_s, starts)
    bitmaps = flat.reshape(n_slots, TILE, WORDS)
    return BlockSparseBitmap(
        slot_src=slot_src,
        slot_row=slot_row,
        bitmaps=bitmaps,
        row_start=row_start,
        row_count=row_count,
        n_dst=edges.n_dst,
        n_src=edges.n_src,
    )


def measure_pack_throughput(
    edges: BipartiteEdges,
    methods: "tuple[str, ...]" = ("reduceat", "scatter"),
    repeats: int = 3,
    time_fn=None,
) -> "dict[str, float]":
    """Measured edges/second of ``pack_bipartite`` per fold method.

    Feeds the extraction cost model (``repro.core.cost.Throughputs``) the
    same way ``measure_crossover`` feeds kernel dispatch: a small measured
    table that overrides the analytic default.  ``time_fn`` is injectable
    for deterministic tests (same contract as ``autotune.measure_crossover``:
    it receives a zero-arg callable and returns elapsed seconds).
    """
    import time as _time

    out: "dict[str, float]" = {}
    for method in methods:
        if time_fn is not None:
            elapsed = float(time_fn(lambda: pack_bipartite(edges, method=method)))
        else:
            elapsed = float("inf")
            for _ in range(max(1, repeats)):
                t0 = _time.perf_counter()
                pack_bipartite(edges, method=method)
                elapsed = min(elapsed, _time.perf_counter() - t0)
        out[method] = edges.n_edges / max(elapsed, 1e-9)
    return out
