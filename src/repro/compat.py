"""Shims over JAX API drift so one codebase runs on the pinned jax.

``shard_map`` is top-level only in newer JAX; on 0.4.x it lives in
``jax.experimental.shard_map``.  ``jax.lax.pvary`` marks a value as
varying over manual axes — 0.4.x ``shard_map`` does not track varying
axes at all, so the identity is the correct stand-in there.
"""
from __future__ import annotations

import jax

__all__ = ["shard_map", "pvary"]

try:
    shard_map = jax.shard_map
except AttributeError:
    from jax.experimental.shard_map import shard_map

try:
    pvary = jax.lax.pvary
except AttributeError:
    def pvary(x, axis_name):
        return x
