"""Optimizers from scratch (no optax in this environment).

API mirrors the usual GradientTransformation: ``init(params) -> state``,
``update(grads, state, params) -> (updates, state)``; apply with
``apply_updates``.  Features needed at scale:

* AdamW with configurable moment dtype (``bf16`` halves optimizer HBM for
  405B-class models — see llama3-405b config);
* Adafactor (factored second moment: rows+cols instead of full tensors);
* global-norm clipping, weight decay masks, LR schedules.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "Optimizer",
    "adamw",
    "sgdm",
    "adafactor",
    "clip_by_global_norm",
    "apply_updates",
    "cosine_schedule",
    "linear_warmup",
]


class Optimizer(NamedTuple):
    init: Callable
    update: Callable  # (grads, state, params, step) -> (updates, state)


def _dt(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[name]


def cosine_schedule(base_lr: float, warmup: int, total: int, floor: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / jnp.maximum(warmup, 1)
        frac = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)

    return lr


def linear_warmup(base_lr: float, warmup: int):
    return lambda step: base_lr * jnp.minimum(
        jnp.asarray(step, jnp.float32) / jnp.maximum(warmup, 1), 1.0
    )


def global_norm(tree) -> jnp.ndarray:
    sq = jax.tree_util.tree_map(
        lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), tree
    )
    return jnp.sqrt(sum(jax.tree_util.tree_leaves(sq)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale.astype(g.dtype), tree), norm


def apply_updates(params, updates):
    return jax.tree_util.tree_map(
        lambda p, u: (p.astype(jnp.float32) + u.astype(jnp.float32)).astype(p.dtype),
        params,
        updates,
    )


def adamw(
    lr: Callable | float,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    moment_dtype: str = "float32",
    decay_mask: Optional[Callable] = None,   # path-aware mask fn(tree)->tree of bool
) -> Optimizer:
    mdt = _dt(moment_dtype)
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        zeros = lambda p: jnp.zeros_like(p, dtype=mdt)
        return {
            "m": jax.tree_util.tree_map(zeros, params),
            "v": jax.tree_util.tree_map(zeros, params),
        }

    def update(grads, state, params, step):
        t = jnp.asarray(step, jnp.float32) + 1.0
        bc1 = 1.0 - b1 ** t
        bc2 = 1.0 - b2 ** t
        lr_t = lr_fn(step)

        def upd(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
            v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
            mh = m32 / bc1
            vh = v32 / bc2
            u = -lr_t * (mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32))
            return u, m32.astype(mdt), v32.astype(mdt)

        if decay_mask is not None:
            mask = decay_mask(params)

            def upd_masked(g, m, v, p, use_wd):
                g32 = g.astype(jnp.float32)
                m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
                v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
                wd = jnp.where(use_wd, weight_decay, 0.0)
                u = -lr_t * (
                    (m32 / bc1) / (jnp.sqrt(v32 / bc2) + eps)
                    + wd * p.astype(jnp.float32)
                )
                return u, m32.astype(mdt), v32.astype(mdt)

            out = jax.tree_util.tree_map(
                upd_masked, grads, state["m"], state["v"], params, mask
            )
        else:
            out = jax.tree_util.tree_map(upd, grads, state["m"], state["v"], params)
        updates = jax.tree_util.tree_map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree_util.tree_map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree_util.tree_map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return updates, {"m": new_m, "v": new_v}

    return Optimizer(init, update)


def sgdm(lr: Callable | float, momentum: float = 0.9) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        return {"mom": jax.tree_util.tree_map(jnp.zeros_like, params)}

    def update(grads, state, params, step):
        lr_t = lr_fn(step)

        def upd(g, m):
            m32 = momentum * m.astype(jnp.float32) + g.astype(jnp.float32)
            return -lr_t * m32, m32.astype(m.dtype)

        out = jax.tree_util.tree_map(upd, grads, state["mom"])
        updates = jax.tree_util.tree_map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree_util.tree_map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        return updates, {"mom": new_m}

    return Optimizer(init, update)


def adafactor(
    lr: Callable | float,
    decay: float = 0.8,
    eps: float = 1e-30,
    clip_threshold: float = 1.0,
) -> Optimizer:
    """Factored second-moment optimizer: O(rows+cols) state for matrices —
    the large-model memory saver (Shazeer & Stern, 2018), simplified."""
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        def factored(p):
            if p.ndim >= 2:
                return {
                    "r": jnp.zeros(p.shape[:-1], jnp.float32),
                    "c": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros_like(p, dtype=jnp.float32)}

        return {"f": jax.tree_util.tree_map(factored, params)}

    def update(grads, state, params, step):
        t = jnp.asarray(step, jnp.float32) + 1.0
        beta = 1.0 - t ** (-decay)
        lr_t = lr_fn(step)

        def upd(g, s):
            g32 = g.astype(jnp.float32)
            sq = g32 * g32 + eps
            if "r" in s:
                r = beta * s["r"] + (1 - beta) * jnp.mean(sq, axis=-1)
                c = beta * s["c"] + (1 - beta) * jnp.mean(sq, axis=-2)
                denom = (
                    r[..., None]
                    * c[..., None, :]
                    / jnp.maximum(jnp.mean(r, axis=-1, keepdims=True)[..., None], eps)
                )
                u = g32 * jax.lax.rsqrt(jnp.maximum(denom, eps))
                new_s = {"r": r, "c": c}
            else:
                v = beta * s["v"] + (1 - beta) * sq
                u = g32 * jax.lax.rsqrt(jnp.maximum(v, eps))
                new_s = {"v": v}
            rms = jnp.sqrt(jnp.mean(u * u))
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            return -lr_t * u, new_s

        # grads' structure is a prefix of state["f"] (factored dicts hang
        # below grad leaves), so tree_map passes each factored dict whole.
        flat = jax.tree_util.tree_map(upd, grads, state["f"])
        is_pair = lambda x: isinstance(x, tuple)
        updates = jax.tree_util.tree_map(lambda o: o[0], flat, is_leaf=is_pair)
        new_f = jax.tree_util.tree_map(lambda o: o[1], flat, is_leaf=is_pair)
        return updates, {"f": new_f}

    return Optimizer(init, update)
