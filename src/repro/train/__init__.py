"""Training substrate: optimizers, train-step builders, checkpointing,
fault tolerance."""
