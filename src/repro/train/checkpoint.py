"""Fault-tolerant checkpointing (no orbax in this environment — built here).

Layout::

    <dir>/step_00001230/          # atomic: written as .tmp then renamed
        manifest.json             # {path: {file, dtype, shape}}, step, ts
        0000.bin, 0001.bin, ...   # raw little-endian buffers
    <dir>/LATEST                  # text file: last committed step

Guarantees:
* step-atomic commits (tmp dir + rename; LATEST written after rename);
* restart safety: restore() ignores uncommitted .tmp dirs;
* keep-last-k retention;
* async saves on a background thread (snapshot taken synchronously);
* dtype-safe for bf16 (raw bytes + ml_dtypes names in the manifest).

State trees must be nested dicts with array leaves (the shape of all
train states in this framework).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["CheckpointManager", "save_checkpoint", "restore_checkpoint", "latest_step"]


def _flatten(tree, prefix="") -> List[Tuple[str, Any]]:
    out = []
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.extend(_flatten(tree[k], f"{prefix}{k}/"))
    else:
        out.append((prefix.rstrip("/"), tree))
    return out


def _unflatten(items: Dict[str, Any]):
    root: Dict[str, Any] = {}
    for path, val in items.items():
        parts = path.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val
    return root


def save_checkpoint(directory: str, step: int, state) -> str:
    os.makedirs(directory, exist_ok=True)
    name = f"step_{step:010d}"
    tmp = os.path.join(directory, name + ".tmp")
    final = os.path.join(directory, name)
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    manifest: Dict[str, Any] = {"step": int(step), "ts": time.time(), "arrays": {}}
    for i, (path, leaf) in enumerate(_flatten(state)):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"{i:04d}.bin"
        with open(os.path.join(tmp, fname), "wb") as f:
            f.write(arr.tobytes())
        manifest["arrays"][path] = {
            "file": fname,
            "dtype": arr.dtype.name,
            "shape": list(arr.shape),
        }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # the commit point
    with open(os.path.join(directory, "LATEST"), "w") as f:
        f.write(str(step))
    return final


def latest_step(directory: str) -> Optional[int]:
    latest = os.path.join(directory, "LATEST")
    if not os.path.exists(latest):
        # scan for committed dirs (LATEST may have been lost)
        steps = [
            int(d.split("_")[1])
            for d in os.listdir(directory)
            if d.startswith("step_") and not d.endswith(".tmp")
            and os.path.exists(os.path.join(directory, d, "manifest.json"))
        ] if os.path.isdir(directory) else []
        return max(steps) if steps else None
    with open(latest) as f:
        return int(f.read().strip())


def restore_checkpoint(
    directory: str,
    step: Optional[int] = None,
    shardings=None,
):
    """Restore a state tree; optionally device_put with a shardings tree."""
    step = latest_step(directory) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no committed checkpoint in {directory}")
    d = os.path.join(directory, f"step_{step:010d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    import ml_dtypes  # jax dependency; provides bfloat16 numpy dtype

    items = {}
    for path, meta in manifest["arrays"].items():
        dtype = np.dtype(getattr(ml_dtypes, meta["dtype"], meta["dtype"]))
        with open(os.path.join(d, meta["file"]), "rb") as f:
            buf = f.read()
        expected = int(np.prod(meta["shape"])) * dtype.itemsize if meta["shape"] else dtype.itemsize
        if len(buf) != expected:
            raise IOError(
                f"corrupt checkpoint {d}: {meta['file']} has {len(buf)} bytes, "
                f"expected {expected} for {path}"
            )
        items[path] = np.frombuffer(buf, dtype=dtype).reshape(meta["shape"])
    tree = _unflatten(items)
    if shardings is not None:
        tree = jax.tree_util.tree_map(
            lambda a, s: jax.device_put(a, s), tree, shardings
        )
    return tree, step


class CheckpointManager:
    """Retention + async writes + restart discovery."""

    def __init__(
        self,
        directory: str,
        keep_last: int = 3,
        async_save: bool = True,
    ):
        self.directory = directory
        self.keep_last = keep_last
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------
    def save(self, step: int, state) -> None:
        self.wait()
        # snapshot on the caller thread (values may be donated/mutated after)
        snapshot = jax.tree_util.tree_map(
            lambda x: np.asarray(jax.device_get(x)), state
        )
        if not self.async_save:
            self._commit(step, snapshot)
            return
        self._thread = threading.Thread(
            target=self._commit, args=(step, snapshot), daemon=True
        )
        self._thread.start()

    def _commit(self, step: int, snapshot) -> None:
        try:
            save_checkpoint(self.directory, step, snapshot)
            self._gc()
        except BaseException as e:  # surfaced on next wait()
            self._error = e

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self) -> None:
        steps = sorted(
            int(d.split("_")[1])
            for d in os.listdir(self.directory)
            if d.startswith("step_") and not d.endswith(".tmp")
        )
        for s in steps[: -self.keep_last]:
            shutil.rmtree(
                os.path.join(self.directory, f"step_{s:010d}"), ignore_errors=True
            )

    # -- restore --------------------------------------------------------------
    def restore_latest(self, shardings=None):
        self.wait()
        return restore_checkpoint(self.directory, shardings=shardings)

    def latest_step(self) -> Optional[int]:
        return latest_step(self.directory)
