"""Train/serve step builders — the functions the launcher jits and shards.

``build_*`` functions close over configs (configs hold dicts and are not
hashable — never passed as static jit args).  A train step:

    state = {"params": ..., "opt": ..., "step": int32}
    new_state, metrics = step(state, batch)

Features: microbatch gradient accumulation (``cfg.microbatches``) via
``lax.scan`` — one gradient all-reduce per *step*, not per microbatch,
which divides cross-pod (DCI) traffic by the accumulation factor;
global-norm clipping; optional int8 error-feedback gradient compression
(``repro.distributed.compression``).
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import GNNConfig, RecsysConfig, TransformerConfig
from ..distributed import compression
from ..models import gnn, sasrec, transformer
from .optimizer import Optimizer, apply_updates, clip_by_global_norm

__all__ = [
    "init_train_state",
    "make_update_fn",
    "lm_loss",
    "build_lm_train_step",
    "build_lm_prefill_step",
    "build_lm_decode_step",
    "build_gnn_train_step",
    "build_gnn_infer_step",
    "build_sasrec_train_step",
]


def init_train_state(params, optimizer: Optimizer) -> Dict:
    return {
        "params": params,
        "opt": optimizer.init(params),
        "step": jnp.zeros((), jnp.int32),
    }


def make_update_fn(
    loss_fn: Callable,              # (params, batch) -> (loss, metrics)
    optimizer: Optimizer,
    clip_norm: float = 1.0,
    microbatches: int = 1,
    compress_grads: bool = False,
    accum_dtype=None,               # jnp dtype for the accumulation buffer
    param_axes=None,                # logical-axes tree: constrains grads to
                                    # the param sharding (reduce-scatter,
                                    # not all-reduce-then-slice)
) -> Callable:
    from ..distributed.sharding import shard as _shard

    def constrain_grads(grads):
        if param_axes is None:
            return grads
        return jax.tree_util.tree_map(
            lambda ax, g: _shard(g, *ax),
            param_axes,
            grads,
            is_leaf=lambda a: isinstance(a, tuple)
            and all(isinstance(e, str) or e is None for e in a),
        )

    def step(state, batch):
        params = state["params"]
        grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

        if microbatches == 1:
            (loss, metrics), grads = grad_fn(params, batch)
            grads = constrain_grads(grads)
        else:
            micro = jax.tree_util.tree_map(
                lambda x: x.reshape((microbatches, x.shape[0] // microbatches)
                                    + x.shape[1:]),
                batch,
            )

            adt = accum_dtype or jnp.float32

            def accum(carry, mb):
                acc, loss_acc = carry
                (l, m), g = grad_fn(params, mb)
                g = constrain_grads(g)
                acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(adt), acc, g
                )
                return (acc, loss_acc + l), m

            zeros = constrain_grads(
                jax.tree_util.tree_map(
                    lambda p: jnp.zeros(p.shape, adt), params
                )
            )
            (gacc, loss_sum), ms = jax.lax.scan(accum, (zeros, 0.0), micro)
            grads = jax.tree_util.tree_map(lambda g: g / microbatches, gacc)
            loss = loss_sum / microbatches
            metrics = jax.tree_util.tree_map(lambda m: m[-1], ms)

        if compress_grads:
            grads, err = compression.compress_decompress(
                grads, state.get("grad_err")
            )
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        updates, new_opt = optimizer.update(
            grads, state["opt"], params, state["step"]
        )
        new_params = apply_updates(params, updates)
        new_state = {
            "params": new_params,
            "opt": new_opt,
            "step": state["step"] + 1,
        }
        if compress_grads:
            new_state["grad_err"] = err
        metrics = dict(metrics)
        metrics.update({"loss": loss, "grad_norm": gnorm})
        return new_state, metrics

    return step


# ---------------------------------------------------------------------------
# LM
# ---------------------------------------------------------------------------

def lm_loss(params, batch: Dict, cfg: TransformerConfig):
    logits, _, aux = transformer.forward(params, batch["tokens"], cfg)
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    labels_safe = jnp.maximum(labels, 0)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, labels_safe[..., None], axis=-1)[..., 0]
    ce = -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    loss = ce + aux
    return loss, {"ce": ce, "aux": aux}


def build_lm_train_step(
    cfg: TransformerConfig,
    optimizer: Optimizer,
    clip_norm: float = 1.0,
    compress_grads: bool = False,
) -> Callable:
    import jax.numpy as _jnp

    return make_update_fn(
        lambda p, b: lm_loss(p, b, cfg),
        optimizer,
        clip_norm=clip_norm,
        microbatches=cfg.microbatches,
        compress_grads=compress_grads,
        accum_dtype={"float32": _jnp.float32, "bfloat16": _jnp.bfloat16}[
            cfg.grad_accum_dtype
        ],
        param_axes=transformer.logical_axes(cfg),
    )


def build_lm_prefill_step(cfg: TransformerConfig, max_len: int) -> Callable:
    def prefill(params, tokens):
        cache = transformer.init_cache(cfg, tokens.shape[0], max_len)
        logits, cache, _ = transformer.forward(params, tokens, cfg, cache)
        return logits[:, -1], cache

    return prefill


def build_lm_decode_step(cfg: TransformerConfig) -> Callable:
    def decode(params, cache, token):
        logits, cache, _ = transformer.forward(params, token, cfg, cache)
        return logits[:, -1], cache

    return decode


# ---------------------------------------------------------------------------
# GNN
# ---------------------------------------------------------------------------

def gnn_loss(params, batch: Dict, cfg: GNNConfig):
    out = gnn.forward(params, batch["graph"], cfg)
    target = batch["target"]
    if target.dtype in (jnp.int32, jnp.int64):  # node classification
        logp = jax.nn.log_softmax(out.astype(jnp.float32), axis=-1)
        mask = (target >= 0).astype(jnp.float32)
        ll = jnp.take_along_axis(logp, jnp.maximum(target, 0)[..., None], -1)[..., 0]
        loss = -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    else:  # regression
        err = (out.astype(jnp.float32) - target.astype(jnp.float32)) ** 2
        if out.ndim == 2 and batch["graph"].graph_ids is None:
            err = err * batch["graph"].node_mask[:, None].astype(jnp.float32)
            loss = jnp.sum(err) / jnp.maximum(
                jnp.sum(batch["graph"].node_mask), 1.0
            )
        else:
            loss = jnp.mean(err)
    return loss, {"mse_or_ce": loss}


def build_gnn_train_step(
    cfg: GNNConfig, optimizer: Optimizer, clip_norm: float = 1.0
) -> Callable:
    return make_update_fn(
        lambda p, b: gnn_loss(p, b, cfg), optimizer, clip_norm=clip_norm
    )


def build_gnn_infer_step(cfg: GNNConfig) -> Callable:
    def infer(params, graph):
        return gnn.forward(params, graph, cfg)

    return infer


# ---------------------------------------------------------------------------
# SASRec
# ---------------------------------------------------------------------------

def sasrec_loss(params, batch: Dict, cfg: RecsysConfig):
    loss = sasrec.train_loss(
        params, batch["seqs"], batch["pos"], batch["neg"], cfg
    )
    return loss, {"bce": loss}


def build_sasrec_train_step(
    cfg: RecsysConfig, optimizer: Optimizer, clip_norm: float = 1.0
) -> Callable:
    return make_update_fn(
        lambda p, b: sasrec_loss(p, b, cfg), optimizer, clip_norm=clip_norm
    )
