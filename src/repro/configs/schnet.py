"""schnet [arXiv:1706.08566]: 3 interactions, d_hidden=64, 300 RBF,
cutoff 10 Å — continuous-filter convolutions."""
from .base import DEFAULT_LM_RULES, GNNConfig

_GNN_RULES = {
    **DEFAULT_LM_RULES,
    "nodes": ("pod", "data", "model"),
    "edges": ("pod", "data", "model"),
}

CONFIG = GNNConfig(
    name="schnet",
    kind="schnet",
    n_layers=3,
    d_hidden=64,
    n_rbf=300,
    cutoff=10.0,
    d_out=1,
    remat_policy="full",
    sharding_rules=_GNN_RULES,
)

SMOKE = GNNConfig(
    name="schnet-smoke",
    kind="schnet",
    n_layers=2,
    d_hidden=16,
    n_rbf=24,
    cutoff=6.0,
    d_out=1,
    remat_policy="none",
)

SHAPE_FAMILY = "gnn"
