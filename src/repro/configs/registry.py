"""Architecture registry: --arch <id> resolution for every launcher."""
from __future__ import annotations

import importlib
from typing import Dict, List

__all__ = ["ARCH_MODULES", "get_arch", "list_archs", "shapes_for"]

ARCH_MODULES: Dict[str, str] = {
    "glm4-9b": "repro.configs.glm4_9b",
    "yi-9b": "repro.configs.yi_9b",
    "llama3-405b": "repro.configs.llama3_405b",
    "granite-moe-3b-a800m": "repro.configs.granite_moe_3b_a800m",
    "moonshot-v1-16b-a3b": "repro.configs.moonshot_v1_16b_a3b",
    "meshgraphnet": "repro.configs.meshgraphnet",
    "graphcast": "repro.configs.graphcast",
    "schnet": "repro.configs.schnet",
    "dimenet": "repro.configs.dimenet",
    "sasrec": "repro.configs.sasrec",
    "graphgen-paper": "repro.configs.graphgen_paper",
}


def get_arch(name: str):
    """Returns the arch module (CONFIG, SMOKE, SHAPE_FAMILY, ...)."""
    if name not in ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCH_MODULES)}")
    return importlib.import_module(ARCH_MODULES[name])


def list_archs(assigned_only: bool = False) -> List[str]:
    names = list(ARCH_MODULES)
    if assigned_only:
        names.remove("graphgen-paper")
    return names


def shapes_for(name: str) -> List[str]:
    from . import shapes

    fam = get_arch(name).SHAPE_FAMILY
    return {
        "lm": list(shapes.LM_SHAPES),
        "gnn": list(shapes.GNN_SHAPES),
        "recsys": list(shapes.REC_SHAPES),
        "graphgen": ["pagerank"],
    }[fam]
