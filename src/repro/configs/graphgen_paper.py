"""The paper's own architecture: distributed condensed-graph analytics.

Not one of the 40 assigned cells — this is the GraphGen workload itself
as a selectable config: PageRank power iteration over a condensed
co-occurrence graph (DEDUP-C exactness), with edges sharded over every
mesh axis.  The dry-run lowers one PageRank sweep at DBLP-2017 scale
(paper Table 1: 1.6M authors / 3M pubs / 8.6M author-pub edges,
17.1M condensed edges vs 86.2M expanded)."""
import dataclasses

from .base import DEFAULT_LM_RULES


@dataclasses.dataclass(frozen=True)
class GraphGenConfig:
    name: str = "graphgen-paper"
    n_real: int = 1_638_400          # authors (padded to 1024 multiple)
    n_virtual: int = 2_998_272       # pubs
    n_in_edges: int = 8_650_752      # author->pub
    n_correction: int = 524_288      # duplicated pairs (paper: rare)
    pagerank_iters: int = 20
    dtype: str = "float32"
    sharding_rules: dict = dataclasses.field(
        default_factory=lambda: {
            **DEFAULT_LM_RULES,
            "nodes": ("pod", "data", "model"),
            "edges": ("pod", "data", "model"),
        }
    )


CONFIG = GraphGenConfig()
SMOKE = GraphGenConfig(
    name="graphgen-smoke",
    n_real=1024,
    n_virtual=2048,
    n_in_edges=8192,
    n_correction=512,
    pagerank_iters=3,
)

SHAPE_FAMILY = "graphgen"
