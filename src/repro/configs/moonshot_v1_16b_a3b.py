"""moonshot-v1-16b-a3b [hf:moonshotai/Moonlight-16B-A3B]: 48L d_model=2048
16H (GQA kv=16) d_ff=1408 vocab=163840, MoE 64 experts top-6."""
from .base import DEFAULT_LM_RULES, MoEConfig, TransformerConfig

CONFIG = TransformerConfig(
    name="moonshot-v1-16b-a3b",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=163840,
    moe=MoEConfig(n_experts=64, top_k=6, d_expert=1408, capacity_factor=1.25),
    microbatches=8,
    remat_policy="full",
    sharding_rules={
        **DEFAULT_LM_RULES,
        "heads": "model",         # 16 / 16 = 1
        "kv_heads": "model",      # MHA-style kv=16 shards cleanly
        "experts": "model",       # 64 / 16 = 4 (EP)
        "expert_ff": None,
        "vocab": "model",         # 163840 / 16 = 10240
        "act_seq": "model",       # SP residual stream
    },
)

SMOKE = TransformerConfig(
    name="moonshot-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=96,
    vocab_size=160,
    moe=MoEConfig(n_experts=8, top_k=2, d_expert=48, capacity_factor=2.0),
    microbatches=1,
    remat_policy="none",
)

SHAPE_FAMILY = "lm"
