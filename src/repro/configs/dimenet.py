"""dimenet [arXiv:2003.03123]: 6 blocks, d_hidden=128, 8 bilinear,
n_spherical=7, n_radial=6 — directional message passing over triplets.

Triplets (k->j->i edge pairs) are enumerated host-side
(:func:`repro.data.graphs.build_triplets`) with a per-shape budget of
``triplet_factor x n_edges`` capped at 16.7M (noted coverage bound for
the ogb_products shape)."""
from .base import DEFAULT_LM_RULES, GNNConfig

_GNN_RULES = {
    **DEFAULT_LM_RULES,
    "nodes": ("pod", "data", "model"),
    "edges": ("pod", "data", "model"),
}

CONFIG = GNNConfig(
    name="dimenet",
    kind="dimenet",
    n_layers=6,
    d_hidden=128,
    n_bilinear=8,
    n_spherical=7,
    n_radial=6,
    n_rbf=64,
    cutoff=10.0,
    d_out=1,
    triplet_factor=8,
    remat_policy="full",
    sharding_rules=_GNN_RULES,
)

SMOKE = GNNConfig(
    name="dimenet-smoke",
    kind="dimenet",
    n_layers=2,
    d_hidden=16,
    n_bilinear=4,
    n_spherical=3,
    n_radial=2,
    n_rbf=12,
    cutoff=6.0,
    d_out=1,
    remat_policy="none",
)

SHAPE_FAMILY = "gnn"
