"""Config dataclasses for every architecture family.

Each assigned architecture gets one file in this package defining
``CONFIG`` (exact published numbers), ``SMOKE`` (reduced same-family
config for CPU tests), ``SHAPES`` (its input-shape set), and
``input_specs(shape_name, smoke=False)`` -> dict of ShapeDtypeStruct.

Sharding is configured *per arch* through ``sharding_rules``: a mapping
from logical axis names to mesh axis names (or None = replicate).  Rules
must respect divisibility (e.g. granite's 24 heads / 40 experts do not
divide a 16-way model axis, so those configs shard d_ff instead).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Optional, Tuple

__all__ = [
    "MoEConfig",
    "TransformerConfig",
    "GNNConfig",
    "RecsysConfig",
    "DEFAULT_LM_RULES",
]

# Logical axes used by the model code; rules map them to mesh axes.
# mesh axes: ("pod", "data", "model") multi-pod / ("data", "model") single.
DEFAULT_LM_RULES: Dict[str, object] = {
    "batch": ("pod", "data"),   # data parallel (pod composes with data)
    "seq": None,                # attention-internal seq axis
    "act_seq": None,            # residual-stream sequence parallelism (SP)
    "expert_capacity": None,    # MoE capacity-dim sharding (granite)
    "cache_batch": ("pod", "data"),
    "cache_seq": None,          # long-context decode shards the KV cache seq
    "embed": None,              # activation embed dim
    "embed_param": "data",      # FSDP weight shard
    "heads": "model",           # TP over query heads
    "kv_heads": None,           # replicated unless kv_heads % model == 0
    "ff": "model",              # TP over FFN hidden
    "vocab": "model",           # vocab-parallel embedding / logits
    "experts": "model",         # EP (MoE) when divisible
    "expert_ff": None,
    "edges": ("pod", "data"),
    "nodes": ("pod", "data"),
    "items": "model",           # recsys embedding rows
}


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int               # per-expert FFN hidden
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    aux_loss_weight: float = 0.01
    # 'sort'  : global sort-based dispatch (XLA SPMD resolves the scatter —
    #           baseline; lowers to large all-reduces, see §Perf)
    # 'a2a'   : shard_map expert-parallel all-to-all dispatch (optimized)
    dispatch: str = "sort"


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None          # default d_model // n_heads
    moe: Optional[MoEConfig] = None
    rope_theta: float = 500_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"                 # activation/compute dtype
    param_dtype: str = "float32"
    opt_state_dtype: str = "float32"        # bf16 for very large models
    remat_policy: str = "minimal"           # 'none' | 'minimal' | 'full'
    scan_layers: bool = True
    attn_block_q: int = 512                 # flash attention block sizes
    attn_block_kv: int = 1024
    microbatches: int = 1                   # gradient accumulation steps
    grad_accum_dtype: str = "float32"       # bf16 halves accumulation HBM
    sharding_rules: Mapping[str, object] = dataclasses.field(
        default_factory=lambda: dict(DEFAULT_LM_RULES)
    )

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def n_params(self) -> int:
        """Total parameter count (embedding + layers [+ experts])."""
        d, hd = self.d_model, self.resolved_head_dim
        attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) \
            + (self.n_heads * hd) * d
        if self.moe is not None:
            ff = self.moe.n_experts * 3 * d * self.moe.d_expert + d * self.moe.n_experts
        else:
            ff = 3 * d * self.d_ff
        norms = 2 * d
        per_layer = attn + ff + norms
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return self.n_layers * per_layer + emb + d

    def n_active_params(self) -> int:
        """Active (per-token) parameters — MoE counts top_k experts."""
        if self.moe is None:
            return self.n_params()
        d = self.d_model
        dense = self.n_params() - self.n_layers * (
            self.moe.n_experts * 3 * d * self.moe.d_expert
        )
        return dense + self.n_layers * self.moe.top_k * 3 * d * self.moe.d_expert


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str
    kind: str                    # 'meshgraphnet' | 'graphcast' | 'schnet' | 'dimenet'
    n_layers: int
    d_hidden: int
    # family-specific knobs (unused ones stay at defaults)
    mlp_layers: int = 2
    aggregator: str = "sum"
    n_rbf: int = 300
    cutoff: float = 10.0
    n_spherical: int = 7
    n_radial: int = 6
    n_bilinear: int = 8
    mesh_refinement: int = 0
    n_vars: int = 0
    d_out: int = 1
    triplet_factor: int = 8      # dimenet: triplets per edge budget
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat_policy: str = "minimal"
    sharding_rules: Mapping[str, object] = dataclasses.field(
        default_factory=lambda: dict(DEFAULT_LM_RULES)
    )


@dataclasses.dataclass(frozen=True)
class RecsysConfig:
    name: str
    embed_dim: int
    n_blocks: int
    n_heads: int
    seq_len: int
    n_items: int
    dropout: float = 0.0
    pad_embed_to: Optional[int] = None   # beyond-paper MXU alignment option
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    sharding_rules: Mapping[str, object] = dataclasses.field(
        default_factory=lambda: dict(DEFAULT_LM_RULES)
    )

    @property
    def d(self) -> int:
        return self.pad_embed_to or self.embed_dim
