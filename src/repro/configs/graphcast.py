"""graphcast [arXiv:2212.12794]: 16-layer encode-process-decode mesh GNN,
d_hidden=512, mesh_refinement=6, 227 output variables.

For assigned graph shapes the input feature width comes from the shape
(d_feat); n_vars=227 defines the output head.  The icosahedral multimesh
of the paper is a *graph construction* choice — the processor consumes
whatever edge set the shape provides (DESIGN.md §4)."""
from .base import DEFAULT_LM_RULES, GNNConfig

_GNN_RULES = {
    **DEFAULT_LM_RULES,
    "nodes": ("pod", "data", "model"),
    "edges": ("pod", "data", "model"),
}

CONFIG = GNNConfig(
    name="graphcast",
    kind="graphcast",
    n_layers=16,
    d_hidden=512,
    mlp_layers=2,
    aggregator="sum",
    mesh_refinement=6,
    n_vars=227,
    d_out=227,
    remat_policy="full",
    sharding_rules=_GNN_RULES,
)

SMOKE = GNNConfig(
    name="graphcast-smoke",
    kind="graphcast",
    n_layers=2,
    d_hidden=48,
    mlp_layers=2,
    n_vars=11,
    d_out=11,
    remat_policy="none",
)

SHAPE_FAMILY = "gnn"
