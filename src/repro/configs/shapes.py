"""Assigned input-shape sets per architecture family.

Every (arch x shape) cell is defined by one of these descriptors; the
cell builders in :mod:`repro.launch.cells` turn (config, shape) into a
function + ShapeDtypeStruct inputs + shardings for the dry-run.

GNN sizes are padded to multiples of 1024 so every tensor divides the
512-way (pod x data x model) edge/node sharding; padding is masked
(GraphBatch.node_mask/edge_mask) and therefore inert.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

__all__ = ["LMShape", "GNNShape", "RecShape", "LM_SHAPES", "GNN_SHAPES", "REC_SHAPES"]


@dataclasses.dataclass(frozen=True)
class LMShape:
    name: str
    kind: str              # 'train' | 'prefill' | 'decode'
    seq_len: int
    global_batch: int


LM_SHAPES: Dict[str, LMShape] = {
    "train_4k": LMShape("train_4k", "train", 4_096, 256),
    "prefill_32k": LMShape("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": LMShape("decode_32k", "decode", 32_768, 128),
    "long_500k": LMShape("long_500k", "decode", 524_288, 1),
}


def _pad(n: int, m: int = 1024) -> int:
    return -(-n // m) * m


@dataclasses.dataclass(frozen=True)
class GNNShape:
    name: str
    kind: str              # 'train' | 'infer'
    n_nodes: int
    n_edges: int
    d_feat: int
    n_graphs: int = 1      # >1 = batched small graphs (graph-level output)
    # real (unpadded) sizes for bookkeeping
    raw_nodes: int = 0
    raw_edges: int = 0


GNN_SHAPES: Dict[str, GNNShape] = {
    # Cora-scale full-batch: 2,708 nodes / 10,556 edges / 1,433 features
    "full_graph_sm": GNNShape(
        "full_graph_sm", "train", _pad(2_708), _pad(10_556), 1_433,
        raw_nodes=2_708, raw_edges=10_556,
    ),
    # Reddit-scale sampled training: seeds 1,024 fanout 15,10 ->
    # nodes 1,024 + 15,360 + 153,600 = 169,984; edges 15,360 + 153,600.
    "minibatch_lg": GNNShape(
        "minibatch_lg", "train", _pad(169_984), _pad(168_960), 602,
        raw_nodes=169_984, raw_edges=168_960,
    ),
    # ogbn-products full-batch-large
    "ogb_products": GNNShape(
        "ogb_products", "train", _pad(2_449_029), _pad(61_859_140), 100,
        raw_nodes=2_449_029, raw_edges=61_859_140,
    ),
    # batched small molecules: 128 graphs x (30 nodes, 64 edges)
    "molecule": GNNShape(
        "molecule", "train", _pad(30 * 128), _pad(64 * 128), 32, n_graphs=128,
        raw_nodes=30 * 128, raw_edges=64 * 128,
    ),
}

# DimeNet triplet budget per shape (triplets = edges x factor, capped).
TRIPLET_CAP = 16_777_216


def triplet_count(shape: GNNShape, factor: int) -> int:
    return min(_pad(shape.n_edges * factor), TRIPLET_CAP)


@dataclasses.dataclass(frozen=True)
class RecShape:
    name: str
    kind: str              # 'train' | 'score_all' | 'score_cand'
    batch: int
    n_candidates: int = 0


REC_SHAPES: Dict[str, RecShape] = {
    "train_batch": RecShape("train_batch", "train", 65_536),
    "serve_p99": RecShape("serve_p99", "score_all", 512),
    "serve_bulk": RecShape("serve_bulk", "score_all", 262_144),
    "retrieval_cand": RecShape("retrieval_cand", "score_cand", 1, 1_000_000),
}
