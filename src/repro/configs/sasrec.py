"""sasrec [arXiv:1808.09781]: embed_dim=50, 2 blocks, 1 head, seq_len=50,
self-attentive sequential recommendation.

Catalog sized to the retrieval shape (1M items); the item table is the
dominant state, row-sharded over the model axis (recsys EP).  The
paper-faithful embed_dim is 50; ``pad_embed_to=64`` exists as a
beyond-paper MXU-alignment option (see EXPERIMENTS.md §Perf)."""
from .base import DEFAULT_LM_RULES, RecsysConfig

CONFIG = RecsysConfig(
    name="sasrec",
    embed_dim=50,
    n_blocks=2,
    n_heads=1,
    seq_len=50,
    n_items=1_000_000,
    sharding_rules={
        **DEFAULT_LM_RULES,
        "items": "model",
        "ff": None,            # d=50 doesn't divide 16; blocks replicated
    },
)

SMOKE = RecsysConfig(
    name="sasrec-smoke",
    embed_dim=16,
    n_blocks=2,
    n_heads=1,
    seq_len=20,
    n_items=500,
)

SHAPE_FAMILY = "recsys"
