"""meshgraphnet [arXiv:2010.03409]: 15 layers, d_hidden=128, sum
aggregator, 2-layer MLPs.  Encode-process-decode mesh GNN."""
from .base import DEFAULT_LM_RULES, GNNConfig

_GNN_RULES = {
    **DEFAULT_LM_RULES,
    # GNN weights are tiny; spend every mesh axis on edge/node parallelism.
    "nodes": ("pod", "data", "model"),
    "edges": ("pod", "data", "model"),
}

CONFIG = GNNConfig(
    name="meshgraphnet",
    kind="meshgraphnet",
    n_layers=15,
    d_hidden=128,
    mlp_layers=2,
    aggregator="sum",
    d_out=3,
    remat_policy="full",
    sharding_rules=_GNN_RULES,
)

SMOKE = GNNConfig(
    name="meshgraphnet-smoke",
    kind="meshgraphnet",
    n_layers=3,
    d_hidden=32,
    mlp_layers=2,
    d_out=3,
    remat_policy="none",
)

SHAPE_FAMILY = "gnn"
