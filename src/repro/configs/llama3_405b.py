"""llama3-405b [arXiv:2407.21783]: 126L d_model=16384 128H (GQA kv=8)
d_ff=53248 vocab=128256 — GQA, 128k vocab.

Memory posture at 256-512 chips (16 GiB HBM each): Adafactor (factored
second moment) instead of Adam, 8-way gradient accumulation,
sequence-parallel residual stream, full remat.  fp32 master weights
sharded over (data x model) = 6.3 GB/chip; see EXPERIMENTS.md §Dry-run.
"""
from .base import DEFAULT_LM_RULES, TransformerConfig

CONFIG = TransformerConfig(
    name="llama3-405b",
    n_layers=126,
    d_model=16384,
    n_heads=128,
    n_kv_heads=8,
    d_ff=53248,
    vocab_size=128256,
    rope_theta=500_000.0,
    microbatches=8,
    remat_policy="full",
    opt_state_dtype="bfloat16",
    grad_accum_dtype="bfloat16",
    sharding_rules={
        **DEFAULT_LM_RULES,
        "heads": "model",       # 128 % 16 == 0
        "kv_heads": None,       # 8 < 16: replicated KV within TP groups
        "act_seq": "model",     # SP: residual stream sharded over model
    },
)

OPTIMIZER = "adafactor"   # factored second moment: the 405B memory saver

SMOKE = TransformerConfig(
    name="llama3-405b-smoke",
    n_layers=2,
    d_model=128,
    n_heads=8,
    n_kv_heads=2,
    d_ff=256,
    vocab_size=256,
    microbatches=1,
    remat_policy="none",
)

SHAPE_FAMILY = "lm"
