"""yi-9b [arXiv:2403.04652]: 48L d_model=4096 32H (GQA kv=4) d_ff=11008
vocab=64000 — llama-arch GQA."""
from .base import DEFAULT_LM_RULES, TransformerConfig

CONFIG = TransformerConfig(
    name="yi-9b",
    n_layers=48,
    d_model=4096,
    n_heads=32,
    n_kv_heads=4,
    d_ff=11008,
    vocab_size=64000,
    rope_theta=5_000_000.0,
    microbatches=4,
    remat_policy="full",
    sharding_rules={
        **DEFAULT_LM_RULES,
        "heads": "model",
        "kv_heads": None,       # 4 < 16
        "act_seq": "model",
    },
)

SMOKE = TransformerConfig(
    name="yi-9b-smoke",
    n_layers=2,
    d_model=64,
    n_heads=8,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=128,
    microbatches=1,
    remat_policy="none",
)

SHAPE_FAMILY = "lm"
