"""glm4-9b [hf:THUDM/glm-4-9b]: 40L d_model=4096 32H (GQA kv=2)
d_ff=13696 vocab=151552 — RoPE, GQA."""
from .base import DEFAULT_LM_RULES, TransformerConfig

CONFIG = TransformerConfig(
    name="glm4-9b",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab_size=151552,
    rope_theta=500_000.0,
    microbatches=4,
    remat_policy="full",
    sharding_rules={
        **DEFAULT_LM_RULES,
        "heads": "model",        # 32 % 16 == 0
        "kv_heads": None,        # 2 < 16: replicate KV (GQA TP convention)
        "act_seq": "model",      # sequence-parallel residual stream
    },
)

SMOKE = TransformerConfig(
    name="glm4-9b-smoke",
    n_layers=2,
    d_model=64,
    n_heads=8,
    n_kv_heads=2,
    d_ff=160,
    vocab_size=128,
    microbatches=1,
    remat_policy="none",
)

SHAPE_FAMILY = "lm"
