"""Arch configs: exact published configurations + reduced smoke variants.

``registry.get_arch(name)`` resolves ``--arch <id>``.
"""
from .base import GNNConfig, MoEConfig, RecsysConfig, TransformerConfig
from .registry import get_arch, list_archs, shapes_for

__all__ = [
    "GNNConfig",
    "MoEConfig",
    "RecsysConfig",
    "TransformerConfig",
    "get_arch",
    "list_archs",
    "shapes_for",
]
