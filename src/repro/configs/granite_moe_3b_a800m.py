"""granite-moe-3b-a800m [hf:ibm-granite/granite-3.0-*-base]: 32L
d_model=1536 24H (GQA kv=8) d_ff=512 vocab=49155, MoE 40 experts top-8.

Sharding notes: 24 heads and 40 experts do not divide the 16-way model
axis -> attention heads and the expert axis stay replicated; TP lives on
the per-expert FFN dim (512/16) and the MoE *capacity* dim instead.
"""
from .base import DEFAULT_LM_RULES, MoEConfig, TransformerConfig

CONFIG = TransformerConfig(
    name="granite-moe-3b-a800m",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    head_dim=64,
    moe=MoEConfig(n_experts=40, top_k=8, d_expert=512, capacity_factor=1.25),
    microbatches=8,
    remat_policy="full",
    sharding_rules={
        **DEFAULT_LM_RULES,
        "heads": None,             # 24 % 16 != 0
        "kv_heads": None,
        "experts": None,           # 40 % 16 != 0
        "expert_ff": "model",      # 512 / 16 = 32
        "expert_capacity": "model",
        "ff": "model",
        "vocab": None,             # 49155 is odd-sized; keep replicated
        "act_seq": "model",        # SP residual stream
    },
)

SMOKE = TransformerConfig(
    name="granite-moe-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=64,
    vocab_size=131,
    head_dim=16,
    moe=MoEConfig(n_experts=8, top_k=2, d_expert=32, capacity_factor=2.0),
    microbatches=1,
    remat_policy="none",
)

SHAPE_FAMILY = "lm"
