"""Deduplication algorithms & structures (paper §5, App. B).

Input everywhere: a C-DUP :class:`~repro.core.condensed.CondensedGraph`.
Outputs:

* :func:`build_correction`   — DEDUP-C (beyond paper): sparse correction
  edge list making ring propagation exact (vectorized TPU-native dedup).
* :func:`bitmap1` / :func:`bitmap2` — BITMAP representations (paper §5.1):
  per-(real source, virtual node) bitmaps over the virtual node's
  out-slots.  BITMAP-2 is the greedy set-cover variant, implemented as a
  *parallel* greedy (all real nodes advance one pick per round — each
  node's pick sequence is independent, so this equals the per-node
  sequential greedy) — that is our multi-core adaptation of the paper's
  chunked threading.
* :func:`dedup1_*`           — four DEDUP-1 rewriting algorithms (§5.2.1)
  for single-layer symmetric condensed graphs (the paper's evaluated
  setting: co-author / co-actor style membership sets).
* :func:`dedup2_greedy`      — DEDUP-2 (App. B): virtual-virtual edges.

Everything here is host-side NumPy/Python preprocessing, exactly as in the
paper (one-time cost amortized over analyses, §6.1.3).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from .condensed import (
    BipartiteEdges,
    Chain,
    CondensedGraph,
    ExpansionAccounting,
    _aggregate_pairs,
    build_csr,
    fold_path_pairs,
    split_expansion_budget,
)

__all__ = [
    "build_correction",
    "build_correction_streaming",
    "build_wedge_correction",
    "StreamedCorrection",
    "BitmapRep",
    "bitmap1",
    "bitmap2",
    "dedup1_naive_virtual_first",
    "dedup1_naive_real_first",
    "dedup1_greedy_real_first",
    "dedup1_greedy_virtual_first",
    "Dedup2Rep",
    "dedup2_greedy",
    "membership_sets",
    "graph_from_membership",
    "is_symmetric_single_layer",
]


# ---------------------------------------------------------------------------
# DEDUP-C: counting correction (vectorized; beyond-paper, see DESIGN.md §2)
# ---------------------------------------------------------------------------

def build_correction(
    graph: CondensedGraph, drop_self_loops: bool = True
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Sparse D with  A = M - D,  A = min(M, 1) (minus diag if requested).

    Returns (src, dst, count) triples: count = multiplicity-1 for
    duplicated off-diagonal pairs, plus full multiplicity on the diagonal
    when ``drop_self_loops``.  nnz(D) is the number of *duplicated* pairs —
    small in practice (paper §6) — so the correction SpMV is cheap.
    """
    s, d, m = graph.multiplicities()
    return _correction_from_multiplicities(s, d, m, drop_self_loops)


def _correction_from_multiplicities(
    s: np.ndarray, d: np.ndarray, m: np.ndarray, drop_self_loops: bool
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    diag = s == d
    if drop_self_loops:
        corr = np.where(diag, m, m - 1)
    else:
        corr = m - 1
    keep = corr > 0
    return s[keep], d[keep], corr[keep]


def _coo_coalesce(
    src: np.ndarray, dst: np.ndarray, val: np.ndarray, n: int
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    key = src.astype(np.int64) * n + dst.astype(np.int64)
    uniq, inv = np.unique(key, return_inverse=True)
    out = np.zeros(uniq.size, dtype=np.int64)
    np.add.at(out, inv, val.astype(np.int64))
    keep = out != 0
    return (uniq[keep] // n), (uniq[keep] % n), out[keep]


def _coo_matmul(
    a: Tuple[np.ndarray, np.ndarray, np.ndarray],
    b: Tuple[np.ndarray, np.ndarray, np.ndarray],
    n: int,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Sparse ``A @ B`` over (src, dst, val) COO triples, no dense n×n."""
    as_, ad, av = a
    bs, bd, bv = b
    if as_.size == 0 or bs.size == 0:
        e = np.zeros(0, np.int64)
        return e, e.copy(), e.copy()
    order = np.argsort(bs, kind="stable")
    bs_s, bd_s, bv_s = bs[order], bd[order], bv[order]
    lo = np.searchsorted(bs_s, ad, side="left")
    hi = np.searchsorted(bs_s, ad, side="right")
    cnt = hi - lo
    total = int(cnt.sum())
    if total == 0:
        e = np.zeros(0, np.int64)
        return e, e.copy(), e.copy()
    rep = np.repeat(np.arange(as_.size), cnt)
    offset = np.arange(total) - np.repeat(np.cumsum(cnt) - cnt, cnt)
    idx = np.repeat(lo, cnt) + offset
    return _coo_coalesce(
        as_[rep], bd_s[idx], av[rep].astype(np.int64) * bv_s[idx], n
    )


def build_wedge_correction(
    graph: CondensedGraph,
    correction: Optional[Tuple[np.ndarray, np.ndarray, np.ndarray]] = None,
    drop_self_loops: bool = True,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Sparse W with  A² = M² − W:  the *wedge correction* (DESIGN.md §11).

    The linear DEDUP-C identity ``A = M − D`` only makes single hops
    exact; wedge counting (the two-hop building block of triangle
    counting and clustering coefficients) squares it:

        ``A² = (M − D)² = M² − (M·D + D·M − D²)``

    so ``W = M·D + D·M − D²`` is exactly the count of *duplicate wedges*
    — two-hop paths whose legs are realized by more than one condensed
    path through shared virtual nodes — that raw C-DUP wedge propagation
    over-counts.  Returned as coalesced (src, dst, count) triples built
    sparsely from the expansion triples (no dense n×n materialization);
    :func:`repro.core.engine.propagate_wedge` subtracts them in one
    segment pass after two raw multiplicity hops.  ``W`` may carry
    negative counts where ``D²`` dominates; that is expected — it is a
    correction operator, not a multiplicity matrix.
    """
    if correction is None:
        correction = build_correction(graph, drop_self_loops=drop_self_loops)
    cs, cd, cm = (np.asarray(t) for t in tuple(correction))
    D = (cs, cd, cm.astype(np.int64))
    s, d, m = graph.multiplicities()
    M = (s, d, m.astype(np.int64))
    n = graph.n_real
    md = _coo_matmul(M, D, n)
    dm = _coo_matmul(D, M, n)
    dd = _coo_matmul(D, D, n)
    src = np.concatenate([md[0], dm[0], dd[0]])
    dst = np.concatenate([md[1], dm[1], dd[1]])
    val = np.concatenate([md[2], dm[2], -dd[2]])
    return _coo_coalesce(src, dst, val, n)


# Host accounting unit for one resident (src, dst, mult) int64 triple.
TRIPLE_BYTES = 24


@dataclasses.dataclass
class StreamedCorrection:
    """DEDUP-C correction triples plus the accounting that built them.

    Unpacks like the plain ``(src, dst, count)`` tuple from
    :func:`build_correction`, so every existing consumer
    (``engine.to_device(..., correction=...)`` and friends) accepts it
    unchanged; ``accounting`` carries the streaming-budget evidence
    (peak resident triples, chunk/merge counts) asserted by benchmarks.
    """

    src: np.ndarray
    dst: np.ndarray
    count: np.ndarray
    accounting: ExpansionAccounting

    def __iter__(self):
        return iter((self.src, self.dst, self.count))

    def __len__(self) -> int:
        return 3

    def __getitem__(self, i):
        return (self.src, self.dst, self.count)[i]

    @property
    def nnz(self) -> int:
        return int(self.src.size)

    def nbytes(self) -> int:
        return int(self.src.nbytes + self.dst.nbytes + self.count.nbytes)


def _aggregate_pairs_device(
    src: np.ndarray, dst: np.ndarray, mult: np.ndarray, n_dst: int
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """On-device multiplicity fold: sort + ``jax.ops.segment_sum``.

    Duplicate (u, v) keys are summed on the accelerator, so the host only
    ever receives already-aggregated triples.  Falls back to the host
    fold when the pair key would overflow int32 (x64 is disabled by
    default) or when multiplicities could exceed float32's exact-integer
    range; both limits are far above every evaluated dataset.
    """
    if src.size == 0:
        return _aggregate_pairs(src, dst, mult, n_dst)
    if int(src.max()) * n_dst + int(dst.max()) >= 2**31 or int(
        mult.sum()
    ) >= 2**24:
        return _aggregate_pairs(src, dst, mult, n_dst)
    import jax
    import jax.numpy as jnp

    key = jnp.asarray(src, jnp.int32) * jnp.int32(n_dst) + jnp.asarray(
        dst, jnp.int32
    )
    order = jnp.argsort(key)
    ks = key[order]
    ms = jnp.asarray(mult, jnp.float32)[order]
    is_new = jnp.concatenate(
        [jnp.ones(1, jnp.int32), (ks[1:] != ks[:-1]).astype(jnp.int32)]
    )
    seg = jnp.cumsum(is_new) - 1
    sums = jax.ops.segment_sum(ms, seg, num_segments=int(ks.size))
    first = np.flatnonzero(np.asarray(is_new))
    uniq = np.asarray(ks)[first].astype(np.int64)
    summed = np.asarray(sums)[: first.size].astype(np.int64)
    return uniq // n_dst, uniq % n_dst, summed


def build_correction_streaming(
    graph: CondensedGraph,
    budget_bytes: Optional[int] = None,
    *,
    budget_triples: Optional[int] = None,
    chunk_rows: Optional[int] = None,
    drop_self_loops: bool = True,
    device_fold: bool = False,
) -> StreamedCorrection:
    """DEDUP-C correction identical to :func:`build_correction`, built
    without ever materializing the full expansion on the host.

    The graph's chunked expansion iterator walks leading rows in bounded
    blocks and a sorted-run fold (:func:`~repro.core.condensed.
    fold_path_pairs`) consolidates duplicate (u, v) keys whenever
    residency crosses the budget — half of which bounds per-chunk
    composition and half run residency, so resident expanded triples stay
    within the budget whenever each row's expansion and the unique-pair
    count fit in half of it (``result.accounting.peak_resident_triples``
    is the asserted evidence).  ``budget_bytes`` is the same budget in
    host bytes (:data:`TRIPLE_BYTES` per triple); ``budget_triples`` takes
    precedence.  ``device_fold`` routes run consolidation through
    :func:`_aggregate_pairs_device` (``jax.ops.segment_sum``), keeping
    duplicate summation off the host.
    """
    if budget_triples is None and budget_bytes is not None:
        budget_triples = max(int(budget_bytes) // TRIPLE_BYTES, 1)
    accounting = ExpansionAccounting(budget_triples=budget_triples)
    half = split_expansion_budget(budget_triples)
    s, d, m = fold_path_pairs(
        graph.iter_path_pairs(
            chunk_rows=chunk_rows,
            budget_triples=half,
            accounting=accounting,
        ),
        graph.n_real,
        budget_triples=half,
        accounting=accounting,
        aggregate=_aggregate_pairs_device if device_fold else None,
    )
    cs, cd, cm = _correction_from_multiplicities(s, d, m, drop_self_loops)
    return StreamedCorrection(cs, cd, cm, accounting)


# ---------------------------------------------------------------------------
# Shared single-layer helpers
# ---------------------------------------------------------------------------

def _single_chain(graph: CondensedGraph) -> Chain:
    if len(graph.chains) != 1 or graph.chains[0].n_layers != 1:
        raise ValueError(
            "this algorithm handles one single-layer chain "
            f"(got {len(graph.chains)} chains, max {graph.max_layers} layers)"
        )
    return graph.chains[0]


def is_symmetric_single_layer(graph: CondensedGraph) -> bool:
    try:
        chain = _single_chain(graph)
    except ValueError:
        return False
    e_in, e_out = chain.edges
    a = np.lexsort((e_in.dst, e_in.src))
    b = np.lexsort((e_out.src, e_out.dst))
    return (
        e_in.n_edges == e_out.n_edges
        and np.array_equal(e_in.src[a], e_out.dst[b])
        and np.array_equal(e_in.dst[a], e_out.src[b])
    )


def membership_sets(graph: CondensedGraph) -> List[Set[int]]:
    """Virtual-node member sets of a symmetric single-layer graph."""
    chain = _single_chain(graph)
    e_in = chain.edges[0]
    sets: List[Set[int]] = [set() for _ in range(e_in.n_dst)]
    for u, v in zip(e_in.src.tolist(), e_in.dst.tolist()):
        sets[v].add(u)
    return sets


def graph_from_membership(
    n_real: int,
    sets: Sequence[Set[int]],
    direct_pairs: Sequence[Tuple[int, int]] = (),
) -> CondensedGraph:
    """Build a symmetric single-layer C-DUP from membership sets.

    ``direct_pairs`` are undirected (u, v) — stored as bidirectional edges.
    Empty and singleton sets are dropped (they realize no pairs).
    """
    live = [s for s in sets if len(s) >= 2]
    srcs: List[np.ndarray] = [np.empty(0, dtype=np.int64)]
    dsts: List[np.ndarray] = [np.empty(0, dtype=np.int64)]
    for vid, s in enumerate(live):
        members = np.fromiter(s, dtype=np.int64)
        srcs.append(members)
        dsts.append(np.full(members.size, vid, dtype=np.int64))
    src = np.concatenate(srcs)
    dst = np.concatenate(dsts)
    chains = []
    if src.size:
        e_in = BipartiteEdges(src, dst, n_real, len(live))
        chains = [Chain([e_in, e_in.reversed()])]
    direct = None
    if direct_pairs:
        pa = np.array([p[0] for p in direct_pairs], dtype=np.int64)
        pb = np.array([p[1] for p in direct_pairs], dtype=np.int64)
        direct = BipartiteEdges(
            np.concatenate([pa, pb]), np.concatenate([pb, pa]), n_real, n_real
        )
    return CondensedGraph(n_real, chains, direct)


# ---------------------------------------------------------------------------
# Triple expansion shared by the BITMAP algorithms.
# For every in-edge (u, V) and every out-slot s of V (dst v): one triple.
# Triple order = (u-grouped, in-adjacency order, slot order) = DFS order.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _Triples:
    edge_id: np.ndarray   # index into the u-grouped in-edge list
    u: np.ndarray
    v: np.ndarray
    slot: np.ndarray      # out-slot within the virtual node
    pair_ptr: np.ndarray  # per in-edge: [ptr[i], ptr[i+1]) range of triples
    in_src: np.ndarray    # u per in-edge (grouped by u, adjacency order)
    in_dst: np.ndarray    # V per in-edge
    out_indptr: np.ndarray
    out_indices: np.ndarray
    n_real: int
    n_virtual: int


def _expand_triples(graph: CondensedGraph) -> _Triples:
    chain = _single_chain(graph)
    e_in, e_out = chain.edges
    out_csr = build_csr(e_out)
    order = np.argsort(e_in.src, kind="stable")
    in_src = e_in.src[order]
    in_dst = e_in.dst[order]
    deg = (out_csr.indptr[1:] - out_csr.indptr[:-1])[in_dst]
    pair_ptr = np.zeros(in_src.size + 1, dtype=np.int64)
    np.cumsum(deg, out=pair_ptr[1:])
    total = int(pair_ptr[-1])
    edge_id = np.repeat(np.arange(in_src.size), deg)
    offs = np.arange(total) - np.repeat(pair_ptr[:-1], deg)
    tri_v = out_csr.indices[np.repeat(out_csr.indptr[:-1][in_dst], deg) + offs]
    return _Triples(
        edge_id=edge_id,
        u=np.repeat(in_src, deg),
        v=tri_v,
        slot=offs,
        pair_ptr=pair_ptr,
        in_src=in_src,
        in_dst=in_dst,
        out_indptr=out_csr.indptr,
        out_indices=out_csr.indices,
        n_real=graph.n_real,
        n_virtual=e_in.n_dst,
    )


@dataclasses.dataclass
class BitmapRep:
    """BITMAP representation: C-DUP edges + per-(u,V) out-slot bitmaps.

    ``bits[pair_ptr[i]:pair_ptr[i+1]]`` is the bitmap of in-edge ``i``
    (edges grouped by source real node, adjacency order).  Deleted in-edges
    (BITMAP-2 set-cover leftovers) have ``edge_alive = False`` and no bits.
    """

    graph: CondensedGraph
    in_src: np.ndarray
    in_dst: np.ndarray
    edge_alive: np.ndarray
    bits: np.ndarray       # uint8 0/1 per (in-edge, out-slot)
    pair_ptr: np.ndarray

    @property
    def n_bitmaps(self) -> int:
        return int(self.edge_alive.sum())

    @property
    def n_bits(self) -> int:
        return int(self.bits.size)

    def nbytes(self) -> int:
        """Packed-bitmap memory accounting (bits/8 + edges + indexes)."""
        edges = int(self.edge_alive.sum()) * 16  # surviving condensed edges
        out_edges = self.graph.chains[0].edges[1].n_edges * 16
        return edges + out_edges + (self.n_bits + 7) // 8 + self.pair_ptr.nbytes

    def to_dedup_pairs(self) -> Tuple[np.ndarray, np.ndarray]:
        """Surviving (u, v) pairs — each exactly once if valid (test hook)."""
        alive = self.edge_alive[
            np.repeat(np.arange(self.in_src.size), np.diff(self.pair_ptr))
        ]
        on = (self.bits == 1) & alive
        tri = _expand_triples(self.graph)
        return tri.u[on], tri.v[on]


def bitmap1(graph: CondensedGraph) -> BitmapRep:
    """BITMAP-1 (paper §5.1.1): first-path-wins bit assignment.

    Vectorized equivalent of the per-real-node DFS: the DFS visit order is
    (source, in-adjacency, out-slot); the first triple reaching a given
    (u, v) pair gets bit 1, later ones 0.  Keeps every C-DUP edge.
    """
    tri = _expand_triples(graph)
    key = tri.u.astype(np.int64) * tri.n_real + tri.v
    _, first_idx = np.unique(key, return_index=True)
    bits = np.zeros(tri.u.size, dtype=np.uint8)
    bits[first_idx] = 1
    return BitmapRep(
        graph=graph,
        in_src=tri.in_src,
        in_dst=tri.in_dst,
        edge_alive=np.ones(tri.in_src.size, dtype=bool),
        bits=bits,
        pair_ptr=tri.pair_ptr,
    )


def bitmap2(graph: CondensedGraph, max_rounds: int = 10_000) -> BitmapRep:
    """BITMAP-2 (paper §5.1.3): greedy set cover per real node.

    Parallel-greedy rounds: in each round every still-unfinished real node
    picks its uncovered-gain-maximizing virtual neighbor (equal to the
    sequential greedy because sources are independent).  Edges with zero
    remaining gain are deleted (paper: "there is no reason to traverse
    those").
    """
    tri = _expand_triples(graph)
    n_in = tri.in_src.size
    key = tri.u.astype(np.int64) * tri.n_real + tri.v
    uniq, pair_id = np.unique(key, return_inverse=True)
    covered = np.zeros(uniq.size, dtype=bool)
    bits = np.zeros(tri.u.size, dtype=np.uint8)
    # edge states: 0 undecided / 1 chosen / 2 deleted
    state = np.zeros(n_in, dtype=np.int8)
    tri_edge = tri.edge_id

    for _ in range(max_rounds):
        undecided = state == 0
        if not undecided.any():
            break
        tri_live = undecided[tri_edge] & ~covered[pair_id]
        gain = np.bincount(tri_edge[tri_live], minlength=n_in)
        gain[~undecided] = -1
        # Per-source argmax over undecided edges.
        src = tri.in_src
        best_gain = np.full(tri.n_real, -1, dtype=np.int64)
        np.maximum.at(best_gain, src, gain)
        is_best = (gain == best_gain[src]) & undecided
        # Tie-break: lowest edge index per source.
        first_of_src = np.zeros(n_in, dtype=bool)
        cand = np.flatnonzero(is_best)
        if cand.size:
            # edges are grouped by src already; first candidate per src wins
            srcs_c = src[cand]
            first = np.ones(cand.size, dtype=bool)
            first[1:] = srcs_c[1:] != srcs_c[:-1]
            first_of_src[cand[first]] = True
        zero_gain = first_of_src & (gain <= 0)
        pick = first_of_src & (gain > 0)
        # Deleting: zero-gain picks mean every remaining edge of that source
        # is useless; delete all undecided edges of finished sources.
        done_src = np.zeros(tri.n_real, dtype=bool)
        done_src[src[zero_gain]] = True
        state[(state == 0) & done_src[src]] = 2
        if pick.any():
            state[pick] = 1
            on = pick[tri_edge] & ~covered[pair_id]
            # a virtual node's out-list may repeat a target (multiplicity
            # from a multi-layer collapse): set one slot per pair, not all
            on_idx = np.flatnonzero(on)
            _, first_slot = np.unique(pair_id[on_idx], return_index=True)
            bits[on_idx[first_slot]] = 1
            covered[pair_id[on_idx]] = True
    else:  # pragma: no cover - loop guard
        raise RuntimeError("bitmap2 did not converge")

    return BitmapRep(
        graph=graph,
        in_src=tri.in_src,
        in_dst=tri.in_dst,
        edge_alive=state == 1,
        bits=bits,
        pair_ptr=tri.pair_ptr,
    )


# ---------------------------------------------------------------------------
# DEDUP-1 rewriting algorithms (paper §5.2.1), symmetric single-layer.
#
# State shared by all four: membership sets S_V, a pair-coverage counter
# over unordered real pairs, and accumulated direct edges.  Validity
# invariant (checked in tests): every originally-connected pair is covered
# exactly once; no new pairs appear.
# ---------------------------------------------------------------------------

def _require_symmetric(graph: CondensedGraph) -> List[Set[int]]:
    if not is_symmetric_single_layer(graph):
        raise ValueError(
            "DEDUP-1 algorithms are implemented for symmetric single-layer "
            "graphs (paper's evaluated setting); symmetrize or use "
            "BITMAP-2 / DEDUP-C for the general case"
        )
    return membership_sets(graph)


def _pair(u: int, v: int) -> Tuple[int, int]:
    return (u, v) if u < v else (v, u)


@dataclasses.dataclass
class Dedup1Result:
    graph: CondensedGraph
    n_direct_edges: int
    n_virtual_edges: int
    seconds: float

    @property
    def total_edges(self) -> int:
        # Undirected accounting to match the paper's figures: a membership
        # edge is one edge, a direct pair is one edge.
        return self.n_direct_edges + self.n_virtual_edges


def _finalize(
    n_real: int,
    sets: Sequence[Set[int]],
    direct: Set[Tuple[int, int]],
    t0: float,
) -> Dedup1Result:
    live = [s for s in sets if len(s) >= 2]
    g = graph_from_membership(n_real, live, sorted(direct))
    return Dedup1Result(
        graph=g,
        n_direct_edges=len(direct),
        n_virtual_edges=sum(len(s) for s in live),
        seconds=time.perf_counter() - t0,
    )


def _order(n: int, ordering: str, rng: Optional[np.random.Generator]) -> np.ndarray:
    idx = np.arange(n)
    if ordering == "random":
        (rng or np.random.default_rng(0)).shuffle(idx)
    return idx


def dedup1_naive_virtual_first(
    graph: CondensedGraph,
    ordering: str = "random",
    rng: Optional[np.random.Generator] = None,
) -> Dedup1Result:
    """Paper 'Naive Virtual Nodes First': add virtual nodes one at a time,
    shaving overlaps > 1 against already-processed nodes by moving one real
    node out of the lower-degree virtual node and patching with direct
    edges."""
    t0 = time.perf_counter()
    sets = [set(s) for s in _require_symmetric(graph)]
    rng = rng or np.random.default_rng(0)
    n_real = graph.n_real
    member_of: List[Set[int]] = [set() for _ in range(n_real)]  # processed only
    covered: Set[Tuple[int, int]] = set()
    direct: Set[Tuple[int, int]] = set()
    processed: List[int] = []

    def cover_set(vid: int) -> None:
        s = sorted(sets[vid])
        for i, a in enumerate(s):
            for b in s[i + 1 :]:
                covered.add(_pair(a, b))

    def uncover_node(vid: int, r: int) -> None:
        for other in sets[vid]:
            if other != r:
                covered.discard(_pair(r, other))

    for vid in _order(len(sets), ordering, rng).tolist():
        S = sets[vid]
        changed = True
        while changed and len(S) >= 2:
            changed = False
            # Find a processed virtual node overlapping in >= 2 members.
            counts: Dict[int, int] = {}
            for u in S:
                for rid in member_of[u]:
                    counts[rid] = counts.get(rid, 0) + 1
            for rid, c in counts.items():
                if c <= 1:
                    continue
                inter = list(S & sets[rid])
                r = inter[int(rng.integers(len(inter)))]
                # Remove from the lower-degree virtual node.
                victim = vid if len(S) <= len(sets[rid]) else rid
                if victim == rid:
                    uncover_node(rid, r)
                    sets[rid].discard(r)
                    member_of[r].discard(rid)
                    # Patch r's lost connections through rid.
                    for other in sets[rid]:
                        p = _pair(r, other)
                        if p not in covered:
                            direct.add(p)
                            covered.add(p)
                else:
                    S.discard(r)
                    # r loses its (future) connections through V; patch
                    # against the rest of V's current members.
                    for other in S:
                        p = _pair(r, other)
                        if p not in covered:
                            direct.add(p)
                            covered.add(p)
                changed = True
                break
        # Commit V: remove members whose pairs are already covered? The
        # naive algorithm guarantees overlap <= 1 now; cover V's pairs,
        # but any single pre-covered pair (overlap exactly 1 via direct
        # edges) must be avoided: drop direct duplicates.
        s_sorted = sorted(S)
        for i, a in enumerate(s_sorted):
            for b in s_sorted[i + 1 :]:
                p = _pair(a, b)
                if p in covered:
                    direct.discard(p)  # keep via V instead if it was direct
                    if p in direct:
                        continue
        # Re-check: pairs covered through processed virtual nodes (overlap
        # exactly 1) stay; that single shared member contributes no pair.
        for i, a in enumerate(s_sorted):
            for b in s_sorted[i + 1 :]:
                covered.add(_pair(a, b))
        for u in S:
            member_of[u].add(vid)
        processed.append(vid)
    return _finalize(n_real, sets, direct, t0)


def dedup1_naive_real_first(
    graph: CondensedGraph,
    ordering: str = "random",
    rng: Optional[np.random.Generator] = None,
) -> Dedup1Result:
    """Paper 'Naive Real Nodes First': per real node, resolve all pairwise
    overlaps among its virtual neighborhood (processed set scoped to the
    node)."""
    t0 = time.perf_counter()
    sets = [set(s) for s in _require_symmetric(graph)]
    rng = rng or np.random.default_rng(0)
    n_real = graph.n_real
    direct: Set[Tuple[int, int]] = set()
    # membership index kept live as sets mutate
    member: List[Set[int]] = [set() for _ in range(n_real)]
    for vid, s in enumerate(sets):
        for u in s:
            member[u].add(vid)

    def covered_elsewhere(a: int, b: int, excl: Tuple[int, ...]) -> bool:
        common = member[a] & member[b]
        return bool(common - set(excl)) or _pair(a, b) in direct

    for u in _order(n_real, ordering, rng).tolist():
        local: List[int] = []
        for vid in sorted(member[u]):
            for rid in local:
                while len(sets[vid] & sets[rid]) > 1:
                    inter = sorted(sets[vid] & sets[rid])
                    r = inter[int(rng.integers(len(inter)))]
                    victim = vid if len(sets[vid]) <= len(sets[rid]) else rid
                    keeper = rid if victim == vid else vid
                    sets[victim].discard(r)
                    member[r].discard(victim)
                    for other in sets[victim]:
                        if not covered_elsewhere(r, other, (victim,)):
                            direct.add(_pair(r, other))
            if vid in member[u]:
                local.append(vid)
    return _finalize(n_real, sets, direct, t0)


def dedup1_greedy_real_first(
    graph: CondensedGraph,
    ordering: str = "random",
    rng: Optional[np.random.Generator] = None,
) -> Dedup1Result:
    """Paper 'Greedy Real Nodes First' (Fig 8): per real node u, greedily
    select which virtual nodes u stays connected to (set-cover heuristic);
    u's duplicated memberships are dropped, patched by direct edges."""
    t0 = time.perf_counter()
    sets = [set(s) for s in _require_symmetric(graph)]
    rng = rng or np.random.default_rng(0)
    n_real = graph.n_real
    direct: Set[Tuple[int, int]] = set()
    member: List[Set[int]] = [set() for _ in range(n_real)]
    for vid, s in enumerate(sets):
        for x in s:
            member[x].add(vid)

    for u in _order(n_real, ordering, rng).tolist():
        vlist = sorted(member[u])
        if len(vlist) <= 1:
            continue
        # Universe: u's neighbors through its virtual nodes.
        covered: Set[int] = set()
        chosen: List[int] = []
        remaining = set(vlist)
        while remaining:
            best, best_gain = -1, 0
            for vid in sorted(remaining):
                gain = len((sets[vid] - {u}) - covered)
                if gain > best_gain:
                    best, best_gain = vid, gain
            if best < 0:
                break
            chosen.append(best)
            remaining.discard(best)
            covered |= sets[best] - {u}
        # u leaves every unchosen virtual node; patch pairs (u, w) that
        # were ONLY covered by an unchosen node.
        for vid in sorted(remaining):
            sets[vid].discard(u)
            member[u].discard(vid)
        # Now recompute u's coverage: duplicates among chosen still exist
        # for neighbors reachable via 2+ chosen nodes — greedy cover keeps
        # first-cover, drop u from later covers would break OTHER pairs;
        # instead shave per-pair: for each neighbor w covered twice, remove
        # w or u from one set and patch.
        seen: Dict[int, int] = {}
        for vid in chosen:
            for w in sorted(sets[vid] - {u}):
                if w not in seen:
                    seen[w] = vid
                    continue
                # duplicate (u, w) via seen[w] and vid: shave from the
                # smaller set, patch broken pairs.
                victim = vid if len(sets[vid]) <= len(sets[seen[w]]) else seen[w]
                r = u if len(sets[victim]) == 2 else (u if rng.integers(2) else w)
                # removing r from victim breaks r's pairs inside victim
                sets[victim].discard(r)
                member[r].discard(victim)
                for other in sorted(sets[victim]):
                    common = member[r] & member[other]
                    if not common and _pair(r, other) not in direct:
                        direct.add(_pair(r, other))
                if victim == seen[w]:
                    seen[w] = vid
    return _finalize(n_real, sets, direct, t0)


def dedup1_greedy_virtual_first(
    graph: CondensedGraph,
    ordering: str = "random",
    rng: Optional[np.random.Generator] = None,
) -> Dedup1Result:
    """Paper 'Greedy Virtual Nodes First' (Fig 9; used for Fig 10 DEDUP-1).

    Virtual nodes enter one at a time; overlaps |C_i| >= 2 against already
    placed nodes are shaved by repeatedly removing the real node with the
    best benefit/cost ratio (cost = direct edges added, benefit = overlap
    reduction across all conflicting nodes).
    """
    t0 = time.perf_counter()
    sets = [set(s) for s in _require_symmetric(graph)]
    rng = rng or np.random.default_rng(0)
    n_real = graph.n_real
    direct: Set[Tuple[int, int]] = set()
    member: List[Set[int]] = [set() for _ in range(n_real)]  # placed only
    placed: Set[int] = set()

    for vid in _order(len(sets), ordering, rng).tolist():
        V = sets[vid]
        while True:
            # Conflicting placed nodes and their intersections with V.
            counts: Dict[int, List[int]] = {}
            for u in sorted(V):
                for rid in member[u]:
                    counts.setdefault(rid, []).append(u)
            conflicts = {rid: c for rid, c in counts.items() if len(c) >= 2}
            if not conflicts:
                break
            # candidate removals: real r from V, or r from a conflicting rid
            best_ratio, best_action = -1.0, None
            cand_pool: List[Tuple[int, int]] = []
            for rid, inter in sorted(conflicts.items()):
                for r in inter:
                    cand_pool.append((rid, r))
            for rid, r in cand_pool:
                # Option A: remove r from V.
                benefit_a = sum(1 for rid2, it in conflicts.items() if r in it)
                cost_a = max(len(V) - 1, 1) - 0  # direct edges to patch
                # Patching only pairs not covered elsewhere — approximate
                # cost by |V|-1 (paper uses the same upper-bound flavor).
                ratio_a = benefit_a / max(cost_a, 1)
                # Option B: remove r from rid.
                benefit_b = 1.0
                cost_b = max(len(sets[rid]) - 1, 1)
                ratio_b = benefit_b / max(cost_b, 1)
                if ratio_a > best_ratio:
                    best_ratio, best_action = ratio_a, ("V", r, rid)
                if ratio_b > best_ratio:
                    best_ratio, best_action = ratio_b, ("R", r, rid)
            assert best_action is not None
            kind, r, rid = best_action
            if kind == "V":
                V.discard(r)
                for other in sorted(V):
                    common = member[r] & member[other]
                    if not common and _pair(r, other) not in direct:
                        direct.add(_pair(r, other))
            else:
                sets[rid].discard(r)
                member[r].discard(rid)
                for other in sorted(sets[rid]):
                    common = member[r] & member[other]
                    # may also be covered by V (about to be placed)
                    in_v = r in V and other in V
                    if not common and not in_v and _pair(r, other) not in direct:
                        direct.add(_pair(r, other))
        # place V
        for u in V:
            member[u].add(vid)
        placed.add(vid)
        # direct edges now covered by V are dropped
        for i, a in enumerate(sorted(V)):
            for b in sorted(V):
                if b > a:
                    direct.discard(_pair(a, b))
    return _finalize(n_real, sets, direct, t0)


# ---------------------------------------------------------------------------
# DEDUP-2 (App. B): symmetric single-layer with virtual-virtual edges.
# neighbors(u) = ⋃_{V ∋ u} [ (S_V − u) ∪ ⋃_{W ~ V} S_W ]
# Invariants: (1) |S_V ∩ S_W| <= 1 for all V, W;
#             (2) adjacent virtual nodes are disjoint;
#             (3) the virtual neighbors of any V are pairwise disjoint;
#             (4) every pair covered exactly once overall.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Dedup2Rep:
    n_real: int
    sets: List[Set[int]]
    vv_edges: Set[Tuple[int, int]]  # undirected virtual-virtual edges
    seconds: float = 0.0

    def neighbor_lists(self) -> List[Set[int]]:
        adj: List[Set[int]] = [set() for _ in range(self.n_real)]
        vadj: Dict[int, Set[int]] = {}
        for a, b in self.vv_edges:
            vadj.setdefault(a, set()).add(b)
            vadj.setdefault(b, set()).add(a)
        for vid, s in enumerate(self.sets):
            for u in s:
                adj[u] |= s - {u}
                for w in vadj.get(vid, ()):
                    adj[u] |= self.sets[w]
        return adj

    def pair_multiplicities(self) -> Dict[Tuple[int, int], int]:
        mult: Dict[Tuple[int, int], int] = {}
        vadj: Dict[int, Set[int]] = {}
        for a, b in self.vv_edges:
            vadj.setdefault(a, set()).add(b)
            vadj.setdefault(b, set()).add(a)
        for vid, s in enumerate(self.sets):
            ss = sorted(s)
            for i, a in enumerate(ss):
                for b in ss[i + 1 :]:
                    p = _pair(a, b)
                    mult[p] = mult.get(p, 0) + 1
            for w in vadj.get(vid, ()):
                if w < vid:
                    continue  # count each vv edge once
                for a in sorted(s):
                    for b in sorted(self.sets[w]):
                        if a == b:
                            continue
                        p = _pair(a, b)
                        mult[p] = mult.get(p, 0) + 1
        return mult

    @property
    def n_edges(self) -> int:
        return sum(len(s) for s in self.sets) + len(self.vv_edges)

    def nbytes(self) -> int:
        return self.n_edges * 16




def dedup2_greedy(
    graph: CondensedGraph,
    ordering: str = "identity",
    rng: Optional[np.random.Generator] = None,
) -> Dedup2Rep:
    """Greedy DEDUP-2 construction (App. B flavor), monotone-coverage variant.

    Virtual nodes are placed one at a time.  When the incoming set ``V``
    overlaps an already-placed set ``P`` in >= 2 members, ``P`` is *split*
    into ``(V∩P, P−V)`` joined by a vv-edge — a transformation that keeps
    the covered-pair set and all invariants exactly intact (both halves
    inherit P's vv-edges) — and the remainder ``V − P`` is placed
    recursively and linked back when legal.  Singleton virtual nodes (the
    paper's device) carry vv-edges for 1-member remainders; leftover pairs
    fall back to 2-member pair-sets.

    Invariants maintained throughout (checked by tests):
      (1) |S_V ∩ S_W| <= 1 for all non-adjacent placed V, W
      (2) adjacent virtual nodes are disjoint
      (3) the virtual neighbors of any V are pairwise disjoint
      (4) every expanded pair is covered exactly once
    """
    t0 = time.perf_counter()
    orig = [set(s) for s in _require_symmetric(graph)]
    rng = rng or np.random.default_rng(0)
    n_real = graph.n_real

    placed: List[Set[int]] = []
    vadj: List[Set[int]] = []  # vv adjacency by placed id
    covered: Set[Tuple[int, int]] = set()

    def pairs_of(s: Set[int]) -> List[Tuple[int, int]]:
        ss = sorted(s)
        return [(a, b) for i, a in enumerate(ss) for b in ss[i + 1 :]]

    def add_node(s: Set[int], cover: bool = True) -> int:
        placed.append(set(s))
        vadj.append(set())
        if cover:
            covered.update(pairs_of(s))
        return len(placed) - 1

    def can_link(i: int, j: int) -> bool:
        a, b = placed[i], placed[j]
        if i == j or a & b:
            return False  # invariant (2)
        if j in vadj[i]:
            return False
        for w in vadj[i]:
            if placed[w] & b:
                return False  # invariant (3) at i
        for w in vadj[j]:
            if placed[w] & a:
                return False  # invariant (3) at j
        return all(
            _pair(x, y) not in covered for x in a for y in b
        )

    def link(i: int, j: int) -> None:
        vadj[i].add(j)
        vadj[j].add(i)
        covered.update(_pair(x, y) for x in placed[i] for y in placed[j])

    def split(i: int, w1: Set[int]) -> int:
        """Split placed[i] into (w1, rest) + vv edge; coverage unchanged."""
        rest = placed[i] - w1
        assert rest, "split requires a proper subset"
        placed[i] = set(w1)
        j = add_node(rest, cover=False)
        old_nbrs = list(vadj[i])
        vadj[i].add(j)
        vadj[j].add(i)
        for w in old_nbrs:
            vadj[j].add(w)
            vadj[w].add(j)
        return i

    def cover_cross(a: Set[int], b: Set[int]) -> None:
        for x in sorted(a):
            for y in sorted(b):
                if x != y and _pair(x, y) not in covered:
                    add_node({x, y})

    def place(V: Set[int]) -> Optional[int]:
        """Cover all pairs of V; return a placed id whose set == V if one
        exists afterwards, else None."""
        if not V:
            return None
        if len(V) == 1:
            return add_node(V)  # singleton (covers nothing; may carry edges)
        # Largest >= 2 overlap with a placed node.
        best, best_ov = -1, 1
        for i, s in enumerate(placed):
            ov = len(V & s)
            if ov > best_ov:
                best, best_ov = i, ov
        if best < 0:
            if all(p not in covered for p in pairs_of(V)):
                return add_node(V)
            for p in pairs_of(V):
                if p not in covered:
                    add_node(set(p))
            return None
        W1 = V & placed[best]
        w1_id = best if placed[best] == W1 else split(best, W1)
        rest = V - W1
        if not rest:
            return w1_id
        r_id = place(rest)
        if r_id is not None and can_link(r_id, w1_id):
            link(r_id, w1_id)
        else:
            cover_cross(W1, rest)
        return None

    for vid in _order(len(orig), ordering, rng).tolist():
        place(orig[vid])

    # Drop empty sets and edge-less singletons; remap vv edges.
    keep = [
        i
        for i, s in enumerate(placed)
        if len(s) >= 2 or (len(s) == 1 and vadj[i])
    ]
    remap = {old: new for new, old in enumerate(keep)}
    vv_out: Set[Tuple[int, int]] = set()
    for i in keep:
        for j in vadj[i]:
            if j in remap:
                vv_out.add(_pair(remap[i], remap[j]))
    return Dedup2Rep(
        n_real=n_real,
        sets=[set(placed[i]) for i in keep],
        vv_edges=vv_out,
        seconds=time.perf_counter() - t0,
    )
