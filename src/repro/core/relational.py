"""Columnar in-memory relational store with catalog statistics.

This is the paper's "RDBMS" substrate (GraphGen sits on PostgreSQL; here we
implement the minimal relational layer the extraction planner needs: tables
as named NumPy columns, key/foreign-key hash joins, projections, selections,
and pg_stats-style ``n_distinct`` statistics used by the large-output-join
detector in :mod:`repro.core.planner`).

Everything is columnar so that join results feed straight into the
condensed-graph edge arrays without row materialization.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "Table",
    "Catalog",
    "ShardedTable",
    "hash_join",
    "semi_join",
    "shard_bounds",
    "hash_partition",
]


@dataclasses.dataclass
class ColumnStats:
    """pg_stats analog for one column."""

    n_distinct: int
    min_value: Optional[float] = None
    max_value: Optional[float] = None
    null_frac: float = 0.0
    # Most-common-value frequency: the largest number of rows sharing one
    # value.  Gives a *sound* per-row join fan-out bound (a probe row can
    # match at most max_count build rows), which the extraction cost model
    # needs for budget-feasibility pruning where the |R||S|/d estimate is
    # only an expectation.
    max_count: int = 1


class Table:
    """An immutable named collection of equal-length columns."""

    def __init__(self, name: str, columns: Mapping[str, np.ndarray]):
        if not columns:
            raise ValueError(f"table {name!r} needs at least one column")
        lengths = {len(v) for v in columns.values()}
        if len(lengths) != 1:
            raise ValueError(f"ragged columns in table {name!r}: {lengths}")
        self.name = name
        self.columns: Dict[str, np.ndarray] = {
            k: np.asarray(v) for k, v in columns.items()
        }
        self._stats: Dict[str, ColumnStats] = {}

    # -- basic relational ops -------------------------------------------------
    def __len__(self) -> int:
        return len(next(iter(self.columns.values())))

    @property
    def column_names(self) -> List[str]:
        return list(self.columns)

    def column(self, name: str) -> np.ndarray:
        try:
            return self.columns[name]
        except KeyError:
            raise KeyError(
                f"table {self.name!r} has no column {name!r}; has {self.column_names}"
            ) from None

    def project(self, names: Sequence[str]) -> "Table":
        return Table(self.name, {n: self.column(n) for n in names})

    def select(self, predicate: Callable[[Dict[str, np.ndarray]], np.ndarray]) -> "Table":
        mask = np.asarray(predicate(self.columns), dtype=bool)
        return Table(self.name, {k: v[mask] for k, v in self.columns.items()})

    def rename(self, mapping: Mapping[str, str]) -> "Table":
        return Table(
            self.name, {mapping.get(k, k): v for k, v in self.columns.items()}
        )

    def head(self, n: int = 5) -> Dict[str, np.ndarray]:
        return {k: v[:n] for k, v in self.columns.items()}

    def row_slice(self, lo: int, hi: int) -> "Table":
        """Contiguous row block ``[lo, hi)`` as a new table (view columns)."""
        return Table(self.name, {k: v[lo:hi] for k, v in self.columns.items()})

    # -- statistics ------------------------------------------------------------
    def analyze(self) -> None:
        """Populate catalog statistics (ANALYZE)."""
        for name, col in self.columns.items():
            uniq, counts = np.unique(col, return_counts=True)
            numeric = np.issubdtype(col.dtype, np.number)
            self._stats[name] = ColumnStats(
                n_distinct=int(uniq.size),
                min_value=float(col.min()) if numeric and col.size else None,
                max_value=float(col.max()) if numeric and col.size else None,
                max_count=int(counts.max()) if counts.size else 0,
            )

    def stats(self, column: str) -> ColumnStats:
        if column not in self._stats:
            self.analyze()
        return self._stats[column]

    def nbytes(self) -> int:
        return sum(int(v.nbytes) for v in self.columns.values())

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"Table({self.name!r}, rows={len(self)}, cols={self.column_names})"


class Catalog:
    """A named collection of tables; the "database" handed to the DSL."""

    def __init__(self, tables: Iterable[Table] = ()):  # noqa: D401
        self._tables: Dict[str, Table] = {}
        for t in tables:
            self.add(t)

    def add(self, table: Table) -> None:
        self._tables[table.name.lower()] = table

    def table(self, name: str) -> Table:
        try:
            return self._tables[name.lower()]
        except KeyError:
            raise KeyError(
                f"no table {name!r}; catalog has {sorted(self._tables)}"
            ) from None

    def __contains__(self, name: str) -> bool:
        return name.lower() in self._tables

    @property
    def table_names(self) -> List[str]:
        return sorted(self._tables)

    def nbytes(self) -> int:
        return sum(t.nbytes() for t in self._tables.values())


# ---------------------------------------------------------------------------
# Sharded table views (DESIGN.md §7).
# ---------------------------------------------------------------------------

def shard_bounds(n_rows: int, n_shards: int) -> List[Tuple[int, int]]:
    """Contiguous row-block boundaries for ``n_shards`` shards.

    Always returns exactly ``n_shards`` blocks: the last block is ragged
    when ``n_rows % n_shards != 0`` and trailing blocks are empty when
    ``n_shards > n_rows`` — callers (the sharded extraction pipeline,
    DESIGN.md §7) rely on the fixed shard count, and concatenating the
    blocks in order reproduces ``range(n_rows)`` exactly.
    """
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    width = -(-n_rows // n_shards) if n_rows else 0
    out = []
    for s in range(n_shards):
        lo = min(s * width, n_rows)
        out.append((lo, min(lo + width, n_rows)))
    return out


def _hash_codes(values: np.ndarray) -> np.ndarray:
    """Value-determined uint64 codes: equal values get equal codes no
    matter which array they appear in.  This is what makes the
    :func:`hash_partition` contract *cross-table* — rank-based codes
    (``searchsorted`` against the array's own unique values) would send
    the same key to different shards of different tables."""
    values = np.asarray(values)
    if values.size == 0:
        return np.zeros(0, dtype=np.uint64)
    if np.issubdtype(values.dtype, np.integer):
        return values.astype(np.int64).view(np.uint64)
    if np.issubdtype(values.dtype, np.floating):
        return values.astype(np.float64).view(np.uint64)
    # fixed-width unicode/bytes: FNV-1a folded over the code units
    u = np.ascontiguousarray(np.asarray(values, dtype=np.str_))
    width = max(u.dtype.itemsize // 4, 1)
    units = u.view(np.uint32).reshape(u.size, width).astype(np.uint64)
    h = np.full(u.size, np.uint64(14695981039346656037))
    for col in units.T:
        h = (h ^ col) * np.uint64(1099511628211)
    return h


def hash_partition(values: np.ndarray, n_shards: int) -> np.ndarray:
    """Shard id per value: a multiplicative hash of value-determined codes.

    Equal values always land in the same shard *across arrays* (the
    join-key contract: partitioning both join sides this way makes
    per-shard joins exhaustive), because the codes depend only on the
    value itself (:func:`_hash_codes`) — never on the surrounding array.
    """
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    codes = _hash_codes(values)
    # Knuth multiplicative hash; spreads consecutive keys across shards.
    mixed = (codes * np.uint64(2654435761)) >> np.uint64(16)
    return (mixed % np.uint64(n_shards)).astype(np.int64)


class ShardedTable:
    """A :class:`Table` partitioned into row shards, with per-shard stats.

    Two partitioning modes (DESIGN.md §7):

    * ``'rows'`` (default) — contiguous row blocks via :func:`shard_bounds`.
      Order-preserving: concatenating the shards in order reproduces the
      base table row-for-row, which is what lets the sharded extraction
      merge step rebuild a byte-identical ``CondensedGraph``.
    * ``'hash'`` — rows bucketed by :func:`hash_partition` of ``key``
      (pg-style hash partitioning on a join key).  Equal keys are co-located
      so per-shard joins against an identically partitioned table are
      exhaustive; row order is *not* preserved across shards.

    Per-shard ``ColumnStats`` come from :meth:`stats` — the planner's
    global estimates stay on the base table, but shard-local cardinalities
    are what a per-shard budget planner needs.
    """

    def __init__(self, table: Table, n_shards: int, mode: str = "rows",
                 key: Optional[str] = None):
        if mode not in ("rows", "hash"):
            raise ValueError(f"unknown shard mode {mode!r}")
        if mode == "hash" and key is None:
            raise ValueError("hash partitioning needs a key column")
        self.table = table
        self.n_shards = int(n_shards)
        self.mode = mode
        self.key = key
        if mode == "rows":
            self._bounds = shard_bounds(len(table), self.n_shards)
            self._masks: Optional[List[np.ndarray]] = None
        else:
            sid = hash_partition(table.column(key), self.n_shards)
            self._bounds = None
            self._masks = [sid == s for s in range(self.n_shards)]
        self._shards: Dict[int, Table] = {}

    def __len__(self) -> int:
        return self.n_shards

    def shard(self, s: int) -> Table:
        if not 0 <= s < self.n_shards:
            raise IndexError(f"shard {s} out of range [0, {self.n_shards})")
        if s not in self._shards:
            if self._bounds is not None:
                lo, hi = self._bounds[s]
                self._shards[s] = self.table.row_slice(lo, hi)
            else:
                mask = self._masks[s]
                self._shards[s] = Table(
                    self.table.name,
                    {k: v[mask] for k, v in self.table.columns.items()},
                )
        return self._shards[s]

    def __iter__(self) -> Iterable[Table]:
        return (self.shard(s) for s in range(self.n_shards))

    def shard_rows(self, s: int) -> int:
        if self._bounds is not None:
            lo, hi = self._bounds[s]
            return hi - lo
        return int(self._masks[s].sum())

    def stats(self, s: int, column: str) -> ColumnStats:
        """Per-shard pg_stats: ``ANALYZE`` scoped to one shard."""
        return self.shard(s).stats(column)


# ---------------------------------------------------------------------------
# Joins. Columnar hash joins over integer or string key columns.
# ---------------------------------------------------------------------------

def _factorize(*cols: np.ndarray) -> Tuple[np.ndarray, ...]:
    """Map the union of values in ``cols`` to dense int codes."""
    union = np.unique(np.concatenate([np.asarray(c) for c in cols]))
    return tuple(np.searchsorted(union, np.asarray(c)) for c in cols)


def hash_join(
    left: Table,
    right: Table,
    left_on: str,
    right_on: str,
    suffixes: Tuple[str, str] = ("_l", "_r"),
) -> Table:
    """Inner equi-join, returning a new table with all columns of both sides.

    Output-size faithful: materializes every matching pair (this is the
    expensive operation the condensed representation avoids for
    large-output joins).
    """
    lkey, rkey = _factorize(left.column(left_on), right.column(right_on))
    order = np.argsort(rkey, kind="stable")
    rkey_sorted = rkey[order]
    # For every left row, the contiguous run of matching right rows.
    starts = np.searchsorted(rkey_sorted, lkey, side="left")
    ends = np.searchsorted(rkey_sorted, lkey, side="right")
    counts = ends - starts
    lidx = np.repeat(np.arange(len(left)), counts)
    # Offsets into each run.
    total = int(counts.sum())
    if total:
        run_offsets = np.arange(total) - np.repeat(
            np.cumsum(counts) - counts, counts
        )
        ridx = order[np.repeat(starts, counts) + run_offsets]
    else:
        ridx = np.empty(0, dtype=np.int64)

    out: Dict[str, np.ndarray] = {}
    same_key = left_on == right_on
    for k, v in left.columns.items():
        if same_key and k == left_on:
            out[k] = v[lidx]  # canonical single copy of the join key
        else:
            out[k if k not in right.columns else k + suffixes[0]] = v[lidx]
    for k, v in right.columns.items():
        if same_key and k == right_on:
            continue
        out[k if k not in left.columns else k + suffixes[1]] = v[ridx]
    return Table(f"{left.name}_join_{right.name}", out)


def semi_join(left: Table, right: Table, left_on: str, right_on: str) -> Table:
    """Rows of ``left`` with at least one match in ``right`` (no blow-up)."""
    lkey, rkey = _factorize(left.column(left_on), right.column(right_on))
    mask = np.isin(lkey, np.unique(rkey))
    return Table(left.name, {k: v[mask] for k, v in left.columns.items()})


def estimate_join_output(
    left: Table, right: Table, left_on: str, right_on: str
) -> float:
    """Uniform-distribution join size estimate |R||S|/max(d_l, d_r).

    This is the estimator the paper's Step 2 uses (``n_distinct`` from
    pg_stats); deliberately simple and replaceable.
    """
    d = max(left.stats(left_on).n_distinct, right.stats(right_on).n_distinct, 1)
    return len(left) * len(right) / d
