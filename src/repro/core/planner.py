"""Extraction planning: chain ordering + large-output join detection (§3.3, §4.2).

For each Edges rule the planner:

1. orders the body atoms into a join chain from the atom binding ``ID1``
   to the atom binding ``ID2`` (acyclic conjunctive queries; Case 1 of the
   paper — Case 2 falls back to full expansion);
2. estimates every join's output with catalog ``n_distinct`` statistics and
   marks it *large-output* iff  ``|R||S|/d > 2(|R|+|S|)``  (paper Step 2);
3. splits the chain into segments at large-output joins — each segment is
   executed eagerly (hash joins; "handed to the database"), each postponed
   join attribute becomes a virtual-node layer.

``mode`` overrides: ``"condensed"`` postpones every join (paper Fig 5a),
``"expanded"`` postpones none (EXP extraction), ``"auto"`` uses the stats.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .dsl import Atom, Comparison, Rule
from .relational import Catalog, Table, hash_join

__all__ = ["ChainPlan", "plan_rule", "bind_atom", "execute_segment"]


@dataclasses.dataclass
class ChainPlan:
    rule: Rule
    atoms: List[Atom]            # chain order
    link_vars: List[str]         # join variable between consecutive atoms
    large: List[bool]            # per link: postponed (virtual layer)?
    est_sizes: List[float]       # per link: estimated join output rows
    segments: List[Tuple[int, int]]  # inclusive atom index ranges
    endpoint_vars: Tuple[str, str]   # (ID1 var, ID2 var)

    @property
    def n_virtual_layers(self) -> int:
        return sum(self.large)

    def describe(self) -> str:
        parts = []
        for i, a in enumerate(self.atoms):
            parts.append(a.relation)
            if i < len(self.link_vars):
                tag = "**" if self.large[i] else ""
                parts.append(f"-[{self.link_vars[i]}{tag}]-")
        return " ".join(parts)


def _chain_order(rule: Rule) -> Tuple[List[Atom], List[str]]:
    """Order atoms into a chain ID1 ~> ID2 (backtracking Hamiltonian path)."""
    id1, id2 = rule.head_vars[0], rule.head_vars[1]
    atoms = list(rule.atoms)
    if len(atoms) == 1:
        a = atoms[0]
        if id1 in a.variables() and id2 in a.variables():
            return atoms, []
        raise ValueError(f"single atom must bind both {id1} and {id2}")

    starts = [i for i, a in enumerate(atoms) if id1 in a.variables()]
    if not starts:
        raise ValueError(f"no atom binds {id1}")

    def shared(a: Atom, b: Atom) -> List[str]:
        return [v for v in a.variables() if v in b.variables()]

    def backtrack(path: List[int], links: List[str]) -> Optional[Tuple[List[int], List[str]]]:
        if len(path) == len(atoms):
            if id2 in atoms[path[-1]].variables():
                return path, links
            return None
        last = atoms[path[-1]]
        for j in range(len(atoms)):
            if j in path:
                continue
            for v in shared(last, atoms[j]):
                res = backtrack(path + [j], links + [v])
                if res:
                    return res
        return None

    for s in starts:
        res = backtrack([s], [])
        if res:
            order, links = res
            return [atoms[i] for i in order], links
    raise ValueError(
        f"atoms of rule do not form a chain from {id1} to {id2} "
        "(cyclic or disconnected query — paper Case 2); "
        "use mode='expanded'"
    )


def bind_atom(catalog: Catalog, atom: Atom, comparisons: Sequence[Comparison]) -> Table:
    """Materialize an atom: positional column->variable binding + selections."""
    table = catalog.table(atom.relation)
    cols = table.column_names
    if len(atom.args) != len(cols):
        raise ValueError(
            f"atom {atom.relation}/{len(atom.args)} does not match table "
            f"arity {len(cols)} ({cols})"
        )
    mask = np.ones(len(table), dtype=bool)
    for pos, value in atom.constants:
        mask &= table.column(cols[pos]) == value
    var_cols: Dict[str, np.ndarray] = {}
    for var, col in zip(atom.args, cols):
        if var == "_":
            continue
        if var in var_cols:
            mask &= table.column(col) == var_cols[var]  # R(x, x) equality
            continue
        var_cols[var] = table.column(col)
    for cmp_ in comparisons:
        if cmp_.var in var_cols:
            mask &= np.asarray(cmp_.apply(var_cols[cmp_.var]), dtype=bool)
    out = Table(atom.relation, {v: c[mask] for v, c in var_cols.items()})
    return out


def plan_rule(catalog: Catalog, rule: Rule, mode: str = "auto") -> ChainPlan:
    if rule.kind != "edges":
        raise ValueError("plan_rule plans Edges rules")
    atoms, links = _chain_order(rule)
    id1, id2 = rule.head_vars[0], rule.head_vars[1]

    large: List[bool] = []
    est: List[float] = []
    for i, v in enumerate(links):
        lt = bind_atom(catalog, atoms[i], rule.comparisons)
        rt = bind_atom(catalog, atoms[i + 1], rule.comparisons)
        d = max(lt.stats(v).n_distinct, rt.stats(v).n_distinct, 1)
        size = len(lt) * len(rt) / d
        est.append(size)
        if mode == "condensed":
            large.append(True)
        elif mode == "expanded":
            large.append(False)
        else:
            large.append(size > 2 * (len(lt) + len(rt)))

    segments: List[Tuple[int, int]] = []
    start = 0
    for i, is_large in enumerate(large):
        if is_large:
            segments.append((start, i))
            start = i + 1
    segments.append((start, len(atoms) - 1))
    return ChainPlan(
        rule=rule,
        atoms=atoms,
        link_vars=links,
        large=large,
        est_sizes=est,
        segments=segments,
        endpoint_vars=(id1, id2),
    )


def execute_segment(
    catalog: Catalog,
    plan: ChainPlan,
    seg: Tuple[int, int],
    in_var: str,
    out_var: str,
) -> Tuple[np.ndarray, np.ndarray]:
    """Run one small-output segment eagerly; returns (in_values, out_values).

    This is the part the paper "hands to the database": a sequence of
    small-output hash joins, projected down to the segment endpoints.
    """
    i, j = seg
    acc = bind_atom(catalog, plan.atoms[i], plan.rule.comparisons)
    for k in range(i + 1, j + 1):
        nxt = bind_atom(catalog, plan.atoms[k], plan.rule.comparisons)
        acc = hash_join(acc, nxt, plan.link_vars[k - 1], plan.link_vars[k - 1])
    if in_var not in acc.column_names or out_var not in acc.column_names:
        raise ValueError(
            f"segment {seg} missing endpoint vars {in_var}/{out_var}; "
            f"has {acc.column_names}"
        )
    return acc.column(in_var), acc.column(out_var)
