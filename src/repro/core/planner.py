"""Extraction planning: chain ordering + large-output join detection (§3.3, §4.2).

For each Edges rule the planner:

1. orders the body atoms into a join chain from the atom binding ``ID1``
   to the atom binding ``ID2`` (acyclic conjunctive queries; Case 1 of the
   paper — Case 2 falls back to full expansion);
2. estimates every join's output with catalog ``n_distinct`` statistics and
   marks it *large-output* iff  ``|R||S|/d > 2(|R|+|S|)``  (paper Step 2);
3. splits the chain into segments at large-output joins — each segment is
   executed eagerly (hash joins; "handed to the database"), each postponed
   join attribute becomes a virtual-node layer.

``mode`` overrides: ``"condensed"`` postpones every join (paper Fig 5a),
``"expanded"`` postpones none (EXP extraction), ``"auto"`` uses the stats.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .dsl import Atom, Comparison, Rule
from .relational import Catalog, Table, hash_join

__all__ = [
    "ChainPlan",
    "plan_rule",
    "bind_atom",
    "execute_segment",
    "execute_segment_sharded",
    "execute_segment_shard",
    "ExtractionBudget",
    "ExtractionBudgetError",
]


class ExtractionBudgetError(RuntimeError):
    """Raised when a shard's resident working set exceeds the budget.

    Per-shard transients (``max_resident_rows``) never spill: a violated
    budget aborts extraction so the caller can re-shard (more shards =
    smaller blocks) instead of quietly blowing host memory (DESIGN.md §7).
    Assembly buffers (``max_assembly_bytes``) raise only when no
    ``spill_dir`` was given — with one, the pipeline spills each shard's
    output to disk as the shard finishes instead (DESIGN.md §8).
    """


@dataclasses.dataclass
class ExtractionBudget:
    """Peak-resident accounting for sharded extraction (DESIGN.md §7/§8).

    The sharded-extraction analog of ``ExpansionAccounting``
    (:mod:`repro.core.condensed`): one instance is threaded through the
    node-space build and every per-shard segment execution, charging each
    transient host array (bound atom blocks, filtered probe sides, join
    outputs) while it is resident.  ``peak_resident_rows`` is therefore an
    upper bound on the rows any single shard holds at once — the quantity
    that must stay bounded for larger-than-memory extraction.

    Two accounts, two units:

    * **Per-shard transients** (rows) — charged by :meth:`charge`,
      capped by ``max_resident_rows``.  A violating charge raises
      :class:`ExtractionBudgetError` immediately; transients never spill.
    * **Assembly buffers** (bytes) — each shard's *output* (the edge /
      key arrays awaiting the merge) charged by :meth:`charge_assembly`
      while resident, capped by ``max_assembly_bytes``.  Without a spill
      directory the outputs of every shard accumulate until the merge,
      so ``peak_assembly_bytes`` grows with shard count and a cap
      violation raises; with ``spill_enabled`` (the ``spill_dir=`` knob,
      DESIGN.md §8) each shard's output is written to disk and released
      as the shard finishes, so the peak stays bounded by roughly one
      shard's output no matter how many shards run, and ``spilled_bytes``
      records what went to disk instead.  Merge-phase residency (the
      tree-reduce operands) is *reported* in
      ``merge_peak_resident_bytes`` / ``n_merge_rounds`` but not capped:
      the final round's output is the condensed graph itself, which must
      fit by definition.
    """

    max_resident_rows: Optional[int] = None
    resident_rows: int = 0           # live: rows currently charged
    peak_resident_rows: int = 0      # max resident_rows ever observed
    n_shards_processed: int = 0
    n_segments_executed: int = 0
    n_rows_joined: int = 0           # total join-output rows across shards
    shard_peaks: List[int] = dataclasses.field(default_factory=list)
    _shard_peak: int = 0
    # -- assembly-buffer account (bytes; DESIGN.md §8) -------------------
    max_assembly_bytes: Optional[int] = None
    spill_enabled: bool = False      # set by the pipeline when spill_dir given
    resident_assembly_bytes: int = 0
    peak_assembly_bytes: int = 0
    spilled_bytes: int = 0           # total bytes written to spill records
    n_spilled_records: int = 0
    merge_peak_resident_bytes: int = 0  # max operand+output bytes in one merge group
    n_merge_rounds: int = 0
    # -- incremental-extraction account (core/delta.py; DESIGN.md §9) ----
    n_delta_applies: int = 0
    delta_rows_inserted: int = 0     # insert rows bound across applies
    delta_rows_deleted: int = 0      # tombstoned rows across applies
    delta_rules_reused: int = 0      # Edges rules reused verbatim
    delta_rules_recomputed: int = 0  # Edges rules re-planned/re-executed

    def charge_delta(self, n_inserted: int, n_deleted: int) -> None:
        """Record one :func:`repro.core.delta.apply_delta` pass.  Delta
        binds and recomputed segments go through the same :meth:`charge` /
        :meth:`release` rows account as sharded extraction; these counters
        only record how much write traffic the live graph absorbed and
        how much cached work each apply salvaged."""
        self.n_delta_applies += 1
        self.delta_rows_inserted += int(n_inserted)
        self.delta_rows_deleted += int(n_deleted)

    def charge(self, n_rows: int, what: str = "rows") -> None:
        self.resident_rows += int(n_rows)
        if self.resident_rows > self.peak_resident_rows:
            self.peak_resident_rows = self.resident_rows
        if self.resident_rows > self._shard_peak:
            self._shard_peak = self.resident_rows
        if (
            self.max_resident_rows is not None
            and self.resident_rows > self.max_resident_rows
        ):
            raise ExtractionBudgetError(
                f"extraction budget exceeded: {self.resident_rows} resident "
                f"rows ({what}) > max_resident_rows={self.max_resident_rows}; "
                "increase the budget or extract with more shards"
            )

    def release(self, n_rows: int) -> None:
        self.resident_rows -= int(n_rows)

    def charge_assembly(
        self, n_bytes: int, what: str = "assembly buffer",
        spilling: bool = False,
    ) -> None:
        """Charge bytes of shard output held resident awaiting the merge.

        Raises :class:`ExtractionBudgetError` past ``max_assembly_bytes``
        unless the charging pipeline is spilling (``spilling=True``) — a
        spilling caller bounds residency by writing the buffer out and
        releasing it, so the cap is enforced by construction rather than
        by raising (a single shard output larger than the cap still
        raises: it must be resident to be built; use more shards).
        ``spilling`` is strictly per-call — the ``spill_enabled`` field
        is bookkeeping for :meth:`summary`, never an enforcement switch —
        so a budget that came out of a spilled run and is reused on a
        later non-spilling run keeps the cap enforced.
        """
        self.resident_assembly_bytes += int(n_bytes)
        if self.resident_assembly_bytes > self.peak_assembly_bytes:
            self.peak_assembly_bytes = self.resident_assembly_bytes
        if (
            self.max_assembly_bytes is not None
            and self.resident_assembly_bytes > self.max_assembly_bytes
        ):
            if not spilling:
                raise ExtractionBudgetError(
                    f"assembly budget exceeded: {self.resident_assembly_bytes} "
                    f"resident assembly bytes ({what}) > max_assembly_bytes="
                    f"{self.max_assembly_bytes}; pass spill_dir= to assemble "
                    "out of core, or raise the budget"
                )
            if int(n_bytes) > self.max_assembly_bytes:
                raise ExtractionBudgetError(
                    f"assembly budget unsatisfiable: a single {what} of "
                    f"{n_bytes} bytes exceeds max_assembly_bytes="
                    f"{self.max_assembly_bytes} even with spilling; "
                    "extract with more shards"
                )

    def release_assembly(self, n_bytes: int) -> None:
        self.resident_assembly_bytes -= int(n_bytes)

    def note_spill(self, n_bytes: int) -> None:
        """Record bytes handed off to a spill record (disk, not RAM)."""
        self.spilled_bytes += int(n_bytes)
        self.n_spilled_records += 1

    def note_merge(self, n_bytes: int) -> None:
        """Record one merge group's operand + output residency."""
        if int(n_bytes) > self.merge_peak_resident_bytes:
            self.merge_peak_resident_bytes = int(n_bytes)

    def begin_shard(self) -> None:
        self._shard_peak = self.resident_rows

    def end_shard(self) -> None:
        self.n_shards_processed += 1
        self.shard_peaks.append(self._shard_peak)
        self._shard_peak = self.resident_rows

    def summary(self) -> Dict[str, object]:
        out: Dict[str, object] = {
            "max_resident_rows": self.max_resident_rows,
            "peak_resident_rows": self.peak_resident_rows,
            "n_shards_processed": self.n_shards_processed,
            "n_segments_executed": self.n_segments_executed,
            "n_rows_joined": self.n_rows_joined,
            "peak_assembly_bytes": self.peak_assembly_bytes,
        }
        if self.max_assembly_bytes is not None:
            out["max_assembly_bytes"] = self.max_assembly_bytes
        if self.spill_enabled or self.spilled_bytes:
            out["spilled_bytes"] = self.spilled_bytes
            out["n_spilled_records"] = self.n_spilled_records
            out["n_merge_rounds"] = self.n_merge_rounds
            out["merge_peak_resident_bytes"] = self.merge_peak_resident_bytes
        if self.n_delta_applies:
            out["n_delta_applies"] = self.n_delta_applies
            out["delta_rows_inserted"] = self.delta_rows_inserted
            out["delta_rows_deleted"] = self.delta_rows_deleted
            out["delta_rules_reused"] = self.delta_rules_reused
            out["delta_rules_recomputed"] = self.delta_rules_recomputed
        return out


@dataclasses.dataclass
class ChainPlan:
    """One Edges rule's executable plan (paper §3.3/§4.2 Step 2): the
    chain-ordered atoms, the per-link large-output decisions, and the
    eager segments between postponed joins."""

    rule: Rule
    atoms: List[Atom]            # chain order
    link_vars: List[str]         # join variable between consecutive atoms
    large: List[bool]            # per link: postponed (virtual layer)?
    est_sizes: List[float]       # per link: estimated join output rows
    segments: List[Tuple[int, int]]  # inclusive atom index ranges
    endpoint_vars: Tuple[str, str]   # (ID1 var, ID2 var)

    @property
    def n_virtual_layers(self) -> int:
        return sum(self.large)

    def describe(self) -> str:
        parts = []
        for i, a in enumerate(self.atoms):
            parts.append(a.relation)
            if i < len(self.link_vars):
                tag = "**" if self.large[i] else ""
                parts.append(f"-[{self.link_vars[i]}{tag}]-")
        return " ".join(parts)


def _chain_order(rule: Rule) -> Tuple[List[Atom], List[str]]:
    """Order atoms into a chain ID1 ~> ID2 (backtracking Hamiltonian path)."""
    id1, id2 = rule.head_vars[0], rule.head_vars[1]
    atoms = list(rule.atoms)
    if len(atoms) == 1:
        a = atoms[0]
        if id1 in a.variables() and id2 in a.variables():
            return atoms, []
        raise ValueError(f"single atom must bind both {id1} and {id2}")

    starts = [i for i, a in enumerate(atoms) if id1 in a.variables()]
    if not starts:
        raise ValueError(f"no atom binds {id1}")

    def shared(a: Atom, b: Atom) -> List[str]:
        return [v for v in a.variables() if v in b.variables()]

    def backtrack(path: List[int], links: List[str]) -> Optional[Tuple[List[int], List[str]]]:
        if len(path) == len(atoms):
            if id2 in atoms[path[-1]].variables():
                return path, links
            return None
        last = atoms[path[-1]]
        for j in range(len(atoms)):
            if j in path:
                continue
            for v in shared(last, atoms[j]):
                res = backtrack(path + [j], links + [v])
                if res:
                    return res
        return None

    for s in starts:
        res = backtrack([s], [])
        if res:
            order, links = res
            return [atoms[i] for i in order], links
    raise ValueError(
        f"atoms of rule do not form a chain from {id1} to {id2} "
        "(cyclic or disconnected query — paper Case 2); "
        "use mode='expanded'"
    )


def bind_atom(catalog: Catalog, atom: Atom, comparisons: Sequence[Comparison]) -> Table:
    """Materialize an atom (paper §4.2 Step 1/3): positional column ->
    variable binding, constant/equality selections, and the rule's
    comparison predicates pushed down to the base relation scan."""
    return _bind_table(catalog.table(atom.relation), atom, comparisons)


def _bind_table(
    table: Table, atom: Atom, comparisons: Sequence[Comparison]
) -> Table:
    """:func:`bind_atom` against an explicit table — every binding step
    (constant/equality masks, comparison pushdown) is row-local, so
    binding a row slice equals slicing the bound table: the property the
    sharded pipeline uses to bind base relations block-at-a-time
    (DESIGN.md §7)."""
    out, _ = _bind_table_rows(table, atom, comparisons)
    return out


def _bind_table_rows(
    table: Table, atom: Atom, comparisons: Sequence[Comparison]
) -> Tuple[Table, np.ndarray]:
    """:func:`_bind_table` with row provenance: also returns the base-row
    indices (ascending, into ``table``) of the surviving bound rows.  The
    incremental pipeline (:mod:`repro.core.delta`, DESIGN.md §9) keeps
    these so a later delete can tombstone exactly the bound rows whose
    base rows went away — the delete-mask extension of the row-local
    binding property above."""
    cols = table.column_names
    if len(atom.args) != len(cols):
        raise ValueError(
            f"atom {atom.relation}/{len(atom.args)} does not match table "
            f"arity {len(cols)} ({cols})"
        )
    mask = np.ones(len(table), dtype=bool)
    for pos, value in atom.constants:
        mask &= table.column(cols[pos]) == value
    var_cols: Dict[str, np.ndarray] = {}
    for var, col in zip(atom.args, cols):
        if var == "_":
            continue
        if var in var_cols:
            mask &= table.column(col) == var_cols[var]  # R(x, x) equality
            continue
        var_cols[var] = table.column(col)
    for cmp_ in comparisons:
        if cmp_.var in var_cols:
            mask &= np.asarray(cmp_.apply(var_cols[cmp_.var]), dtype=bool)
    rows = np.nonzero(mask)[0]
    out = Table(atom.relation, {v: c[rows] for v, c in var_cols.items()})
    return out, rows


def plan_rule(catalog: Catalog, rule: Rule, mode: str = "auto") -> ChainPlan:
    """Plan one Edges rule (paper §3.3 chain ordering + §4.2 Step 2
    large-output marking): order the body atoms into an ID1 ~> ID2 chain,
    estimate each link's join output from catalog ``n_distinct`` stats,
    and split the chain into eager segments at postponed joins.  ``mode``:
    ``'auto'`` (stats decide, the paper's ``|R||S|/d > 2(|R|+|S|)`` rule),
    ``'condensed'`` (postpone every join, Fig 5a), ``'expanded'``
    (postpone none — EXP extraction)."""
    if rule.kind != "edges":
        raise ValueError("plan_rule plans Edges rules")
    atoms, links = _chain_order(rule)
    id1, id2 = rule.head_vars[0], rule.head_vars[1]

    large: List[bool] = []
    est: List[float] = []
    for i, v in enumerate(links):
        lt = bind_atom(catalog, atoms[i], rule.comparisons)
        rt = bind_atom(catalog, atoms[i + 1], rule.comparisons)
        d = max(lt.stats(v).n_distinct, rt.stats(v).n_distinct, 1)
        size = len(lt) * len(rt) / d
        est.append(size)
        if mode == "condensed":
            large.append(True)
        elif mode == "expanded":
            large.append(False)
        else:
            large.append(size > 2 * (len(lt) + len(rt)))

    segments: List[Tuple[int, int]] = []
    start = 0
    for i, is_large in enumerate(large):
        if is_large:
            segments.append((start, i))
            start = i + 1
    segments.append((start, len(atoms) - 1))
    return ChainPlan(
        rule=rule,
        atoms=atoms,
        link_vars=links,
        large=large,
        est_sizes=est,
        segments=segments,
        endpoint_vars=(id1, id2),
    )


def execute_segment(
    catalog: Catalog,
    plan: ChainPlan,
    seg: Tuple[int, int],
    in_var: str,
    out_var: str,
) -> Tuple[np.ndarray, np.ndarray]:
    """Run one small-output segment eagerly; returns (in_values, out_values).

    This is the part the paper "hands to the database" (§4.2 Step 3): a
    sequence of small-output hash joins, projected down to the segment
    endpoints.  The whole segment is materialized on one host; for the
    partition-parallel variant see :func:`execute_segment_sharded`.
    """
    i, j = seg
    acc = bind_atom(catalog, plan.atoms[i], plan.rule.comparisons)
    for k in range(i + 1, j + 1):
        nxt = bind_atom(catalog, plan.atoms[k], plan.rule.comparisons)
        acc = hash_join(acc, nxt, plan.link_vars[k - 1], plan.link_vars[k - 1])
    if in_var not in acc.column_names or out_var not in acc.column_names:
        raise ValueError(
            f"segment {seg} missing endpoint vars {in_var}/{out_var}; "
            f"has {acc.column_names}"
        )
    return acc.column(in_var), acc.column(out_var)


def _probe_partition(
    table: Table,
    atom: Atom,
    comparisons: Sequence[Comparison],
    key_var: str,
    shard_keys: np.ndarray,
    n_blocks: int,
    budget: Optional[ExtractionBudget],
) -> Table:
    """Bind + filter the probe side of one shard's join, block by block.

    A columnar semi-join: keep only probe rows whose join key occurs in
    the shard's build-side keys (sorted-membership test, the bucket-probe
    half of a hash-partitioned join).  Dropping non-matching rows cannot
    change the join output, and — because binding is row-local and the
    surviving rows keep their relative order — it cannot change the
    output *order* either, which is what the byte-identical merge step
    relies on (DESIGN.md §7).

    The base relation is scanned in ``n_blocks`` row blocks, each bound
    and filtered before the next is touched, so the charged residency is
    one scan block plus the accumulated survivors — never a full bound
    copy of the probe table (the budget's whole point).
    """
    from .relational import shard_bounds

    parts: List[Dict[str, np.ndarray]] = []
    for lo, hi in shard_bounds(len(table), n_blocks):
        block = table.row_slice(lo, hi)
        if budget is not None:
            budget.charge(len(block), "probe scan block")
        bound = _bind_table(block, atom, comparisons)
        mask = np.isin(bound.column(key_var), shard_keys)
        part = {k: v[mask] for k, v in bound.columns.items()}
        if budget is not None:
            budget.charge(int(mask.sum()), "filtered probe rows")
            budget.release(len(block))
        parts.append(part)
    return Table(
        atom.relation,
        {k: np.concatenate([p[k] for p in parts]) for k in parts[0]},
    )


def execute_segment_sharded(
    catalog: Catalog,
    plan: ChainPlan,
    seg: Tuple[int, int],
    in_var: str,
    out_var: str,
    n_shards: int,
    budget: Optional[ExtractionBudget] = None,
) -> List[Tuple[np.ndarray, np.ndarray]]:
    """Partition-parallel :func:`execute_segment` (DESIGN.md §7).

    The segment's leading *base relation* is split into ``n_shards``
    contiguous row blocks (:class:`repro.core.relational.ShardedTable`,
    ``mode='rows'``) and bound block-at-a-time (binding is row-local, see
    :func:`_bind_table`); each shard joins its bound block through the
    remaining atoms, with every probe side scanned in blocks and cut down
    to the shard's live join keys by :func:`_probe_partition`.  Returns
    one ``(in_values, out_values)`` pair per shard — empty shards return
    empty arrays, and concatenating the shard results in order reproduces
    the unsharded :func:`execute_segment` output element-for-element
    (``hash_join`` enumerates build rows in order, so a contiguous build
    block yields the corresponding contiguous output slice).

    ``budget`` charges *everything* a shard makes resident — base-scan
    blocks, bound blocks, filtered probe survivors, join outputs — so
    ``peak_resident_rows`` is an honest bound on per-shard extraction
    transients (the catalog's own columns are the database substrate and
    are not charged; no full bound copy of any table is ever created on
    this path).
    """
    return [
        execute_segment_shard(
            catalog, plan, seg, in_var, out_var, s, n_shards, budget
        )
        for s in range(n_shards)
    ]


def execute_segment_shard(
    catalog: Catalog,
    plan: ChainPlan,
    seg: Tuple[int, int],
    in_var: str,
    out_var: str,
    shard_index: int,
    n_shards: int,
    budget: Optional[ExtractionBudget] = None,
) -> Tuple[np.ndarray, np.ndarray]:
    """One shard of :func:`execute_segment_sharded` (DESIGN.md §7/§8).

    Runs shard ``shard_index`` of the segment's leading-base-relation row
    partition through the remaining atoms and returns its ``(in_values,
    out_values)`` pair.  Factored out of the all-shards loop so callers
    can drive shards in any grouping — in particular the out-of-core
    pipeline, which runs *every segment of one shard* before moving on,
    letting that shard's whole assembled output spill to disk while later
    shards are still unextracted, and the multi-host mapping
    (``repro.distributed.sharding.extraction_shard_range``), which hands
    each JAX process a contiguous slice of ``range(n_shards)``.  Budget
    charges are identical per ``(segment, shard)`` regardless of the
    driving order, so ``peak_resident_rows`` does not depend on who
    loops.
    """
    from .relational import ShardedTable

    i, j = seg
    sharded = ShardedTable(
        catalog.table(plan.atoms[i].relation), n_shards, mode="rows"
    )
    probe_tables = [
        catalog.table(plan.atoms[k].relation) for k in range(i + 1, j + 1)
    ]
    if budget is not None:
        budget.begin_shard()
    block = sharded.shard(shard_index)
    if budget is not None:
        budget.charge(len(block), "leading base block")
    acc = _bind_table(block, plan.atoms[i], plan.rule.comparisons)
    if budget is not None:
        budget.charge(len(acc), "bound leading block")
        budget.release(len(block))
    for k, ptab in enumerate(probe_tables):
        link = plan.link_vars[i + k]
        probe = _probe_partition(
            ptab, plan.atoms[i + 1 + k], plan.rule.comparisons,
            link, acc.column(link), n_shards, budget,
        )
        joined = hash_join(acc, probe, link, link)
        if budget is not None:
            budget.charge(len(joined), "join output")
            budget.n_rows_joined += len(joined)
            budget.release(len(acc) + len(probe))
        acc = joined
    if in_var not in acc.column_names or out_var not in acc.column_names:
        raise ValueError(
            f"segment {seg} missing endpoint vars {in_var}/{out_var}; "
            f"has {acc.column_names}"
        )
    result = (acc.column(in_var), acc.column(out_var))
    if budget is not None:
        # the shard's output is streamed into the assembly buffers (its
        # bytes are charged there via charge_assembly) — release it from
        # the per-shard transient rows account
        budget.release(len(acc))
        budget.n_segments_executed += 1
        budget.end_shard()
    return result
