"""Device-side propagation engine over graph representations.

Implements the paper's ``getNeighbors``-driven execution model as bulk
semiring propagation (DESIGN.md §2).  One call to :func:`propagate`
computes, for every vertex at once,

    y[v] = ⊕_{u -> v}  x[u] ⊗ w(u, v)

on any representation:

* ``DeviceExpanded``   — EXP: one segment-reduce over the expanded edges.
* ``DeviceCondensed``  — C-DUP / DEDUP-1: one segment-reduce per condensed
  layer (the 2-hop factorized SpMV, ``y = B_out^T (B_in^T x)``); path
  multiplicity is counted by ring semirings and ignored by idempotent ones.
* ``DevicePacked``     — the same condensed semantics with each layer also
  carried as a bit-packed block-sparse incidence so batched ring
  propagation feeds the MXU-aligned Pallas SpMM (DESIGN.md §6).
* correction structure — DEDUP-C: C-DUP propagation minus a sparse
  correction term makes ring propagation exact without rewriting edges.

**Batched frontiers** (DESIGN.md §3): ``x`` may be a single ``(n,)``
vector or an ``(n, B)`` matrix of ``B`` independent frontiers (multi-source
BFS, per-user personalized PageRank, ...).  Every semiring step then runs
as one factorized SpMM ``Y = B_out^T (B_in^T X)`` — per-column results are
identical to ``B`` single-vector calls, and the batch axis is annotated
with the ``graph_batch`` logical axis for mesh sharding.

All arrays are JAX; graph containers are registered pytrees so jitted
algorithms take them as arguments.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import TYPE_CHECKING, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..distributed.sharding import shard_frontier
from .condensed import BipartiteEdges, CondensedGraph, ExpandedGraph
from .semiring import PLUS_TIMES, Semiring, kernelizable, segment_reduce

if TYPE_CHECKING:  # pragma: no cover - typing only
    from .dedup import StreamedCorrection

__all__ = [
    "DeviceBipartite",
    "DeviceExpanded",
    "DeviceCondensed",
    "PackedOperands",
    "DevicePackedLayer",
    "DevicePacked",
    "DeviceGraph",
    "Correction",
    "to_device",
    "to_device_packed",
    "propagate",
]

# Trace-time evidence that a propagation step dispatched to the Pallas
# kernel instead of the XLA segment path (asserted by no-fallback tests
# and reported by benchmarks).  Incremented per layer step at dispatch.
KERNEL_DISPATCH_COUNT = 0


def reset_kernel_dispatch_count() -> None:
    global KERNEL_DISPATCH_COUNT
    KERNEL_DISPATCH_COUNT = 0

# A DEDUP-C correction as the engine accepts it: the plain (src, dst,
# count) triples from build_correction, or the StreamedCorrection wrapper
# from build_correction_streaming (accounting rides along; the arrays are
# identical).  Anything that unpacks into three host arrays works.
Correction = Union[
    Tuple[np.ndarray, np.ndarray, np.ndarray], "StreamedCorrection"
]


def _correction_triples(
    correction: Optional[Correction],
) -> Optional[Tuple[np.ndarray, np.ndarray, np.ndarray]]:
    if correction is None:
        return None
    cs, cd, cm = correction
    return cs, cd, cm


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["src", "dst"],
    meta_fields=["n_src", "n_dst"],
)
@dataclasses.dataclass
class DeviceBipartite:
    src: jnp.ndarray
    dst: jnp.ndarray
    n_src: int
    n_dst: int


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["src", "dst", "weight"],
    meta_fields=["n"],
)
@dataclasses.dataclass
class DeviceExpanded:
    """EXP: unique edges with multiplicity weights (1 after dedup)."""

    src: jnp.ndarray
    dst: jnp.ndarray
    weight: jnp.ndarray  # float multiplicities; all-ones when deduplicated
    n: int


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["chains", "direct", "correction", "diag_mult"],
    meta_fields=["n_real", "deduplicated"],
)
@dataclasses.dataclass
class DeviceCondensed:
    """C-DUP / DEDUP-1 / DEDUP-C on device.

    ``chains``      list of chains; each chain a tuple of DeviceBipartite.
    ``direct``      optional real->real edges (may repeat = multiplicity).
    ``correction``  optional (src, dst, count) triple; when present, ring
                    propagation subtracts it (DEDUP-C).
    ``diag_mult``   per-node count of self paths (subtracted by ring
                    propagation so self-loops never contribute).
    ``deduplicated``True when path multiplicity is structurally 1
                    (DEDUP-1 output), so ring propagation is exact as-is.
    """

    chains: Tuple[Tuple[DeviceBipartite, ...], ...]
    direct: Optional[DeviceBipartite]
    correction: Optional[Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]]
    diag_mult: Optional[jnp.ndarray]
    n_real: int
    deduplicated: bool


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["slot_src", "slot_row", "row_start", "row_count", "bitmaps"],
    meta_fields=[],
)
@dataclasses.dataclass
class PackedOperands:
    """One direction's streamed-slot kernel operands (see
    :class:`repro.kernels.pack.BlockSparseBitmap` for the layout)."""

    slot_src: jnp.ndarray   # (n_slots,) int32
    slot_row: jnp.ndarray   # (n_slots,) int32
    row_start: jnp.ndarray  # (n_rt,) int32
    row_count: jnp.ndarray  # (n_rt,) int32
    bitmaps: jnp.ndarray    # (n_slots, TILE, WORDS) uint32


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["src", "dst", "fwd", "rev"],
    meta_fields=["n_src", "n_dst", "n_src_pad", "n_dst_pad"],
)
@dataclasses.dataclass
class DevicePackedLayer:
    """One condensed layer in COO plus bit-packed streamed-slot form.

    ``src``/``dst`` drive the segment-reduce path (any semiring, any
    direction).  ``fwd`` is the dst-major packed incidence
    (:mod:`repro.kernels.pack`) consumed by the Pallas SpMM for batched
    forward propagation; ``rev`` packs the transposed incidence so
    ``reverse=True`` steps (HITS, out-degrees) dispatch to the kernel
    too.  Either is ``None`` when the layer is not packable (duplicate
    edges, e.g. multiplicity-carrying direct edges).
    """

    src: jnp.ndarray
    dst: jnp.ndarray
    fwd: Optional[PackedOperands]
    rev: Optional[PackedOperands]
    n_src: int
    n_dst: int
    n_src_pad: int
    n_dst_pad: int


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["chains", "direct", "correction", "diag_mult"],
    meta_fields=["n_real", "deduplicated", "backend", "feature_block"],
)
@dataclasses.dataclass
class DevicePacked:
    """A :class:`DeviceCondensed` whose layers carry packed SpMM operands.

    Identical propagation semantics; batched (``(n, B)``) steps under any
    kernelizable semiring (plus-times, min-plus, max-times, or-and), in
    either direction, are dispatched to :func:`repro.kernels.bitmap_spmm.
    bitmap_spmm_pallas` per layer when ``backend`` resolves to Pallas
    (DESIGN.md §6).  ``backend``: ``'pallas'`` | ``'xla'`` | ``'auto'``
    (Pallas on TPU when the streamed working set fits VMEM — independent
    of the source count — XLA segment-reduce otherwise).
    """

    chains: Tuple[Tuple[DevicePackedLayer, ...], ...]
    direct: Optional[DevicePackedLayer]
    correction: Optional[Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]]
    diag_mult: Optional[jnp.ndarray]
    n_real: int
    deduplicated: bool
    backend: str
    feature_block: int


DeviceGraph = Union[DeviceExpanded, DeviceCondensed, DevicePacked]


# ---------------------------------------------------------------------------
# Host -> device conversion
# ---------------------------------------------------------------------------

def _dev_edges(e: BipartiteEdges) -> DeviceBipartite:
    return DeviceBipartite(
        jnp.asarray(e.src, dtype=jnp.int32),
        jnp.asarray(e.dst, dtype=jnp.int32),
        e.n_src,
        e.n_dst,
    )


def self_path_counts(graph: CondensedGraph) -> np.ndarray:
    """Host: number of closed u->u paths per real node (diagonal of M)."""
    diag = np.zeros(graph.n_real, dtype=np.int64)
    for chain in graph.chains:
        if chain.n_layers == 1:
            e_in, e_out = chain.edges
            # Join (u, V) with (V, u): count matching (V, u) occurrences.
            key_in = e_in.dst.astype(np.int64) * graph.n_real + e_in.src
            key_out = e_out.src.astype(np.int64) * graph.n_real + e_out.dst
            key_out_sorted = np.sort(key_out)
            lo = np.searchsorted(key_out_sorted, key_in, side="left")
            hi = np.searchsorted(key_out_sorted, key_in, side="right")
            np.add.at(diag, e_in.src, (hi - lo))
        else:
            s, d, m = chain.path_pairs()
            mask = s == d
            np.add.at(diag, s[mask], m[mask])
    if graph.direct is not None and graph.direct.n_edges:
        mask = graph.direct.src == graph.direct.dst
        np.add.at(diag, graph.direct.src[mask], 1)
    return diag


def to_device(
    graph: Union[CondensedGraph, ExpandedGraph],
    correction: Optional[Correction] = None,
    deduplicated: bool = False,
    drop_self_loops: bool = True,
) -> DeviceGraph:
    """Build the device representation.

    For ``CondensedGraph`` inputs, pass ``correction`` (the triples from
    :func:`repro.core.dedup.build_correction` or a
    :class:`~repro.core.dedup.StreamedCorrection` built under a budget by
    :func:`~repro.core.dedup.build_correction_streaming`) to get DEDUP-C
    semantics, or ``deduplicated=True`` for DEDUP-1 output.  Without
    either, ring propagation counts duplicate paths (C-DUP semantics) —
    fine for idempotent algorithms, flagged by :func:`propagate`
    otherwise.
    """
    if isinstance(graph, ExpandedGraph):
        g = graph.without_self_loops() if drop_self_loops else graph
        return DeviceExpanded(
            jnp.asarray(g.src, dtype=jnp.int32),
            jnp.asarray(g.dst, dtype=jnp.int32),
            jnp.minimum(jnp.asarray(g.multiplicity, dtype=jnp.float32), 1.0),
            g.n,
        )
    chains = tuple(tuple(_dev_edges(e) for e in c.edges) for c in graph.chains)
    direct = _dev_edges(graph.direct) if graph.direct is not None else None
    corr = None
    triples = _correction_triples(correction)
    if triples is not None:
        cs, cd, cm = triples
        corr = (
            jnp.asarray(cs, dtype=jnp.int32),
            jnp.asarray(cd, dtype=jnp.int32),
            jnp.asarray(cm, dtype=jnp.float32),
        )
    diag = None
    if drop_self_loops and corr is None:
        # Full self-path multiplicity: DEDUP-1's uniqueness invariant is
        # off-diagonal only — u reaches itself once per containing virtual
        # node, and all of those must be subtracted.
        diag = jnp.asarray(self_path_counts(graph), dtype=jnp.float32)
    return DeviceCondensed(
        chains=chains,
        direct=direct,
        correction=corr,
        diag_mult=diag,
        n_real=graph.n_real,
        deduplicated=deduplicated,
    )


def _upload_operands(bsb) -> PackedOperands:
    return PackedOperands(
        slot_src=jnp.asarray(bsb.slot_src),
        slot_row=jnp.asarray(bsb.slot_row),
        row_start=jnp.asarray(bsb.row_start),
        row_count=jnp.asarray(bsb.row_count),
        bitmaps=jnp.asarray(bsb.bitmaps),
    )


def _pack_edges(
    e: BipartiteEdges,
    dev: DeviceBipartite,
    shard_edges: Optional[int] = None,
) -> DevicePackedLayer:
    """``dev`` is the already-uploaded COO layer from :func:`to_device`,
    reused so the edge arrays cross to the device only once.  Packs both
    directions: the forward incidence and its transpose (reverse steps).
    ``shard_edges`` routes the packing through the shard-at-a-time path
    (:func:`repro.kernels.pack.pack_bipartite` slices + OR-merge,
    DESIGN.md §7) so packing transients stay bounded for large layers."""
    from ..kernels.pack import TILE, pack_bipartite

    fwd = rev = None
    # min one tile each way, matching the pack's pad-slot convention
    # (BlockSparseBitmap.n_src_tiles): zero-node layers stay kernel-safe
    n_src_pad = max(-(-e.n_src // TILE), 1) * TILE
    n_dst_pad = max(-(-e.n_dst // TILE), 1) * TILE
    try:
        fwd = _upload_operands(pack_bipartite(e, shard_edges=shard_edges))
        rev = _upload_operands(
            pack_bipartite(e.reversed(), shard_edges=shard_edges)
        )
    except ValueError:
        fwd = rev = None  # duplicate edges (multiplicity): COO path only
    return DevicePackedLayer(
        src=dev.src,
        dst=dev.dst,
        fwd=fwd,
        rev=rev,
        n_src=e.n_src,
        n_dst=e.n_dst,
        n_src_pad=n_src_pad,
        n_dst_pad=n_dst_pad,
    )


def to_device_packed(
    graph: CondensedGraph,
    correction: Optional[Correction] = None,
    deduplicated: bool = False,
    drop_self_loops: bool = True,
    backend: str = "auto",
    feature_block: int = 128,
    pack_shard_edges: Optional[int] = None,
) -> DevicePacked:
    """Like :func:`to_device`, additionally packing every condensed layer
    into bit-packed block-sparse SpMM operands (DESIGN.md §6) so batched
    ring propagation runs on the Pallas kernel.  Correction / dedup
    semantics are identical to :func:`to_device` (streamed corrections
    accepted the same way).  ``pack_shard_edges`` bounds the host packing
    transients per layer (shard-at-a-time packing, DESIGN.md §7) — the
    uploaded operands are byte-identical either way.
    """
    base = to_device(
        graph,
        correction=correction,
        deduplicated=deduplicated,
        drop_self_loops=drop_self_loops,
    )
    assert isinstance(base, DeviceCondensed)
    chains = tuple(
        tuple(
            _pack_edges(e, d, pack_shard_edges)
            for e, d in zip(c.edges, dc)
        )
        for c, dc in zip(graph.chains, base.chains)
    )
    direct = (
        _pack_edges(graph.direct, base.direct, pack_shard_edges)
        if graph.direct is not None
        else None
    )
    return DevicePacked(
        chains=chains,
        direct=direct,
        correction=base.correction,
        diag_mult=base.diag_mult,
        n_real=graph.n_real,
        deduplicated=deduplicated,
        backend=backend,
        feature_block=feature_block,
    )


# ---------------------------------------------------------------------------
# Propagation
# ---------------------------------------------------------------------------

def _gather(x: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(x, idx, axis=0)


def _edge_propagate(
    sr: Semiring,
    edges: DeviceBipartite,
    x: jnp.ndarray,
    reverse: bool,
) -> jnp.ndarray:
    src, dst = (edges.dst, edges.src) if reverse else (edges.src, edges.dst)
    n_out = edges.n_src if reverse else edges.n_dst
    return segment_reduce(sr, _gather(x, src), dst, n_out)


def _kernel_applicable(
    graph: "DevicePacked",
    layer: DevicePackedLayer,
    x: jnp.ndarray,
    semiring: Semiring,
    reverse: bool,
) -> bool:
    """Static (trace-time) dispatch: batched kernelizable steps, both
    directions.

    The streamed-window VMEM footprint (DESIGN.md §6) is shared with
    kernels.ops via kernels.pack (imported lazily — the kernels package
    pulls in the Pallas stack); since the source column is streamed, the
    formula no longer depends on the source count, so the old 8 MiB
    resident-column cliff is gone.  The two 'auto' policies intentionally
    differ in one respect: the engine only selects Pallas on a real TPU
    (interpret mode is for explicit backend='pallas' testing), while the
    standalone ops wrapper will run interpret mode anywhere.
    """
    if x.ndim != 2 or not kernelizable(semiring):
        return False
    packed = layer.rev if reverse else layer.fwd
    if packed is None:
        return False
    if graph.backend == "pallas":
        return True
    if graph.backend == "xla":
        return False
    from ..kernels.pack import fits_vmem

    fits = fits_vmem(
        x.shape[1],
        graph.feature_block,
        x.dtype.itemsize,
        n_slots=int(packed.slot_src.shape[0]),
    )
    return jax.default_backend() == "tpu" and fits


def _packed_layer_spmm(
    layer: DevicePackedLayer,
    x: jnp.ndarray,
    feature_block: int,
    semiring: Semiring,
    reverse: bool,
) -> jnp.ndarray:
    """One layer of the factorized SpMM ``Y = B ⊕ X`` on the Pallas kernel."""
    from ..kernels.bitmap_spmm import bitmap_spmm_pallas

    global KERNEL_DISPATCH_COUNT
    KERNEL_DISPATCH_COUNT += 1
    ops = layer.rev if reverse else layer.fwd
    n_in_pad = layer.n_dst_pad if reverse else layer.n_src_pad
    n_out_pad = layer.n_src_pad if reverse else layer.n_dst_pad
    n_out = layer.n_src if reverse else layer.n_dst
    f = x.shape[1]
    f_pad = -(-f // feature_block) * feature_block
    xp = jnp.pad(x, ((0, n_in_pad - x.shape[0]), (0, f_pad - f)))
    yp = bitmap_spmm_pallas(
        ops.slot_src,
        ops.slot_row,
        ops.row_start,
        ops.row_count,
        ops.bitmaps,
        xp,
        n_dst_pad=n_out_pad,
        feature_block=feature_block,
        op=semiring.add_kind,
        zero=float(semiring.zero),
    )
    return yp[:n_out, :f]


def _layer_propagate(
    graph: DeviceGraph,
    sr: Semiring,
    edges,
    x: jnp.ndarray,
    reverse: bool,
) -> jnp.ndarray:
    if isinstance(graph, DevicePacked) and _kernel_applicable(
        graph, edges, x, sr, reverse
    ):
        return _packed_layer_spmm(edges, x, graph.feature_block, sr, reverse)
    return _edge_propagate(sr, edges, x, reverse)


def _apply_hop(sr: Semiring, y: jnp.ndarray, hop_weight: Optional[float]) -> jnp.ndarray:
    if hop_weight is None:
        return y
    return sr.mul(y, jnp.asarray(hop_weight, dtype=y.dtype))


def propagate(
    graph: DeviceGraph,
    x: jnp.ndarray,
    semiring: Semiring = PLUS_TIMES,
    *,
    reverse: bool = False,
    hop_weight: Optional[float] = None,
    allow_duplicates: bool = False,
) -> jnp.ndarray:
    """One superstep: ⊕-combine ⊗-weighted messages along all edges.

    ``x`` is one frontier ``(n,)`` or a batch of ``B`` frontiers ``(n, B)``
    processed in a single factorized SpMM; per-column results equal ``B``
    independent single-frontier calls (DESIGN.md §3).  ``hop_weight`` is
    applied once per *logical* (real->real) hop, not per condensed layer,
    so BFS hop counting matches the expanded graph.
    """
    n_in = graph.n if isinstance(graph, DeviceExpanded) else graph.n_real
    if x.ndim not in (1, 2) or x.shape[0] != n_in:
        raise ValueError(
            f"frontier must be ({n_in},) or ({n_in}, B); got shape {x.shape}"
        )
    x = shard_frontier(x)
    if isinstance(graph, DeviceExpanded):
        src, dst = (graph.dst, graph.src) if reverse else (graph.src, graph.dst)
        msgs = _gather(x, src)
        if semiring.name == "plus_times":
            msgs = msgs * _bcast(graph.weight, msgs)
        y = segment_reduce(semiring, msgs, dst, graph.n)
        return shard_frontier(_apply_hop(semiring, y, hop_weight))

    assert isinstance(graph, (DeviceCondensed, DevicePacked))
    exact = (
        semiring.idempotent
        or graph.deduplicated
        or graph.correction is not None
    )
    if not exact and not allow_duplicates:
        raise ValueError(
            "ring propagation on C-DUP counts duplicate paths; pass a "
            "correction (DEDUP-C), a deduplicated graph (DEDUP-1), or "
            "allow_duplicates=True (paper §4.1 duplication problem)"
        )

    y = None
    for chain in graph.chains:
        seq: Sequence[DeviceBipartite] = chain[::-1] if reverse else chain
        h = x
        for e in seq:
            h = _layer_propagate(graph, semiring, e, h, reverse)
        h = _apply_hop(semiring, h, hop_weight)
        y = h if y is None else semiring.add(y, h)
    if graph.direct is not None:
        h = _layer_propagate(graph, semiring, graph.direct, x, reverse)
        h = _apply_hop(semiring, h, hop_weight)
        y = h if y is None else semiring.add(y, h)
    if y is None:
        zero_shape = (graph.n_real,) + x.shape[1:]
        y = jnp.full(zero_shape, semiring.zero, dtype=x.dtype)

    if semiring.name == "plus_times":
        # Exactness corrections only make sense in the ring.
        if graph.correction is not None:
            cs, cd, cm = graph.correction
            src, dst = (cd, cs) if reverse else (cs, cd)
            corr = jax.ops.segment_sum(
                _gather(x, src) * _bcast(cm, _gather(x, src)),
                dst,
                num_segments=graph.n_real,
            )
            y = y - _apply_hop(semiring, corr, hop_weight)
        elif graph.diag_mult is not None:
            y = y - _apply_hop(
                semiring, x * _bcast(graph.diag_mult, x), hop_weight
            )
    return shard_frontier(y)


def _bcast(w: jnp.ndarray, like: jnp.ndarray) -> jnp.ndarray:
    """Broadcast per-edge/per-node weight against feature matrices."""
    if like.ndim == w.ndim:
        return w.astype(like.dtype)
    return w.astype(like.dtype).reshape(w.shape + (1,) * (like.ndim - w.ndim))
