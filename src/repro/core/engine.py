"""Device-side propagation engine over graph representations.

Implements the paper's ``getNeighbors``-driven execution model as bulk
semiring propagation (DESIGN.md §2).  One call to :func:`propagate`
computes, for every vertex at once,

    y[v] = ⊕_{u -> v}  x[u] ⊗ w(u, v)

on any representation:

* ``DeviceExpanded``   — EXP: one segment-reduce over the expanded edges.
* ``DeviceCondensed``  — C-DUP / DEDUP-1: one segment-reduce per condensed
  layer (the 2-hop factorized SpMV, ``y = B_out^T (B_in^T x)``); path
  multiplicity is counted by ring semirings and ignored by idempotent ones.
* correction structure — DEDUP-C: C-DUP propagation minus a sparse
  correction term makes ring propagation exact without rewriting edges.

All arrays are JAX; graph containers are registered pytrees so jitted
algorithms take them as arguments.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from .condensed import BipartiteEdges, CondensedGraph, ExpandedGraph
from .semiring import PLUS_TIMES, Semiring, segment_reduce

__all__ = [
    "DeviceBipartite",
    "DeviceExpanded",
    "DeviceCondensed",
    "DeviceGraph",
    "to_device",
    "propagate",
]


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["src", "dst"],
    meta_fields=["n_src", "n_dst"],
)
@dataclasses.dataclass
class DeviceBipartite:
    src: jnp.ndarray
    dst: jnp.ndarray
    n_src: int
    n_dst: int


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["src", "dst", "weight"],
    meta_fields=["n"],
)
@dataclasses.dataclass
class DeviceExpanded:
    """EXP: unique edges with multiplicity weights (1 after dedup)."""

    src: jnp.ndarray
    dst: jnp.ndarray
    weight: jnp.ndarray  # float multiplicities; all-ones when deduplicated
    n: int


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["chains", "direct", "correction", "diag_mult"],
    meta_fields=["n_real", "deduplicated"],
)
@dataclasses.dataclass
class DeviceCondensed:
    """C-DUP / DEDUP-1 / DEDUP-C on device.

    ``chains``      list of chains; each chain a tuple of DeviceBipartite.
    ``direct``      optional real->real edges (may repeat = multiplicity).
    ``correction``  optional (src, dst, count) triple; when present, ring
                    propagation subtracts it (DEDUP-C).
    ``diag_mult``   per-node count of self paths (subtracted by ring
                    propagation so self-loops never contribute).
    ``deduplicated``True when path multiplicity is structurally 1
                    (DEDUP-1 output), so ring propagation is exact as-is.
    """

    chains: Tuple[Tuple[DeviceBipartite, ...], ...]
    direct: Optional[DeviceBipartite]
    correction: Optional[Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]]
    diag_mult: Optional[jnp.ndarray]
    n_real: int
    deduplicated: bool


DeviceGraph = Union[DeviceExpanded, DeviceCondensed]


# ---------------------------------------------------------------------------
# Host -> device conversion
# ---------------------------------------------------------------------------

def _dev_edges(e: BipartiteEdges) -> DeviceBipartite:
    return DeviceBipartite(
        jnp.asarray(e.src, dtype=jnp.int32),
        jnp.asarray(e.dst, dtype=jnp.int32),
        e.n_src,
        e.n_dst,
    )


def self_path_counts(graph: CondensedGraph) -> np.ndarray:
    """Host: number of closed u->u paths per real node (diagonal of M)."""
    diag = np.zeros(graph.n_real, dtype=np.int64)
    for chain in graph.chains:
        if chain.n_layers == 1:
            e_in, e_out = chain.edges
            # Join (u, V) with (V, u): count matching (V, u) occurrences.
            key_in = e_in.dst.astype(np.int64) * graph.n_real + e_in.src
            key_out = e_out.src.astype(np.int64) * graph.n_real + e_out.dst
            key_out_sorted = np.sort(key_out)
            lo = np.searchsorted(key_out_sorted, key_in, side="left")
            hi = np.searchsorted(key_out_sorted, key_in, side="right")
            np.add.at(diag, e_in.src, (hi - lo))
        else:
            s, d, m = chain.path_pairs()
            mask = s == d
            np.add.at(diag, s[mask], m[mask])
    if graph.direct is not None and graph.direct.n_edges:
        mask = graph.direct.src == graph.direct.dst
        np.add.at(diag, graph.direct.src[mask], 1)
    return diag


def to_device(
    graph: Union[CondensedGraph, ExpandedGraph],
    correction: Optional[Tuple[np.ndarray, np.ndarray, np.ndarray]] = None,
    deduplicated: bool = False,
    drop_self_loops: bool = True,
) -> DeviceGraph:
    """Build the device representation.

    For ``CondensedGraph`` inputs, pass ``correction`` (from
    :func:`repro.core.dedup.build_correction`) to get DEDUP-C semantics, or
    ``deduplicated=True`` for DEDUP-1 output.  Without either, ring
    propagation counts duplicate paths (C-DUP semantics) — fine for
    idempotent algorithms, flagged by :func:`propagate` otherwise.
    """
    if isinstance(graph, ExpandedGraph):
        g = graph.without_self_loops() if drop_self_loops else graph
        return DeviceExpanded(
            jnp.asarray(g.src, dtype=jnp.int32),
            jnp.asarray(g.dst, dtype=jnp.int32),
            jnp.minimum(jnp.asarray(g.multiplicity, dtype=jnp.float32), 1.0),
            g.n,
        )
    chains = tuple(tuple(_dev_edges(e) for e in c.edges) for c in graph.chains)
    direct = _dev_edges(graph.direct) if graph.direct is not None else None
    corr = None
    if correction is not None:
        cs, cd, cm = correction
        corr = (
            jnp.asarray(cs, dtype=jnp.int32),
            jnp.asarray(cd, dtype=jnp.int32),
            jnp.asarray(cm, dtype=jnp.float32),
        )
    diag = None
    if drop_self_loops and corr is None:
        # Full self-path multiplicity: DEDUP-1's uniqueness invariant is
        # off-diagonal only — u reaches itself once per containing virtual
        # node, and all of those must be subtracted.
        diag = jnp.asarray(self_path_counts(graph), dtype=jnp.float32)
    return DeviceCondensed(
        chains=chains,
        direct=direct,
        correction=corr,
        diag_mult=diag,
        n_real=graph.n_real,
        deduplicated=deduplicated,
    )


# ---------------------------------------------------------------------------
# Propagation
# ---------------------------------------------------------------------------

def _gather(x: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(x, idx, axis=0)


def _edge_propagate(
    sr: Semiring,
    edges: DeviceBipartite,
    x: jnp.ndarray,
    reverse: bool,
) -> jnp.ndarray:
    src, dst = (edges.dst, edges.src) if reverse else (edges.src, edges.dst)
    n_out = edges.n_src if reverse else edges.n_dst
    return segment_reduce(sr, _gather(x, src), dst, n_out)


def _apply_hop(sr: Semiring, y: jnp.ndarray, hop_weight: Optional[float]) -> jnp.ndarray:
    if hop_weight is None:
        return y
    return sr.mul(y, jnp.asarray(hop_weight, dtype=y.dtype))


def propagate(
    graph: DeviceGraph,
    x: jnp.ndarray,
    semiring: Semiring = PLUS_TIMES,
    *,
    reverse: bool = False,
    hop_weight: Optional[float] = None,
    allow_duplicates: bool = False,
) -> jnp.ndarray:
    """One superstep: ⊕-combine ⊗-weighted messages along all edges.

    ``hop_weight`` is applied once per *logical* (real->real) hop, not per
    condensed layer, so BFS hop counting matches the expanded graph.
    """
    if isinstance(graph, DeviceExpanded):
        src, dst = (graph.dst, graph.src) if reverse else (graph.src, graph.dst)
        msgs = _gather(x, src)
        if semiring.name == "plus_times":
            msgs = msgs * _bcast(graph.weight, msgs)
        y = segment_reduce(semiring, msgs, dst, graph.n)
        return _apply_hop(semiring, y, hop_weight)

    assert isinstance(graph, DeviceCondensed)
    exact = (
        semiring.idempotent
        or graph.deduplicated
        or graph.correction is not None
    )
    if not exact and not allow_duplicates:
        raise ValueError(
            "ring propagation on C-DUP counts duplicate paths; pass a "
            "correction (DEDUP-C), a deduplicated graph (DEDUP-1), or "
            "allow_duplicates=True (paper §4.1 duplication problem)"
        )

    y = None
    for chain in graph.chains:
        seq: Sequence[DeviceBipartite] = chain[::-1] if reverse else chain
        h = x
        for e in seq:
            h = _edge_propagate(semiring, e, h, reverse)
        h = _apply_hop(semiring, h, hop_weight)
        y = h if y is None else semiring.add(y, h)
    if graph.direct is not None:
        h = _edge_propagate(semiring, graph.direct, x, reverse)
        h = _apply_hop(semiring, h, hop_weight)
        y = h if y is None else semiring.add(y, h)
    if y is None:
        zero_shape = (graph.n_real,) + x.shape[1:]
        y = jnp.full(zero_shape, semiring.zero, dtype=x.dtype)

    if semiring.name == "plus_times":
        # Exactness corrections only make sense in the ring.
        if graph.correction is not None:
            cs, cd, cm = graph.correction
            src, dst = (cd, cs) if reverse else (cs, cd)
            corr = jax.ops.segment_sum(
                _gather(x, src) * _bcast(cm, _gather(x, src)),
                dst,
                num_segments=graph.n_real,
            )
            y = y - _apply_hop(semiring, corr, hop_weight)
        elif graph.diag_mult is not None:
            y = y - _apply_hop(
                semiring, x * _bcast(graph.diag_mult, x), hop_weight
            )
    return y


def _bcast(w: jnp.ndarray, like: jnp.ndarray) -> jnp.ndarray:
    """Broadcast per-edge/per-node weight against feature matrices."""
    if like.ndim == w.ndim:
        return w.astype(like.dtype)
    return w.astype(like.dtype).reshape(w.shape + (1,) * (like.ndim - w.ndim))
