"""Device-side propagation engine over graph representations.

Implements the paper's ``getNeighbors``-driven execution model as bulk
semiring propagation (DESIGN.md §2).  One call to :func:`propagate`
computes, for every vertex at once,

    y[v] = ⊕_{u -> v}  x[u] ⊗ w(u, v)

on any representation:

* ``DeviceExpanded``   — EXP: one segment-reduce over the expanded edges.
* ``DeviceCondensed``  — C-DUP / DEDUP-1: one segment-reduce per condensed
  layer (the 2-hop factorized SpMV, ``y = B_out^T (B_in^T x)``); path
  multiplicity is counted by ring semirings and ignored by idempotent ones.
* ``DevicePacked``     — the same condensed semantics with each layer also
  carried as a bit-packed block-sparse incidence so batched ring
  propagation feeds the MXU-aligned Pallas SpMM (DESIGN.md §6).
* correction structure — DEDUP-C: C-DUP propagation minus a sparse
  correction term makes ring propagation exact without rewriting edges.

**Batched frontiers** (DESIGN.md §3): ``x`` may be a single ``(n,)``
vector or an ``(n, B)`` matrix of ``B`` independent frontiers (multi-source
BFS, per-user personalized PageRank, ...).  Every semiring step then runs
as one factorized SpMM ``Y = B_out^T (B_in^T X)`` — per-column results are
identical to ``B`` single-vector calls, and the batch axis is annotated
with the ``graph_batch`` logical axis for mesh sharding.

All arrays are JAX; graph containers are registered pytrees so jitted
algorithms take them as arguments.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import TYPE_CHECKING, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..distributed.sharding import shard_frontier
from .condensed import BipartiteEdges, CondensedGraph, ExpandedGraph
from .semiring import PLUS_TIMES, Semiring, kernelizable, segment_reduce

if TYPE_CHECKING:  # pragma: no cover - typing only
    from ..kernels.autotune import CrossoverTable
    from .dedup import StreamedCorrection

__all__ = [
    "DeviceBipartite",
    "DeviceExpanded",
    "DeviceCondensed",
    "PackedOperands",
    "FusedOperands",
    "DevicePackedLayer",
    "DevicePacked",
    "DeviceGraph",
    "Correction",
    "ResidencyBudget",
    "ResidencyError",
    "device_graph_bytes",
    "graph_shape_signature",
    "to_device",
    "to_device_packed",
    "with_graph_version",
    "propagate",
    "propagate_wedge",
]

# Trace-time evidence that a propagation step dispatched to the Pallas
# kernel instead of the XLA segment path (asserted by no-fallback tests
# and reported by benchmarks).  Incremented per layer step at dispatch.
KERNEL_DISPATCH_COUNT = 0

# Trace-time evidence of fused-epilogue stand-downs: every time a ring
# propagation over a corrected DevicePacked considers the fused DEDUP-C
# path and declines, the machine-readable reason from
# :func:`_fused_applicable` is counted here (dispatch-honesty tests pin
# these instead of guessing from timings).  Reset together with the
# dispatch count.
KERNEL_STANDDOWN_COUNT: dict = {}


def reset_kernel_dispatch_count() -> None:
    global KERNEL_DISPATCH_COUNT
    KERNEL_DISPATCH_COUNT = 0
    KERNEL_STANDDOWN_COUNT.clear()

# A DEDUP-C correction as the engine accepts it: the plain (src, dst,
# count) triples from build_correction, or the StreamedCorrection wrapper
# from build_correction_streaming (accounting rides along; the arrays are
# identical).  Anything that unpacks into three host arrays works.
Correction = Union[
    Tuple[np.ndarray, np.ndarray, np.ndarray], "StreamedCorrection"
]


def _correction_triples(
    correction: Optional[Correction],
) -> Optional[Tuple[np.ndarray, np.ndarray, np.ndarray]]:
    if correction is None:
        return None
    cs, cd, cm = correction
    return cs, cd, cm


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["src", "dst"],
    meta_fields=["n_src", "n_dst"],
)
@dataclasses.dataclass
class DeviceBipartite:
    src: jnp.ndarray
    dst: jnp.ndarray
    n_src: int
    n_dst: int


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["src", "dst", "weight"],
    meta_fields=["n", "graph_version"],
)
@dataclasses.dataclass
class DeviceExpanded:
    """EXP: unique edges with multiplicity weights (1 after dedup).

    ``graph_version`` is the :class:`repro.core.delta.GraphVersion` of
    the extraction this upload came from (DESIGN.md §9).  It rides in the
    pytree *meta*, so it participates in jit static hashing: any compiled
    executable and donated/cached operand is keyed on it, and a version
    bump invalidates them all by construction.
    """

    src: jnp.ndarray
    dst: jnp.ndarray
    weight: jnp.ndarray  # float multiplicities; all-ones when deduplicated
    n: int
    graph_version: int = 0


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["chains", "direct", "correction", "diag_mult"],
    meta_fields=["n_real", "deduplicated", "graph_version"],
)
@dataclasses.dataclass
class DeviceCondensed:
    """C-DUP / DEDUP-1 / DEDUP-C on device.

    ``chains``      list of chains; each chain a tuple of DeviceBipartite.
    ``direct``      optional real->real edges (may repeat = multiplicity).
    ``correction``  optional (src, dst, count) triple; when present, ring
                    propagation subtracts it (DEDUP-C).
    ``diag_mult``   per-node count of self paths (subtracted by ring
                    propagation so self-loops never contribute).
    ``deduplicated``True when path multiplicity is structurally 1
                    (DEDUP-1 output), so ring propagation is exact as-is.
    ``graph_version`` source graph's delta version (DESIGN.md §9); static
                    pytree meta, so a bump invalidates every compiled
                    executable / cached operand keyed on this graph.
    """

    chains: Tuple[Tuple[DeviceBipartite, ...], ...]
    direct: Optional[DeviceBipartite]
    correction: Optional[Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]]
    diag_mult: Optional[jnp.ndarray]
    n_real: int
    deduplicated: bool
    graph_version: int = 0


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["slot_src", "slot_row", "row_start", "row_count", "bitmaps"],
    meta_fields=["crossover"],
)
@dataclasses.dataclass
class PackedOperands:
    """One direction's streamed-slot kernel operands (see
    :class:`repro.kernels.pack.BlockSparseBitmap` for the layout).

    ``crossover`` is the measured-crossover dispatch table recorded at
    pack time (``to_device_packed(..., measure=True)``); it is a frozen
    hashable value riding in the pytree *meta* (it steers trace-time
    dispatch, so it must participate in jit static hashing).  ``None``
    means unmeasured: 'auto' falls back to the footprint formula.
    """

    slot_src: jnp.ndarray   # (n_slots,) int32
    slot_row: jnp.ndarray   # (n_slots,) int32
    row_start: jnp.ndarray  # (n_rt,) int32
    row_count: jnp.ndarray  # (n_rt,) int32
    bitmaps: jnp.ndarray    # (n_slots, TILE, WORDS) uint32
    crossover: Optional["CrossoverTable"] = None


@partial(
    jax.tree_util.register_dataclass,
    data_fields=[
        "kind", "main_src", "corr_src", "main_idx", "corr_idx",
        "slot_row", "row_start", "row_count", "bitmaps", "planes",
    ],
    meta_fields=["plane_weights", "n_h_pad", "n_x_pad", "n_out", "n_out_pad"],
)
@dataclasses.dataclass
class FusedOperands:
    """Operands of the fused last-layer-SpMM + DEDUP-C-epilogue kernel
    (:func:`repro.kernels.bitmap_spmm.bitmap_spmm_fused_pallas`): the
    interleaved main/correction slot stream built by
    :func:`repro.kernels.correction.build_fused_stream`, the main layer's
    bitmaps, and the correction's bit-planes.  ``n_h_pad`` / ``n_x_pad``
    are the padded row counts of the two streamed feature operands (the
    last hidden frontier and the original input)."""

    kind: jnp.ndarray       # (n_slots,) int32 — 0 main, 1 correction
    main_src: jnp.ndarray   # (n_slots,) int32
    corr_src: jnp.ndarray   # (n_slots,) int32
    main_idx: jnp.ndarray   # (n_slots,) int32
    corr_idx: jnp.ndarray   # (n_slots,) int32
    slot_row: jnp.ndarray   # (n_slots,) int32
    row_start: jnp.ndarray  # (n_rt,) int32
    row_count: jnp.ndarray  # (n_rt,) int32
    bitmaps: jnp.ndarray    # (n_main, TILE, WORDS) uint32
    planes: jnp.ndarray     # (n_corr, P, TILE, WORDS) uint32
    plane_weights: Tuple[float, ...]
    n_h_pad: int
    n_x_pad: int
    n_out: int
    n_out_pad: int


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["src", "dst", "fwd", "rev"],
    meta_fields=["n_src", "n_dst", "n_src_pad", "n_dst_pad"],
)
@dataclasses.dataclass
class DevicePackedLayer:
    """One condensed layer in COO plus bit-packed streamed-slot form.

    ``src``/``dst`` drive the segment-reduce path (any semiring, any
    direction).  ``fwd`` is the dst-major packed incidence
    (:mod:`repro.kernels.pack`) consumed by the Pallas SpMM for batched
    forward propagation; ``rev`` packs the transposed incidence so
    ``reverse=True`` steps (HITS, out-degrees) dispatch to the kernel
    too.  Either is ``None`` when the layer is not packable (duplicate
    edges, e.g. multiplicity-carrying direct edges).
    """

    src: jnp.ndarray
    dst: jnp.ndarray
    fwd: Optional[PackedOperands]
    rev: Optional[PackedOperands]
    n_src: int
    n_dst: int
    n_src_pad: int
    n_dst_pad: int


@partial(
    jax.tree_util.register_dataclass,
    data_fields=[
        "chains", "direct", "correction", "diag_mult",
        "fused_fwd", "fused_rev",
    ],
    meta_fields=[
        "n_real", "deduplicated", "backend", "feature_block",
        "graph_version", "fused_standdown",
    ],
)
@dataclasses.dataclass
class DevicePacked:
    """A :class:`DeviceCondensed` whose layers carry packed SpMM operands.

    Identical propagation semantics; batched (``(n, B)``) steps under any
    kernelizable semiring (plus-times, min-plus, max-times, or-and), in
    either direction, are dispatched to :func:`repro.kernels.bitmap_spmm.
    bitmap_spmm_pallas` per layer when ``backend`` resolves to Pallas
    (DESIGN.md §6).  ``backend``: ``'pallas'`` | ``'xla'`` | ``'auto'``
    (the measured-crossover table recorded at pack time when present,
    else Pallas on TPU when the streamed working set fits VMEM — XLA
    segment-reduce otherwise).

    ``fused_fwd`` / ``fused_rev`` carry the fused last-layer +
    DEDUP-C-epilogue operands (one per direction) when the graph has a
    correction; ring propagation then runs the subtraction inside the
    kernel instead of as a separate segment_sum pass.  When they could
    *not* be built, ``fused_standdown`` records the machine-readable
    pack-time reason (``''`` when built; e.g. ``'unpackable_last_layer'``
    — see :func:`_build_fused`), so dispatch-honesty tests pin why a
    graph stood down instead of guessing.  Further trace-time stand-downs
    (1-D frontier, non-ring semiring, ``hop_weight``) are counted per
    reason in :data:`KERNEL_STANDDOWN_COUNT`.

    ``graph_version`` is the source graph's delta version (DESIGN.md §9):
    static pytree meta, so a version bump invalidates every compiled
    executable and cached packed operand keyed on this graph.
    """

    chains: Tuple[Tuple[DevicePackedLayer, ...], ...]
    direct: Optional[DevicePackedLayer]
    correction: Optional[Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]]
    diag_mult: Optional[jnp.ndarray]
    n_real: int
    deduplicated: bool
    backend: str
    feature_block: int
    fused_fwd: Optional[FusedOperands] = None
    fused_rev: Optional[FusedOperands] = None
    graph_version: int = 0
    fused_standdown: str = ""


DeviceGraph = Union[DeviceExpanded, DeviceCondensed, DevicePacked]


# ---------------------------------------------------------------------------
# Residency accounting and version-keyed dispatch (DESIGN.md §10)
# ---------------------------------------------------------------------------

def with_graph_version(graph: DeviceGraph, version: int) -> DeviceGraph:
    """The same device graph stamped with a different delta version.

    ``graph_version`` is static pytree metadata (it invalidates compiled
    executables by changing the jit cache key), so two stamps of the same
    arrays are distinct trace keys.  The serving tier uses this both ways:
    re-stamping an upload after :meth:`~repro.core.delta.LiveGraph.
    apply_delta`, and *normalizing* the version to 0 before dispatching a
    cached executable — staleness is enforced by the version-keyed result
    cache at admission, so the executable itself may be shared by every
    version (and every tenant) with the same shape signature."""
    return dataclasses.replace(graph, graph_version=int(version))


def device_graph_bytes(graph: DeviceGraph) -> int:
    """Device bytes held by one uploaded graph: the sum over every pytree
    leaf (edge arrays, packed bitmaps, fused operand streams, correction
    triples).  This is the unit the serving tier's :class:`ResidencyBudget`
    charges per resident tenant."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(graph):
        nbytes = getattr(leaf, "nbytes", None)
        total += int(nbytes) if nbytes is not None else np.asarray(leaf).nbytes
    return total


def graph_shape_signature(graph: DeviceGraph) -> str:
    """Hashable signature of a device graph's *compiled shape*: the pytree
    structure (version normalized to 0) plus every leaf's shape and dtype.

    Two graphs with equal signatures produce identical jit trace keys, so
    a compiled propagation executable for one serves the other without
    re-tracing — the key of the serving tier's executable cache
    ``(kind, bucket, signature)`` (DESIGN.md §10).  The signature excludes
    ``graph_version`` on purpose: version churn under a live delta stream
    must not churn executables (staleness lives in the result cache)."""
    import hashlib

    leaves, treedef = jax.tree_util.tree_flatten(with_graph_version(graph, 0))
    parts = [str(treedef)]
    for leaf in leaves:
        shape = tuple(getattr(leaf, "shape", np.asarray(leaf).shape))
        dtype = getattr(leaf, "dtype", np.asarray(leaf).dtype)
        parts.append(f"{shape}:{dtype}")
    return hashlib.sha1("|".join(parts).encode()).hexdigest()


class ResidencyError(RuntimeError):
    """A device-graph upload cannot fit the residency budget even after
    every evictable tenant has been evicted (a single graph larger than
    ``max_device_bytes`` is unsatisfiable — raise, never thrash)."""


@dataclasses.dataclass
class ResidencyBudget:
    """Device-byte accounting for multi-graph serving residency.

    The serving twin of :class:`repro.core.planner.ExtractionBudget`'s
    assembly account (same charge/release discipline, bytes not rows):
    every resident tenant's packed operands are charged while on device,
    ``peak_resident_bytes`` bounds what the device ever held at once, and
    the LRU eviction traffic is recorded so benches and tests can assert
    the budget actually did work (``n_evictions > 0`` under pressure) —
    not just that answers came back.

    :meth:`charge` raises :class:`ResidencyError` on a violating upload;
    the serving tier evicts least-recently-used tenants *before* charging,
    so a raise here means a single graph exceeds the whole budget."""

    max_device_bytes: Optional[int] = None
    resident_bytes: int = 0          # live: bytes currently on device
    peak_resident_bytes: int = 0     # max resident_bytes ever observed
    uploaded_bytes: int = 0          # total bytes ever uploaded
    evicted_bytes: int = 0           # total bytes freed by eviction
    n_uploads: int = 0
    n_evictions: int = 0

    def would_fit(self, nbytes: int) -> bool:
        return (
            self.max_device_bytes is None
            or self.resident_bytes + int(nbytes) <= self.max_device_bytes
        )

    def charge(self, nbytes: int, what: str = "device graph") -> None:
        nbytes = int(nbytes)
        if not self.would_fit(nbytes):
            raise ResidencyError(
                f"residency budget exceeded: {self.resident_bytes} resident "
                f"+ {nbytes} uploading ({what}) > max_device_bytes="
                f"{self.max_device_bytes}; evict a tenant or raise the budget"
            )
        self.resident_bytes += nbytes
        self.uploaded_bytes += nbytes
        self.n_uploads += 1
        if self.resident_bytes > self.peak_resident_bytes:
            self.peak_resident_bytes = self.resident_bytes

    def release(self, nbytes: int, evicted: bool = False) -> None:
        self.resident_bytes -= int(nbytes)
        assert self.resident_bytes >= 0, "released more bytes than charged"
        if evicted:
            self.evicted_bytes += int(nbytes)
            self.n_evictions += 1


# ---------------------------------------------------------------------------
# Host -> device conversion
# ---------------------------------------------------------------------------

def _dev_edges(e: BipartiteEdges) -> DeviceBipartite:
    return DeviceBipartite(
        jnp.asarray(e.src, dtype=jnp.int32),
        jnp.asarray(e.dst, dtype=jnp.int32),
        e.n_src,
        e.n_dst,
    )


def self_path_counts(graph: CondensedGraph) -> np.ndarray:
    """Host: number of closed u->u paths per real node (diagonal of M)."""
    diag = np.zeros(graph.n_real, dtype=np.int64)
    for chain in graph.chains:
        if chain.n_layers == 1:
            e_in, e_out = chain.edges
            # Join (u, V) with (V, u): count matching (V, u) occurrences.
            key_in = e_in.dst.astype(np.int64) * graph.n_real + e_in.src
            key_out = e_out.src.astype(np.int64) * graph.n_real + e_out.dst
            key_out_sorted = np.sort(key_out)
            lo = np.searchsorted(key_out_sorted, key_in, side="left")
            hi = np.searchsorted(key_out_sorted, key_in, side="right")
            np.add.at(diag, e_in.src, (hi - lo))
        else:
            s, d, m = chain.path_pairs()
            mask = s == d
            np.add.at(diag, s[mask], m[mask])
    if graph.direct is not None and graph.direct.n_edges:
        mask = graph.direct.src == graph.direct.dst
        np.add.at(diag, graph.direct.src[mask], 1)
    return diag


def to_device(
    graph: Union[CondensedGraph, ExpandedGraph],
    correction: Optional[Correction] = None,
    deduplicated: bool = False,
    drop_self_loops: bool = True,
    graph_version: int = 0,
) -> DeviceGraph:
    """Build the device representation.

    For ``CondensedGraph`` inputs, pass ``correction`` (the triples from
    :func:`repro.core.dedup.build_correction` or a
    :class:`~repro.core.dedup.StreamedCorrection` built under a budget by
    :func:`~repro.core.dedup.build_correction_streaming`) to get DEDUP-C
    semantics, or ``deduplicated=True`` for DEDUP-1 output.  Without
    either, ring propagation counts duplicate paths (C-DUP semantics) —
    fine for idempotent algorithms, flagged by :func:`propagate`
    otherwise.

    ``graph_version`` stamps the upload with the live graph's delta
    version (:class:`repro.core.delta.GraphVersion`, DESIGN.md §9); it is
    static pytree meta, so re-uploading after ``apply_delta`` changes the
    jit cache key and every stale compiled executable dies with it.
    """
    if isinstance(graph, ExpandedGraph):
        g = graph.without_self_loops() if drop_self_loops else graph
        return DeviceExpanded(
            jnp.asarray(g.src, dtype=jnp.int32),
            jnp.asarray(g.dst, dtype=jnp.int32),
            jnp.minimum(jnp.asarray(g.multiplicity, dtype=jnp.float32), 1.0),
            g.n,
            graph_version=int(graph_version),
        )
    chains = tuple(tuple(_dev_edges(e) for e in c.edges) for c in graph.chains)
    direct = _dev_edges(graph.direct) if graph.direct is not None else None
    corr = None
    triples = _correction_triples(correction)
    if triples is not None:
        cs, cd, cm = triples
        corr = (
            jnp.asarray(cs, dtype=jnp.int32),
            jnp.asarray(cd, dtype=jnp.int32),
            jnp.asarray(cm, dtype=jnp.float32),
        )
    diag = None
    if drop_self_loops and corr is None:
        # Full self-path multiplicity: DEDUP-1's uniqueness invariant is
        # off-diagonal only — u reaches itself once per containing virtual
        # node, and all of those must be subtracted.
        diag = jnp.asarray(self_path_counts(graph), dtype=jnp.float32)
    return DeviceCondensed(
        chains=chains,
        direct=direct,
        correction=corr,
        diag_mult=diag,
        n_real=graph.n_real,
        deduplicated=deduplicated,
        graph_version=int(graph_version),
    )


def _upload_operands(bsb, crossover=None) -> PackedOperands:
    return PackedOperands(
        slot_src=jnp.asarray(bsb.slot_src),
        slot_row=jnp.asarray(bsb.slot_row),
        row_start=jnp.asarray(bsb.row_start),
        row_count=jnp.asarray(bsb.row_count),
        bitmaps=jnp.asarray(bsb.bitmaps),
        crossover=crossover,
    )


def _measure_direction(bsb, dev_src, dev_dst, n_src, n_dst, measure_kwargs):
    """Record a crossover table for one packed direction by racing the
    kernel (autotuned) against the segment path on this host."""
    from ..kernels.autotune import measure_crossover
    from ..kernels.ops import PackedLayer

    layer = PackedLayer(
        bsb=bsb,
        bsb_rev=None,
        src=dev_src,
        dst=dev_dst,
        n_src=n_src,
        n_dst=n_dst,
    )
    return measure_crossover(layer, **measure_kwargs)


def _pack_edges(
    e: BipartiteEdges,
    dev: DeviceBipartite,
    shard_edges: Optional[int] = None,
    measure: bool = False,
    measure_kwargs: Optional[dict] = None,
    pack_method: str = "reduceat",
):
    """``dev`` is the already-uploaded COO layer from :func:`to_device`,
    reused so the edge arrays cross to the device only once.  Packs both
    directions: the forward incidence and its transpose (reverse steps).
    ``shard_edges`` routes the packing through the shard-at-a-time path
    (:func:`repro.kernels.pack.pack_bipartite` slices + OR-merge,
    DESIGN.md §7) so packing transients stay bounded for large layers.
    ``measure`` additionally races each direction against the segment
    path and stores the crossover table on the uploaded operands.

    Returns ``(DevicePackedLayer, fwd_bsb, rev_bsb)`` — the host-side
    packings ride along so :func:`to_device_packed` can build the fused
    correction stream without re-packing."""
    from ..kernels.pack import TILE, pack_bipartite

    fwd = rev = None
    fwd_bsb = rev_bsb = None
    # min one tile each way, matching the pack's pad-slot convention
    # (BlockSparseBitmap.n_src_tiles): zero-node layers stay kernel-safe
    n_src_pad = max(-(-e.n_src // TILE), 1) * TILE
    n_dst_pad = max(-(-e.n_dst // TILE), 1) * TILE
    try:
        fwd_bsb = pack_bipartite(e, method=pack_method, shard_edges=shard_edges)
        rev_bsb = pack_bipartite(
            e.reversed(), method=pack_method, shard_edges=shard_edges
        )
        fwd_table = rev_table = None
        if measure:
            kw = measure_kwargs or {}
            fwd_table = _measure_direction(
                fwd_bsb, dev.src, dev.dst, e.n_src, e.n_dst, kw
            )
            rev_table = _measure_direction(
                rev_bsb, dev.dst, dev.src, e.n_dst, e.n_src, kw
            )
        fwd = _upload_operands(fwd_bsb, fwd_table)
        rev = _upload_operands(rev_bsb, rev_table)
    except ValueError:
        fwd = rev = None  # duplicate edges (multiplicity): COO path only
        fwd_bsb = rev_bsb = None
    layer = DevicePackedLayer(
        src=dev.src,
        dst=dev.dst,
        fwd=fwd,
        rev=rev,
        n_src=e.n_src,
        n_dst=e.n_dst,
        n_src_pad=n_src_pad,
        n_dst_pad=n_dst_pad,
    )
    return layer, fwd_bsb, rev_bsb


def _upload_fused(stream, main_bsb, corr_planes) -> FusedOperands:
    from ..kernels.pack import TILE

    return FusedOperands(
        kind=jnp.asarray(stream.kind),
        main_src=jnp.asarray(stream.main_src),
        corr_src=jnp.asarray(stream.corr_src),
        main_idx=jnp.asarray(stream.main_idx),
        corr_idx=jnp.asarray(stream.corr_idx),
        slot_row=jnp.asarray(stream.slot_row),
        row_start=jnp.asarray(stream.row_start),
        row_count=jnp.asarray(stream.row_count),
        bitmaps=jnp.asarray(main_bsb.bitmaps),
        planes=jnp.asarray(corr_planes.planes),
        plane_weights=corr_planes.plane_weights,
        n_h_pad=main_bsb.n_src_tiles * TILE,
        n_x_pad=corr_planes.n_src_tiles * TILE,
        n_out=main_bsb.n_dst,
        n_out_pad=main_bsb.n_row_tiles * TILE,
    )


def _build_fused(
    graph: CondensedGraph,
    chains_host,
    triples: Tuple[np.ndarray, np.ndarray, np.ndarray],
) -> Tuple[Optional[FusedOperands], Optional[FusedOperands], str]:
    """Build the fused (last layer + DEDUP-C epilogue) operands for both
    directions.  Forward fuses into the last chain's final layer (the one
    whose output space is the real nodes); reverse propagation walks each
    chain backwards, so its final step is the same chain's *first* layer
    transposed.  Requires that layer to be packable (no duplicates).

    Returns ``(fused_fwd, fused_rev, standdown_reason)`` — the reason is
    ``''`` when the operands were built and otherwise one of the
    machine-readable pack-time stand-down reasons recorded on
    :attr:`DevicePacked.fused_standdown`:

    * ``'no_chains_or_empty_correction'`` — nothing to fuse into, or a
      correction with zero triples (the epilogue would be a no-op);
    * ``'unpackable_last_layer'`` — the fusing layer has duplicate edges
      and cannot be bit-packed;
    * ``'endpoint_mismatch'`` — the fusing layer's output space is not
      the real-node space (the correction subtracts over real nodes).
    """
    from ..kernels.correction import build_fused_stream, pack_correction

    cs, cd, cm = triples
    if not graph.chains or cs.size == 0:
        return None, None, "no_chains_or_empty_correction"
    _, last_fwd_bsb, _ = chains_host[-1][-1]
    _, _, first_rev_bsb = chains_host[-1][0]
    if last_fwd_bsb is None or first_rev_bsb is None:
        return None, None, "unpackable_last_layer"
    n = graph.n_real
    if last_fwd_bsb.n_dst != n or first_rev_bsb.n_dst != n:
        return None, None, "endpoint_mismatch"
    corr_fwd = pack_correction(cs, cd, cm, n_src=n, n_dst=n)
    corr_rev = pack_correction(cd, cs, cm, n_src=n, n_dst=n)
    fused_fwd = _upload_fused(
        build_fused_stream(last_fwd_bsb, corr_fwd), last_fwd_bsb, corr_fwd
    )
    fused_rev = _upload_fused(
        build_fused_stream(first_rev_bsb, corr_rev), first_rev_bsb, corr_rev
    )
    return fused_fwd, fused_rev, ""


def to_device_packed(
    graph: CondensedGraph,
    correction: Optional[Correction] = None,
    deduplicated: bool = False,
    drop_self_loops: bool = True,
    backend: str = "auto",
    feature_block: int = 128,
    pack_shard_edges: Optional[int] = None,
    fuse_correction: bool = True,
    measure: bool = False,
    measure_kwargs: Optional[dict] = None,
    graph_version: int = 0,
    pack_method: str = "reduceat",
) -> DevicePacked:
    """Like :func:`to_device`, additionally packing every condensed layer
    into bit-packed block-sparse SpMM operands (DESIGN.md §6) so batched
    ring propagation runs on the Pallas kernel.  Correction / dedup
    semantics are identical to :func:`to_device` (streamed corrections
    accepted the same way).  ``pack_shard_edges`` bounds the host packing
    transients per layer (shard-at-a-time packing, DESIGN.md §7) — the
    uploaded operands are byte-identical either way.

    ``fuse_correction`` (default on) also builds the fused last-layer +
    DEDUP-C-epilogue operands when a correction is present, so batched
    ring propagation subtracts the correction inside the kernel.
    ``measure=True`` races each packed direction against the segment path
    at pack time and records the crossover table on the operands
    (:mod:`repro.kernels.autotune`); 'auto' dispatch then follows the
    measurement.  ``measure_kwargs`` forwards to
    :func:`~repro.kernels.autotune.measure_crossover` (batch sizes, ops,
    a deterministic ``time_fn`` for tests).  ``pack_method`` selects the
    host-side pack fold (``'reduceat'`` | ``'scatter'``, a cost-model
    knob — DESIGN.md §12); the packed operands are byte-identical either
    way.
    """
    base = to_device(
        graph,
        correction=correction,
        deduplicated=deduplicated,
        drop_self_loops=drop_self_loops,
    )
    assert isinstance(base, DeviceCondensed)
    chains_host = tuple(
        tuple(
            _pack_edges(
                e, d, pack_shard_edges, measure, measure_kwargs, pack_method
            )
            for e, d in zip(c.edges, dc)
        )
        for c, dc in zip(graph.chains, base.chains)
    )
    chains = tuple(tuple(t[0] for t in c) for c in chains_host)
    direct = (
        _pack_edges(
            graph.direct, base.direct, pack_shard_edges, measure,
            measure_kwargs, pack_method,
        )[0]
        if graph.direct is not None
        else None
    )
    fused_fwd = fused_rev = None
    triples = _correction_triples(correction)
    if triples is None:
        standdown = "no_correction"
    elif not fuse_correction:
        standdown = "fuse_correction_disabled"
    else:
        cs, cd, cm = triples
        fused_fwd, fused_rev, standdown = _build_fused(
            graph,
            chains_host,
            (np.asarray(cs), np.asarray(cd), np.asarray(cm)),
        )
    return DevicePacked(
        chains=chains,
        direct=direct,
        correction=base.correction,
        diag_mult=base.diag_mult,
        n_real=graph.n_real,
        deduplicated=deduplicated,
        backend=backend,
        feature_block=feature_block,
        fused_fwd=fused_fwd,
        fused_rev=fused_rev,
        graph_version=int(graph_version),
        fused_standdown=standdown,
    )


# ---------------------------------------------------------------------------
# Propagation
# ---------------------------------------------------------------------------

def _gather(x: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(x, idx, axis=0)


def _edge_propagate(
    sr: Semiring,
    edges: DeviceBipartite,
    x: jnp.ndarray,
    reverse: bool,
) -> jnp.ndarray:
    src, dst = (edges.dst, edges.src) if reverse else (edges.src, edges.dst)
    n_out = edges.n_src if reverse else edges.n_dst
    return segment_reduce(sr, _gather(x, src), dst, n_out)


def _kernel_applicable(
    graph: "DevicePacked",
    layer: DevicePackedLayer,
    x: jnp.ndarray,
    semiring: Semiring,
    reverse: bool,
) -> bool:
    """Static (trace-time) dispatch: batched kernelizable steps, both
    directions.

    The streamed-window VMEM footprint (DESIGN.md §6) is shared with
    kernels.ops via kernels.pack (imported lazily — the kernels package
    pulls in the Pallas stack); since the source column is streamed, the
    formula no longer depends on the source count, so the old 8 MiB
    resident-column cliff is gone.  The two 'auto' policies intentionally
    differ in one respect: the engine only selects Pallas on a real TPU
    (interpret mode is for explicit backend='pallas' testing), while the
    standalone ops wrapper will run interpret mode anywhere.
    """
    if x.ndim != 2 or not kernelizable(semiring):
        return False
    packed = layer.rev if reverse else layer.fwd
    if packed is None:
        return False
    if graph.backend == "pallas":
        return True
    if graph.backend == "xla":
        return False
    from ..kernels.pack import fits_vmem

    n_slots = int(packed.slot_src.shape[0])
    if packed.crossover is not None:
        # measured decision wins over both heuristics: the table was
        # recorded on this host, so a measured-pallas cell dispatches
        # even off-TPU (only sanity-checked against the VMEM budget of
        # its recorded config), and a measured-xla cell never dispatches
        # no matter what the footprint formula says
        n_src_dir = layer.n_dst if reverse else layer.n_src
        entry = packed.crossover.lookup(
            semiring.add_kind, n_src_dir, x.shape[1]
        )
        if entry is not None:
            if entry.backend == "xla":
                return False
            return fits_vmem(
                x.shape[1],
                entry.feature_block,
                x.dtype.itemsize,
                n_slots=n_slots,
                row_window=entry.row_window,
            )
    fits = fits_vmem(
        x.shape[1],
        graph.feature_block,
        x.dtype.itemsize,
        n_slots=n_slots,
    )
    return jax.default_backend() == "tpu" and fits


def _packed_layer_spmm(
    layer: DevicePackedLayer,
    x: jnp.ndarray,
    feature_block: int,
    semiring: Semiring,
    reverse: bool,
) -> jnp.ndarray:
    """One layer of the factorized SpMM ``Y = B ⊕ X`` on the Pallas kernel.

    The kernel window geometry comes from the operands' crossover table
    when one was recorded (the measured-fastest config for this cell);
    unmeasured packs stream the default ``(TILE, feature_block)`` window.
    """
    from ..kernels.bitmap_spmm import bitmap_spmm_pallas
    from ..kernels.pack import TILE

    global KERNEL_DISPATCH_COUNT
    KERNEL_DISPATCH_COUNT += 1
    ops = layer.rev if reverse else layer.fwd
    n_in_pad = layer.n_dst_pad if reverse else layer.n_src_pad
    n_out_pad = layer.n_src_pad if reverse else layer.n_dst_pad
    n_out = layer.n_src if reverse else layer.n_dst
    row_window = TILE
    if ops.crossover is not None:
        n_src_dir = layer.n_dst if reverse else layer.n_src
        entry = ops.crossover.lookup(semiring.add_kind, n_src_dir, x.shape[1])
        if entry is not None and entry.backend == "pallas":
            row_window = entry.row_window
            feature_block = entry.feature_block
    f = x.shape[1]
    f_pad = -(-f // feature_block) * feature_block
    # a >TILE window streams several source tiles per fetch: the source
    # axis must pad to a whole number of windows
    n_in_pad = -(-n_in_pad // row_window) * row_window
    xp = jnp.pad(x, ((0, n_in_pad - x.shape[0]), (0, f_pad - f)))
    yp = bitmap_spmm_pallas(
        ops.slot_src,
        ops.slot_row,
        ops.row_start,
        ops.row_count,
        ops.bitmaps,
        xp,
        n_dst_pad=n_out_pad,
        feature_block=feature_block,
        op=semiring.add_kind,
        zero=float(semiring.zero),
        row_window=row_window,
    )
    return yp[:n_out, :f]


def _layer_propagate(
    graph: DeviceGraph,
    sr: Semiring,
    edges,
    x: jnp.ndarray,
    reverse: bool,
) -> jnp.ndarray:
    if isinstance(graph, DevicePacked) and _kernel_applicable(
        graph, edges, x, sr, reverse
    ):
        return _packed_layer_spmm(edges, x, graph.feature_block, sr, reverse)
    return _edge_propagate(sr, edges, x, reverse)


def _fused_applicable(
    graph: "DevicePacked",
    fused: Optional[FusedOperands],
    x: jnp.ndarray,
    semiring: Semiring,
    hop_weight: Optional[float],
) -> Tuple[bool, str]:
    """Trace-time fused-epilogue dispatch: batched plus-times ring steps
    only (the correction is a ring concept), no per-hop weighting (the
    fused output folds the subtraction into one chain's hop, which only
    commutes unweighted), and the same backend policy as the per-layer
    kernel (explicit 'pallas' always, 'xla' never, 'auto' on TPU when the
    fused working set — two streamed feature operands, the plane stack,
    two accumulators — fits VMEM).

    Returns ``(dispatch, reason)``: ``(True, '')`` when the fused kernel
    runs, else ``False`` plus the machine-readable stand-down reason —
    the pack-time :attr:`DevicePacked.fused_standdown` when the operands
    were never built, or one of ``'frontier_1d'`` /
    ``'semiring_<name>'`` / ``'hop_weight'`` / ``'backend_xla'`` /
    ``'vmem_or_backend'`` for trace-time declines.  :func:`propagate`
    counts each miss under its reason in
    :data:`KERNEL_STANDDOWN_COUNT`."""
    if fused is None:
        return False, graph.fused_standdown or "not_built"
    if x.ndim != 2:
        return False, "frontier_1d"
    if semiring.name != "plus_times":
        return False, f"semiring_{semiring.name}"
    if hop_weight is not None:
        return False, "hop_weight"
    if graph.backend == "pallas":
        return True, ""
    if graph.backend == "xla":
        return False, "backend_xla"
    from ..kernels.pack import fused_fits_vmem

    fits = fused_fits_vmem(
        x.shape[1],
        graph.feature_block,
        x.dtype.itemsize,
        n_planes=len(fused.plane_weights),
        n_slots=int(fused.kind.shape[0]),
    )
    if jax.default_backend() == "tpu" and fits:
        return True, ""
    return False, "vmem_or_backend"


def _fused_layer_spmm(
    fused: FusedOperands,
    h: jnp.ndarray,
    x: jnp.ndarray,
    feature_block: int,
) -> jnp.ndarray:
    """The last layer of the last chain with the DEDUP-C subtraction in
    the kernel epilogue: ``y = B h − D x`` in one launch."""
    from ..kernels.bitmap_spmm import bitmap_spmm_fused_pallas

    global KERNEL_DISPATCH_COUNT
    KERNEL_DISPATCH_COUNT += 1
    f = h.shape[1]
    f_pad = -(-f // feature_block) * feature_block
    hp = jnp.pad(h, ((0, fused.n_h_pad - h.shape[0]), (0, f_pad - f)))
    xp = jnp.pad(x, ((0, fused.n_x_pad - x.shape[0]), (0, f_pad - f)))
    yp = bitmap_spmm_fused_pallas(
        fused.kind,
        fused.main_src,
        fused.corr_src,
        fused.main_idx,
        fused.corr_idx,
        fused.slot_row,
        fused.row_start,
        fused.row_count,
        fused.bitmaps,
        fused.planes,
        hp,
        xp,
        n_dst_pad=fused.n_out_pad,
        plane_weights=fused.plane_weights,
        feature_block=feature_block,
    )
    return yp[: fused.n_out, :f]


def _apply_hop(sr: Semiring, y: jnp.ndarray, hop_weight: Optional[float]) -> jnp.ndarray:
    if hop_weight is None:
        return y
    return sr.mul(y, jnp.asarray(hop_weight, dtype=y.dtype))


def propagate(
    graph: DeviceGraph,
    x: jnp.ndarray,
    semiring: Semiring = PLUS_TIMES,
    *,
    reverse: bool = False,
    hop_weight: Optional[float] = None,
    allow_duplicates: bool = False,
    layer_weights: Optional[Tuple[Tuple[jnp.ndarray, ...], ...]] = None,
) -> jnp.ndarray:
    """One superstep: ⊕-combine ⊗-weighted messages along all edges.

    ``x`` is one frontier ``(n,)`` or a batch of ``B`` frontiers ``(n, B)``
    processed in a single factorized SpMM; per-column results equal ``B``
    independent single-frontier calls (DESIGN.md §3).  ``hop_weight`` is
    applied once per *logical* (real->real) hop, not per condensed layer,
    so BFS hop counting matches the expanded graph.

    ``layer_weights`` carries edge properties on condensed chains
    (DESIGN.md §11): one tuple per chain, one ``(layer_size,)`` array per
    *virtual* layer, ⊗-applied to the hidden frontier while it occupies
    that layer.  A condensed path's weight is then the ⊗-product of its
    virtual-node properties (min-plus: path cost = Σ weights; max-min:
    path width = min capacity), while every incidence step stays an
    unweighted SpMM — so :func:`~repro.core.semiring.kernelizable`
    packed/Pallas dispatch is unaffected.  Direct edges carry no virtual
    node, hence the weight identity (``semiring.one``).  Only idempotent
    semirings are supported (the DEDUP-C correction algebra is
    multiplicity-based and does not extend to weighted ring sums).
    """
    n_in = graph.n if isinstance(graph, DeviceExpanded) else graph.n_real
    if x.ndim not in (1, 2) or x.shape[0] != n_in:
        raise ValueError(
            f"frontier must be ({n_in},) or ({n_in}, B); got shape {x.shape}"
        )
    x = shard_frontier(x)
    if layer_weights is not None:
        if isinstance(graph, DeviceExpanded):
            raise ValueError(
                "layer_weights are condensed-chain edge properties; the "
                "expanded representation needs them folded into a dense "
                "weighted matrix instead (tests/oracle.py does exactly that)"
            )
        if not semiring.idempotent:
            raise ValueError(
                "layer_weights require an idempotent semiring: the ring "
                "correction (DEDUP-C) subtracts path multiplicities and "
                "has no weighted analogue"
            )
        if len(layer_weights) != len(graph.chains):
            raise ValueError(
                f"layer_weights must cover all {len(graph.chains)} chains; "
                f"got {len(layer_weights)}"
            )
        for ci, (cw, chain) in enumerate(zip(layer_weights, graph.chains)):
            if len(cw) != len(chain) - 1:
                raise ValueError(
                    f"chain {ci} has {len(chain) - 1} virtual layers; got "
                    f"{len(cw)} weight arrays"
                )
    if isinstance(graph, DeviceExpanded):
        src, dst = (graph.dst, graph.src) if reverse else (graph.src, graph.dst)
        msgs = _gather(x, src)
        if semiring.name == "plus_times":
            msgs = msgs * _bcast(graph.weight, msgs)
        y = segment_reduce(semiring, msgs, dst, graph.n)
        return shard_frontier(_apply_hop(semiring, y, hop_weight))

    assert isinstance(graph, (DeviceCondensed, DevicePacked))
    exact = (
        semiring.idempotent
        or graph.deduplicated
        or graph.correction is not None
    )
    if not exact and not allow_duplicates:
        raise ValueError(
            "ring propagation on C-DUP counts duplicate paths; pass a "
            "correction (DEDUP-C), a deduplicated graph (DEDUP-1), or "
            "allow_duplicates=True (paper §4.1 duplication problem)"
        )

    # Fused DEDUP-C epilogue (DESIGN.md §6): the last chain's final layer
    # and the correction subtraction run as one kernel launch; the
    # trailing segment_sum correction below is then skipped.
    fused = None
    if isinstance(graph, DevicePacked) and graph.correction is not None:
        cand = graph.fused_rev if reverse else graph.fused_fwd
        ok, reason = _fused_applicable(graph, cand, x, semiring, hop_weight)
        if ok:
            fused = cand
        else:
            KERNEL_STANDDOWN_COUNT[reason] = (
                KERNEL_STANDDOWN_COUNT.get(reason, 0) + 1
            )

    y = None
    for ci, chain in enumerate(graph.chains):
        seq: Sequence[DeviceBipartite] = chain[::-1] if reverse else chain
        w_seq: Optional[Sequence[jnp.ndarray]] = None
        if layer_weights is not None:
            # weight i lives on virtual layer i; walking the chain
            # backwards visits the layers in reverse order
            cw = layer_weights[ci]
            w_seq = cw[::-1] if reverse else cw
        h = x
        fuse_here = fused is not None and ci == len(graph.chains) - 1
        for si, e in enumerate(seq[:-1] if fuse_here else seq):
            h = _layer_propagate(graph, semiring, e, h, reverse)
            if w_seq is not None and si < len(seq) - 1:
                h = semiring.mul(h, _bcast(jnp.asarray(w_seq[si]), h))
        if fuse_here:
            h = _fused_layer_spmm(fused, h, x, graph.feature_block)
        h = _apply_hop(semiring, h, hop_weight)
        y = h if y is None else semiring.add(y, h)
    if graph.direct is not None:
        h = _layer_propagate(graph, semiring, graph.direct, x, reverse)
        h = _apply_hop(semiring, h, hop_weight)
        y = h if y is None else semiring.add(y, h)
    if y is None:
        zero_shape = (graph.n_real,) + x.shape[1:]
        y = jnp.full(zero_shape, semiring.zero, dtype=x.dtype)

    if semiring.name == "plus_times":
        # Exactness corrections only make sense in the ring.
        if graph.correction is not None and fused is not None:
            pass  # already subtracted inside the fused kernel epilogue
        elif graph.correction is not None:
            cs, cd, cm = graph.correction
            src, dst = (cd, cs) if reverse else (cs, cd)
            corr = jax.ops.segment_sum(
                _gather(x, src) * _bcast(cm, _gather(x, src)),
                dst,
                num_segments=graph.n_real,
            )
            y = y - _apply_hop(semiring, corr, hop_weight)
        elif graph.diag_mult is not None:
            y = y - _apply_hop(
                semiring, x * _bcast(graph.diag_mult, x), hop_weight
            )
    return shard_frontier(y)


def _correction_apply(
    triples: Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray],
    x: jnp.ndarray,
    n_real: int,
    reverse: bool,
) -> jnp.ndarray:
    """``D·x`` (or ``Dᵀ·x``) for a sparse (src, dst, count) triple set."""
    cs, cd, cm = triples
    src, dst = (cd, cs) if reverse else (cs, cd)
    return jax.ops.segment_sum(
        _gather(x, src) * _bcast(cm, _gather(x, src)), dst, num_segments=n_real
    )


def propagate_wedge(
    graph: DeviceGraph,
    x: jnp.ndarray,
    *,
    reverse: bool = False,
    wedge: Optional[Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]] = None,
) -> jnp.ndarray:
    """Exact two-hop ring propagation ``y = Aᵀ(Aᵀx)`` on a DEDUP-C graph
    from *uncorrected* C-DUP hops (DESIGN.md §11).

    The linear DEDUP-C identity ``A = M − D`` composes quadratically:

        ``A² = (M − D)² = M² − (MD + DM − D²)``

    so the exact wedge count is two raw multiplicity hops (each a plain
    kernel-path SpMM — no per-step correction subtraction, no fused
    epilogue needed) minus the *wedge correction* ``W = MD + DM − D²`` —
    the duplicate wedges whose legs are multiple condensed paths through
    shared virtual nodes.  With ``wedge`` triples precomputed by
    :func:`repro.core.dedup.build_wedge_correction` the correction is one
    sparse pass (``y = M(Mx) − Wx``); without them it is assembled on the
    fly from the graph's own ``D`` triples
    (``y = M(Mx) − M(Dx) − D(Mx) + D(Dx)``).  Byte-identical to two
    per-step-corrected :func:`propagate` calls on integer frontiers.
    """
    if isinstance(graph, DeviceExpanded):
        y = propagate(graph, x, PLUS_TIMES, reverse=reverse)
        return propagate(graph, y, PLUS_TIMES, reverse=reverse)
    if graph.correction is None:
        if graph.deduplicated:
            y = propagate(graph, x, PLUS_TIMES, reverse=reverse)
            return propagate(graph, y, PLUS_TIMES, reverse=reverse)
        raise ValueError(
            "propagate_wedge needs a DEDUP-C correction: the quadratic "
            "wedge correction is built from the linear D triples"
        )
    raw = dataclasses.replace(graph, correction=None, diag_mult=None)
    mx = propagate(raw, x, PLUS_TIMES, reverse=reverse, allow_duplicates=True)
    mmx = propagate(raw, mx, PLUS_TIMES, reverse=reverse, allow_duplicates=True)
    if wedge is not None:
        return shard_frontier(
            mmx - _correction_apply(wedge, x, graph.n_real, reverse)
        )
    dx = _correction_apply(graph.correction, x, graph.n_real, reverse)
    mdx = propagate(raw, dx, PLUS_TIMES, reverse=reverse, allow_duplicates=True)
    dmx = _correction_apply(graph.correction, mx, graph.n_real, reverse)
    ddx = _correction_apply(graph.correction, dx, graph.n_real, reverse)
    return shard_frontier(mmx - mdx - dmx + ddx)


def _bcast(w: jnp.ndarray, like: jnp.ndarray) -> jnp.ndarray:
    """Broadcast per-edge/per-node weight against feature matrices."""
    if like.ndim == w.ndim:
        return w.astype(like.dtype)
    return w.astype(like.dtype).reshape(w.shape + (1,) * (like.ndim - w.ndim))
