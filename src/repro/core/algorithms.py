"""Graph algorithms over any device representation (paper §3.4, §6.1.2).

Each algorithm is a pure function of a :class:`~repro.core.engine.DeviceGraph`
pytree, jit-compatible, and by construction produces identical results on
EXP / DEDUP-1 / DEDUP-C (duplicate-sensitive) or additionally on raw C-DUP
(duplicate-insensitive: BFS, connected components, reachability).

**Batched multi-source variants** (DESIGN.md §3): :func:`bfs_multi`,
:func:`reachable_multi`, :func:`personalized_pagerank` over a seed batch,
and :func:`common_neighbors_multi` run ``B`` independent analyses as one
``(n, B)`` frontier through the engine — a single factorized SpMM per
superstep instead of ``B`` serial traversals, with one *shared*
vote-to-halt across the batch (supersteps continue while any column is
still active; finished columns are fixed points of their own updates, so
extra supersteps cannot change them).  The batch axis carries the
``graph_batch`` logical axis for mesh sharding
(:data:`repro.distributed.sharding.GRAPH_RULES`).

The vertex-centric API of the paper maps to :func:`vertex_program`: the
user supplies ``compute(state, messages) -> state`` and a message semiring;
supersteps run under ``lax.while_loop`` with a vote-to-halt predicate.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..distributed.sharding import shard_frontier
from .engine import DeviceGraph, propagate, propagate_wedge
from .semiring import MAX_MIN, MIN_PLUS, OR_AND, PLUS_TIMES, Semiring

__all__ = [
    "n_nodes",
    "out_degrees",
    "in_degrees",
    "pagerank",
    "bfs",
    "bfs_multi",
    "reachable",
    "reachable_multi",
    "connected_components",
    "common_neighbor_counts",
    "common_neighbors_multi",
    "one_hot_frontier",
    "personalized_pagerank",
    "hits",
    "vertex_program",
    "shortest_paths",
    "shortest_paths_multi",
    "widest_paths",
    "widest_paths_multi",
    "scc_labels",
    "Condensation",
    "condensation",
    "triangle_counts",
    "clustering_coefficients",
]


def n_nodes(graph: DeviceGraph) -> int:
    """Number of real nodes in any device representation."""
    return graph.n if hasattr(graph, "n") else graph.n_real


_n = n_nodes


def one_hot_frontier(
    n: int,
    sources: jnp.ndarray,
    value: float = 1.0,
    fill: float = 0.0,
    dtype=jnp.float32,
) -> jnp.ndarray:
    """``(n, B)`` frontier matrix: column ``i`` is ``fill`` everywhere and
    ``value`` at ``sources[i]`` (the batched analogue of a one-hot seed).

    Precondition: ``0 <= sources[i] < n``.  Values cannot be checked under
    jit — JAX scatters silently drop out-of-bounds indices and wrap
    negative ones, leaving an all-``fill`` column — so validate at the
    boundary where sources are concrete (as ``GraphQueryServer.submit``
    does)."""
    sources = jnp.asarray(sources, dtype=jnp.int32)
    b = sources.shape[0]
    x = jnp.full((n, b), fill, dtype=dtype)
    return x.at[sources, jnp.arange(b)].set(value)


# ---------------------------------------------------------------------------
# Degree (duplicate-SENSITIVE: needs dedup; paper §6.4 Degree benchmark)
# ---------------------------------------------------------------------------

@jax.jit
def out_degrees(graph: DeviceGraph) -> jnp.ndarray:
    ones = jnp.ones((_n(graph),), dtype=jnp.float32)
    return propagate(graph, ones, PLUS_TIMES, reverse=True)


@jax.jit
def in_degrees(graph: DeviceGraph) -> jnp.ndarray:
    ones = jnp.ones((_n(graph),), dtype=jnp.float32)
    return propagate(graph, ones, PLUS_TIMES)


# ---------------------------------------------------------------------------
# PageRank (duplicate-SENSITIVE)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("num_iters",))
def pagerank(
    graph: DeviceGraph,
    damping: float = 0.85,
    num_iters: int = 20,
) -> jnp.ndarray:
    """Standard power-iteration PageRank with dangling redistribution."""
    n = _n(graph)
    deg = out_degrees(graph)
    x = jnp.full((n,), 1.0 / n, dtype=jnp.float32)

    def body(_, x):
        contrib = jnp.where(deg > 0, x / jnp.maximum(deg, 1.0), 0.0)
        y = propagate(graph, contrib, PLUS_TIMES)
        dangling = jnp.sum(jnp.where(deg > 0, 0.0, x))
        y = y + dangling / n
        return (1.0 - damping) / n + damping * y

    return jax.lax.fori_loop(0, num_iters, body, x)


# ---------------------------------------------------------------------------
# BFS & reachability (duplicate-INSENSITIVE: run directly on C-DUP)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("max_iters",))
def bfs(graph: DeviceGraph, source: int, max_iters: Optional[int] = None) -> jnp.ndarray:
    """Hop distances from ``source`` (inf where unreachable); the ``B=1``
    column of :func:`bfs_multi` so there is one relaxation loop to
    maintain."""
    srcs = jnp.asarray(source, dtype=jnp.int32).reshape(1)
    return bfs_multi(graph, srcs, max_iters=max_iters)[:, 0]


@partial(jax.jit, static_argnames=("max_iters",))
def bfs_multi(
    graph: DeviceGraph,
    sources: jnp.ndarray,
    max_iters: Optional[int] = None,
) -> jnp.ndarray:
    """Hop distances from every source at once: ``(n, B)`` for ``(B,)``
    sources; column ``i`` equals ``bfs(graph, sources[i])``.

    One min-plus SpMM relaxes all ``B`` frontiers per superstep; the
    vote-to-halt is shared (run while *any* column still changes — settled
    columns are fixed points, so they are unaffected by extra supersteps).
    Sources must satisfy ``0 <= sources[i] < n`` (see
    :func:`one_hot_frontier`).
    """
    n = _n(graph)
    max_iters = n if max_iters is None else max_iters
    dist0 = one_hot_frontier(n, sources, value=0.0, fill=jnp.inf)

    def cond(state):
        dist, changed, it = state
        return jnp.logical_and(changed, it < max_iters)

    def body(state):
        dist, _, it = state
        relaxed = propagate(graph, dist, MIN_PLUS, hop_weight=1.0)
        new = jnp.minimum(dist, relaxed)
        return shard_frontier(new), jnp.any(new < dist), it + 1

    dist, _, _ = jax.lax.while_loop(cond, body, (dist0, jnp.array(True), 0))
    return dist


@partial(jax.jit, static_argnames=("max_iters", "reverse"))
def reachable(
    graph: DeviceGraph,
    source: int,
    max_iters: Optional[int] = None,
    reverse: bool = False,
) -> jnp.ndarray:
    """Boolean (0/1) reachability from ``source`` under OR-AND; the
    ``B=1`` column of :func:`reachable_multi`."""
    srcs = jnp.asarray(source, dtype=jnp.int32).reshape(1)
    return reachable_multi(graph, srcs, max_iters=max_iters, reverse=reverse)[:, 0]


@partial(jax.jit, static_argnames=("max_iters", "reverse"))
def reachable_multi(
    graph: DeviceGraph,
    sources: jnp.ndarray,
    max_iters: Optional[int] = None,
    reverse: bool = False,
) -> jnp.ndarray:
    """Batched OR-AND reachability: ``(n, B)`` of 0/1 indicators.
    ``reverse=True`` follows edges backwards (ancestor reachability, via
    the packed reverse operands) — the other half of the SCC
    forward/backward intersection (:func:`scc_labels`).  Sources must
    satisfy ``0 <= sources[i] < n`` (see :func:`one_hot_frontier`)."""
    n = _n(graph)
    max_iters = n if max_iters is None else max_iters
    r0 = one_hot_frontier(n, sources, value=1.0, fill=0.0)

    def cond(state):
        r, changed, it = state
        return jnp.logical_and(changed, it < max_iters)

    def body(state):
        r, _, it = state
        nxt = jnp.maximum(r, propagate(graph, r, OR_AND, reverse=reverse))
        return shard_frontier(nxt), jnp.any(nxt > r), it + 1

    r, _, _ = jax.lax.while_loop(cond, body, (r0, jnp.array(True), 0))
    return r


# ---------------------------------------------------------------------------
# Connected components (duplicate-INSENSITIVE) — min-label propagation
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("max_iters", "undirected"))
def connected_components(
    graph: DeviceGraph,
    max_iters: Optional[int] = None,
    undirected: bool = True,
) -> jnp.ndarray:
    """Min-label propagation; labels = component representative ids.

    ``undirected=True`` (the default) symmetrizes every superstep by also
    propagating along reversed edges (the packed reverse operands on
    :class:`~repro.core.engine.DevicePacked`), so weakly connected
    components come out right on *asymmetric* graphs too.  The previous
    behaviour propagated forward only — treating the graph as directed,
    which splits weak components joined only against edge direction; pass
    ``undirected=False`` to get that directed min-label flow explicitly.
    (Graphs from symmetric extraction queries contain both directions, so
    either setting agrees there.)
    """
    n = _n(graph)
    max_iters = n if max_iters is None else max_iters
    labels0 = jnp.arange(n, dtype=jnp.float32)

    def cond(state):
        labels, changed, it = state
        return jnp.logical_and(changed, it < max_iters)

    def body(state):
        labels, _, it = state
        nxt = jnp.minimum(labels, propagate(graph, labels, MIN_PLUS, hop_weight=0.0))
        if undirected:
            nxt = jnp.minimum(
                nxt, propagate(graph, labels, MIN_PLUS, hop_weight=0.0, reverse=True)
            )
        return nxt, jnp.any(nxt < labels), it + 1

    labels, _, _ = jax.lax.while_loop(cond, body, (labels0, jnp.array(True), 0))
    return labels


# ---------------------------------------------------------------------------
# Common-neighbor counting — the condensed rep's native strength:
# M = B·Bᵀ entries ARE co-occurrence counts, so *duplication is signal*
# (beyond-paper: link prediction / collaboration strength, free on C-DUP).
# ---------------------------------------------------------------------------

@jax.jit
def common_neighbor_counts(graph: DeviceGraph, seeds: jnp.ndarray) -> jnp.ndarray:
    """For a one-hot/indicator seed vector: per-node path-multiplicity mass.

    On C-DUP this counts shared virtual entities (e.g. #co-authored papers)
    — exactly the quantity dedup would destroy; exposed as a feature.
    ``seeds`` may also be an ``(n, B)`` indicator batch (one query per
    column), scored in a single SpMM.
    """
    return propagate(graph, seeds, PLUS_TIMES, allow_duplicates=True)


@jax.jit
def common_neighbors_multi(
    graph: DeviceGraph, query_nodes: jnp.ndarray
) -> jnp.ndarray:
    """Common-neighbor scores for a ``(B,)`` batch of query nodes.

    ``out[v, i]`` = number of shared virtual entities between ``v`` and
    ``query_nodes[i]`` — the recsys-serving scoring primitive, one
    propagation for the whole batch.  Query nodes must satisfy
    ``0 <= query_nodes[i] < n`` (see :func:`one_hot_frontier`).
    """
    seeds = one_hot_frontier(_n(graph), query_nodes)
    return common_neighbor_counts(graph, seeds)


# ---------------------------------------------------------------------------
# Vertex-centric API (paper §3.4) — superstep driver
# ---------------------------------------------------------------------------

class VertexProgram(NamedTuple):
    """``compute`` folds incoming aggregated messages into vertex state."""

    semiring: Semiring
    to_message: Callable[[jnp.ndarray], jnp.ndarray]
    compute: Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray]


@partial(jax.jit, static_argnames=("program", "max_supersteps"))
def vertex_program(
    graph: DeviceGraph,
    program: VertexProgram,
    init_state: jnp.ndarray,
    max_supersteps: int = 50,
) -> jnp.ndarray:
    def cond(state):
        s, halted, it = state
        return jnp.logical_and(~halted, it < max_supersteps)

    def body(state):
        s, _, it = state
        msgs = propagate(graph, program.to_message(s), program.semiring)
        s_new = program.compute(s, msgs)
        halted = jnp.all(jnp.abs(s_new - s) < 1e-12)
        return s_new, halted, it + 1

    s, _, _ = jax.lax.while_loop(
        cond, body, (init_state, jnp.array(False), 0)
    )
    return s


# ---------------------------------------------------------------------------
# Extended analytics (beyond the paper's benchmarked set, same engine)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("num_iters",))
def personalized_pagerank(
    graph: DeviceGraph,
    seeds: jnp.ndarray,            # (n,) or (n, B) restart distribution(s)
    damping: float = 0.85,
    num_iters: int = 20,
) -> jnp.ndarray:
    """PageRank with restart at ``seeds`` (recommendation-style queries).

    ``seeds`` is one restart distribution ``(n,)`` (columns sum to 1) or a
    batch ``(n, B)`` — e.g. one one-hot column per user — iterated jointly
    so each power step is a single SpMM over all ``B`` queries; column
    ``i`` equals ``personalized_pagerank(graph, seeds[:, i])``.
    """
    deg = out_degrees(graph)
    degb = deg if seeds.ndim == 1 else deg[:, None]
    seeds = shard_frontier(seeds.astype(jnp.float32))
    x = seeds

    def body(_, x):
        contrib = jnp.where(degb > 0, x / jnp.maximum(degb, 1.0), 0.0)
        y = propagate(graph, contrib, PLUS_TIMES)
        dangling = jnp.sum(jnp.where(degb > 0, 0.0, x), axis=0)
        y = y + dangling * seeds
        return (1.0 - damping) * seeds + damping * y

    return jax.lax.fori_loop(0, num_iters, body, x)


@partial(jax.jit, static_argnames=("num_iters",))
def hits(
    graph: DeviceGraph, num_iters: int = 30
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Hubs & authorities by power iteration (duplicate-sensitive)."""
    n = _n(graph)
    h = jnp.full((n,), 1.0 / jnp.sqrt(n), dtype=jnp.float32)

    def body(_, carry):
        h, a = carry
        a = propagate(graph, h, PLUS_TIMES)            # auth = sum of in-hubs
        a = a / jnp.maximum(jnp.linalg.norm(a), 1e-12)
        h = propagate(graph, a, PLUS_TIMES, reverse=True)
        h = h / jnp.maximum(jnp.linalg.norm(h), 1e-12)
        return h, a

    h, a = jax.lax.fori_loop(0, num_iters, body, (h, jnp.zeros_like(h)))
    return h, a


# ---------------------------------------------------------------------------
# Weighted / temporal semiring analytics (DESIGN.md §11): edge properties
# ride on condensed chains as per-virtual-layer weights — every incidence
# step stays an unweighted kernelizable SpMM.
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("max_iters",))
def shortest_paths_multi(
    graph: DeviceGraph,
    sources: jnp.ndarray,
    layer_weights=None,
    hop_weight: Optional[float] = None,
    max_iters: Optional[int] = None,
) -> jnp.ndarray:
    """Batched min-plus shortest paths: ``(n, B)`` distances (inf where
    unreachable), relaxed to a fixed point à la Bellman-Ford.

    ``layer_weights`` (see :func:`~repro.core.engine.propagate`) carries
    non-negative per-virtual-layer costs: a condensed path costs the sum
    of its virtual-node weights, plus ``hop_weight`` per logical hop when
    given (direct real->real edges cost only ``hop_weight``).  Called
    with neither, it degrades to hop counting — identical to
    :func:`bfs_multi`.
    """
    n = _n(graph)
    max_iters = n if max_iters is None else max_iters
    if layer_weights is None and hop_weight is None:
        hop_weight = 1.0
    dist0 = one_hot_frontier(n, sources, value=0.0, fill=jnp.inf)

    def cond(state):
        dist, changed, it = state
        return jnp.logical_and(changed, it < max_iters)

    def body(state):
        dist, _, it = state
        relaxed = propagate(
            graph, dist, MIN_PLUS,
            hop_weight=hop_weight, layer_weights=layer_weights,
        )
        new = jnp.minimum(dist, relaxed)
        return shard_frontier(new), jnp.any(new < dist), it + 1

    dist, _, _ = jax.lax.while_loop(cond, body, (dist0, jnp.array(True), 0))
    return dist


@partial(jax.jit, static_argnames=("max_iters",))
def shortest_paths(
    graph: DeviceGraph,
    source: int,
    layer_weights=None,
    hop_weight: Optional[float] = None,
    max_iters: Optional[int] = None,
) -> jnp.ndarray:
    """Single-source min-plus distances; the ``B=1`` column of
    :func:`shortest_paths_multi` (the looped oracle the batched path is
    benchmarked against)."""
    srcs = jnp.asarray(source, dtype=jnp.int32).reshape(1)
    return shortest_paths_multi(
        graph, srcs, layer_weights=layer_weights,
        hop_weight=hop_weight, max_iters=max_iters,
    )[:, 0]


@partial(jax.jit, static_argnames=("max_iters",))
def widest_paths_multi(
    graph: DeviceGraph,
    sources: jnp.ndarray,
    layer_capacities=None,
    hop_weight: Optional[float] = None,
    max_iters: Optional[int] = None,
) -> jnp.ndarray:
    """Batched max-min widest (bottleneck) paths: ``(n, B)`` widths —
    0 where unreachable, ``inf`` at each source.

    ``layer_capacities`` carries non-negative per-virtual-layer
    capacities: a path's width is the min capacity along it, the answer
    the max over paths (the :data:`~repro.core.semiring.MAX_MIN`
    semiring).  Without capacities every edge has infinite capacity and
    the result is reachability scaled to {0, inf}.
    """
    n = _n(graph)
    max_iters = n if max_iters is None else max_iters
    w0 = one_hot_frontier(n, sources, value=jnp.inf, fill=0.0)

    def cond(state):
        w, changed, it = state
        return jnp.logical_and(changed, it < max_iters)

    def body(state):
        w, _, it = state
        relaxed = propagate(
            graph, w, MAX_MIN,
            hop_weight=hop_weight, layer_weights=layer_capacities,
        )
        new = jnp.maximum(w, relaxed)
        return shard_frontier(new), jnp.any(new > w), it + 1

    w, _, _ = jax.lax.while_loop(cond, body, (w0, jnp.array(True), 0))
    return w


@partial(jax.jit, static_argnames=("max_iters",))
def widest_paths(
    graph: DeviceGraph,
    source: int,
    layer_capacities=None,
    hop_weight: Optional[float] = None,
    max_iters: Optional[int] = None,
) -> jnp.ndarray:
    """Single-source max-min widths; the ``B=1`` column of
    :func:`widest_paths_multi`."""
    srcs = jnp.asarray(source, dtype=jnp.int32).reshape(1)
    return widest_paths_multi(
        graph, srcs, layer_capacities=layer_capacities,
        hop_weight=hop_weight, max_iters=max_iters,
    )[:, 0]


# ---------------------------------------------------------------------------
# Strongly connected components + condensation DAG layering (DESIGN.md §11;
# the cppdep dependency-cycle workload): forward ∧ backward reachability
# over pivot batches, entirely on the condensed representation.
# ---------------------------------------------------------------------------

def scc_labels(
    graph: DeviceGraph, batch: int = 32, max_iters: Optional[int] = None
) -> np.ndarray:
    """SCC label per node: the minimum member id of its component.

    Batched forward/backward pivot sweep: each round takes the ``batch``
    lowest unassigned node ids as pivots, computes descendants
    (:func:`reachable_multi`) and ancestors (``reverse=True``, the packed
    reverse operands) for all of them in two batched OR-AND fixpoints,
    and labels each pivot's forward∧backward intersection — exactly its
    SCC.  Every pivot is a member of its own intersection, so each round
    assigns at least ``batch`` nodes; because pivots are the lowest
    unassigned ids and whole SCCs are labeled at once, every pivot is the
    minimum id of its component — labels are deterministic and
    representation-independent.  ``batch=1`` is the looped single-source
    oracle.
    """
    n = _n(graph)
    batch = max(1, min(int(batch), n))
    labels = np.full(n, -1, dtype=np.int64)
    while True:
        unassigned = np.flatnonzero(labels < 0)
        if unassigned.size == 0:
            break
        pivots = unassigned[:batch]
        # pad to the fixed batch width so every round reuses one compiled
        # executable; duplicate columns are skipped at assignment
        padded = np.concatenate(
            [pivots, np.full(batch - pivots.size, pivots[0], dtype=pivots.dtype)]
        )
        srcs = jnp.asarray(padded.astype(np.int32))
        fwd = np.asarray(reachable_multi(graph, srcs, max_iters=max_iters))
        bwd = np.asarray(
            reachable_multi(graph, srcs, max_iters=max_iters, reverse=True)
        )
        both = (fwd > 0) & (bwd > 0)
        for j, p in enumerate(padded.tolist()):
            if labels[p] >= 0:
                continue  # already labeled (same-SCC pivot or pad column)
            members = both[:, j] & (labels < 0)
            labels[members] = p
    return labels


class Condensation(NamedTuple):
    """SCC condensation of a graph: per-node labels, the component DAG,
    and its longest-path-to-sink topological layering (the cppdep
    package-dependency report: layer 0 = leaf components, each higher
    layer depends only on lower ones)."""

    labels: np.ndarray      # (n,) SCC label = min member id
    component: np.ndarray   # (n,) dense component index, ordered by label
    sizes: np.ndarray       # (k,) members per component
    dag_src: np.ndarray     # inter-component edges (dense ids), deduped
    dag_dst: np.ndarray
    layers: np.ndarray      # (k,) longest path length to a sink

    @property
    def n_components(self) -> int:
        return int(self.sizes.size)


def condensation(
    graph: DeviceGraph,
    labels: Optional[np.ndarray] = None,
    batch: int = 32,
) -> Condensation:
    """Condense SCCs to a DAG and layer it topologically — without
    expanding the graph: the component adjacency comes from ONE batched
    OR-AND propagation of the (n, k) membership indicator matrix (column
    c of the result marks every node with an in-edge from component c).
    """
    if labels is None:
        labels = scc_labels(graph, batch=batch)
    n = _n(graph)
    uniq, comp = np.unique(labels, return_inverse=True)
    k = uniq.size
    sizes = np.bincount(comp, minlength=k)
    member = np.zeros((n, k), dtype=np.float32)
    member[np.arange(n), comp] = 1.0
    hit = np.asarray(propagate(graph, jnp.asarray(member), OR_AND))
    node, from_comp = np.nonzero(hit > 0)
    to_comp = comp[node]
    keep = from_comp != to_comp
    if keep.any():
        pairs = np.unique(
            np.stack([from_comp[keep], to_comp[keep]], axis=1), axis=0
        )
        dag_src, dag_dst = pairs[:, 0], pairs[:, 1]
    else:
        dag_src = np.zeros(0, np.int64)
        dag_dst = np.zeros(0, np.int64)
    # longest-path-to-sink layering: sinks stay 0, everything else is
    # 1 + max over successors; monotone relaxation converges within the
    # DAG's longest path length
    layers = np.zeros(k, dtype=np.int64)
    for _ in range(k + 1):
        nxt = np.zeros(k, dtype=np.int64)
        if dag_src.size:
            np.maximum.at(nxt, dag_src, layers[dag_dst] + 1)
        if np.array_equal(nxt, layers):
            break
        layers = nxt
    return Condensation(labels, comp, sizes, dag_src, dag_dst, layers)


# ---------------------------------------------------------------------------
# Triangles & clustering coefficients (DESIGN.md §11): two-hop wedge
# counting needs the *quadratic* DEDUP correction — duplicate wedges
# through shared virtual nodes (engine.propagate_wedge).
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("mode",))
def _triangle_block(graph, X, wedge, mode):
    a1 = propagate(graph, X, PLUS_TIMES)
    if mode == "wedge":
        a2 = propagate_wedge(graph, X, wedge=wedge)
    else:
        a2 = propagate(graph, a1, PLUS_TIMES)
    return 0.5 * jnp.sum(a1 * a2, axis=0)


def triangle_counts(
    graph: DeviceGraph,
    block: int = 128,
    mode: str = "per_step",
    wedge=None,
) -> np.ndarray:
    """Per-node triangle counts ``t[v] = ½ Σ_w A[v,w]·(A²)[v,w]`` on a
    symmetric simple graph (A = dedup'd adjacency, zero diagonal).

    Runs condensation-native: identity columns in blocks of ``block``
    through two exact ring propagations per block — never materializing
    A.  ``mode='per_step'`` corrects each hop linearly (DEDUP-C);
    ``mode='wedge'`` runs both hops RAW (plain kernel-path SpMMs) and
    subtracts the quadratic wedge correction once
    (:func:`~repro.core.engine.propagate_wedge`; pass ``wedge`` triples
    from :func:`~repro.core.dedup.build_wedge_correction` to make the
    correction a single sparse pass).  Both modes are byte-identical on
    integer counts.  ``block=1`` is the looped per-node oracle.
    """
    n = _n(graph)
    block = max(1, min(int(block), n))
    wedge_dev = None
    if wedge is not None:
        ws, wd, wm = tuple(wedge)
        wedge_dev = (
            jnp.asarray(ws, jnp.int32),
            jnp.asarray(wd, jnp.int32),
            jnp.asarray(wm, jnp.float32),
        )
        mode = "wedge"
    t = np.zeros(n, dtype=np.float64)
    for lo in range(0, n, block):
        cols = np.arange(lo, min(lo + block, n))
        X = np.zeros((n, block), dtype=np.float32)
        X[cols, np.arange(cols.size)] = 1.0
        contrib = np.asarray(
            _triangle_block(graph, jnp.asarray(X), wedge_dev, mode)
        )
        t[cols] += contrib[: cols.size]
    return t


def clustering_coefficients(
    graph: DeviceGraph,
    block: int = 128,
    mode: str = "per_step",
    wedge=None,
) -> np.ndarray:
    """Local clustering coefficient ``c[v] = 2·t[v] / (deg[v]·(deg[v]−1))``
    (0 where degree < 2), from :func:`triangle_counts` and the exact
    dedup'd degrees (:func:`out_degrees` on a corrected graph)."""
    t = triangle_counts(graph, block=block, mode=mode, wedge=wedge)
    deg = np.asarray(out_degrees(graph), dtype=np.float64)
    denom = deg * (deg - 1.0)
    return np.where(denom > 0, 2.0 * t / np.maximum(denom, 1.0), 0.0)
