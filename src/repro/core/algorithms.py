"""Graph algorithms over any device representation (paper §3.4, §6.1.2).

Each algorithm is a pure function of a :class:`~repro.core.engine.DeviceGraph`
pytree, jit-compatible, and by construction produces identical results on
EXP / DEDUP-1 / DEDUP-C (duplicate-sensitive) or additionally on raw C-DUP
(duplicate-insensitive: BFS, connected components, reachability).

The vertex-centric API of the paper maps to :func:`vertex_program`: the
user supplies ``compute(state, messages) -> state`` and a message semiring;
supersteps run under ``lax.while_loop`` with a vote-to-halt predicate.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .engine import DeviceGraph, propagate
from .semiring import MIN_PLUS, OR_AND, PLUS_TIMES, Semiring

__all__ = [
    "out_degrees",
    "in_degrees",
    "pagerank",
    "bfs",
    "reachable",
    "connected_components",
    "common_neighbor_counts",
    "vertex_program",
]


def _n(graph: DeviceGraph) -> int:
    return graph.n if hasattr(graph, "n") else graph.n_real


# ---------------------------------------------------------------------------
# Degree (duplicate-SENSITIVE: needs dedup; paper §6.4 Degree benchmark)
# ---------------------------------------------------------------------------

@jax.jit
def out_degrees(graph: DeviceGraph) -> jnp.ndarray:
    ones = jnp.ones((_n(graph),), dtype=jnp.float32)
    return propagate(graph, ones, PLUS_TIMES, reverse=True)


@jax.jit
def in_degrees(graph: DeviceGraph) -> jnp.ndarray:
    ones = jnp.ones((_n(graph),), dtype=jnp.float32)
    return propagate(graph, ones, PLUS_TIMES)


# ---------------------------------------------------------------------------
# PageRank (duplicate-SENSITIVE)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("num_iters",))
def pagerank(
    graph: DeviceGraph,
    damping: float = 0.85,
    num_iters: int = 20,
) -> jnp.ndarray:
    """Standard power-iteration PageRank with dangling redistribution."""
    n = _n(graph)
    deg = out_degrees(graph)
    x = jnp.full((n,), 1.0 / n, dtype=jnp.float32)

    def body(_, x):
        contrib = jnp.where(deg > 0, x / jnp.maximum(deg, 1.0), 0.0)
        y = propagate(graph, contrib, PLUS_TIMES)
        dangling = jnp.sum(jnp.where(deg > 0, 0.0, x))
        y = y + dangling / n
        return (1.0 - damping) / n + damping * y

    return jax.lax.fori_loop(0, num_iters, body, x)


# ---------------------------------------------------------------------------
# BFS & reachability (duplicate-INSENSITIVE: run directly on C-DUP)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("max_iters",))
def bfs(graph: DeviceGraph, source: int, max_iters: Optional[int] = None) -> jnp.ndarray:
    """Hop distances from ``source`` (inf where unreachable)."""
    n = _n(graph)
    max_iters = n if max_iters is None else max_iters
    dist0 = jnp.full((n,), jnp.inf, dtype=jnp.float32).at[source].set(0.0)

    def cond(state):
        dist, changed, it = state
        return jnp.logical_and(changed, it < max_iters)

    def body(state):
        dist, _, it = state
        relaxed = propagate(graph, dist, MIN_PLUS, hop_weight=1.0)
        new = jnp.minimum(dist, relaxed)
        return new, jnp.any(new < dist), it + 1

    dist, _, _ = jax.lax.while_loop(cond, body, (dist0, jnp.array(True), 0))
    return dist


@partial(jax.jit, static_argnames=("max_iters",))
def reachable(
    graph: DeviceGraph, source: int, max_iters: Optional[int] = None
) -> jnp.ndarray:
    """Boolean (0/1) reachability from ``source`` under OR-AND."""
    n = _n(graph)
    max_iters = n if max_iters is None else max_iters
    r0 = jnp.zeros((n,), dtype=jnp.float32).at[source].set(1.0)

    def cond(state):
        r, changed, it = state
        return jnp.logical_and(changed, it < max_iters)

    def body(state):
        r, _, it = state
        nxt = jnp.maximum(r, propagate(graph, r, OR_AND))
        return nxt, jnp.any(nxt > r), it + 1

    r, _, _ = jax.lax.while_loop(cond, body, (r0, jnp.array(True), 0))
    return r


# ---------------------------------------------------------------------------
# Connected components (duplicate-INSENSITIVE) — min-label propagation
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("max_iters", "symmetric"))
def connected_components(
    graph: DeviceGraph,
    max_iters: Optional[int] = None,
    symmetric: bool = True,
) -> jnp.ndarray:
    """Min-label propagation; labels = component representative ids.

    With ``symmetric=False`` the graph is treated as undirected by also
    propagating along reversed edges each superstep (paper graphs from
    symmetric extraction queries already contain both directions).
    """
    n = _n(graph)
    max_iters = n if max_iters is None else max_iters
    labels0 = jnp.arange(n, dtype=jnp.float32)

    def cond(state):
        labels, changed, it = state
        return jnp.logical_and(changed, it < max_iters)

    def body(state):
        labels, _, it = state
        nxt = jnp.minimum(labels, propagate(graph, labels, MIN_PLUS, hop_weight=0.0))
        if not symmetric:
            nxt = jnp.minimum(
                nxt, propagate(graph, labels, MIN_PLUS, hop_weight=0.0, reverse=True)
            )
        return nxt, jnp.any(nxt < labels), it + 1

    labels, _, _ = jax.lax.while_loop(cond, body, (labels0, jnp.array(True), 0))
    return labels


# ---------------------------------------------------------------------------
# Common-neighbor counting — the condensed rep's native strength:
# M = B·Bᵀ entries ARE co-occurrence counts, so *duplication is signal*
# (beyond-paper: link prediction / collaboration strength, free on C-DUP).
# ---------------------------------------------------------------------------

@jax.jit
def common_neighbor_counts(graph: DeviceGraph, seeds: jnp.ndarray) -> jnp.ndarray:
    """For a one-hot/indicator seed vector: per-node path-multiplicity mass.

    On C-DUP this counts shared virtual entities (e.g. #co-authored papers)
    — exactly the quantity dedup would destroy; exposed as a feature.
    """
    return propagate(graph, seeds, PLUS_TIMES, allow_duplicates=True)


# ---------------------------------------------------------------------------
# Vertex-centric API (paper §3.4) — superstep driver
# ---------------------------------------------------------------------------

class VertexProgram(NamedTuple):
    """``compute`` folds incoming aggregated messages into vertex state."""

    semiring: Semiring
    to_message: Callable[[jnp.ndarray], jnp.ndarray]
    compute: Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray]


@partial(jax.jit, static_argnames=("program", "max_supersteps"))
def vertex_program(
    graph: DeviceGraph,
    program: VertexProgram,
    init_state: jnp.ndarray,
    max_supersteps: int = 50,
) -> jnp.ndarray:
    def cond(state):
        s, halted, it = state
        return jnp.logical_and(~halted, it < max_supersteps)

    def body(state):
        s, _, it = state
        msgs = propagate(graph, program.to_message(s), program.semiring)
        s_new = program.compute(s, msgs)
        halted = jnp.all(jnp.abs(s_new - s) < 1e-12)
        return s_new, halted, it + 1

    s, _, _ = jax.lax.while_loop(
        cond, body, (init_state, jnp.array(False), 0)
    )
    return s


# ---------------------------------------------------------------------------
# Extended analytics (beyond the paper's benchmarked set, same engine)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("num_iters",))
def personalized_pagerank(
    graph: DeviceGraph,
    seeds: jnp.ndarray,            # (n,) restart distribution (sums to 1)
    damping: float = 0.85,
    num_iters: int = 20,
) -> jnp.ndarray:
    """PageRank with restart at ``seeds`` (recommendation-style queries)."""
    n = _n(graph)
    deg = out_degrees(graph)
    x = seeds.astype(jnp.float32)

    def body(_, x):
        contrib = jnp.where(deg > 0, x / jnp.maximum(deg, 1.0), 0.0)
        y = propagate(graph, contrib, PLUS_TIMES)
        dangling = jnp.sum(jnp.where(deg > 0, 0.0, x))
        y = y + dangling * seeds
        return (1.0 - damping) * seeds + damping * y

    return jax.lax.fori_loop(0, num_iters, body, x)


@partial(jax.jit, static_argnames=("num_iters",))
def hits(
    graph: DeviceGraph, num_iters: int = 30
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Hubs & authorities by power iteration (duplicate-sensitive)."""
    n = _n(graph)
    h = jnp.full((n,), 1.0 / jnp.sqrt(n), dtype=jnp.float32)

    def body(_, carry):
        h, a = carry
        a = propagate(graph, h, PLUS_TIMES)            # auth = sum of in-hubs
        a = a / jnp.maximum(jnp.linalg.norm(a), 1e-12)
        h = propagate(graph, a, PLUS_TIMES, reverse=True)
        h = h / jnp.maximum(jnp.linalg.norm(h), 1e-12)
        return h, a

    h, a = jax.lax.fori_loop(0, num_iters, body, (h, jnp.zeros_like(h)))
    return h, a
