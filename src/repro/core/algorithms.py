"""Graph algorithms over any device representation (paper §3.4, §6.1.2).

Each algorithm is a pure function of a :class:`~repro.core.engine.DeviceGraph`
pytree, jit-compatible, and by construction produces identical results on
EXP / DEDUP-1 / DEDUP-C (duplicate-sensitive) or additionally on raw C-DUP
(duplicate-insensitive: BFS, connected components, reachability).

**Batched multi-source variants** (DESIGN.md §3): :func:`bfs_multi`,
:func:`reachable_multi`, :func:`personalized_pagerank` over a seed batch,
and :func:`common_neighbors_multi` run ``B`` independent analyses as one
``(n, B)`` frontier through the engine — a single factorized SpMM per
superstep instead of ``B`` serial traversals, with one *shared*
vote-to-halt across the batch (supersteps continue while any column is
still active; finished columns are fixed points of their own updates, so
extra supersteps cannot change them).  The batch axis carries the
``graph_batch`` logical axis for mesh sharding
(:data:`repro.distributed.sharding.GRAPH_RULES`).

The vertex-centric API of the paper maps to :func:`vertex_program`: the
user supplies ``compute(state, messages) -> state`` and a message semiring;
supersteps run under ``lax.while_loop`` with a vote-to-halt predicate.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..distributed.sharding import shard_frontier
from .engine import DeviceGraph, propagate
from .semiring import MIN_PLUS, OR_AND, PLUS_TIMES, Semiring

__all__ = [
    "n_nodes",
    "out_degrees",
    "in_degrees",
    "pagerank",
    "bfs",
    "bfs_multi",
    "reachable",
    "reachable_multi",
    "connected_components",
    "common_neighbor_counts",
    "common_neighbors_multi",
    "one_hot_frontier",
    "personalized_pagerank",
    "hits",
    "vertex_program",
]


def n_nodes(graph: DeviceGraph) -> int:
    """Number of real nodes in any device representation."""
    return graph.n if hasattr(graph, "n") else graph.n_real


_n = n_nodes


def one_hot_frontier(
    n: int,
    sources: jnp.ndarray,
    value: float = 1.0,
    fill: float = 0.0,
    dtype=jnp.float32,
) -> jnp.ndarray:
    """``(n, B)`` frontier matrix: column ``i`` is ``fill`` everywhere and
    ``value`` at ``sources[i]`` (the batched analogue of a one-hot seed).

    Precondition: ``0 <= sources[i] < n``.  Values cannot be checked under
    jit — JAX scatters silently drop out-of-bounds indices and wrap
    negative ones, leaving an all-``fill`` column — so validate at the
    boundary where sources are concrete (as ``GraphQueryServer.submit``
    does)."""
    sources = jnp.asarray(sources, dtype=jnp.int32)
    b = sources.shape[0]
    x = jnp.full((n, b), fill, dtype=dtype)
    return x.at[sources, jnp.arange(b)].set(value)


# ---------------------------------------------------------------------------
# Degree (duplicate-SENSITIVE: needs dedup; paper §6.4 Degree benchmark)
# ---------------------------------------------------------------------------

@jax.jit
def out_degrees(graph: DeviceGraph) -> jnp.ndarray:
    ones = jnp.ones((_n(graph),), dtype=jnp.float32)
    return propagate(graph, ones, PLUS_TIMES, reverse=True)


@jax.jit
def in_degrees(graph: DeviceGraph) -> jnp.ndarray:
    ones = jnp.ones((_n(graph),), dtype=jnp.float32)
    return propagate(graph, ones, PLUS_TIMES)


# ---------------------------------------------------------------------------
# PageRank (duplicate-SENSITIVE)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("num_iters",))
def pagerank(
    graph: DeviceGraph,
    damping: float = 0.85,
    num_iters: int = 20,
) -> jnp.ndarray:
    """Standard power-iteration PageRank with dangling redistribution."""
    n = _n(graph)
    deg = out_degrees(graph)
    x = jnp.full((n,), 1.0 / n, dtype=jnp.float32)

    def body(_, x):
        contrib = jnp.where(deg > 0, x / jnp.maximum(deg, 1.0), 0.0)
        y = propagate(graph, contrib, PLUS_TIMES)
        dangling = jnp.sum(jnp.where(deg > 0, 0.0, x))
        y = y + dangling / n
        return (1.0 - damping) / n + damping * y

    return jax.lax.fori_loop(0, num_iters, body, x)


# ---------------------------------------------------------------------------
# BFS & reachability (duplicate-INSENSITIVE: run directly on C-DUP)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("max_iters",))
def bfs(graph: DeviceGraph, source: int, max_iters: Optional[int] = None) -> jnp.ndarray:
    """Hop distances from ``source`` (inf where unreachable); the ``B=1``
    column of :func:`bfs_multi` so there is one relaxation loop to
    maintain."""
    srcs = jnp.asarray(source, dtype=jnp.int32).reshape(1)
    return bfs_multi(graph, srcs, max_iters=max_iters)[:, 0]


@partial(jax.jit, static_argnames=("max_iters",))
def bfs_multi(
    graph: DeviceGraph,
    sources: jnp.ndarray,
    max_iters: Optional[int] = None,
) -> jnp.ndarray:
    """Hop distances from every source at once: ``(n, B)`` for ``(B,)``
    sources; column ``i`` equals ``bfs(graph, sources[i])``.

    One min-plus SpMM relaxes all ``B`` frontiers per superstep; the
    vote-to-halt is shared (run while *any* column still changes — settled
    columns are fixed points, so they are unaffected by extra supersteps).
    Sources must satisfy ``0 <= sources[i] < n`` (see
    :func:`one_hot_frontier`).
    """
    n = _n(graph)
    max_iters = n if max_iters is None else max_iters
    dist0 = one_hot_frontier(n, sources, value=0.0, fill=jnp.inf)

    def cond(state):
        dist, changed, it = state
        return jnp.logical_and(changed, it < max_iters)

    def body(state):
        dist, _, it = state
        relaxed = propagate(graph, dist, MIN_PLUS, hop_weight=1.0)
        new = jnp.minimum(dist, relaxed)
        return shard_frontier(new), jnp.any(new < dist), it + 1

    dist, _, _ = jax.lax.while_loop(cond, body, (dist0, jnp.array(True), 0))
    return dist


@partial(jax.jit, static_argnames=("max_iters",))
def reachable(
    graph: DeviceGraph, source: int, max_iters: Optional[int] = None
) -> jnp.ndarray:
    """Boolean (0/1) reachability from ``source`` under OR-AND; the
    ``B=1`` column of :func:`reachable_multi`."""
    srcs = jnp.asarray(source, dtype=jnp.int32).reshape(1)
    return reachable_multi(graph, srcs, max_iters=max_iters)[:, 0]


@partial(jax.jit, static_argnames=("max_iters",))
def reachable_multi(
    graph: DeviceGraph,
    sources: jnp.ndarray,
    max_iters: Optional[int] = None,
) -> jnp.ndarray:
    """Batched OR-AND reachability: ``(n, B)`` of 0/1 indicators.
    Sources must satisfy ``0 <= sources[i] < n`` (see
    :func:`one_hot_frontier`)."""
    n = _n(graph)
    max_iters = n if max_iters is None else max_iters
    r0 = one_hot_frontier(n, sources, value=1.0, fill=0.0)

    def cond(state):
        r, changed, it = state
        return jnp.logical_and(changed, it < max_iters)

    def body(state):
        r, _, it = state
        nxt = jnp.maximum(r, propagate(graph, r, OR_AND))
        return shard_frontier(nxt), jnp.any(nxt > r), it + 1

    r, _, _ = jax.lax.while_loop(cond, body, (r0, jnp.array(True), 0))
    return r


# ---------------------------------------------------------------------------
# Connected components (duplicate-INSENSITIVE) — min-label propagation
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("max_iters", "symmetric"))
def connected_components(
    graph: DeviceGraph,
    max_iters: Optional[int] = None,
    symmetric: bool = True,
) -> jnp.ndarray:
    """Min-label propagation; labels = component representative ids.

    With ``symmetric=False`` the graph is treated as undirected by also
    propagating along reversed edges each superstep (paper graphs from
    symmetric extraction queries already contain both directions).
    """
    n = _n(graph)
    max_iters = n if max_iters is None else max_iters
    labels0 = jnp.arange(n, dtype=jnp.float32)

    def cond(state):
        labels, changed, it = state
        return jnp.logical_and(changed, it < max_iters)

    def body(state):
        labels, _, it = state
        nxt = jnp.minimum(labels, propagate(graph, labels, MIN_PLUS, hop_weight=0.0))
        if not symmetric:
            nxt = jnp.minimum(
                nxt, propagate(graph, labels, MIN_PLUS, hop_weight=0.0, reverse=True)
            )
        return nxt, jnp.any(nxt < labels), it + 1

    labels, _, _ = jax.lax.while_loop(cond, body, (labels0, jnp.array(True), 0))
    return labels


# ---------------------------------------------------------------------------
# Common-neighbor counting — the condensed rep's native strength:
# M = B·Bᵀ entries ARE co-occurrence counts, so *duplication is signal*
# (beyond-paper: link prediction / collaboration strength, free on C-DUP).
# ---------------------------------------------------------------------------

@jax.jit
def common_neighbor_counts(graph: DeviceGraph, seeds: jnp.ndarray) -> jnp.ndarray:
    """For a one-hot/indicator seed vector: per-node path-multiplicity mass.

    On C-DUP this counts shared virtual entities (e.g. #co-authored papers)
    — exactly the quantity dedup would destroy; exposed as a feature.
    ``seeds`` may also be an ``(n, B)`` indicator batch (one query per
    column), scored in a single SpMM.
    """
    return propagate(graph, seeds, PLUS_TIMES, allow_duplicates=True)


@jax.jit
def common_neighbors_multi(
    graph: DeviceGraph, query_nodes: jnp.ndarray
) -> jnp.ndarray:
    """Common-neighbor scores for a ``(B,)`` batch of query nodes.

    ``out[v, i]`` = number of shared virtual entities between ``v`` and
    ``query_nodes[i]`` — the recsys-serving scoring primitive, one
    propagation for the whole batch.  Query nodes must satisfy
    ``0 <= query_nodes[i] < n`` (see :func:`one_hot_frontier`).
    """
    seeds = one_hot_frontier(_n(graph), query_nodes)
    return common_neighbor_counts(graph, seeds)


# ---------------------------------------------------------------------------
# Vertex-centric API (paper §3.4) — superstep driver
# ---------------------------------------------------------------------------

class VertexProgram(NamedTuple):
    """``compute`` folds incoming aggregated messages into vertex state."""

    semiring: Semiring
    to_message: Callable[[jnp.ndarray], jnp.ndarray]
    compute: Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray]


@partial(jax.jit, static_argnames=("program", "max_supersteps"))
def vertex_program(
    graph: DeviceGraph,
    program: VertexProgram,
    init_state: jnp.ndarray,
    max_supersteps: int = 50,
) -> jnp.ndarray:
    def cond(state):
        s, halted, it = state
        return jnp.logical_and(~halted, it < max_supersteps)

    def body(state):
        s, _, it = state
        msgs = propagate(graph, program.to_message(s), program.semiring)
        s_new = program.compute(s, msgs)
        halted = jnp.all(jnp.abs(s_new - s) < 1e-12)
        return s_new, halted, it + 1

    s, _, _ = jax.lax.while_loop(
        cond, body, (init_state, jnp.array(False), 0)
    )
    return s


# ---------------------------------------------------------------------------
# Extended analytics (beyond the paper's benchmarked set, same engine)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("num_iters",))
def personalized_pagerank(
    graph: DeviceGraph,
    seeds: jnp.ndarray,            # (n,) or (n, B) restart distribution(s)
    damping: float = 0.85,
    num_iters: int = 20,
) -> jnp.ndarray:
    """PageRank with restart at ``seeds`` (recommendation-style queries).

    ``seeds`` is one restart distribution ``(n,)`` (columns sum to 1) or a
    batch ``(n, B)`` — e.g. one one-hot column per user — iterated jointly
    so each power step is a single SpMM over all ``B`` queries; column
    ``i`` equals ``personalized_pagerank(graph, seeds[:, i])``.
    """
    deg = out_degrees(graph)
    degb = deg if seeds.ndim == 1 else deg[:, None]
    seeds = shard_frontier(seeds.astype(jnp.float32))
    x = seeds

    def body(_, x):
        contrib = jnp.where(degb > 0, x / jnp.maximum(degb, 1.0), 0.0)
        y = propagate(graph, contrib, PLUS_TIMES)
        dangling = jnp.sum(jnp.where(degb > 0, 0.0, x), axis=0)
        y = y + dangling * seeds
        return (1.0 - damping) * seeds + damping * y

    return jax.lax.fori_loop(0, num_iters, body, x)


@partial(jax.jit, static_argnames=("num_iters",))
def hits(
    graph: DeviceGraph, num_iters: int = 30
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Hubs & authorities by power iteration (duplicate-sensitive)."""
    n = _n(graph)
    h = jnp.full((n,), 1.0 / jnp.sqrt(n), dtype=jnp.float32)

    def body(_, carry):
        h, a = carry
        a = propagate(graph, h, PLUS_TIMES)            # auth = sum of in-hubs
        a = a / jnp.maximum(jnp.linalg.norm(a), 1e-12)
        h = propagate(graph, a, PLUS_TIMES, reverse=True)
        h = h / jnp.maximum(jnp.linalg.norm(h), 1e-12)
        return h, a

    h, a = jax.lax.fori_loop(0, num_iters, body, (h, jnp.zeros_like(h)))
    return h, a
