"""Semirings for condensed-graph propagation.

The paper distinguishes *duplicate-insensitive* graph algorithms (run
directly on C-DUP) from *duplicate-sensitive* ones (need dedup).  In
linear-algebra terms: propagation under an **idempotent** semiring add
(``min``, ``max``, ``or``) is invariant to path multiplicity, while a ring
add (``+``) counts paths.  Each algorithm in :mod:`repro.core.algorithms`
declares its semiring; the engine uses the ``idempotent`` flag to decide
whether a dedup structure is required for exactness (paper §4.1).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

__all__ = [
    "Semiring",
    "PLUS_TIMES",
    "MIN_PLUS",
    "MAX_TIMES",
    "MAX_MIN",
    "OR_AND",
    "KERNEL_SEMIRINGS",
    "kernelizable",
    "segment_reduce",
]


@dataclasses.dataclass(frozen=True)
class Semiring:
    name: str
    add_kind: str  # 'sum' | 'min' | 'max'
    mul: Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray]
    zero: float
    one: float
    idempotent: bool
    supports_subtraction: bool = False  # needed by the DEDUP-C correction

    def add(self, x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
        if self.add_kind == "sum":
            return x + y
        if self.add_kind == "min":
            return jnp.minimum(x, y)
        if self.add_kind == "max":
            return jnp.maximum(x, y)
        raise ValueError(self.add_kind)


PLUS_TIMES = Semiring(
    name="plus_times",
    add_kind="sum",
    mul=jnp.multiply,
    zero=0.0,
    one=1.0,
    idempotent=False,
    supports_subtraction=True,
)

MIN_PLUS = Semiring(
    name="min_plus",
    add_kind="min",
    mul=jnp.add,
    zero=jnp.inf,
    one=0.0,
    idempotent=True,
)

MAX_TIMES = Semiring(
    name="max_times",
    add_kind="max",
    mul=jnp.multiply,
    zero=0.0,
    one=1.0,
    idempotent=True,
)

# Boolean reachability encoded in {0,1} floats so the same segment kernels
# apply; `or` == max, `and` == min(x, y) == x*y on {0,1}.
OR_AND = Semiring(
    name="or_and",
    add_kind="max",
    mul=jnp.minimum,
    zero=0.0,
    one=1.0,
    idempotent=True,
)

# Widest / bottleneck paths over non-negative capacities (DESIGN.md §11):
# a path's width is the min capacity along it, the best path the max over
# widths.  OR_AND is the {0,1} special case; the general semiring carries
# real capacities applied per virtual layer via ``propagate``'s
# ``layer_weights`` (⊗ = min leaves unweighted incidence steps untouched,
# since ⊗ by ``one = +inf`` is the identity — hence kernelizable).
MAX_MIN = Semiring(
    name="max_min",
    add_kind="max",
    mul=jnp.minimum,
    zero=0.0,
    one=jnp.inf,
    idempotent=True,
)


# Semirings the bit-packed Pallas kernel realizes (DESIGN.md §6): over a
# 0/1 incidence layer ⊗ by the incidence weight (the semiring one) is the
# identity for all of these, so one kernel step is just the ⊕-reduction —
# MXU dot for the ring sum, masked select for idempotent min/max.
KERNEL_SEMIRINGS = frozenset(
    {"plus_times", "min_plus", "max_times", "or_and", "max_min"}
)


def kernelizable(semiring: Semiring) -> bool:
    """Whether one propagation step of this semiring can dispatch to the
    bit-packed SpMM kernel (``repro.kernels.bitmap_spmm``).  The kernel
    reduces plain gathered sources — correct exactly when ``mul(x, one)``
    is ``x``, which holds for every registered semiring; unknown semirings
    conservatively stay on the segment-reduce path."""
    return semiring.name in KERNEL_SEMIRINGS and semiring.add_kind in (
        "sum",
        "min",
        "max",
    )


def segment_reduce(
    semiring: Semiring,
    values: jnp.ndarray,
    segment_ids: jnp.ndarray,
    num_segments: int,
) -> jnp.ndarray:
    """⊕-reduce ``values`` by ``segment_ids`` (vector or (n, f) features)."""
    if semiring.add_kind == "sum":
        return jax.ops.segment_sum(values, segment_ids, num_segments=num_segments)
    if semiring.add_kind == "min":
        out = jax.ops.segment_min(values, segment_ids, num_segments=num_segments)
        # Empty segments come back as +inf already for min; normalize dtype.
        return out
    if semiring.add_kind == "max":
        out = jax.ops.segment_max(values, segment_ids, num_segments=num_segments)
        # Empty segments of segment_max are -inf; semiring zero may differ.
        return jnp.where(jnp.isneginf(out), semiring.zero, out)
    raise ValueError(semiring.add_kind)
