"""Representation advisor (paper §6.5) + cost-based plan front door.

Given a freshly extracted C-DUP graph and workload hints, recommend the
in-memory representation:

* expansion small (< ``expand_margin`` growth)       -> EXP
* algorithms touch a small fraction of the graph     -> C-DUP
* multi-pass duplicate-sensitive analytics           -> BITMAP-2 / DEDUP-C
* repeated analyses over time (amortized preprocessing) -> DEDUP-1/DEDUP-2

On the TPU engine the BITMAP traversal semantics collapse into DEDUP-C
(see DESIGN.md §2), so the device recommendation column differs from the
paper's host recommendation where applicable.

Since PR 10 the advisor is cost-based (DESIGN.md §12): the *pipeline*
knobs (sharding, spilling, merge arity, pack method, fused correction)
are chosen by :func:`repro.core.cost.plan` — re-exported here — and the
*device* representation is routed through the same cost model when the
caller hands over a measured :class:`~repro.kernels.autotune.
CrossoverTable`: a measured-slower Pallas cell removes DEDUP-C's kernel
advantage and can flip the device recommendation back to EXP for
mildly-expanding graphs (``device_representation_costs``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from .condensed import CondensedGraph, ExpansionAccounting
from .cost import (  # noqa: F401  (re-exported plan API)
    ExtractionPlan,
    PlanConfig,
    PlanReport,
    Throughputs,
    device_representation_costs,
    plan,
)

__all__ = [
    "Recommendation",
    "recommend",
    "plan",
    "ExtractionPlan",
    "PlanConfig",
    "PlanReport",
    "Throughputs",
]


@dataclasses.dataclass
class Recommendation:
    host_representation: str
    device_representation: str
    reason: str
    expansion_ratio: float
    duplication_ratio: float
    # evidence for the expansion sweep the ratios came from: chunk/run
    # residency under the caller's budget (None only if stats were
    # injected some other way)
    expansion_accounting: Optional[ExpansionAccounting] = None
    # measured device costs (µs per pass) when a CrossoverTable was given
    device_costs: Optional[dict] = None


def _route_device(
    rec: Recommendation, graph: CondensedGraph, crossover, n_features: int
) -> Recommendation:
    """Re-decide the device column from measured kernel timings.

    The ladder's device pick assumes the condensed SpMM wins on the
    kernel; a measured CrossoverTable can contradict that.  Only the
    DEDUP-C pick is revisited — EXP/C-DUP picks have no kernel leg."""
    if crossover is None or rec.device_representation != "DEDUP-C":
        return rec
    costs = device_representation_costs(
        rec.expansion_ratio, rec.duplication_ratio, crossover,
        n_src=graph.n_real, n_features=n_features,
    )
    if costs is None:
        return rec
    rec = dataclasses.replace(rec, device_costs=costs)
    if costs["EXP"] < costs["DEDUP-C"]:
        return dataclasses.replace(
            rec,
            device_representation="EXP",
            reason=rec.reason + (
                "; measured CrossoverTable makes DEDUP-C "
                f"{costs['DEDUP-C']:.1f}us/pass vs EXP "
                f"{costs['EXP']:.1f}us/pass — device flips to EXP"
            ),
        )
    return rec


def recommend(
    graph: CondensedGraph,
    workload: str = "multi_pass",          # 'point' | 'single_pass' | 'multi_pass' | 'repeated'
    duplicate_sensitive: bool = True,
    expand_margin: float = 1.2,
    budget_triples: Optional[int] = None,
    chunk_rows: Optional[int] = None,
    crossover=None,
    n_features: int = 128,
) -> Recommendation:
    """Recommend host/device representations for ``graph``.

    The sizing stats are measured with one budgeted
    :meth:`~repro.core.condensed.CondensedGraph.expansion_stats` sweep
    (previously two unbudgeted full expansions — an advisor call could
    blow the memory wall it exists to warn about).  ``budget_triples``
    bounds that sweep's resident triples; the
    :class:`~repro.core.condensed.ExpansionAccounting` evidence rides on
    ``Recommendation.expansion_accounting``.

    ``crossover`` (a measured :class:`~repro.kernels.autotune.
    CrossoverTable`) routes the device column through the cost model
    (DESIGN.md §12): a DEDUP-C pick survives only while the measured
    kernel timings actually favor it at ``n_features``-wide batches.
    """
    cond = max(graph.n_edges_condensed, 1)
    acct = ExpansionAccounting(budget_triples=budget_triples)
    exp_edges, dup = graph.expansion_stats(
        chunk_rows=chunk_rows,
        budget_triples=budget_triples,
        accounting=acct,
    )
    ratio = exp_edges / cond

    if ratio <= expand_margin:
        return Recommendation(
            "EXP", "EXP",
            f"expansion grows edges only {ratio:.2f}x (<= {expand_margin}); "
            "paper §6.5 suggests expanding outright",
            ratio, dup, acct,
        )
    if not duplicate_sensitive or workload == "point":
        return Recommendation(
            "C-DUP", "C-DUP",
            "duplicate-insensitive or point workload: operate on C-DUP "
            "directly (paper §4.1/§6.5)",
            ratio, dup, acct,
        )
    if workload == "repeated":
        rep = "DEDUP-2" if graph.is_single_layer() else "DEDUP-1"
        rec = Recommendation(
            rep, "DEDUP-C",
            "repeated analyses amortize one-time dedup rewriting "
            "(paper §6.5); device engine uses the vectorized correction",
            ratio, dup, acct,
        )
        return _route_device(rec, graph, crossover, n_features)
    rec = Recommendation(
        "BITMAP-2", "DEDUP-C",
        "multi-pass duplicate-sensitive analytics: BITMAP-2 on host "
        "iterators; correction-SpMV on device (DESIGN.md §2)",
        ratio, dup, acct,
    )
    return _route_device(rec, graph, crossover, n_features)
