"""Representation advisor (paper §6.5).

Given a freshly extracted C-DUP graph and workload hints, recommend the
in-memory representation:

* expansion small (< ``expand_margin`` growth)       -> EXP
* algorithms touch a small fraction of the graph     -> C-DUP
* multi-pass duplicate-sensitive analytics           -> BITMAP-2 / DEDUP-C
* repeated analyses over time (amortized preprocessing) -> DEDUP-1/DEDUP-2

On the TPU engine the BITMAP traversal semantics collapse into DEDUP-C
(see DESIGN.md §2), so the device recommendation column differs from the
paper's host recommendation where applicable.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from .condensed import CondensedGraph, ExpansionAccounting

__all__ = ["Recommendation", "recommend"]


@dataclasses.dataclass
class Recommendation:
    host_representation: str
    device_representation: str
    reason: str
    expansion_ratio: float
    duplication_ratio: float
    # evidence for the expansion sweep the ratios came from: chunk/run
    # residency under the caller's budget (None only if stats were
    # injected some other way)
    expansion_accounting: Optional[ExpansionAccounting] = None


def recommend(
    graph: CondensedGraph,
    workload: str = "multi_pass",          # 'point' | 'single_pass' | 'multi_pass' | 'repeated'
    duplicate_sensitive: bool = True,
    expand_margin: float = 1.2,
    budget_triples: Optional[int] = None,
    chunk_rows: Optional[int] = None,
) -> Recommendation:
    """Recommend host/device representations for ``graph``.

    The sizing stats are measured with one budgeted
    :meth:`~repro.core.condensed.CondensedGraph.expansion_stats` sweep
    (previously two unbudgeted full expansions — an advisor call could
    blow the memory wall it exists to warn about).  ``budget_triples``
    bounds that sweep's resident triples; the
    :class:`~repro.core.condensed.ExpansionAccounting` evidence rides on
    ``Recommendation.expansion_accounting``.
    """
    cond = max(graph.n_edges_condensed, 1)
    acct = ExpansionAccounting(budget_triples=budget_triples)
    exp_edges, dup = graph.expansion_stats(
        chunk_rows=chunk_rows,
        budget_triples=budget_triples,
        accounting=acct,
    )
    ratio = exp_edges / cond

    if ratio <= expand_margin:
        return Recommendation(
            "EXP", "EXP",
            f"expansion grows edges only {ratio:.2f}x (<= {expand_margin}); "
            "paper §6.5 suggests expanding outright",
            ratio, dup, acct,
        )
    if not duplicate_sensitive or workload == "point":
        return Recommendation(
            "C-DUP", "C-DUP",
            "duplicate-insensitive or point workload: operate on C-DUP "
            "directly (paper §4.1/§6.5)",
            ratio, dup, acct,
        )
    if workload == "repeated":
        rep = "DEDUP-2" if graph.is_single_layer() else "DEDUP-1"
        return Recommendation(
            rep, "DEDUP-C",
            "repeated analyses amortize one-time dedup rewriting "
            "(paper §6.5); device engine uses the vectorized correction",
            ratio, dup, acct,
        )
    return Recommendation(
        "BITMAP-2", "DEDUP-C",
        "multi-pass duplicate-sensitive analytics: BITMAP-2 on host "
        "iterators; correction-SpMV on device (DESIGN.md §2)",
        ratio, dup, acct,
    )
