"""End-to-end graph extraction: DSL text + Catalog -> CondensedGraph (§4.2).

Steps (paper §4.2):
  1. execute Nodes statements, build the real-node id space;
  2. plan every Edges statement (chain order + large-output marking);
  3. execute small-output segments eagerly ("handed to the database");
  4. create a virtual-node layer per postponed join attribute;
  5. assemble BipartiteEdges per segment into Chains (direct edges when a
     statement has no postponed join);
  6. optional preprocessing: expand cheap virtual nodes (Step 6).

Sharded extraction (DESIGN.md §7): pass ``n_shards > 1`` (or any
``ExtractionBudget``) and every step above runs partition-parallel —
Nodes tables and segment leading atoms are split into contiguous row
shards, each shard is executed with its transients charged against the
budget, and a merge step (sorted-key :class:`NodeSpace` union, local ->
global virtual-id remap, shard-order edge concatenation) reassembles a
``CondensedGraph`` byte-identical to the unsharded build.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .condensed import (
    BipartiteEdges,
    Chain,
    CondensedGraph,
    merge_chain_shards,
)
from .dsl import ExtractionQuery, Rule, parse
from .planner import (
    ChainPlan,
    ExtractionBudget,
    _bind_table,
    bind_atom,
    execute_segment,
    execute_segment_sharded,
    plan_rule,
)
from .relational import Catalog, ShardedTable, Table

__all__ = [
    "ExtractionResult",
    "NodeSpace",
    "extract",
    "extract_query",
    "extract_sharded",
]


@dataclasses.dataclass
class NodeSpace:
    """Raw node keys <-> dense ids, with per-type bookkeeping (paper §4.2
    Step 1: the real-node id space every chain endpoint indexes into).

    ``keys`` must be sorted strictly ascending (i.e. sorted and
    duplicate-free): :meth:`lookup` is a ``searchsorted``, and the sharded
    merge step unions per-shard key sets under the same invariant — so it
    is asserted at construction (the ``BipartiteEdges`` convention) rather
    than surfacing later as silently wrong lookups.
    """

    keys: np.ndarray          # raw key per dense id, sorted ascending
    type_ids: np.ndarray      # node-type index per dense id
    type_names: List[str]

    def __post_init__(self) -> None:
        self.keys = np.asarray(self.keys)
        if self.keys.ndim != 1:
            raise ValueError(f"keys must be 1-D, got shape {self.keys.shape}")
        if self.keys.size > 1 and not bool(np.all(self.keys[:-1] < self.keys[1:])):
            raise ValueError(
                "NodeSpace keys must be sorted strictly ascending "
                "(searchsorted lookups and shard merges rely on it)"
            )

    @property
    def n(self) -> int:
        return int(self.keys.size)

    def lookup(self, values: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Map raw keys to dense ids; second array = found mask."""
        values = np.asarray(values)
        if self.n == 0:
            # clip against n-1 == -1 would index the empty key array;
            # an empty space simply finds nothing.
            return (
                np.zeros(values.shape, dtype=np.int64),
                np.zeros(values.shape, dtype=bool),
            )
        idx = np.searchsorted(self.keys, values)
        idx = np.clip(idx, 0, self.n - 1)
        found = self.keys[idx] == values
        return idx, found


@dataclasses.dataclass
class ExtractionResult:
    """Everything one extraction produced (paper §4.2 output bundle):
    the condensed graph, the node id space, the per-rule plans, and —
    when the sharded pipeline ran — the shard count and the threaded
    :class:`~repro.core.planner.ExtractionBudget` accounting."""

    graph: CondensedGraph
    nodes: NodeSpace
    plans: List[ChainPlan]
    seconds: float
    dropped_endpoints: int
    mode: str
    n_shards: int = 1
    budget: Optional[ExtractionBudget] = None

    def summary(self) -> Dict[str, object]:
        out = {
            "n_real": self.graph.n_real,
            "n_virtual": self.graph.n_virtual,
            "edges_condensed": self.graph.n_edges_condensed,
            "seconds": round(self.seconds, 4),
            "mode": self.mode,
            "plans": [p.describe() for p in self.plans],
        }
        if self.n_shards != 1 or self.budget is not None:
            out["n_shards"] = self.n_shards
        if self.budget is not None:
            out["budget"] = self.budget.summary()
        return out


def _node_rule_parts(
    catalog: Catalog, rules: Sequence[Rule]
) -> List[Tuple[Rule, Table, str, int]]:
    """Bind every Nodes rule once; returns (rule, bound table, id var,
    type index) in rule order (paper §4.2 Step 1)."""
    parts = []
    for i, rule in enumerate(rules):
        if len(rule.atoms) != 1:
            raise ValueError("Nodes statements bind one relation each")
        t = bind_atom(catalog, rule.atoms[0], rule.comparisons)
        parts.append((rule, t, rule.head_vars[0], i))
    return parts


def _build_node_space(
    catalog: Catalog, rules: Sequence[Rule]
) -> Tuple[NodeSpace, Dict[str, np.ndarray]]:
    """One-shot node-space build (paper §4.2 Step 1): concatenate every
    Nodes rule's keys, dedup with first-occurrence wins for the type id.
    The sharded equivalent is :func:`_build_node_space_sharded`."""
    key_parts: List[np.ndarray] = []
    type_parts: List[np.ndarray] = []
    prop_parts: Dict[str, List[Tuple[np.ndarray, np.ndarray]]] = {}
    type_names: List[str] = []
    for rule, t, id_var, _ in _node_rule_parts(catalog, rules):
        keys = t.column(id_var)
        type_names.append(rule.atoms[0].relation)
        key_parts.append(keys)
        type_parts.append(np.full(keys.size, len(type_names) - 1, dtype=np.int32))
        for prop in rule.head_vars[1:]:
            prop_parts.setdefault(prop, []).append((keys, t.column(prop)))
    all_keys = np.concatenate(key_parts)
    all_types = np.concatenate(type_parts)
    uniq, first = np.unique(all_keys, return_index=True)
    space = NodeSpace(keys=uniq, type_ids=all_types[first], type_names=type_names)
    props = _scatter_props(space, prop_parts)
    return space, props


def _scatter_props(
    space: NodeSpace,
    prop_parts: Dict[str, List[Tuple[np.ndarray, np.ndarray]]],
) -> Dict[str, np.ndarray]:
    """Scatter per-rule property columns into the dense node space, in
    part order (later parts overwrite, matching the one-shot build)."""
    props: Dict[str, np.ndarray] = {}
    for name, parts in prop_parts.items():
        out = np.zeros(space.n, dtype=parts[0][1].dtype)
        for keys, vals in parts:
            idx, found = space.lookup(keys)
            out[idx[found]] = vals[found]
        props[name] = out
    return props


def _build_node_space_sharded(
    catalog: Catalog,
    rules: Sequence[Rule],
    n_shards: int,
    budget: Optional[ExtractionBudget],
) -> Tuple[NodeSpace, Dict[str, np.ndarray]]:
    """Shard-wise node-space build, byte-identical to
    :func:`_build_node_space` (DESIGN.md §7).

    Each Nodes rule's *base relation* is row-sharded and bound
    block-at-a-time (binding is row-local, so concatenated bound blocks
    equal the one-shot bound table row-for-row); every shard contributes
    its sorted unique keys tagged with the *global* bound-row index of
    their first occurrence.  The merge sorts candidates by that index and
    dedups, so the "first Nodes row wins" type assignment of the one-shot
    build is preserved exactly, while no single step ever holds more than
    one shard's scan block plus the (deduplicated) candidate set.
    """
    cand_keys: List[np.ndarray] = []
    cand_types: List[np.ndarray] = []
    cand_gidx: List[np.ndarray] = []
    prop_parts: Dict[str, List[Tuple[np.ndarray, np.ndarray]]] = {}
    type_names: List[str] = []
    offset = 0
    for tindex, rule in enumerate(rules):
        if len(rule.atoms) != 1:
            raise ValueError("Nodes statements bind one relation each")
        id_var = rule.head_vars[0]
        type_names.append(rule.atoms[0].relation)
        sharded = ShardedTable(
            catalog.table(rule.atoms[0].relation), n_shards, mode="rows"
        )
        for s in range(n_shards):
            if budget is not None:
                budget.begin_shard()
            block = sharded.shard(s)
            if budget is not None:
                budget.charge(len(block), "node-space base block")
            st = _bind_table(block, rule.atoms[0], rule.comparisons)
            if budget is not None:
                budget.charge(len(st), "bound node block")
                budget.release(len(block))
            keys = st.column(id_var)
            uk, first = np.unique(keys, return_index=True)
            cand_keys.append(uk)
            cand_types.append(np.full(uk.size, tindex, dtype=np.int32))
            cand_gidx.append(first.astype(np.int64) + offset)
            for prop in rule.head_vars[1:]:
                prop_parts.setdefault(prop, []).append((keys, st.column(prop)))
            offset += len(st)
            if budget is not None:
                budget.release(len(st))
                budget.end_shard()
    all_keys = np.concatenate(cand_keys)
    all_types = np.concatenate(cand_types)
    all_gidx = np.concatenate(cand_gidx)
    # sorted-key union with first-global-occurrence wins: ordering the
    # candidates by global row index makes np.unique's first-occurrence
    # index pick exactly the row the one-shot build would have picked
    order = np.argsort(all_gidx, kind="stable")
    uniq, first = np.unique(all_keys[order], return_index=True)
    space = NodeSpace(
        keys=uniq, type_ids=all_types[order][first], type_names=type_names
    )
    props = _scatter_props(space, prop_parts)
    return space, props


def _assemble_rule(
    nodes: NodeSpace,
    seg_results: Sequence[Tuple[np.ndarray, np.ndarray]],
    layer_keys: Sequence[np.ndarray],
) -> Tuple[Chain, int]:
    """Paper §4.2 Steps 4–5 for one Edges rule with postponed joins: map
    segment endpoint values into the real node space / the given virtual
    layer key spaces and wrap the per-segment ``BipartiteEdges`` in a
    :class:`Chain`.  ``layer_keys`` may be shard-local (the sharded path
    remaps to global ids in the merge step) or global (one-shot path).
    Returns the chain and the count of dropped real endpoints."""
    dropped = 0
    edges: List[BipartiteEdges] = []
    for k, (sv, dv) in enumerate(seg_results):
        if k == 0:
            sid, sok = nodes.lookup(sv)
            n_src = nodes.n
        else:
            sid = np.searchsorted(layer_keys[k - 1], sv)
            sok = np.ones(sid.size, dtype=bool)
            n_src = layer_keys[k - 1].size
        if k == len(seg_results) - 1:
            did, dok = nodes.lookup(dv)
            n_dst = nodes.n
        else:
            did = np.searchsorted(layer_keys[k], dv)
            dok = np.ones(did.size, dtype=bool)
            n_dst = layer_keys[k].size
        ok = sok & dok
        dropped += int((~ok).sum())
        edges.append(BipartiteEdges(sid[ok], did[ok], n_src, n_dst))
    return Chain(edges), dropped


def _local_layer_keys(
    seg_results: Sequence[Tuple[np.ndarray, np.ndarray]], n_layers: int
) -> List[np.ndarray]:
    """Virtual-node key space per postponed attribute (paper §4.2 Step 4):
    the distinct values observed on both sides of each segment boundary."""
    return [
        np.unique(np.concatenate([seg_results[k][1], seg_results[k + 1][0]]))
        for k in range(n_layers)
    ]


def extract_query(
    catalog: Catalog,
    query: ExtractionQuery,
    mode: str = "auto",
    preprocess: bool = False,
    n_shards: int = 1,
    budget: Optional[ExtractionBudget] = None,
) -> ExtractionResult:
    """Plan + execute a parsed extraction query (paper §4.2 Steps 1–6).

    ``mode`` selects join postponement (see :func:`repro.core.planner.
    plan_rule`); ``preprocess`` applies the paper's Step-6 cheap-virtual-
    node expansion.  With ``n_shards > 1`` — or any ``budget``, which
    forces the instrumented pipeline even for one shard — extraction runs
    sharded (DESIGN.md §7): per-table row partitions, per-shard segment
    execution under budget accounting, and a merge step that reassembles
    a ``CondensedGraph`` byte-identical to the unsharded build.
    """
    if n_shards != 1 or budget is not None:
        return _extract_query_sharded(
            catalog, query, mode, preprocess, max(n_shards, 1), budget
        )
    t0 = time.perf_counter()
    nodes, props = _build_node_space(catalog, query.nodes_rules)

    chains: List[Chain] = []
    direct_s: List[np.ndarray] = []
    direct_d: List[np.ndarray] = []
    plans: List[ChainPlan] = []
    dropped = 0

    for rule in query.edges_rules:
        plan = plan_rule(catalog, rule, mode=mode)
        plans.append(plan)
        id1, id2 = plan.endpoint_vars
        # Segment endpoint variables: ID1, large attrs..., ID2
        large_vars = [v for v, l in zip(plan.link_vars, plan.large) if l]
        seg_vars = [id1] + large_vars + [id2]
        seg_results: List[Tuple[np.ndarray, np.ndarray]] = []
        for k, seg in enumerate(plan.segments):
            seg_results.append(
                execute_segment(catalog, plan, seg, seg_vars[k], seg_vars[k + 1])
            )
        if len(seg_results) == 1:
            # No postponed join: direct real->real edges (multiplicity kept
            # as repeated entries — this IS the expanded multiset).
            sv, dv = seg_results[0]
            sid, sok = nodes.lookup(sv)
            did, dok = nodes.lookup(dv)
            ok = sok & dok
            dropped += int((~ok).sum())
            direct_s.append(sid[ok])
            direct_d.append(did[ok])
            continue
        layer_keys = _local_layer_keys(seg_results, len(large_vars))
        chain, d = _assemble_rule(nodes, seg_results, layer_keys)
        dropped += d
        chains.append(chain)

    graph = _finish_graph(nodes, props, chains, direct_s, direct_d, preprocess)
    return ExtractionResult(
        graph=graph,
        nodes=nodes,
        plans=plans,
        seconds=time.perf_counter() - t0,
        dropped_endpoints=dropped,
        mode=mode,
    )


def _finish_graph(
    nodes: NodeSpace,
    props: Dict[str, np.ndarray],
    chains: List[Chain],
    direct_s: List[np.ndarray],
    direct_d: List[np.ndarray],
    preprocess: bool,
) -> CondensedGraph:
    """Shared tail of both pipelines: concatenate direct edges, build the
    ``CondensedGraph``, optionally run paper §4.2 Step-6 preprocessing."""
    direct = None
    if direct_s:
        ds, dd = np.concatenate(direct_s), np.concatenate(direct_d)
        if ds.size:
            direct = BipartiteEdges(ds, dd, nodes.n, nodes.n)
    graph = CondensedGraph(
        nodes.n, chains, direct, node_properties=props, node_type=nodes.type_ids
    )
    if preprocess:
        graph = graph.preprocess()
    return graph


def _extract_query_sharded(
    catalog: Catalog,
    query: ExtractionQuery,
    mode: str,
    preprocess: bool,
    n_shards: int,
    budget: Optional[ExtractionBudget],
) -> ExtractionResult:
    """The sharded pipeline behind :func:`extract_query` (DESIGN.md §7).

    Identical structure to the one-shot path, except that every data-
    touching step runs per row shard: the node space is built shard-wise
    and merged by sorted key, each segment executes per shard via
    :func:`repro.core.planner.execute_segment_sharded`, each shard
    assembles a shard-local :class:`Chain` over its own virtual key
    spaces, and :func:`repro.core.condensed.merge_chain_shards` remaps
    those to the global sorted key union — producing edge arrays equal
    element-for-element to the unsharded build's.
    """
    t0 = time.perf_counter()
    nodes, props = _build_node_space_sharded(
        catalog, query.nodes_rules, n_shards, budget
    )

    chains: List[Chain] = []
    direct_s: List[np.ndarray] = []
    direct_d: List[np.ndarray] = []
    plans: List[ChainPlan] = []
    dropped = 0

    for rule in query.edges_rules:
        plan = plan_rule(catalog, rule, mode=mode)
        plans.append(plan)
        id1, id2 = plan.endpoint_vars
        large_vars = [v for v, l in zip(plan.link_vars, plan.large) if l]
        seg_vars = [id1] + large_vars + [id2]
        # per segment: one (in_values, out_values) pair per shard
        seg_shard: List[List[Tuple[np.ndarray, np.ndarray]]] = [
            execute_segment_sharded(
                catalog, plan, seg, seg_vars[k], seg_vars[k + 1],
                n_shards, budget,
            )
            for k, seg in enumerate(plan.segments)
        ]
        if len(plan.segments) == 1:
            # direct edges: per-shard lookups, concatenated in shard order
            for s in range(n_shards):
                sv, dv = seg_shard[0][s]
                sid, sok = nodes.lookup(sv)
                did, dok = nodes.lookup(dv)
                ok = sok & dok
                dropped += int((~ok).sum())
                direct_s.append(sid[ok])
                direct_d.append(did[ok])
            continue
        shard_chains: List[Chain] = []
        shard_keys: List[List[np.ndarray]] = []
        for s in range(n_shards):
            seg_results = [seg_shard[k][s] for k in range(len(plan.segments))]
            local_keys = _local_layer_keys(seg_results, len(large_vars))
            chain_s, d = _assemble_rule(nodes, seg_results, local_keys)
            dropped += d
            shard_chains.append(chain_s)
            shard_keys.append(local_keys)
        merged, _ = merge_chain_shards(shard_chains, shard_keys)
        chains.append(merged)

    graph = _finish_graph(nodes, props, chains, direct_s, direct_d, preprocess)
    return ExtractionResult(
        graph=graph,
        nodes=nodes,
        plans=plans,
        seconds=time.perf_counter() - t0,
        dropped_endpoints=dropped,
        mode=mode,
        n_shards=n_shards,
        budget=budget,
    )


def extract(
    catalog: Catalog,
    dsl_text: str,
    mode: str = "auto",
    preprocess: bool = False,
    n_shards: int = 1,
    budget: Optional[ExtractionBudget] = None,
) -> ExtractionResult:
    """Parse + plan + execute a DSL program against a catalog (paper §4.2;
    the Fig-1 entry point).  ``n_shards`` / ``budget`` select the sharded
    out-of-core pipeline (DESIGN.md §7)."""
    return extract_query(
        catalog, parse(dsl_text), mode=mode, preprocess=preprocess,
        n_shards=n_shards, budget=budget,
    )


def extract_sharded(
    catalog: Catalog,
    dsl_text: str,
    n_shards: int,
    max_resident_rows: Optional[int] = None,
    mode: str = "auto",
    preprocess: bool = False,
) -> ExtractionResult:
    """Convenience front-end for larger-than-memory extraction
    (DESIGN.md §7): shard the pipeline ``n_shards`` ways and enforce
    ``max_resident_rows`` per shard (violations raise
    :class:`~repro.core.planner.ExtractionBudgetError`).  The result's
    ``budget`` field carries the accounting; the graph is byte-identical
    to ``extract(catalog, dsl_text)``'s.
    """
    budget = ExtractionBudget(max_resident_rows=max_resident_rows)
    return extract(
        catalog, dsl_text, mode=mode, preprocess=preprocess,
        n_shards=n_shards, budget=budget,
    )
