"""End-to-end graph extraction: DSL text + Catalog -> CondensedGraph (§4.2).

Steps (paper §4.2):
  1. execute Nodes statements, build the real-node id space;
  2. plan every Edges statement (chain order + large-output marking);
  3. execute small-output segments eagerly ("handed to the database");
  4. create a virtual-node layer per postponed join attribute;
  5. assemble BipartiteEdges per segment into Chains (direct edges when a
     statement has no postponed join);
  6. optional preprocessing: expand cheap virtual nodes (Step 6).

Sharded extraction (DESIGN.md §7): pass ``n_shards > 1`` (or any
``ExtractionBudget``) and every step above runs partition-parallel —
Nodes tables and segment leading atoms are split into contiguous row
shards, each shard is executed with its transients charged against the
budget, and a merge step (sorted-key :class:`NodeSpace` union, local ->
global virtual-id remap, shard-order edge concatenation) reassembles a
``CondensedGraph`` byte-identical to the unsharded build.

Out-of-core assembly (DESIGN.md §8): pass ``spill_dir=`` and the per-
shard outputs no longer accumulate in host RAM — each shard's assembled
bundle (:class:`~repro.core.serialize.ShardAssembly`) is written to an
atomically-committed, byte-accounted spill record the moment the shard
finishes, and the merge becomes a log-depth tree reduce
(:func:`~repro.core.serialize.tree_merge_records`) that streams spilled
shards ``merge_arity`` at a time.  A finished spill directory is
self-contained: :func:`merge_spilled_graph` rebuilds the identical
``CondensedGraph`` from disk alone (and refuses a partial spill).  The
multi-host driver on top lives in
``repro.distributed.sharding.MultihostSpillExtraction``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .condensed import BipartiteEdges, Chain, CondensedGraph
from .dsl import ExtractionQuery, Rule, parse
from .planner import (
    ChainPlan,
    ExtractionBudget,
    _bind_table,
    bind_atom,
    execute_segment,
    execute_segment_shard,
    plan_rule,
)
from .relational import Catalog, ShardedTable, Table
from .serialize import (
    ShardAssembly,
    ShardSpillStore,
    SpillError,
    merge_assemblies,
    tree_merge_records,
)

__all__ = [
    "ExtractionResult",
    "NodeSpace",
    "extract",
    "extract_query",
    "extract_sharded",
    "merge_spilled_graph",
]


@dataclasses.dataclass
class NodeSpace:
    """Raw node keys <-> dense ids, with per-type bookkeeping (paper §4.2
    Step 1: the real-node id space every chain endpoint indexes into).

    ``keys`` must be sorted strictly ascending (i.e. sorted and
    duplicate-free): :meth:`lookup` is a ``searchsorted``, and the sharded
    merge step unions per-shard key sets under the same invariant — so it
    is asserted at construction (the ``BipartiteEdges`` convention) rather
    than surfacing later as silently wrong lookups.
    """

    keys: np.ndarray          # raw key per dense id, sorted ascending
    type_ids: np.ndarray      # node-type index per dense id
    type_names: List[str]

    def __post_init__(self) -> None:
        self.keys = np.asarray(self.keys)
        if self.keys.ndim != 1:
            raise ValueError(f"keys must be 1-D, got shape {self.keys.shape}")
        if self.keys.size > 1 and not bool(np.all(self.keys[:-1] < self.keys[1:])):
            raise ValueError(
                "NodeSpace keys must be sorted strictly ascending "
                "(searchsorted lookups and shard merges rely on it)"
            )

    @property
    def n(self) -> int:
        return int(self.keys.size)

    def lookup(self, values: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Map raw keys to dense ids; second array = found mask."""
        values = np.asarray(values)
        if self.n == 0:
            # clip against n-1 == -1 would index the empty key array;
            # an empty space simply finds nothing.
            return (
                np.zeros(values.shape, dtype=np.int64),
                np.zeros(values.shape, dtype=bool),
            )
        idx = np.searchsorted(self.keys, values)
        idx = np.clip(idx, 0, self.n - 1)
        found = self.keys[idx] == values
        return idx, found


@dataclasses.dataclass
class ExtractionResult:
    """Everything one extraction produced (paper §4.2 output bundle):
    the condensed graph, the node id space, the per-rule plans, and —
    when the sharded pipeline ran — the shard count and the threaded
    :class:`~repro.core.planner.ExtractionBudget` accounting."""

    graph: CondensedGraph
    nodes: NodeSpace
    plans: List[ChainPlan]
    seconds: float
    dropped_endpoints: int
    mode: str
    n_shards: int = 1
    budget: Optional[ExtractionBudget] = None

    def summary(self) -> Dict[str, object]:
        out = {
            "n_real": self.graph.n_real,
            "n_virtual": self.graph.n_virtual,
            "edges_condensed": self.graph.n_edges_condensed,
            "seconds": round(self.seconds, 4),
            "mode": self.mode,
            "plans": [p.describe() for p in self.plans],
        }
        if self.n_shards != 1 or self.budget is not None:
            out["n_shards"] = self.n_shards
        if self.budget is not None:
            out["budget"] = self.budget.summary()
        return out


def _node_rule_parts(
    catalog: Catalog, rules: Sequence[Rule]
) -> List[Tuple[Rule, Table, str, int]]:
    """Bind every Nodes rule once; returns (rule, bound table, id var,
    type index) in rule order (paper §4.2 Step 1)."""
    parts = []
    for i, rule in enumerate(rules):
        if len(rule.atoms) != 1:
            raise ValueError("Nodes statements bind one relation each")
        t = bind_atom(catalog, rule.atoms[0], rule.comparisons)
        parts.append((rule, t, rule.head_vars[0], i))
    return parts


def _build_node_space(
    catalog: Catalog, rules: Sequence[Rule]
) -> Tuple[NodeSpace, Dict[str, np.ndarray]]:
    """One-shot node-space build (paper §4.2 Step 1): concatenate every
    Nodes rule's keys, dedup with first-occurrence wins for the type id.
    The sharded equivalent is :func:`_build_node_space_sharded`."""
    key_parts: List[np.ndarray] = []
    type_parts: List[np.ndarray] = []
    prop_parts: Dict[str, List[Tuple[np.ndarray, np.ndarray]]] = {}
    type_names: List[str] = []
    for rule, t, id_var, _ in _node_rule_parts(catalog, rules):
        keys = t.column(id_var)
        type_names.append(rule.atoms[0].relation)
        key_parts.append(keys)
        type_parts.append(np.full(keys.size, len(type_names) - 1, dtype=np.int32))
        for prop in rule.head_vars[1:]:
            prop_parts.setdefault(prop, []).append((keys, t.column(prop)))
    return _node_space_from_parts(key_parts, type_parts, prop_parts, type_names)


def _node_space_from_parts(
    key_parts: Sequence[np.ndarray],
    type_parts: Sequence[np.ndarray],
    prop_parts: Dict[str, List[Tuple[np.ndarray, np.ndarray]]],
    type_names: List[str],
) -> Tuple[NodeSpace, Dict[str, np.ndarray]]:
    """Bound Nodes-rule parts (in rule order) -> ``(NodeSpace, props)``.

    The first-occurrence-wins dedup + property scatter shared by the
    one-shot build above and the incremental rebuild
    (:mod:`repro.core.delta`, DESIGN.md §9) — one implementation, so the
    two node spaces cannot drift.  ``key_parts`` may already carry a
    delete mask applied by the caller: a key whose every occurrence was
    masked out simply never reaches the union (the tombstone semantics)."""
    all_keys = np.concatenate(key_parts)
    all_types = np.concatenate(type_parts)
    uniq, first = np.unique(all_keys, return_index=True)
    space = NodeSpace(keys=uniq, type_ids=all_types[first], type_names=type_names)
    props = _scatter_props(space, prop_parts)
    return space, props


def _scatter_props(
    space: NodeSpace,
    prop_parts: Dict[str, List[Tuple[np.ndarray, np.ndarray]]],
) -> Dict[str, np.ndarray]:
    """Scatter per-rule property columns into the dense node space, in
    part order (later parts overwrite, matching the one-shot build)."""
    props: Dict[str, np.ndarray] = {}
    for name, parts in prop_parts.items():
        out = np.zeros(space.n, dtype=parts[0][1].dtype)
        for keys, vals in parts:
            idx, found = space.lookup(keys)
            out[idx[found]] = vals[found]
        props[name] = out
    return props


def _iter_node_shard_blocks(
    catalog: Catalog,
    rules: Sequence[Rule],
    n_shards: int,
    shard_range: Sequence[int],
    budget: Optional[ExtractionBudget],
):
    """Yield one bound Nodes-rule row shard at a time: ``(rule_index,
    rule, shard_index, bound_table, keys, unique_keys, first_local)``.

    The single implementation of the per-``(rule, shard)`` bind /
    budget-charge / unique sequence that both the in-memory candidate
    build (:func:`_build_node_space_sharded`) and the spill path
    (:func:`_spill_node_shards`) consume — they must never drift, or the
    spilled and resident node spaces stop being byte-identical.  The
    bound table is released from the budget when the caller advances the
    iterator, so each consumer must finish with one shard before asking
    for the next (both do: spill writes the record, the in-memory path
    stashes candidate arrays).
    """
    for tindex, rule in enumerate(rules):
        if len(rule.atoms) != 1:
            raise ValueError("Nodes statements bind one relation each")
        id_var = rule.head_vars[0]
        sharded = ShardedTable(
            catalog.table(rule.atoms[0].relation), n_shards, mode="rows"
        )
        for s in shard_range:
            if budget is not None:
                budget.begin_shard()
            block = sharded.shard(s)
            if budget is not None:
                budget.charge(len(block), "node-space base block")
            st = _bind_table(block, rule.atoms[0], rule.comparisons)
            if budget is not None:
                budget.charge(len(st), "bound node block")
                budget.release(len(block))
            keys = st.column(id_var)
            uk, first = np.unique(keys, return_index=True)
            yield tindex, rule, s, st, keys, uk, first
            if budget is not None:
                budget.release(len(st))
                budget.end_shard()


def _build_node_space_sharded(
    catalog: Catalog,
    rules: Sequence[Rule],
    n_shards: int,
    budget: Optional[ExtractionBudget],
) -> Tuple[NodeSpace, Dict[str, np.ndarray]]:
    """Shard-wise node-space build, byte-identical to
    :func:`_build_node_space` (DESIGN.md §7).

    Each Nodes rule's *base relation* is row-sharded and bound
    block-at-a-time (binding is row-local, so concatenated bound blocks
    equal the one-shot bound table row-for-row); every shard contributes
    its sorted unique keys tagged with the *global* bound-row index of
    their first occurrence.  The merge sorts candidates by that index and
    dedups, so the "first Nodes row wins" type assignment of the one-shot
    build is preserved exactly, while no single step ever holds more than
    one shard's scan block plus the (deduplicated) candidate set.
    """
    cand_keys: List[np.ndarray] = []
    cand_types: List[np.ndarray] = []
    cand_gidx: List[np.ndarray] = []
    prop_parts: Dict[str, List[Tuple[np.ndarray, np.ndarray]]] = {}
    type_names: List[str] = [rule.atoms[0].relation for rule in rules]
    offset = 0
    node_bytes = 0  # candidate + property buffers held until the merge
    for tindex, rule, s, st, keys, uk, first in _iter_node_shard_blocks(
        catalog, rules, n_shards, range(n_shards), budget
    ):
        cand_keys.append(uk)
        cand_types.append(np.full(uk.size, tindex, dtype=np.int32))
        cand_gidx.append(first.astype(np.int64) + offset)
        # charge what the spill path would have written as this shard's
        # node record (same bytes), so peak_assembly_bytes is comparable
        # between the accumulate-resident and spill-to-disk pipelines
        nb = int(uk.nbytes) + uk.size * 8
        for prop in rule.head_vars[1:]:
            prop_parts.setdefault(prop, []).append((keys, st.column(prop)))
        if rule.head_vars[1:]:
            nb += int(keys.nbytes) + sum(
                int(st.column(p).nbytes) for p in rule.head_vars[1:]
            )
        if budget is not None:
            budget.charge_assembly(nb, "node-shard candidates (resident)")
        node_bytes += nb
        offset += len(st)
    all_keys = np.concatenate(cand_keys)
    all_types = np.concatenate(cand_types)
    all_gidx = np.concatenate(cand_gidx)
    # sorted-key union with first-global-occurrence wins: ordering the
    # candidates by global row index makes np.unique's first-occurrence
    # index pick exactly the row the one-shot build would have picked
    order = np.argsort(all_gidx, kind="stable")
    uniq, first = np.unique(all_keys[order], return_index=True)
    space = NodeSpace(
        keys=uniq, type_ids=all_types[order][first], type_names=type_names
    )
    props = _scatter_props(space, prop_parts)
    if budget is not None:
        budget.release_assembly(node_bytes)
    return space, props


def _assemble_rule(
    nodes: NodeSpace,
    seg_results: Sequence[Tuple[np.ndarray, np.ndarray]],
    layer_keys: Sequence[np.ndarray],
) -> Tuple[Chain, int]:
    """Paper §4.2 Steps 4–5 for one Edges rule with postponed joins: map
    segment endpoint values into the real node space / the given virtual
    layer key spaces and wrap the per-segment ``BipartiteEdges`` in a
    :class:`Chain`.  ``layer_keys`` may be shard-local (the sharded path
    remaps to global ids in the merge step) or global (one-shot path).
    Returns the chain and the count of dropped real endpoints."""
    dropped = 0
    edges: List[BipartiteEdges] = []
    for k, (sv, dv) in enumerate(seg_results):
        if k == 0:
            sid, sok = nodes.lookup(sv)
            n_src = nodes.n
        else:
            sid = np.searchsorted(layer_keys[k - 1], sv)
            sok = np.ones(sid.size, dtype=bool)
            n_src = layer_keys[k - 1].size
        if k == len(seg_results) - 1:
            did, dok = nodes.lookup(dv)
            n_dst = nodes.n
        else:
            did = np.searchsorted(layer_keys[k], dv)
            dok = np.ones(did.size, dtype=bool)
            n_dst = layer_keys[k].size
        ok = sok & dok
        dropped += int((~ok).sum())
        edges.append(BipartiteEdges(sid[ok], did[ok], n_src, n_dst))
    return Chain(edges), dropped


def _local_layer_keys(
    seg_results: Sequence[Tuple[np.ndarray, np.ndarray]], n_layers: int
) -> List[np.ndarray]:
    """Virtual-node key space per postponed attribute (paper §4.2 Step 4):
    the distinct values observed on both sides of each segment boundary."""
    return [
        np.unique(np.concatenate([seg_results[k][1], seg_results[k + 1][0]]))
        for k in range(n_layers)
    ]


def extract_query(
    catalog: Catalog,
    query: ExtractionQuery,
    mode: str = "auto",
    preprocess: bool = False,
    n_shards: int = 1,
    budget: Optional[ExtractionBudget] = None,
    spill_dir: Optional[str] = None,
    merge_arity: int = 2,
    plan: Optional[object] = None,
) -> ExtractionResult:
    """Plan + execute a parsed extraction query (paper §4.2 Steps 1–6).

    ``mode`` selects join postponement (see :func:`repro.core.planner.
    plan_rule`); ``preprocess`` applies the paper's Step-6 cheap-virtual-
    node expansion.  With ``n_shards > 1`` — or any ``budget``, which
    forces the instrumented pipeline even for one shard — extraction runs
    sharded (DESIGN.md §7): per-table row partitions, per-shard segment
    execution under budget accounting, and a merge step that reassembles
    a ``CondensedGraph`` byte-identical to the unsharded build.

    ``spill_dir`` additionally makes the *assembly* out of core
    (DESIGN.md §8): each shard's output is written to a spill record as
    the shard finishes instead of accumulating in RAM, and the merge
    runs as an ``merge_arity``-way tree reduce over the spilled records.
    The result is still byte-identical; assembly-budget violations
    (``budget.max_assembly_bytes``) spill instead of raising.

    ``plan`` executes a :class:`repro.core.cost.ExtractionPlan` directly
    (DESIGN.md §12): the plan's config overrides ``n_shards`` /
    ``merge_arity`` / ``mode``, its budget caps are installed when the
    caller did not pass a ``budget``, and a spilling plan without an
    explicit ``spill_dir`` assembles through a temporary directory.
    """
    if plan is not None:
        cfg = plan.config
        n_shards = int(cfg.n_shards)
        merge_arity = int(cfg.merge_arity)
        mode = plan.mode
        if budget is None:
            budget = plan.make_budget()
        if cfg.spill and spill_dir is None:
            import os as _os
            import tempfile as _tempfile

            with _tempfile.TemporaryDirectory(prefix="extract-plan-") as td:
                return _extract_query_sharded(
                    catalog, query, mode, preprocess, n_shards, budget,
                    _os.path.join(td, "spill"), merge_arity,
                )
        if not cfg.spill:
            spill_dir = None
    if n_shards != 1 or budget is not None or spill_dir is not None:
        return _extract_query_sharded(
            catalog, query, mode, preprocess, max(n_shards, 1), budget,
            spill_dir, merge_arity,
        )
    t0 = time.perf_counter()
    nodes, props = _build_node_space(catalog, query.nodes_rules)

    chains: List[Chain] = []
    direct_s: List[np.ndarray] = []
    direct_d: List[np.ndarray] = []
    plans: List[ChainPlan] = []
    dropped = 0

    for rule in query.edges_rules:
        plan = plan_rule(catalog, rule, mode=mode)
        plans.append(plan)
        id1, id2 = plan.endpoint_vars
        # Segment endpoint variables: ID1, large attrs..., ID2
        large_vars = [v for v, l in zip(plan.link_vars, plan.large) if l]
        seg_vars = [id1] + large_vars + [id2]
        seg_results: List[Tuple[np.ndarray, np.ndarray]] = []
        for k, seg in enumerate(plan.segments):
            seg_results.append(
                execute_segment(catalog, plan, seg, seg_vars[k], seg_vars[k + 1])
            )
        if len(seg_results) == 1:
            # No postponed join: direct real->real edges (multiplicity kept
            # as repeated entries — this IS the expanded multiset).
            sv, dv = seg_results[0]
            sid, sok = nodes.lookup(sv)
            did, dok = nodes.lookup(dv)
            ok = sok & dok
            dropped += int((~ok).sum())
            direct_s.append(sid[ok])
            direct_d.append(did[ok])
            continue
        layer_keys = _local_layer_keys(seg_results, len(large_vars))
        chain, d = _assemble_rule(nodes, seg_results, layer_keys)
        dropped += d
        chains.append(chain)

    graph = _finish_graph(nodes, props, chains, direct_s, direct_d, preprocess)
    return ExtractionResult(
        graph=graph,
        nodes=nodes,
        plans=plans,
        seconds=time.perf_counter() - t0,
        dropped_endpoints=dropped,
        mode=mode,
    )


def _finish_graph(
    nodes: NodeSpace,
    props: Dict[str, np.ndarray],
    chains: List[Chain],
    direct_s: List[np.ndarray],
    direct_d: List[np.ndarray],
    preprocess: bool,
) -> CondensedGraph:
    """Shared tail of both pipelines: concatenate direct edges, build the
    ``CondensedGraph``, optionally run paper §4.2 Step-6 preprocessing."""
    direct = None
    if direct_s:
        ds, dd = np.concatenate(direct_s), np.concatenate(direct_d)
        if ds.size:
            direct = BipartiteEdges(ds, dd, nodes.n, nodes.n)
    graph = CondensedGraph(
        nodes.n, chains, direct, node_properties=props, node_type=nodes.type_ids
    )
    if preprocess:
        graph = graph.preprocess()
    return graph


def _plans_info(
    catalog: Catalog, query: ExtractionQuery, mode: str
) -> List[Tuple[ChainPlan, List[str], List[str]]]:
    """Plan every Edges rule once; returns ``(plan, seg_vars,
    large_vars)`` per rule — the static inputs of every shard's run."""
    info = []
    for rule in query.edges_rules:
        plan = plan_rule(catalog, rule, mode=mode)
        id1, id2 = plan.endpoint_vars
        large_vars = [v for v, l in zip(plan.link_vars, plan.large) if l]
        info.append((plan, [id1] + large_vars + [id2], large_vars))
    return info


def _extract_shard(
    catalog: Catalog,
    plans_info: Sequence[Tuple[ChainPlan, List[str], List[str]]],
    nodes: NodeSpace,
    shard_index: int,
    n_shards: int,
    budget: Optional[ExtractionBudget],
) -> ShardAssembly:
    """Run *every* Edges rule's segments for one shard and assemble the
    shard's complete output bundle (DESIGN.md §8).

    Shard-major driving order — all segments of shard ``s`` before any
    segment of shard ``s+1`` — is what makes spilling possible: the
    moment this returns, everything shard ``s`` will ever contribute is
    in one :class:`~repro.core.serialize.ShardAssembly`, ready to leave
    RAM.  Per-``(segment, shard)`` budget charges are identical to the
    segment-major order of DESIGN.md §7, so ``peak_resident_rows`` is
    unchanged.
    """
    chains: Dict[int, Tuple[Chain, List[np.ndarray]]] = {}
    direct: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}
    dropped = 0
    for r, (plan, seg_vars, large_vars) in enumerate(plans_info):
        seg_results = [
            execute_segment_shard(
                catalog, plan, seg, seg_vars[k], seg_vars[k + 1],
                shard_index, n_shards, budget,
            )
            for k, seg in enumerate(plan.segments)
        ]
        if len(plan.segments) == 1:
            sv, dv = seg_results[0]
            sid, sok = nodes.lookup(sv)
            did, dok = nodes.lookup(dv)
            ok = sok & dok
            dropped += int((~ok).sum())
            direct[r] = (sid[ok], did[ok])
            continue
        local_keys = _local_layer_keys(seg_results, len(large_vars))
        chain_s, d = _assemble_rule(nodes, seg_results, local_keys)
        dropped += d
        chains[r] = (chain_s, local_keys)
    return ShardAssembly(chains, direct, dropped)


def _graph_from_assembly(
    nodes: NodeSpace,
    props: Dict[str, np.ndarray],
    assembly: ShardAssembly,
    preprocess: bool,
) -> CondensedGraph:
    """Fully-merged assembly -> ``CondensedGraph``, in rule order (the
    order the one-shot build appends chains and direct blocks)."""
    chains = [assembly.chains[r][0] for r in sorted(assembly.chains)]
    direct_s = [assembly.direct[r][0] for r in sorted(assembly.direct)]
    direct_d = [assembly.direct[r][1] for r in sorted(assembly.direct)]
    return _finish_graph(nodes, props, chains, direct_s, direct_d, preprocess)


def _extract_query_sharded(
    catalog: Catalog,
    query: ExtractionQuery,
    mode: str,
    preprocess: bool,
    n_shards: int,
    budget: Optional[ExtractionBudget],
    spill_dir: Optional[str] = None,
    merge_arity: int = 2,
) -> ExtractionResult:
    """The sharded pipeline behind :func:`extract_query` (DESIGN.md §7/§8).

    Identical structure to the one-shot path, except that every data-
    touching step runs per row shard: the node space is built shard-wise
    and merged by sorted key, each shard executes all its segments via
    :func:`repro.core.planner.execute_segment_shard` and assembles a
    shard-local bundle over its own virtual key spaces, and the merge
    (:func:`repro.core.serialize.merge_assemblies`, built on
    :func:`repro.core.condensed.merge_chain_shards`) remaps those to the
    global sorted key union — producing edge arrays equal element-for-
    element to the unsharded build's.

    Without ``spill_dir`` every shard bundle stays resident until one
    single-pass merge (the §7 behaviour, assembly bytes charged to the
    budget); with it, bundles spill to disk as they finish and the merge
    is a ``merge_arity``-way tree reduce over the records (§8).
    """
    if spill_dir is not None and budget is None:
        budget = ExtractionBudget(spill_enabled=True)
    t0 = time.perf_counter()

    if spill_dir is not None:
        store = ShardSpillStore(spill_dir)
        # single-writer pipeline: drop any records a previous run left in
        # a reused directory, so finalize() certifies only this run's
        store.clear_records()
        _spill_node_shards(
            catalog, query.nodes_rules, n_shards, range(n_shards), store, budget
        )
        nodes, props = _node_space_from_spill(
            store, query.nodes_rules, n_shards, budget
        )
    else:
        store = None
        nodes, props = _build_node_space_sharded(
            catalog, query.nodes_rules, n_shards, budget
        )

    plans_info = _plans_info(catalog, query, mode)
    plans = [p for p, _, _ in plans_info]

    if store is not None:
        shard_names = _spill_chain_shards(
            catalog, plans_info, nodes, n_shards, range(n_shards), store, budget
        )
        final, merged = tree_merge_records(
            store, shard_names, arity=merge_arity, budget=budget
        )
        # the final merged assembly is the condensed graph itself — the
        # product, not an assembly buffer; its residency is already the
        # last tree round's output in merge_peak_resident_bytes
        if merged is None:  # single shard: no merge ran, read the leaf
            merged, _ = store.read_assembly(final)
        _write_nodespace_record(store, nodes, props)
        store.finalize(meta={
            "kind": "extraction_spill",
            "n_shards": n_shards,
            "n_rules": len(plans_info),
            "mode": mode,
            "preprocess": preprocess,
            "final_record": final,
        })
        graph = _graph_from_assembly(nodes, props, merged, preprocess)
    else:
        assemblies: List[ShardAssembly] = []
        charged = 0
        for s in range(n_shards):
            a = _extract_shard(catalog, plans_info, nodes, s, n_shards, budget)
            if budget is not None:
                nb = a.nbytes()
                budget.charge_assembly(nb, "shard assembly (resident)")
                charged += nb
            assemblies.append(a)
        merged = merge_assemblies(assemblies)
        if budget is not None:
            if len(assemblies) > 1:  # a single shard passes through unmerged
                budget.note_merge(charged + merged.nbytes())
            budget.release_assembly(charged)
        graph = _graph_from_assembly(nodes, props, merged, preprocess)

    return ExtractionResult(
        graph=graph,
        nodes=nodes,
        plans=plans,
        seconds=time.perf_counter() - t0,
        dropped_endpoints=merged.dropped,
        mode=mode,
        n_shards=n_shards,
        budget=budget,
    )


# ---------------------------------------------------------------------------
# Spill-phase primitives (DESIGN.md §8) — also driven, phase by phase with
# barriers between, by repro.distributed.sharding.MultihostSpillExtraction
# ---------------------------------------------------------------------------

def _node_record_name(rule_index: int, shard_index: int) -> str:
    return f"nodes_r{rule_index:03d}_s{shard_index:05d}"


def _shard_record_name(shard_index: int) -> str:
    return f"shard_s{shard_index:05d}"


def _spill_node_shards(
    catalog: Catalog,
    rules: Sequence[Rule],
    n_shards: int,
    shard_range: Sequence[int],
    store: ShardSpillStore,
    budget: Optional[ExtractionBudget],
) -> List[str]:
    """Spill phase 1: bind each Nodes rule's row shards in ``shard_range``
    and write one candidate record per ``(rule, shard)``.

    A record holds the shard-local *NodeSpace candidates* — the block's
    sorted-unique keys plus each key's first-occurrence row index local
    to the block — and the raw property columns.  The global merge
    (:func:`_node_space_from_spill`) orders candidates by the
    lexicographic triple ``(rule, shard, local_first)``, which equals the
    global bound-row order the one-shot build dedups in, without any
    shard needing the bound row counts of shards it never saw — that is
    what lets processes spill node candidates independently and exchange
    them through the spill directory.
    """
    names: List[str] = []
    for tindex, rule, s, st, keys, uk, first in _iter_node_shard_blocks(
        catalog, rules, n_shards, shard_range, budget
    ):
        arrays: Dict[str, np.ndarray] = {
            "cand_keys": uk,
            "cand_local_first": first.astype(np.int64),
        }
        prop_names = list(rule.head_vars[1:])
        if prop_names:
            arrays["prop_keys"] = keys
            for prop in prop_names:
                arrays[f"prop_{prop}"] = st.column(prop)
        nbytes = sum(int(np.asarray(a).nbytes) for a in arrays.values())
        name = _node_record_name(tindex, s)
        if budget is not None:
            budget.charge_assembly(nbytes, "node-shard record", spilling=True)
        store.write_record(
            name, arrays,
            meta={"rule": tindex, "shard": s, "props": prop_names},
        )
        if budget is not None:
            budget.note_spill(nbytes)
            budget.release_assembly(nbytes)
        names.append(name)
    return names


def _node_space_from_spill(
    store: ShardSpillStore,
    rules: Sequence[Rule],
    n_shards: int,
    budget: Optional[ExtractionBudget],
) -> Tuple[NodeSpace, Dict[str, np.ndarray]]:
    """Spill phase 2a: global :class:`NodeSpace` + dense properties from
    *every* ``(rule, shard)`` node record in the store.

    Candidates from all records are unioned with first-occurrence-wins
    ordered by ``(rule, shard, local_first)`` — byte-identical to the
    in-memory :func:`_build_node_space_sharded` and therefore to the
    one-shot build.  Properties are then scattered in a second streaming
    pass, one record resident at a time, in the same rule-major
    shard-minor order as the in-memory scatter (later parts overwrite).
    """
    cand_keys: List[np.ndarray] = []
    cand_rule: List[np.ndarray] = []
    cand_shard: List[np.ndarray] = []
    cand_local: List[np.ndarray] = []
    type_names = [rule.atoms[0].relation for rule in rules]
    cand_bytes = 0  # the candidate union is resident until the space exists
    for r in range(len(rules)):
        for s in range(n_shards):
            # selective read: the candidate pass never touches the
            # property columns — those stream back in the scatter pass
            arrays, meta, nbytes = store.read_record(
                _node_record_name(r, s),
                names=["cand_keys", "cand_local_first"],
            )
            uk = arrays["cand_keys"]
            cand_keys.append(uk)
            cand_rule.append(np.full(uk.size, r, dtype=np.int32))
            cand_shard.append(np.full(uk.size, s, dtype=np.int64))
            cand_local.append(arrays["cand_local_first"])
            nb = int(uk.nbytes) + uk.size * (8 + 8 + 4)
            if budget is not None:
                # the union itself cannot spill (it becomes the NodeSpace),
                # so charge it report-only like the other spill-path buffers
                budget.charge_assembly(
                    nb, "node-candidate union (resident)", spilling=True
                )
            cand_bytes += nb
    all_keys = np.concatenate(cand_keys)
    all_rule = np.concatenate(cand_rule)
    # first-global-occurrence wins: (rule, shard, local_first) is the
    # bound-row concat order of the one-shot build, lexsorted
    order = np.lexsort(
        (np.concatenate(cand_local), np.concatenate(cand_shard), all_rule)
    )
    uniq, first = np.unique(all_keys[order], return_index=True)
    space = NodeSpace(
        keys=uniq, type_ids=all_rule[order][first], type_names=type_names
    )
    if budget is not None:
        budget.release_assembly(cand_bytes)
    # streaming property scatter, rule-major shard-minor (= part order of
    # the in-memory build; later parts overwrite)
    props: Dict[str, np.ndarray] = {}
    for r, rule in enumerate(rules):
        prop_names = list(rule.head_vars[1:])
        if not prop_names:
            continue
        for s in range(n_shards):
            arrays, meta, nbytes = store.read_record(
                _node_record_name(r, s),
                names=["prop_keys"] + [f"prop_{p}" for p in prop_names],
            )
            # charge what was actually read (the selective load skips the
            # candidate arrays), not the record's total
            read_bytes = sum(int(a.nbytes) for a in arrays.values())
            if budget is not None:
                budget.charge_assembly(
                    read_bytes, "node-record scatter", spilling=True
                )
            keys = arrays["prop_keys"]
            idx, found = space.lookup(keys)
            for prop in prop_names:
                vals = arrays[f"prop_{prop}"]
                if prop not in props:
                    props[prop] = np.zeros(space.n, dtype=vals.dtype)
                props[prop][idx[found]] = vals[found]
            if budget is not None:
                budget.release_assembly(read_bytes)
    return space, props


def _spill_chain_shards(
    catalog: Catalog,
    plans_info: Sequence[Tuple[ChainPlan, List[str], List[str]]],
    nodes: NodeSpace,
    n_shards: int,
    shard_range: Sequence[int],
    store: ShardSpillStore,
    budget: Optional[ExtractionBudget],
) -> List[str]:
    """Spill phase 2b: extract each shard in ``shard_range`` (all rules,
    all segments) and write its :class:`ShardAssembly` record the moment
    it completes — the shard's output leaves RAM before the next shard's
    extraction begins, which is the whole out-of-core point."""
    names: List[str] = []
    for s in shard_range:
        assembly = _extract_shard(catalog, plans_info, nodes, s, n_shards, budget)
        nbytes = assembly.nbytes()
        name = _shard_record_name(s)
        if budget is not None:
            budget.charge_assembly(nbytes, "shard assembly", spilling=True)
        store.write_assembly(name, assembly)
        if budget is not None:
            budget.note_spill(nbytes)
            budget.release_assembly(nbytes)
        names.append(name)
    return names


def _write_nodespace_record(
    store: ShardSpillStore, nodes: NodeSpace, props: Dict[str, np.ndarray]
) -> int:
    """Persist the merged node space so a finished spill directory is
    self-contained (:func:`merge_spilled_graph` needs no catalog)."""
    arrays: Dict[str, np.ndarray] = {"keys": nodes.keys, "type_ids": nodes.type_ids}
    for name, arr in props.items():
        arrays[f"prop_{name}"] = np.asarray(arr)
    return store.write_record(
        "nodespace", arrays,
        meta={"type_names": nodes.type_names, "props": sorted(props)},
    )


def _read_nodespace_record(
    store: ShardSpillStore,
) -> Tuple[NodeSpace, Dict[str, np.ndarray]]:
    arrays, meta, _ = store.read_record("nodespace")
    nodes = NodeSpace(
        keys=arrays["keys"], type_ids=arrays["type_ids"],
        type_names=list(meta["type_names"]),
    )
    props = {name: arrays[f"prop_{name}"] for name in meta["props"]}
    return nodes, props


def merge_spilled_graph(
    spill_dir: str,
    merge_arity: int = 2,
    budget: Optional[ExtractionBudget] = None,
    reuse_final: bool = True,
) -> Tuple[CondensedGraph, NodeSpace]:
    """Rebuild the ``CondensedGraph`` from a finished spill directory
    alone — no catalog, no re-extraction (DESIGN.md §8).

    Validates the spill first (:meth:`ShardSpillStore.open`): a partial
    directory — missing closing manifest, missing or truncated records,
    uncommitted ``*.tmp-*`` litter — raises
    :class:`~repro.core.serialize.SpillError` instead of being silently
    merged.  The writing run records its fully-merged partial in the
    manifest (``final_record``); with ``reuse_final`` (the default) that
    record is loaded directly — a pure read, safe on read-only storage.
    With ``reuse_final=False`` (or when the final record is absent) the
    per-shard assembly records are tree-reduced again ``merge_arity`` at
    a time.  Either way the graph is byte-identical to the extraction
    that wrote the spill (and to the unsharded build).
    """
    store = ShardSpillStore.open(spill_dir)
    meta = store.manifest()["meta"]
    if meta.get("kind") != "extraction_spill":
        raise SpillError(
            f"{spill_dir!r} is not an extraction spill (kind={meta.get('kind')!r})"
        )
    n_shards = int(meta["n_shards"])
    nodes, props = _read_nodespace_record(store)
    final_record = meta.get("final_record")
    if reuse_final and final_record and store.has_record(final_record):
        merged, _ = store.read_assembly(final_record)
    else:
        shard_names = [_shard_record_name(s) for s in range(n_shards)]
        missing = [n for n in shard_names if not store.has_record(n)]
        if missing:
            raise SpillError(f"spill is missing shard records: {missing}")
        final, merged = tree_merge_records(
            store, shard_names, arity=merge_arity, out_prefix="remerge_",
            budget=budget,
        )
        if merged is None:
            merged, _ = store.read_assembly(final)
        if final.startswith("remerge_"):
            store.delete_record(final)
    graph = _graph_from_assembly(nodes, props, merged, bool(meta["preprocess"]))
    return graph, nodes


def extract(
    catalog: Catalog,
    dsl_text: str,
    mode: str = "auto",
    preprocess: bool = False,
    n_shards: int = 1,
    budget: Optional[ExtractionBudget] = None,
    spill_dir: Optional[str] = None,
    merge_arity: int = 2,
    plan: Optional[object] = None,
) -> ExtractionResult:
    """Parse + plan + execute a DSL program against a catalog (paper §4.2;
    the Fig-1 entry point).  ``n_shards`` / ``budget`` select the sharded
    pipeline (DESIGN.md §7); ``spill_dir`` makes assembly out-of-core
    with a ``merge_arity``-way tree-reduce merge (DESIGN.md §8).

    ``plan`` takes a :class:`repro.core.cost.ExtractionPlan` (from
    :func:`repro.core.cost.plan`, DESIGN.md §12): its config supplies
    ``n_shards`` / ``merge_arity`` / spilling and — unless the caller
    passes an explicit ``budget`` — its budget caps; the remaining
    explicit knobs are ignored in its favor."""
    return extract_query(
        catalog, parse(dsl_text), mode=mode, preprocess=preprocess,
        n_shards=n_shards, budget=budget, spill_dir=spill_dir,
        merge_arity=merge_arity, plan=plan,
    )


def extract_sharded(
    catalog: Catalog,
    dsl_text: str,
    n_shards: int,
    max_resident_rows: Optional[int] = None,
    mode: str = "auto",
    preprocess: bool = False,
    spill_dir: Optional[str] = None,
    max_assembly_bytes: Optional[int] = None,
    merge_arity: int = 2,
) -> ExtractionResult:
    """Convenience front-end for larger-than-memory extraction
    (DESIGN.md §7/§8): shard the pipeline ``n_shards`` ways and enforce
    ``max_resident_rows`` per shard (violations raise
    :class:`~repro.core.planner.ExtractionBudgetError`).
    ``max_assembly_bytes`` caps the assembly buffers too: without
    ``spill_dir`` an over-cap accumulation raises; with it, shard outputs
    spill to disk as they finish and the merge streams them back
    ``merge_arity`` at a time.  The result's ``budget`` field carries the
    accounting; the graph is byte-identical to
    ``extract(catalog, dsl_text)``'s either way.
    """
    budget = ExtractionBudget(
        max_resident_rows=max_resident_rows,
        max_assembly_bytes=max_assembly_bytes,
    )
    return extract(
        catalog, dsl_text, mode=mode, preprocess=preprocess,
        n_shards=n_shards, budget=budget, spill_dir=spill_dir,
        merge_arity=merge_arity,
    )
