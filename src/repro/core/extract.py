"""End-to-end graph extraction: DSL text + Catalog -> CondensedGraph (§4.2).

Steps (paper §4.2):
  1. execute Nodes statements, build the real-node id space;
  2. plan every Edges statement (chain order + large-output marking);
  3. execute small-output segments eagerly ("handed to the database");
  4. create a virtual-node layer per postponed join attribute;
  5. assemble BipartiteEdges per segment into Chains (direct edges when a
     statement has no postponed join);
  6. optional preprocessing: expand cheap virtual nodes (Step 6).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .condensed import BipartiteEdges, Chain, CondensedGraph
from .dsl import ExtractionQuery, Rule, parse
from .planner import ChainPlan, bind_atom, execute_segment, plan_rule
from .relational import Catalog

__all__ = ["ExtractionResult", "extract", "extract_query"]


@dataclasses.dataclass
class NodeSpace:
    """Raw node keys <-> dense ids, with per-type bookkeeping."""

    keys: np.ndarray          # raw key per dense id
    type_ids: np.ndarray      # node-type index per dense id
    type_names: List[str]

    @property
    def n(self) -> int:
        return int(self.keys.size)

    def lookup(self, values: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Map raw keys to dense ids; second array = found mask."""
        values = np.asarray(values)
        if self.n == 0:
            # clip against n-1 == -1 would index the empty key array;
            # an empty space simply finds nothing.
            return (
                np.zeros(values.shape, dtype=np.int64),
                np.zeros(values.shape, dtype=bool),
            )
        idx = np.searchsorted(self.keys, values)
        idx = np.clip(idx, 0, self.n - 1)
        found = self.keys[idx] == values
        return idx, found


@dataclasses.dataclass
class ExtractionResult:
    graph: CondensedGraph
    nodes: NodeSpace
    plans: List[ChainPlan]
    seconds: float
    dropped_endpoints: int
    mode: str

    def summary(self) -> Dict[str, object]:
        return {
            "n_real": self.graph.n_real,
            "n_virtual": self.graph.n_virtual,
            "edges_condensed": self.graph.n_edges_condensed,
            "seconds": round(self.seconds, 4),
            "mode": self.mode,
            "plans": [p.describe() for p in self.plans],
        }


def _build_node_space(
    catalog: Catalog, rules: Sequence[Rule]
) -> Tuple[NodeSpace, Dict[str, np.ndarray]]:
    key_parts: List[np.ndarray] = []
    type_parts: List[np.ndarray] = []
    prop_parts: Dict[str, List[Tuple[np.ndarray, np.ndarray]]] = {}
    type_names: List[str] = []
    for rule in rules:
        if len(rule.atoms) != 1:
            raise ValueError("Nodes statements bind one relation each")
        t = bind_atom(catalog, rule.atoms[0], rule.comparisons)
        id_var = rule.head_vars[0]
        keys = t.column(id_var)
        type_names.append(rule.atoms[0].relation)
        key_parts.append(keys)
        type_parts.append(np.full(keys.size, len(type_names) - 1, dtype=np.int32))
        for prop in rule.head_vars[1:]:
            prop_parts.setdefault(prop, []).append((keys, t.column(prop)))
    all_keys = np.concatenate(key_parts)
    all_types = np.concatenate(type_parts)
    uniq, first = np.unique(all_keys, return_index=True)
    space = NodeSpace(keys=uniq, type_ids=all_types[first], type_names=type_names)
    props: Dict[str, np.ndarray] = {}
    for name, parts in prop_parts.items():
        out = np.zeros(space.n, dtype=parts[0][1].dtype)
        for keys, vals in parts:
            idx, found = space.lookup(keys)
            out[idx[found]] = vals[found]
        props[name] = out
    return space, props


def extract_query(
    catalog: Catalog,
    query: ExtractionQuery,
    mode: str = "auto",
    preprocess: bool = False,
) -> ExtractionResult:
    t0 = time.perf_counter()
    nodes, props = _build_node_space(catalog, query.nodes_rules)

    chains: List[Chain] = []
    direct_s: List[np.ndarray] = []
    direct_d: List[np.ndarray] = []
    plans: List[ChainPlan] = []
    dropped = 0

    for rule in query.edges_rules:
        plan = plan_rule(catalog, rule, mode=mode)
        plans.append(plan)
        id1, id2 = plan.endpoint_vars
        # Segment endpoint variables: ID1, large attrs..., ID2
        large_vars = [v for v, l in zip(plan.link_vars, plan.large) if l]
        seg_vars = [id1] + large_vars + [id2]
        seg_results: List[Tuple[np.ndarray, np.ndarray]] = []
        for k, seg in enumerate(plan.segments):
            seg_results.append(
                execute_segment(catalog, plan, seg, seg_vars[k], seg_vars[k + 1])
            )
        if len(seg_results) == 1:
            # No postponed join: direct real->real edges (multiplicity kept
            # as repeated entries — this IS the expanded multiset).
            sv, dv = seg_results[0]
            sid, sok = nodes.lookup(sv)
            did, dok = nodes.lookup(dv)
            ok = sok & dok
            dropped += int((~ok).sum())
            direct_s.append(sid[ok])
            direct_d.append(did[ok])
            continue
        # Virtual layer id spaces: distinct values per postponed attribute.
        layer_keys: List[np.ndarray] = []
        for k in range(len(large_vars)):
            vals = np.concatenate([seg_results[k][1], seg_results[k + 1][0]])
            layer_keys.append(np.unique(vals))
        edges: List[BipartiteEdges] = []
        for k, (sv, dv) in enumerate(seg_results):
            if k == 0:
                sid, sok = nodes.lookup(sv)
                n_src = nodes.n
            else:
                sid = np.searchsorted(layer_keys[k - 1], sv)
                sok = np.ones(sid.size, dtype=bool)
                n_src = layer_keys[k - 1].size
            if k == len(seg_results) - 1:
                did, dok = nodes.lookup(dv)
                n_dst = nodes.n
            else:
                did = np.searchsorted(layer_keys[k], dv)
                dok = np.ones(did.size, dtype=bool)
                n_dst = layer_keys[k].size
            ok = sok & dok
            dropped += int((~ok).sum())
            edges.append(BipartiteEdges(sid[ok], did[ok], n_src, n_dst))
        chains.append(Chain(edges))

    direct = None
    if direct_s:
        ds, dd = np.concatenate(direct_s), np.concatenate(direct_d)
        if ds.size:
            direct = BipartiteEdges(ds, dd, nodes.n, nodes.n)
    graph = CondensedGraph(
        nodes.n, chains, direct, node_properties=props, node_type=nodes.type_ids
    )
    if preprocess:
        graph = graph.preprocess()
    return ExtractionResult(
        graph=graph,
        nodes=nodes,
        plans=plans,
        seconds=time.perf_counter() - t0,
        dropped_endpoints=dropped,
        mode=mode,
    )


def extract(
    catalog: Catalog,
    dsl_text: str,
    mode: str = "auto",
    preprocess: bool = False,
) -> ExtractionResult:
    """Parse + plan + execute a DSL program against a catalog."""
    return extract_query(catalog, parse(dsl_text), mode=mode, preprocess=preprocess)
