"""Datalog-based graph-extraction DSL (paper §3.2).

Grammar (non-recursive Datalog subset + comparison predicates)::

    query    := rule+
    rule     := head ":-" body "."
    head     := ("Nodes" | "Edges") "(" var ("," var)* ")"
    body     := atom ("," atom)*
    atom     := RelName "(" arg ("," arg)* ")" | comparison
    arg      := var | "_" | INT | 'string'
    comparison := var OP (INT | FLOAT | 'string'),  OP in < > <= >= = !=

Examples (paper Figures 1 & 4)::

    Nodes(ID, Name)  :- Author(ID, Name).
    Edges(ID1, ID2)  :- AuthorPub(ID1, PubID), AuthorPub(ID2, PubID).

    Nodes(ID, Name)  :- Customer(ID, Name).
    Edges(ID1, ID2)  :- Orders(ok1, ID1), LineItem(ok1, pk),
                        Orders(ok2, ID2), LineItem(ok2, pk).

Atom arguments map positionally to table columns.  Constants in atom
arguments or comparison predicates become selections.
"""
from __future__ import annotations

import dataclasses
import re
from typing import List, Optional, Sequence, Tuple, Union

__all__ = [
    "Atom",
    "Comparison",
    "Rule",
    "ExtractionQuery",
    "parse",
    "ParseError",
]


class ParseError(ValueError):
    pass


Constant = Union[int, float, str]


@dataclasses.dataclass(frozen=True)
class Atom:
    relation: str
    args: Tuple[str, ...]          # variable names; "_" = wildcard
    constants: Tuple[Tuple[int, Constant], ...] = ()  # (position, value)

    def variables(self) -> Tuple[str, ...]:
        return tuple(a for a in self.args if a != "_")


@dataclasses.dataclass(frozen=True)
class Comparison:
    var: str
    op: str
    value: Constant

    _OPS = {
        "<": lambda a, b: a < b,
        ">": lambda a, b: a > b,
        "<=": lambda a, b: a <= b,
        ">=": lambda a, b: a >= b,
        "=": lambda a, b: a == b,
        "!=": lambda a, b: a != b,
    }

    def apply(self, col):
        import numpy as np

        return self._OPS[self.op](col, self.value)


@dataclasses.dataclass(frozen=True)
class Rule:
    kind: str                      # "nodes" | "edges"
    head_vars: Tuple[str, ...]
    atoms: Tuple[Atom, ...]
    comparisons: Tuple[Comparison, ...] = ()


@dataclasses.dataclass(frozen=True)
class ExtractionQuery:
    nodes_rules: Tuple[Rule, ...]
    edges_rules: Tuple[Rule, ...]

    @property
    def heterogeneous(self) -> bool:
        return len(self.nodes_rules) > 1


_TOKEN_RE = re.compile(
    r"""
    (?P<ws>\s+|\#[^\n]*|%[^\n]*)
  | (?P<implies>:-)
  | (?P<op><=|>=|!=|<|>|=)
  | (?P<lparen>\()
  | (?P<rparen>\))
  | (?P<comma>,)
  | (?P<dot>\.)
  | (?P<string>'[^']*'|"[^"]*")
  | (?P<number>-?\d+(?:\.\d+)?)
  | (?P<ident>[A-Za-z_][A-Za-z0-9_]*)
    """,
    re.VERBOSE,
)


def _tokenize(text: str) -> List[Tuple[str, str]]:
    out: List[Tuple[str, str]] = []
    pos = 0
    while pos < len(text):
        m = _TOKEN_RE.match(text, pos)
        if not m:
            raise ParseError(f"unexpected character {text[pos]!r} at offset {pos}")
        pos = m.end()
        kind = m.lastgroup
        if kind != "ws":
            out.append((kind, m.group()))
    out.append(("eof", ""))
    return out


class _Parser:
    def __init__(self, tokens: List[Tuple[str, str]]):
        self.tokens = tokens
        self.i = 0

    def peek(self) -> Tuple[str, str]:
        return self.tokens[self.i]

    def next(self, kind: Optional[str] = None) -> Tuple[str, str]:
        tok = self.tokens[self.i]
        if kind is not None and tok[0] != kind:
            raise ParseError(f"expected {kind}, got {tok[1]!r}")
        self.i += 1
        return tok

    # rule := head :- body .
    def parse_rule(self) -> Rule:
        _, name = self.next("ident")
        if name not in ("Nodes", "Edges"):
            raise ParseError(f"rule head must be Nodes or Edges, got {name!r}")
        head_vars = self._arglist_vars()
        self.next("implies")
        atoms: List[Atom] = []
        comparisons: List[Comparison] = []
        while True:
            atoms_or_cmp = self._body_item()
            if isinstance(atoms_or_cmp, Atom):
                atoms.append(atoms_or_cmp)
            else:
                comparisons.append(atoms_or_cmp)
            if self.peek()[0] == "comma":
                self.next("comma")
                continue
            break
        self.next("dot")
        kind = name.lower()
        if kind == "nodes" and len(head_vars) < 1:
            raise ParseError("Nodes needs at least an ID attribute")
        if kind == "edges" and len(head_vars) < 2:
            raise ParseError("Edges needs at least (ID1, ID2)")
        if not atoms:
            raise ParseError("rule body needs at least one relational atom")
        return Rule(kind, tuple(head_vars), tuple(atoms), tuple(comparisons))

    def _arglist_vars(self) -> List[str]:
        self.next("lparen")
        out: List[str] = []
        while True:
            _, v = self.next("ident")
            out.append(v)
            if self.peek()[0] == "comma":
                self.next("comma")
                continue
            break
        self.next("rparen")
        return out

    def _body_item(self) -> Union[Atom, Comparison]:
        kind, val = self.next()
        if kind != "ident":
            raise ParseError(f"expected atom or comparison, got {val!r}")
        if self.peek()[0] == "op":  # comparison: var OP const
            _, op = self.next("op")
            ckind, cval = self.next()
            if ckind == "number":
                value: Constant = float(cval) if "." in cval else int(cval)
            elif ckind == "string":
                value = cval[1:-1]
            else:
                raise ParseError(f"comparison value must be constant, got {cval!r}")
            return Comparison(val, op, value)
        # relational atom
        self.next("lparen")
        args: List[str] = []
        constants: List[Tuple[int, Constant]] = []
        pos = 0
        while True:
            akind, aval = self.next()
            if akind == "ident":
                args.append(aval)
            elif akind == "number":
                args.append("_")
                constants.append((pos, float(aval) if "." in aval else int(aval)))
            elif akind == "string":
                args.append("_")
                constants.append((pos, aval[1:-1]))
            else:
                raise ParseError(f"bad atom argument {aval!r}")
            pos += 1
            if self.peek()[0] == "comma":
                self.next("comma")
                continue
            break
        self.next("rparen")
        return Atom(val, tuple(args), tuple(constants))


def parse(text: str) -> ExtractionQuery:
    """Parse a DSL program into an :class:`ExtractionQuery`."""
    parser = _Parser(_tokenize(text))
    nodes: List[Rule] = []
    edges: List[Rule] = []
    while parser.peek()[0] != "eof":
        rule = parser.parse_rule()
        (nodes if rule.kind == "nodes" else edges).append(rule)
    if not nodes:
        raise ParseError("query needs at least one Nodes statement")
    if not edges:
        raise ParseError("query needs at least one Edges statement")
    return ExtractionQuery(tuple(nodes), tuple(edges))
