"""GraphGen core: the paper's contribution as a composable library.

Public API:

    from repro.core import extract, parse, CondensedGraph
    from repro.core import engine, algorithms, dedup, advisor
"""
from .condensed import BipartiteEdges, Chain, CondensedGraph, ExpandedGraph
from .dsl import ExtractionQuery, ParseError, parse
from .extract import ExtractionResult, extract, extract_query
from .relational import Catalog, Table
from .advisor import recommend
from .serialize import export_edge_list, load_condensed, save_condensed

__all__ = [
    "BipartiteEdges",
    "Chain",
    "CondensedGraph",
    "ExpandedGraph",
    "ExtractionQuery",
    "ExtractionResult",
    "ParseError",
    "Catalog",
    "Table",
    "parse",
    "extract",
    "extract_query",
    "recommend",
    "save_condensed",
    "load_condensed",
    "export_edge_list",
]
