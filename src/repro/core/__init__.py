"""GraphGen core: the paper's contribution as a composable library.

Public API:

    from repro.core import extract, parse, CondensedGraph
    from repro.core import engine, algorithms, dedup, advisor
"""
from .condensed import (
    BipartiteEdges,
    Chain,
    CondensedGraph,
    ExpandedGraph,
    graphs_identical,
)
from .dsl import ExtractionQuery, ParseError, parse
from .extract import (
    ExtractionResult,
    extract,
    extract_query,
    extract_sharded,
    merge_spilled_graph,
)
from .planner import ExtractionBudget, ExtractionBudgetError
from .relational import Catalog, ShardedTable, Table
from .advisor import recommend
from .cost import (
    ExtractionPlan,
    PlanConfig,
    PlanReport,
    Throughputs,
    plan,
    profile_query,
)
from .delta import GraphVersion, LiveGraph, apply_delta, mutate_catalog
from .serialize import (
    DeltaLog,
    ShardAssembly,
    ShardSpillStore,
    SpillError,
    export_edge_list,
    load_condensed,
    save_condensed,
)

__all__ = [
    "BipartiteEdges",
    "Chain",
    "CondensedGraph",
    "ExpandedGraph",
    "ExtractionQuery",
    "ExtractionResult",
    "ExtractionBudget",
    "ExtractionBudgetError",
    "ParseError",
    "Catalog",
    "ShardedTable",
    "Table",
    "parse",
    "extract",
    "extract_query",
    "extract_sharded",
    "graphs_identical",
    "merge_spilled_graph",
    "recommend",
    "plan",
    "profile_query",
    "ExtractionPlan",
    "PlanConfig",
    "PlanReport",
    "Throughputs",
    "GraphVersion",
    "LiveGraph",
    "apply_delta",
    "mutate_catalog",
    "DeltaLog",
    "save_condensed",
    "load_condensed",
    "export_edge_list",
    "ShardAssembly",
    "ShardSpillStore",
    "SpillError",
]
