"""Condensed graph representations (C-DUP and friends).

The paper's central data structure: a directed acyclic multi-layer graph in
which *real* nodes are connected only through layers of *virtual* nodes
(one layer per postponed large-output join attribute).  An edge ``u -> v``
exists in the *expanded* graph iff at least one directed path
``u_s -> ... -> v_t`` exists here; the number of such paths is the pair's
*multiplicity* (the duplication problem, paper §4.1).

Linear-algebra view (see DESIGN.md §2): a single-layer chain is an
incidence pair ``(B_in, B_out)`` and the expanded multiplicity matrix is
``M = B_in · B_out``; a k-layer chain is the product of k+1 sparse
matrices.  All propagation in :mod:`repro.core.engine` exploits this
factorization instead of materializing ``M``.

Everything in this module is host-side NumPy — extraction and dedup are
irregular/preprocessing work; the device-facing arrays are built by
``repro.core.engine.to_device`` / ``to_device_packed`` from these
containers.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "BipartiteEdges",
    "Chain",
    "CondensedGraph",
    "ExpandedGraph",
    "ExpansionAccounting",
    "CSR",
    "build_csr",
    "fold_path_pairs",
    "split_expansion_budget",
    "merge_sorted_unique",
    "merge_chain_shards",
    "graphs_identical",
    "DEFAULT_CHUNK_ROWS",
]

# Leading-row block size used when a streaming caller gives no explicit
# chunking: small graphs expand in one block (no overhead vs the old
# one-shot path), graphs with more real nodes get bounded blocks.
DEFAULT_CHUNK_ROWS = 65_536


@dataclasses.dataclass
class ExpansionAccounting:
    """Bookkeeping for streaming expansion (DESIGN.md §2).

    One instance is threaded through ``iter_path_pairs`` (which reports the
    active chunk's raw-composition bound) and :func:`fold_path_pairs`
    (which reports sorted-run residency), so ``peak_resident_triples`` is
    an upper bound on the number of expanded ``(u, v, m)`` triples live at
    any instant — the quantity the streaming-budget benchmarks assert
    against ``budget_triples``.
    """

    budget_triples: Optional[int] = None
    n_chunks: int = 0                # chunks yielded by the iterator
    n_paths: int = 0                 # raw expanded paths walked
    n_triples_out: int = 0           # aggregated triples yielded
    peak_resident_triples: int = 0   # max triples live at once
    n_merges: int = 0                # sorted-run consolidation passes
    n_overflow_chunks: int = 0       # single rows whose cost exceeds budget
    resident_chunk: int = 0          # live: active chunk's raw bound
    resident_runs: int = 0           # live: triples held in fold runs

    def _observe(self) -> None:
        live = self.resident_chunk + self.resident_runs
        if live > self.peak_resident_triples:
            self.peak_resident_triples = live

    def begin_chunk(self, cost: int, budget: Optional[int] = None) -> None:
        """``budget`` is the *chunker's* active budget (the half split off
        ``budget_triples``) — a chunk above it is a single row too big to
        honor the residency guarantee, recorded as an overflow."""
        self.n_chunks += 1
        self.resident_chunk = int(cost)
        if budget is not None and cost > budget:
            self.n_overflow_chunks += 1
        self._observe()

    def end_chunk(self, n_paths: int, n_triples: int) -> None:
        self.n_paths += int(n_paths)
        self.n_triples_out += int(n_triples)
        self.resident_chunk = 0

    def runs_changed(self, resident: int, merged: bool = False) -> None:
        self.resident_runs = int(resident)
        if merged:
            self.n_merges += 1
        self._observe()


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class BipartiteEdges:
    """Directed edges from one level to the next (COO) — one incidence
    factor of the condensed representation (paper §4.2 Step 5).  Ids are
    validated against ``n_src``/``n_dst`` at construction so range bugs
    surface here, not as silent gather corruption."""

    src: np.ndarray
    dst: np.ndarray
    n_src: int
    n_dst: int

    def __post_init__(self) -> None:
        self.src = np.asarray(self.src, dtype=np.int64)
        self.dst = np.asarray(self.dst, dtype=np.int64)
        if self.src.shape != self.dst.shape:
            raise ValueError("src/dst shape mismatch")
        if self.src.size:
            if self.src.max() >= self.n_src or self.src.min() < 0:
                raise ValueError("src id out of range")
            if self.dst.max() >= self.n_dst or self.dst.min() < 0:
                raise ValueError("dst id out of range")

    @property
    def n_edges(self) -> int:
        return int(self.src.size)

    def reversed(self) -> "BipartiteEdges":
        return BipartiteEdges(self.dst.copy(), self.src.copy(), self.n_dst, self.n_src)

    def sorted_by_src(self) -> "BipartiteEdges":
        order = np.lexsort((self.dst, self.src))
        return BipartiteEdges(self.src[order], self.dst[order], self.n_src, self.n_dst)

    def out_degrees(self) -> np.ndarray:
        return np.bincount(self.src, minlength=self.n_src)

    def in_degrees(self) -> np.ndarray:
        return np.bincount(self.dst, minlength=self.n_dst)

    def nbytes(self) -> int:
        return int(self.src.nbytes + self.dst.nbytes)


@dataclasses.dataclass
class CSR:
    """Compressed sparse row view of a BipartiteEdges (host-side): the
    paper's adjacency-list layout (§5.1) for iterator-style traversal."""

    indptr: np.ndarray
    indices: np.ndarray
    n_src: int
    n_dst: int

    def neighbors(self, i: int) -> np.ndarray:
        return self.indices[self.indptr[i] : self.indptr[i + 1]]


def build_csr(edges: BipartiteEdges) -> CSR:
    """COO -> CSR by stable counting sort (paper §5.1 layout)."""
    order = np.argsort(edges.src, kind="stable")
    indices = edges.dst[order]
    counts = np.bincount(edges.src, minlength=edges.n_src)
    indptr = np.zeros(edges.n_src + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])
    return CSR(indptr, indices, edges.n_src, edges.n_dst)


@dataclasses.dataclass
class Chain:
    """One Edges-statement's condensed path structure (paper §4.2 Step 5:
    one virtual-node layer per postponed large-output join).

    ``edges[0]`` goes real -> virtual-layer-1, ``edges[-1]`` goes
    virtual-layer-k -> real; middle entries connect consecutive virtual
    layers.  ``len(edges) == n_layers + 1`` and ``n_layers >= 1``.
    """

    edges: List[BipartiteEdges]

    def __post_init__(self) -> None:
        if len(self.edges) < 2:
            raise ValueError("a Chain needs at least one virtual layer")
        for a, b in zip(self.edges, self.edges[1:]):
            if a.n_dst != b.n_src:
                raise ValueError("inconsistent layer sizes in chain")

    @property
    def n_layers(self) -> int:
        return len(self.edges) - 1

    @property
    def n_real(self) -> int:
        return self.edges[0].n_src

    @property
    def layer_sizes(self) -> List[int]:
        return [e.n_dst for e in self.edges[:-1]]

    @property
    def n_virtual(self) -> int:
        return sum(self.layer_sizes)

    @property
    def n_edges(self) -> int:
        return sum(e.n_edges for e in self.edges)

    def nbytes(self) -> int:
        return sum(e.nbytes() for e in self.edges)

    # -- expansion -----------------------------------------------------------
    def path_pairs(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """All (u, v, multiplicity) realized by this chain.

        Materializes the expansion — only used by EXP conversion, oracle
        tests, and DEDUP-C correction building.  Work/memory is
        O(#expanded paths), chunked over leading-layer nodes to bound the
        peak (paper: this is exactly the cost the condensed rep avoids at
        query time).
        """
        src, dst, mult = _compose_chain(self.edges)
        return src, dst, mult

    # -- streaming expansion (DESIGN.md §2) ------------------------------------
    def per_source_expansion_cost(self) -> np.ndarray:
        """Upper bound on raw triples materialized expanding each leading row.

        ``cost[u] = Σ_i paths(u -> level i+1)``: the sum over compose steps
        of the pre-aggregation output size, i.e. everything the chunked
        composition ever materializes for ``u``.  Computed with k+1
        backward bincount sweeps — O(k²·E) host work, no expansion.
        """
        cost = np.zeros(self.n_real, dtype=np.int64)
        for i in range(len(self.edges)):
            v = np.ones(self.edges[i].n_dst, dtype=np.float64)
            for j in range(i, -1, -1):
                e = self.edges[j]
                v = np.bincount(
                    e.src, weights=v[e.dst], minlength=e.n_src
                )
            cost += v.astype(np.int64)
        return cost

    def n_paths(self) -> int:
        """Total expanded path count (``M.sum()``) without expanding."""
        v = np.ones(self.edges[-1].n_dst, dtype=np.float64)
        for e in reversed(self.edges):
            v = np.bincount(e.src, weights=v[e.dst], minlength=e.n_src)
        return int(v.sum())

    def iter_path_pairs(
        self,
        chunk_rows: Optional[int] = None,
        budget_triples: Optional[int] = None,
        accounting: Optional["ExpansionAccounting"] = None,
    ) -> Iterator[Tuple[np.ndarray, np.ndarray, np.ndarray]]:
        """Chunked :meth:`path_pairs`: yield aggregated (u, v, m) triples
        block-by-block over leading real rows, never composing more than a
        bounded slice of the expansion at once.

        ``chunk_rows`` fixes the block width in leading rows;
        ``budget_triples`` sizes blocks adaptively from
        :meth:`per_source_expansion_cost` so each block's raw composition
        stays within the budget (a single row whose cost exceeds it gets
        its own block, recorded as an overflow chunk in ``accounting``).
        With neither, blocks default to :data:`DEFAULT_CHUNK_ROWS`.
        Concatenating and aggregating all yielded chunks reproduces
        :meth:`path_pairs` exactly (chunks of one chain are disjoint in u).
        """
        e0 = self.edges[0]
        order = np.argsort(e0.src, kind="stable")
        src_sorted = e0.src[order]
        dst_sorted = e0.dst[order]
        # Cost planning is only needed for budget-sized blocks and for
        # accounting; the default fixed-width path skips the k+1 sweeps.
        cost = None
        if budget_triples is not None or accounting is not None:
            cost = self.per_source_expansion_cost()
        for lo, hi in _row_blocks(self.n_real, cost, chunk_rows, budget_triples):
            a = np.searchsorted(src_sorted, lo, side="left")
            b = np.searchsorted(src_sorted, hi, side="left")
            if a == b:
                continue
            if accounting is not None:
                accounting.begin_chunk(
                    int(cost[lo:hi].sum()), budget=budget_triples
                )
            sub = BipartiteEdges(
                src_sorted[a:b], dst_sorted[a:b], e0.n_src, e0.n_dst
            )
            s, d, m = _compose_chain([sub] + list(self.edges[1:]))
            if accounting is not None:
                accounting.end_chunk(int(m.sum()), s.size)
            yield s, d, m


def _row_blocks(
    n: int,
    cost: Optional[np.ndarray],
    chunk_rows: Optional[int],
    budget_triples: Optional[int],
) -> Iterator[Tuple[int, int]]:
    """Leading-row block boundaries for one streaming pass.

    With a budget, each block is the maximal row prefix whose summed cost
    stays within it (never fewer than one row), found by binary search on
    the cumulative cost — no per-row Python loop.
    """
    if n == 0:
        return
    if budget_triples is not None:
        assert cost is not None
        cum = np.cumsum(cost)
        lo = 0
        base = 0
        while lo < n:
            hi = int(np.searchsorted(cum, base + budget_triples, side="right"))
            hi = max(hi, lo + 1)  # a single row may exceed the budget
            yield lo, hi
            base = int(cum[hi - 1])
            lo = hi
        return
    width = chunk_rows if chunk_rows is not None else DEFAULT_CHUNK_ROWS
    width = max(int(width), 1)
    for lo in range(0, n, width):
        yield lo, min(lo + width, n)


def _compose_pair(
    left: Tuple[np.ndarray, np.ndarray, np.ndarray],
    right: BipartiteEdges,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Compose (u -> m, mult) with bipartite (m -> v): returns (u -> v, mult)."""
    lsrc, lmid, lmult = left
    # Sort right edges by src so each mid id owns a contiguous run.
    order = np.argsort(right.src, kind="stable")
    rsrc_sorted = right.src[order]
    rdst_sorted = right.dst[order]
    starts = np.searchsorted(rsrc_sorted, lmid, side="left")
    ends = np.searchsorted(rsrc_sorted, lmid, side="right")
    counts = ends - starts
    total = int(counts.sum())
    usrc = np.repeat(lsrc, counts)
    umult = np.repeat(lmult, counts)
    if total:
        offs = np.arange(total) - np.repeat(np.cumsum(counts) - counts, counts)
        udst = rdst_sorted[np.repeat(starts, counts) + offs]
    else:
        udst = np.empty(0, dtype=np.int64)
    # Aggregate duplicate (u, v) pairs, summing multiplicities.
    return _aggregate_pairs(usrc, udst, umult, right.n_dst)


def _aggregate_pairs(
    src: np.ndarray, dst: np.ndarray, mult: np.ndarray, n_dst: int
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    if src.size == 0:
        return src, dst, mult
    key = src * np.int64(n_dst) + dst
    uniq, inverse = np.unique(key, return_inverse=True)
    summed = np.bincount(inverse, weights=mult.astype(np.float64))
    return (uniq // n_dst).astype(np.int64), (uniq % n_dst).astype(np.int64), summed.astype(np.int64)


def _compose_chain(
    edges: Sequence[BipartiteEdges],
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    acc = (edges[0].src, edges[0].dst, np.ones(edges[0].n_edges, dtype=np.int64))
    acc = _aggregate_pairs(*acc, edges[0].n_dst)
    for e in edges[1:]:
        acc = _compose_pair(acc, e)
    return acc


def split_expansion_budget(budget_triples: Optional[int]) -> Optional[int]:
    """Half of a full streaming budget: one half bounds chunk composition,
    the other bounds sorted-run residency in :func:`fold_path_pairs`."""
    if budget_triples is None:
        return None
    return max(int(budget_triples) // 2, 1)


def fold_path_pairs(
    chunks: Iterator[Tuple[np.ndarray, np.ndarray, np.ndarray]],
    n_dst: int,
    budget_triples: Optional[int] = None,
    accounting: Optional[ExpansionAccounting] = None,
    aggregate=None,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Out-of-core merge of aggregated (src, dst, mult) chunk triples.

    Chunks accumulate as sorted runs; whenever the resident triple count
    exceeds ``budget_triples`` the runs are consolidated into one (equal
    keys summed), so residency never grows past
    ``max(budget, unique pairs) + one chunk``.  The result is identical —
    ordering, values, and dtypes — to aggregating all chunks at once.
    ``aggregate`` defaults to the host merge; pass an alternative (e.g.
    the device segment-sum fold in :mod:`repro.core.dedup`) to run the
    consolidation elsewhere.
    """
    if aggregate is None:
        aggregate = _aggregate_pairs
    runs_s: List[np.ndarray] = []
    runs_d: List[np.ndarray] = []
    runs_m: List[np.ndarray] = []
    resident = 0
    for s, d, m in chunks:
        runs_s.append(s)
        runs_d.append(d)
        runs_m.append(m)
        resident += s.size
        if accounting is not None:
            accounting.runs_changed(resident)
        if (
            budget_triples is not None
            and resident > budget_triples
            and len(runs_s) > 1
        ):
            s, d, m = aggregate(
                np.concatenate(runs_s),
                np.concatenate(runs_d),
                np.concatenate(runs_m),
                n_dst,
            )
            runs_s, runs_d, runs_m = [s], [d], [m]
            resident = s.size
            if accounting is not None:
                accounting.runs_changed(resident, merged=True)
    if not runs_s:
        z = np.empty(0, dtype=np.int64)
        return z, z, z
    out = aggregate(
        np.concatenate(runs_s),
        np.concatenate(runs_d),
        np.concatenate(runs_m),
        n_dst,
    )
    if accounting is not None:
        accounting.runs_changed(out[0].size, merged=len(runs_s) > 1)
    return out


# ---------------------------------------------------------------------------
# Shard merging (DESIGN.md §7)
# ---------------------------------------------------------------------------

def merge_sorted_unique(parts: Sequence[np.ndarray]) -> np.ndarray:
    """Sorted-key union of per-shard sorted-unique key arrays.

    The associativity that makes sharded extraction exact: the union of
    per-shard distinct values equals the distinct values of the union, and
    sorting makes the result independent of the shard partition — so the
    merged virtual-node id space is byte-identical to the unsharded one.
    """
    if not parts:
        return np.empty(0, dtype=np.int64)
    return np.unique(np.concatenate(list(parts)))


def merge_chain_shards(
    shard_chains: Sequence[Chain],
    shard_layer_keys: Sequence[Sequence[np.ndarray]],
    arity: Optional[int] = None,
) -> Tuple[Chain, List[np.ndarray]]:
    """Merge per-shard condensed chains into one global :class:`Chain`
    (paper §4.2 Step 5, partition-parallel form; DESIGN.md §7/§8).

    Each shard arrives with its own *local* virtual-node id spaces
    (``shard_layer_keys[s][k]`` = sorted distinct values of postponed
    attribute ``k`` seen by shard ``s``); real endpoints are already
    global.  The merge:

    1. unions every layer's key sets by sorted-key merge
       (:func:`merge_sorted_unique`) — a plain offset concatenation would
       duplicate virtual nodes whose key occurs in more than one shard,
       which is why locals are *remapped*, not offset;
    2. remaps each shard's local virtual ids through
       ``searchsorted(merged_keys, local_keys)``;
    3. concatenates each level's edges across shards in shard order.

    Because ``remap[searchsorted(local, v)] == searchsorted(merged, v)``
    for every value ``v`` a shard saw, and shard outputs are contiguous
    slices of the unsharded segment output, the merged edge arrays are
    byte-identical to the unsharded build's.

    ``arity=None`` (default) merges all shards in one pass — the
    DESIGN.md §7 behaviour, every shard resident at once.  ``arity=r``
    runs the same operation as a tree reduce (DESIGN.md §8): consecutive
    groups of ``r`` shards are merged per round until one remains.  The
    union is associative and remapping composes
    (``searchsorted(final, partial_keys)[searchsorted(partial, v)] ==
    searchsorted(final, v)``), and groups stay consecutive, so the result
    is byte-identical for every arity — but no round ever has more than
    ``r`` shard chains plus one output resident, which is what lets the
    out-of-core pipeline stream spilled shards two at a time.
    """
    if not shard_chains:
        raise ValueError("merge_chain_shards needs at least one shard")
    if arity is not None:
        if arity < 2:
            raise ValueError(f"tree-reduce arity must be >= 2, got {arity}")
        chains = list(shard_chains)
        keys = [list(k) for k in shard_layer_keys]
        while len(chains) > 1:
            next_chains: List[Chain] = []
            next_keys: List[List[np.ndarray]] = []
            for i in range(0, len(chains), arity):
                if i + 1 >= len(chains):  # carried singleton
                    next_chains.append(chains[i])
                    next_keys.append(keys[i])
                    continue
                c, k = _merge_chain_group(
                    chains[i : i + arity], keys[i : i + arity]
                )
                next_chains.append(c)
                next_keys.append(k)
            chains, keys = next_chains, next_keys
        return chains[0], list(keys[0])
    return _merge_chain_group(shard_chains, shard_layer_keys)


def _merge_chain_group(
    shard_chains: Sequence[Chain],
    shard_layer_keys: Sequence[Sequence[np.ndarray]],
) -> Tuple[Chain, List[np.ndarray]]:
    """Single-pass k-way merge of one group — the §7 merge body; both the
    all-at-once path and each tree-reduce round reduce to this."""
    n_levels = len(shard_chains[0].edges)
    n_layers = n_levels - 1
    for c, keys in zip(shard_chains, shard_layer_keys):
        if len(c.edges) != n_levels or len(keys) != n_layers:
            raise ValueError("shards disagree on chain layer structure")
    merged_keys = [
        merge_sorted_unique([keys[k] for keys in shard_layer_keys])
        for k in range(n_layers)
    ]
    remaps = [
        [np.searchsorted(merged_keys[k], keys[k]) for k in range(n_layers)]
        for keys in shard_layer_keys
    ]
    levels: List[BipartiteEdges] = []
    n_real_src = shard_chains[0].edges[0].n_src
    n_real_dst = shard_chains[0].edges[-1].n_dst
    for lvl in range(n_levels):
        srcs: List[np.ndarray] = []
        dsts: List[np.ndarray] = []
        for s, chain in enumerate(shard_chains):
            e = chain.edges[lvl]
            src = e.src if lvl == 0 else remaps[s][lvl - 1][e.src]
            dst = e.dst if lvl == n_levels - 1 else remaps[s][lvl][e.dst]
            srcs.append(np.asarray(src, dtype=np.int64))
            dsts.append(np.asarray(dst, dtype=np.int64))
        n_src = n_real_src if lvl == 0 else merged_keys[lvl - 1].size
        n_dst = n_real_dst if lvl == n_levels - 1 else merged_keys[lvl].size
        levels.append(
            BipartiteEdges(
                np.concatenate(srcs), np.concatenate(dsts), n_src, int(n_dst)
            )
        )
    return Chain(levels), merged_keys


def _arrays_identical(a: Optional[np.ndarray], b: Optional[np.ndarray]) -> bool:
    if a is None or b is None:
        return a is b
    a, b = np.asarray(a), np.asarray(b)
    return a.dtype == b.dtype and a.shape == b.shape and bool(np.array_equal(a, b))


def _edges_identical(a: Optional[BipartiteEdges], b: Optional[BipartiteEdges]) -> bool:
    if a is None or b is None:
        return a is b
    return (
        a.n_src == b.n_src
        and a.n_dst == b.n_dst
        and _arrays_identical(a.src, b.src)
        and _arrays_identical(a.dst, b.dst)
    )


def graphs_identical(a: "CondensedGraph", b: "CondensedGraph") -> bool:
    """Byte-identity of two condensed graphs: every edge array (values,
    order, dtype), layer size, direct edge set, node type, and node
    property must match exactly.  This is the sharded-extraction merge
    invariant (DESIGN.md §7) — far stricter than graph isomorphism or
    equal expansions, and what the parity suite asserts.
    """
    if a.n_real != b.n_real or len(a.chains) != len(b.chains):
        return False
    for ca, cb in zip(a.chains, b.chains):
        if len(ca.edges) != len(cb.edges):
            return False
        if not all(_edges_identical(ea, eb) for ea, eb in zip(ca.edges, cb.edges)):
            return False
    if not _edges_identical(a.direct, b.direct):
        return False
    if not _arrays_identical(a.node_type, b.node_type):
        return False
    if sorted(a.node_properties) != sorted(b.node_properties):
        return False
    return all(
        _arrays_identical(v, b.node_properties[k])
        for k, v in a.node_properties.items()
    )


# ---------------------------------------------------------------------------
# Expanded graph
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ExpandedGraph:
    """The EXP representation (paper §4.1 baseline): unique (src, dst)
    pairs + path multiplicity."""

    src: np.ndarray
    dst: np.ndarray
    multiplicity: np.ndarray
    n: int

    @property
    def n_edges(self) -> int:
        return int(self.src.size)

    def nbytes(self) -> int:
        return int(self.src.nbytes + self.dst.nbytes)

    def out_degrees(self) -> np.ndarray:
        return np.bincount(self.src, minlength=self.n)

    def adjacency_multiplicity(self) -> np.ndarray:
        """Dense multiplicity matrix — tiny-graph tests only."""
        m = np.zeros((self.n, self.n), dtype=np.int64)
        np.add.at(m, (self.src, self.dst), self.multiplicity)
        return m

    def without_self_loops(self) -> "ExpandedGraph":
        keep = self.src != self.dst
        return ExpandedGraph(
            self.src[keep], self.dst[keep], self.multiplicity[keep], self.n
        )


# ---------------------------------------------------------------------------
# The C-DUP container
# ---------------------------------------------------------------------------

class CondensedGraph:
    """Union of condensed chains + direct edges over one real-node set.

    This is C-DUP exactly as extracted: duplication (multiplicity > 1) is
    allowed and expected.  Dedup algorithms in :mod:`repro.core.dedup`
    consume this and emit either a rewritten ``CondensedGraph`` (DEDUP-1),
    bitmap side-structures (BITMAP-1/2), or a correction edge list
    (DEDUP-C).
    """

    def __init__(
        self,
        n_real: int,
        chains: Sequence[Chain] = (),
        direct: Optional[BipartiteEdges] = None,
        node_properties: Optional[Dict[str, np.ndarray]] = None,
        node_type: Optional[np.ndarray] = None,
    ) -> None:
        self.n_real = int(n_real)
        self.chains = list(chains)
        for c in self.chains:
            if c.n_real != self.n_real or c.edges[-1].n_dst != self.n_real:
                raise ValueError("chain endpoints must be the real node set")
        if direct is not None and (
            direct.n_src != self.n_real or direct.n_dst != self.n_real
        ):
            raise ValueError("direct edges must connect real nodes")
        self.direct = direct
        self.node_properties = dict(node_properties or {})
        self.node_type = node_type  # heterogeneous graphs: int type id per node

    # -- bookkeeping ----------------------------------------------------------
    @property
    def n_virtual(self) -> int:
        return sum(c.n_virtual for c in self.chains)

    @property
    def n_edges_condensed(self) -> int:
        n = sum(c.n_edges for c in self.chains)
        if self.direct is not None:
            n += self.direct.n_edges
        return n

    @property
    def max_layers(self) -> int:
        return max((c.n_layers for c in self.chains), default=0)

    def is_single_layer(self) -> bool:
        return all(c.n_layers == 1 for c in self.chains)

    def nbytes(self) -> int:
        n = sum(c.nbytes() for c in self.chains)
        if self.direct is not None:
            n += self.direct.nbytes()
        return n

    # -- semantics ------------------------------------------------------------
    def iter_path_pairs(
        self,
        chunk_rows: Optional[int] = None,
        budget_triples: Optional[int] = None,
        accounting: Optional[ExpansionAccounting] = None,
    ) -> Iterator[Tuple[np.ndarray, np.ndarray, np.ndarray]]:
        """Chunked expansion of the whole graph: every chain's
        :meth:`Chain.iter_path_pairs` blocks followed by direct-edge blocks
        (each aggregated, multiplicity = repeat count).  Chunks from
        different chains / the direct set may repeat a (u, v) pair — fold
        them with :func:`fold_path_pairs` to recover
        :meth:`multiplicities` exactly.
        """
        for c in self.chains:
            yield from c.iter_path_pairs(
                chunk_rows=chunk_rows,
                budget_triples=budget_triples,
                accounting=accounting,
            )
        if self.direct is not None and self.direct.n_edges:
            e = self.direct
            order = np.argsort(e.src, kind="stable")
            src_sorted = e.src[order]
            dst_sorted = e.dst[order]
            cost = None
            if budget_triples is not None:
                cost = np.bincount(e.src, minlength=e.n_src)
            for lo, hi in _row_blocks(e.n_src, cost, chunk_rows, budget_triples):
                a = np.searchsorted(src_sorted, lo, side="left")
                b = np.searchsorted(src_sorted, hi, side="left")
                if a == b:
                    continue
                if accounting is not None:
                    accounting.begin_chunk(b - a, budget=budget_triples)
                s, d, m = _aggregate_pairs(
                    src_sorted[a:b],
                    dst_sorted[a:b],
                    np.ones(b - a, dtype=np.int64),
                    e.n_dst,
                )
                if accounting is not None:
                    accounting.end_chunk(b - a, s.size)
                yield s, d, m

    def multiplicities(
        self,
        chunk_rows: Optional[int] = None,
        budget_triples: Optional[int] = None,
        accounting: Optional[ExpansionAccounting] = None,
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """All expanded (u, v, multiplicity) triples.

        Streams :meth:`iter_path_pairs` through the sorted-run fold, so
        peak host memory is O(unique pairs + one chunk), never O(raw
        expanded paths) — the expansion memory wall the condensed
        representation exists to avoid.  ``budget_triples`` is split
        half/half between chunk composition and run residency, so the
        combined peak stays within the budget whenever the unique-pair
        count and every single row's expansion fit in half of it.
        """
        half = split_expansion_budget(budget_triples)
        return fold_path_pairs(
            self.iter_path_pairs(
                chunk_rows=chunk_rows,
                budget_triples=half,
                accounting=accounting,
            ),
            self.n_real,
            budget_triples=half,
            accounting=accounting,
        )

    def expand(
        self,
        drop_self_loops: bool = False,
        chunk_rows: Optional[int] = None,
        budget_triples: Optional[int] = None,
    ) -> ExpandedGraph:
        """Materialize EXP (paper's baseline representation) via the
        chunked iterator — the output is O(unique pairs) either way; the
        intermediate expansion is bounded by the chunking."""
        s, d, m = self.multiplicities(
            chunk_rows=chunk_rows, budget_triples=budget_triples
        )
        g = ExpandedGraph(s, d, m, self.n_real)
        return g.without_self_loops() if drop_self_loops else g

    def n_paths_expanded(self) -> int:
        """Total expanded path count (``M.sum()``), computed without
        expanding (k backward sweeps per chain)."""
        n = sum(c.n_paths() for c in self.chains)
        if self.direct is not None:
            n += self.direct.n_edges
        return n

    def n_edges_expanded(self, chunk_rows: Optional[int] = None) -> int:
        s, _, _ = self.multiplicities(chunk_rows=chunk_rows)
        return int(s.size)

    def duplication_ratio(self, chunk_rows: Optional[int] = None) -> float:
        """Mean path multiplicity over expanded edges (1.0 = no duplication)."""
        _, _, m = self.multiplicities(chunk_rows=chunk_rows)
        return float(m.mean()) if m.size else 1.0

    def expansion_stats(
        self,
        chunk_rows: Optional[int] = None,
        budget_triples: Optional[int] = None,
        accounting: Optional[ExpansionAccounting] = None,
    ) -> Tuple[int, float]:
        """``(n_edges_expanded, duplication_ratio)`` in one budgeted pass.

        :meth:`n_edges_expanded` and :meth:`duplication_ratio` each run a
        full expansion sweep; callers that need both (the representation
        advisor) should take this instead — one sweep, and it accepts the
        same ``budget_triples`` / ``accounting`` plumbing as
        :meth:`multiplicities` so the sweep is bounded and auditable.
        """
        s, _, m = self.multiplicities(
            chunk_rows=chunk_rows,
            budget_triples=budget_triples,
            accounting=accounting,
        )
        dup = float(m.mean()) if m.size else 1.0
        return int(s.size), dup

    # -- preprocessing (paper §4.2 step 6) -------------------------------------
    def preprocess(self, expand_threshold: Optional[float] = None) -> "CondensedGraph":
        """Expand virtual nodes whose expansion does not grow the graph.

        Paper rule: expand virtual node with ``in*out <= in + out + 1``.
        Implemented for single-layer chains (the common case; multi-layer
        middle nodes would need a DAG rep — those chains pass through).
        """
        new_chains: List[Chain] = []
        direct_s: List[np.ndarray] = [
            self.direct.src if self.direct is not None else np.empty(0, np.int64)
        ]
        direct_d: List[np.ndarray] = [
            self.direct.dst if self.direct is not None else np.empty(0, np.int64)
        ]
        for chain in self.chains:
            if chain.n_layers != 1:
                new_chains.append(chain)
                continue
            e_in, e_out = chain.edges
            ins = e_in.in_degrees()  # per virtual node
            outs = e_out.out_degrees()
            cost_keep = ins + outs + 1
            cost_expand = ins * outs
            expand_mask = cost_expand <= cost_keep
            if not expand_mask.any():
                new_chains.append(chain)
                continue
            # Direct edges from expanded virtual nodes.
            keep_in = ~expand_mask[e_in.dst]
            keep_out = ~expand_mask[e_out.src]
            sub_in = BipartiteEdges(
                e_in.src[~keep_in], e_in.dst[~keep_in], e_in.n_src, e_in.n_dst
            )
            sub_out = BipartiteEdges(
                e_out.src[~keep_out], e_out.dst[~keep_out], e_out.n_src, e_out.n_dst
            )
            if sub_in.n_edges:
                # Preserve path multiplicity: expanding a virtual node keeps
                # each path as its own direct edge (dedup happens later).
                s, d, m = _compose_chain([sub_in, sub_out])
                direct_s.append(np.repeat(s, m))
                direct_d.append(np.repeat(d, m))
            # Remaining virtual nodes, re-indexed densely.
            remap = -np.ones(e_in.n_dst, dtype=np.int64)
            kept = np.flatnonzero(~expand_mask)
            remap[kept] = np.arange(kept.size)
            if kept.size:
                new_in = BipartiteEdges(
                    e_in.src[keep_in],
                    remap[e_in.dst[keep_in]],
                    e_in.n_src,
                    int(kept.size),
                )
                new_out = BipartiteEdges(
                    remap[e_out.src[keep_out]],
                    e_out.dst[keep_out],
                    int(kept.size),
                    e_out.n_dst,
                )
                new_chains.append(Chain([new_in, new_out]))
        ds = np.concatenate(direct_s)
        dd = np.concatenate(direct_d)
        direct = (
            BipartiteEdges(ds, dd, self.n_real, self.n_real) if ds.size else None
        )
        return CondensedGraph(
            self.n_real, new_chains, direct, self.node_properties, self.node_type
        )

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return (
            f"CondensedGraph(n_real={self.n_real}, n_virtual={self.n_virtual}, "
            f"chains={len(self.chains)}, edges={self.n_edges_condensed})"
        )


def collapse_to_single_layer(
    graph: CondensedGraph,
    keep_layer: Optional[int] = None,
    max_growth: float = 10.0,
) -> CondensedGraph:
    """Collapse multi-layer chains to single-layer (paper §5.2.2).

    The paper's prescription for multi-layer dedup: "first converting it
    into a single-layer graph ... through expansion of all virtual nodes
    in all but one layer".  For each chain, every level before/after the
    kept layer is composed into direct (real -> kept) / (kept -> real)
    incidences; composed pair multiplicities are preserved as repeated
    edges (C-DUP semantics).  ``keep_layer`` defaults to the layer
    minimizing the composed edge count; raises if the composition would
    grow the chain by more than ``max_growth`` (the paper's space-explosion
    guard).
    """
    new_chains: List[Chain] = []
    for chain in graph.chains:
        if chain.n_layers == 1:
            new_chains.append(chain)
            continue
        k = chain.n_layers
        best: Optional[Chain] = None
        candidates = range(k) if keep_layer is None else [keep_layer]
        for keep in candidates:
            # compose levels 0..keep into (real -> kept layer)
            s, d, m = _compose_chain(chain.edges[: keep + 1])
            e_in = BipartiteEdges(
                np.repeat(s, m), np.repeat(d, m),
                chain.edges[0].n_src, chain.edges[keep].n_dst,
            )
            s2, d2, m2 = _compose_chain(chain.edges[keep + 1 :])
            e_out = BipartiteEdges(
                np.repeat(s2, m2), np.repeat(d2, m2),
                chain.edges[keep + 1].n_src, chain.edges[-1].n_dst,
            )
            cand = Chain([e_in, e_out])
            if best is None or cand.n_edges < best.n_edges:
                best = cand
        assert best is not None
        if best.n_edges > max_growth * chain.n_edges:
            raise ValueError(
                f"collapse grows chain {chain.n_edges} -> {best.n_edges} "
                f"edges (> {max_growth}x); keep multi-layer + DEDUP-C instead"
            )
        new_chains.append(best)
    return CondensedGraph(
        graph.n_real, new_chains, graph.direct,
        graph.node_properties, graph.node_type,
    )
