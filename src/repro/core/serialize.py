"""Condensed-graph serialization (paper §3.1: "serialize the graph onto
disk in a standardized format").

Two formats:

* :func:`save_condensed` / :func:`load_condensed` — the *condensed*
  structure itself (chains + direct edges + properties) as raw little-
  endian buffers + a JSON manifest (same discipline as
  :mod:`repro.train.checkpoint`: atomic rename, restart-safe).  This is
  what "store the deduplicated graph back into the database" (paper §6.5)
  maps to.
* :func:`export_edge_list` — the *expanded* representation as a plain
  ``src dst`` text/npz edge list consumable by external tools
  (NetworkX et al.), the paper's interchange path.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Dict, Optional

import numpy as np

from .condensed import BipartiteEdges, Chain, CondensedGraph

__all__ = ["save_condensed", "load_condensed", "export_edge_list"]

_FORMAT_VERSION = 1


def save_condensed(graph: CondensedGraph, directory: str) -> str:
    tmp = directory + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    manifest: Dict = {
        "version": _FORMAT_VERSION,
        "n_real": graph.n_real,
        "chains": [],
        "direct": None,
        "properties": {},
        "node_type": None,
    }
    idx = 0

    def dump(arr: np.ndarray) -> Dict:
        nonlocal idx
        fname = f"{idx:04d}.bin"
        idx += 1
        with open(os.path.join(tmp, fname), "wb") as f:
            f.write(np.ascontiguousarray(arr).tobytes())
        return {"file": fname, "dtype": arr.dtype.str, "shape": list(arr.shape)}

    for chain in graph.chains:
        edges = []
        for e in chain.edges:
            edges.append({
                "src": dump(e.src), "dst": dump(e.dst),
                "n_src": e.n_src, "n_dst": e.n_dst,
            })
        manifest["chains"].append(edges)
    if graph.direct is not None:
        manifest["direct"] = {
            "src": dump(graph.direct.src), "dst": dump(graph.direct.dst),
            "n_src": graph.direct.n_src, "n_dst": graph.direct.n_dst,
        }
    for name, arr in graph.node_properties.items():
        manifest["properties"][name] = dump(np.asarray(arr))
    if graph.node_type is not None:
        manifest["node_type"] = dump(np.asarray(graph.node_type))
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(directory):
        shutil.rmtree(directory)
    os.rename(tmp, directory)
    return directory


def load_condensed(directory: str) -> CondensedGraph:
    with open(os.path.join(directory, "manifest.json")) as f:
        manifest = json.load(f)
    if manifest["version"] != _FORMAT_VERSION:
        raise ValueError(f"unsupported format version {manifest['version']}")

    def load(meta: Dict) -> np.ndarray:
        with open(os.path.join(directory, meta["file"]), "rb") as f:
            return np.frombuffer(
                f.read(), dtype=np.dtype(meta["dtype"])
            ).reshape(meta["shape"])

    chains = []
    for edges_meta in manifest["chains"]:
        edges = [
            BipartiteEdges(load(m["src"]), load(m["dst"]), m["n_src"], m["n_dst"])
            for m in edges_meta
        ]
        chains.append(Chain(edges))
    direct = None
    if manifest["direct"] is not None:
        m = manifest["direct"]
        direct = BipartiteEdges(load(m["src"]), load(m["dst"]), m["n_src"], m["n_dst"])
    props = {k: load(m) for k, m in manifest["properties"].items()}
    node_type = load(manifest["node_type"]) if manifest["node_type"] else None
    return CondensedGraph(
        manifest["n_real"], chains, direct, node_properties=props,
        node_type=node_type,
    )


def export_edge_list(
    graph: CondensedGraph, path: str, fmt: str = "npz",
    drop_self_loops: bool = True,
) -> str:
    """Expand and write src/dst (+multiplicity) for external consumers."""
    exp = graph.expand(drop_self_loops=drop_self_loops)
    if fmt == "npz":
        np.savez_compressed(
            path, src=exp.src, dst=exp.dst, multiplicity=exp.multiplicity,
            n=exp.n,
        )
        return path if path.endswith(".npz") else path + ".npz"
    if fmt == "txt":
        with open(path, "w") as f:
            for s, d in zip(exp.src, exp.dst):
                f.write(f"{s} {d}\n")
        return path
    raise ValueError(fmt)
