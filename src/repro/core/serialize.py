"""Condensed-graph serialization (paper §3.1: "serialize the graph onto
disk in a standardized format").

Three formats:

* :func:`save_condensed` / :func:`load_condensed` — the *condensed*
  structure itself (chains + direct edges + properties) as raw little-
  endian buffers + a JSON manifest (same discipline as
  :mod:`repro.train.checkpoint`: atomic rename, restart-safe).  This is
  what "store the deduplicated graph back into the database" (paper §6.5)
  maps to.
* :func:`export_edge_list` — the *expanded* representation as a plain
  ``src dst`` text/npz edge list consumable by external tools
  (NetworkX et al.), the paper's interchange path.
* :class:`ShardSpillStore` + :class:`ShardAssembly` — the *spill* format
  for sharded out-of-core extraction (DESIGN.md §8): per-shard extraction
  outputs (shard-local node-space candidates, per-rule ``Chain`` arrays
  and direct edge blocks) written incrementally as each shard finishes,
  one atomically-committed record per shard, each with a byte-accounted
  manifest.  :func:`merge_assemblies` / :func:`tree_merge_records` are
  the merge half: pairwise (or ``arity``-wise) sorted-key unions that
  stream spilled shards a group at a time, so the single-pass all-shards
  merge of DESIGN.md §7 becomes a log-depth tree reduce whose resident
  operand count is ``arity + 1`` records, independent of shard count.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .condensed import BipartiteEdges, Chain, CondensedGraph, merge_chain_shards

__all__ = [
    "save_condensed",
    "load_condensed",
    "save_crossover_table",
    "load_crossover_table",
    "save_plan_report",
    "load_plan_report",
    "export_edge_list",
    "SpillError",
    "ShardSpillStore",
    "ShardAssembly",
    "merge_assemblies",
    "tree_merge_records",
    "DeltaLog",
    "SPILL_MANIFEST",
]

_FORMAT_VERSION = 1
_SPILL_VERSION = 1

# Name of the closing top-level manifest a complete spill directory must
# carry (written once by ShardSpillStore.finalize, after every record).
SPILL_MANIFEST = "spill_manifest.json"


def save_condensed(graph: CondensedGraph, directory: str) -> str:
    """Write a condensed graph to ``directory`` (paper §3.1 "standardized
    format", §6.5 "store the deduplicated graph back into the
    database"): every chain level / direct / property / node-type array
    as a raw little-endian buffer, plus a ``manifest.json`` recording
    dtype, shape and file per array.  Written to ``<directory>.tmp``
    and committed by one atomic rename, so a crashed save never leaves a
    half-written directory behind.  Returns ``directory``."""
    tmp = directory + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    manifest: Dict = {
        "version": _FORMAT_VERSION,
        "n_real": graph.n_real,
        "chains": [],
        "direct": None,
        "properties": {},
        "node_type": None,
    }
    idx = 0

    def dump(arr: np.ndarray) -> Dict:
        nonlocal idx
        fname = f"{idx:04d}.bin"
        idx += 1
        with open(os.path.join(tmp, fname), "wb") as f:
            f.write(np.ascontiguousarray(arr).tobytes())
        return {"file": fname, "dtype": arr.dtype.str, "shape": list(arr.shape)}

    for chain in graph.chains:
        edges = []
        for e in chain.edges:
            edges.append({
                "src": dump(e.src), "dst": dump(e.dst),
                "n_src": e.n_src, "n_dst": e.n_dst,
            })
        manifest["chains"].append(edges)
    if graph.direct is not None:
        manifest["direct"] = {
            "src": dump(graph.direct.src), "dst": dump(graph.direct.dst),
            "n_src": graph.direct.n_src, "n_dst": graph.direct.n_dst,
        }
    for name, arr in graph.node_properties.items():
        manifest["properties"][name] = dump(np.asarray(arr))
    if graph.node_type is not None:
        manifest["node_type"] = dump(np.asarray(graph.node_type))
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(directory):
        shutil.rmtree(directory)
    os.rename(tmp, directory)
    return directory


def save_crossover_table(table, path: str) -> str:
    """Persist a measured-crossover dispatch table
    (:class:`repro.kernels.autotune.CrossoverTable`) next to the pack it
    was recorded for — same atomic-rename discipline as the graph
    manifests, so a reloaded pack replays the exact dispatch decisions
    that were measured (golden-tested: tests/test_crossover_golden.py).
    Returns ``path``."""
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        f.write(table.to_json())
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    return path


def load_crossover_table(path: str):
    """Load a table written by :func:`save_crossover_table`."""
    from ..kernels.autotune import CrossoverTable

    with open(path) as f:
        return CrossoverTable.from_json(f.read())


def save_plan_report(report, path: str) -> str:
    """Persist an extraction-plan report
    (:class:`repro.core.cost.PlanReport`) as canonical JSON — same atomic
    write-replace discipline as :func:`save_crossover_table`, so an
    audited plan decision can ride next to the artifacts it produced
    (golden-tested: tests/test_advisor_plan.py).  Returns ``path``."""
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        f.write(report.to_json())
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    return path


def load_plan_report(path: str):
    """Load a report written by :func:`save_plan_report`."""
    from .cost import PlanReport

    with open(path) as f:
        return PlanReport.from_json(f.read())


def load_condensed(directory: str) -> CondensedGraph:
    """Inverse of :func:`save_condensed` (paper §3.1): read the
    ``manifest.json`` written there and rebuild the ``CondensedGraph``
    with identical array bytes, shapes and dtypes.  Rejects manifests
    from a different format version."""
    with open(os.path.join(directory, "manifest.json")) as f:
        manifest = json.load(f)
    if manifest["version"] != _FORMAT_VERSION:
        raise ValueError(f"unsupported format version {manifest['version']}")

    def load(meta: Dict) -> np.ndarray:
        with open(os.path.join(directory, meta["file"]), "rb") as f:
            return np.frombuffer(
                f.read(), dtype=np.dtype(meta["dtype"])
            ).reshape(meta["shape"])

    chains = []
    for edges_meta in manifest["chains"]:
        edges = [
            BipartiteEdges(load(m["src"]), load(m["dst"]), m["n_src"], m["n_dst"])
            for m in edges_meta
        ]
        chains.append(Chain(edges))
    direct = None
    if manifest["direct"] is not None:
        m = manifest["direct"]
        direct = BipartiteEdges(load(m["src"]), load(m["dst"]), m["n_src"], m["n_dst"])
    props = {k: load(m) for k, m in manifest["properties"].items()}
    node_type = load(manifest["node_type"]) if manifest["node_type"] else None
    return CondensedGraph(
        manifest["n_real"], chains, direct, node_properties=props,
        node_type=node_type,
    )


# ---------------------------------------------------------------------------
# Spill format for sharded out-of-core extraction (DESIGN.md §8)
# ---------------------------------------------------------------------------

class SpillError(RuntimeError):
    """A spill directory is absent, partial, or corrupt.

    Raised by :meth:`ShardSpillStore.open` / :meth:`ShardSpillStore.validate`
    when the closing manifest is missing (the writer crashed before
    :meth:`ShardSpillStore.finalize`), a listed record is gone or
    truncated, or an uncommitted ``*.tmp`` record is left behind.  A
    partial spill is rejected here, never silently merged.
    """


@dataclasses.dataclass
class ShardAssembly:
    """One shard's (or one merged partial's) assembled extraction output.

    The unit of the spill format and of the tree-reduce merge
    (DESIGN.md §8): for every Edges rule either a shard-local
    :class:`~repro.core.condensed.Chain` plus its local virtual-layer key
    spaces (``chains[rule_index] = (chain, layer_keys)``) or, for rules
    with no postponed join, the shard's direct edge block over dense real
    ids (``direct[rule_index] = (src_ids, dst_ids)``).  ``dropped``
    counts endpoints that missed the node space.  Merging two assemblies
    with :func:`merge_assemblies` is associative (sorted-key union +
    remap, shard-order concat), which is what makes the tree reduce
    byte-identical to the single-pass merge.
    """

    chains: Dict[int, Tuple[Chain, List[np.ndarray]]]
    direct: Dict[int, Tuple[np.ndarray, np.ndarray]]
    dropped: int = 0

    def nbytes(self) -> int:
        """Resident bytes of every edge / key array in this assembly —
        the quantity charged to ``ExtractionBudget.charge_assembly`` and
        recorded in the record's byte-accounted manifest."""
        n = 0
        for chain, keys in self.chains.values():
            n += chain.nbytes()
            n += sum(int(k.nbytes) for k in keys)
        for s, d in self.direct.values():
            n += int(s.nbytes) + int(d.nbytes)
        return n


def merge_assemblies(parts: Sequence[ShardAssembly]) -> ShardAssembly:
    """Merge shard assemblies (in shard order) into one partial.

    Per rule: chains go through
    :func:`~repro.core.condensed.merge_chain_shards` (sorted-key union of
    the local virtual key spaces, local ids *remapped* — never offset —
    through ``searchsorted``, per-level edges concatenated in part
    order); direct edge blocks concatenate in part order; dropped counts
    sum.  Every one of those operations is associative, so folding
    groups of parts in any tree shape — provided group order follows
    shard order — yields the same bytes as merging all shards at once.
    """
    if not parts:
        raise ValueError("merge_assemblies needs at least one part")
    if len(parts) == 1:
        return parts[0]
    first = parts[0]
    for p in parts[1:]:
        if sorted(p.chains) != sorted(first.chains) or sorted(p.direct) != sorted(first.direct):
            raise ValueError("shard assemblies disagree on rule structure")
    chains: Dict[int, Tuple[Chain, List[np.ndarray]]] = {}
    for r in first.chains:
        merged, keys = merge_chain_shards(
            [p.chains[r][0] for p in parts],
            [p.chains[r][1] for p in parts],
        )
        chains[r] = (merged, keys)
    direct: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}
    for r in first.direct:
        direct[r] = (
            np.concatenate([p.direct[r][0] for p in parts]),
            np.concatenate([p.direct[r][1] for p in parts]),
        )
    return ShardAssembly(chains, direct, sum(p.dropped for p in parts))


def _assembly_to_arrays(a: ShardAssembly) -> Tuple[Dict[str, np.ndarray], Dict]:
    """Flatten a :class:`ShardAssembly` into the (arrays, meta) pair a
    spill record stores; inverse of :func:`_assembly_from_arrays`."""
    arrays: Dict[str, np.ndarray] = {}
    meta: Dict = {"dropped": int(a.dropped), "rules": {}}
    for r, (chain, keys) in a.chains.items():
        meta["rules"][str(r)] = {
            "kind": "chain",
            "levels": [[e.n_src, e.n_dst] for e in chain.edges],
        }
        for lvl, e in enumerate(chain.edges):
            arrays[f"r{r}_lvl{lvl}_src"] = e.src
            arrays[f"r{r}_lvl{lvl}_dst"] = e.dst
        for k, key_arr in enumerate(keys):
            arrays[f"r{r}_key{k}"] = key_arr
    for r, (s, d) in a.direct.items():
        meta["rules"][str(r)] = {"kind": "direct"}
        arrays[f"r{r}_direct_src"] = s
        arrays[f"r{r}_direct_dst"] = d
    return arrays, meta


def _assembly_from_arrays(
    arrays: Dict[str, np.ndarray], meta: Dict
) -> ShardAssembly:
    chains: Dict[int, Tuple[Chain, List[np.ndarray]]] = {}
    direct: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}
    for r_str, info in meta["rules"].items():
        r = int(r_str)
        if info["kind"] == "direct":
            direct[r] = (arrays[f"r{r}_direct_src"], arrays[f"r{r}_direct_dst"])
            continue
        edges = [
            BipartiteEdges(
                arrays[f"r{r}_lvl{lvl}_src"], arrays[f"r{r}_lvl{lvl}_dst"],
                int(n_src), int(n_dst),
            )
            for lvl, (n_src, n_dst) in enumerate(info["levels"])
        ]
        keys = [
            arrays[f"r{r}_key{k}"] for k in range(len(info["levels"]) - 1)
        ]
        chains[r] = (Chain(edges), keys)
    return ShardAssembly(chains, direct, int(meta["dropped"]))


class ShardSpillStore:
    """A directory of atomically-committed array records + one closing
    manifest — the on-disk side of out-of-core shard assembly
    (DESIGN.md §8).

    Layout::

        <directory>/
          spill_manifest.json     # written LAST by finalize(): version,
                                  # pipeline meta, {record: nbytes} map
          <record name>/          # one dir per record, atomic-renamed
            record.json           # per-array meta + total payload bytes
            0000.bin ...          # raw little-endian array buffers

    Records are written to ``<name>.tmp-<pid>`` and committed by a
    single ``os.rename`` — a record directory either exists completely
    or not at all, so a crash can only ever leave behind ``*.tmp-*``
    litter and a missing closing manifest, both of which
    :meth:`validate` rejects.  Record names are namespaced by the
    extraction pipeline (``nodes_r<rule>_s<shard>``, ``shard_s<shard>``,
    ``nodespace``, merge partials ``<prefix>L<level>g<group>``).

    The per-record manifest carries ``nbytes`` (summed array payload),
    making the spill *byte-accounted*: `ExtractionBudget` charges the
    same number while the record's arrays are resident, so RAM-vs-disk
    accounting lines up exactly.
    """

    def __init__(self, directory: str, create: bool = True) -> None:
        """``create=True`` opens the store *for writing*: the directory is
        made if absent and any closing manifest left by a previous run is
        removed — the spill is partial again until this run's
        :meth:`finalize`.  Without that invalidation, a re-run into a
        used directory that crashes mid-way would leave the *old*
        manifest certifying a mix of old and new records, exactly the
        silent-merge case :meth:`validate` exists to reject.
        ``create=False`` opens read-only (see :meth:`open`)."""
        self.directory = directory
        if create:
            os.makedirs(directory, exist_ok=True)
            try:
                # racy-safe: concurrent multi-host writers may all try
                os.remove(os.path.join(directory, SPILL_MANIFEST))
            except FileNotFoundError:
                pass
        elif not os.path.isdir(directory):
            raise SpillError(f"spill directory {directory!r} does not exist")

    # -- record I/O -----------------------------------------------------------
    def write_record(
        self, name: str, arrays: Dict[str, np.ndarray], meta: Optional[Dict] = None
    ) -> int:
        """Atomically write one record; returns its payload bytes.

        Atomicity is with respect to *process* crashes (the failure mode
        extraction actually restarts from): the rename makes the record
        appear all-at-once in the namespace, and an interrupted write
        only ever leaves ``*.tmp-*`` litter behind.  Payload ``.bin``
        files are not individually fsynced, so OS/power-loss durability
        is not claimed — :meth:`validate` stats every payload against
        its manifest size, which catches that case too.
        """
        tmp = os.path.join(self.directory, f"{name}.tmp-{os.getpid()}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        record: Dict = {"arrays": {}, "meta": meta or {}, "nbytes": 0}
        for i, (aname, arr) in enumerate(arrays.items()):
            arr = np.ascontiguousarray(arr)
            fname = f"{i:04d}.bin"
            with open(os.path.join(tmp, fname), "wb") as f:
                f.write(arr.tobytes())
            record["arrays"][aname] = {
                "file": fname, "dtype": arr.dtype.str, "shape": list(arr.shape),
            }
            record["nbytes"] += int(arr.nbytes)
        with open(os.path.join(tmp, "record.json"), "w") as f:
            json.dump(record, f)
            f.flush()
            os.fsync(f.fileno())
        final = os.path.join(self.directory, name)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        return int(record["nbytes"])

    def _record_header(self, name: str) -> Dict:
        """Parse a record's ``record.json`` alone — no payload I/O."""
        rdir = os.path.join(self.directory, name)
        try:
            with open(os.path.join(rdir, "record.json")) as f:
                return json.load(f)
        except (OSError, ValueError) as e:
            raise SpillError(f"spill record {name!r} is missing or partial: {e}")

    def read_record(
        self, name: str, names: Optional[Sequence[str]] = None
    ) -> Tuple[Dict[str, np.ndarray], Dict, int]:
        """Load one record; returns ``(arrays, meta, nbytes)``.

        ``names`` restricts which arrays are read from disk (the record's
        total ``nbytes`` is reported either way) — e.g. the node-space
        candidate pass skips the property columns it will stream later.
        A missing or truncated payload raises :class:`SpillError`.
        """
        rdir = os.path.join(self.directory, name)
        record = self._record_header(name)
        arrays: Dict[str, np.ndarray] = {}
        for aname, m in record["arrays"].items():
            if names is not None and aname not in names:
                continue
            try:
                with open(os.path.join(rdir, m["file"]), "rb") as f:
                    arrays[aname] = np.frombuffer(
                        f.read(), dtype=np.dtype(m["dtype"])
                    ).reshape(m["shape"])
            except (OSError, ValueError) as e:
                raise SpillError(
                    f"spill record {name!r} array {aname!r} is missing or "
                    f"truncated: {e}"
                )
        return arrays, record["meta"], int(record["nbytes"])

    def has_record(self, name: str) -> bool:
        return os.path.exists(os.path.join(self.directory, name, "record.json"))

    def delete_record(self, name: str) -> None:
        shutil.rmtree(os.path.join(self.directory, name), ignore_errors=True)

    def rename_record(self, old: str, new: str) -> None:
        """Move a committed record to a new name — metadata-only (no
        payload rewrite).  An existing target is replaced."""
        src = os.path.join(self.directory, old)
        dst = os.path.join(self.directory, new)
        if os.path.exists(dst):
            shutil.rmtree(dst)
        os.rename(src, dst)

    def list_records(self) -> List[str]:
        """Committed record names (sorted); ``*.tmp-*`` litter excluded —
        including a tmp directory whose ``record.json`` was fully written
        before a crash interrupted the commit rename."""
        return sorted(
            d for d in os.listdir(self.directory)
            if ".tmp-" not in d
            and os.path.isfile(os.path.join(self.directory, d, "record.json"))
        )

    # -- shard-assembly convenience -------------------------------------------
    def write_assembly(self, name: str, assembly: ShardAssembly) -> int:
        arrays, meta = _assembly_to_arrays(assembly)
        return self.write_record(name, arrays, meta)

    def read_assembly(self, name: str) -> Tuple[ShardAssembly, int]:
        arrays, meta, nbytes = self.read_record(name)
        return _assembly_from_arrays(arrays, meta), nbytes

    # -- completeness ---------------------------------------------------------
    def finalize(self, meta: Optional[Dict] = None) -> str:
        """Write the closing manifest over every record currently
        committed on disk.  Until this exists the spill is *partial* by
        definition and :meth:`open` refuses it."""
        manifest = {
            "version": _SPILL_VERSION,
            "meta": meta or {},
            "records": {},
        }
        for name in self.list_records():
            # header-only: finalizing must not re-read the whole spill
            manifest["records"][name] = int(self._record_header(name)["nbytes"])
        manifest["total_bytes"] = sum(manifest["records"].values())
        path = os.path.join(self.directory, SPILL_MANIFEST)
        tmp = path + f".tmp-{os.getpid()}"
        with open(tmp, "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
        return path

    def clear_records(self) -> None:
        """Delete every committed record (and ``*.tmp-*`` litter) — a
        writer starting a fresh run into a reused directory calls this so
        stale records from a previous run (e.g. a larger ``n_shards``)
        are never certified into the new closing manifest.  Single-writer
        only: concurrent multi-host processes must not race it, so the
        multi-host driver requires a fresh directory instead."""
        for d in os.listdir(self.directory):
            path = os.path.join(self.directory, d)
            if os.path.isdir(path) and (
                ".tmp-" in d or os.path.isfile(os.path.join(path, "record.json"))
            ):
                shutil.rmtree(path, ignore_errors=True)

    def manifest(self) -> Dict:
        path = os.path.join(self.directory, SPILL_MANIFEST)
        try:
            with open(path) as f:
                return json.load(f)
        except OSError:
            raise SpillError(
                f"{self.directory!r} has no {SPILL_MANIFEST}: the spill is "
                "partial (writer did not finalize) — refusing to merge it"
            )
        except ValueError as e:
            raise SpillError(
                f"{self.directory!r} has a corrupt {SPILL_MANIFEST}: {e}"
            )

    def validate(self) -> Dict:
        """Crash-safety gate: reject partial or corrupt spills.

        Checks, in order: the closing manifest exists; no uncommitted
        ``*.tmp-*`` record directories are left behind; every listed
        record's header is present with byte counts matching the
        manifest; every payload file's on-disk size equals
        ``itemsize × prod(shape)`` from the header (so a truncated or
        lost ``.bin`` is caught *here*, without reading the spill back).
        Header/stat work only — O(records), not O(bytes).  Returns the
        parsed manifest on success, raises :class:`SpillError` otherwise.
        """
        manifest = self.manifest()
        if manifest.get("version") != _SPILL_VERSION:
            raise SpillError(
                f"unsupported spill version {manifest.get('version')}"
            )
        litter = [
            d for d in os.listdir(self.directory)
            if ".tmp-" in d and os.path.isdir(os.path.join(self.directory, d))
        ]
        if litter:
            raise SpillError(
                f"uncommitted spill records left behind: {sorted(litter)} — "
                "the writing run crashed mid-record; re-run the extraction"
            )
        for name, nbytes in manifest["records"].items():
            if not self.has_record(name):
                raise SpillError(
                    f"spill record {name!r} listed in the manifest is missing"
                )
            header = self._record_header(name)
            if int(header["nbytes"]) != nbytes:
                raise SpillError(
                    f"spill record {name!r} byte count mismatch: manifest "
                    f"says {nbytes}, record says {header['nbytes']}"
                )
            for aname, m in header["arrays"].items():
                path = os.path.join(self.directory, name, m["file"])
                expect = int(np.dtype(m["dtype"]).itemsize) * int(
                    np.prod(m["shape"], dtype=np.int64)
                )
                try:
                    got = os.path.getsize(path)
                except OSError:
                    raise SpillError(
                        f"spill record {name!r} array {aname!r} payload is "
                        "missing"
                    )
                if got != expect:
                    raise SpillError(
                        f"spill record {name!r} array {aname!r} is truncated:"
                        f" {got} bytes on disk, header says {expect}"
                    )
        return manifest

    @classmethod
    def open(cls, directory: str) -> "ShardSpillStore":
        """Open an existing spill for reading; validates completeness."""
        store = cls(directory, create=False)
        store.validate()
        return store


def tree_merge_records(
    store: ShardSpillStore,
    names: Sequence[str],
    arity: int = 2,
    out_prefix: str = "partial_",
    budget=None,
    keep_leaves: bool = True,
) -> Tuple[str, Optional[ShardAssembly]]:
    """Log-depth tree reduce over spilled assembly records (DESIGN.md §8).

    ``names`` are record names in shard order.  Each round groups
    ``arity`` consecutive records, loads just that group, merges it with
    :func:`merge_assemblies`, writes the partial back as a new record,
    and frees the operands — so at any instant at most ``arity`` input
    records plus one output are resident, regardless of shard count.
    A trailing singleton is carried to the next round unchanged (it
    simply joins a later group), which preserves shard order and hence
    byte-identity with the single-pass merge.  Intermediate partials are
    deleted once consumed; the input leaf records are kept when
    ``keep_leaves`` (the default — a crash mid-merge loses no shard
    output and the merge can simply be re-run).

    ``budget`` (an ``ExtractionBudget``) gets the merge-phase residency
    recorded: operand + output bytes per group via ``note_merge``, and
    one ``n_merge_rounds`` increment per level.  Returns ``(final record
    name, final assembly or None)`` — the assembly is the last round's
    in-memory output, handed back so callers need not re-read from disk
    the record that was just written; it is ``None`` exactly when no
    merge ran (a single input record, returned by name untouched).
    """
    if arity < 2:
        raise ValueError(f"tree-reduce arity must be >= 2, got {arity}")
    if not names:
        raise ValueError("tree_merge_records needs at least one record")
    current = list(names)
    intermediates: set = set()
    level = 0
    last_merged: Optional[ShardAssembly] = None
    while len(current) > 1:
        nxt: List[str] = []
        last_merged = None  # only the final round's survivor is reusable
        for g, i in enumerate(range(0, len(current), arity)):
            group = current[i : i + arity]
            if len(group) == 1:
                nxt.append(group[0])  # carried: joins a later group
                continue
            loaded = [store.read_assembly(n) for n in group]
            merged = merge_assemblies([a for a, _ in loaded])
            out_name = f"{out_prefix}L{level}g{g}"
            out_bytes = store.write_assembly(out_name, merged)
            if budget is not None:
                budget.note_merge(
                    sum(nb for _, nb in loaded) + out_bytes
                )
            for n in group:
                if n in intermediates or not keep_leaves:
                    store.delete_record(n)
            intermediates.add(out_name)
            nxt.append(out_name)
            last_merged = merged if len(nxt) == 1 else None
        if budget is not None:
            budget.n_merge_rounds += 1
        current = nxt
        level += 1
    return current[0], (last_merged if len(current) == 1 else None)


# ---------------------------------------------------------------------------
# Crash-safe delta log for incremental extraction (DESIGN.md §9)
# ---------------------------------------------------------------------------

class DeltaLog:
    """A replayable, crash-safe log of table deltas for incremental
    extraction (:mod:`repro.core.delta`, DESIGN.md §9), built on
    :class:`ShardSpillStore`'s atomic-commit records.

    One committed entry per :func:`repro.core.delta.apply_delta` call,
    named ``delta_000000``, ``delta_000001``, ... in apply order.  An
    append is: write the entry record (payload + fsynced ``record.json``,
    committed by one atomic rename), then rewrite the closing manifest
    (fsync + atomic ``os.replace``) — *manifest-last*, so the manifest
    always certifies a consistent prefix of the log.  A crash can
    therefore only leave (a) ``*.tmp-*`` litter from a torn record write,
    or (b) a committed entry the manifest never certified (torn append);
    :meth:`open` rejects both with :class:`SpillError` — exactly like a
    partial extraction spill — and ``DeltaLog(dir, recover=True)`` drops
    the uncertified tail, restoring the last acknowledged state.
    Truncated or missing payloads of *certified* entries are corruption,
    rejected by validation and never recovered over.

    Entry payload: the insert rows per table (column arrays) and the
    delete specs per table (``(key_column, values)``); replaying every
    certified entry over the base catalog rebuilds the identical graph
    (asserted byte-for-byte in tests/test_delta.py).
    """

    _KIND = "delta_log"

    def __init__(
        self, directory: str, create: bool = True, recover: bool = False
    ) -> None:
        if create:
            os.makedirs(directory, exist_ok=True)
        self.store = ShardSpillStore(directory, create=False)
        self.directory = directory
        has_manifest = os.path.exists(
            os.path.join(directory, SPILL_MANIFEST)
        )
        if not has_manifest:
            if self.store.list_records() or self._tmp_litter():
                raise SpillError(
                    f"{directory!r} has delta records but no {SPILL_MANIFEST}:"
                    " the log was never certified — refusing to replay it"
                )
            # a freshly created log is certified-empty from the start
            self._n = 0
            self.store.finalize(meta={"kind": self._KIND, "n_entries": 0})
            return
        if recover:
            self._drop_uncertified()
        self._n = self._validate()

    # -- integrity ------------------------------------------------------------
    def _tmp_litter(self) -> List[str]:
        return sorted(
            d for d in os.listdir(self.directory)
            if ".tmp-" in d and os.path.isdir(os.path.join(self.directory, d))
        )

    def _drop_uncertified(self) -> None:
        """Recovery: delete ``*.tmp-*`` litter and committed entries the
        manifest never certified (the torn tail of a crashed append)."""
        certified = set(self.store.manifest()["records"])
        for name in self._tmp_litter():
            shutil.rmtree(
                os.path.join(self.directory, name), ignore_errors=True
            )
        for name in self.store.list_records():
            if name not in certified:
                self.store.delete_record(name)

    def _validate(self) -> int:
        """Full crash-safety gate; returns the certified entry count."""
        manifest = self.store.validate()
        meta = manifest.get("meta", {})
        if meta.get("kind") != self._KIND:
            raise SpillError(
                f"{self.directory!r} is not a delta log "
                f"(kind={meta.get('kind')!r})"
            )
        n = int(meta.get("n_entries", -1))
        expect = [self._entry_name(i) for i in range(n)]
        listed = sorted(manifest["records"])
        if listed != expect:
            raise SpillError(
                f"delta log manifest is inconsistent: certifies {listed}, "
                f"expected exactly {expect}"
            )
        extra = sorted(set(self.store.list_records()) - set(listed))
        if extra:
            raise SpillError(
                f"uncertified delta records beyond the manifest: {extra} — "
                "a torn append; reopen with DeltaLog(dir, recover=True) to "
                "drop the tail"
            )
        return n

    @classmethod
    def open(cls, directory: str) -> "DeltaLog":
        """Open an existing log for replay/append; validates completeness
        (raises :class:`SpillError` on any torn or corrupt state)."""
        return cls(directory, create=False)

    # -- entries --------------------------------------------------------------
    @staticmethod
    def _entry_name(index: int) -> str:
        return f"delta_{index:06d}"

    def __len__(self) -> int:
        return self._n

    def append(self, inserts=None, deletes=None) -> int:
        """Durably log one delta; returns its entry index.

        ``inserts``: ``{table: {column: values}}`` rows to append;
        ``deletes``: ``{table: (key_column, values)}`` — drop every row
        whose key column takes one of the values.  Write order is
        entry-record first (atomic commit), manifest last (atomic
        replace): the entry is acknowledged only once the manifest
        certifies it.
        """
        arrays: Dict[str, np.ndarray] = {}
        ins_meta: List = []
        del_meta: List = []
        for ti, (tname, cols) in enumerate(sorted((inserts or {}).items())):
            colnames = list(cols)
            ins_meta.append([tname, colnames])
            for ci, cname in enumerate(colnames):
                arrays[f"ins{ti}_{ci}"] = np.asarray(cols[cname])
        for di, (tname, spec) in enumerate(sorted((deletes or {}).items())):
            key_col, values = spec
            del_meta.append([tname, key_col])
            arrays[f"del{di}"] = np.asarray(values)
        index = self._n
        self.store.write_record(
            self._entry_name(index), arrays,
            meta={"index": index, "inserts": ins_meta, "deletes": del_meta},
        )
        self._n = index + 1
        self.store.finalize(meta={"kind": self._KIND, "n_entries": self._n})
        return index

    def read(self, index: int):
        """Load entry ``index``; returns ``(inserts, deletes)`` in the
        exact shapes :meth:`append` took them."""
        if not 0 <= index < self._n:
            raise IndexError(f"delta log has {self._n} entries, not {index}")
        arrays, meta, _ = self.store.read_record(self._entry_name(index))
        inserts = {
            tname: {
                cname: arrays[f"ins{ti}_{ci}"]
                for ci, cname in enumerate(colnames)
            }
            for ti, (tname, colnames) in enumerate(meta["inserts"])
        }
        deletes = {
            tname: (key_col, arrays[f"del{di}"])
            for di, (tname, key_col) in enumerate(meta["deletes"])
        }
        return inserts, deletes

    def entries(self):
        """Iterate certified entries in apply order (the replay order)."""
        for i in range(self._n):
            yield self.read(i)


def export_edge_list(
    graph: CondensedGraph, path: str, fmt: str = "npz",
    drop_self_loops: bool = True,
) -> str:
    """Expand and write src/dst (+multiplicity) for external consumers —
    the paper's EXP interchange path (§4.1 baseline representation):
    ``fmt='npz'`` for NumPy-native tools, ``'txt'`` for the classic
    whitespace edge-list format (NetworkX et al.)."""
    exp = graph.expand(drop_self_loops=drop_self_loops)
    if fmt == "npz":
        np.savez_compressed(
            path, src=exp.src, dst=exp.dst, multiplicity=exp.multiplicity,
            n=exp.n,
        )
        return path if path.endswith(".npz") else path + ".npz"
    if fmt == "txt":
        with open(path, "w") as f:
            for s, d in zip(exp.src, exp.dst):
                f.write(f"{s} {d}\n")
        return path
    raise ValueError(fmt)
