"""Band partitioning for the distributed condensed engine (§Perf 'banded').

Splits a symmetric single-layer condensed graph into ``n_shards``
contiguous virtual-node bands (for the fused 2-hop) and real-node bands
(for corrections), padding every band to equal length with inert entries
so the arrays shard evenly.  Consumed by the shard_map PageRank in
:mod:`repro.launch.cells` and by tests.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np

from .condensed import CondensedGraph

__all__ = ["BandedGraph", "band_partition"]


@dataclasses.dataclass
class BandedGraph:
    """Flat arrays whose equal n_shards-slices are per-band locals."""

    in_src: np.ndarray    # (S*eb,) global real ids
    in_dst: np.ndarray    # (S*eb,) band-local virtual ids
    out_src: np.ndarray   # (S*eb,) band-local virtual ids
    out_dst: np.ndarray   # (S*eb,) global real ids
    corr_src: np.ndarray  # (S*cb,) global real ids
    corr_dst: np.ndarray  # (S*cb,) band-local real ids
    corr_cnt: np.ndarray  # (S*cb,) float32 (0 = padding)
    deg: np.ndarray       # (n_real,) deduplicated out-degree
    n_real: int
    n_virtual: int
    n_shards: int

    @property
    def virt_band(self) -> int:
        return self.n_virtual // self.n_shards

    @property
    def real_band(self) -> int:
        return self.n_real // self.n_shards


def _pad_bands(values_per_band, fill, dtype):
    width = max(len(v) for v in values_per_band)
    out = np.full((len(values_per_band), width), fill, dtype=dtype)
    for i, v in enumerate(values_per_band):
        out[i, : len(v)] = v
    return out.reshape(-1)


def band_partition(
    graph: CondensedGraph,
    correction: Tuple[np.ndarray, np.ndarray, np.ndarray],
    n_shards: int,
    deg: np.ndarray,
) -> BandedGraph:
    if len(graph.chains) != 1 or graph.chains[0].n_layers != 1:
        raise ValueError("banding implemented for single-layer chains")
    chain = graph.chains[0]
    e_in, e_out = chain.edges
    n_real = -(-graph.n_real // n_shards) * n_shards
    n_virt = -(-e_in.n_dst // n_shards) * n_shards
    vb, rb = n_virt // n_shards, n_real // n_shards

    # group in-edges by virtual band; padding edge: src=0 -> local dst 0
    # is harmless only if it contributes 0 — use src pointing at a real
    # node and dst at a PADDED virtual id (>= e_in.n_dst) within the band.
    in_by_band = [[] for _ in range(n_shards)]
    for s, d in zip(e_in.src, e_in.dst):
        in_by_band[d // vb].append((s, d % vb))
    out_by_band = [[] for _ in range(n_shards)]
    for s, d in zip(e_out.src, e_out.dst):
        out_by_band[s // vb].append((s % vb, d))
    # Two dedicated inert virtual slots per band: in-edge padding WRITES
    # slot vb (which no out-edge reads), out-edge padding READS slot vb+1
    # (which no in-edge writes) — so padding moves zero mass.
    vb_pad = vb + 2
    in_bands = []
    out_bands = []
    for b in range(n_shards):
        in_bands.append([(s, d) for s, d in in_by_band[b]])
        out_bands.append([(s, d) for s, d in out_by_band[b]])
    width_in = max(len(v) for v in in_bands)
    width_out = max(len(v) for v in out_bands)
    width = max(width_in, width_out)
    in_src = np.zeros((n_shards, width), np.int32)
    in_dst = np.full((n_shards, width), vb, np.int32)      # write-only slot
    out_src = np.full((n_shards, width), vb + 1, np.int32)  # read-only slot
    out_dst = np.zeros((n_shards, width), np.int32)
    out_pad_mask = np.zeros((n_shards, width), bool)
    for b in range(n_shards):
        for i, (s, d) in enumerate(in_bands[b]):
            in_src[b, i], in_dst[b, i] = s, d
        for i, (s, d) in enumerate(out_bands[b]):
            out_src[b, i], out_dst[b, i] = s, d
            out_pad_mask[b, i] = True

    cs, cd, cm = correction
    c_by_band = [[] for _ in range(n_shards)]
    for s, d, m in zip(cs, cd, cm):
        c_by_band[d // rb].append((s, d % rb, m))
    cw = max(max((len(v) for v in c_by_band), default=1), 1)
    corr_src = np.zeros((n_shards, cw), np.int32)
    corr_dst = np.zeros((n_shards, cw), np.int32)
    corr_cnt = np.zeros((n_shards, cw), np.float32)
    for b in range(n_shards):
        for i, (s, d, m) in enumerate(c_by_band[b]):
            corr_src[b, i], corr_dst[b, i], corr_cnt[b, i] = s, d, m

    deg_pad = np.zeros(n_real, np.float32)
    deg_pad[: deg.size] = deg
    return BandedGraph(
        in_src=in_src.reshape(-1),
        in_dst=in_dst.reshape(-1),
        out_src=out_src.reshape(-1),
        out_dst=out_dst.reshape(-1),
        corr_src=corr_src.reshape(-1),
        corr_dst=corr_dst.reshape(-1),
        corr_cnt=corr_cnt.reshape(-1),
        deg=deg_pad,
        n_real=n_real,
        n_virtual=n_shards * vb_pad,
        n_shards=n_shards,
    )


def make_banded_pagerank(
    mesh,
    axes: Tuple[str, ...],
    n_real: int,
    n_virt_banded: int,     # n_shards * (vb_pad)
    n_shards: int,
    iters: int = 20,
    damping: float = 0.85,
):
    """shard_map PageRank over band-partitioned arrays (see BandedGraph).

    Per iteration: one all-gather of the rank vector + one psum-scatter of
    the partial result — no all-reduce (§Perf 'banded' variant).
    """
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from ..compat import pvary, shard_map

    vb = n_virt_banded // n_shards
    rb = n_real // n_shards

    def pagerank_banded(args):
        def local(in_src, in_dst, out_src, out_dst, c_src, c_dst, c_cnt, deg):
            deg_loc = deg  # (rb,)

            def body(_, x_loc):
                contrib = jnp.where(
                    deg_loc > 0, x_loc / jnp.maximum(deg_loc, 1.0), 0.0
                )
                dangling = jax.lax.psum(
                    jnp.sum(jnp.where(deg_loc > 0, 0.0, x_loc)), axes
                )
                x_full = jax.lax.all_gather(contrib, axes, tiled=True)
                h_band = jax.ops.segment_sum(
                    jnp.take(x_full, in_src, axis=0), in_dst, num_segments=vb
                )
                y_partial = jax.ops.segment_sum(
                    jnp.take(h_band, out_src, axis=0), out_dst,
                    num_segments=n_real,
                )
                y_loc = jax.lax.psum_scatter(
                    y_partial, axes, scatter_dimension=0, tiled=True
                )
                corr = jax.ops.segment_sum(
                    jnp.take(x_full, c_src, axis=0) * c_cnt, c_dst,
                    num_segments=rb,
                )
                y_loc = y_loc - corr + dangling / n_real
                return (1.0 - damping) / n_real + damping * y_loc

            x0 = jnp.full((rb,), 1.0 / n_real, dtype=jnp.float32)
            x0 = pvary(x0, axes)
            return jax.lax.fori_loop(0, iters, body, x0)

        return shard_map(
            local,
            mesh=mesh,
            in_specs=tuple([P(axes)] * 8),
            out_specs=P(axes),
        )(
            args["in_src"], args["in_dst"], args["out_src"], args["out_dst"],
            args["corr_src"], args["corr_dst"], args["corr_cnt"], args["deg"],
        )

    return pagerank_banded
