"""Incremental extraction: a :class:`CondensedGraph` that stays fresh
under table writes (DESIGN.md §9).

The paper extracts once; production databases mutate continuously.  The
observation that makes incremental maintenance *exact* (byte-identical to
re-extraction, not approximately fresh) is that the sharded pipeline's
merge is already an associative monoid over contiguous partitions of
every segment's output (DESIGN.md §7/§8) — so a row delta is just one
more partition:

* **binding is row-local** (:func:`repro.core.planner._bind_table_rows`):
  the mutated table is ``old[keep] ++ inserts``, so its bound rows are
  the surviving old bound rows followed by the bound insert rows — a
  two-part contiguous partition ``(kept, delta)``;
* **the node space is a first-occurrence-wins sorted-key union**
  (:func:`repro.core.extract._node_space_from_parts`): applying the
  delete mask to the cached key parts *is* the tombstone — a key whose
  every occurrence was deleted never reaches the union;
* **the shard merge** (:func:`repro.core.serialize.merge_assemblies`)
  turns per-part assemblies back into the one-shot build, byte for byte.

:class:`LiveGraph` caches the per-rule bound tables, segment outputs and
assembled chains of the base extraction; :meth:`LiveGraph.apply_delta`
re-binds only the touched tables, re-executes only the touched
multi-atom segments, assembles one :class:`ShardAssembly` per delta
partition, merges, and bumps a monotonic :class:`GraphVersion` the
device layer and :class:`repro.serve.server.GraphQueryServer` use for
cache invalidation.  Durability comes from the write-ahead
:class:`repro.core.serialize.DeltaLog` — every delta is logged (append
-> fsync -> manifest-last) *before* it is applied, so a crashed update
replays to the identical graph via :meth:`LiveGraph.replay`.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .dsl import ExtractionQuery, parse
from .extract import (
    ExtractionResult,
    NodeSpace,
    _assemble_rule,
    _graph_from_assembly,
    _local_layer_keys,
    _node_space_from_parts,
    _plans_info,
    bind_atom,
)
from .condensed import Chain, CondensedGraph
from .planner import (
    ChainPlan,
    ExtractionBudget,
    _bind_table_rows,
    execute_segment,
    execute_segment_shard,
    plan_rule,
)
from .relational import Catalog, Table
from .serialize import DeltaLog, ShardAssembly, merge_assemblies

__all__ = [
    "GraphVersion",
    "LiveGraph",
    "apply_delta",
    "mutate_catalog",
]

# Delta specs (the shapes DeltaLog.append stores and replays):
#   inserts: {table_name: {column_name: values}}   rows appended
#   deletes: {table_name: (key_column, values)}    rows whose key matches
Inserts = Dict[str, Dict[str, np.ndarray]]
Deletes = Dict[str, Tuple[str, np.ndarray]]


@dataclasses.dataclass(frozen=True, order=True)
class GraphVersion:
    """Monotonic version of a live graph: bumped by every
    :func:`apply_delta` (including an empty one — the write was
    acknowledged, so caches keyed on the old version must die).  The
    device layer carries it as a static pytree field, so propagation over
    a stale packed graph can never silently mix versions, and
    :class:`repro.serve.server.GraphQueryServer` rejects stale-version
    submits outright (DESIGN.md §9)."""

    version: int

    def __int__(self) -> int:
        return int(self.version)

    def __index__(self) -> int:
        return int(self.version)


# ---------------------------------------------------------------------------
# Canonical delta semantics (shared by apply_delta and the test reference)
# ---------------------------------------------------------------------------

def _mutate_table(
    table: Table,
    ins_cols: Optional[Dict[str, np.ndarray]],
    del_spec: Optional[Tuple[str, np.ndarray]],
) -> Tuple[Table, int, int, int]:
    """Apply one table's delta; returns ``(new_table, n_kept, n_deleted,
    n_inserted)``.  Deletes first (drop every row whose key column takes
    a deleted value), then inserts appended at the end — so a
    delete-then-reinsert of the same key lands at the table's tail, and
    ``n_kept`` is the base-row index where the insert partition begins
    (the split point the incremental bind partitions at)."""
    keep = np.ones(len(table), dtype=bool)
    if del_spec is not None:
        key_col, values = del_spec
        if key_col not in table.column_names:
            raise ValueError(
                f"delete key column {key_col!r} not in table "
                f"{table.name!r} ({table.column_names})"
            )
        keep &= ~np.isin(table.column(key_col), np.asarray(values))
    n_deleted = int(keep.size - keep.sum())
    n_kept = int(keep.sum())
    keep_rows = np.nonzero(keep)[0]
    cols = {c: table.column(c)[keep_rows] for c in table.column_names}
    n_inserted = 0
    if ins_cols:
        if set(ins_cols) != set(table.column_names):
            raise ValueError(
                f"insert into {table.name!r} must give exactly columns "
                f"{table.column_names}, got {sorted(ins_cols)}"
            )
        arrays = {c: np.asarray(ins_cols[c]) for c in table.column_names}
        sizes = {a.shape[0] for a in arrays.values()}
        if len(sizes) != 1:
            raise ValueError(
                f"insert columns for {table.name!r} have unequal lengths"
            )
        n_inserted = sizes.pop()
        cols = {
            c: np.concatenate([cols[c], arrays[c]])
            for c in table.column_names
        }
    return Table(table.name, cols), n_kept, n_deleted, n_inserted


def _mutate_catalog_info(
    catalog: Catalog, inserts: Optional[Inserts], deletes: Optional[Deletes]
) -> Tuple[Catalog, Dict[str, Tuple[int, int, int]]]:
    """Apply a delta to every touched table; returns the new catalog plus
    ``{lowercase_name: (n_kept, n_deleted, n_inserted)}`` for the touched
    tables.  Untouched :class:`Table` objects are *reused* (their cached
    column stats stay valid — which is why an untouched rule re-plans to
    the identical plan)."""
    ins = {k.lower(): v for k, v in (inserts or {}).items()}
    dels = {k.lower(): v for k, v in (deletes or {}).items()}
    for name in list(ins) + list(dels):
        if name not in catalog:
            raise KeyError(
                f"delta touches unknown table {name!r}; "
                f"catalog has {catalog.table_names}"
            )
    touched: Dict[str, Tuple[int, int, int, bool]] = {}
    out = Catalog()
    for name in catalog.table_names:
        t = catalog.table(name)
        if name in ins or name in dels:
            t2, n_kept, n_del, n_ins = _mutate_table(
                t, ins.get(name), dels.get(name)
            )
            # dtype-preserved: concatenating the inserts did not promote
            # any column (e.g. a wider unicode or int->float), so bound
            # values of the base rows are bit-identical to the cached
            # ones — the precondition of the append-only fast path
            preserved = all(
                t2.column(c).dtype == t.column(c).dtype
                for c in t.column_names
            )
            touched[name] = (n_kept, n_del, n_ins, preserved)
            t = t2
        out.add(t)
    return out, touched


def mutate_catalog(
    catalog: Catalog,
    inserts: Optional[Inserts] = None,
    deletes: Optional[Deletes] = None,
) -> Catalog:
    """The canonical delta semantics, applied to a plain catalog: per
    touched table, delete every row whose key column matches a deleted
    value, then append the insert rows.  :func:`apply_delta` maintains
    the live graph so it is byte-identical to
    ``extract(mutate_catalog(catalog, inserts, deletes), dsl)`` — this
    function is that reference, and the property tests compare against
    it directly."""
    out, _ = _mutate_catalog_info(catalog, inserts, deletes)
    return out


# ---------------------------------------------------------------------------
# Live graph
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _RuleCache:
    """Everything one Edges rule's base extraction produced, kept so an
    untouched rule costs nothing on the next delta: the plan, the
    per-segment endpoint values, and the assembled entry (chain + global
    layer keys, or direct dense-id edges)."""

    plan: ChainPlan
    seg_vars: List[str]
    large_vars: List[str]
    seg_values: List[Tuple[np.ndarray, np.ndarray]]
    chain: Optional[Tuple[Chain, List[np.ndarray]]]
    direct: Optional[Tuple[np.ndarray, np.ndarray]]
    dropped: int


class LiveGraph:
    """A condensed graph plus the extraction state needed to keep it
    fresh under writes (DESIGN.md §9).

    Construction runs a full extraction and caches, per Nodes rule, the
    bound table, and per Edges rule a :class:`_RuleCache`.
    :meth:`apply_delta` then maintains the graph incrementally:

    * tables: deletes first, inserts appended (:func:`mutate_catalog`);
    * node space: rebuilt from cached bound tables only when a Nodes
      relation was touched — the delete mask applied before the
      sorted-key union is the tombstone;
    * Edges rules: untouched rules (with an unchanged node space) reuse
      their assembled entry verbatim; touched rules re-bind only their
      single-atom segments (split at the insert boundary into a
      ``(kept, delta)`` partition) and re-execute only their touched
      multi-atom segments, then assemble one :class:`ShardAssembly` per
      partition and merge — the DESIGN.md §7 merge invariant makes the
      result byte-identical to a fresh extraction of the mutated tables.

    With ``log=`` attached (a fresh :class:`~repro.core.serialize.
    DeltaLog`), every delta is appended to the write-ahead log *before*
    it is applied; :meth:`replay` rebuilds the identical live graph from
    the base catalog plus the log after a crash.
    """

    def __init__(
        self,
        catalog: Catalog,
        dsl_text: str,
        mode: str = "auto",
        preprocess: bool = False,
        budget: Optional[ExtractionBudget] = None,
        log: Optional[DeltaLog] = None,
    ) -> None:
        if log is not None and len(log):
            raise ValueError(
                "LiveGraph() builds the *base* graph and must start from "
                f"an empty delta log, but {log.directory!r} has "
                f"{len(log)} entries — use LiveGraph.replay() to rebuild "
                "from base catalog + log"
            )
        self.query: ExtractionQuery = parse(dsl_text)
        self.mode = mode
        self.preprocess = preprocess
        self.budget = budget
        self.log = log
        self.catalog = catalog
        self.version = 0
        self.last_apply_seconds = 0.0
        # version listeners (DESIGN.md §10): called after every successful
        # apply with (graph, GraphVersion) — the serving tier's
        # invalidation hook (result caches keyed on the old version die,
        # device residency refreshes from the new host graph)
        self._version_listeners: List = []
        self._build_full()

    # -- invalidation hooks ---------------------------------------------------
    def add_version_listener(self, callback) -> None:
        """Register ``callback(graph, version)`` to fire after every
        successful :meth:`apply_delta` (state already swapped, version
        already bumped — the callback sees exactly what a fresh reader
        would).  Listeners fire *after* the WAL append and the apply, so
        a listener crash cannot lose an acknowledged write; exceptions
        propagate to the caller of ``apply_delta``."""
        self._version_listeners.append(callback)

    def remove_version_listener(self, callback) -> None:
        self._version_listeners.remove(callback)

    # -- base build -----------------------------------------------------------
    def _build_full(self) -> None:
        t0 = time.perf_counter()
        self._node_bound: List[Table] = []
        for rule in self.query.nodes_rules:
            if len(rule.atoms) != 1:
                raise ValueError("Nodes statements bind one relation each")
            self._node_bound.append(
                bind_atom(self.catalog, rule.atoms[0], rule.comparisons)
            )
        self.nodes, self.props = self._node_space()
        self._rules: List[_RuleCache] = []
        for plan, seg_vars, large_vars in _plans_info(
            self.catalog, self.query, self.mode
        ):
            seg_values = [
                self._run_segment(self.catalog, plan, k, seg_vars)
                for k in range(len(plan.segments))
            ]
            cache = _RuleCache(
                plan, seg_vars, large_vars, seg_values, None, None, 0
            )
            self._set_entry(cache, self._assemble(
                len(self._rules), plan, large_vars, [seg_values]
            ))
            self._rules.append(cache)
        self.graph = self._finish()
        self.last_apply_seconds = time.perf_counter() - t0

    def _node_space(self) -> Tuple[NodeSpace, Dict[str, np.ndarray]]:
        """Node space from the cached bound Nodes tables — the same
        :func:`_node_space_from_parts` tail as the one-shot build, so the
        incremental rebuild cannot drift from ``extract``'s."""
        key_parts: List[np.ndarray] = []
        type_parts: List[np.ndarray] = []
        prop_parts: Dict[str, List[Tuple[np.ndarray, np.ndarray]]] = {}
        type_names: List[str] = []
        for rule, t in zip(self.query.nodes_rules, self._node_bound):
            keys = t.column(rule.head_vars[0])
            type_names.append(rule.atoms[0].relation)
            key_parts.append(keys)
            type_parts.append(
                np.full(keys.size, len(type_names) - 1, dtype=np.int32)
            )
            for prop in rule.head_vars[1:]:
                prop_parts.setdefault(prop, []).append((keys, t.column(prop)))
        return _node_space_from_parts(
            key_parts, type_parts, prop_parts, type_names
        )

    def _run_segment(
        self, catalog: Catalog, plan: ChainPlan, k: int, seg_vars: List[str]
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Execute one segment eagerly.  With a budget attached the
        single-shard sharded executor runs instead of the one-shot one —
        byte-identical output (the DESIGN.md §7 parity invariant), but
        every transient is charged to the rows account."""
        seg = plan.segments[k]
        if self.budget is not None:
            return execute_segment_shard(
                catalog, plan, seg, seg_vars[k], seg_vars[k + 1],
                0, 1, self.budget,
            )
        return execute_segment(catalog, plan, seg, seg_vars[k], seg_vars[k + 1])

    # -- assembly -------------------------------------------------------------
    def _assemble(
        self,
        r: int,
        plan: ChainPlan,
        large_vars: List[str],
        parts: Sequence[List[Tuple[np.ndarray, np.ndarray]]],
        pre: Optional[ShardAssembly] = None,
    ) -> ShardAssembly:
        """Assemble each delta partition against the current node space
        and merge them in partition order.  ``parts`` is a contiguous
        partition of every segment's output rows (kept rows first, then
        the delta; a fully recomputed segment contributes ``(full,
        empty)``), which is exactly the contract of the sharded merge —
        so the merged entry equals the one-shot assembly of the
        concatenated values, byte for byte.

        ``pre`` is an already-assembled leading partition (the cached
        entry on the append-only fast path); it is merged ahead of the
        value parts without re-assembling its rows."""
        live = [
            p for i, p in enumerate(parts)
            if (pre is None and i == 0) or any(sv.size for sv, _ in p)
        ]
        assemblies: List[ShardAssembly] = [] if pre is None else [pre]
        for pv in live:
            if len(plan.segments) == 1:
                sv, dv = pv[0]
                sid, sok = self.nodes.lookup(sv)
                did, dok = self.nodes.lookup(dv)
                ok = sok & dok
                assemblies.append(ShardAssembly(
                    {}, {r: (sid[ok], did[ok])}, int((~ok).sum())
                ))
            else:
                keys = _local_layer_keys(pv, len(large_vars))
                chain, d = _assemble_rule(self.nodes, pv, keys)
                assemblies.append(ShardAssembly({r: (chain, keys)}, {}, d))
        return merge_assemblies(assemblies)

    @staticmethod
    def _set_entry(cache: _RuleCache, merged: ShardAssembly) -> None:
        cache.chain = next(iter(merged.chains.values()), None)
        cache.direct = next(iter(merged.direct.values()), None)
        cache.dropped = merged.dropped

    def _finish(self) -> CondensedGraph:
        assembly = ShardAssembly(
            {r: c.chain for r, c in enumerate(self._rules) if c.chain},
            {r: c.direct for r, c in enumerate(self._rules) if c.direct},
            sum(c.dropped for c in self._rules),
        )
        return _graph_from_assembly(
            self.nodes, self.props, assembly, self.preprocess
        )

    # -- deltas ---------------------------------------------------------------
    def apply_delta(
        self,
        inserts: Optional[Inserts] = None,
        deletes: Optional[Deletes] = None,
    ) -> Tuple[CondensedGraph, GraphVersion]:
        """Apply one batch of writes; returns the fresh graph and its new
        version.  When a :class:`DeltaLog` is attached the batch is
        appended (append -> fsync -> manifest-last) *before* any state
        changes — the write-ahead order that makes a crashed apply
        replayable to the identical graph."""
        # validate against the current catalog before logging, so a bad
        # delta is rejected without leaving a poisoned log entry behind
        _mutate_catalog_info(self.catalog, inserts, deletes)
        if self.log is not None:
            self.log.append(inserts, deletes)
        return self._apply(inserts, deletes)

    def _apply(
        self, inserts: Optional[Inserts], deletes: Optional[Deletes]
    ) -> Tuple[CondensedGraph, GraphVersion]:
        t0 = time.perf_counter()
        budget = self.budget
        catalog, touched = _mutate_catalog_info(self.catalog, inserts, deletes)

        # -- node space: rebind touched Nodes tables, tombstoned union ----
        nodes_changed = False
        for i, rule in enumerate(self.query.nodes_rules):
            if rule.atoms[0].relation.lower() in touched:
                base = catalog.table(rule.atoms[0].relation)
                if budget is not None:
                    budget.charge(len(base), "delta node rebind")
                self._node_bound[i] = bind_atom(
                    catalog, rule.atoms[0], rule.comparisons
                )
                if budget is not None:
                    budget.release(len(base))
                nodes_changed = True
        if nodes_changed:
            old = self.nodes
            self.nodes, self.props = self._node_space()
            # a write that leaves the key->id mapping intact (property
            # update, delete-then-reinsert of the same key) invalidates
            # nothing downstream: chains index dense ids, and those only
            # depend on (keys, type_ids) — reuse every cached entry
            nodes_changed = not (
                old.keys.dtype == self.nodes.keys.dtype
                and np.array_equal(old.keys, self.nodes.keys)
                and np.array_equal(old.type_ids, self.nodes.type_ids)
            )

        # -- Edges rules: reuse, re-bind, or re-execute -------------------
        for r, cache in enumerate(self._rules):
            rule_touched = any(
                a.relation.lower() in touched for a in cache.plan.atoms
            )
            if not rule_touched and not nodes_changed:
                if budget is not None:
                    budget.delta_rules_reused += 1
                continue  # entry reused verbatim
            if not rule_touched:
                # segment outputs are unchanged; only the endpoint id
                # space moved — re-assemble from the cached values
                self._set_entry(cache, self._assemble(
                    r, cache.plan, cache.large_vars, [cache.seg_values]
                ))
                if budget is not None:
                    budget.delta_rules_recomputed += 1
                continue
            self._apply_rule(r, cache, catalog, touched, nodes_changed)
            if budget is not None:
                budget.delta_rules_recomputed += 1

        self.catalog = catalog
        self.graph = self._finish()
        self.version += 1
        if budget is not None:
            budget.charge_delta(
                sum(t[2] for t in touched.values()),
                sum(t[1] for t in touched.values()),
            )
        self.last_apply_seconds = time.perf_counter() - t0
        out = self.graph, GraphVersion(self.version)
        for callback in list(self._version_listeners):
            callback(*out)
        return out

    def _apply_rule(
        self,
        r: int,
        cache: _RuleCache,
        catalog: Catalog,
        touched: Dict[str, Tuple[int, int, int, bool]],
        nodes_changed: bool,
    ) -> None:
        """Incrementally recompute one touched Edges rule: keep cached
        segment outputs where possible, split re-bound single-atom
        segments at the insert boundary, fully re-execute touched
        multi-atom segments, then assemble per partition and merge.

        Append-only fast path: when the delta only *inserts* rows (no
        deletes on any table this rule reads, column dtypes preserved),
        the plan marking is unchanged, every touched segment is
        single-atom and the node space did not move, the cached merged
        entry already *is* the assembly of all pre-delta rows (by
        induction over the merge monoid) — so only the insert tail is
        bound and assembled, and merged behind the cached entry.  That
        turns the apply cost from O(table) into O(delta) + O(merge)."""
        plan, compatible = cache.plan, True
        if self.mode == "auto":
            # stats of the touched tables moved; the chain order is
            # structural (never stats-dependent) but the large-output
            # marking is — a changed marking voids the segment caches
            plan = plan_rule(catalog, cache.plan.rule, mode=self.mode)
            compatible = plan.large == cache.plan.large
        id1, id2 = plan.endpoint_vars
        large_vars = [v for v, l in zip(plan.link_vars, plan.large) if l]
        seg_vars = [id1] + large_vars + [id2]

        fast = compatible and not nodes_changed
        if fast:
            for seg in plan.segments:
                atoms = plan.atoms[seg[0]: seg[1] + 1]
                stats = [
                    touched[a.relation.lower()] for a in atoms
                    if a.relation.lower() in touched
                ]
                if not stats:
                    continue
                if len(atoms) != 1 or any(
                    n_del or not preserved
                    for _, n_del, _, preserved in stats
                ):
                    fast = False
                    break
        if fast:
            self._apply_rule_append(
                r, cache, catalog, plan, large_vars, seg_vars, touched
            )
            return

        kept: List[Tuple[np.ndarray, np.ndarray]] = []
        delta: List[Tuple[np.ndarray, np.ndarray]] = []
        new_values: List[Tuple[np.ndarray, np.ndarray]] = []
        for k, seg in enumerate(plan.segments):
            atoms = plan.atoms[seg[0]: seg[1] + 1]
            seg_touched = any(a.relation.lower() in touched for a in atoms)
            if compatible and not seg_touched:
                vals = cache.seg_values[k]
                kept.append(vals)
                delta.append((vals[0][:0], vals[1][:0]))
                new_values.append(vals)
            elif compatible and len(atoms) == 1:
                # single-atom segment: binding is row-local, so the bound
                # mutated table splits at the insert boundary into the
                # (kept, delta) partition — no join to redo
                atom = atoms[0]
                base = catalog.table(atom.relation)
                if self.budget is not None:
                    self.budget.charge(len(base), "delta segment rebind")
                bound, rows = _bind_table_rows(
                    base, atom, plan.rule.comparisons
                )
                if self.budget is not None:
                    self.budget.release(len(base))
                sv = bound.column(seg_vars[k])
                dv = bound.column(seg_vars[k + 1])
                n_kept_base = touched[atom.relation.lower()][0]
                split = int(np.searchsorted(rows, n_kept_base))
                kept.append((sv[:split], dv[:split]))
                delta.append((sv[split:], dv[split:]))
                new_values.append((sv, dv))
            else:
                # multi-atom (eager hash-join) segments interleave rows
                # from both join sides, so a row delta is not a
                # contiguous slice of the output — re-execute in full
                vals = self._run_segment(catalog, plan, k, seg_vars)
                kept.append(vals)
                delta.append((vals[0][:0], vals[1][:0]))
                new_values.append(vals)

        cache.plan = plan
        cache.seg_vars = seg_vars
        cache.large_vars = large_vars
        cache.seg_values = new_values
        self._set_entry(
            cache, self._assemble(r, plan, large_vars, [kept, delta])
        )

    def _apply_rule_append(
        self,
        r: int,
        cache: _RuleCache,
        catalog: Catalog,
        plan: ChainPlan,
        large_vars: List[str],
        seg_vars: List[str],
        touched: Dict[str, Tuple[int, int, int, bool]],
    ) -> None:
        """The append-only fast path (preconditions checked by the
        caller).  Binding is row-local and there are no deletes, so the
        bound mutated table is exactly ``cached bound rows ++ bound
        insert rows``: only the insert tail of each touched table is
        bound, assembled as the delta partition, and merged behind the
        cached entry — which by induction equals the single-part
        assembly of every pre-delta row."""
        delta: List[Tuple[np.ndarray, np.ndarray]] = []
        new_values: List[Tuple[np.ndarray, np.ndarray]] = []
        for k, seg in enumerate(plan.segments):
            atoms = plan.atoms[seg[0]: seg[1] + 1]
            vals = cache.seg_values[k]
            if not any(a.relation.lower() in touched for a in atoms):
                delta.append((vals[0][:0], vals[1][:0]))
                new_values.append(vals)
                continue
            atom = atoms[0]
            table = catalog.table(atom.relation)
            n_kept_base = touched[atom.relation.lower()][0]
            tail = Table(table.name, {
                c: table.column(c)[n_kept_base:] for c in table.column_names
            })
            if self.budget is not None:
                self.budget.charge(len(tail), "delta tail rebind")
            bound, _rows = _bind_table_rows(tail, atom, plan.rule.comparisons)
            if self.budget is not None:
                self.budget.release(len(tail))
            sv = bound.column(seg_vars[k])
            dv = bound.column(seg_vars[k + 1])
            delta.append((sv, dv))
            new_values.append((
                np.concatenate([vals[0], sv]),
                np.concatenate([vals[1], dv]),
            ))

        pre = ShardAssembly(
            {r: cache.chain} if cache.chain is not None else {},
            {r: cache.direct} if cache.direct is not None else {},
            cache.dropped,
        )
        cache.plan = plan
        cache.seg_vars = seg_vars
        cache.large_vars = large_vars
        cache.seg_values = new_values
        self._set_entry(
            cache, self._assemble(r, plan, large_vars, [delta], pre=pre)
        )

    # -- durability -----------------------------------------------------------
    @classmethod
    def replay(
        cls,
        catalog: Catalog,
        dsl_text: str,
        log: DeltaLog,
        mode: str = "auto",
        preprocess: bool = False,
        budget: Optional[ExtractionBudget] = None,
    ) -> "LiveGraph":
        """Rebuild the live graph from the *base* catalog plus a delta
        log: build the base extraction, then re-apply every certified
        log entry in order (without re-appending).  Because
        :meth:`apply_delta` logs before it mutates, this lands on the
        exact graph and version the crashed process had acknowledged —
        byte-identical, not merely equivalent.  The log stays attached,
        so subsequent applies append to it."""
        live = cls(catalog, dsl_text, mode=mode, preprocess=preprocess,
                   budget=budget)
        for ins, dels in log.entries():
            live._apply(ins, dels)
        live.log = log
        return live

    def result(self) -> ExtractionResult:
        """Package the live state as an :class:`ExtractionResult`, the
        bundle the device pipeline (:mod:`repro.data.pipeline`) consumes."""
        return ExtractionResult(
            graph=self.graph,
            nodes=self.nodes,
            plans=[c.plan for c in self._rules],
            seconds=self.last_apply_seconds,
            dropped_endpoints=sum(c.dropped for c in self._rules),
            mode=self.mode,
            n_shards=1,
            budget=self.budget,
        )


def apply_delta(
    live: LiveGraph,
    inserts: Optional[Inserts] = None,
    deletes: Optional[Deletes] = None,
) -> Tuple[CondensedGraph, GraphVersion]:
    """Apply one batch of inserts/deletes to a live graph; returns
    ``(graph, version)`` with the graph byte-identical
    (:func:`repro.core.condensed.graphs_identical`) to a fresh
    ``extract`` of the mutated tables and the version bumped by one.
    Module-level spelling of :meth:`LiveGraph.apply_delta`."""
    return live.apply_delta(inserts, deletes)
