"""Cost-based extraction optimizer (DESIGN.md §12).

The paper's §6.5 advisor picks a *representation* from two measured
ratios; by PR 9 the pipeline had grown many more knobs — ``n_shards``,
rows-vs-hash partitioning, spilling, ``merge_arity``, the pack fold
method, fused-vs-unfused correction — that interact with the caller's
:class:`~repro.core.planner.ExtractionBudget`.  This module chooses them
with a cost model instead of by hand:

* :func:`profile_query` binds every rule atom once (binding is row-local
  and cheap relative to extraction) and records, per atom, the exact
  bound cardinality plus the join-key fan-out stats
  (:class:`~repro.core.relational.ColumnStats.max_count`) that make the
  peak bounds *sound* rather than expected.
* :func:`peak_resident_rows_bound` / :func:`assembly_account_bounds`
  replay the budget's exact charge sequences
  (:func:`~repro.core.planner.execute_segment_shard`,
  ``_build_node_space_sharded``, the spill writers) symbolically and
  return upper bounds on what :class:`ExtractionBudget` will observe.
  Feasibility pruning against the caller's caps therefore cannot pass a
  plan that raises :class:`~repro.core.planner.ExtractionBudgetError`.
* :func:`plan_cost` turns the profile into predicted wall seconds using
  measured throughputs where available (``CrossoverTable`` kernel
  timings, :func:`repro.kernels.pack.measure_pack_throughput`) and
  host-roofline defaults (``repro.launch.roofline.HOST_MEM_BW`` /
  ``HOST_DISK_BW``) where not — the same measured-overrides-analytic
  precedence as kernel dispatch.
* :func:`plan` enumerates the bounded configuration space, prunes
  infeasible or invariant-breaking configs with an explicit reason each,
  and returns a :class:`PlanReport` whose chosen
  :class:`ExtractionPlan` executes directly through
  :func:`repro.core.extract.extract` /
  :func:`repro.data.pipeline.sharded_extract_to_device`.

All predictions are deterministic functions of (catalog, query, mode,
throughputs, crossover) — no clocks, no randomness — so plan choice is
reproducible and golden-testable (tests/test_advisor_plan.py).
"""
from __future__ import annotations

import dataclasses
import json
import math
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from .dsl import ExtractionQuery, parse
from .planner import ExtractionBudget, bind_atom, plan_rule
from .relational import Catalog, Table

try:  # host throughput floors live with the other roofline constants
    from ..launch.roofline import HOST_DISK_BW, HOST_MEM_BW
except Exception:  # pragma: no cover - launch layer unavailable
    HOST_MEM_BW, HOST_DISK_BW = 8e9, 0.8e9

__all__ = [
    "Throughputs",
    "QueryProfile",
    "profile_query",
    "peak_resident_rows_bound",
    "peak_transient_bytes_bound",
    "assembly_account_bounds",
    "PlanConfig",
    "PlanCost",
    "ExtractionPlan",
    "PrunedPlan",
    "PlanReport",
    "plan",
    "plan_cost",
    "device_representation_costs",
]

# Charged alongside each unique-key candidate: the int64 first-occurrence
# index in the no-spill node build / the spilled candidate record.
_CAND_EXTRA = 8
# The spilled candidate *union* additionally holds int64 shard + int32
# rule tags per candidate (see extract._node_space_from_spill).
_UNION_EXTRA = 8 + 8 + 4
# Edge arrays are int64 (src, dst) pairs from lookup/searchsorted.
_EDGE_BYTES = 16


# ---------------------------------------------------------------------------
# Throughputs: measured where available, roofline defaults where not
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Throughputs:
    """Rates the wall-time model divides work by.

    Defaults are conservative single-core floors derived from the host
    roofline constants; callers with measurements (``BENCH_kernels.json``
    pack numbers, :func:`repro.kernels.pack.measure_pack_throughput`)
    override the relevant fields.  Frozen so a ``Throughputs`` pins a
    deterministic plan choice.
    """

    scan_rows_per_s: float = 100e6       # base-relation row-slice scan
    bind_rows_per_s: float = 60e6        # selection masks + column gather
    join_rows_per_s: float = 25e6        # hash_join build+probe+emit rows
    assemble_bytes_per_s: float = HOST_MEM_BW / 4
    merge_bytes_per_s: float = HOST_MEM_BW / 8
    spill_bytes_per_s: float = HOST_DISK_BW
    shard_overhead_s: float = 2e-4       # per-shard fixed dispatch cost
    pack_reduceat_edges_per_s: float = 30e6
    pack_scatter_edges_per_s: float = 12e6
    correction_triples_per_s: float = 8e6

    def pack_edges_per_s(self, method: str) -> float:
        if method == "scatter":
            return self.pack_scatter_edges_per_s
        return self.pack_reduceat_edges_per_s

    @classmethod
    def with_measured_pack(
        cls, pack_rates: Dict[str, float], **overrides: float
    ) -> "Throughputs":
        """Build from a :func:`measure_pack_throughput` result."""
        kw: Dict[str, float] = dict(overrides)
        if "reduceat" in pack_rates:
            kw["pack_reduceat_edges_per_s"] = float(pack_rates["reduceat"])
        if "scatter" in pack_rates:
            kw["pack_scatter_edges_per_s"] = float(pack_rates["scatter"])
        return cls(**kw)


# ---------------------------------------------------------------------------
# Query profile: one bind pass, exact cardinalities + sound fan-out stats
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AtomProfile:
    """One chain atom.  For probe atoms (every atom after a segment's
    lead) the ``link_*`` fields describe the join into this atom:
    ``link_max_count`` is the most-common join-key frequency in the bound
    probe table — each accumulator row matches at most that many probe
    rows, which is what makes the join-output bounds sound."""

    relation: str
    base_rows: int
    base_row_bytes: int
    bound_rows: int
    bound_row_bytes: int
    link_max_count: int = 0       # 0 for segment leads
    link_n_distinct: int = 1      # max of both bound sides (planner's d)
    link_value_bytes: int = 8


@dataclasses.dataclass(frozen=True)
class SegmentProfile:
    atoms: Tuple[AtomProfile, ...]
    in_value_bytes: int = 8       # dtype itemsize of the in-endpoint var
    out_value_bytes: int = 8      # dtype itemsize of the out-endpoint var


@dataclasses.dataclass(frozen=True)
class RuleProfile:
    describe: str
    segments: Tuple[SegmentProfile, ...]

    @property
    def direct(self) -> bool:
        """Single-segment rules emit direct real->real edges."""
        return len(self.segments) == 1


@dataclasses.dataclass(frozen=True)
class NodeRuleProfile:
    relation: str
    base_rows: int
    base_row_bytes: int
    bound_rows: int
    key_bytes: int
    prop_bytes: int               # summed property-column itemsizes (0 = none)


@dataclasses.dataclass(frozen=True)
class QueryProfile:
    node_rules: Tuple[NodeRuleProfile, ...]
    edge_rules: Tuple[RuleProfile, ...]

    def scaled(self, row_factor: float) -> "QueryProfile":
        """The profile of the same query over ``row_factor``-times the
        rows (distinct counts held fixed, so per-key fan-out scales with
        the rows).  Used by the monotonicity properties."""

        def s(v: int) -> int:
            return int(math.ceil(v * row_factor))

        nodes = tuple(
            dataclasses.replace(
                nr, base_rows=s(nr.base_rows), bound_rows=s(nr.bound_rows)
            )
            for nr in self.node_rules
        )
        edges = []
        for rp in self.edge_rules:
            segs = []
            for sp in rp.segments:
                atoms = tuple(
                    dataclasses.replace(
                        a,
                        base_rows=s(a.base_rows),
                        bound_rows=s(a.bound_rows),
                        link_max_count=s(a.link_max_count),
                    )
                    for a in sp.atoms
                )
                segs.append(dataclasses.replace(sp, atoms=atoms))
            edges.append(dataclasses.replace(rp, segments=tuple(segs)))
        return QueryProfile(nodes, tuple(edges))


def _row_bytes(table: Table) -> int:
    return sum(int(c.dtype.itemsize) for c in table.columns.values())


def _var_itemsize(bound_tables: Sequence[Table], var: str) -> int:
    for t in bound_tables:
        if var in t.column_names:
            return int(t.column(var).dtype.itemsize)
    return 8


def profile_query(
    catalog: Catalog,
    query: Union[str, ExtractionQuery],
    mode: str = "auto",
) -> QueryProfile:
    """Bind every rule atom once and collect the cardinalities the cost
    model needs.  One pass over the bound data (``Table.analyze`` on the
    bound columns) — the same work :func:`plan_rule` already does to mark
    large links, extended with the ``max_count`` fan-out stat."""
    if isinstance(query, str):
        query = parse(query)

    node_profiles: List[NodeRuleProfile] = []
    for rule in query.nodes_rules:
        atom = rule.atoms[0]
        base = catalog.table(atom.relation)
        bound = bind_atom(catalog, atom, rule.comparisons)
        key_isz = int(bound.column(rule.head_vars[0]).dtype.itemsize)
        prop_isz = sum(
            int(bound.column(p).dtype.itemsize) for p in rule.head_vars[1:]
        )
        node_profiles.append(NodeRuleProfile(
            relation=atom.relation,
            base_rows=len(base),
            base_row_bytes=_row_bytes(base),
            bound_rows=len(bound),
            key_bytes=key_isz,
            prop_bytes=prop_isz,
        ))

    edge_profiles: List[RuleProfile] = []
    for rule in query.edges_rules:
        cp = plan_rule(catalog, rule, mode=mode)
        id1, id2 = cp.endpoint_vars
        large_vars = [v for v, l in zip(cp.link_vars, cp.large) if l]
        seg_vars = [id1] + large_vars + [id2]
        segs: List[SegmentProfile] = []
        for k, (i, j) in enumerate(cp.segments):
            atom_profiles: List[AtomProfile] = []
            bound_tables: List[Table] = []
            for a_idx in range(i, j + 1):
                atom = cp.atoms[a_idx]
                base = catalog.table(atom.relation)
                bound = bind_atom(catalog, atom, rule.comparisons)
                bound_tables.append(bound)
                if a_idx == i:
                    atom_profiles.append(AtomProfile(
                        relation=atom.relation,
                        base_rows=len(base),
                        base_row_bytes=_row_bytes(base),
                        bound_rows=len(bound),
                        bound_row_bytes=_row_bytes(bound),
                    ))
                    continue
                link = cp.link_vars[a_idx - 1]
                left = bound_tables[-2].stats(link)
                right = bound.stats(link)
                atom_profiles.append(AtomProfile(
                    relation=atom.relation,
                    base_rows=len(base),
                    base_row_bytes=_row_bytes(base),
                    bound_rows=len(bound),
                    bound_row_bytes=_row_bytes(bound),
                    link_max_count=int(right.max_count),
                    link_n_distinct=max(left.n_distinct, right.n_distinct, 1),
                    link_value_bytes=int(bound.column(link).dtype.itemsize),
                ))
            segs.append(SegmentProfile(
                atoms=tuple(atom_profiles),
                in_value_bytes=_var_itemsize(bound_tables, seg_vars[k]),
                out_value_bytes=_var_itemsize(bound_tables, seg_vars[k + 1]),
            ))
        edge_profiles.append(RuleProfile(
            describe=cp.describe(), segments=tuple(segs)
        ))
    return QueryProfile(tuple(node_profiles), tuple(edge_profiles))


# ---------------------------------------------------------------------------
# Sound peak bounds: symbolic replay of the budget charge sequences
# ---------------------------------------------------------------------------

def _ceil_div(a: int, n: int) -> int:
    return -(-int(a) // max(int(n), 1))


def _segment_peaks(
    seg: SegmentProfile, n_shards: int
) -> Tuple[int, int, int, int]:
    """Replay :func:`execute_segment_shard`'s charges for the worst shard.

    Returns ``(peak_rows, peak_bytes, out_rows_total, out_rows_shard)``:
    the rows/bytes peaks any one shard's transients can reach, the sound
    bound on the segment's *total* output rows (all shards), and on one
    shard's output rows.  All four are nondecreasing in table rows and
    nonincreasing in ``n_shards`` by construction.
    """
    lead = seg.atoms[0]
    block = _ceil_div(lead.base_rows, n_shards)
    acc_s = min(block, lead.bound_rows)       # worst shard's accumulator rows
    acc_t = lead.bound_rows                   # summed over all shards
    acc_w = lead.bound_row_bytes              # accumulator row width
    peak_r = block + acc_s
    peak_b = block * lead.base_row_bytes + acc_s * acc_w
    for pa in seg.atoms[1:]:
        pblock = _ceil_div(pa.base_rows, n_shards)
        # probe survivors: every kept row's key occurs in the shard's
        # accumulator, and one key matches at most link_max_count rows
        surv = min(pa.bound_rows, acc_s * pa.link_max_count)
        j_s = acc_s * pa.link_max_count
        j_t = acc_t * pa.link_max_count
        j_w = acc_w + pa.bound_row_bytes      # join concatenates columns
        # (a) last probe scan block charged on top of all survivors
        peak_r = max(peak_r, acc_s + surv + pblock)
        peak_b = max(
            peak_b,
            acc_s * acc_w + surv * pa.bound_row_bytes
            + pblock * pa.base_row_bytes,
        )
        # (b) join output charged before acc + probe are released
        peak_r = max(peak_r, acc_s + surv + j_s)
        peak_b = max(
            peak_b, acc_s * acc_w + surv * pa.bound_row_bytes + j_s * j_w
        )
        acc_s, acc_t, acc_w = j_s, j_t, j_w
    return peak_r, peak_b, acc_t, acc_s


def peak_resident_rows_bound(profile: QueryProfile, n_shards: int) -> int:
    """Sound upper bound on ``ExtractionBudget.peak_resident_rows``.

    Every charge/release pair of the node build and every segment shard
    is replayed symbolically; transients are fully released between
    shards and segments, so the overall peak is the max over phases."""
    peak = 0
    for nr in profile.node_rules:
        block = _ceil_div(nr.base_rows, n_shards)
        peak = max(peak, block + min(block, nr.bound_rows))
    for rp in profile.edge_rules:
        for sp in rp.segments:
            peak = max(peak, _segment_peaks(sp, n_shards)[0])
    return peak


def peak_transient_bytes_bound(profile: QueryProfile, n_shards: int) -> int:
    """:func:`peak_resident_rows_bound` with each charged row weighted by
    its table's actual per-row byte width (string property columns are
    wide; a rows-only view hides that)."""
    peak = 0
    for nr in profile.node_rules:
        block = _ceil_div(nr.base_rows, n_shards)
        bnd = min(block, nr.bound_rows)
        peak = max(
            peak,
            block * nr.base_row_bytes + bnd * (nr.key_bytes + nr.prop_bytes),
        )
    for rp in profile.edge_rules:
        for sp in rp.segments:
            peak = max(peak, _segment_peaks(sp, n_shards)[1])
    return peak


def _node_assembly_bounds(
    profile: QueryProfile, n_shards: int
) -> Tuple[int, int]:
    """(no-spill accumulated node-candidate bytes, max single spill
    charge) for the node-space phase."""
    total = 0
    single = 0
    for nr in profile.node_rules:
        block = _ceil_div(nr.base_rows, n_shards)
        b_s = min(block, nr.bound_rows)
        per_shard = b_s * (nr.key_bytes + _CAND_EXTRA)
        per_rule = nr.bound_rows * (nr.key_bytes + _CAND_EXTRA)
        if nr.prop_bytes:
            per_shard += b_s * (nr.key_bytes + nr.prop_bytes)
            per_rule += nr.bound_rows * (nr.key_bytes + nr.prop_bytes)
        total += per_rule
        # spill singles: the (rule, shard) record, the candidate-union
        # slice, and the property-scatter read — the largest covers all
        single = max(
            single, per_shard, b_s * (nr.key_bytes + _UNION_EXTRA)
        )
    return total, single


def assembly_account_bounds(
    profile: QueryProfile, n_shards: int
) -> Tuple[int, int]:
    """Sound bounds for the assembly-bytes account, as
    ``(no_spill_peak, spill_single_charge_peak)``.

    No-spill: node candidates accumulate (then release), then every
    shard's :class:`~repro.core.serialize.ShardAssembly` accumulates
    until the merge — the peak is the larger phase, and a cap violation
    raises.  Spilling: each buffer is charged ``spilling=True`` and
    released once written, so only a *single* charge above the cap can
    raise ("unsatisfiable") — the bound is the largest single charge:
    one shard's complete assembly, or one node record/union slice."""
    node_total, node_single = _node_assembly_bounds(profile, n_shards)
    chain_total = 0
    chain_shard = 0  # one shard's complete ShardAssembly (all rules)
    for rp in profile.edge_rules:
        outs_t: List[int] = []
        outs_s: List[int] = []
        for sp in rp.segments:
            _, _, out_t, out_s = _segment_peaks(sp, n_shards)
            outs_t.append(out_t)
            outs_s.append(out_s)
            chain_total += out_t * _EDGE_BYTES
            chain_shard += out_s * _EDGE_BYTES
        for k in range(len(rp.segments) - 1):
            vb = max(
                rp.segments[k].out_value_bytes,
                rp.segments[k + 1].in_value_bytes,
            )
            chain_total += (outs_t[k] + outs_t[k + 1]) * vb
            chain_shard += (outs_s[k] + outs_s[k + 1]) * vb
    return max(node_total, chain_total), max(node_single, chain_shard)


# ---------------------------------------------------------------------------
# Plan space
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True, order=True)
class PlanConfig:
    """One point of the bounded configuration space.  Ordered, so ties in
    predicted wall time break deterministically by field order."""

    n_shards: int = 1
    partition: str = "rows"
    spill: bool = False
    merge_arity: int = 2
    pack_method: str = "reduceat"
    fuse_correction: bool = True

    def to_json_dict(self) -> Dict[str, object]:
        return dataclasses.asdict(self)

    @classmethod
    def from_json_dict(cls, d: Dict[str, object]) -> "PlanConfig":
        return cls(**{f.name: d[f.name] for f in dataclasses.fields(cls)})


@dataclasses.dataclass(frozen=True)
class PlanCost:
    """Predicted cost of one :class:`PlanConfig`.

    ``wall_s`` and the per-stage terms are *expectations* (planner's
    ``|R||S|/d`` estimates over measured/roofline rates); the ``peak_*``
    fields are *sound upper bounds* on what the budget accounts will
    observe — the feasibility side never relies on expectations."""

    wall_s: float
    scan_s: float
    bind_s: float
    join_s: float
    assemble_s: float
    spill_s: float
    merge_s: float
    pack_s: float
    correction_s: float
    est_edges: float                # expected condensed edges
    est_assembly_bytes: float
    peak_resident_rows: int         # sound bound (rows account)
    peak_transient_bytes: int       # rows bound weighted by row widths
    peak_assembly_bytes: int        # sound bound (bytes account)
    peak_bytes: int                 # transient + assembly co-residency

    def to_json_dict(self) -> Dict[str, object]:
        return dataclasses.asdict(self)

    @classmethod
    def from_json_dict(cls, d: Dict[str, object]) -> "PlanCost":
        return cls(**{f.name: d[f.name] for f in dataclasses.fields(cls)})


def _estimate_stage_seconds(
    profile: QueryProfile, config: PlanConfig, tp: Throughputs
) -> Dict[str, float]:
    n = config.n_shards
    scan_rows = 0.0
    bind_rows = 0.0
    join_rows = 0.0
    node_bytes = 0.0
    for nr in profile.node_rules:
        scan_rows += nr.base_rows
        bind_rows += nr.bound_rows
        node_bytes += nr.bound_rows * (nr.key_bytes + _CAND_EXTRA)
        if nr.prop_bytes:
            node_bytes += nr.bound_rows * (nr.key_bytes + nr.prop_bytes)
    est_edges = 0.0
    chain_bytes = 0.0
    for rp in profile.edge_rules:
        seg_outs: List[float] = []
        for sp in rp.segments:
            lead = sp.atoms[0]
            scan_rows += lead.base_rows
            bind_rows += lead.bound_rows
            acc = float(lead.bound_rows)
            for pa in sp.atoms[1:]:
                # _probe_partition scans the FULL probe relation once per
                # shard — the dominant reason small jobs prefer n_shards=1
                scan_rows += n * pa.base_rows
                out = acc * pa.bound_rows / max(pa.link_n_distinct, 1)
                bind_rows += min(float(pa.bound_rows), out + acc)
                join_rows += acc + pa.bound_rows + out
                acc = out
            seg_outs.append(acc)
            est_edges += acc
            chain_bytes += acc * _EDGE_BYTES
        for k in range(len(rp.segments) - 1):
            vb = max(
                rp.segments[k].out_value_bytes,
                rp.segments[k + 1].in_value_bytes,
            )
            chain_bytes += (seg_outs[k] + seg_outs[k + 1]) * vb
    assembly_bytes = node_bytes + chain_bytes

    scan_s = scan_rows / tp.scan_rows_per_s
    bind_s = bind_rows / tp.bind_rows_per_s
    join_s = join_rows / tp.join_rows_per_s
    assemble_s = assembly_bytes / tp.assemble_bytes_per_s \
        + n * tp.shard_overhead_s
    spill_s = 0.0
    merge_s = 0.0
    if config.spill:
        # every assembly buffer is written out and read back at least once
        spill_s = 2.0 * assembly_bytes / tp.spill_bytes_per_s
        if n > 1:
            rounds = max(
                1, math.ceil(math.log(n) / math.log(max(config.merge_arity, 2)))
            )
            merge_s = rounds * chain_bytes / tp.merge_bytes_per_s
    elif n > 1:
        merge_s = chain_bytes / tp.merge_bytes_per_s
    pack_s = 2.0 * est_edges / tp.pack_edges_per_s(config.pack_method)
    correction_s = est_edges / tp.correction_triples_per_s
    if config.fuse_correction:
        # the fused epilogue folds the correction into the propagation
        # pass instead of a separate SpMV over the duplicate planes
        correction_s *= 0.25
    return {
        "scan_s": scan_s,
        "bind_s": bind_s,
        "join_s": join_s,
        "assemble_s": assemble_s,
        "spill_s": spill_s,
        "merge_s": merge_s,
        "pack_s": pack_s,
        "correction_s": correction_s,
        "est_edges": est_edges,
        "est_assembly_bytes": assembly_bytes,
    }


def plan_cost(
    profile: QueryProfile,
    config: PlanConfig,
    throughputs: Optional[Throughputs] = None,
) -> PlanCost:
    """Predicted cost of executing ``profile`` under ``config``."""
    tp = throughputs or Throughputs()
    stages = _estimate_stage_seconds(profile, config, tp)
    rows_bound = peak_resident_rows_bound(profile, config.n_shards)
    transient_bound = peak_transient_bytes_bound(profile, config.n_shards)
    no_spill_peak, spill_single = assembly_account_bounds(
        profile, config.n_shards
    )
    assembly_bound = spill_single if config.spill else no_spill_peak
    wall = sum(
        stages[k] for k in (
            "scan_s", "bind_s", "join_s", "assemble_s", "spill_s",
            "merge_s", "pack_s", "correction_s",
        )
    )
    return PlanCost(
        wall_s=wall,
        scan_s=stages["scan_s"],
        bind_s=stages["bind_s"],
        join_s=stages["join_s"],
        assemble_s=stages["assemble_s"],
        spill_s=stages["spill_s"],
        merge_s=stages["merge_s"],
        pack_s=stages["pack_s"],
        correction_s=stages["correction_s"],
        est_edges=stages["est_edges"],
        est_assembly_bytes=stages["est_assembly_bytes"],
        peak_resident_rows=rows_bound,
        peak_transient_bytes=transient_bound,
        peak_assembly_bytes=assembly_bound,
        peak_bytes=transient_bound + assembly_bound,
    )


# ---------------------------------------------------------------------------
# ExtractionPlan / PlanReport
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ExtractionPlan:
    """An executable plan: the chosen config plus everything
    ``extract`` / ``sharded_extract_to_device`` need to run it."""

    config: PlanConfig
    cost: PlanCost
    mode: str
    query_text: str
    max_resident_rows: Optional[int] = None
    max_assembly_bytes: Optional[int] = None

    def make_budget(self) -> ExtractionBudget:
        return ExtractionBudget(
            max_resident_rows=self.max_resident_rows,
            max_assembly_bytes=self.max_assembly_bytes,
            spill_enabled=self.config.spill,
        )

    def extract_kwargs(self) -> Dict[str, object]:
        """Knobs for :func:`repro.core.extract.extract`."""
        return {
            "n_shards": self.config.n_shards,
            "merge_arity": self.config.merge_arity,
        }

    def device_kwargs(self) -> Dict[str, object]:
        """Knobs for :func:`repro.core.engine.to_device_packed`."""
        return {
            "pack_method": self.config.pack_method,
            "fuse_correction": self.config.fuse_correction,
        }

    def execute(self, catalog: Catalog, preprocess: bool = False,
                spill_dir: Optional[str] = None):
        """Run the plan; returns an ``ExtractionResult``.  Spilling plans
        without an explicit ``spill_dir`` use a temporary directory."""
        from .extract import extract

        if not self.query_text:
            raise ValueError(
                "plan was built from a parsed ExtractionQuery, not DSL "
                "text; call extract(catalog, dsl_text, plan=plan) instead"
            )

        return extract(
            catalog, self.query_text, mode=self.mode, preprocess=preprocess,
            plan=self, spill_dir=spill_dir,
        )

    def to_json_dict(self) -> Dict[str, object]:
        return {
            "config": self.config.to_json_dict(),
            "cost": self.cost.to_json_dict(),
            "mode": self.mode,
            "query_text": self.query_text,
            "max_resident_rows": self.max_resident_rows,
            "max_assembly_bytes": self.max_assembly_bytes,
        }

    @classmethod
    def from_json_dict(cls, d: Dict[str, object]) -> "ExtractionPlan":
        return cls(
            config=PlanConfig.from_json_dict(d["config"]),
            cost=PlanCost.from_json_dict(d["cost"]),
            mode=d["mode"],
            query_text=d["query_text"],
            max_resident_rows=d["max_resident_rows"],
            max_assembly_bytes=d["max_assembly_bytes"],
        )


@dataclasses.dataclass(frozen=True)
class PrunedPlan:
    config: PlanConfig
    reason: str
    predicted_peak_bytes: Optional[int] = None

    def to_json_dict(self) -> Dict[str, object]:
        return {
            "config": self.config.to_json_dict(),
            "reason": self.reason,
            "predicted_peak_bytes": self.predicted_peak_bytes,
        }

    @classmethod
    def from_json_dict(cls, d: Dict[str, object]) -> "PrunedPlan":
        return cls(
            config=PlanConfig.from_json_dict(d["config"]),
            reason=d["reason"],
            predicted_peak_bytes=d["predicted_peak_bytes"],
        )


@dataclasses.dataclass(frozen=True)
class PlanReport:
    """The optimizer's full answer: chosen plan, ranked feasible
    alternatives, and every pruned config with the reason it lost."""

    chosen: ExtractionPlan
    ranked: Tuple[Tuple[PlanConfig, PlanCost], ...]
    pruned: Tuple[PrunedPlan, ...]
    rules: Tuple[str, ...]
    n_enumerated: int
    budget_rows: Optional[int] = None
    budget_bytes: Optional[int] = None

    def to_json_dict(self) -> Dict[str, object]:
        return {
            "version": 1,
            "chosen": self.chosen.to_json_dict(),
            "ranked": [
                {"config": c.to_json_dict(), "cost": k.to_json_dict()}
                for c, k in self.ranked
            ],
            "pruned": [p.to_json_dict() for p in self.pruned],
            "rules": list(self.rules),
            "n_enumerated": self.n_enumerated,
            "budget_rows": self.budget_rows,
            "budget_bytes": self.budget_bytes,
        }

    def to_json(self) -> str:
        return json.dumps(self.to_json_dict(), sort_keys=True, indent=1)

    @classmethod
    def from_json_dict(cls, d: Dict[str, object]) -> "PlanReport":
        if d.get("version") != 1:
            raise ValueError(f"unknown plan-report version: {d.get('version')!r}")
        return cls(
            chosen=ExtractionPlan.from_json_dict(d["chosen"]),
            ranked=tuple(
                (
                    PlanConfig.from_json_dict(r["config"]),
                    PlanCost.from_json_dict(r["cost"]),
                )
                for r in d["ranked"]
            ),
            pruned=tuple(
                PrunedPlan.from_json_dict(p) for p in d["pruned"]
            ),
            rules=tuple(d["rules"]),
            n_enumerated=d["n_enumerated"],
            budget_rows=d["budget_rows"],
            budget_bytes=d["budget_bytes"],
        )

    @classmethod
    def from_json(cls, text: str) -> "PlanReport":
        return cls.from_json_dict(json.loads(text))

    def render(self) -> str:
        """Markdown report through the launch-layer renderer."""
        from ..launch.report import render_plan_report

        return render_plan_report(self.to_json_dict())


def _crossover_prefers_xla(crossover) -> bool:
    """True when every measured cell of the table says XLA wins — the
    fused Pallas epilogue then stands down at dispatch, so enumerating
    fused configs would just mispredict."""
    entries = getattr(crossover, "entries", ())
    if not entries:
        return False
    return all(entry.backend == "xla" for _, entry in entries)


def plan(
    catalog: Catalog,
    dsl_text: Union[str, ExtractionQuery],
    *,
    budget: Optional[ExtractionBudget] = None,
    mode: str = "auto",
    throughputs: Optional[Throughputs] = None,
    crossover=None,
    n_shards_candidates: Sequence[int] = (1, 2, 4, 8),
    merge_arities: Sequence[int] = (2, 4),
    pack_methods: Sequence[str] = ("reduceat", "scatter"),
) -> PlanReport:
    """Enumerate, prune, rank; return the full :class:`PlanReport`.

    Pruning invariants (DESIGN.md §12):

    * hash partitioning is enumerated but always pruned — the shard merge
      relies on contiguous-row shards to reproduce the unsharded output
      order, so a hash partition would break byte-identity;
    * a config whose *sound* peak bound violates the caller's budget is
      pruned before costing — so a plan this function returns never
      raises :class:`~repro.core.planner.ExtractionBudgetError`;
    * spilling with one shard is skipped (one record, nothing to bound);
    * fused-correction configs are pruned when a measured
      ``CrossoverTable`` says XLA wins everywhere (the fused Pallas
      epilogue stands down at dispatch, so the knob cannot pay off).
    """
    text = dsl_text if isinstance(dsl_text, str) else None
    query = parse(dsl_text) if isinstance(dsl_text, str) else dsl_text
    tp = throughputs or Throughputs()
    profile = profile_query(catalog, query, mode=mode)
    budget_rows = budget.max_resident_rows if budget is not None else None
    budget_bytes = budget.max_assembly_bytes if budget is not None else None
    fused_stands_down = crossover is not None and _crossover_prefers_xla(
        crossover
    )

    feasible: List[Tuple[PlanConfig, PlanCost]] = []
    pruned: List[PrunedPlan] = []
    n_enumerated = 0
    for n in n_shards_candidates:
        base_cfg = PlanConfig(n_shards=n)
        # hash partitioning: enumerated, never feasible (see docstring)
        if n > 1:
            n_enumerated += 1
            pruned.append(PrunedPlan(
                config=dataclasses.replace(base_cfg, partition="hash"),
                reason=(
                    "hash partitioning breaks the order-preserving shard "
                    "merge (DESIGN.md §7 byte-identity invariant); only "
                    "contiguous row shards reproduce the unsharded output"
                ),
            ))
        rows_bound = peak_resident_rows_bound(profile, n)
        transient_bound = peak_transient_bytes_bound(profile, n)
        no_spill_peak, spill_single = assembly_account_bounds(profile, n)
        spill_options: List[Tuple[bool, int]] = [(False, merge_arities[0])]
        if n > 1:
            spill_options += [(True, a) for a in merge_arities]
        for spill, arity in spill_options:
            cfg0 = dataclasses.replace(
                base_cfg, spill=spill, merge_arity=arity
            )
            assembly_bound = spill_single if spill else no_spill_peak
            n_enumerated += 1
            if budget_rows is not None and rows_bound > budget_rows:
                pruned.append(PrunedPlan(
                    config=cfg0,
                    reason=(
                        f"predicted peak resident rows {rows_bound} > "
                        f"max_resident_rows={budget_rows}"
                    ),
                    predicted_peak_bytes=transient_bound + assembly_bound,
                ))
                continue
            if budget_bytes is not None and assembly_bound > budget_bytes:
                why = "single spill charge" if spill else "resident assembly"
                pruned.append(PrunedPlan(
                    config=cfg0,
                    reason=(
                        f"predicted {why} {assembly_bound} bytes > "
                        f"max_assembly_bytes={budget_bytes}"
                    ),
                    predicted_peak_bytes=transient_bound + assembly_bound,
                ))
                continue
            for pm in pack_methods:
                for fuse in (True, False):
                    cfg = dataclasses.replace(
                        cfg0, pack_method=pm, fuse_correction=fuse
                    )
                    if fuse and fused_stands_down:
                        n_enumerated += 1
                        pruned.append(PrunedPlan(
                            config=cfg,
                            reason=(
                                "measured CrossoverTable prefers XLA in "
                                "every cell: the fused Pallas epilogue "
                                "stands down at dispatch"
                            ),
                        ))
                        continue
                    n_enumerated += 1
                    feasible.append((cfg, plan_cost(profile, cfg, tp)))

    if not feasible:
        detail = "; ".join(
            f"{p.config.n_shards}-shard "
            f"{'spill' if p.config.spill else 'no-spill'}: {p.reason}"
            for p in pruned[:4]
        )
        raise ValueError(
            f"no feasible extraction plan under the budget ({detail})"
        )

    feasible.sort(key=lambda t: (t[1].wall_s, t[0]))
    chosen_cfg, chosen_cost = feasible[0]
    chosen = ExtractionPlan(
        config=chosen_cfg,
        cost=chosen_cost,
        mode=mode,
        query_text=text if text is not None else "",
        max_resident_rows=budget_rows,
        max_assembly_bytes=budget_bytes,
    )
    rules = tuple(rp.describe for rp in profile.edge_rules)
    return PlanReport(
        chosen=chosen,
        ranked=tuple(feasible),
        pruned=tuple(pruned),
        rules=rules,
        n_enumerated=n_enumerated,
        budget_rows=budget_rows,
        budget_bytes=budget_bytes,
    )


# ---------------------------------------------------------------------------
# Device-representation costs (advisor routing, DESIGN.md §12)
# ---------------------------------------------------------------------------

def device_representation_costs(
    expansion_ratio: float,
    duplication_ratio: float,
    crossover,
    n_src: int,
    n_features: int = 128,
) -> Optional[Dict[str, float]]:
    """Relative device cost (µs per propagation pass) of DEDUP-C vs EXP
    from a measured :class:`~repro.kernels.autotune.CrossoverTable` cell.

    DEDUP-C runs the condensed SpMM on the measured-faster backend plus a
    correction pass over the duplicate planes (XLA, scaled by the
    duplication ratio); EXP runs the XLA segment path over the expanded
    edge multiset (scaled by the expansion ratio).  A measured-slower
    Pallas cell removes DEDUP-C's kernel advantage, which can flip the
    recommendation back to EXP for mildly-expanding graphs.  Returns None
    when the table has no measurement for this op."""
    if crossover is None:
        return None
    entry = crossover.lookup("sum", n_src, n_features)
    if entry is None:
        return None
    xla = float(entry.xla_us)
    pallas = float(entry.pallas_us)
    return {
        "DEDUP-C": min(pallas, xla) + xla * max(duplication_ratio, 0.0),
        "EXP": xla * max(expansion_ratio, 1.0),
    }
