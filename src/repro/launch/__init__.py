"""Launchers: production mesh, multi-pod dry-run, roofline analysis,
train/serve entry points, elastic orchestrator."""
