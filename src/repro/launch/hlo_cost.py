"""Loop-aware HLO cost analysis (flops / HBM bytes / collective bytes).

XLA's ``compiled.cost_analysis()`` counts each while-loop *body once* —
useless for scan-over-layers / gradient-accumulation programs where
>97% of work lives inside loops (verified empirically; see
EXPERIMENTS.md §Roofline methodology).  This module walks the compiled
HLO text with a real call graph:

* ``while`` bodies are multiplied by their trip count, recovered from the
  loop condition's integer constant (jax scan/fori conditions are
  ``lt(counter, CONST)``; dynamic bounds fall back to 1 with a warning);
* ``fusion``/``call`` instructions recurse into their called computation
  for FLOPs; HBM bytes are counted at fusion *boundaries* (operands +
  results — fusion internals live in registers/VMEM, which makes this a
  closer HBM-traffic model than XLA's per-op "bytes accessed");
* ``dot`` FLOPs = 2 x batch x M x N x K from dot_dimension_numbers;
  elementwise ops count one FLOP per output element;
* collective operand bytes are split ICI vs cross-pod DCI by decoding
  ``replica_groups`` (iota and explicit formats).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

import numpy as np

__all__ = ["analyze_hlo", "HloCost"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3b11fnuz": 1, "token": 0, "s2": 1, "u2": 1,
}

_COLLECTIVES = {
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "all-reduce-start", "all-gather-start",
    "collective-permute-start",
}

_SKIP_BYTES_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "copy-start", "copy-done",
    "all-reduce-done", "all-gather-done", "collective-permute-done",
    "opt-barrier",
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->\s*.*\{\s*$")
_CONST_RE = re.compile(r"=\s*[su]\d+\[\]\s+constant\((\d+)\)")


def _shape_dims(type_str: str) -> Tuple[str, List[int]]:
    m = _SHAPE_RE.match(type_str)
    if not m:
        return "", []
    dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
    return m.group(1), dims


def _type_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _elements(type_str: str) -> int:
    m = _SHAPE_RE.match(type_str)
    if not m:
        return 0
    n = 1
    if m.group(2):
        for d in m.group(2).split(","):
            n *= int(d)
    return n


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    op: str
    operands: List[str]
    attrs: str


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    ici_bytes: float = 0.0
    dci_bytes: float = 0.0
    n_collectives: float = 0.0
    by_collective: Dict[str, float] = dataclasses.field(default_factory=dict)
    warnings: List[str] = dataclasses.field(default_factory=list)

    def scaled(self, k: float) -> "HloCost":
        return HloCost(
            self.flops * k, self.bytes * k, self.ici_bytes * k,
            self.dci_bytes * k, self.n_collectives * k,
            {o: b * k for o, b in self.by_collective.items()},
            list(self.warnings),
        )

    def add(self, other: "HloCost") -> None:
        self.flops += other.flops
        self.bytes += other.bytes
        self.ici_bytes += other.ici_bytes
        self.dci_bytes += other.dci_bytes
        self.n_collectives += other.n_collectives
        for o, b in other.by_collective.items():
            self.by_collective[o] = self.by_collective.get(o, 0.0) + b
        for w in other.warnings:
            if w not in self.warnings:
                self.warnings.append(w)


def _split_instr(line: str) -> Optional[Instr]:
    line = line.strip()
    if line.startswith("ROOT "):
        line = line[5:]
    m = re.match(r"^%?([\w.\-]+)\s*=\s*(.*)$", line)
    if not m:
        return None
    name, rhs = m.group(1), m.group(2)
    # type: either a tuple type "(...)" or "dtype[dims]{layout}"
    if rhs.startswith("("):
        depth = 0
        for i, ch in enumerate(rhs):
            depth += ch == "("
            depth -= ch == ")"
            if depth == 0:
                type_str, rest = rhs[: i + 1], rhs[i + 1 :].strip()
                break
        else:
            return None
    else:
        sp = rhs.find(" ")
        if sp < 0:
            return None
        type_str, rest = rhs[:sp], rhs[sp + 1 :]
    om = re.match(r"^([\w\-]+)\(", rest)
    if not om:
        return None
    op = om.group(1)
    depth = 0
    start = om.end() - 1
    for i in range(start, len(rest)):
        depth += rest[i] == "("
        depth -= rest[i] == ")"
        if depth == 0:
            operand_str = rest[start + 1 : i]
            attrs = rest[i + 1 :]
            break
    else:
        return None
    operands = re.findall(r"%([\w.\-]+)", operand_str)
    return Instr(name, type_str, op, operands, attrs)


def _parse_computations(text: str) -> Tuple[Dict[str, List[Instr]], Optional[str]]:
    comps: Dict[str, List[Instr]] = {}
    entry: Optional[str] = None
    current: Optional[str] = None
    for line in text.splitlines():
        if current is None:
            m = _COMP_HDR.match(line.strip())
            if m:
                current = m.group(2)
                comps[current] = []
                if m.group(1):
                    entry = current
            continue
        if line.strip() == "}":
            current = None
            continue
        ins = _split_instr(line)
        if ins is not None:
            comps[current].append(ins)
    return comps, entry


_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?"
)


def _decode_groups(attrs: str) -> Optional[np.ndarray]:
    m = _IOTA_RE.search(attrs)
    if m:
        g, s = int(m.group(1)), int(m.group(2))
        dims = [int(d) for d in m.group(3).split(",")]
        ids = np.arange(int(np.prod(dims))).reshape(dims)
        if m.group(4):
            ids = ids.transpose([int(p) for p in m.group(4).split(",")])
        return ids.reshape(g, s)
    m = re.search(r"replica_groups=\{(.*?)\}\}", attrs)
    if m:
        rows = [
            [int(x) for x in grp.replace(" ", "").split(",") if x]
            for grp in re.findall(r"\{([\d, ]*)\}", m.group(1) + "}")
            if grp.strip()
        ]
        if rows:
            width = max(len(r) for r in rows)
            return np.array([r + r[-1:] * (width - len(r)) for r in rows])
    return None


def _dot_flops(ins: Instr, table: Dict[str, str]) -> float:
    lhs_t = table.get(ins.operands[0], "")
    rhs_t = table.get(ins.operands[1], "") if len(ins.operands) > 1 else ""
    _, lhs = _shape_dims(lhs_t)
    _, rhs = _shape_dims(rhs_t)
    if not lhs or not rhs:
        return 2.0 * _elements(ins.type_str)  # fallback

    def dims_of(key):
        m = re.search(key + r"=\{([\d,]*)\}", ins.attrs)
        return [int(d) for d in m.group(1).split(",")] if m and m.group(1) else []

    rc = dims_of("rhs_contracting_dims")
    rb = dims_of("rhs_batch_dims")
    n_free_rhs = 1
    for i, d in enumerate(rhs):
        if i not in rc and i not in rb:
            n_free_rhs *= d
    lhs_prod = 1
    for d in lhs:
        lhs_prod *= d
    return 2.0 * lhs_prod * n_free_rhs


def analyze_hlo(text: str, pod_size: int = 256, debug: bool = False) -> HloCost:
    comps, entry = _parse_computations(text)
    debug_log: List[str] = []
    if entry is None:
        entry = max(comps, key=lambda c: len(comps[c])) if comps else None
        if entry is None:
            return HloCost(warnings=["no computations parsed"])

    # integer constants per computation (for while trip counts)
    cond_consts: Dict[str, List[int]] = {c: [] for c in comps}
    current = None
    for line in text.splitlines():
        m = _COMP_HDR.match(line.strip())
        if m:
            current = m.group(2)
            continue
        if line.strip() == "}":
            current = None
            continue
        if current is not None:
            cm = re.search(r"=\s*[su]\d+\[\]\s+constant\((\d+)\)", line)
            if cm:
                cond_consts[current].append(int(cm.group(1)))

    tables: Dict[str, Dict[str, str]] = {
        cname: {i.name: i.type_str for i in instrs}
        for cname, instrs in comps.items()
    }
    # producer map per computation (for collective dtype normalization)
    _producers: Dict[str, Dict[str, Instr]] = {
        cname: {i.name: i for i in instrs} for cname, instrs in comps.items()
    }

    memo: Dict[Tuple[str, bool], HloCost] = {}

    def called_comp(ins: Instr) -> Optional[str]:
        m = re.search(r"calls=%?([\w.\-]+)", ins.attrs)
        if m:
            return m.group(1)
        return None

    # Sliced-read ops: true HBM traffic is the slice, not the (possibly
    # layer-stacked) full operand — critical for scan-over-layers programs
    # where stacked weights are dynamic-sliced every iteration.
    _SLICING = {"dynamic-slice", "slice", "gather"}

    def _effective_operand_bytes(ins: Instr, table: Dict[str, str]) -> float:
        op = ins.op
        if op in _SLICING:
            return float(_type_bytes(ins.type_str))  # read == result size
        if op == "dynamic-update-slice":
            upd = table.get(ins.operands[1], "") if len(ins.operands) > 1 else ""
            return float(_type_bytes(upd))           # read update only
        if op == "scatter":
            upd = table.get(ins.operands[-1], "") if ins.operands else ""
            return 2.0 * _type_bytes(upd)
        if op == "broadcast":
            return float(_type_bytes(table.get(ins.operands[0], ""))) if ins.operands else 0.0
        if op == "copy":
            # loop-boundary aliasing copies are elided by buffer donation
            # on TPU; count the write side only (1x, not read+write)
            return 0.0
        return float(sum(_type_bytes(table.get(o, "")) for o in ins.operands))

    # Per-fusion-parameter effective bytes: if a fusion parameter is
    # consumed only by slicing ops inside the callee, the fusion reads the
    # slices, not the whole array (the scan weight-stack pattern).
    _fusion_param_cache: Dict[str, Dict[int, Optional[float]]] = {}

    def _fusion_param_bytes(callee: str) -> Dict[int, Optional[float]]:
        if callee in _fusion_param_cache:
            return _fusion_param_cache[callee]
        instrs = comps.get(callee, [])
        params: Dict[str, int] = {}
        for sub in instrs:
            if sub.op == "parameter":
                m = re.match(r"^(\d+)", sub.attrs.strip(", ")) if sub.attrs else None
                idx = int(m.group(1)) if m else len(params)
                # parameter(N): N sits in the operand parens, recover it
                params[sub.name] = idx
        # parameter index lives inside the parens: parameter(0) — our
        # parser put it nowhere, so re-derive by order of appearance.
        ordered = [s.name for s in instrs if s.op == "parameter"]
        params = {n: i for i, n in enumerate(ordered)}
        uses: Dict[str, List[Instr]] = {n: [] for n in params}
        for sub in instrs:
            for o in sub.operands:
                if o in uses:
                    uses[o].append(sub)
        out: Dict[int, Optional[float]] = {}
        for pname, idx in params.items():
            us = uses[pname]
            if us and all(
                u.op in _SLICING and u.operands and u.operands[0] == pname
                for u in us
            ):
                out[idx] = float(sum(_type_bytes(u.type_str) for u in us))
            else:
                out[idx] = None  # full operand
        _fusion_param_cache[callee] = out
        return out

    def while_parts(ins: Instr) -> Tuple[Optional[str], Optional[str]]:
        cm = re.search(r"condition=%?([\w.\-]+)", ins.attrs)
        bm = re.search(r"body=%?([\w.\-]+)", ins.attrs)
        return (cm.group(1) if cm else None, bm.group(1) if bm else None)

    def comp_cost(name: str, count_bytes: bool) -> HloCost:
        key = (name, count_bytes)
        if key in memo:
            return memo[key]
        memo[key] = HloCost()  # cycle guard
        total = HloCost()
        table = tables.get(name, {})
        for ins in comps.get(name, []):
            op = ins.op
            if op == "while":
                cond, body = while_parts(ins)
                trips = 1.0
                if cond is not None:
                    consts = cond_consts.get(cond, [])
                    # also look one level into fusions called by the cond
                    for sub in comps.get(cond, []):
                        cc = called_comp(sub)
                        if cc:
                            consts = consts + cond_consts.get(cc, [])
                    if consts:
                        trips = float(max(consts))
                    else:
                        total.warnings.append(f"dynamic trip count in {name}")
                if body is not None:
                    bc = comp_cost(body, count_bytes)
                    if debug:
                        debug_log.append(
                            f"while body={body} trips={trips:.0f} "
                            f"flops={bc.flops:.3e} bytes={bc.bytes:.3e}"
                        )
                    total.add(bc.scaled(trips))
                if cond is not None:
                    total.add(comp_cost(cond, False).scaled(trips))
                continue
            if op in ("fusion", "call", "async-start"):
                callee = called_comp(ins)
                if callee:
                    inner = comp_cost(callee, False)
                    total.flops += inner.flops
                    total.ici_bytes += inner.ici_bytes
                    total.dci_bytes += inner.dci_bytes
                    total.n_collectives += inner.n_collectives
                    for o, b in inner.by_collective.items():
                        total.by_collective[o] = total.by_collective.get(o, 0) + b
                if count_bytes:
                    nbytes = float(_type_bytes(ins.type_str))
                    pb = _fusion_param_bytes(callee) if callee else {}
                    for i, o in enumerate(ins.operands):
                        eff = pb.get(i)
                        nbytes += (
                            eff if eff is not None
                            else _type_bytes(table.get(o, ""))
                        )
                    total.bytes += nbytes
                continue
            if op == "conditional":
                for m in re.finditer(r"(?:true_computation|false_computation|branch_computations=\{)[^,}]*%?([\w.\-]+)", ins.attrs):
                    total.add(comp_cost(m.group(1), count_bytes))
                continue
            if op in _COLLECTIVES:
                nbytes = 0
                for o in ins.operands:
                    b = _type_bytes(table.get(o, ""))
                    # CPU float-normalization: a collective whose operand
                    # was upcast bf16->f32 moves bf16 on TPU — halve it.
                    prod = _producers.get(name, {}).get(o)
                    if (
                        prod is not None
                        and prod.op == "convert"
                        and table.get(o, "").startswith("f32")
                        and prod.operands
                        and table.get(prod.operands[0], "").startswith("bf16")
                    ):
                        b //= 2
                    nbytes += b
                if nbytes == 0:
                    nbytes = _type_bytes(ins.type_str)
                groups = _decode_groups(ins.attrs)
                crosses = False
                if groups is not None and groups.size:
                    crosses = bool(
                        ((groups // pod_size).max(axis=1)
                         != (groups // pod_size).min(axis=1)).any()
                    )
                if crosses:
                    total.dci_bytes += nbytes
                else:
                    total.ici_bytes += nbytes
                total.n_collectives += 1
                base = op.replace("-start", "")
                total.by_collective[base] = total.by_collective.get(base, 0) + nbytes
                if count_bytes:
                    total.bytes += nbytes + _type_bytes(ins.type_str)
                continue
            # ordinary instruction
            if op == "convert":
                # CPU float-normalization artifact: XLA:CPU upcasts bf16
                # compute to f32, inserting convert round-trips that do not
                # exist on TPU (native bf16).  Costed at zero; the residual
                # f32 fusion-boundary buffers still count (documented as a
                # <=2x pessimism for bf16-heavy cells in EXPERIMENTS.md).
                continue
            if op == "dot":
                total.flops += _dot_flops(ins, table)
            elif op == "convolution":
                total.flops += 2.0 * _elements(ins.type_str)
                total.warnings.append("convolution flops underestimated")
            elif op not in _SKIP_BYTES_OPS:
                total.flops += float(_elements(ins.type_str))
            if count_bytes and op not in _SKIP_BYTES_OPS and op != "fusion":
                if op == "dynamic-update-slice" and len(ins.operands) > 1:
                    res_bytes = float(
                        _type_bytes(table.get(ins.operands[1], ""))
                    )  # writes only the updated slice (buffer is aliased)
                else:
                    res_bytes = float(_type_bytes(ins.type_str))
                total.bytes += res_bytes + _effective_operand_bytes(ins, table)
        memo[key] = total
        return total

    result = comp_cost(entry, True)
    if debug:
        result.warnings.extend(debug_log)
    return result
