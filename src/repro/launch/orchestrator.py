"""Elastic training orchestrator: heartbeats, stragglers, failure recovery.

On a real cluster each worker process runs this supervisor around the
train loop; here the control plane is engineered for-real (state machine,
deadlines, re-mesh decisions, checkpoint discipline) and exercised in
tests/examples with simulated failures — the TPU runtime layer is the
only stub (CPU container).

Recovery contract:
* every worker heartbeats (step, wall_time) after each step;
* a worker missing ``miss_limit`` deadlines is declared dead ->
  surviving devices re-mesh via ``largest_feasible_mesh`` and training
  resumes from the last committed checkpoint (step-atomic, so at-most-one
  step of lost work per failure);
* stragglers (step time > ``straggler_factor`` x running p50) trigger a
  flag; policy hook decides (ignore / shrink / evict);
* checkpoint cadence adapts: on flagged instability, checkpoint interval
  halves (cheap insurance while a node is wobbling).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from .mesh import largest_feasible_mesh

__all__ = ["WorkerState", "Heartbeat", "Supervisor"]


@dataclasses.dataclass
class Heartbeat:
    worker: int
    step: int
    wall_time: float


@dataclasses.dataclass
class WorkerState:
    worker: int
    last_step: int = -1
    last_seen: float = 0.0
    missed: int = 0
    alive: bool = True
    straggler: bool = False
    step_times: List[float] = dataclasses.field(default_factory=list)


class Supervisor:
    """Tracks worker health and drives elastic decisions."""

    def __init__(
        self,
        n_workers: int,
        heartbeat_deadline: float = 30.0,
        miss_limit: int = 3,
        straggler_factor: float = 2.0,
        model_parallel: int = 16,
        checkpoint_interval: int = 100,
    ):
        self.workers: Dict[int, WorkerState] = {
            i: WorkerState(i) for i in range(n_workers)
        }
        self.deadline = heartbeat_deadline
        self.miss_limit = miss_limit
        self.straggler_factor = straggler_factor
        self.model_parallel = model_parallel
        self.base_checkpoint_interval = checkpoint_interval
        self.checkpoint_interval = checkpoint_interval
        self.events: List[Tuple[str, int]] = []

    # -- ingestion -------------------------------------------------------------
    def heartbeat(self, hb: Heartbeat) -> None:
        w = self.workers[hb.worker]
        if not w.alive:
            return
        if w.last_seen:
            w.step_times.append(hb.wall_time - w.last_seen)
            w.step_times = w.step_times[-50:]
        w.last_seen = hb.wall_time
        w.last_step = hb.step
        w.missed = 0
        self._update_straggler(w)

    def check_deadlines(self, now: float) -> None:
        for w in self.workers.values():
            if not w.alive or not w.last_seen:
                continue
            if now - w.last_seen > self.deadline:
                w.missed += 1
                w.last_seen = now
                if w.missed >= self.miss_limit:
                    w.alive = False
                    self.events.append(("dead", w.worker))

    def _update_straggler(self, w: WorkerState) -> None:
        times = [
            t for ws in self.workers.values() if ws.alive for t in ws.step_times
        ]
        if len(times) < 8 or not w.step_times:
            return
        p50 = float(np.percentile(times, 50))
        was = w.straggler
        w.straggler = w.step_times[-1] > self.straggler_factor * p50
        if w.straggler and not was:
            self.events.append(("straggler", w.worker))
            # adaptive checkpoint cadence while unstable
            self.checkpoint_interval = max(
                self.base_checkpoint_interval // 2, 1
            )
        elif not any(ws.straggler for ws in self.workers.values()):
            self.checkpoint_interval = self.base_checkpoint_interval

    # -- decisions ---------------------------------------------------------------
    @property
    def alive_workers(self) -> List[int]:
        return [w.worker for w in self.workers.values() if w.alive]

    def needs_remesh(self) -> bool:
        return len(self.alive_workers) < len(self.workers)

    def remesh_plan(self, devices_per_worker: int) -> Tuple[Tuple[int, ...], Tuple[str, ...]]:
        """Largest feasible (data, model) mesh on surviving devices."""
        n = len(self.alive_workers) * devices_per_worker
        return largest_feasible_mesh(n, self.model_parallel)

    def should_checkpoint(self, step: int) -> bool:
        return step > 0 and step % self.checkpoint_interval == 0


def run_with_recovery(
    train_once: Callable[[int, Optional[int]], int],
    supervisor: Supervisor,
    max_restarts: int = 3,
) -> int:
    """Driver: call ``train_once(restart_idx, resume_step)``; on failure
    (exception), re-mesh and resume from the last committed step.

    ``train_once`` returns the final step reached; raises to simulate/
    propagate node failure.
    """
    resume: Optional[int] = None
    for attempt in range(max_restarts + 1):
        try:
            return train_once(attempt, resume)
        except RuntimeError as e:  # node failure class
            supervisor.events.append(("restart", attempt))
            resume = None  # train_once rediscovers from CheckpointManager
            if attempt == max_restarts:
                raise
    raise AssertionError("unreachable")
