"""Roofline analysis from compiled dry-run artifacts (no hardware needed).

Three terms per (arch x shape x mesh), in seconds (TPU v5e constants):

    compute    = HLO_flops_per_device / 197e12
    memory     = HLO_bytes_per_device / 819e9
    collective = ici_bytes / 45e9  +  dci_bytes / 25e9

``cost_analysis()`` is per-device under SPMD (verified empirically), so no
chip division is applied.  Collective bytes are parsed from the compiled
HLO: every all-reduce / all-gather / reduce-scatter / all-to-all /
collective-permute, summing *operand* sizes (per the brief).  Cross-pod
(DCI) traffic is detected by decoding iota-format replica_groups
(``[G,S]<=[dims]T(perm)``) and checking whether any group spans a pod
boundary (device id // 256).
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

import numpy as np

from .mesh import DCI_BW, HBM_BW, ICI_BW, PEAK_FLOPS_BF16

__all__ = [
    "collective_bytes", "roofline_terms", "model_flops", "RooflineReport",
    "HOST_MEM_BW", "HOST_DISK_BW",
]

# Host-side throughput floors used by the extraction cost model
# (repro.core.cost).  Same role as the TPU constants above, but for the
# numpy extraction pipeline: sequential copy/scan bandwidth of one host
# core, and the effective write+read bandwidth of the spill directory.
# Deliberately conservative — the planner treats them as defaults that a
# measured Throughputs overrides, exactly like a measured CrossoverTable
# overrides the streamed-footprint formula in kernel dispatch.
HOST_MEM_BW = 8e9       # bytes/s: host-side memcpy/scan floor
HOST_DISK_BW = 0.8e9    # bytes/s: spill-record write + read-back

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_TYPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+)$")
_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?"
)
_LIST_RE = re.compile(r"replica_groups=\{(\{[\d,\{\} ]*\})\}")


def _shape_bytes(type_str: str) -> int:
    """Bytes of one HLO type string (handles tuples by summing)."""
    total = 0
    for m in _TYPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _decode_groups(line: str) -> Optional[np.ndarray]:
    """replica_groups -> (G, S) array of device ids, or None."""
    m = _IOTA_RE.search(line)
    if m:
        g, s = int(m.group(1)), int(m.group(2))
        dims = [int(d) for d in m.group(3).split(",")]
        ids = np.arange(int(np.prod(dims))).reshape(dims)
        if m.group(4):
            perm = [int(p) for p in m.group(4).split(",")]
            ids = ids.transpose(perm)
        return ids.reshape(g, s)
    m = _LIST_RE.search(line)
    if m:
        groups = re.findall(r"\{([\d, ]+)\}", m.group(1) + "}")
        rows = [[int(x) for x in g.replace(" ", "").split(",") if x] for g in groups]
        width = max(len(r) for r in rows)
        return np.array([r + r[-1:] * (width - len(r)) for r in rows])
    return None


def collective_bytes(hlo_text: str, pod_size: int = 256) -> Dict[str, float]:
    """Per-device collective operand bytes, split ICI vs cross-pod DCI."""
    # instruction name -> result type string (operand lookup table)
    types: Dict[str, str] = {}
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        rhs = m.group(2)
        # type prefix of rhs up to the op name
        tm = re.match(r"((?:\([^)]*\))|(?:\w+\[[^\]]*\]\S*))\s", rhs)
        if tm:
            types[m.group(1)] = tm.group(1)

    out = {"ici_bytes": 0.0, "dci_bytes": 0.0, "n_collectives": 0}
    per_op: Dict[str, float] = {}
    for line in hlo_text.splitlines():
        opm = re.search(r"=\s*(?:\([^)]*\)|\S+)\s+(" + "|".join(_COLLECTIVES) + r")\(([^)]*)\)", line)
        if not opm:
            continue
        op_name = opm.group(1)
        if f" {op_name}(" not in line and f"{op_name}(" not in line:
            continue
        # operand bytes: inline-typed operands or lookup by name
        operand_str = opm.group(2)
        nbytes = _shape_bytes(operand_str)
        if nbytes == 0:
            for ref in re.findall(r"%([\w.\-]+)", operand_str):
                nbytes += _shape_bytes(types.get(ref, ""))
        groups = _decode_groups(line)
        crosses_pod = False
        if groups is not None and groups.size:
            crosses_pod = bool(((groups // pod_size).max(axis=1)
                                != (groups // pod_size).min(axis=1)).any())
        key = "dci_bytes" if crosses_pod else "ici_bytes"
        out[key] += nbytes
        out["n_collectives"] += 1
        per_op[op_name] = per_op.get(op_name, 0.0) + nbytes
    out["by_op"] = per_op
    return out


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    flops_per_device: float
    bytes_per_device: float
    ici_bytes: float
    dci_bytes: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    useful_ratio: float
    memory_stats: Dict[str, float]
    n_collectives: int = 0
    by_op: Dict[str, float] = dataclasses.field(default_factory=dict)

    def to_json(self) -> Dict:
        return dataclasses.asdict(self)


def roofline_terms(
    arch: str,
    shape: str,
    mesh_name: str,
    n_chips: int,
    cost: Dict[str, float],
    hlo_text: str,
    memory_stats: Dict[str, float],
    model_total_flops: float,
) -> RooflineReport:
    """Roofline from the loop-aware analyzer (XLA cost_analysis counts
    while bodies once; see repro.launch.hlo_cost)."""
    from .hlo_cost import analyze_hlo

    hc = analyze_hlo(hlo_text, pod_size=256)
    flops = hc.flops
    byts = hc.bytes
    compute_s = flops / PEAK_FLOPS_BF16
    memory_s = byts / HBM_BW
    collective_s = hc.ici_bytes / ICI_BW + hc.dci_bytes / DCI_BW
    dominant = max(
        [("compute", compute_s), ("memory", memory_s), ("collective", collective_s)],
        key=lambda kv: kv[1],
    )[0]
    useful = model_total_flops / max(flops * n_chips, 1.0)
    mem = dict(memory_stats)
    mem["xla_flops_per_device"] = float(cost.get("flops", 0.0))
    mem["xla_bytes_per_device"] = float(cost.get("bytes accessed", 0.0))
    return RooflineReport(
        arch=arch,
        shape=shape,
        mesh=mesh_name,
        flops_per_device=flops,
        bytes_per_device=byts,
        ici_bytes=hc.ici_bytes,
        dci_bytes=hc.dci_bytes,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        dominant=dominant,
        model_flops=model_total_flops,
        useful_ratio=useful,
        memory_stats=mem,
        n_collectives=int(hc.n_collectives),
        by_op={k: float(v) for k, v in hc.by_collective.items()},
    )


# ---------------------------------------------------------------------------
# Analytic MODEL_FLOPS per cell (global, not per-device)
# ---------------------------------------------------------------------------

def model_flops(arch: str, shape: str) -> float:
    from ..configs import registry, shapes as shp

    mod = registry.get_arch(arch)
    cfg = mod.CONFIG
    fam = mod.SHAPE_FAMILY
    if fam == "lm":
        s = shp.LM_SHAPES[shape]
        n_active = cfg.n_active_params()
        if s.kind == "train":
            tokens = s.seq_len * s.global_batch
            return 6.0 * n_active * tokens
        if s.kind == "prefill":
            tokens = s.seq_len * s.global_batch
            return 2.0 * n_active * tokens
        # decode: one token per sequence + attention over the KV cache
        hd = cfg.resolved_head_dim
        attn_kv = (
            4.0 * cfg.n_layers * cfg.n_heads * hd * s.seq_len * s.global_batch
        )
        return 2.0 * n_active * s.global_batch + attn_kv
    if fam == "gnn":
        s = shp.GNN_SHAPES[shape]
        h = cfg.d_hidden
        mult = 3.0 if s.kind == "train" else 1.0  # fwd + 2x bwd
        if cfg.kind in ("meshgraphnet", "graphcast"):
            per_layer = 2.0 * (s.raw_edges * 3 * h * h * cfg.mlp_layers
                               + s.raw_nodes * 2 * h * h * cfg.mlp_layers)
            enc = 2.0 * s.raw_nodes * s.d_feat * h + 2.0 * s.raw_edges * 4 * h
            return mult * (cfg.n_layers * per_layer + enc)
        if cfg.kind == "schnet":
            per_block = 2.0 * (s.raw_edges * cfg.n_rbf * h + s.raw_edges * h
                               + s.raw_nodes * 2 * h * h)
            return mult * (cfg.n_layers * per_block + 2.0 * s.raw_nodes * s.d_feat * h)
        if cfg.kind == "dimenet":
            tri = shp.triplet_count(s, cfg.triplet_factor)
            per_block = 2.0 * tri * (cfg.n_bilinear * h * h / max(h, 1) + cfg.n_bilinear * h) \
                + 2.0 * tri * cfg.n_radial * cfg.n_spherical * cfg.n_bilinear \
                + 2.0 * s.raw_edges * 2 * h * h
            return mult * (cfg.n_layers * per_block + 2.0 * s.raw_edges * 3 * h)
    if fam == "recsys":
        s = shp.REC_SHAPES[shape]
        d = cfg.d
        L = cfg.seq_len
        blocks = 2.0 * cfg.n_blocks * (4 * L * d * d + 2 * L * L * d) * s.batch
        if s.kind == "train":
            return 3.0 * (blocks + 2.0 * s.batch * L * d)  # + embedding dots
        if s.kind == "score_all":
            return blocks + 2.0 * s.batch * cfg.n_items * d
        return blocks + 2.0 * s.batch * s.n_candidates * d
    if fam == "graphgen":
        cfg2 = mod.CONFIG
        return 2.0 * (2 * cfg2.n_in_edges + cfg2.n_correction) * cfg2.pagerank_iters
    raise ValueError(fam)
