"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module does not touch JAX device state — smoke tests see 1 CPU device;
only dryrun.py forces 512 host devices via XLA_FLAGS before any import.

Topology: TPU v5e pods of 256 chips in a 16x16 ICI torus; the multi-pod
mesh adds a leading "pod" axis over the (slower) DCI links.  The sharding
rules put only data-parallel traffic (one gradient reduce-scatter per
step, further thinned by gradient accumulation and optional int8
compression) on the pod axis.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import numpy as np

__all__ = ["make_production_mesh", "make_host_mesh", "largest_feasible_mesh"]

# TPU v5e hardware constants used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12       # per chip
HBM_BW = 819e9                 # bytes/s per chip
ICI_BW = 4.5e10                # ~45 GB/s per link direction, 50 quoted
DCI_BW = 2.5e10                # cross-pod (data-center interconnect), est.
HBM_BYTES = 16 * 2**30         # 16 GiB per chip


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """(16, 16) single pod or (2, 16, 16) two pods = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh() -> jax.sharding.Mesh:
    """Whatever this host actually has (smoke tests: 1 CPU device)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


def largest_feasible_mesh(
    n_devices: int, model_parallel: int = 16
) -> Tuple[Tuple[int, ...], Tuple[str, ...]]:
    """Elastic re-mesh after failures: the largest (data, model) grid that
    fits the surviving device count, shrinking data parallelism first
    (orchestrator contract: model-parallel groups are the survival unit).
    """
    if n_devices < 1:
        raise ValueError("no surviving devices to re-mesh")
    model = min(model_parallel, n_devices)
    while n_devices % model:
        model -= 1
    data = n_devices // model
    return (data, model), ("data", "model")
