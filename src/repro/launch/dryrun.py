import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production mesh and record memory/cost/roofline artifacts.

MUST be run as its own process (the two lines above must execute before
any jax import anywhere): ``PYTHONPATH=src python -m repro.launch.dryrun
--arch glm4-9b --shape train_4k --mesh single`` or ``--all``.

Results land in ``results/dryrun/<arch>__<shape>__<mesh>.json`` and are
consumed by EXPERIMENTS.md §Dry-run / §Roofline and benchmarks/roofline.
"""
import argparse
import json
import time
import traceback

import jax

from . import cells as cells_lib
from . import roofline as rl
from .mesh import make_production_mesh
from ..configs import registry
from ..distributed.sharding import use_mesh_rules

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "results", "dryrun")


def run_cell(arch: str, shape: str, mesh_name: str, verbose: bool = True,
             variant=None) -> dict:
    multi = mesh_name == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    n_chips = len(mesh.devices.flatten())
    cell = cells_lib.build_cell(arch, shape, mesh, variant=variant)
    t0 = time.time()
    with use_mesh_rules(mesh, cell.rules):
        jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                         donate_argnums=cell.donate)
        lowered = jitted.lower(*cell.args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):  # older jax returns [dict]
        cost = cost[0] if cost else {}
    mem_stats = {
        "argument_bytes": mem.argument_size_in_bytes,
        "output_bytes": mem.output_size_in_bytes,
        "temp_bytes": mem.temp_size_in_bytes,
        "alias_bytes": mem.alias_size_in_bytes,
        "peak_bytes_per_device": mem.argument_size_in_bytes
        + mem.output_size_in_bytes
        + mem.temp_size_in_bytes
        - mem.alias_size_in_bytes,
    }
    if verbose:
        print(f"[{arch} x {shape} x {mesh_name}] lower {t_lower:.1f}s "
              f"compile {t_compile:.1f}s")
        print("  memory_analysis:", mem)
        print("  cost_analysis: flops/device = %.3e, bytes/device = %.3e"
              % (cost.get("flops", 0), cost.get("bytes accessed", 0)))
    hlo = compiled.as_text()
    report = rl.roofline_terms(
        arch, shape, mesh_name, n_chips, cost, hlo, mem_stats,
        rl.model_flops(arch, shape),
    )
    rec = report.to_json()
    rec.update({
        "n_chips": n_chips,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "ok": True,
    })
    if verbose:
        print(f"  roofline: compute {report.compute_s*1e3:.3f}ms | memory "
              f"{report.memory_s*1e3:.3f}ms | collective {report.collective_s*1e3:.3f}ms "
              f"-> dominant: {report.dominant}; useful_flops_ratio "
              f"{report.useful_ratio:.3f}")
    return rec


def save(rec: dict, arch: str, shape: str, mesh_name: str) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{arch}__{shape}__{mesh_name}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return path


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", help="architecture id (see configs.registry)")
    ap.add_argument("--shape", help="input-shape name for the arch family")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true", help="all 40 assigned cells")
    ap.add_argument("--include-paper", action="store_true",
                    help="also run the graphgen-paper analytics cell")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--variant", default=None,
                    help="optimization variant (e.g. a2a); result files get a suffix")
    args = ap.parse_args()

    targets = []
    if args.all:
        targets = cells_lib.all_cells()
        if args.include_paper:
            targets.append(("graphgen-paper", "pagerank"))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        targets = [(args.arch, args.shape)]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    failures = []
    for arch, shape in targets:
        for mesh_name in meshes:
            tag0 = mesh_name if not args.variant else f"{mesh_name}__{args.variant}"
            out = os.path.join(RESULTS_DIR, f"{arch}__{shape}__{tag0}.json")
            if args.skip_existing and os.path.exists(out):
                with open(out) as f:
                    if json.load(f).get("ok"):
                        print(f"[skip] {arch} x {shape} x {mesh_name}")
                        continue
            try:
                rec = run_cell(arch, shape, mesh_name, variant=args.variant)
            except Exception as e:
                traceback.print_exc()
                rec = {"ok": False, "error": f"{type(e).__name__}: {e}",
                       "arch": arch, "shape": shape, "mesh": mesh_name}
                failures.append((arch, shape, mesh_name))
            tag = mesh_name if not args.variant else f"{mesh_name}__{args.variant}"
            save(rec, arch, shape, tag)
    if failures:
        print("FAILURES:", failures)
        return 1
    print("all dry-run cells OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
