"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from results/dryrun.

    PYTHONPATH=src python -m repro.launch.report [--mesh single]
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List

RESULTS_DIR = os.path.join(
    os.path.dirname(__file__), "..", "..", "..", "results", "dryrun"
)


def load_all() -> List[Dict]:
    out = []
    if not os.path.isdir(RESULTS_DIR):
        return out
    for f in sorted(os.listdir(RESULTS_DIR)):
        if f.endswith(".json"):
            with open(os.path.join(RESULTS_DIR, f)) as fh:
                out.append(json.load(fh))
    return out


def fmt_bytes(b: float) -> str:
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def _fmt_cfg(cfg: Dict) -> str:
    spill = f"spill(arity={cfg['merge_arity']})" if cfg["spill"] else "no-spill"
    fused = "fused" if cfg["fuse_correction"] else "unfused"
    return (
        f"{cfg['n_shards']}-shard {cfg['partition']} {spill} "
        f"pack={cfg['pack_method']} {fused}"
    )


def render_plan_report(doc: Dict) -> str:
    """Markdown for one extraction-plan report (repro.core.cost.PlanReport
    JSON dict): the chosen knobs, predicted vs. available bytes and wall
    time, the top ranked alternatives, and why each pruned plan lost."""
    chosen = doc["chosen"]
    cfg, cost = chosen["config"], chosen["cost"]
    cap = doc.get("budget_bytes")
    avail = fmt_bytes(cap) if cap is not None else "unbounded"
    rows_cap = doc.get("budget_rows")
    rows_avail = str(rows_cap) if rows_cap is not None else "unbounded"
    lines = [
        "## Extraction plan",
        "",
        f"rules: {'; '.join(doc['rules'])}" if doc.get("rules") else "rules: (none)",
        f"configurations enumerated: {doc['n_enumerated']} "
        f"({len(doc['ranked'])} feasible, {len(doc['pruned'])} pruned)",
        "",
        f"**chosen:** {_fmt_cfg(cfg)}",
        "",
        f"- predicted wall time: {cost['wall_s'] * 1e3:.3f} ms",
        f"- predicted peak bytes: {fmt_bytes(cost['peak_bytes'])} "
        f"(assembly account {fmt_bytes(cost['peak_assembly_bytes'])} "
        f"vs available {avail})",
        f"- predicted peak resident rows: {cost['peak_resident_rows']} "
        f"(budget {rows_avail})",
        f"- expected condensed edges: {cost['est_edges']:.0f}",
        "",
        "### Ranked alternatives",
        "",
        "| config | predicted wall | peak bytes | vs chosen |",
        "|---|---|---|---|",
    ]
    for r in doc["ranked"][:4]:
        delta = (r["cost"]["wall_s"] - cost["wall_s"]) * 1e3
        tag = "**chosen**" if r["config"] == cfg else f"+{delta:.3f} ms"
        lines.append(
            "| {c} | {w:.3f} ms | {b} | {t} |".format(
                c=_fmt_cfg(r["config"]), w=r["cost"]["wall_s"] * 1e3,
                b=fmt_bytes(r["cost"]["peak_bytes"]), t=tag,
            )
        )
    lines += ["", "### Pruned plans", ""]
    if doc["pruned"]:
        lines += ["| config | why it lost |", "|---|---|"]
        for p in doc["pruned"][:3]:
            lines.append(f"| {_fmt_cfg(p['config'])} | {p['reason']} |")
        if len(doc["pruned"]) > 3:
            lines.append(f"| ... | {len(doc['pruned']) - 3} more |")
    else:
        lines.append("(none)")
    return "\n".join(lines)


def dryrun_table(recs: List[Dict], mesh: str) -> str:
    rows = [
        "| arch | shape | chips | peak HBM/chip | flops/chip | ICI B/chip | DCI B/chip | lower+compile s |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if not r.get("ok") or r.get("mesh") != mesh:
            continue
        peak = r["memory_stats"]["peak_bytes_per_device"]
        rows.append(
            "| {arch} | {shape} | {chips} | {peak} | {fl:.2e} | {ici} | {dci} | {t:.0f} |".format(
                arch=r["arch"], shape=r["shape"], chips=r["n_chips"],
                peak=fmt_bytes(peak), fl=r["flops_per_device"],
                ici=fmt_bytes(r["ici_bytes"]), dci=fmt_bytes(r["dci_bytes"]),
                t=r.get("lower_s", 0) + r.get("compile_s", 0),
            )
        )
    return "\n".join(rows)


def roofline_table(recs: List[Dict], mesh: str) -> str:
    rows = [
        "| arch | shape | compute s | memory s | collective s | dominant | MODEL_FLOPS | useful ratio |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if not r.get("ok") or r.get("mesh") != mesh:
            continue
        rows.append(
            "| {arch} | {shape} | {c:.4f} | {m:.4f} | {k:.4f} | **{dom}** | {mf:.2e} | {ur:.3f} |".format(
                arch=r["arch"], shape=r["shape"], c=r["compute_s"],
                m=r["memory_s"], k=r["collective_s"], dom=r["dominant"],
                mf=r["model_flops"], ur=r["useful_ratio"],
            )
        )
    return "\n".join(rows)


def summary(recs: List[Dict]) -> str:
    ok = [r for r in recs if r.get("ok")]
    fail = [r for r in recs if not r.get("ok")]
    doms: Dict[str, int] = {}
    for r in ok:
        doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
    lines = [
        f"cells passed: {len(ok)}; failed: {len(fail)}",
        f"dominant-term distribution: {doms}",
    ]
    for r in fail:
        lines.append(f"  FAILED {r.get('arch')}x{r.get('shape')}x{r.get('mesh')}: {r.get('error','')[:80]}")
    return "\n".join(lines)


def render(mesh: str) -> str:
    recs = load_all()
    return "\n".join([
        "## Summary", "", summary(recs), "",
        f"## Dry-run ({mesh} mesh)", "", dryrun_table(recs, mesh), "",
        f"## Roofline ({mesh} mesh)", "", roofline_table(recs, mesh), "",
    ])


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--emit", action="store_true",
                    help="write results/tables_<mesh>.md as well")
    args = ap.parse_args()
    text = render(args.mesh)
    print(text)
    if args.emit:
        out = os.path.join(RESULTS_DIR, "..", f"tables_{args.mesh}.md")
        with open(out, "w") as f:
            f.write(text + "\n")
        print(f"# wrote {os.path.normpath(out)}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
