"""Cell builders: (arch, shape, mesh) -> (fn, ShapeDtypeStruct args, shardings).

One *cell* is an assigned (architecture x input-shape) pair.  The dry-run
jits ``fn`` with the returned in_shardings and lowers it against the
ShapeDtypeStructs — no arrays are ever allocated (the 40 full-size cells
would not fit on one host).

Step lowered per shape kind:
  train   -> train_step(state, batch)     (params + optimizer included)
  prefill -> prefill(params, tokens)      (serve dtype: bf16 params)
  decode  -> decode(params, cache, token)
  score_* -> sasrec scoring functions
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from ..configs import registry, shapes as shp
from ..configs.base import GNNConfig, RecsysConfig, TransformerConfig
from ..distributed.sharding import logical_spec, specs_for_tree, use_mesh_rules
from ..models import gnn, sasrec, transformer
from ..train import optimizer as opt_lib
from ..train import steps

__all__ = [
    "Cell", "build_cell", "all_cells",
    "ReplicaPlacement", "place_serving_replicas",
]

S = jax.ShapeDtypeStruct


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    kind: str
    fn: Callable
    args: Tuple[Any, ...]
    in_shardings: Any
    rules: Dict
    cfg: Any
    flops_note: str = ""
    donate: Tuple[int, ...] = ()   # donated arg indices (state / KV cache)


def _ns(mesh, rules, axes):
    from ..distributed.sharding import _dedup_axes

    # keep-first duplicate resolution (e.g. cache_seq and kv_heads both on
    # 'model' for MHA-style archs: the seq dim wins, heads replicate)
    return NamedSharding(mesh, _dedup_axes(logical_spec(axes, rules, mesh)))


def _replicated_tree(tree, mesh):
    return jax.tree_util.tree_map(
        lambda _: NamedSharding(mesh, PartitionSpec()), tree
    )


def _opt_shardings(opt_struct, param_specs, mesh):
    """Optimizer-state shardings derived from param shardings.

    adamw/sgdm moments mirror params; adafactor's factored r/c drop the
    last / second-to-last axis of the param spec.
    """
    def factored(spec_tree, leaf_dict):
        spec = spec_tree.spec if isinstance(spec_tree, NamedSharding) else spec_tree
        out = {}
        for k in leaf_dict:
            if k == "v":
                out[k] = NamedSharding(mesh, PartitionSpec(*spec))
            elif k == "r":
                out[k] = NamedSharding(mesh, PartitionSpec(*spec[:-1]))
            elif k == "c":
                out[k] = NamedSharding(
                    mesh, PartitionSpec(*(tuple(spec[:-2]) + tuple(spec[-1:])))
                )
        return out

    out = {}
    for key, sub in opt_struct.items():
        if key in ("m", "v", "mom"):
            out[key] = param_specs
        elif key == "f":
            out[key] = jax.tree_util.tree_map(
                lambda spec, d: factored(spec, d),
                param_specs,
                sub,
                is_leaf=lambda x: isinstance(x, dict) and ("r" in x or "v" in x),
            )
        else:
            out[key] = _replicated_tree(sub, mesh)
    return out


def _choose_optimizer(arch_mod):
    name = getattr(arch_mod, "OPTIMIZER", "adamw")
    if name == "adafactor":
        return opt_lib.adafactor(1e-2)
    moment_dtype = getattr(arch_mod.CONFIG, "opt_state_dtype", "float32")
    return opt_lib.adamw(3e-4, moment_dtype=moment_dtype)


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------

def _lm_cell(arch, arch_mod, cfg: TransformerConfig, shape: shp.LMShape, mesh) -> Cell:
    rules = dict(cfg.sharding_rules)
    key = jax.random.PRNGKey(0)

    if shape.kind == "train":
        optimizer = _choose_optimizer(arch_mod)
        step = steps.build_lm_train_step(cfg, optimizer)
        params_s = jax.eval_shape(functools.partial(transformer.init_params, cfg=cfg), key)
        opt_s = jax.eval_shape(optimizer.init, params_s)
        state_s = {"params": params_s, "opt": opt_s, "step": S((), jnp.int32)}
        batch_s = {
            "tokens": S((shape.global_batch, shape.seq_len), jnp.int32),
            "labels": S((shape.global_batch, shape.seq_len), jnp.int32),
        }
        param_specs = specs_for_tree(transformer.logical_axes(cfg), rules, mesh)
        state_sh = {
            "params": param_specs,
            "opt": _opt_shardings(opt_s, param_specs, mesh),
            "step": NamedSharding(mesh, PartitionSpec()),
        }
        batch_sh = {
            "tokens": _ns(mesh, rules, ("batch", None)),
            "labels": _ns(mesh, rules, ("batch", None)),
        }
        return Cell(arch, shape.name, "train", step, (state_s, batch_s),
                    (state_sh, batch_sh), rules, cfg, donate=(0,))

    scfg = dataclasses.replace(cfg, param_dtype="bfloat16", remat_policy="none",
                               microbatches=1)
    params_s = jax.eval_shape(functools.partial(transformer.init_params, cfg=scfg), key)
    param_specs = specs_for_tree(transformer.logical_axes(scfg), rules, mesh)

    if shape.kind == "prefill":
        fn = steps.build_lm_prefill_step(scfg, max_len=shape.seq_len)
        tokens_s = S((shape.global_batch, shape.seq_len), jnp.int32)
        return Cell(arch, shape.name, "prefill", fn, (params_s, tokens_s),
                    (param_specs, _ns(mesh, rules, ("batch", None))), rules, scfg)

    # decode: one new token against a full cache.  The cache sequence dim
    # carries the model axis (the batch dim cannot absorb 256-512 chips),
    # and the cache buffer is donated (in-place update, counted once).
    if shape.name == "long_500k":
        rules = {**rules, "cache_batch": None,
                 "cache_seq": ("pod", "data", "model")}
    else:
        rules = {**rules, "cache_seq": "model"}
    fn = steps.build_lm_decode_step(scfg)
    cache_s = jax.eval_shape(
        functools.partial(
            transformer.init_cache, scfg, shape.global_batch, shape.seq_len
        )
    )
    cache_sh = transformer.KVCache(
        k=_ns(mesh, rules, (None, "cache_batch", "cache_seq", "kv_heads", None)),
        v=_ns(mesh, rules, (None, "cache_batch", "cache_seq", "kv_heads", None)),
        length=NamedSharding(mesh, PartitionSpec()),
    )
    token_s = S((shape.global_batch, 1), jnp.int32)
    token_sh = _ns(mesh, rules, ("cache_batch", None))
    return Cell(arch, shape.name, "decode", fn,
                (params_s, cache_s, token_s),
                (param_specs, cache_sh, token_sh), rules, scfg, donate=(1,))


# ---------------------------------------------------------------------------
# GNN cells
# ---------------------------------------------------------------------------

def _gnn_graph_struct(cfg: GNNConfig, shape: shp.GNNShape):
    N, E = shape.n_nodes, shape.n_edges
    needs_pos = cfg.kind in ("schnet", "dimenet", "meshgraphnet", "graphcast")
    tri = None
    tri_mask = None
    if cfg.kind == "dimenet":
        T = shp.triplet_count(shape, cfg.triplet_factor)
        tri = S((T, 2), jnp.int32)
        tri_mask = S((T,), jnp.bool_)
    return gnn.GraphBatch(
        nodes=S((N, shape.d_feat), jnp.float32),
        edge_src=S((E,), jnp.int32),
        edge_dst=S((E,), jnp.int32),
        node_mask=S((N,), jnp.bool_),
        edge_mask=S((E,), jnp.bool_),
        positions=S((N, 3), jnp.float32) if needs_pos else None,
        edge_feat=None,
        graph_ids=S((N,), jnp.int32) if shape.n_graphs > 1 else None,
        triplets=tri,
        triplet_mask=tri_mask,
        n_graphs=shape.n_graphs,
    )


def _gnn_graph_shardings(cfg, shape, mesh, rules):
    n_ax = ("nodes",)
    e_ax = ("edges",)
    return gnn.GraphBatch(
        nodes=_ns(mesh, rules, n_ax + (None,)),
        edge_src=_ns(mesh, rules, e_ax),
        edge_dst=_ns(mesh, rules, e_ax),
        node_mask=_ns(mesh, rules, n_ax),
        edge_mask=_ns(mesh, rules, e_ax),
        positions=_ns(mesh, rules, n_ax + (None,))
        if cfg.kind in ("schnet", "dimenet", "meshgraphnet", "graphcast")
        else None,
        edge_feat=None,
        graph_ids=_ns(mesh, rules, n_ax) if shape.n_graphs > 1 else None,
        triplets=_ns(mesh, rules, e_ax + (None,)) if cfg.kind == "dimenet" else None,
        triplet_mask=_ns(mesh, rules, e_ax) if cfg.kind == "dimenet" else None,
        n_graphs=shape.n_graphs,
    )


def _gnn_cell(arch, arch_mod, cfg: GNNConfig, shape: shp.GNNShape, mesh) -> Cell:
    rules = dict(cfg.sharding_rules)
    optimizer = opt_lib.adamw(3e-4)
    step = steps.build_gnn_train_step(cfg, optimizer)
    key = jax.random.PRNGKey(0)
    params_s = jax.eval_shape(
        functools.partial(gnn.init_params, cfg=cfg, d_in=shape.d_feat, d_edge_in=4),
        key,
    )
    opt_s = jax.eval_shape(optimizer.init, params_s)
    state_s = {"params": params_s, "opt": opt_s, "step": S((), jnp.int32)}
    graph_s = _gnn_graph_struct(cfg, shape)
    graph_level = cfg.kind in ("schnet", "dimenet") and shape.n_graphs > 1
    target_s = (
        S((shape.n_graphs, cfg.d_out), jnp.float32)
        if graph_level
        else S((shape.n_nodes, cfg.d_out), jnp.float32)
    )
    batch_s = {"graph": graph_s, "target": target_s}

    param_specs = _replicated_tree(params_s, mesh)   # GNN weights are tiny
    state_sh = {
        "params": param_specs,
        "opt": _replicated_tree(opt_s, mesh),
        "step": NamedSharding(mesh, PartitionSpec()),
    }
    graph_sh = _gnn_graph_shardings(cfg, shape, mesh, rules)
    target_sh = (
        _ns(mesh, rules, ("batch", None))
        if graph_level
        else _ns(mesh, rules, ("nodes", None))
    )
    return Cell(arch, shape.name, "train", step,
                (state_s, {"graph": graph_s, "target": target_s}),
                (state_sh, {"graph": graph_sh, "target": target_sh}), rules, cfg,
                donate=(0,))


# ---------------------------------------------------------------------------
# RecSys cells
# ---------------------------------------------------------------------------

def _rec_cell(arch, arch_mod, cfg: RecsysConfig, shape: shp.RecShape, mesh) -> Cell:
    rules = dict(cfg.sharding_rules)
    key = jax.random.PRNGKey(0)
    params_s = jax.eval_shape(functools.partial(sasrec.init_params, cfg=cfg), key)
    param_specs = specs_for_tree(sasrec.logical_axes(cfg), rules, mesh)

    if shape.kind == "train":
        optimizer = opt_lib.adamw(1e-3)
        step = steps.build_sasrec_train_step(cfg, optimizer)
        opt_s = jax.eval_shape(optimizer.init, params_s)
        state_s = {"params": params_s, "opt": opt_s, "step": S((), jnp.int32)}
        batch_s = {
            k: S((shape.batch, cfg.seq_len), jnp.int32) for k in ("seqs", "pos", "neg")
        }
        state_sh = {
            "params": param_specs,
            "opt": _opt_shardings(opt_s, param_specs, mesh),
            "step": NamedSharding(mesh, PartitionSpec()),
        }
        batch_sh = {k: _ns(mesh, rules, ("batch", None)) for k in batch_s}
        return Cell(arch, shape.name, "train", step, (state_s, batch_s),
                    (state_sh, batch_sh), rules, cfg, donate=(0,))

    seqs_s = S((shape.batch, cfg.seq_len), jnp.int32)
    # batch=1 retrieval cannot shard the batch dim; parallelism lives on
    # the candidate/item axis instead.
    batch_ax = ("batch", None) if shape.batch > 1 else (None, None)
    seqs_sh = _ns(mesh, rules, batch_ax)
    if shape.kind == "score_all":
        # offline bulk scoring tiles the batch so logits stay bounded
        bc = 4096 if shape.batch > 8192 else None
        fn = lambda p, s: sasrec.score_all(p, s, cfg, top_k=10, batch_chunk=bc)
        return Cell(arch, shape.name, "score_all", fn, (params_s, seqs_s),
                    (param_specs, seqs_sh), rules, cfg)
    cand_s = S((shape.batch, shape.n_candidates), jnp.int32)
    cand_sh = _ns(mesh, rules, (None, "items"))
    fn = lambda p, s, c: sasrec.score_candidates(p, s, c, cfg)
    return Cell(arch, shape.name, "score_cand", fn, (params_s, seqs_s, cand_s),
                (param_specs, seqs_sh, cand_sh), rules, cfg)


# ---------------------------------------------------------------------------
# GraphGen (paper) cell
# ---------------------------------------------------------------------------

def _graphgen_banded_cell(arch, cfg, shape_name, mesh) -> Cell:
    """§Perf variant 'banded': shard_map PageRank with band-partitioned
    condensed edges (see repro.core.banding) — one all-gather + one
    psum-scatter per iteration instead of per-hop all-reduces (XLA cannot
    prove scatter locality from a flat edge list; shard_map states it)."""
    from jax.sharding import PartitionSpec as P

    from ..core.banding import make_banded_pagerank

    rules = dict(cfg.sharding_rules)
    axes = tuple(a for a in ("pod", "data", "model") if a in mesh.axis_names)
    n_sh = 1
    for a in axes:
        n_sh *= mesh.shape[a]
    vb_pad = cfg.n_virtual // n_sh + 2          # +2 inert pad slots per band
    fn = make_banded_pagerank(
        mesh, axes, cfg.n_real, n_sh * vb_pad, n_sh,
        iters=cfg.pagerank_iters,
    )
    eb = cfg.n_in_edges // n_sh
    cb = cfg.n_correction // n_sh
    args_s = {
        "in_src": S((cfg.n_in_edges,), jnp.int32),
        "in_dst": S((cfg.n_in_edges,), jnp.int32),
        "out_src": S((cfg.n_in_edges,), jnp.int32),
        "out_dst": S((cfg.n_in_edges,), jnp.int32),
        "corr_src": S((cfg.n_correction,), jnp.int32),
        "corr_dst": S((cfg.n_correction,), jnp.int32),
        "corr_cnt": S((cfg.n_correction,), jnp.float32),
        "deg": S((cfg.n_real,), jnp.float32),
    }
    sh = NamedSharding(mesh, P(axes))
    args_sh = {k: sh for k in args_s}
    return Cell(arch, shape_name, "analytics", fn, (args_s,),
                (args_sh,), rules, cfg)


def _graphgen_cell(arch, arch_mod, cfg, shape_name, mesh) -> Cell:
    from ..core import algorithms, engine

    rules = dict(cfg.sharding_rules)

    def pagerank_step(args):
        in_src, in_dst, cs, cd, cm, diag = (
            args["in_src"], args["in_dst"], args["corr_src"],
            args["corr_dst"], args["corr_cnt"], args["diag"],
        )
        fwd = engine.DeviceBipartite(in_src, in_dst, cfg.n_real, cfg.n_virtual)
        rev = engine.DeviceBipartite(in_dst, in_src, cfg.n_virtual, cfg.n_real)
        g = engine.DeviceCondensed(
            chains=((fwd, rev),),
            direct=None,
            correction=(cs, cd, cm),
            diag_mult=None,
            n_real=cfg.n_real,
            deduplicated=False,
        )
        return algorithms.pagerank(g, num_iters=cfg.pagerank_iters)

    E, C = cfg.n_in_edges, cfg.n_correction
    args_s = {
        "in_src": S((E,), jnp.int32),
        "in_dst": S((E,), jnp.int32),
        "corr_src": S((C,), jnp.int32),
        "corr_dst": S((C,), jnp.int32),
        "corr_cnt": S((C,), jnp.float32),
        "diag": S((cfg.n_real,), jnp.float32),
    }
    e_sh = _ns(mesh, rules, ("edges",))
    args_sh = {k: e_sh for k in args_s}
    args_sh["diag"] = _ns(mesh, rules, ("nodes",))
    return Cell(arch, shape_name, "analytics", pagerank_step, (args_s,),
                (args_sh,), rules, cfg)


# ---------------------------------------------------------------------------
# Serving replica placement (DESIGN.md §10)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ReplicaPlacement:
    """One serving replica pinned to a contiguous device group."""

    tenant: str
    replica: int
    devices: Tuple[int, ...]


def place_serving_replicas(
    tenants,
    n_devices: int,
    *,
    group_size: int = 1,
    replicas: int = 1,
) -> list:
    """Place ``replicas`` serving replicas per tenant over ``n_devices``.

    Devices are carved into contiguous groups of ``group_size`` (a group
    is one :class:`~repro.serve.tier.GraphServingTier` process's mesh);
    tenant replicas go round-robin over the groups, so group load is
    balanced to within one replica and two replicas of the same tenant
    never share a group (they exist to survive that group).  Pure
    planning — no devices are touched; launchers consume the returned
    :class:`ReplicaPlacement` list.
    """
    tenants = list(tenants)
    if group_size <= 0 or n_devices < group_size:
        raise ValueError(
            f"need at least one group of {group_size} devices, have "
            f"{n_devices}"
        )
    groups = [
        tuple(range(g * group_size, (g + 1) * group_size))
        for g in range(n_devices // group_size)
    ]
    if replicas > len(groups):
        raise ValueError(
            f"{replicas} replicas per tenant need {replicas} distinct "
            f"device groups, have {len(groups)}"
        )
    # consecutive slots per tenant: replicas land on consecutive groups
    # (mod G), so with replicas <= len(groups) a tenant's replicas are
    # always disjoint, and sequential slot assignment keeps group load
    # balanced to within one replica
    out = []
    slot = 0
    for tenant in tenants:
        for r in range(replicas):
            out.append(ReplicaPlacement(
                tenant=tenant, replica=r,
                devices=groups[slot % len(groups)],
            ))
            slot += 1
    return out


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------

def build_cell(
    arch: str, shape: str, mesh: Mesh, smoke: bool = False,
    variant: Optional[str] = None,
) -> Cell:
    """``variant`` applies a documented beyond-baseline tweak:
    'a2a'      — MoE expert-parallel all-to-all dispatch (shard_map)
    'zero3'    — parameters sharded over the pod axis as well (DCI FSDP)
    'banded'   — graphgen band-partitioned shard_map propagation
    """
    mod = registry.get_arch(arch)
    cfg = mod.SMOKE if smoke else mod.CONFIG
    if variant == "a2a":
        if getattr(cfg, "moe", None) is None:
            raise ValueError(f"variant 'a2a' needs a MoE arch, got {arch}")
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, dispatch="a2a")
        )
    elif variant == "zero3":
        # params (and optimizer state) sharded over the pod axis too:
        # ZeRO-3 across DCI — the memory prescription for 405B-class train
        cfg = dataclasses.replace(
            cfg, sharding_rules={**cfg.sharding_rules,
                                 "embed_param": ("pod", "data")},
        )
    elif variant == "banded":
        if mod.SHAPE_FAMILY != "graphgen":
            raise ValueError("variant 'banded' applies to graphgen-paper")
        return _graphgen_banded_cell(arch, cfg, shape, mesh)
    elif variant is not None:
        raise ValueError(f"unknown variant {variant!r}")
    fam = mod.SHAPE_FAMILY
    if fam == "lm":
        return _lm_cell(arch, mod, cfg, shp.LM_SHAPES[shape], mesh)
    if fam == "gnn":
        return _gnn_cell(arch, mod, cfg, shp.GNN_SHAPES[shape], mesh)
    if fam == "recsys":
        return _rec_cell(arch, mod, cfg, shp.REC_SHAPES[shape], mesh)
    if fam == "graphgen":
        return _graphgen_cell(arch, mod, cfg, shape, mesh)
    raise ValueError(fam)


def all_cells() -> list:
    """The 40 assigned (arch x shape) pairs."""
    out = []
    for arch in registry.list_archs(assigned_only=True):
        for shape in registry.shapes_for(arch):
            out.append((arch, shape))
    return out
