"""Training launcher: --arch <id> end-to-end training on the local mesh.

Production anatomy on a real cluster: the same module runs under
``jax.distributed.initialize`` per host, the mesh comes from
``make_production_mesh``, and the orchestrator supervises restarts.  On
this container it runs the smoke-scale configs end-to-end (CPU), or
lowers full configs when ``--dry-run`` is passed.

Example::

    PYTHONPATH=src python -m repro.launch.train --arch glm4-9b --steps 20 \
        --smoke --checkpoint-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import registry
from ..data.pipeline import TokenPipeline, sasrec_batches
from ..distributed.sharding import use_mesh_rules
from ..launch.mesh import make_host_mesh
from ..launch.orchestrator import Supervisor
from ..models import gnn, sasrec, transformer
from ..train import optimizer as opt_lib
from ..train import steps as steps_lib
from ..train.checkpoint import CheckpointManager


def build_lm_training(cfg, smoke_batch=4, smoke_seq=32):
    optimizer = opt_lib.adamw(opt_lib.cosine_schedule(3e-4, 20, 1000))
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    state = steps_lib.init_train_state(params, optimizer)
    step_fn = jax.jit(steps_lib.build_lm_train_step(cfg, optimizer))
    pipe = iter(
        TokenPipeline(cfg.vocab_size, smoke_seq, smoke_batch).device_iter()
    )
    return state, step_fn, pipe


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    mod = registry.get_arch(args.arch)
    cfg = mod.SMOKE if args.smoke else mod.CONFIG
    mesh = make_host_mesh()
    supervisor = Supervisor(n_workers=1, checkpoint_interval=args.checkpoint_every)
    mgr = (
        CheckpointManager(args.checkpoint_dir, keep_last=2)
        if args.checkpoint_dir
        else None
    )

    if mod.SHAPE_FAMILY == "lm":
        state, step_fn, pipe = build_lm_training(cfg)
        batch_of = lambda: next(pipe)
    elif mod.SHAPE_FAMILY == "recsys":
        optimizer = opt_lib.adamw(1e-3)
        params = sasrec.init_params(jax.random.PRNGKey(0), cfg)
        state = steps_lib.init_train_state(params, optimizer)
        step_fn = jax.jit(steps_lib.build_sasrec_train_step(cfg, optimizer))
        it = sasrec_batches(cfg.n_items, cfg.seq_len, 8)
        batch_of = lambda: {k: jnp.asarray(v) for k, v in next(it).items()}
    else:
        from ..data.graphs import batch_molecules, graph_batch_from_numpy, random_graph

        optimizer = opt_lib.adamw(1e-3)
        if cfg.kind in ("schnet", "dimenet"):
            g = batch_molecules(4, 8, 20, d_feat=6, seed=1)
            target = np.zeros((4, cfg.d_out), np.float32)
        else:
            src, dst, feats, pos = random_graph(64, 200, 6, seed=1, with_positions=True)
            g = graph_batch_from_numpy(src, dst, feats, positions=pos)
            target = np.zeros((64, cfg.d_out), np.float32)
        params = gnn.init_params(jax.random.PRNGKey(0), cfg, d_in=6)
        state = steps_lib.init_train_state(params, optimizer)
        step_fn = jax.jit(steps_lib.build_gnn_train_step(cfg, optimizer))
        batch = {"graph": g, "target": jnp.asarray(target)}
        batch_of = lambda: batch

    start = 0
    if args.resume and mgr is not None and mgr.latest_step() is not None:
        state, start = mgr.restore_latest()
        state = jax.tree_util.tree_map(jnp.asarray, state)
        print(f"resumed from step {start}")

    with use_mesh_rules(mesh, dict(cfg.sharding_rules)):
        for i in range(start, args.steps):
            t0 = time.perf_counter()
            state, metrics = step_fn(state, batch_of())
            dt = time.perf_counter() - t0
            if i % 5 == 0 or i == args.steps - 1:
                print(f"step {i:5d} loss {float(metrics['loss']):.4f} "
                      f"({dt*1e3:.0f} ms)")
            if mgr is not None and supervisor.should_checkpoint(i + 1):
                mgr.save(i + 1, state)
        if mgr is not None:
            mgr.save(args.steps, state)
            mgr.wait()
    print("done")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
