"""Serving launcher: batched LM generation, or the multi-tenant graph tier.

    PYTHONPATH=src python -m repro.launch.serve --arch glm4-9b --requests 6
    PYTHONPATH=src python -m repro.launch.serve --graphs 3 --requests 64

``--graphs N`` serves N extracted graphs from one
:class:`~repro.serve.tier.GraphServingTier` under a device-byte budget,
prints the replica placement plan
(:func:`~repro.launch.cells.place_serving_replicas`) for the local device
count, runs a mixed bfs/ppr/common-neighbors workload, and reports batch
occupancy plus cache hit rates.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from ..configs import registry
from ..models import transformer
from ..serve.server import BatchedServer, Request


def _serve_lm(args) -> int:
    cfg = registry.get_arch(args.arch).SMOKE
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    server = BatchedServer(params, cfg, batch_slots=args.slots, max_len=64)
    rng = np.random.default_rng(0)
    reqs = [
        Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size, size=rng.integers(4, 12)),
            max_new_tokens=args.new_tokens,
        )
        for i in range(args.requests)
    ]
    out = server.run(reqs)
    for rid in sorted(out):
        print(f"request {rid}: {out[rid]}")
    assert len(out) == args.requests
    print("served", len(out), "requests")
    return 0


def _serve_graphs(args) -> int:
    from ..core.dedup import graph_from_membership
    from ..core.engine import ResidencyBudget, device_graph_bytes, to_device
    from ..serve.tier import GraphServingTier, ServeRequest, KINDS
    from .cells import place_serving_replicas

    rng = np.random.default_rng(args.seed)
    tenants = {}
    for g in range(args.graphs):
        n_real, n_virt = 60 + 10 * g, 18 + 2 * g
        sets = [
            rng.choice(n_real, size=rng.integers(2, 6), replace=False)
            for _ in range(n_virt)
        ]
        tenants[f"graph{g}"] = graph_from_membership(n_real, sets)

    # budget: fit roughly two of the tenants at a time
    per_tenant = [
        2 * device_graph_bytes(to_device(g)) for g in tenants.values()
    ]
    budget = ResidencyBudget(
        max_device_bytes=int(sum(sorted(per_tenant)[-2:]) * 1.25)
    )
    tier = GraphServingTier(max_batch=args.slots, budget=budget)
    for name, g in tenants.items():
        tier.add_tenant(name, g)

    placements = place_serving_replicas(
        sorted(tenants), n_devices=max(jax.device_count(), 1),
        replicas=min(args.replicas, max(jax.device_count(), 1)),
    )
    for p in placements:
        print(f"placement: {p.tenant} replica {p.replica} -> devices {p.devices}")

    names = sorted(tenants)
    reqs = [
        ServeRequest(
            qid=i,
            tenant=names[int(rng.integers(len(names)))],
            kind=KINDS[int(rng.integers(len(KINDS)))],
            node=int(rng.integers(40)),
        )
        for i in range(args.requests)
    ]
    out = tier.serve(reqs)
    assert len(out) == args.requests
    print(
        f"served {len(out)} requests over {len(tenants)} tenants: "
        f"occupancy={tier.stats.occupancy:.2f} "
        f"result_cache_hit_rate={tier.result_stats.hit_rate:.2f} "
        f"exec_cache_hit_rate={tier.exec_stats.hit_rate:.2f} "
        f"resident={budget.resident_bytes}B/"
        f"{budget.max_device_bytes}B evictions={budget.n_evictions}"
    )
    return 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--graphs", type=int, default=0,
                    help="serve N graph tenants from one tier instead of the LM")
    ap.add_argument("--replicas", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.graphs > 0:
        return _serve_graphs(args)
    return _serve_lm(args)


if __name__ == "__main__":
    raise SystemExit(main())
