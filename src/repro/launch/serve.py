"""Serving launcher: batched LM generation on the local mesh.

    PYTHONPATH=src python -m repro.launch.serve --arch glm4-9b --requests 6
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from ..configs import registry
from ..models import transformer
from ..serve.server import BatchedServer, Request


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--new-tokens", type=int, default=8)
    args = ap.parse_args()

    cfg = registry.get_arch(args.arch).SMOKE
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    server = BatchedServer(params, cfg, batch_slots=args.slots, max_len=64)
    rng = np.random.default_rng(0)
    reqs = [
        Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size, size=rng.integers(4, 12)),
            max_new_tokens=args.new_tokens,
        )
        for i in range(args.requests)
    ]
    out = server.run(reqs)
    for rid in sorted(out):
        print(f"request {rid}: {out[rid]}")
    assert len(out) == args.requests
    print("served", len(out), "requests")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
