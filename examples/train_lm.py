"""End-to-end LM training driver: ~100M-parameter decoder, synthetic
corpus, AdamW + cosine schedule, checkpoint/resume, loss logging.

    PYTHONPATH=src python examples/train_lm.py --steps 300   # full run
    PYTHONPATH=src python examples/train_lm.py --steps 20    # quick check

The model is the same composable TransformerLM the 40 dry-run cells use;
on TPU this script is launched per-host with the production mesh (see
repro/launch/train.py) — here it runs on the local device.
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import TransformerConfig
from repro.data.pipeline import TokenPipeline
from repro.models import transformer
from repro.train import optimizer as opt_lib
from repro.train import steps as steps_lib
from repro.train.checkpoint import CheckpointManager


def model_100m() -> TransformerConfig:
    cfg = TransformerConfig(
        name="lm-100m",
        n_layers=12,
        d_model=512,
        n_heads=8,
        n_kv_heads=4,
        d_ff=2048,
        vocab_size=50_257,
        remat_policy="none",
        microbatches=1,
        dtype="float32",        # CPU-friendly
    )
    print(f"model: {cfg.n_params()/1e6:.1f}M parameters")
    return cfg


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_lm_ckpt")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = model_100m()
    optimizer = opt_lib.adamw(opt_lib.cosine_schedule(3e-4, 50, args.steps))
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    state = steps_lib.init_train_state(params, optimizer)
    step_fn = jax.jit(steps_lib.build_lm_train_step(cfg, optimizer))
    mgr = CheckpointManager(args.checkpoint_dir, keep_last=2)

    start = 0
    if args.resume and mgr.latest_step() is not None:
        state, start = mgr.restore_latest()
        state = jax.tree_util.tree_map(jnp.asarray, state)
        print(f"resumed from step {start}")

    pipe = iter(TokenPipeline(cfg.vocab_size, args.seq, args.batch).device_iter())
    t_start = time.time()
    for i in range(start, args.steps):
        batch = next(pipe)
        state, metrics = step_fn(state, batch)
        if i % 10 == 0 or i == args.steps - 1:
            loss = float(metrics["loss"])
            tok_s = (i - start + 1) * args.batch * args.seq / (time.time() - t_start)
            print(f"step {i:4d}  loss {loss:7.4f}  grad_norm "
                  f"{float(metrics['grad_norm']):6.2f}  ({tok_s:,.0f} tok/s)")
        if (i + 1) % 50 == 0:
            mgr.save(i + 1, state)
    mgr.save(args.steps, state)
    mgr.wait()
    print(f"done; checkpoints in {args.checkpoint_dir}")


if __name__ == "__main__":
    main()
