"""Batched LM serving demo: continuous slot-based prefill + decode.

    PYTHONPATH=src python examples/serve_lm.py
"""
import jax
import numpy as np

from repro.configs import registry
from repro.models import transformer
from repro.serve.server import BatchedServer, Request


def main():
    cfg = registry.get_arch("yi-9b").SMOKE
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    server = BatchedServer(params, cfg, batch_slots=3, max_len=64)
    rng = np.random.default_rng(0)
    requests = [
        Request(rid=i,
                prompt=rng.integers(0, cfg.vocab_size, size=int(rng.integers(4, 12))),
                max_new_tokens=8)
        for i in range(7)
    ]
    out = server.run(requests)
    for rid in sorted(out):
        print(f"request {rid}: generated {out[rid]}")
    assert all(len(v) >= 8 for v in out.values())
    print(f"served {len(out)} requests on {len(server.slots)} slots")


if __name__ == "__main__":
    main()
