"""Distributed condensed-graph analytics + fault tolerance demo.

Forces 8 host devices, shards the condensed engine's edge arrays over a
(4 data x 2 model) mesh, runs PageRank on the sharded condensed graph,
then simulates a node failure: the supervisor detects it, re-meshes to
the surviving devices, and training^Wanalysis resumes from checkpoint.

    PYTHONPATH=src python examples/graph_analytics_distributed.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import algorithms, dedup, engine
from repro.data.synth import barabasi_albert_condensed
from repro.launch.mesh import largest_feasible_mesh
from repro.launch.orchestrator import Heartbeat, Supervisor


def shard_graph(dev_graph, mesh):
    """Place edge arrays of a DeviceCondensed across the mesh.

    ``device_put`` needs divisible dims, so ragged edge lists are padded
    with *inert* entries: padded in-edges point real node 0 at a fresh
    dummy virtual node with no out-edges (and vice versa for out-edges),
    so no complete path — hence zero propagated mass — is added.
    """
    n_dev = len(mesh.devices.flatten())
    e_sh = NamedSharding(mesh, P(("data", "model")))
    r = NamedSharding(mesh, P())

    def place(x, sharding):
        return jax.device_put(x, sharding)

    def pad_edges(e, dummy_src, dummy_dst, n_src, n_dst):
        pad = (-e.src.shape[0]) % n_dev
        if pad == 0:
            return engine.DeviceBipartite(
                place(e.src, e_sh), place(e.dst, e_sh), n_src, n_dst
            )
        src = jnp.concatenate([e.src, jnp.full(pad, dummy_src, e.src.dtype)])
        dst = jnp.concatenate([e.dst, jnp.full(pad, dummy_dst, e.dst.dtype)])
        return engine.DeviceBipartite(place(src, e_sh), place(dst, e_sh),
                                      n_src, n_dst)

    chains = []
    for chain in dev_graph.chains:
        padded = []
        for li, e in enumerate(chain):
            # grow every virtual level by 2 dummies: dummy A has only
            # in-edges, dummy B only out-edges -> no complete paths.
            n_src = e.n_src + (2 if li > 0 else 0)
            n_dst = e.n_dst + (2 if li < len(chain) - 1 else 0)
            dummy_dst = e.n_dst if li < len(chain) - 1 else 0
            dummy_src = e.n_src + 1 if li > 0 else 0
            padded.append(pad_edges(e, dummy_src, dummy_dst, n_src, n_dst))
        chains.append(tuple(padded))
    corr = None
    if dev_graph.correction is not None:
        cs, cd, cm = dev_graph.correction
        pad = (-cs.shape[0]) % n_dev
        if pad:
            cs = jnp.concatenate([cs, jnp.zeros(pad, cs.dtype)])
            cd = jnp.concatenate([cd, jnp.zeros(pad, cd.dtype)])
            cm = jnp.concatenate([cm, jnp.zeros(pad, cm.dtype)])  # count 0
        corr = (place(cs, e_sh), place(cd, e_sh), place(cm, e_sh))
    diag = place(dev_graph.diag_mult, r) if dev_graph.diag_mult is not None else None
    return engine.DeviceCondensed(
        chains=tuple(chains), direct=None, correction=corr, diag_mult=diag,
        n_real=dev_graph.n_real, deduplicated=dev_graph.deduplicated,
    )


def main():
    n_dev = len(jax.devices())
    print(f"devices: {n_dev}")
    g = barabasi_albert_condensed(20_000, 2_000, 12.0, 4.0, seed=0)
    corr = dedup.build_correction(g)
    dev = engine.to_device(g, correction=corr)
    print(f"graph: {g.n_real} real, {g.n_virtual} virtual, "
          f"{g.n_edges_condensed} condensed edges "
          f"({g.n_edges_expanded()} expanded)")

    # reference on one device
    pr_ref = np.asarray(algorithms.pagerank(dev, num_iters=20))

    mesh = jax.make_mesh((n_dev // 2, 2), ("data", "model"))
    sharded = shard_graph(dev, mesh)
    t0 = time.time()
    pr = np.asarray(algorithms.pagerank(sharded, num_iters=20))
    print(f"sharded PageRank on {n_dev} devices: {time.time()-t0:.2f}s; "
          f"max |diff| vs single-device = {np.abs(pr - pr_ref).max():.2e}")
    assert np.allclose(pr, pr_ref, atol=1e-6)

    # --- failure + elastic re-mesh -----------------------------------------
    sup = Supervisor(n_workers=4, heartbeat_deadline=0.5, miss_limit=2,
                     model_parallel=2)
    now = time.time()
    for w in range(4):
        sup.heartbeat(Heartbeat(w, step=100, wall_time=now))
    # workers 0-2 keep reporting; worker 3 goes silent
    for t_off in (1.0, 2.0):
        for w in range(3):
            sup.heartbeat(Heartbeat(w, step=101, wall_time=now + t_off))
        sup.check_deadlines(now + t_off)
    assert not sup.workers[3].alive
    print(f"supervisor: worker 3 declared dead; events={sup.events}")
    shape, axes = sup.remesh_plan(devices_per_worker=2)
    print(f"re-mesh plan on survivors: shape={shape} axes={axes}")
    new_mesh = jax.make_mesh(shape, axes,
                             devices=np.array(jax.devices()[: shape[0]*shape[1]]))
    sharded2 = shard_graph(dev, new_mesh)
    pr2 = np.asarray(algorithms.pagerank(sharded2, num_iters=20))
    assert np.allclose(pr2, pr_ref, atol=1e-6)
    print("analysis resumed on the shrunken mesh; results identical")


if __name__ == "__main__":
    main()
