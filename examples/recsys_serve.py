"""SASRec end-to-end: train briefly, then serve (full-catalog + candidate
scoring) — plus the GraphGen tie-in: the co-interaction graph of the
training data extracted with the paper's DSL and condensed representation.

    PYTHONPATH=src python examples/recsys_serve.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import RecsysConfig
from repro.core import algorithms, extract
from repro.core.relational import Catalog, Table
from repro.data.pipeline import sasrec_batches
from repro.models import sasrec
from repro.train import optimizer as opt_lib
from repro.train import steps as steps_lib


def main():
    cfg = RecsysConfig(name="sasrec-demo", embed_dim=50, n_blocks=2,
                       n_heads=1, seq_len=50, n_items=5_000)
    params = sasrec.init_params(jax.random.PRNGKey(0), cfg)
    optimizer = opt_lib.adamw(1e-3)
    state = steps_lib.init_train_state(params, optimizer)
    step = jax.jit(steps_lib.build_sasrec_train_step(cfg, optimizer))

    batches = sasrec_batches(cfg.n_items, cfg.seq_len, batch=64, seed=0)
    print("training SASRec...")
    for i in range(30):
        batch = {k: jnp.asarray(v) for k, v in next(batches).items()}
        state, m = step(state, batch)
        if i % 10 == 0:
            print(f"  step {i}: bce={float(m['loss']):.4f}")

    # serving: full-catalog top-k
    seqs = jnp.asarray(next(batches)["seqs"][:8])
    t0 = time.time()
    scores, ids = sasrec.score_all(state["params"], seqs, cfg, top_k=5)
    print(f"top-5 for 8 users in {(time.time()-t0)*1e3:.0f} ms:")
    print(np.asarray(ids)[:3])

    # retrieval: one user vs candidate set (batched dot, not a loop)
    cands = jnp.asarray(
        np.random.default_rng(0).integers(1, cfg.n_items, size=(1, 2_000))
    )
    cs = sasrec.score_candidates(state["params"], seqs[:1], cands, cfg)
    print(f"candidate scoring: {cs.shape} scores, "
          f"best={int(cands[0, int(jnp.argmax(cs[0]))])}")

    # --- GraphGen tie-in: users who bought the same item (paper's TPCH Q2)
    rng = np.random.default_rng(1)
    n_users, n_interactions = 500, 4_000
    users = rng.integers(0, n_users, n_interactions)
    items = rng.zipf(1.5, n_interactions) % 300
    catalog = Catalog([
        Table("User", {"uid": np.arange(n_users)}),
        Table("Interaction", {"uid": users, "iid": items}),
    ])
    res = extract(catalog, """
        Nodes(ID) :- User(ID).
        Edges(ID1, ID2) :- Interaction(ID1, item), Interaction(ID2, item).
    """)
    g = res.graph
    print(f"co-interaction graph: {g.n_edges_condensed} condensed edges "
          f"vs {g.n_edges_expanded()} expanded "
          f"({g.n_edges_expanded()/max(g.n_edges_condensed,1):.0f}x)")
    # --- batched serving: per-user queries fused into one propagation -------
    from repro.serve import GraphQuery, GraphQueryServer

    # from_condensed builds the DEDUP-C correction under a streaming
    # budget (the raw expansion never materializes on the host,
    # DESIGN.md §2) and wires ppr against the duplicate-exact graph,
    # common-neighbor scoring against raw C-DUP (self loops kept)
    server = GraphQueryServer.from_condensed(g, budget_bytes=2 << 20, max_batch=32)
    acct = server.correction_accounting
    print(f"correction built streaming: peak {acct.peak_resident_triples} "
          f"resident triples over {acct.n_chunks} chunks "
          f"({acct.n_paths} raw paths)")
    pr = algorithms.pagerank(server.graph, num_iters=10)
    print(f"most central user (candidate-generation seed): "
          f"{int(jnp.argmax(pr))}")
    queries = [GraphQuery(qid=i, kind="common_neighbors", node=int(u))
               for i, u in enumerate(rng.integers(0, n_users, size=24))]
    queries += [GraphQuery(qid=100 + i, kind="ppr", node=int(u))
                for i, u in enumerate(rng.integers(0, n_users, size=8))]
    t0 = time.time()
    answers = server.run(queries)
    print(f"served {server.n_queries} queries in "
          f"{server.n_propagation_batches} propagation batches "
          f"({(time.time()-t0)*1e3:.0f} ms)")
    q0 = queries[0]
    scores = np.array(answers[q0.qid])
    scores[q0.node] = -np.inf  # self-score is the user's own degree
    top = np.argsort(scores)[::-1][:3]
    print(f"  user {q0.node}: strongest co-interaction partners {top.tolist()}")


if __name__ == "__main__":
    main()
