"""Quickstart: extract a hidden graph from a relational DB and analyze it.

The paper's end-to-end flow (Fig 1): declare the co-author graph in the
Datalog DSL, extract it as a *condensed* representation (no quadratic
join), deduplicate, and run graph algorithms — all in one script.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import algorithms, dedup, engine, extract, recommend
from repro.data.synth import dblp_catalog

QUERY = """
# co-authors: connect authors who share a publication  [paper Q1]
Nodes(ID, Name) :- Author(ID, Name).
Edges(ID1, ID2) :- AuthorPub(ID1, PubID), AuthorPub(ID2, PubID).
"""


def main():
    catalog = dblp_catalog(n_authors=3000, n_pubs=6000,
                           mean_authors_per_pub=6.0, seed=7)
    print(f"catalog: {catalog.table_names}, {catalog.nbytes()/1e6:.1f} MB")

    # 1. declarative extraction -> condensed representation
    res = extract(catalog, QUERY)
    g = res.graph
    print(f"plan: {res.plans[0].describe()}   (** = postponed large join)")
    print(f"condensed: {g.n_edges_condensed} edges, {g.n_virtual} virtual nodes")
    print(f"expanded would be: {g.n_edges_expanded()} edges "
          f"({g.n_edges_expanded()/g.n_edges_condensed:.1f}x larger)")

    # 2. representation choice (paper §6.5)
    rec = recommend(g, workload="multi_pass")
    print(f"advisor: host={rec.host_representation} device={rec.device_representation}")
    print(f"  ({rec.reason})")

    # 3. deduplicate for duplicate-sensitive analytics (DEDUP-C)
    corr = dedup.build_correction(g)
    dev = engine.to_device(g, correction=corr)
    print(f"correction: {len(corr[0])} duplicated pairs "
          f"(duplication ratio {g.duplication_ratio():.3f})")

    # 4. run algorithms on the condensed graph
    pr = algorithms.pagerank(dev, num_iters=30)
    deg = algorithms.out_degrees(dev)
    cc = algorithms.connected_components(engine.to_device(g))  # C-DUP direct!
    top = np.argsort(np.asarray(pr))[::-1][:5]
    names = g.node_properties["Name"]
    print("top-5 authors by PageRank:")
    for i in top:
        print(f"  {names[i]}: pr={float(pr[i]):.5f} degree={int(deg[i])}")
    n_comp = len(np.unique(np.asarray(cc)))
    print(f"connected components: {n_comp}")

    # 5. exactness: identical results on the expanded graph
    exp = engine.to_device(g.expand())
    assert np.allclose(np.asarray(algorithms.pagerank(exp, num_iters=30)),
                       np.asarray(pr), atol=1e-6)
    print("verified: condensed == expanded PageRank (paper's correctness bar)")


if __name__ == "__main__":
    main()
