"""Quickstart: extract a hidden graph from a relational DB and analyze it.

The paper's end-to-end flow (Fig 1), with this repo's scaling layers in
the order you would use them in production: consult the advisor, extract
*sharded* under a memory budget (DESIGN.md §7 — byte-identical to the
one-shot build), deduplicate with the DEDUP-C correction, and propagate.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (
    algorithms,
    dedup,
    engine,
    extract,
    extract_sharded,
    graphs_identical,
    recommend,
)
from repro.data.synth import dblp_catalog

QUERY = """
# co-authors: connect authors who share a publication  [paper Q1]
Nodes(ID, Name) :- Author(ID, Name).
Edges(ID1, ID2) :- AuthorPub(ID1, PubID), AuthorPub(ID2, PubID).
"""


def main():
    catalog = dblp_catalog(n_authors=3000, n_pubs=6000,
                           mean_authors_per_pub=6.0, seed=7)
    print(f"catalog: {catalog.table_names}, {catalog.nbytes()/1e6:.1f} MB")

    # 1. declarative extraction, sharded + budgeted (DESIGN.md §7):
    #    8 row shards, peak resident rows per shard enforced
    res = extract_sharded(catalog, QUERY, n_shards=8,
                          max_resident_rows=200_000)
    g = res.graph
    print(f"plan: {res.plans[0].describe()}   (** = postponed large join)")
    print(f"condensed: {g.n_edges_condensed} edges, {g.n_virtual} virtual nodes")
    print(f"expanded would be: {g.n_edges_expanded()} edges "
          f"({g.n_edges_expanded()/g.n_edges_condensed:.1f}x larger)")
    print(f"sharded build: peak {res.budget.peak_resident_rows} resident "
          f"rows/shard (cap 200000) over {res.budget.n_shards_processed} "
          "shard tasks")
    # the merge step is exact — same bytes as the one-shot build
    assert graphs_identical(g, extract(catalog, QUERY).graph)

    # 2. representation choice (paper §6.5)
    rec = recommend(g, workload="multi_pass")
    print(f"advisor: host={rec.host_representation} device={rec.device_representation}")
    print(f"  ({rec.reason})")

    # 3. deduplicate for duplicate-sensitive analytics (DEDUP-C),
    #    built with the streaming fold so the host never holds the
    #    raw expansion (DESIGN.md §2)
    corr = dedup.build_correction_streaming(g)
    dev = engine.to_device(g, correction=corr)
    print(f"correction: {len(corr[0])} duplicated pairs "
          f"(duplication ratio {g.duplication_ratio():.3f})")

    # 4. propagate on the condensed graph
    pr = algorithms.pagerank(dev, num_iters=30)
    deg = algorithms.out_degrees(dev)
    cc = algorithms.connected_components(engine.to_device(g))  # C-DUP direct!
    top = np.argsort(np.asarray(pr))[::-1][:5]
    names = g.node_properties["Name"]
    print("top-5 authors by PageRank:")
    for i in top:
        print(f"  {names[i]}: pr={float(pr[i]):.5f} degree={int(deg[i])}")
    n_comp = len(np.unique(np.asarray(cc)))
    print(f"connected components: {n_comp}")

    # 5. exactness: identical results on the expanded graph
    exp = engine.to_device(g.expand())
    assert np.allclose(np.asarray(algorithms.pagerank(exp, num_iters=30)),
                       np.asarray(pr), atol=1e-6)
    print("verified: condensed == expanded PageRank (paper's correctness bar)")


if __name__ == "__main__":
    main()
