"""Fused DEDUP-C epilogue correctness.

The fused kernel (last-layer SpMM with the correction subtraction in the
epilogue) must be *byte-identical* to the existing two-pass path (SpMM
then segment_sum subtract) — integer-valued f32 frontiers make every sum
exact, so equality is bitwise, not approximate.  Pinned on the DBLP and
TPCH extraction fixtures (the paper's running examples), at the kernel
level against a dense oracle, and property-style over random condensed
graphs (hypothesis under the tier2 marker, with seeded offline variants
via the conftest stub, like tests/test_properties.py).
"""
import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from conftest import random_membership_graph

from repro.core import dedup, engine, extract
from repro.core.semiring import PLUS_TIMES
from repro.data.synth import dblp_catalog, tpch_catalog
from repro.kernels.correction import build_fused_stream, pack_correction
from repro.kernels.pack import TILE, pack_bipartite
from repro.kernels.bitmap_spmm import bitmap_spmm_fused_pallas
from test_properties import random_condensed

Q1 = """
Nodes(ID, Name) :- Author(ID, Name).
Edges(ID1, ID2) :- AuthorPub(ID1, PubID), AuthorPub(ID2, PubID).
"""

Q2 = """
Nodes(ID, Name) :- Customer(ID, Name).
Edges(ID1, ID2) :- Orders(ok1, ID1), LineItem(ok1, pk),
                   Orders(ok2, ID2), LineItem(ok2, pk).
"""


def _int_frontier(n, b, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, 7, (n, b)).astype(np.float32))


def _check_fused_byte_identical(g, batch=16, seed=0):
    """Fused vs two-pass vs plain segment path, both directions."""
    corr = dedup.build_correction(g)
    if corr[0].size == 0:
        pytest.skip("graph has an empty correction")
    fused = engine.to_device_packed(g, correction=corr, backend="pallas")
    two_pass = engine.to_device_packed(
        g, correction=corr, backend="pallas", fuse_correction=False
    )
    segment = engine.to_device(g, correction=corr)
    assert fused.fused_fwd is not None and fused.fused_rev is not None
    x = _int_frontier(g.n_real, batch, seed)
    for reverse in (False, True):
        engine.reset_kernel_dispatch_count()
        got = np.asarray(
            engine.propagate(fused, x, PLUS_TIMES, reverse=reverse)
        )
        assert engine.KERNEL_DISPATCH_COUNT > 0
        ref2 = np.asarray(
            engine.propagate(two_pass, x, PLUS_TIMES, reverse=reverse)
        )
        ref0 = np.asarray(
            engine.propagate(segment, x, PLUS_TIMES, reverse=reverse)
        )
        assert np.array_equal(got, ref2), f"reverse={reverse} vs two-pass"
        assert np.array_equal(got, ref0), f"reverse={reverse} vs segment"


# ---------------------------------------------------------------------------
# Extraction fixtures: the paper's running examples
# ---------------------------------------------------------------------------

def test_fused_byte_identical_dblp():
    cat = dblp_catalog(n_authors=400, n_pubs=700, mean_authors_per_pub=6.0,
                       seed=1)
    g = extract(cat, Q1, mode="condensed").graph
    _check_fused_byte_identical(g, batch=16, seed=1)


def test_fused_byte_identical_tpch_multilayer():
    cat = tpch_catalog(seed=2)
    g = extract(cat, Q2, mode="condensed").graph
    assert g.chains[0].n_layers == 3  # fused step is the LAST of 4 hops
    _check_fused_byte_identical(g, batch=8, seed=2)


def test_fused_byte_identical_membership():
    rng = np.random.default_rng(11)
    g = random_membership_graph(200, 40, 6, rng)
    _check_fused_byte_identical(g, batch=33, seed=3)


# ---------------------------------------------------------------------------
# Kernel-level parity against a dense oracle
# ---------------------------------------------------------------------------

def test_fused_kernel_matches_dense_oracle():
    rng = np.random.default_rng(5)
    n_virtual, n_real = 260, 300
    key = rng.choice(n_virtual * n_real, size=2000, replace=False)
    src, dst = key % n_virtual, key // n_virtual
    from repro.core.condensed import BipartiteEdges

    main = pack_bipartite(BipartiteEdges(src, dst, n_virtual, n_real))
    ck = rng.choice(n_real * n_real, size=400, replace=False)
    cs, cd = ck % n_real, ck // n_real
    cm = rng.integers(1, 6, cs.size)
    corr = pack_correction(cs, cd, cm, n_real, n_real)
    assert corr.n_planes == 3  # counts up to 5 need three bit-planes
    stream = build_fused_stream(main, corr)

    f = 40
    h = rng.integers(0, 7, (n_virtual, f)).astype(np.float32)
    x = rng.integers(0, 7, (n_real, f)).astype(np.float32)
    B = main.to_dense()[:n_real, :n_virtual]
    D = corr.to_dense()[:n_real, :n_real]
    want = B @ h - D @ x

    hp = np.zeros((main.n_src_tiles * TILE, 128), np.float32)
    hp[:n_virtual, :f] = h
    xp = np.zeros((corr.n_src_tiles * TILE, 128), np.float32)
    xp[:n_real, :f] = x
    y = bitmap_spmm_fused_pallas(
        jnp.asarray(stream.kind), jnp.asarray(stream.main_src),
        jnp.asarray(stream.corr_src), jnp.asarray(stream.main_idx),
        jnp.asarray(stream.corr_idx), jnp.asarray(stream.slot_row),
        jnp.asarray(stream.row_start), jnp.asarray(stream.row_count),
        jnp.asarray(main.bitmaps), jnp.asarray(corr.planes),
        jnp.asarray(hp), jnp.asarray(xp),
        n_dst_pad=main.n_row_tiles * TILE,
        plane_weights=corr.plane_weights,
    )
    got = np.asarray(y)[:n_real, :f]
    assert np.array_equal(got, want.astype(np.float32))


def test_pack_correction_bit_planes_reconstruct_counts():
    rng = np.random.default_rng(8)
    n = 200
    ck = rng.choice(n * n, size=300, replace=False)
    cs, cd = ck % n, ck // n
    cm = rng.integers(1, 9, cs.size)
    corr = pack_correction(cs, cd, cm, n, n)
    D = np.zeros((n, n))
    D[cd, cs] = cm
    assert np.array_equal(corr.to_dense()[:n, :n], D)
    # no pad slots: every slot holds at least one bit
    assert corr.n_slots == 0 or corr.planes.any(axis=(1, 2, 3)).all()


def test_pack_correction_rejects_non_integer_counts():
    with pytest.raises(ValueError):
        pack_correction(
            np.array([0]), np.array([1]), np.array([0.5]), 4, 4
        )
    with pytest.raises(ValueError):
        pack_correction(np.array([0]), np.array([1]), np.array([0]), 4, 4)


# ---------------------------------------------------------------------------
# Fallback semantics: fusion must quietly stand down where it cannot
# preserve the two-pass contract
# ---------------------------------------------------------------------------

def test_fused_disabled_for_hop_weight_and_1d():
    rng = np.random.default_rng(4)
    g = random_membership_graph(120, 25, 5, rng)
    corr = dedup.build_correction(g)
    fused = engine.to_device_packed(g, correction=corr, backend="pallas")
    segment = engine.to_device(g, correction=corr)
    x2 = _int_frontier(g.n_real, 4, seed=9)
    # hop_weight: fused path stands down, results still agree (two-pass)
    a = np.asarray(engine.propagate(fused, x2, PLUS_TIMES, hop_weight=2.0))
    b = np.asarray(engine.propagate(segment, x2, PLUS_TIMES, hop_weight=2.0))
    assert np.array_equal(a, b)
    # 1-D frontier: fused path requires a batch axis
    v = np.asarray(engine.propagate(fused, x2[:, 0], PLUS_TIMES))
    w = np.asarray(engine.propagate(segment, x2[:, 0], PLUS_TIMES))
    assert np.array_equal(v, w)


def test_fused_ops_absent_without_correction_or_when_disabled():
    rng = np.random.default_rng(6)
    g = random_membership_graph(100, 20, 5, rng)
    corr = dedup.build_correction(g)
    assert engine.to_device_packed(g).fused_fwd is None
    assert (
        engine.to_device_packed(
            g, correction=corr, fuse_correction=False
        ).fused_fwd
        is None
    )


# ---------------------------------------------------------------------------
# Property test over random condensed graphs (tier2 + offline variants)
# ---------------------------------------------------------------------------

def _check_fused_property(seed: int) -> None:
    rng = np.random.default_rng(seed)
    g = random_condensed(rng)
    corr = dedup.build_correction(g)
    fused = engine.to_device_packed(g, correction=corr, backend="pallas")
    segment = engine.to_device(g, correction=corr)
    x = _int_frontier(g.n_real, int(rng.integers(1, 9)), seed)
    for reverse in (False, True):
        got = np.asarray(
            engine.propagate(fused, x, PLUS_TIMES, reverse=reverse)
        )
        want = np.asarray(
            engine.propagate(segment, x, PLUS_TIMES, reverse=reverse)
        )
        assert np.array_equal(got, want), f"seed={seed} reverse={reverse}"


@pytest.mark.tier2
@given(seed=st.integers(0, 100_000))
@settings(max_examples=20, deadline=None)
def test_fused_propagation_matches_two_pass(seed):
    _check_fused_property(seed)


@pytest.mark.parametrize("seed", [0, 1, 7, 42])
def test_fused_propagation_matches_two_pass_offline(seed):
    _check_fused_property(seed)
