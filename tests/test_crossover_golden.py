"""Seeded golden regressions for the measured-crossover table.

Mirrors tests/test_dedup_golden.py: a fixed-seed layer measured with a
deterministic injected timer must always produce the SAME table — same
keys, same winning configs, same backend decisions — and the table must
survive a JSON round-trip (and the serialize.py save/load helpers)
byte-for-byte, with ``resolve_backend`` reading identical decisions from
the original and the reloaded copy.  A change in any of these values is
a dispatch-policy regression (or an intentional policy change) — it
should fail loudly here instead of silently re-routing SpMM traffic.
"""
import numpy as np
import pytest

jax = pytest.importorskip("jax")

from conftest import random_bipartite

from repro.core.semiring import MIN_PLUS, PLUS_TIMES
from repro.core.serialize import load_crossover_table, save_crossover_table
from repro.kernels.autotune import CrossoverTable, measure_crossover
from repro.kernels.ops import PackedLayer, resolve_backend

# Deterministic 'measurements': 5 candidate timings then one XLA timing
# per cell, in seconds.  Chosen to exercise every selection rule once:
# widest-window win, an XLA win, a tie broken by (row_window,
# feature_block), and a pallas-on-equal tie.
_SCRIPTED_TIMES = [
    5.0, 4.0, 3.0, 2.0, 1.0,   # (sum, B=8)  autotune -> rw512 wins
    10.0,                      #             xla      -> pallas cell
    1.0, 2.0, 3.0, 4.0, 5.0,   # (sum, B=64) autotune -> rw128/fb128 wins
    0.5,                       #             xla      -> xla cell
    3.0, 1.0, 4.0, 1.0, 5.0,   # (min, B=8)  tie -> smaller (rw, fb) wins
    9.0,                       #             xla      -> pallas cell
    2.0, 2.0, 2.0, 2.0, 2.0,   # (min, B=64) all tie -> rw128/fb128
    2.0,                       #             xla tie  -> pallas (<=)
]

# Golden decisions for the scripted run above.  Layer n_src=300 ->
# src_bucket 9; batch buckets: 8 -> 3, 64 -> 6.
GOLDEN_CELLS = {
    # key: (backend, row_window, feature_block, pallas_us, xla_us)
    ("sum", 9, 3): ("pallas", 512, 128, 1.0e6, 10.0e6),
    ("sum", 9, 6): ("xla", 128, 128, 1.0e6, 0.5e6),
    ("min", 9, 3): ("pallas", 128, 256, 1.0e6, 9.0e6),
    ("min", 9, 6): ("pallas", 128, 128, 2.0e6, 2.0e6),
}


def _seeded_layer():
    rng = np.random.default_rng(21)
    return PackedLayer.from_edges(random_bipartite(300, 200, 1200, rng))


def _scripted_table():
    times = iter(_SCRIPTED_TIMES)
    return measure_crossover(
        _seeded_layer(),
        ops=("sum", "min"),
        batch_sizes=(8, 64),
        time_fn=lambda fn: next(times),
    )


def test_scripted_measurement_reproduces_golden_table():
    table = _scripted_table()
    assert len(table) == len(GOLDEN_CELLS)
    for key, entry in table.entries:
        backend, rw, fb, p_us, x_us = GOLDEN_CELLS[key]
        assert entry.backend == backend, key
        assert (entry.row_window, entry.feature_block) == (rw, fb), key
        assert (entry.pallas_us, entry.xla_us) == (p_us, x_us), key


def test_scripted_measurement_is_deterministic():
    a, b = _scripted_table(), _scripted_table()
    assert a == b
    assert a.to_json() == b.to_json()


def test_json_round_trip_is_stable():
    table = _scripted_table()
    text = table.to_json()
    again = CrossoverTable.from_json(text)
    assert again == table
    # round-tripping the round-trip changes nothing (canonical encoding)
    assert again.to_json() == text


def test_serialize_save_load_round_trip(tmp_path):
    table = _scripted_table()
    path = str(tmp_path / "crossover.json")
    save_crossover_table(table, path)
    loaded = load_crossover_table(path)
    assert loaded == table
    assert loaded.to_json() == table.to_json()


def test_resolve_backend_decisions_survive_reload(tmp_path):
    table = _scripted_table()
    path = str(tmp_path / "crossover.json")
    save_crossover_table(table, path)
    loaded = load_crossover_table(path)
    # probe measured buckets AND nearest-bucket fallbacks, both semirings
    probes = [
        (PLUS_TIMES, 300, 8), (PLUS_TIMES, 300, 64),
        (PLUS_TIMES, 300, 200), (PLUS_TIMES, 40_000, 64),
        (MIN_PLUS, 300, 8), (MIN_PLUS, 300, 64), (MIN_PLUS, 7, 1),
    ]
    for semiring, n_src, b in probes:
        before = resolve_backend(
            "auto", b, 128, 4, semiring=semiring, table=table, n_src=n_src
        )
        after = resolve_backend(
            "auto", b, 128, 4, semiring=semiring, table=loaded, n_src=n_src
        )
        assert before == after, (semiring.name, n_src, b)


def test_golden_resolved_backends():
    table = _scripted_table()
    assert resolve_backend(
        "auto", 8, 128, 4, table=table, n_src=300
    ) == "pallas"
    assert resolve_backend(
        "auto", 64, 128, 4, table=table, n_src=300
    ) == "xla"
    assert resolve_backend(
        "auto", 8, 128, 4, semiring=MIN_PLUS, table=table, n_src=300
    ) == "pallas"
    # the (sum, B=64) xla verdict generalises to nearby unmeasured sizes
    assert resolve_backend(
        "auto", 64, 128, 4, table=table, n_src=290
    ) == "xla"
