import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from conftest import random_membership_graph, random_multilayer_graph

from repro.core import algorithms, dedup, engine
from repro.core.semiring import MIN_PLUS, OR_AND, PLUS_TIMES


def _reps(g):
    """All duplicate-exact device representations of the same graph."""
    corr = dedup.build_correction(g)
    reps = {
        "EXP": engine.to_device(g.expand()),
        "DEDUP-C": engine.to_device(g, correction=corr),
    }
    if dedup.is_symmetric_single_layer(g):
        d1 = dedup.dedup1_greedy_virtual_first(g)
        reps["DEDUP-1"] = engine.to_device(d1.graph, deduplicated=True)
    return reps


@given(seed=st.integers(0, 10_000))
@settings(max_examples=30, deadline=None)
def test_plus_times_propagate_matches_dense(seed):
    rng = np.random.default_rng(seed)
    g = random_membership_graph(int(rng.integers(4, 20)), int(rng.integers(1, 6)), 3, rng)
    A = np.minimum(g.expand().adjacency_multiplicity(), 1).astype(np.float64)
    np.fill_diagonal(A, 0.0)
    x = rng.standard_normal(g.n_real).astype(np.float32)
    want = A.T @ x  # propagate pushes along edges: y[v] = sum_{u->v} x[u]
    for name, rep in _reps(g).items():
        got = np.asarray(engine.propagate(rep, x, PLUS_TIMES))
        assert np.allclose(got, want, atol=1e-3), name
        got_r = np.asarray(engine.propagate(rep, x, PLUS_TIMES, reverse=True))
        assert np.allclose(got_r, A @ x, atol=1e-3), f"{name} reverse"


@given(seed=st.integers(0, 10_000))
@settings(max_examples=30, deadline=None)
def test_cdup_counts_paths_with_multiplicity(seed):
    rng = np.random.default_rng(seed)
    g = random_membership_graph(int(rng.integers(4, 15)), int(rng.integers(1, 5)), 3, rng)
    M = g.expand().adjacency_multiplicity().astype(np.float64)
    np.fill_diagonal(M, 0.0)  # engine drops self loops via diag_mult
    x = rng.standard_normal(g.n_real).astype(np.float32)
    rep = engine.to_device(g)  # raw C-DUP
    got = np.asarray(engine.propagate(rep, x, PLUS_TIMES, allow_duplicates=True))
    assert np.allclose(got, M.T @ x, atol=1e-3)
    # and without allow_duplicates it must refuse
    with pytest.raises(ValueError):
        engine.propagate(rep, x, PLUS_TIMES)


@given(seed=st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_algorithms_agree_across_representations(seed):
    rng = np.random.default_rng(seed)
    g = random_membership_graph(int(rng.integers(5, 18)), int(rng.integers(1, 6)), 3, rng)
    reps = _reps(g)
    exp = reps.pop("EXP")
    deg0 = np.asarray(algorithms.out_degrees(exp))
    pr0 = np.asarray(algorithms.pagerank(exp, num_iters=15))
    bfs0 = np.asarray(algorithms.bfs(exp, 0))
    cc0 = np.asarray(algorithms.connected_components(exp))
    for name, rep in reps.items():
        assert np.allclose(np.asarray(algorithms.out_degrees(rep)), deg0, atol=1e-3), name
        assert np.allclose(np.asarray(algorithms.pagerank(rep, num_iters=15)), pr0, atol=1e-5), name
        assert np.allclose(np.asarray(algorithms.bfs(rep, 0)), bfs0), name
        assert np.allclose(np.asarray(algorithms.connected_components(rep)), cc0), name
    # duplicate-insensitive algorithms also run on raw C-DUP (paper §4.1)
    cdup = engine.to_device(g)
    assert np.allclose(np.asarray(algorithms.bfs(cdup, 0)), bfs0)
    assert np.allclose(np.asarray(algorithms.connected_components(cdup)), cc0)
    assert np.allclose(np.asarray(algorithms.reachable(cdup, 0)), np.isfinite(bfs0))


@given(seed=st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_multilayer_idempotent_propagation(seed):
    rng = np.random.default_rng(seed)
    n_real = int(rng.integers(4, 12))
    g = random_multilayer_graph(n_real, [3, 4], 0.3, rng)
    exp = engine.to_device(g.expand())
    cdup = engine.to_device(g)
    bfs_exp = np.asarray(algorithms.bfs(exp, 0))
    bfs_cdup = np.asarray(algorithms.bfs(cdup, 0))
    assert np.allclose(bfs_exp, bfs_cdup)
    corr = dedup.build_correction(g)
    dc = engine.to_device(g, correction=corr)
    assert np.allclose(
        np.asarray(algorithms.pagerank(exp, num_iters=10)),
        np.asarray(algorithms.pagerank(dc, num_iters=10)),
        atol=1e-5,
    )


def test_common_neighbor_counts_keeps_duplication_signal():
    rng = np.random.default_rng(3)
    g = random_membership_graph(12, 5, 4, rng)
    rep = engine.to_device(g, drop_self_loops=False)
    M = g.expand().adjacency_multiplicity()
    seed_vec = np.zeros(12, dtype=np.float32)
    seed_vec[0] = 1.0
    got = np.asarray(algorithms.common_neighbor_counts(rep, seed_vec))
    assert np.allclose(got, M[0].astype(np.float32))


def test_vertex_program_degree():
    rng = np.random.default_rng(4)
    g = random_membership_graph(10, 4, 3, rng)
    corr = dedup.build_correction(g)
    rep = engine.to_device(g, correction=corr)
    prog = algorithms.VertexProgram(
        semiring=PLUS_TIMES,
        to_message=lambda s: np.float32(1.0) + 0.0 * s,
        compute=lambda s, m: m,
    )
    out = algorithms.vertex_program(rep, prog, np.zeros(10, dtype=np.float32), 3)
    assert np.allclose(np.asarray(out), np.asarray(algorithms.in_degrees(rep)))


@given(seed=st.integers(0, 10_000))
@settings(max_examples=15, deadline=None)
def test_personalized_pagerank_and_hits_across_reps(seed):
    rng = np.random.default_rng(seed)
    g = random_membership_graph(int(rng.integers(5, 16)), int(rng.integers(1, 6)), 3, rng)
    reps = _reps(g)
    exp = reps.pop("EXP")
    n = g.n_real
    seeds = np.zeros(n, dtype=np.float32)
    seeds[0] = 1.0
    ppr0 = np.asarray(algorithms.personalized_pagerank(exp, seeds, num_iters=15))
    h0, a0 = algorithms.hits(exp, num_iters=15)
    for name, rep in reps.items():
        ppr = np.asarray(algorithms.personalized_pagerank(rep, seeds, num_iters=15))
        assert np.allclose(ppr, ppr0, atol=1e-5), name
        h, a = algorithms.hits(rep, num_iters=15)
        assert np.allclose(np.asarray(h), np.asarray(h0), atol=1e-4), name
        assert np.allclose(np.asarray(a), np.asarray(a0), atol=1e-4), name


def test_serialize_roundtrip_and_export(tmp_path):
    from repro.core import serialize

    rng = np.random.default_rng(12)
    g = random_membership_graph(25, 8, 4, rng)
    g.node_properties["Name"] = np.array([f"n{i}" for i in range(25)])
    d = str(tmp_path / "graph")
    serialize.save_condensed(g, d)
    g2 = serialize.load_condensed(d)
    assert g2.n_real == g.n_real
    assert (g2.expand().adjacency_multiplicity()
            == g.expand().adjacency_multiplicity()).all()
    assert list(g2.node_properties["Name"]) == list(g.node_properties["Name"])
    # expanded interchange
    out = serialize.export_edge_list(g, str(tmp_path / "edges"), fmt="npz")
    data = np.load(out)
    exp = g.expand(drop_self_loops=True)
    assert data["src"].shape == exp.src.shape
    # saving is atomic: a second save replaces cleanly
    serialize.save_condensed(g, d)
    assert serialize.load_condensed(d).n_real == g.n_real
