"""Out-of-core shard assembly + multi-host tree-reduce merge (DESIGN.md §8).

The spill contract: with ``spill_dir=`` the sharded pipeline writes each
shard's assembled output to an atomically-committed, byte-accounted
record as the shard finishes, merges by log-depth tree reduce, and still
produces a ``CondensedGraph`` *byte-identical* to the unsharded build —
while the assembly-buffer account stays bounded by roughly one shard's
output instead of growing with shard count.  A partial spill directory
is rejected, never silently merged; the multi-host reduce
(``MultihostSpillExtraction``) yields the same bytes on every process.
"""
import os

import numpy as np
import pytest

from repro.core import (
    ExtractionBudget,
    ExtractionBudgetError,
    ShardSpillStore,
    SpillError,
    extract,
    extract_sharded,
    graphs_identical,
    merge_spilled_graph,
)
from repro.core.condensed import merge_chain_shards
from repro.core.dsl import parse
from repro.core.extract import (
    _build_node_space_sharded,
    _extract_shard,
    _plans_info,
    _shard_record_name,
)
from repro.core.serialize import (
    SPILL_MANIFEST,
    ShardAssembly,
    merge_assemblies,
    tree_merge_records,
)
from repro.data.synth import dblp_catalog, tpch_catalog, univ_catalog

Q_DBLP = """
Nodes(ID, Name) :- Author(ID, Name).
Edges(ID1, ID2) :- AuthorPub(ID1, PubID), AuthorPub(ID2, PubID).
"""
Q_TPCH = """
Nodes(ID, Name) :- Customer(ID, Name).
Edges(ID1, ID2) :- Orders(ok1, ID1), LineItem(ok1, pk),
                   Orders(ok2, ID2), LineItem(ok2, pk).
"""
Q_UNIV = """
Nodes(ID, Name) :- Instructor(ID, Name).
Nodes(ID, Name) :- Student(ID, Name).
Edges(ID1, ID2) :- TaughtCourse(ID1, courseId), TookCourse(ID2, courseId).
"""


@pytest.fixture(scope="module")
def dblp():
    # 401/701: indivisible by every tested shard count -> ragged last shard
    return dblp_catalog(n_authors=401, n_pubs=701, mean_authors_per_pub=5.0, seed=11)


@pytest.fixture(scope="module")
def dblp_shards(dblp):
    """Per-shard chains + local key spaces for direct merge-op tests."""
    q = parse(Q_DBLP)
    nodes, _ = _build_node_space_sharded(dblp, q.nodes_rules, 7, None)
    info = _plans_info(dblp, q, "condensed")
    assemblies = [_extract_shard(dblp, info, nodes, s, 7, None) for s in range(7)]
    return assemblies


def _assemblies_identical(a: ShardAssembly, b: ShardAssembly) -> bool:
    if sorted(a.chains) != sorted(b.chains) or sorted(a.direct) != sorted(b.direct):
        return False
    if a.dropped != b.dropped:
        return False
    for r in a.chains:
        ca, ka = a.chains[r]
        cb, kb = b.chains[r]
        if len(ca.edges) != len(cb.edges) or len(ka) != len(kb):
            return False
        for ea, eb in zip(ca.edges, cb.edges):
            if (ea.n_src, ea.n_dst) != (eb.n_src, eb.n_dst):
                return False
            if not (np.array_equal(ea.src, eb.src) and np.array_equal(ea.dst, eb.dst)):
                return False
            if ea.src.dtype != eb.src.dtype:
                return False
        for x, y in zip(ka, kb):
            if x.dtype != y.dtype or not np.array_equal(x, y):
                return False
    for r in a.direct:
        for x, y in zip(a.direct[r], b.direct[r]):
            if x.dtype != y.dtype or not np.array_equal(x, y):
                return False
    return True


# -- spill/load round trip ----------------------------------------------------

def test_spill_round_trip_byte_identical_per_shard(dblp_shards, tmp_path):
    store = ShardSpillStore(str(tmp_path / "spill"))
    for s, assembly in enumerate(dblp_shards):
        written = store.write_assembly(_shard_record_name(s), assembly)
        assert written == assembly.nbytes()
        loaded, nbytes = store.read_assembly(_shard_record_name(s))
        assert nbytes == written
        assert _assemblies_identical(assembly, loaded)


def test_spill_record_byte_accounting(tmp_path):
    store = ShardSpillStore(str(tmp_path / "spill"))
    arrays = {"a": np.arange(10, dtype=np.int64), "b": np.zeros(3, np.int32)}
    written = store.write_record("rec", arrays, meta={"x": 1})
    assert written == 10 * 8 + 3 * 4
    got, meta, nbytes = store.read_record("rec")
    assert nbytes == written and meta == {"x": 1}
    assert np.array_equal(got["a"], arrays["a"])
    assert got["b"].dtype == np.int32


# -- tree-reduce merge parity -------------------------------------------------

@pytest.mark.parametrize("arity", [2, 3])
@pytest.mark.parametrize("n_shards", [1, 2, 7])
def test_tree_reduce_chain_merge_matches_single_pass(dblp_shards, n_shards, arity):
    parts = dblp_shards[:n_shards]
    chains = [a.chains[0][0] for a in parts]
    keys = [a.chains[0][1] for a in parts]
    ref_c, ref_k = merge_chain_shards(chains, keys)  # PR-4 single pass
    got_c, got_k = merge_chain_shards(chains, keys, arity=arity)
    for ea, eb in zip(ref_c.edges, got_c.edges):
        assert (ea.n_src, ea.n_dst) == (eb.n_src, eb.n_dst)
        assert np.array_equal(ea.src, eb.src) and np.array_equal(ea.dst, eb.dst)
        assert ea.src.dtype == eb.src.dtype
    assert all(np.array_equal(a, b) for a, b in zip(ref_k, got_k))


def test_tree_reduce_rejects_bad_arity(dblp_shards):
    chains = [a.chains[0][0] for a in dblp_shards[:2]]
    keys = [a.chains[0][1] for a in dblp_shards[:2]]
    with pytest.raises(ValueError, match="arity"):
        merge_chain_shards(chains, keys, arity=1)


@pytest.mark.parametrize("arity", [2, 3])
@pytest.mark.parametrize("n_shards", [1, 2, 7])
def test_spilled_extraction_parity(dblp, tmp_path, n_shards, arity):
    base = extract(dblp, Q_DBLP)
    sp = str(tmp_path / f"spill{n_shards}_{arity}")
    got = extract_sharded(
        dblp, Q_DBLP, n_shards=n_shards, spill_dir=sp, merge_arity=arity
    )
    assert graphs_identical(base.graph, got.graph)
    assert np.array_equal(base.nodes.keys, got.nodes.keys)
    assert np.array_equal(base.nodes.type_ids, got.nodes.type_ids)
    assert base.dropped_endpoints == got.dropped_endpoints
    assert got.budget.spilled_bytes > 0
    assert got.budget.n_spilled_records >= n_shards


def test_spilled_multilayer_and_heterogeneous_parity(tmp_path):
    """Multi-layer remap (TPCH condensed) and two Nodes rules with
    properties (UNIV) both survive the spill round trip exactly."""
    tcat = tpch_catalog(seed=12)
    base = extract(tcat, Q_TPCH, mode="condensed")
    got = extract_sharded(
        tcat, Q_TPCH, n_shards=4, mode="condensed",
        spill_dir=str(tmp_path / "tpch"),
    )
    assert base.graph.chains[0].n_layers == 3
    assert graphs_identical(base.graph, got.graph)

    ucat = univ_catalog(seed=13)
    ubase = extract(ucat, Q_UNIV)
    ugot = extract_sharded(
        ucat, Q_UNIV, n_shards=5, spill_dir=str(tmp_path / "univ")
    )
    assert graphs_identical(ubase.graph, ugot.graph)
    assert np.array_equal(
        ubase.graph.node_properties["Name"], ugot.graph.node_properties["Name"]
    )


def test_merge_spilled_graph_rebuilds_without_catalog(dblp, tmp_path):
    """A finalized spill directory is self-contained: the graph comes
    back byte-identical from disk alone."""
    sp = str(tmp_path / "spill")
    got = extract_sharded(dblp, Q_DBLP, n_shards=7, spill_dir=sp)
    # fast path: read the writing run's recorded final partial
    g1, nodes1 = merge_spilled_graph(sp)
    assert graphs_identical(got.graph, g1)
    # full path: tree-reduce the shard records again, both arities
    for arity in (2, 3):
        g2, nodes2 = merge_spilled_graph(sp, merge_arity=arity, reuse_final=False)
        assert graphs_identical(got.graph, g2)
        assert np.array_equal(got.nodes.keys, nodes2.keys)
        assert np.array_equal(got.nodes.type_ids, nodes2.type_ids)
        assert got.nodes.type_names == nodes2.type_names


# -- budget accounting over assembly buffers ----------------------------------

def test_assembly_budget_raises_without_spill_and_spills_with_it(dblp, tmp_path):
    probe_mem = extract_sharded(dblp, Q_DBLP, n_shards=7)
    probe_sp = extract_sharded(
        dblp, Q_DBLP, n_shards=7, spill_dir=str(tmp_path / "probe")
    )
    # a cap between the spilled peak and the resident accumulation:
    # satisfiable only out of core
    cap = (probe_sp.budget.peak_assembly_bytes + probe_mem.budget.peak_assembly_bytes) // 2
    assert probe_sp.budget.peak_assembly_bytes < cap < probe_mem.budget.peak_assembly_bytes
    with pytest.raises(ExtractionBudgetError, match="assembly"):
        extract_sharded(dblp, Q_DBLP, n_shards=7, max_assembly_bytes=cap)
    res = extract_sharded(
        dblp, Q_DBLP, n_shards=7, max_assembly_bytes=cap,
        spill_dir=str(tmp_path / "spill"),
    )
    assert graphs_identical(extract(dblp, Q_DBLP).graph, res.graph)
    assert res.budget.peak_assembly_bytes <= cap
    assert res.budget.resident_assembly_bytes == 0  # all released


def test_spill_peak_bounded_by_two_shard_outputs(dblp, tmp_path):
    """The acceptance bound: peak resident assembly state <= 2 shards'
    outputs with spilling, vs the full accumulation without."""
    q = parse(Q_DBLP)
    nodes, _ = _build_node_space_sharded(dblp, q.nodes_rules, 7, None)
    info = _plans_info(dblp, q, "auto")
    shard_bytes = [
        _extract_shard(dblp, info, nodes, s, 7, None).nbytes() for s in range(7)
    ]
    res = extract_sharded(
        dblp, Q_DBLP, n_shards=7, spill_dir=str(tmp_path / "s")
    )
    assert res.budget.peak_assembly_bytes <= 2 * max(shard_bytes)
    mem = extract_sharded(dblp, Q_DBLP, n_shards=7)
    assert mem.budget.peak_assembly_bytes >= sum(shard_bytes)
    assert res.budget.peak_assembly_bytes < mem.budget.peak_assembly_bytes


def test_unsatisfiable_assembly_budget_raises_even_with_spill(dblp, tmp_path):
    """A single shard output bigger than the cap cannot be honored by
    spilling — it must be resident to be built."""
    with pytest.raises(ExtractionBudgetError, match="unsatisfiable|assembly"):
        extract_sharded(
            dblp, Q_DBLP, n_shards=2, max_assembly_bytes=64,
            spill_dir=str(tmp_path / "s"),
        )


def test_merge_residency_reported(dblp, tmp_path):
    res = extract_sharded(dblp, Q_DBLP, n_shards=7, spill_dir=str(tmp_path / "s"))
    assert res.budget.n_merge_rounds == 3  # ceil(log2(7)) rounds
    assert res.budget.merge_peak_resident_bytes > 0
    assert "spilled_bytes" in res.budget.summary()


# -- crash safety -------------------------------------------------------------

def test_partial_spill_missing_manifest_rejected(dblp, tmp_path):
    sp = str(tmp_path / "spill")
    extract_sharded(dblp, Q_DBLP, n_shards=3, spill_dir=sp)
    os.remove(os.path.join(sp, SPILL_MANIFEST))
    with pytest.raises(SpillError, match="partial"):
        merge_spilled_graph(sp)


def test_partial_spill_missing_record_rejected(dblp, tmp_path):
    import shutil

    sp = str(tmp_path / "spill")
    extract_sharded(dblp, Q_DBLP, n_shards=3, spill_dir=sp)
    shutil.rmtree(os.path.join(sp, _shard_record_name(1)))
    with pytest.raises(SpillError, match="missing"):
        merge_spilled_graph(sp)


def test_partial_spill_tmp_litter_rejected(dblp, tmp_path):
    sp = str(tmp_path / "spill")
    extract_sharded(dblp, Q_DBLP, n_shards=3, spill_dir=sp)
    os.makedirs(os.path.join(sp, "shard_s00099.tmp-123"))
    with pytest.raises(SpillError, match="uncommitted"):
        merge_spilled_graph(sp)


def test_truncated_spill_record_rejected(dblp, tmp_path):
    sp = str(tmp_path / "spill")
    extract_sharded(dblp, Q_DBLP, n_shards=3, spill_dir=sp)
    rec = os.path.join(sp, _shard_record_name(0), "record.json")
    os.remove(rec)
    with pytest.raises(SpillError):
        merge_spilled_graph(sp)


def test_truncated_payload_rejected(dblp, tmp_path):
    """A lost/truncated .bin (e.g. power loss after the rename) is caught
    by the size check in validate(), as SpillError — not a numpy
    reshape crash deep in the merge."""
    sp = str(tmp_path / "spill")
    extract_sharded(dblp, Q_DBLP, n_shards=3, spill_dir=sp)
    rdir = os.path.join(sp, _shard_record_name(1))
    target = next(f for f in sorted(os.listdir(rdir)) if f.endswith(".bin"))
    with open(os.path.join(rdir, target), "r+b") as f:
        f.truncate(3)
    with pytest.raises(SpillError, match="truncated"):
        merge_spilled_graph(sp)


def test_budget_object_not_mutated_by_spill_run(dblp, tmp_path):
    """A caller-supplied budget reused after a spilled run still enforces
    max_assembly_bytes on a later non-spilling run."""
    probe = extract_sharded(dblp, Q_DBLP, n_shards=7, spill_dir=str(tmp_path / "p"))
    cap = probe.budget.peak_assembly_bytes * 2  # fine for spilling, too
    budget = ExtractionBudget(max_assembly_bytes=cap)
    extract(dblp, Q_DBLP, n_shards=7, budget=budget, spill_dir=str(tmp_path / "s"))
    assert not budget.spill_enabled  # the run did not flip the flag
    budget2 = ExtractionBudget(max_assembly_bytes=cap)
    with pytest.raises(ExtractionBudgetError, match="assembly"):
        extract(dblp, Q_DBLP, n_shards=7, budget=budget2)


def test_nonexistent_spill_dir_rejected(tmp_path):
    with pytest.raises(SpillError, match="does not exist"):
        ShardSpillStore.open(str(tmp_path / "nope"))


def test_rerun_into_used_dir_invalidates_stale_manifest(dblp, tmp_path):
    """Starting a new run into a finalized spill dir removes the old
    closing manifest immediately — a crash mid-re-run leaves a *partial*
    spill (rejected), never the old manifest certifying a mix of old and
    new records."""
    sp = str(tmp_path / "spill")
    extract_sharded(dblp, Q_DBLP, n_shards=3, spill_dir=sp)
    assert ShardSpillStore.open(sp)  # finalized
    # opening for writing (what a re-run does first) drops the manifest
    ShardSpillStore(sp)
    with pytest.raises(SpillError, match="partial"):
        ShardSpillStore.open(sp)
    # a completed re-run finalizes again and is whole — including a
    # re-run with FEWER shards: stale shard records from the old run are
    # cleared, not certified into the new manifest
    res = extract_sharded(dblp, Q_DBLP, n_shards=2, spill_dir=sp)
    store = ShardSpillStore.open(sp)
    listed = store.manifest()["records"]
    assert _shard_record_name(2) not in listed  # old 3-shard leftover gone
    g, _ = merge_spilled_graph(sp)
    assert graphs_identical(res.graph, g)


def test_committed_tmp_litter_not_listed(tmp_path):
    """A tmp record dir whose record.json was fully written before the
    crash must not be listed as committed (finalize would certify it)."""
    store = ShardSpillStore(str(tmp_path / "s"))
    store.write_record("good", {"a": np.arange(4)})
    import shutil

    shutil.copytree(
        str(tmp_path / "s" / "good"), str(tmp_path / "s" / "bad.tmp-99")
    )
    assert store.list_records() == ["good"]


# -- tree_merge_records primitives --------------------------------------------

def test_tree_merge_records_matches_in_memory(dblp_shards, tmp_path):
    store = ShardSpillStore(str(tmp_path / "s"))
    names = []
    for s, a in enumerate(dblp_shards):
        names.append(_shard_record_name(s))
        store.write_assembly(names[-1], a)
    ref = merge_assemblies(list(dblp_shards))
    for arity in (2, 3):
        budget = ExtractionBudget(spill_enabled=True)
        final, in_memory = tree_merge_records(
            store, names, arity=arity, out_prefix=f"t{arity}_", budget=budget
        )
        got, _ = store.read_assembly(final)
        assert _assemblies_identical(ref, got)
        # the returned in-memory final equals the record just written
        assert in_memory is not None and _assemblies_identical(ref, in_memory)
        # leaves survive the merge (crash mid-merge loses no shard output)
        assert all(store.has_record(n) for n in names)
        assert budget.n_merge_rounds == {2: 3, 3: 2}[arity]


def test_tree_merge_records_single_record_passthrough(dblp_shards, tmp_path):
    store = ShardSpillStore(str(tmp_path / "s"))
    store.write_assembly("only", dblp_shards[0])
    assert tree_merge_records(store, ["only"]) == ("only", None)
    with pytest.raises(ValueError):
        tree_merge_records(store, [])
