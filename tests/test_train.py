"""Training substrate: optimizers, train steps, checkpointing, compression,
orchestrator state machine."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import MoEConfig, TransformerConfig
from repro.distributed import compression
from repro.launch.mesh import largest_feasible_mesh
from repro.launch.orchestrator import Heartbeat, Supervisor
from repro.models import transformer
from repro.train import checkpoint, steps
from repro.train import optimizer as opt_lib


@pytest.mark.parametrize(
    "make",
    [
        lambda: opt_lib.adamw(0.1),
        lambda: opt_lib.adamw(0.1, moment_dtype="bfloat16"),
        lambda: opt_lib.sgdm(0.05),
        lambda: opt_lib.adafactor(0.5),
    ],
    ids=["adamw", "adamw_bf16", "sgdm", "adafactor"],
)
def test_optimizers_minimize_quadratic(make):
    opt = make()
    params = {"w": jnp.array([3.0, -2.0]), "b": jnp.array(1.5)}
    state = opt.init(params)
    for i in range(200):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2) + p["b"] ** 2)(params)
        u, state = opt.update(g, state, params, i)
        params = opt_lib.apply_updates(params, u)
    assert float(opt_lib.global_norm(params)) < 0.5


def test_clip_by_global_norm():
    tree = {"a": jnp.ones(4) * 10.0}
    clipped, norm = opt_lib.clip_by_global_norm(tree, 1.0)
    assert float(norm) == pytest.approx(20.0)
    assert float(opt_lib.global_norm(clipped)) == pytest.approx(1.0, rel=1e-4)


def test_lm_train_loss_decreases_with_accumulation():
    cfg = TransformerConfig(
        name="t", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2, d_ff=64,
        vocab_size=64, microbatches=2, remat_policy="none",
    )
    key = jax.random.PRNGKey(0)
    opt = opt_lib.adamw(3e-3)
    state = steps.init_train_state(transformer.init_params(key, cfg), opt)
    step = jax.jit(steps.build_lm_train_step(cfg, opt))
    toks = jax.random.randint(key, (8, 17), 0, 64)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    first = last = None
    for _ in range(25):
        state, m = step(state, batch)
        first = first if first is not None else float(m["loss"])
        last = float(m["loss"])
    assert last < first * 0.8


def test_accumulation_matches_single_batch_gradients():
    """microbatches=N must equal one big batch up to numerics."""
    cfg1 = TransformerConfig(
        name="a", n_layers=1, d_model=16, n_heads=2, n_kv_heads=2, d_ff=32,
        vocab_size=32, microbatches=1, remat_policy="none", dtype="float32",
    )
    import dataclasses
    cfg2 = dataclasses.replace(cfg1, microbatches=4)
    key = jax.random.PRNGKey(0)
    params = transformer.init_params(key, cfg1)
    opt = opt_lib.sgdm(0.1, momentum=0.0)
    toks = jax.random.randint(key, (8, 9), 0, 32)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    s1, _ = steps.build_lm_train_step(cfg1, opt)(steps.init_train_state(params, opt), batch)
    s2, _ = steps.build_lm_train_step(cfg2, opt)(steps.init_train_state(params, opt), batch)
    d = jax.tree_util.tree_map(
        lambda a, b: float(jnp.abs(a - b).max()), s1["params"], s2["params"]
    )
    assert max(jax.tree_util.tree_leaves(d)) < 1e-5


@given(seed=st.integers(0, 1000))
@settings(max_examples=20, deadline=None)
def test_int8_compression_error_feedback(seed):
    rng = np.random.default_rng(seed)
    g = {"w": jnp.asarray(rng.standard_normal((16, 16)).astype(np.float32))}
    # single-shot quantization error is bounded
    deq, err = compression.compress_decompress(g, None)
    scale = float(jnp.max(jnp.abs(g["w"]))) / 127.0
    assert float(jnp.abs(deq["w"] - g["w"]).max()) <= scale * 0.51
    # error feedback: accumulated error stays bounded over repeats
    e = None
    total = jnp.zeros_like(g["w"])
    for _ in range(20):
        deq, e = compression.compress_decompress(g, e)
        total = total + deq["w"]
    # long-run average converges to the true gradient
    assert float(jnp.abs(total / 20 - g["w"]).max()) < scale


def test_checkpoint_restart_discovery_and_atomicity():
    with tempfile.TemporaryDirectory() as d:
        state = {"x": jnp.arange(4, dtype=jnp.float32), "n": jnp.array(3)}
        checkpoint.save_checkpoint(d, 5, state)
        checkpoint.save_checkpoint(d, 9, state)
        # simulate torn write: a .tmp dir must be ignored
        os.makedirs(os.path.join(d, "step_0000000011.tmp"))
        assert checkpoint.latest_step(d) == 9
        # losing LATEST still discovers committed steps
        os.remove(os.path.join(d, "LATEST"))
        assert checkpoint.latest_step(d) == 9
        tree, step = checkpoint.restore_checkpoint(d)
        assert step == 9 and np.allclose(tree["x"], [0, 1, 2, 3])


def test_checkpoint_roundtrip_through_train_state():
    cfg = TransformerConfig(
        name="t", n_layers=1, d_model=16, n_heads=2, n_kv_heads=1, d_ff=32,
        vocab_size=32, remat_policy="none",
    )
    opt = opt_lib.adamw(1e-3, moment_dtype="bfloat16")
    state = steps.init_train_state(
        transformer.init_params(jax.random.PRNGKey(0), cfg), opt
    )
    with tempfile.TemporaryDirectory() as d:
        mgr = checkpoint.CheckpointManager(d, async_save=False)
        mgr.save(1, state)
        restored, step = mgr.restore_latest()
        flat1 = jax.tree_util.tree_leaves(state)
        flat2 = jax.tree_util.tree_leaves(restored)
        assert len(flat1) == len(flat2)
        for a, b in zip(flat1, flat2):
            assert a.dtype == b.dtype and a.shape == b.shape
            assert np.allclose(np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_supervisor_failure_and_straggler_detection():
    sup = Supervisor(n_workers=4, heartbeat_deadline=1.0, miss_limit=2,
                     straggler_factor=2.0, checkpoint_interval=100)
    t = 1000.0
    for step in range(10):
        for w in range(4):
            dt = 1.0 if w != 2 else (1.0 if step < 5 else 3.5)
            sup.heartbeat(Heartbeat(w, step, t + dt * step))
    assert sup.workers[2].straggler
    assert sup.checkpoint_interval == 50  # adaptive cadence halved
    # worker 1 goes silent; the others keep reporting
    for t_chk in (t + 20, t + 40):
        for w in (0, 2, 3):
            sup.heartbeat(Heartbeat(w, 11, t_chk))
        sup.check_deadlines(t_chk)
    assert not sup.workers[1].alive
    assert sup.needs_remesh()
    shape, axes = sup.remesh_plan(devices_per_worker=4)
    assert shape[0] * shape[1] == 12 and axes == ("data", "model")


def test_largest_feasible_mesh():
    assert largest_feasible_mesh(512, 16) == ((32, 16), ("data", "model"))
    assert largest_feasible_mesh(496, 16) == ((31, 16), ("data", "model"))
    assert largest_feasible_mesh(30, 16) == ((2, 15), ("data", "model"))
    assert largest_feasible_mesh(7, 16) == ((1, 7), ("data", "model"))


def test_moe_dispatch_capacity_and_gates():
    from repro.models import moe as moe_lib

    cfg = MoEConfig(n_experts=4, top_k=2, d_expert=8, capacity_factor=4.0)
    params = moe_lib.moe_init(jax.random.PRNGKey(0), 16, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (32, 16))
    y, metrics = moe_lib.moe_apply(params, x, cfg)
    assert y.shape == x.shape
    assert float(metrics["moe_drop_fraction"]) == 0.0  # ample capacity
    assert float(metrics["moe_aux_loss"]) > 0
    # tight capacity drops tokens but keeps output finite
    cfg2 = MoEConfig(n_experts=4, top_k=2, d_expert=8, capacity_factor=0.1)
    y2, m2 = moe_lib.moe_apply(params, jnp.tile(x, (8, 1)), cfg2)
    assert bool(jnp.isfinite(y2).all())
    assert float(m2["moe_drop_fraction"]) > 0


def test_restore_detects_corruption_and_falls_back():
    """A torn/corrupted latest checkpoint must raise loudly; the previous
    committed step remains restorable (the orchestrator's fallback path)."""
    with tempfile.TemporaryDirectory() as d:
        state = {"x": jnp.arange(8, dtype=jnp.float32)}
        checkpoint.save_checkpoint(d, 1, state)
        checkpoint.save_checkpoint(d, 2, state)
        # corrupt step 2's data file
        target = os.path.join(d, "step_0000000002", "0000.bin")
        with open(target, "wb") as f:
            f.write(b"\x00" * 3)
        with pytest.raises(IOError):
            checkpoint.restore_checkpoint(d, step=2)
        tree, step = checkpoint.restore_checkpoint(d, step=1)
        assert step == 1 and np.allclose(tree["x"], np.arange(8))


def test_crash_resume_end_to_end():
    """Simulated mid-training crash: restart resumes from the last
    committed step and reaches the same final state as an uninterrupted
    run (step-atomic checkpoints => at most one step of lost work)."""
    from repro.configs.base import TransformerConfig
    from repro.launch.orchestrator import Supervisor, run_with_recovery

    cfg = TransformerConfig(
        name="t", n_layers=1, d_model=16, n_heads=2, n_kv_heads=1, d_ff=32,
        vocab_size=32, remat_policy="none", dtype="float32",
    )
    opt = opt_lib.sgdm(0.05, momentum=0.0)
    key = jax.random.PRNGKey(0)
    toks = jax.random.randint(key, (4, 9), 0, 32)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    step_fn = jax.jit(steps.build_lm_train_step(cfg, opt))

    def run_training(ckpt_dir, crash_at=None, total=10):
        mgr = checkpoint.CheckpointManager(ckpt_dir, keep_last=3, async_save=False)
        if mgr.latest_step() is not None:
            state, start = mgr.restore_latest()
            state = jax.tree_util.tree_map(jnp.asarray, state)
        else:
            state = steps.init_train_state(
                transformer.init_params(key, cfg), opt
            )
            start = 0
        for i in range(start, total):
            state, _ = step_fn(state, batch)
            mgr.save(i + 1, state)
            if crash_at is not None and i + 1 == crash_at:
                raise RuntimeError("simulated node failure")
        return state

    with tempfile.TemporaryDirectory() as d1, tempfile.TemporaryDirectory() as d2:
        ref = run_training(d1)  # uninterrupted
        sup = Supervisor(n_workers=1)
        attempts = {"n": 0}

        def train_once(attempt, resume):
            attempts["n"] += 1
            return run_training(d2, crash_at=4 if attempt == 0 else None)

        got = run_with_recovery(train_once, sup, max_restarts=2)
        assert attempts["n"] == 2  # crashed once, resumed once
        for a, b in zip(jax.tree_util.tree_leaves(ref["params"]),
                        jax.tree_util.tree_leaves(got["params"])):
            assert np.allclose(np.asarray(a), np.asarray(b), atol=1e-6)
