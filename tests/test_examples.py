"""Examples are part of the public API surface — run them as subprocesses
(marked slow; the quickstart doubles as the end-to-end extraction test)."""
import os
import subprocess
import sys

import pytest

REPO = os.path.join(os.path.dirname(__file__), "..")


def _run(script, timeout=600):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(
        [sys.executable, os.path.join(REPO, "examples", script)],
        capture_output=True, text=True, timeout=timeout, cwd=REPO, env=env,
    )
    assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-2000:]
    return proc.stdout


@pytest.mark.slow
def test_quickstart():
    out = _run("quickstart.py")
    assert "verified: condensed == expanded PageRank" in out


@pytest.mark.slow
def test_train_lm_short():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(
        [sys.executable, os.path.join(REPO, "examples", "train_lm.py"),
         "--steps", "12", "--batch", "2", "--seq", "32",
         "--checkpoint-dir", "/tmp/test_lm_ckpt"],
        capture_output=True, text=True, timeout=900, cwd=REPO, env=env,
    )
    assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-2000:]
    assert "done" in proc.stdout


@pytest.mark.slow
def test_serve_lm():
    out = _run("serve_lm.py")
    assert "served 7 requests" in out


@pytest.mark.slow
def test_recsys_serve():
    out = _run("recsys_serve.py")
    assert "co-interaction graph" in out


@pytest.mark.slow
def test_distributed_analytics_and_recovery():
    out = _run("graph_analytics_distributed.py", timeout=900)
    assert "results identical" in out
