"""Per-arch smoke tests: reduced configs, one forward/train step on CPU,
output shapes + finiteness (full configs are exercised only via dry-run)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.data.graphs import batch_molecules, graph_batch_from_numpy, random_graph, build_triplets
from repro.models import gnn, sasrec, transformer
from repro.train import optimizer as opt_lib
from repro.train import steps

LM_ARCHS = ["glm4-9b", "yi-9b", "llama3-405b", "granite-moe-3b-a800m",
            "moonshot-v1-16b-a3b"]
GNN_ARCHS = ["meshgraphnet", "graphcast", "schnet", "dimenet"]


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_forward_and_train(arch):
    cfg = registry.get_arch(arch).SMOKE
    key = jax.random.PRNGKey(0)
    params = transformer.init_params(key, cfg)
    toks = jax.random.randint(key, (2, 12), 0, cfg.vocab_size)
    logits, _, aux = transformer.forward(params, toks, cfg)
    assert logits.shape == (2, 12, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    # one train step
    opt = opt_lib.adamw(1e-3)
    state = steps.init_train_state(params, opt)
    step = jax.jit(steps.build_lm_train_step(cfg, opt))
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(state["step"]) == 1


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_decode_matches_full(arch):
    cfg = registry.get_arch(arch).SMOKE
    key = jax.random.PRNGKey(1)
    params = transformer.init_params(key, cfg)
    toks = jax.random.randint(key, (2, 9), 0, cfg.vocab_size)
    cache = transformer.init_cache(cfg, 2, 16)
    _, cache, _ = transformer.forward(params, toks[:, :8], cfg, cache)
    dec, _, _ = transformer.forward(params, toks[:, 8:9], cfg, cache)
    full, _, _ = transformer.forward(params, toks, cfg)
    # MoE top-k can flip under tiny numeric differences; dense must be tight
    tol = 0.2 if cfg.moe is not None else 2e-2
    assert float(jnp.abs(dec[:, 0] - full[:, 8]).max()) < tol


@pytest.mark.parametrize("arch", GNN_ARCHS)
def test_gnn_smoke_forward_and_train(arch):
    cfg = registry.get_arch(arch).SMOKE
    key = jax.random.PRNGKey(0)
    if cfg.kind in ("schnet", "dimenet"):
        g = batch_molecules(4, 8, 20, d_feat=6, seed=1)
        target = np.random.default_rng(0).standard_normal((4, cfg.d_out)).astype(np.float32)
    else:
        src, dst, feats, pos = random_graph(50, 160, 6, seed=1, with_positions=True)
        g = graph_batch_from_numpy(src, dst, feats, positions=pos)
        target = np.random.default_rng(0).standard_normal((50, cfg.d_out)).astype(np.float32)
    params = gnn.init_params(key, cfg, d_in=6)
    out = gnn.forward(params, g, cfg)
    assert out.shape == target.shape
    assert bool(jnp.isfinite(out).all())
    opt = opt_lib.adamw(1e-3)
    state = steps.init_train_state(params, opt)
    step = jax.jit(steps.build_gnn_train_step(cfg, opt))
    batch = {"graph": g, "target": jnp.asarray(target)}
    losses = []
    for _ in range(5):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert all(np.isfinite(l) for l in losses)
    assert losses[-1] < losses[0], f"{arch} loss did not drop: {losses}"


def test_sasrec_smoke():
    cfg = registry.get_arch("sasrec").SMOKE
    key = jax.random.PRNGKey(0)
    params = sasrec.init_params(key, cfg)
    seqs = jax.random.randint(key, (4, cfg.seq_len), 1, cfg.n_items)
    opt = opt_lib.adamw(1e-3)
    state = steps.init_train_state(params, opt)
    step = jax.jit(steps.build_sasrec_train_step(cfg, opt))
    batch = {
        "seqs": seqs,
        "pos": jnp.roll(seqs, -1, axis=1),
        "neg": jax.random.randint(jax.random.PRNGKey(1), seqs.shape, 1, cfg.n_items),
    }
    losses = []
    for _ in range(10):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
    scores, ids = sasrec.score_all(state["params"], seqs, cfg, top_k=5)
    assert scores.shape == (4, 5) and bool(jnp.isfinite(scores).all())
    cand = jax.random.randint(key, (4, 32), 0, cfg.n_items)
    cs = sasrec.score_candidates(state["params"], seqs, cand, cfg)
    assert cs.shape == (4, 32)


def test_graphgen_paper_smoke():
    """The paper's own config: condensed PageRank on a small instance."""
    import numpy as np
    from repro.configs.graphgen_paper import SMOKE
    from repro.core import algorithms, dedup, engine
    from conftest import random_membership_graph

    rng = np.random.default_rng(0)
    g = random_membership_graph(200, 60, 5, rng)
    corr = dedup.build_correction(g)
    dev = engine.to_device(g, correction=corr)
    pr = algorithms.pagerank(dev, num_iters=SMOKE.pagerank_iters)
    exp = engine.to_device(g.expand())
    pr_ref = algorithms.pagerank(exp, num_iters=SMOKE.pagerank_iters)
    assert np.allclose(np.asarray(pr), np.asarray(pr_ref), atol=1e-6)


def test_exact_config_numbers():
    """The registry carries the exact published configurations."""
    c = registry.get_arch("glm4-9b").CONFIG
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (40, 4096, 32, 2, 13696, 151552)
    c = registry.get_arch("yi-9b").CONFIG
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (48, 4096, 32, 4, 11008, 64000)
    c = registry.get_arch("llama3-405b").CONFIG
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (126, 16384, 128, 8, 53248, 128256)
    assert 380e9 < c.n_params() < 430e9  # ~405B
    c = registry.get_arch("granite-moe-3b-a800m").CONFIG
    assert (c.moe.n_experts, c.moe.top_k, c.d_ff) == (40, 8, 512)
    c = registry.get_arch("moonshot-v1-16b-a3b").CONFIG
    assert (c.moe.n_experts, c.moe.top_k, c.vocab_size) == (64, 6, 163840)
    assert c.n_active_params() < c.n_params() / 3
    c = registry.get_arch("meshgraphnet").CONFIG
    assert (c.n_layers, c.d_hidden) == (15, 128)
    c = registry.get_arch("graphcast").CONFIG
    assert (c.n_layers, c.d_hidden, c.n_vars) == (16, 512, 227)
    c = registry.get_arch("schnet").CONFIG
    assert (c.n_layers, c.d_hidden, c.n_rbf, c.cutoff) == (3, 64, 300, 10.0)
    c = registry.get_arch("dimenet").CONFIG
    assert (c.n_layers, c.d_hidden, c.n_bilinear, c.n_spherical,
            c.n_radial) == (6, 128, 8, 7, 6)
    c = registry.get_arch("sasrec").CONFIG
    assert (c.embed_dim, c.n_blocks, c.n_heads, c.seq_len) == (50, 2, 1, 50)
    assert len(registry.list_archs(assigned_only=True)) == 10
