import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from conftest import random_bipartite

from repro.core.condensed import BipartiteEdges
from repro.kernels.ops import PackedLayer, bitmap_spmm, condensed_two_hop
from repro.kernels.pack import TILE, pack_bipartite
from repro.kernels.ref import bitmap_spmm_ref, two_hop_ref


SHAPE_SWEEP = [
    # (n_src, n_dst, n_edges, feature_dim)
    (4, 4, 6, 1),
    (50, 40, 120, 3),
    (128, 128, 1000, 128),
    (130, 257, 900, 7),
    (300, 300, 3000, 64),
    (513, 200, 4000, 129),
]


@pytest.mark.parametrize("shape", SHAPE_SWEEP)
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_bitmap_spmm_shape_dtype_sweep(shape, dtype):
    n_src, n_dst, n_e, f = shape
    rng = np.random.default_rng(hash(shape) % 2**31)
    e = random_bipartite(n_src, n_dst, n_e, rng)
    layer = PackedLayer.from_edges(e)
    x = rng.standard_normal((n_src, f)).astype(np.float32)
    want = bitmap_spmm_ref(layer.bsb, x)
    got = bitmap_spmm(layer, jnp.asarray(x, dtype=dtype), backend="pallas")
    tol = 1e-4 if dtype == np.float32 else 0.3
    assert np.allclose(np.asarray(got, dtype=np.float32), want, atol=tol), (
        np.abs(np.asarray(got, dtype=np.float32) - want).max()
    )


@given(seed=st.integers(0, 100_000))
@settings(max_examples=25, deadline=None)
def test_bitmap_spmm_random_graphs(seed):
    rng = np.random.default_rng(seed)
    n_src = int(rng.integers(2, 300))
    n_dst = int(rng.integers(2, 300))
    n_e = int(rng.integers(1, min(n_src * n_dst, 2000)))
    f = int(rng.integers(1, 40))
    e = random_bipartite(n_src, n_dst, n_e, rng)
    layer = PackedLayer.from_edges(e)
    x = rng.standard_normal((n_src, f)).astype(np.float32)
    want = bitmap_spmm_ref(layer.bsb, x)
    got_pl = bitmap_spmm(layer, jnp.asarray(x), backend="pallas")
    got_xla = bitmap_spmm(layer, jnp.asarray(x), backend="xla")
    assert np.allclose(np.asarray(got_pl), want, atol=1e-3)
    assert np.allclose(np.asarray(got_xla), want, atol=1e-3)


def test_pack_rejects_duplicates():
    e = BipartiteEdges(np.array([0, 0]), np.array([1, 1]), 2, 2)
    with pytest.raises(ValueError):
        pack_bipartite(e)


def test_pack_roundtrip_dense():
    rng = np.random.default_rng(5)
    e = random_bipartite(200, 150, 900, rng)
    bsb = pack_bipartite(e)
    dense = bsb.to_dense()
    want = np.zeros((150, 200))
    want[e.dst, e.src] = 1
    assert (dense[:150, :200] == want).all()
    assert dense[150:].sum() == 0 and dense[:, 200:].sum() == 0
    # compression accounting: bitmaps are 32x smaller than f32 blocks
    assert bsb.nbytes() < bsb.n_nonzero_blocks * TILE * TILE * 4


def test_two_hop_matches_ref():
    rng = np.random.default_rng(9)
    e_in = random_bipartite(180, 60, 700, rng)
    e_out = e_in.reversed()
    li, lo = PackedLayer.from_edges(e_in), PackedLayer.from_edges(e_out)
    x = rng.standard_normal((180, 16)).astype(np.float32)
    got = condensed_two_hop(li, lo, jnp.asarray(x), backend="pallas")
    want = two_hop_ref(
        jnp.asarray(e_in.src), jnp.asarray(e_in.dst), 60,
        jnp.asarray(e_out.src), jnp.asarray(e_out.dst), 180, jnp.asarray(x),
    )
    assert np.allclose(np.asarray(got), np.asarray(want), atol=1e-3)


def test_vector_input_squeeze():
    rng = np.random.default_rng(11)
    e = random_bipartite(64, 64, 300, rng)
    layer = PackedLayer.from_edges(e)
    x = rng.standard_normal(64).astype(np.float32)
    y = bitmap_spmm(layer, jnp.asarray(x), backend="pallas")
    assert y.shape == (64,)
    want = bitmap_spmm_ref(layer.bsb, x[:, None])[:, 0]
    assert np.allclose(np.asarray(y), want, atol=1e-4)


FLASH_SWEEP = [
    # (B, T, H, KV, D, bq, bkv, causal)
    (1, 64, 2, 1, 8, 16, 16, True),
    (2, 128, 4, 2, 16, 32, 64, True),
    (1, 96, 4, 4, 8, 32, 32, False),
    (2, 100, 2, 1, 8, 16, 16, True),     # ragged q -> padded
    (1, 256, 8, 2, 32, 128, 128, True),  # MXU-aligned blocks
]


@pytest.mark.parametrize("shape", FLASH_SWEEP)
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_pallas_flash_attention_sweep(shape, dtype):
    import jax
    from repro.kernels.flash_attention import flash_attention_pallas

    B, T, H, KV, D, bq, bkv, causal = shape
    rng = np.random.default_rng(hash(shape) % 2**31)
    q = jnp.asarray(rng.standard_normal((B, T, H, D)), dtype=dtype)
    k = jnp.asarray(rng.standard_normal((B, T, KV, D)), dtype=dtype)
    v = jnp.asarray(rng.standard_normal((B, T, KV, D)), dtype=dtype)

    def naive(q, k, v):
        G = H // KV
        qg = q.astype(jnp.float32).reshape(B, T, KV, G, D)
        s = jnp.einsum("bqkgd,bskd->bqkgs", qg, k.astype(jnp.float32))
        s = s / np.sqrt(D)
        if causal:
            mask = jnp.arange(T)[None, :] <= jnp.arange(T)[:, None]
            s = jnp.where(mask[None, :, None, None, :], s, -jnp.inf)
        p = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bqkgs,bskd->bqkgd", p, v.astype(jnp.float32)).reshape(B, T, H, D)

    out = flash_attention_pallas(q, k, v, causal=causal, block_q=bq, block_kv=bkv)
    ref = naive(q, k, v)
    tol = 2e-5 if dtype == np.float32 else 0.05
    assert float(jnp.abs(out.astype(jnp.float32) - ref).max()) < tol
