import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from conftest import random_membership_graph, random_multilayer_graph

from repro.core.condensed import BipartiteEdges, Chain, CondensedGraph


def test_fig1_coauthor_example():
    # Paper Figure 1: a1 & a4 share p1 and p2 -> multiplicity 2.
    ap = np.array([[1, 1], [1, 2], [4, 1], [4, 2], [2, 1], [3, 3], [0, 3]])
    e_in = BipartiteEdges(ap[:, 0], ap[:, 1], 5, 4)
    g = CondensedGraph(5, [Chain([e_in, e_in.reversed()])])
    M = g.expand().adjacency_multiplicity()
    assert M[1, 4] == 2 and M[4, 1] == 2
    assert M[1, 2] == 1
    assert M[0, 3] == 1
    assert g.duplication_ratio() > 1.0


def test_validation():
    with pytest.raises(ValueError):
        BipartiteEdges(np.array([0, 5]), np.array([0, 0]), 3, 2)  # src oob
    with pytest.raises(ValueError):
        Chain([BipartiteEdges(np.array([0]), np.array([0]), 2, 3)])  # 1 level
    e = BipartiteEdges(np.array([0]), np.array([0]), 2, 3)
    f = BipartiteEdges(np.array([0]), np.array([0]), 4, 2)
    with pytest.raises(ValueError):
        Chain([e, f])  # size mismatch


@given(seed=st.integers(0, 10_000))
@settings(max_examples=50, deadline=None)
def test_multilayer_expand_matches_matrix_product(seed):
    rng = np.random.default_rng(seed)
    n_real = int(rng.integers(3, 12))
    layers = [int(rng.integers(2, 6)) for _ in range(int(rng.integers(1, 4)))]
    g = random_multilayer_graph(n_real, layers, 0.3, rng)
    M = g.expand().adjacency_multiplicity()
    # oracle: dense chain product
    levels = [n_real] + layers + [n_real]
    P = np.eye(n_real, dtype=np.int64)
    for e in g.chains[0].edges:
        B = np.zeros((e.n_src, e.n_dst), dtype=np.int64)
        np.add.at(B, (e.src, e.dst), 1)
        P = P @ B
    assert (M == P).all()


@given(seed=st.integers(0, 10_000))
@settings(max_examples=50, deadline=None)
def test_preprocess_preserves_multiplicities(seed):
    rng = np.random.default_rng(seed)
    g = random_membership_graph(int(rng.integers(4, 25)), int(rng.integers(1, 8)), 3, rng)
    g2 = g.preprocess()
    assert (g2.expand().adjacency_multiplicity() == g.expand().adjacency_multiplicity()).all()
    # step-6 rule removes only cheap virtual nodes
    assert g2.n_virtual <= g.n_virtual


def test_counts_and_bytes():
    rng = np.random.default_rng(1)
    g = random_membership_graph(30, 10, 4, rng)
    assert g.n_edges_condensed == sum(c.n_edges for c in g.chains)
    assert g.nbytes() > 0
    assert g.is_single_layer()
    exp = g.expand()
    assert exp.n_edges == g.n_edges_expanded()
    no_self = exp.without_self_loops()
    assert no_self.n_edges <= exp.n_edges
    assert (no_self.src != no_self.dst).all()
