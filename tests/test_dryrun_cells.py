"""Cell-builder structure tests (host mesh, no 512-device compile) and a
subprocess smoke of the real dry-run CLI on the paper's analytics cell."""
import json
import os
import subprocess
import sys

import jax
import pytest

from repro.configs import registry, shapes
from repro.launch import cells as cells_lib
from repro.launch.mesh import make_host_mesh
from repro.launch.roofline import model_flops

REPO = os.path.join(os.path.dirname(__file__), "..")


def test_all_cells_enumerates_40():
    cells = cells_lib.all_cells()
    assert len(cells) == 40
    archs = {a for a, _ in cells}
    assert len(archs) == 10


@pytest.mark.parametrize("arch,shape", cells_lib.all_cells())
def test_cell_builds_structurally(arch, shape):
    """ShapeDtypeStructs + shardings assemble for every assigned cell."""
    mesh = make_host_mesh()
    cell = cells_lib.build_cell(arch, shape, mesh)
    args_leaves = jax.tree_util.tree_leaves(cell.args)
    sh_leaves = jax.tree_util.tree_leaves(
        cell.in_shardings, is_leaf=lambda x: hasattr(x, "spec")
    )
    assert args_leaves, (arch, shape)
    assert all(isinstance(a, jax.ShapeDtypeStruct) for a in args_leaves)
    assert len(args_leaves) == len(sh_leaves), (arch, shape)
    assert model_flops(arch, shape) > 0


def test_lm_shapes_exact():
    s = shapes.LM_SHAPES
    assert (s["train_4k"].seq_len, s["train_4k"].global_batch) == (4096, 256)
    assert (s["prefill_32k"].seq_len, s["prefill_32k"].global_batch) == (32768, 32)
    assert (s["decode_32k"].seq_len, s["decode_32k"].global_batch) == (32768, 128)
    assert (s["long_500k"].seq_len, s["long_500k"].global_batch) == (524288, 1)
    g = shapes.GNN_SHAPES
    assert g["full_graph_sm"].raw_nodes == 2708 and g["full_graph_sm"].d_feat == 1433
    assert g["ogb_products"].raw_edges == 61_859_140
    assert g["minibatch_lg"].raw_nodes == 1024 + 1024 * 15 + 15360 * 10
    r = shapes.REC_SHAPES
    assert r["train_batch"].batch == 65536
    assert r["retrieval_cand"].n_candidates == 1_000_000


@pytest.mark.slow
def test_dryrun_cli_subprocess():
    """The real dry-run entry point (512 host devices) on the cheapest cell."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "graphgen-paper", "--shape", "pagerank", "--mesh", "multi"],
        capture_output=True, text=True, timeout=900, cwd=REPO, env=env,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "all dry-run cells OK" in proc.stdout
    out = os.path.join(REPO, "results", "dryrun",
                       "graphgen-paper__pagerank__multi.json")
    with open(out) as f:
        rec = json.load(f)
    assert rec["ok"] and rec["n_chips"] == 512
    assert rec["collective_s"] > 0  # sharded segment-sums must communicate


def test_hlo_cost_trip_count_linearity():
    """The loop-aware analyzer must scale flops linearly in scan length
    (the exact failure mode of XLA's stock cost_analysis)."""
    import jax.numpy as jnp
    from repro.launch.hlo_cost import analyze_hlo

    w = jnp.zeros((32, 32))

    def make(n):
        def f(x):
            y, _ = jax.lax.scan(lambda c, _: (jnp.tanh(c @ w), None), x, None,
                                length=n)
            return y
        comp = jax.jit(f).lower(jax.ShapeDtypeStruct((32, 32), jnp.float32)).compile()
        return analyze_hlo(comp.as_text()).flops

    f5, f20 = make(5), make(20)
    assert 3.5 < f20 / f5 < 4.5, (f5, f20)


def test_hlo_cost_collective_split_multi_pod_groups():
    """Iota replica_groups spanning the pod boundary must count as DCI."""
    from repro.launch.hlo_cost import _decode_groups
    import numpy as np

    # pod-axis groups on a (2, 256) layout: {i, i+256}
    g = _decode_groups("replica_groups=[256,2]<=[2,256]T(1,0)")
    assert g.shape == (256, 2)
    assert (g[:, 1] - g[:, 0] == 256).all()
    crosses = ((g // 256).max(axis=1) != (g // 256).min(axis=1)).any()
    assert bool(crosses)
    # within-pod groups: consecutive ids
    g2 = _decode_groups("replica_groups=[256,2]<=[512]")
    crosses2 = ((g2 // 256).max(axis=1) != (g2 // 256).min(axis=1)).any()
    assert not bool(crosses2)
