"""Sharded extraction parity suite (DESIGN.md §7).

The merge-step contract: for any shard count — including one shard, a
ragged last shard, and shards with no rows at all — the sharded pipeline
must produce a ``CondensedGraph`` and ``NodeSpace`` *byte-identical* to
the unsharded build (same arrays, same order, same dtypes), and the
threaded ``ExtractionBudget`` must enforce its per-shard resident-row
limit by raising, never by spilling.
"""
import numpy as np
import pytest

from repro.core import (
    ExtractionBudget,
    ExtractionBudgetError,
    extract,
    extract_sharded,
    graphs_identical,
)
from repro.core.condensed import BipartiteEdges, merge_sorted_unique
from repro.core.extract import NodeSpace
from repro.core.relational import (
    Catalog,
    ShardedTable,
    Table,
    hash_partition,
    shard_bounds,
)
from repro.data.synth import dblp_catalog, tpch_catalog, univ_catalog

Q_DBLP = """
Nodes(ID, Name) :- Author(ID, Name).
Edges(ID1, ID2) :- AuthorPub(ID1, PubID), AuthorPub(ID2, PubID).
"""
Q_TPCH = """
Nodes(ID, Name) :- Customer(ID, Name).
Edges(ID1, ID2) :- Orders(ok1, ID1), LineItem(ok1, pk),
                   Orders(ok2, ID2), LineItem(ok2, pk).
"""
Q_UNIV = """
Nodes(ID, Name) :- Instructor(ID, Name).
Nodes(ID, Name) :- Student(ID, Name).
Edges(ID1, ID2) :- TaughtCourse(ID1, courseId), TookCourse(ID2, courseId).
"""


@pytest.fixture(scope="module")
def dblp():
    # 401 authors / 701 pubs: indivisible by every tested shard count, so
    # the last shard is always ragged
    return dblp_catalog(n_authors=401, n_pubs=701, mean_authors_per_pub=5.0, seed=11)


def _assert_parity(catalog, query, n_shards, mode="auto", preprocess=False):
    base = extract(catalog, query, mode=mode, preprocess=preprocess)
    got = extract_sharded(
        catalog, query, n_shards=n_shards, mode=mode, preprocess=preprocess
    )
    assert graphs_identical(base.graph, got.graph)
    assert np.array_equal(base.nodes.keys, got.nodes.keys)
    assert base.nodes.keys.dtype == got.nodes.keys.dtype
    assert np.array_equal(base.nodes.type_ids, got.nodes.type_ids)
    assert base.nodes.type_names == got.nodes.type_names
    assert base.dropped_endpoints == got.dropped_endpoints
    assert got.n_shards == n_shards
    assert got.budget is not None
    return base, got


@pytest.mark.parametrize("n_shards", [1, 2, 7])
@pytest.mark.parametrize("mode", ["auto", "condensed", "expanded"])
def test_dblp_parity_all_modes(dblp, n_shards, mode):
    _assert_parity(dblp, Q_DBLP, n_shards, mode=mode)


@pytest.mark.parametrize("n_shards", [2, 7])
def test_tpch_multilayer_parity(n_shards):
    cat = tpch_catalog(seed=12)
    base, got = _assert_parity(cat, Q_TPCH, n_shards, mode="condensed")
    # the condensed plan must really be multi-layer for this to test the
    # local->global virtual-id remap across several layers
    assert base.graph.chains[0].n_layers == 3


@pytest.mark.parametrize("n_shards", [2, 5])
def test_univ_heterogeneous_parity(n_shards):
    """Two Nodes rules: the sorted-key NodeSpace union must keep the
    first-rule-wins type assignment and the property scatter order."""
    cat = univ_catalog(seed=13)
    base, got = _assert_parity(cat, Q_UNIV, n_shards)
    assert "Name" in got.graph.node_properties
    assert np.array_equal(
        base.graph.node_properties["Name"], got.graph.node_properties["Name"]
    )


def test_preprocess_parity(dblp):
    _assert_parity(dblp, Q_DBLP, 3, mode="condensed", preprocess=True)


def test_selection_predicate_parity(dblp):
    q = """
    Nodes(ID, Name) :- Author(ID, Name).
    Edges(ID1, ID2) :- AuthorPub(ID1, PubID), Pub(PubID, year),
                       AuthorPub(ID2, PubID), year > 2010.
    """
    _assert_parity(dblp, q, 4)


def test_empty_shards_parity():
    """More shards than rows: trailing shards are empty, the merge must
    still reproduce the unsharded build exactly."""
    tiny = dblp_catalog(n_authors=6, n_pubs=5, mean_authors_per_pub=2.0, seed=14)
    for mode in ("auto", "condensed"):
        _assert_parity(tiny, Q_DBLP, 50, mode=mode)


def test_empty_node_space_sharded(dblp):
    """A Nodes statement matching zero rows: every shard is empty and the
    merged space finds nothing — same contract as the unsharded path."""
    q = """
    Nodes(ID, Name) :- Author(ID, Name), ID < 0.
    Edges(ID1, ID2) :- AuthorPub(ID1, PubID), AuthorPub(ID2, PubID).
    """
    base, got = _assert_parity(dblp, q, 3, mode="condensed")
    assert got.graph.n_real == 0
    assert got.dropped_endpoints > 0


# -- budget accounting -------------------------------------------------------

def test_budget_violation_raises(dblp):
    with pytest.raises(ExtractionBudgetError):
        extract_sharded(dblp, Q_DBLP, n_shards=2, max_resident_rows=10)


def test_budget_enforced_not_spilled(dblp):
    """A satisfiable budget passes and the accounting is the evidence:
    peak per-shard residency never exceeded the cap."""
    probe = extract_sharded(dblp, Q_DBLP, n_shards=8, mode="condensed")
    cap = probe.budget.peak_resident_rows
    res = extract_sharded(
        dblp, Q_DBLP, n_shards=8, mode="condensed", max_resident_rows=cap
    )
    assert res.budget.max_resident_rows == cap
    assert res.budget.peak_resident_rows <= cap
    # one fewer shard means bigger blocks: the same cap must now fail
    with pytest.raises(ExtractionBudgetError):
        extract_sharded(
            dblp, Q_DBLP, n_shards=2, mode="condensed",
            max_resident_rows=max(cap // 3, 1),
        )


def test_budget_shrinks_with_shard_count(dblp):
    p1 = extract_sharded(dblp, Q_DBLP, n_shards=1, mode="condensed")
    p8 = extract_sharded(dblp, Q_DBLP, n_shards=8, mode="condensed")
    assert p8.budget.peak_resident_rows < p1.budget.peak_resident_rows
    assert p8.budget.n_shards_processed > p1.budget.n_shards_processed
    assert len(p8.budget.shard_peaks) == p8.budget.n_shards_processed
    assert max(p8.budget.shard_peaks) == p8.budget.peak_resident_rows
    assert p8.budget.resident_rows == 0  # everything released at the end


def test_budget_forces_instrumented_path(dblp):
    """budget alone (n_shards=1) routes through the sharded pipeline and
    still reproduces the unsharded build byte-for-byte."""
    base = extract(dblp, Q_DBLP)
    got = extract(dblp, Q_DBLP, budget=ExtractionBudget())
    assert graphs_identical(base.graph, got.graph)
    assert got.budget.peak_resident_rows > 0


# -- NodeSpace sort invariant (the hoisted lookup precondition) --------------

def test_node_space_rejects_unsorted_keys():
    with pytest.raises(ValueError, match="sorted"):
        NodeSpace(
            keys=np.array([3, 1, 2]),
            type_ids=np.zeros(3, dtype=np.int32),
            type_names=["t"],
        )


def test_node_space_rejects_duplicate_keys():
    with pytest.raises(ValueError, match="sorted"):
        NodeSpace(
            keys=np.array([1, 2, 2]),
            type_ids=np.zeros(3, dtype=np.int32),
            type_names=["t"],
        )


def test_node_space_accepts_sorted_and_empty():
    s = NodeSpace(
        keys=np.array([1, 5, 9]),
        type_ids=np.zeros(3, dtype=np.int32),
        type_names=["t"],
    )
    idx, found = s.lookup(np.array([5, 7]))
    assert idx[0] == 1 and found[0] and not found[1]
    empty = NodeSpace(
        keys=np.empty(0, dtype=np.int64),
        type_ids=np.empty(0, dtype=np.int32),
        type_names=[],
    )
    _, found = empty.lookup(np.array([1]))
    assert not found.any()


# -- sharded table views -----------------------------------------------------

def test_shard_bounds_cover_and_order():
    for n, k in [(10, 3), (7, 7), (3, 8), (0, 4), (100, 1)]:
        bounds = shard_bounds(n, k)
        assert len(bounds) == k
        flat = [i for lo, hi in bounds for i in range(lo, hi)]
        assert flat == list(range(n))
    with pytest.raises(ValueError):
        shard_bounds(5, 0)


def test_sharded_table_rows_mode_reassembles():
    t = Table("T", {"a": np.arange(11), "b": np.arange(11) % 3})
    st = ShardedTable(t, 4)
    assert len(st) == 4
    assert sum(st.shard_rows(s) for s in range(4)) == 11
    re = np.concatenate([st.shard(s).column("a") for s in range(4)])
    assert np.array_equal(re, t.column("a"))
    # per-shard stats: shard 0 holds rows [0, 3) of column a
    assert st.stats(0, "a").n_distinct == 3
    assert st.stats(0, "a").max_value == 2.0


def test_sharded_table_hash_mode_colocates_keys():
    rng = np.random.default_rng(7)
    keys = rng.integers(0, 20, size=200)
    t = Table("T", {"k": keys, "v": np.arange(200)})
    st = ShardedTable(t, 5, mode="hash", key="k")
    # every key lands in exactly one shard, and the union is the table
    seen = {}
    total = 0
    for s in range(5):
        sh = st.shard(s)
        total += len(sh)
        for k in np.unique(sh.column("k")):
            assert seen.setdefault(int(k), s) == s
    assert total == 200
    # shard assignment matches the hash function's contract
    sid = hash_partition(keys, 5)
    assert np.array_equal(sid, hash_partition(keys.copy(), 5))
    with pytest.raises(ValueError):
        ShardedTable(t, 3, mode="hash")  # key required
    with pytest.raises(ValueError):
        ShardedTable(t, 3, mode="banana")


def test_hash_partition_cross_table_consistent():
    """The join-key contract: the same key must land in the same shard no
    matter which table (or key population) it sits in — otherwise
    per-shard joins of two hash-partitioned sides would drop matches."""
    rng = np.random.default_rng(9)
    r_keys = rng.integers(0, 1000, size=500)
    s_keys = np.concatenate([r_keys[::3], rng.integers(1000, 2000, size=200)])
    for n in (2, 5, 9):
        r_sid = hash_partition(r_keys, n)
        s_sid = hash_partition(s_keys, n)
        common = np.intersect1d(r_keys, s_keys)
        for k in common:
            assert (
                r_sid[r_keys == k][0] == s_sid[s_keys == k][0]
            ), f"key {k} split across shards"
    # string keys use value-determined codes too
    a = np.array(["alpha", "beta", "gamma"])
    b = np.array(["gamma", "delta", "alpha", "zz"])
    ha, hb = hash_partition(a, 4), hash_partition(b, 4)
    assert ha[2] == hb[0] and ha[0] == hb[2]


# -- merge primitives --------------------------------------------------------

def test_merge_sorted_unique_matches_global():
    rng = np.random.default_rng(3)
    vals = rng.integers(0, 50, size=300)
    parts = np.array_split(vals, 6)
    merged = merge_sorted_unique([np.unique(p) for p in parts])
    assert np.array_equal(merged, np.unique(vals))
    assert merge_sorted_unique([]).size == 0


# -- shard-at-a-time packing -------------------------------------------------

def test_pack_bipartite_sharded_byte_identical():
    from repro.kernels.pack import merge_block_sparse, pack_bipartite

    rng = np.random.default_rng(21)
    key = rng.choice(400 * 300, size=5000, replace=False)
    e = BipartiteEdges(key % 400, key // 400, 400, 300)
    base = pack_bipartite(e)
    for k in (17, 512, 4999, 6000):
        got = pack_bipartite(e, shard_edges=k)
        for f in ("slot_src", "slot_row", "bitmaps", "row_start", "row_count"):
            assert np.array_equal(getattr(base, f), getattr(got, f)), (k, f)
        assert (got.n_dst, got.n_src) == (base.n_dst, base.n_src)
    # overlapping shards are duplicate edges: rejected like the one-shot pack
    p1 = pack_bipartite(BipartiteEdges([0, 1], [0, 1], 4, 4))
    p2 = pack_bipartite(BipartiteEdges([1, 2], [1, 2], 4, 4))
    with pytest.raises(ValueError, match="disjoint"):
        merge_block_sparse([p1, p2])
    with pytest.raises(ValueError):
        merge_block_sparse([])


def test_to_device_packed_shard_at_a_time(dblp):
    """Engine wiring: packed operands built shard-at-a-time equal the
    one-shot ones, so kernel dispatch sees identical layouts."""
    from repro.core import engine

    g = extract(dblp, Q_DBLP, mode="condensed").graph
    one = engine.to_device_packed(g)
    sharded = engine.to_device_packed(g, pack_shard_edges=256)
    for ca, cb in zip(one.chains, sharded.chains):
        for la, lb in zip(ca, cb):
            assert (la.fwd is None) == (lb.fwd is None)
            if la.fwd is not None:
                assert np.array_equal(
                    np.asarray(la.fwd.bitmaps), np.asarray(lb.fwd.bitmaps)
                )
                assert np.array_equal(
                    np.asarray(la.rev.bitmaps), np.asarray(lb.rev.bitmaps)
                )
                assert np.array_equal(
                    np.asarray(la.fwd.slot_src), np.asarray(lb.fwd.slot_src)
                )


# -- end-to-end pipeline + multi-host shard ranges ---------------------------

def test_sharded_extract_to_device_pipeline():
    from repro.core import algorithms
    from repro.data.pipeline import sharded_extract_to_device

    cat = dblp_catalog(n_authors=120, n_pubs=200, mean_authors_per_pub=4.0, seed=15)
    res, dev = sharded_extract_to_device(cat, Q_DBLP, n_shards=3, packed=False)
    assert res.budget.peak_resident_rows > 0
    pr = np.asarray(algorithms.pagerank(dev, num_iters=5))
    assert pr.shape == (res.graph.n_real,)
    assert np.isfinite(pr).all()


def test_extraction_shard_range_partitions():
    from repro.distributed.sharding import extraction_shard_range

    for n_shards, procs in [(10, 4), (3, 8), (16, 1), (5, 5)]:
        covered = []
        for p in range(procs):
            covered.extend(extraction_shard_range(n_shards, p, procs))
        assert covered == list(range(n_shards))
    assert list(extraction_shard_range(4, 0, 1)) == [0, 1, 2, 3]
    with pytest.raises(ValueError):
        extraction_shard_range(4, 2, 2)
