import os
import sys

# Allow `pytest tests/` without PYTHONPATH=src (docs still recommend it).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest

from repro.core.condensed import BipartiteEdges, Chain, CondensedGraph
from repro.core.dedup import graph_from_membership


def random_membership_graph(n_real, n_virt, avg_size, rng):
    """Random symmetric single-layer condensed graph (membership sets)."""
    sets = []
    for _ in range(n_virt):
        k = max(2, int(rng.poisson(avg_size)))
        sets.append(
            set(rng.choice(n_real, size=min(k, n_real), replace=False).tolist())
        )
    return graph_from_membership(n_real, sets)


def random_bipartite(n_src, n_dst, n_edges, rng, unique=True):
    total = n_src * n_dst
    n_edges = min(n_edges, total)
    if unique:
        key = rng.choice(total, size=n_edges, replace=False)
    else:
        key = rng.integers(0, total, size=n_edges)
    return BipartiteEdges(key % n_src, key // n_src, n_src, n_dst)


def random_multilayer_graph(n_real, layer_sizes, density, rng):
    levels = [n_real] + list(layer_sizes) + [n_real]
    edges = []
    for a, b in zip(levels, levels[1:]):
        n_e = max(2, int(a * b * density))
        edges.append(random_bipartite(a, b, n_e, rng))
    return CondensedGraph(n_real, [Chain(edges)])


def expanded_simple_pairs(g):
    s, d, m = g.multiplicities()
    off = s != d
    return set(zip(s[off].tolist(), d[off].tolist()))


@pytest.fixture
def rng():
    return np.random.default_rng(0)
