import os
import sys
import types

# Allow `pytest tests/` without PYTHONPATH=src (docs still recommend it).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest

# ---------------------------------------------------------------------------
# Optional-dependency shim: `hypothesis` is not installable in the offline
# container.  When absent, install a stub into sys.modules *before* test
# modules import it, so each module still collects; property-based tests
# (anything decorated with the stub `@given`) skip at runtime while the
# plain tests in the same module run normally.
# ---------------------------------------------------------------------------

try:  # pragma: no cover - trivial branch
    import hypothesis

    # Fixed-seed profile for the check.sh --tier2-oracle gate: derandomized
    # example generation, so a red run reproduces locally with the same
    # command (select with HYPOTHESIS_PROFILE=oracle-ci).
    hypothesis.settings.register_profile(
        "oracle-ci", hypothesis.settings(derandomize=True, deadline=None)
    )
    if os.environ.get("HYPOTHESIS_PROFILE"):
        hypothesis.settings.load_profile(os.environ["HYPOTHESIS_PROFILE"])
except ModuleNotFoundError:

    class _Anything:
        """Stands in for strategy objects; inert under any fluent call."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    def _given(*strategy_args, **strategy_kwargs):
        """Replace the test with a skipper whose signature drops the
        strategy-filled arguments (so ``@pytest.mark.parametrize`` stacked
        outside ``@given`` keeps working).  Positional strategies fill the
        *rightmost* parameters (hypothesis semantics), keyword strategies
        fill by name."""

        def deco(fn):
            import functools
            import inspect

            @functools.wraps(fn)
            def skipped(*a, **k):
                pytest.skip("hypothesis not installed")

            sig = inspect.signature(fn)
            params = list(sig.parameters.values())
            if strategy_args:
                params = params[: len(params) - len(strategy_args)]
            kept = [p for p in params if p.name not in strategy_kwargs]
            skipped.__signature__ = sig.replace(parameters=kept)
            return skipped

        return deco

    def _identity_decorator(*_args, **_kwargs):
        def deco(fn):
            return fn

        return deco

    _stub = types.ModuleType("hypothesis")
    _stub.given = _given
    _stub.settings = _identity_decorator
    _stub.assume = lambda *a, **k: True
    _stub.note = lambda *a, **k: None
    _stub.HealthCheck = _Anything()
    _strategies = types.ModuleType("hypothesis.strategies")

    def _strategies_getattr(name):
        return _Anything()

    _strategies.__getattr__ = _strategies_getattr
    _stub.strategies = _strategies
    sys.modules["hypothesis"] = _stub
    sys.modules["hypothesis.strategies"] = _strategies

from repro.core.condensed import BipartiteEdges, Chain, CondensedGraph
from repro.core.dedup import graph_from_membership


def random_membership_graph(n_real, n_virt, avg_size, rng):
    """Random symmetric single-layer condensed graph (membership sets)."""
    sets = []
    for _ in range(n_virt):
        k = max(2, int(rng.poisson(avg_size)))
        sets.append(
            set(rng.choice(n_real, size=min(k, n_real), replace=False).tolist())
        )
    return graph_from_membership(n_real, sets)


def random_bipartite(n_src, n_dst, n_edges, rng, unique=True):
    total = n_src * n_dst
    n_edges = min(n_edges, total)
    if unique:
        key = rng.choice(total, size=n_edges, replace=False)
    else:
        key = rng.integers(0, total, size=n_edges)
    return BipartiteEdges(key % n_src, key // n_src, n_src, n_dst)


def random_multilayer_graph(n_real, layer_sizes, density, rng):
    levels = [n_real] + list(layer_sizes) + [n_real]
    edges = []
    for a, b in zip(levels, levels[1:]):
        n_e = max(2, int(a * b * density))
        edges.append(random_bipartite(a, b, n_e, rng))
    return CondensedGraph(n_real, [Chain(edges)])


def expanded_simple_pairs(g):
    s, d, m = g.multiplicities()
    off = s != d
    return set(zip(s[off].tolist(), d[off].tolist()))


@pytest.fixture
def rng():
    return np.random.default_rng(0)
