"""Incremental extraction (DESIGN.md §9).

The delta contract: ``LiveGraph.apply_delta`` over any sequence of row
inserts/deletes produces a graph *byte-identical* (``graphs_identical``
— dtypes, shapes, values, order, properties) to a fresh ``extract`` of
the mutated catalog, at a fraction of the work — untouched rules are
reused, touched single-atom segments rebind only the mutated table.
``mutate_catalog`` is the executable reference for the mutation
semantics (deletes first, inserts appended at the tail).

The durability contract mirrors the extraction spill store
(tests/test_extract_spill.py): a ``DeltaLog`` append is
record-then-manifest, so a crash leaves either tmp litter or an
uncertified tail — both rejected at ``open`` and dropped by
``recover=True`` — and replaying the certified prefix over the base
catalog rebuilds the last acknowledged graph exactly.
"""
import os

import numpy as np
import pytest

from hypothesis import given, settings, strategies as st

from repro.core import (
    DeltaLog,
    ExtractionBudget,
    LiveGraph,
    SpillError,
    apply_delta,
    extract,
    graphs_identical,
    mutate_catalog,
)
from repro.core.serialize import SPILL_MANIFEST
from repro.data.synth import dblp_catalog, tpch_catalog

Q_DBLP = """
Nodes(ID, Name) :- Author(ID, Name).
Edges(ID1, ID2) :- AuthorPub(ID1, PubID), AuthorPub(ID2, PubID).
"""
Q_TPCH = """
Nodes(ID, Name) :- Customer(ID, Name).
Edges(ID1, ID2) :- Orders(ok1, ID1), LineItem(ok1, pk),
                   Orders(ok2, ID2), LineItem(ok2, pk).
"""


@pytest.fixture(scope="module")
def dblp():
    return dblp_catalog(n_authors=300, n_pubs=600, mean_authors_per_pub=4.0, seed=0)


def _ap_inserts(aids, pids):
    return {"AuthorPub": {"aid": np.asarray(aids, np.int64),
                          "pid": np.asarray(pids, np.int64)}}


# -- byte-identity against fresh extraction of the mutated catalog -----------

@pytest.mark.parametrize("mode", ["auto", "condensed", "expanded"])
def test_base_build_matches_extract(dblp, mode):
    live = LiveGraph(dblp, Q_DBLP, mode=mode)
    ref = extract(dblp, Q_DBLP, mode=mode)
    assert live.version == 0
    assert graphs_identical(live.graph, ref.graph)


def test_empty_delta_is_identity_but_bumps_version(dblp):
    live = LiveGraph(dblp, Q_DBLP)
    base = extract(dblp, Q_DBLP)
    g, v = live.apply_delta()
    assert int(v) == 1 and live.version == 1
    assert graphs_identical(g, base.graph)


@pytest.mark.parametrize("mode", ["auto", "condensed", "expanded"])
def test_insert_delete_sequence_byte_identical(dblp, mode):
    """The acceptance sequence: non-node inserts, deletes,
    delete-then-reinsert of a node key, then a mixed delta — each step
    byte-identical to extracting the mutated catalog from scratch."""
    live = LiveGraph(dblp, Q_DBLP, mode=mode)
    cat = dblp
    steps = [
        (_ap_inserts([1, 2, 299], [1000001, 1000001, 1000002]), None),
        (None, {"AuthorPub": ("pid", np.array([1000003, 1000004]))}),
        # delete an Author then reinsert the same key with a new name,
        # plus a brand-new author: tombstone + tail insert in one delta
        ({"Author": {"aid": np.array([5, 300]),
                     "name": np.array(["author_5b", "author_300"])}},
         {"Author": ("aid", np.array([5]))}),
        ({"AuthorPub": {"aid": np.array([300]), "pid": np.array([1000005])},
          "Author": {"aid": np.array([301]), "name": np.array(["author_301"])}},
         {"AuthorPub": ("aid", np.array([7]))}),
    ]
    for i, (ins, dels) in enumerate(steps):
        g, v = live.apply_delta(inserts=ins, deletes=dels)
        cat = mutate_catalog(cat, inserts=ins, deletes=dels)
        assert int(v) == i + 1
        assert graphs_identical(g, extract(cat, Q_DBLP, mode=mode).graph), i


@pytest.mark.parametrize("preprocess", [False, True])
def test_multi_atom_rule_delta(preprocess):
    """Join rules (hash-join segments interleave rows from both sides)
    fall back to recomputing the touched segment — still byte-identical,
    including under virtual-node preprocessing."""
    cat = tpch_catalog(200, 600, 60, 2.0, seed=1)
    live = LiveGraph(cat, Q_TPCH, mode="condensed", preprocess=preprocess)
    ins = {"LineItem": {"okey": np.array([5000001, 5000002]),
                        "pkey": np.array([9000001, 9000002])}}
    dels = {"Orders": ("okey", np.array([5000010]))}
    g, _ = live.apply_delta(inserts=ins, deletes=dels)
    mut = mutate_catalog(cat, inserts=ins, deletes=dels)
    ref = extract(mut, Q_TPCH, mode="condensed", preprocess=preprocess)
    assert graphs_identical(g, ref.graph)


def test_module_level_apply_delta_delegates(dblp):
    live = LiveGraph(dblp, Q_DBLP)
    ins = _ap_inserts([3], [1000002])
    g, v = apply_delta(live, inserts=ins)
    assert int(v) == 1
    assert graphs_identical(
        g, extract(mutate_catalog(dblp, inserts=ins), Q_DBLP).graph
    )


def test_delta_budget_accounting(dblp):
    """Delta applies are charged to the extraction budget: rows in/out
    counted, untouched rules reused (Nodes table untouched -> the Edges
    rule over AuthorPub recomputes but Author-derived state is reused)."""
    budget = ExtractionBudget()
    live = LiveGraph(dblp, Q_DBLP, budget=budget)
    live.apply_delta(inserts=_ap_inserts([1, 2], [1000001, 1000001]))
    assert budget.n_delta_applies == 1
    assert budget.delta_rows_inserted == 2
    assert budget.delta_rows_deleted == 0
    assert budget.delta_rules_recomputed == 1  # the AuthorPub edge rule
    live.apply_delta(deletes={"Author": ("aid", np.array([1]))})
    assert budget.n_delta_applies == 2
    assert budget.delta_rows_deleted >= 1
    assert "delta_rows_inserted" in budget.summary()


def test_mutate_catalog_reference_semantics(dblp):
    """Deletes first, inserts appended at the tail — so delete-then-
    reinsert of a key lands the fresh row at the end of the table."""
    ins = {"Author": {"aid": np.array([5]), "name": np.array(["author_5b"])}}
    dels = {"Author": ("aid", np.array([5]))}
    mut = mutate_catalog(dblp, inserts=ins, deletes=dels)
    a = mut.table("Author")
    aid = a.column("aid")
    assert len(a) == len(dblp.table("Author"))
    assert aid[-1] == 5 and np.count_nonzero(aid == 5) == 1
    assert mut.table("Author").column("name")[-1] == "author_5b"
    # the input catalog is never mutated in place
    assert np.count_nonzero(dblp.table("Author").column("aid") == 5) == 1
    assert dblp.table("Author").column("name")[5] != "author_5b"


def test_bad_deltas_rejected_and_state_unchanged(dblp, tmp_path):
    log = DeltaLog(str(tmp_path / "log"))
    live = LiveGraph(dblp, Q_DBLP, log=log)
    before = live.graph
    with pytest.raises(KeyError):
        live.apply_delta(inserts={"NoSuchTable": {"x": np.array([1])}})
    with pytest.raises(ValueError, match="column"):
        live.apply_delta(inserts={"Author": {"aid": np.array([999])}})  # no name
    with pytest.raises(ValueError, match="key column"):
        live.apply_delta(deletes={"Author": ("nope", np.array([1]))})
    # validation happens before the WAL append and before any state
    # change: the log stays clean, the version stays put
    assert len(log) == 0
    assert live.version == 0
    assert live.graph is before


# -- random-sequence property (tier2 hypothesis + offline seeds) -------------

def _random_delta(rng, n_authors):
    inserts, deletes = {}, {}
    if rng.random() < 0.8:
        k = int(rng.integers(1, 5))
        inserts["AuthorPub"] = {
            "aid": rng.integers(0, n_authors + 20, size=k),
            "pid": rng.integers(1_000_000, 1_000_040, size=k),
        }
    if rng.random() < 0.5:
        k = int(rng.integers(1, 4))
        deletes["AuthorPub"] = (
            "pid", rng.integers(1_000_000, 1_000_040, size=k)
        )
    if rng.random() < 0.4:
        ids = rng.integers(0, n_authors + 20, size=int(rng.integers(1, 3)))
        inserts["Author"] = {
            "aid": ids,
            "name": np.array([f"author_{i}r" for i in ids]),
        }
    if rng.random() < 0.3:
        deletes["Author"] = ("aid", rng.integers(0, n_authors, size=2))
    return inserts or None, deletes or None


def _check_delta_sequence(seed: int) -> None:
    rng = np.random.default_rng(seed)
    cat = dblp_catalog(n_authors=60, n_pubs=120, mean_authors_per_pub=3.0,
                       seed=seed % 7)
    live = LiveGraph(cat, Q_DBLP)
    for step in range(3):
        ins, dels = _random_delta(rng, 60)
        g, v = live.apply_delta(inserts=ins, deletes=dels)
        cat = mutate_catalog(cat, inserts=ins, deletes=dels)
        assert int(v) == step + 1
        ref = extract(cat, Q_DBLP)
        assert graphs_identical(g, ref.graph), f"seed={seed} step={step}"


@pytest.mark.tier2
@given(seed=st.integers(0, 100_000))
@settings(max_examples=15, deadline=None)
def test_random_delta_sequences_byte_identical(seed):
    _check_delta_sequence(seed)


@pytest.mark.parametrize("seed", [0, 1, 7, 42])
def test_random_delta_sequences_byte_identical_offline(seed):
    _check_delta_sequence(seed)


# -- delta log: WAL round trip, replay, crash safety -------------------------

def _logged_live(dblp, path):
    log = DeltaLog(str(path))
    live = LiveGraph(dblp, Q_DBLP, log=log)
    live.apply_delta(inserts=_ap_inserts([1], [1000009]))
    live.apply_delta(deletes={"Author": ("aid", np.array([3]))})
    live.apply_delta(
        inserts={"Author": {"aid": np.array([3]), "name": np.array(["author_3"])}},
        deletes={"AuthorPub": ("pid", np.array([1000000]))},
    )
    return log, live


def test_log_append_read_round_trip(dblp, tmp_path):
    log, _ = _logged_live(dblp, tmp_path / "log")
    assert len(log) == 3
    ins, dels = log.read(0)
    assert set(ins) == {"AuthorPub"} and dels == {}
    assert np.array_equal(ins["AuthorPub"]["pid"], [1000009])
    ins, dels = log.read(2)
    assert dels["AuthorPub"][0] == "pid"
    assert np.array_equal(dels["AuthorPub"][1], [1000000])
    assert ins["Author"]["name"].dtype.kind == "U"
    with pytest.raises(IndexError):
        log.read(3)


def test_replay_rebuilds_identical_graph(dblp, tmp_path):
    log, live = _logged_live(dblp, tmp_path / "log")
    relive = LiveGraph.replay(dblp, Q_DBLP, DeltaLog.open(str(tmp_path / "log")))
    assert relive.version == 3
    assert graphs_identical(relive.graph, live.graph)
    # the replayed LiveGraph stays live: more deltas land in the same log
    relive.apply_delta(inserts=_ap_inserts([2], [1000001]))
    assert len(log) == 3  # original handle unaware...
    assert len(DeltaLog.open(str(tmp_path / "log"))) == 4  # ...but durably 4


def test_fresh_livegraph_rejects_nonempty_log(dblp, tmp_path):
    log, _ = _logged_live(dblp, tmp_path / "log")
    with pytest.raises(ValueError, match="replay"):
        LiveGraph(dblp, Q_DBLP, log=log)


def test_torn_append_rejected_then_recovered(dblp, tmp_path):
    """A record committed but never certified by the manifest (crash
    between the two appends) is rejected at open; recover=True drops the
    tail and replay returns the last acknowledged graph."""
    log, live = _logged_live(dblp, tmp_path / "log")
    acked = live.graph
    # simulate the crash: commit entry 3's record without the manifest
    log.store.write_record(
        "delta_000003",
        {"ins0_0": np.array([9]), "ins0_1": np.array([1000011])},
        meta={"index": 3, "inserts": [["AuthorPub", ["aid", "pid"]]],
              "deletes": []},
    )
    with pytest.raises(SpillError, match="uncertified"):
        DeltaLog.open(str(tmp_path / "log"))
    recovered = DeltaLog(str(tmp_path / "log"), create=False, recover=True)
    assert len(recovered) == 3
    relive = LiveGraph.replay(dblp, Q_DBLP, recovered)
    assert graphs_identical(relive.graph, acked)


def test_tmp_litter_rejected_then_recovered(dblp, tmp_path):
    _logged_live(dblp, tmp_path / "log")
    os.makedirs(str(tmp_path / "log" / "delta_000099.tmp-123"))
    with pytest.raises(SpillError):
        DeltaLog.open(str(tmp_path / "log"))
    recovered = DeltaLog(str(tmp_path / "log"), create=False, recover=True)
    assert len(recovered) == 3


def test_truncated_certified_payload_rejected(dblp, tmp_path):
    """Corruption of a *certified* entry is never recovered over — the
    log refuses to replay rather than rebuild a wrong graph."""
    _logged_live(dblp, tmp_path / "log")
    rdir = str(tmp_path / "log" / "delta_000001")
    target = next(f for f in sorted(os.listdir(rdir)) if f.endswith(".bin"))
    with open(os.path.join(rdir, target), "r+b") as f:
        f.truncate(2)
    with pytest.raises(SpillError, match="truncated"):
        DeltaLog.open(str(tmp_path / "log"))
    with pytest.raises(SpillError, match="truncated"):
        DeltaLog(str(tmp_path / "log"), create=False, recover=True)


def test_missing_manifest_with_records_rejected(dblp, tmp_path):
    _logged_live(dblp, tmp_path / "log")
    os.remove(str(tmp_path / "log" / SPILL_MANIFEST))
    with pytest.raises(SpillError, match="certified"):
        DeltaLog(str(tmp_path / "log"), create=False)


def test_manifest_kind_checked(tmp_path):
    from repro.core import ShardSpillStore

    store = ShardSpillStore(str(tmp_path / "s"))
    store.finalize(meta={"kind": "something_else"})
    with pytest.raises(SpillError, match="delta log"):
        DeltaLog.open(str(tmp_path / "s"))


# -- pipeline resume: base graph + log -> current device graph ---------------

def test_pipeline_resumes_from_base_plus_log(dblp, tmp_path):
    from repro.data.pipeline import sharded_extract_to_device

    log, live = _logged_live(dblp, tmp_path / "log")
    res, dev = sharded_extract_to_device(
        dblp, Q_DBLP, n_shards=2, delta_log=DeltaLog.open(str(tmp_path / "log"))
    )
    assert graphs_identical(res.graph, live.graph)
    assert dev.graph_version == 3
    base_res, base_dev = sharded_extract_to_device(dblp, Q_DBLP, n_shards=2)
    assert base_dev.graph_version == 0
    assert not graphs_identical(base_res.graph, res.graph)
