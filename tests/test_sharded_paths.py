"""Numerical equivalence of the §Perf sharded paths (banded PageRank,
a2a MoE dispatch) against their single-device baselines.

These run in subprocesses with 8 forced host devices — the main pytest
process must keep seeing exactly 1 device (smoke-test contract).
"""
import os
import subprocess
import sys

import pytest

REPO = os.path.join(os.path.dirname(__file__), "..")

BANDED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core import algorithms, dedup, engine
from repro.core.banding import band_partition, make_banded_pagerank
from repro.data.synth import barabasi_albert_condensed

n_shards = 8
g = barabasi_albert_condensed(4096, 512, 10.0, 3.0, seed=3)   # 4096 % 8 == 0
corr = dedup.build_correction(g)
dev = engine.to_device(g, correction=corr)
ref = np.asarray(algorithms.pagerank(dev, num_iters=15))

deg = np.asarray(algorithms.out_degrees(dev))
banded = band_partition(g, corr, n_shards, deg)
mesh = jax.make_mesh((4, 2), ("data", "model"))
fn = make_banded_pagerank(mesh, ("data", "model"), banded.n_real,
                          banded.n_virtual, n_shards, iters=15)
sh = NamedSharding(mesh, P(("data", "model")))
args = {k: jax.device_put(jnp.asarray(getattr(banded, k)), sh)
        for k in ("in_src", "in_dst", "out_src", "out_dst",
                   "corr_src", "corr_dst", "corr_cnt", "deg")}
got = np.asarray(jax.jit(fn)(args))[: g.n_real]
d = np.abs(got - ref).max()
assert d < 1e-7, f"banded mismatch {d}"
print("BANDED_OK", d)
"""

A2A_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs.base import MoEConfig
from repro.distributed.sharding import use_mesh_rules
from repro.models import moe as moe_lib

mesh = jax.make_mesh((2, 4), ("data", "model"))
rules = {"experts": "model", "expert_ff": None, "expert_capacity": None,
         "embed": None, "batch": "data"}
cfg_sort = MoEConfig(n_experts=8, top_k=2, d_expert=32, capacity_factor=8.0,
                     dispatch="sort")
cfg_a2a = dataclasses.replace(cfg_sort, dispatch="a2a")
params = moe_lib.moe_init(jax.random.PRNGKey(0), 16, cfg_sort)
x = jax.random.normal(jax.random.PRNGKey(1), (64, 16))

y_ref, m_ref = moe_lib.moe_apply(params, x, cfg_sort)      # no mesh: dense path
with use_mesh_rules(mesh, rules):
    xs = jax.device_put(x, NamedSharding(mesh, P(("data", "model"), None)))
    ps = jax.device_put(params, NamedSharding(mesh, P()))
    y_a2a, m_a2a = jax.jit(
        lambda p, x: moe_lib.moe_apply(p, x, cfg_a2a)
    )(ps, xs)
d = float(jnp.abs(y_a2a - y_ref).max())
# ample capacity on both sides -> identical routing, tight match
assert d < 1e-4, f"a2a mismatch {d}"
assert float(m_a2a["moe_drop_fraction"]) == 0.0
print("A2A_OK", d)
"""


def _run(script: str) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        timeout=600, cwd=REPO, env=env,
    )
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    return proc.stdout


@pytest.mark.slow
def test_banded_pagerank_matches_engine():
    out = _run(BANDED_SCRIPT)
    assert "BANDED_OK" in out


@pytest.mark.slow
def test_a2a_moe_matches_dense():
    out = _run(A2A_SCRIPT)
    assert "A2A_OK" in out
