import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models.layers import embedding_bag, flash_attention, rms_norm, rope


def naive_attention(q, k, v, causal=True):
    B, T, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, T, KV, G, D)
    s = jnp.einsum("bqkgd,bskd->bqkgs", qg, k) / np.sqrt(D)
    if causal:
        mask = jnp.tril(jnp.ones((T, T), bool))
        s = jnp.where(mask[None, :, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqkgs,bskd->bqkgd", p, v).reshape(B, T, H, D)


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("shape", [(1, 8, 2, 2, 4), (2, 37, 8, 4, 16), (2, 64, 4, 1, 8)])
def test_flash_attention_forward(causal, shape):
    B, T, H, KV, D = shape
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, T, H, D))
    k = jax.random.normal(ks[1], (B, T, KV, D))
    v = jax.random.normal(ks[2], (B, T, KV, D))
    out = flash_attention(q, k, v, causal=causal, block_q=8, block_kv=16)
    ref = naive_attention(q, k, v, causal)
    assert float(jnp.abs(out - ref).max()) < 1e-4


@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_custom_vjp_gradients(causal):
    B, T, H, KV, D = 2, 29, 4, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, T, H, D))
    k = jax.random.normal(ks[1], (B, T, KV, D))
    v = jax.random.normal(ks[2], (B, T, KV, D))
    f1 = lambda q, k, v: jnp.sum(
        jnp.sin(flash_attention(q, k, v, causal=causal, block_q=8, block_kv=8))
    )
    f2 = lambda q, k, v: jnp.sum(jnp.sin(naive_attention(q, k, v, causal)))
    g1 = jax.grad(f1, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f2, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        assert float(jnp.abs(a - b).max()) < 1e-4


def test_flash_attention_bwd_saves_no_quadratic_residuals():
    """The custom VJP must not stash (Tq, Tk) probability blocks."""
    B, T, H, KV, D = 1, 256, 2, 1, 8
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (B, T, H, D))
    k = jax.random.normal(ks[1], (B, T, KV, D))
    v = jax.random.normal(ks[2], (B, T, KV, D))
    fn = jax.jit(
        lambda q, k, v: jax.grad(
            lambda q: jnp.sum(flash_attention(q, k, v, block_q=32, block_kv=32))
        )(q)
    )
    txt = fn.lower(q, k, v).compile().as_text()
    # no tensor anywhere near T*T*heads f32 (= 512 KiB) should be stored
    import re

    for m in re.finditer(r"f32\[([\d,]+)\]", txt):
        dims = [int(d) for d in m.group(1).split(",")]
        n = int(np.prod(dims))
        assert n < T * T, f"quadratic residual found: {m.group(0)}"


def test_flash_decode_path_with_cache_semantics():
    B, T, H, KV, D = 2, 24, 4, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (B, 1, H, D))
    k = jax.random.normal(ks[1], (B, T, KV, D))
    v = jax.random.normal(ks[2], (B, T, KV, D))
    valid = jnp.array([10, 17], dtype=jnp.int32)
    out = flash_attention(
        q, k, v, causal=False, q_offset=jnp.array(9), kv_length=valid,
        block_q=4, block_kv=8,
    )
    # oracle: mask beyond valid length
    for b, n in enumerate([10, 17]):
        ref = naive_attention(
            q[b : b + 1], k[b : b + 1, :n], v[b : b + 1, :n], causal=False
        )
        assert float(jnp.abs(out[b : b + 1] - ref).max()) < 1e-4


@given(seed=st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_embedding_bag_matches_loop(seed):
    rng = np.random.default_rng(seed)
    n_items, d = int(rng.integers(3, 30)), int(rng.integers(1, 9))
    n_lookups = int(rng.integers(1, 50))
    n_bags = int(rng.integers(1, 8))
    table = rng.standard_normal((n_items, d)).astype(np.float32)
    idx = rng.integers(0, n_items, n_lookups)
    seg = np.sort(rng.integers(0, n_bags, n_lookups))
    w = rng.standard_normal(n_lookups).astype(np.float32)
    for mode in ("sum", "mean", "max"):
        got = embedding_bag(
            jnp.asarray(table), jnp.asarray(idx), jnp.asarray(seg), n_bags,
            mode=mode, weights=jnp.asarray(w) if mode == "sum" else None,
        )
        want = np.zeros((n_bags, d), dtype=np.float64)
        for b in range(n_bags):
            rows = table[idx[seg == b]]
            if mode == "sum":
                rows = rows * w[seg == b][:, None]
                want[b] = rows.sum(0) if rows.size else 0
            elif mode == "mean":
                want[b] = rows.mean(0) if rows.size else 0
            else:
                want[b] = rows.max(0) if rows.size else 0
        assert np.allclose(np.asarray(got), want, atol=1e-4), mode


def test_rope_properties():
    # relative-position property: <rope(q,i), rope(k,j)> depends on i-j only
    D = 16
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 1, 1, D))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, D))
    def dot_at(i, j):
        qi = rope(q, jnp.array([i]), theta=10_000.0)
        kj = rope(k, jnp.array([j]), theta=10_000.0)
        return float(jnp.sum(qi * kj))
    assert abs(dot_at(3, 1) - dot_at(10, 8)) < 1e-4
    assert abs(dot_at(0, 0) - float(jnp.sum(q * k))) < 1e-5


def test_rms_norm_scale_invariance():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 8))
    w = jnp.ones((8,))
    y1 = rms_norm(x, w)
    y2 = rms_norm(x * 7.3, w)
    assert float(jnp.abs(y1 - y2).max()) < 1e-4
