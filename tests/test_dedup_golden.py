"""Seeded golden regressions for the dedup family.

Fixed-seed graphs with recorded representation sizes: expanded edge
counts, BITMAP byte footprints, DEDUP-1 edge totals, DEDUP-2 structure.
A change in any of these numbers is a representation-size regression (or
an intentional algorithm change) — it should fail loudly here instead of
only drifting in benchmark output.  All values were recorded from the
implementation at the time this harness was added; update them only with
an explanation of why the representation legitimately changed.
"""
import numpy as np
import pytest

from repro.core import dedup
from repro.data.synth import barabasi_albert_condensed, layered_condensed


def _ba_sparse():
    return barabasi_albert_condensed(200, 80, 5.0, 2.0, seed=11)


def _ba_dense():
    return barabasi_albert_condensed(150, 12, 40.0, 8.0, seed=12)


def _layered():
    return layered_condensed(60, [20, 15], [150, 100, 150], seed=13, symmetric=False)


GOLDEN_GRAPHS = {
    # name: (factory, cond_edges, exp_edges, paths, corr_nnz, corr_sum)
    "ba_sparse": (_ba_sparse, 736, 999, 1900, 279, 1004),
    "ba_dense": (_ba_dense, 980, 6740, 20576, 3206, 13936),
    "layered": (_layered, 495, 3005, 14151, 2576, 11196),
}


@pytest.mark.parametrize("name", sorted(GOLDEN_GRAPHS))
def test_golden_graph_and_correction_sizes(name):
    factory, cond_edges, exp_edges, paths, corr_nnz, corr_sum = GOLDEN_GRAPHS[name]
    g = factory()
    assert g.n_edges_condensed == cond_edges
    assert g.n_edges_expanded() == exp_edges
    assert g.n_paths_expanded() == paths
    cs, cd, cm = dedup.build_correction(g)
    assert cs.size == corr_nnz
    assert int(cm.sum()) == corr_sum
    streamed = dedup.build_correction_streaming(g, budget_triples=4 * exp_edges)
    assert streamed.nnz == corr_nnz and int(streamed.count.sum()) == corr_sum


GOLDEN_BITMAPS = {
    # name: (bitmap1_nbytes, bitmap1_n, bitmap2_nbytes, bitmap2_n)
    "ba_sparse": (14966, 368, 13062, 249),
    "ba_dense": (22180, 490, 18772, 277),
}


@pytest.mark.parametrize("name", sorted(GOLDEN_BITMAPS))
def test_golden_bitmap_sizes(name):
    b1_bytes, b1_n, b2_bytes, b2_n = GOLDEN_BITMAPS[name]
    g = GOLDEN_GRAPHS[name][0]()
    b1 = dedup.bitmap1(g)
    assert (b1.nbytes(), b1.n_bitmaps) == (b1_bytes, b1_n)
    b2 = dedup.bitmap2(g)
    assert (b2.nbytes(), b2.n_bitmaps) == (b2_bytes, b2_n)
    assert b2.nbytes() < b1.nbytes()  # set cover must not regress past BITMAP-1


GOLDEN_DEDUP1 = {
    # name: {algorithm: total_edges}
    "ba_sparse": {
        "dedup1_naive_virtual_first": 285,
        "dedup1_naive_real_first": 281,
        "dedup1_greedy_real_first": 284,
        "dedup1_greedy_virtual_first": 270,
    },
    "ba_dense": {
        "dedup1_naive_virtual_first": 1577,
        "dedup1_naive_real_first": 1466,
        "dedup1_greedy_real_first": 1495,
        "dedup1_greedy_virtual_first": 1584,
    },
}


@pytest.mark.parametrize("name", sorted(GOLDEN_DEDUP1))
def test_golden_dedup1_edge_totals(name):
    g = GOLDEN_GRAPHS[name][0]()
    for fn_name, want in GOLDEN_DEDUP1[name].items():
        fn = getattr(dedup, fn_name)
        res = fn(g, ordering="identity", rng=np.random.default_rng(0))
        assert res.total_edges == want, fn_name


GOLDEN_DEDUP2 = {
    # name: (n_edges, n_sets, n_vv_edges, n_pairs)
    "ba_sparse": (432, 177, 16, 448),
    "ba_dense": (2821, 1222, 342, 3320),
}


@pytest.mark.parametrize("name", sorted(GOLDEN_DEDUP2))
def test_golden_dedup2_structure_and_multiplicities(name):
    n_edges, n_sets, n_vv, n_pairs = GOLDEN_DEDUP2[name]
    g = GOLDEN_GRAPHS[name][0]()
    rep = dedup.dedup2_greedy(g, ordering="identity", rng=np.random.default_rng(0))
    assert rep.n_edges == n_edges
    assert len(rep.sets) == n_sets
    assert len(rep.vv_edges) == n_vv
    mult = rep.pair_multiplicities()
    assert len(mult) == n_pairs
    assert all(c == 1 for c in mult.values())
